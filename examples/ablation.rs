//! Table 9 ablation driver: quantify each H2 component's contribution on
//! the Exp-C-1 configuration (and optionally any other experiment).
//!
//! ```bash
//! cargo run --release --example ablation
//! ```

use anyhow::Result;
use h2::report::table9_ablation;
use h2::util::table::Table;

fn main() -> Result<()> {
    let rows = table9_ablation()?;
    let mut t = Table::new(&["variant", "relative iteration time", "paper"])
        .with_title("Table 9 — component ablations on Exp-C-1");
    for r in &rows {
        t.row(vec![
            r.label.to_string(),
            format!("{:.1}%", r.relative_percent),
            format!("{:.1}%", r.paper_percent),
        ]);
    }
    t.print();
    println!("\nreading: >100% = slower than the full H2 system. The paper's");
    println!("dominant factor is HeteroPP's non-uniform sharding (126.4%),");
    println!("followed by DDR (110.1%), SR&AG (104.8%) and overlap (101.8%).");
    Ok(())
}
