//! Table 9 ablation driver: quantify each H2 component's contribution on
//! the Exp-C-1 configuration, plus the pipeline-schedule axis (1F1B vs
//! interleaved vs zero-bubble) the paper's single-α model could not
//! measure — each schedule here runs a real issue order in the simulator
//! (see the `Schedule` API in `h2::costmodel`).
//!
//! ```bash
//! cargo run --release --example ablation
//! ```

use anyhow::Result;
use h2::report::{schedule_axis, table9_ablation};
use h2::util::table::Table;

fn main() -> Result<()> {
    let rows = table9_ablation()?;
    let mut t = Table::new(&["variant", "relative iteration time", "paper"])
        .with_title("Table 9 — component ablations on Exp-C-1");
    for r in &rows {
        t.row(vec![
            r.label.to_string(),
            format!("{:.1}%", r.relative_percent),
            format!("{:.1}%", r.paper_percent),
        ]);
    }
    t.print();
    println!("\nreading: >100% = slower than the full H2 system. The paper's");
    println!("dominant factor is HeteroPP's non-uniform sharding (126.4%),");
    println!("followed by DDR (110.1%), SR&AG (104.8%) and overlap (101.8%).");

    let axis = schedule_axis("exp-c-1")?;
    let mut t = Table::new(&["schedule", "iteration", "TGS"])
        .with_title("Schedule axis — HeteroAuto pinned per schedule on Exp-C-1");
    for r in &axis {
        t.row(vec![
            r.schedule.to_string(),
            r.iteration_seconds.map(|s| format!("{s:.3}s")).unwrap_or("infeasible".into()),
            r.tgs.map(|x| format!("{x:.1}")).unwrap_or("-".into()),
        ]);
    }
    t.print();
    Ok(())
}
