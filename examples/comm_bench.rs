//! DiComm explorer: latency sweep across strategies, collective costs, and
//! the NIC-affinity effect — the communication half of the paper in one
//! binary.
//!
//! ```bash
//! cargo run --release --example comm_bench
//! ```

use h2::comm::collectives::{ring_allgather, ring_allreduce, tree_broadcast};
use h2::comm::{cross_node_time, p2p_latency, CommMode, CommTopology};
use h2::hetero::{spec, ChipKind};
use h2::sim::{reshard_time, ReshardStrategy};
use h2::topology::NicAssignment;
use h2::util::rng::Rng;
use h2::util::table::{fmt_bytes, fmt_duration, Table};

fn main() {
    // 1. Strategy sweep (Fig 7 shape).
    let mut t = Table::new(&["size", "TCP", "CPU-RDMA", "DDR"])
        .with_title("P2P latency by strategy");
    for shift in [10usize, 14, 18, 22, 26] {
        let bytes = 1usize << shift;
        t.row(vec![
            fmt_bytes(bytes as f64),
            fmt_duration(p2p_latency(CommMode::TcpCpu, bytes)),
            fmt_duration(p2p_latency(CommMode::RdmaCpu, bytes)),
            fmt_duration(p2p_latency(CommMode::DeviceDirect, bytes)),
        ]);
    }
    t.print();

    // 2. Real collectives with modeled wire time.
    let mut rng = Rng::new(3);
    let mut bufs: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..65536).map(|_| rng.f32()).collect())
        .collect();
    // Hop times from the Chip-A DP-group topology (cross-node link), the
    // same spec-derived model the coordinator's DpGroup runs on.
    let topo = CommTopology::dp_group(&spec(ChipKind::A), 8, 2, NicAssignment::Affinity);
    let hop = |bytes: usize| topo.inter.time(bytes);
    let ar = ring_allreduce(&mut bufs, &hop);
    let (_, ag) = ring_allgather(&bufs, &hop);
    let bc = tree_broadcast(&mut bufs, 0, &hop);
    println!("\ncollectives over 8 ranks x 256KB:");
    println!("  ring allreduce : {}  ({} on wire)", fmt_duration(ar.seconds),
             fmt_bytes(ar.wire_bytes as f64));
    println!("  ring allgather : {}  ({} on wire)", fmt_duration(ag.seconds),
             fmt_bytes(ag.wire_bytes as f64));
    println!("  tree broadcast : {}  ({} on wire)", fmt_duration(bc.seconds),
             fmt_bytes(bc.wire_bytes as f64));

    // 3. Cross-node per-pair times + affinity effect (Table 3 flavour).
    let mut t = Table::new(&["pair", "affinity", "non-affinity"])
        .with_title("\n64MiB cross-node transfer (DDR)");
    for (a, b) in [(ChipKind::A, ChipKind::B), (ChipKind::B, ChipKind::D),
                   (ChipKind::A, ChipKind::C)] {
        let sa = spec(a);
        let sb = spec(b);
        t.row(vec![
            format!("{a} -> {b}"),
            fmt_duration(cross_node_time(CommMode::DeviceDirect, 64 << 20, &sa, &sb,
                                         NicAssignment::Affinity)),
            fmt_duration(cross_node_time(CommMode::DeviceDirect, 64 << 20, &sa, &sb,
                                         NicAssignment::NonAffinity)),
        ]);
    }
    t.print();

    // 4. Resharding strategies at a hetero stage boundary (Fig 10 / §5).
    let a = spec(ChipKind::A);
    let b = spec(ChipKind::B);
    let act = 4096 * 8192 * 2; // one 100B-model activation, bf16
    let mut t = Table::new(&["strategy", "time"])
        .with_title("\nactivation resharding A(tp4) -> B(tp2), 64MiB activation");
    for s in [ReshardStrategy::NaiveP2p, ReshardStrategy::Broadcast,
              ReshardStrategy::SendRecvAllGather] {
        t.row(vec![
            s.name().to_string(),
            fmt_duration(reshard_time(s, CommMode::DeviceDirect, act, &a, 4, &b, 2,
                                      NicAssignment::Affinity)),
        ]);
    }
    t.print();
}
