//! End-to-end validation (DESIGN.md §5): train the ~107M-parameter
//! `h2_100m` transformer with the full H2 stack — HeteroAuto-style stage
//! placement (big-memory Chip-A first, Chip-B later, non-uniform 10/6 layer
//! split), real 1F1B pipeline over PJRT stage executables, DP gradient
//! allreduce over DiComm — and log the loss curve.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_e2e -- [--steps 200] [--dp 1]
//!     [--micros 2] [--uniform] [--csv loss.csv]
//! ```
//!
//! The recorded 300-step run lives in EXPERIMENTS.md §E2E.

use anyhow::Result;
use h2::coordinator::{train, StagePlan, TrainConfig};
use h2::hetero::ChipKind;
use h2::runtime::Runtime;
use h2::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 200)?;
    let dp = args.usize_or("dp", 1)?;
    let micros = args.usize_or("micros", 2)?;

    // HeteroPP placement: Chip-A (96 GB) takes the deeper early stage with
    // MORE layers (10/6 split, Observations #3+#4); `--uniform` falls back
    // to the homogeneous-style 8/8 split for comparison.
    let stages = if args.has("uniform") {
        vec![
            StagePlan { prefix: "first_l8".into(), chip: ChipKind::A },
            StagePlan { prefix: "last_l8".into(), chip: ChipKind::B },
        ]
    } else {
        vec![
            StagePlan { prefix: "first_l10".into(), chip: ChipKind::A },
            StagePlan { prefix: "last_l6".into(), chip: ChipKind::B },
        ]
    };

    let mut cfg = TrainConfig::quick("h2_100m", stages, dp, micros, steps);
    cfg.lr = args.f64_or("lr", 2e-3)? as f32;
    cfg.log_every = args.usize_or("log-every", 5)?;
    let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
    let entry = rt.manifest.model("h2_100m")?;
    println!("[e2e] h2_100m: {:.1}M params, {} layers, split {}",
             entry.param_count as f64 / 1e6, entry.n_layers,
             if args.has("uniform") { "8/8 uniform" } else { "10/6 HeteroPP" });
    println!("[e2e] pipeline: {} stages x dp {} x {} micros, {} steps",
             cfg.stages.len(), dp, micros, steps);

    let report = train(&rt, &cfg)?;

    println!("[e2e] wall {:.1}s  ({:.2}s/step, {:.0} tokens/s real)",
             report.wall_seconds, report.wall_seconds / steps as f64,
             report.tokens_per_second);
    println!("[e2e] modeled comm per step: {:.4}s",
             report.virtual_comm_seconds / steps as f64);
    println!("[e2e] loss: {:.4} -> {:.4}",
             report.losses.first().unwrap(), report.losses.last().unwrap());

    if let Some(path) = args.get("csv") {
        let mut csv = String::from("step,loss\n");
        for (i, l) in report.losses.iter().enumerate() {
            csv.push_str(&format!("{i},{l:.6}\n"));
        }
        std::fs::write(path, csv)?;
        println!("[e2e] loss curve written to {path}");
    }

    // The run is only a success if the model actually learned. Short runs
    // validate composition with a modest threshold (at 512 tokens/step the
    // early-phase LM descent is ~0.003 nats/step at lr 4e-4); the recorded
    // EXPERIMENTS.md §E2E runs show the longer trajectories.
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    let expected_drop = (0.002 * steps as f64).min(1.0);
    anyhow::ensure!(last < first - expected_drop,
                    "loss did not fall enough: {first:.3} -> {last:.3}");
    println!("[e2e] OK — all three layers compose (Pallas kernels -> JAX stages -> rust 1F1B)");
    Ok(())
}
