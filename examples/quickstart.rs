//! Quickstart: load the fused `train_step` artifact and train the tiny
//! model on the synthetic corpus for a handful of steps — the smallest
//! possible tour of the AOT → PJRT → rust loop.
//!
//! For the search → plan → simulate loop (including the `Schedule` API:
//! 1F1B / interleaved / zero-bubble pipelines), see
//! `examples/auto_search.rs`, `examples/ablation.rs`, and the compiled
//! doctests in `rust/src/lib.rs`.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use h2::coordinator::data::Corpus;
use h2::coordinator::params::{init_params, zeros_like};
use h2::runtime::{HostTensor, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let model = rt.manifest.model("h2_tiny")?.clone();
    println!("model h2_tiny: {} layers, {} params",
             model.n_layers, model.param_count);

    let step_exe = rt.load("h2_tiny", "train_step")?;
    let meta = step_exe.meta.clone();
    let n_p = meta.params.len();
    let (batch, seq) = (meta.micro_batch.unwrap(), meta.seq.unwrap());

    let mut params = init_params(&meta.params, 42);
    let mut m = zeros_like(&meta.params);
    let mut v = zeros_like(&meta.params);
    let corpus = Corpus::new(model.vocab, 7);

    println!("training {} steps (batch {batch} x seq {seq})...", 30);
    for step in 0..30u32 {
        let (inp, tgt) = corpus.microbatch(step as usize, 0, 0, batch, seq);
        let mut inputs = Vec::with_capacity(3 * n_p + 4);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(HostTensor::i32(&[batch, seq], inp));
        inputs.push(HostTensor::i32(&[batch, seq], tgt));
        inputs.push(HostTensor::scalar_f32((step + 1) as f32));
        inputs.push(HostTensor::scalar_f32(3e-3));
        let out = step_exe.run(&inputs)?;
        let loss = out[0].as_f32()?[0];
        if step % 5 == 0 || step == 29 {
            println!("  step {step:>3}  loss {loss:.4}");
        }
        params = out[1..1 + n_p].to_vec();
        m = out[1 + n_p..1 + 2 * n_p].to_vec();
        v = out[1 + 2 * n_p..1 + 3 * n_p].to_vec();
    }
    println!("done — python was never on this path.");
    Ok(())
}
