//! HeteroAuto walkthrough: search strategies for every Table 7 experiment
//! — across 1F1B / interleaved / zero-bubble pipeline schedules, in
//! parallel with branch-and-bound pruning — and print the chosen plan,
//! schedule, iteration estimate, TGS, and search cost: the `search`
//! subcommand in batch form.
//!
//! ```bash
//! cargo run --release --example auto_search
//! ```

use anyhow::Result;
use h2::auto::{search, SearchConfig};
use h2::costmodel::{tgs, H2_100B};
use h2::hetero::{experiment, ALL_EXPERIMENTS};
use h2::util::table::{fmt_duration, Table};

fn main() -> Result<()> {
    for exp_name in ALL_EXPERIMENTS {
        let exp = experiment(exp_name)?;
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default())?;
        println!("\n=== {exp_name}: {} chips, GBS {}M tokens ===",
                 exp.cluster.total_chips(), exp.gbs_tokens >> 20);
        println!("searched {} candidates in {} (paper budget for this class: seconds)",
                 r.candidates_explored, fmt_duration(r.elapsed_seconds));
        let mut t = Table::new(&["group", "chips", "s_pp", "s_tp", "layers/stage",
                                 "recompute"]);
        for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
            t.row(vec![
                g.spec.kind.to_string(),
                g.n_chips.to_string(),
                p.s_pp.to_string(),
                p.s_tp.to_string(),
                format!("{}", p.layers_per_stage()),
                p.recompute.to_string(),
            ]);
        }
        t.print();
        println!("s_dp {}, {} micro-batches, schedule {}, est. iteration {}, TGS {:.1}",
                 r.strategy.s_dp, r.strategy.micro_batches, r.strategy.schedule,
                 fmt_duration(r.eval.iteration_seconds),
                 tgs(&exp.cluster, exp.gbs_tokens, r.eval.iteration_seconds));
    }
    Ok(())
}
