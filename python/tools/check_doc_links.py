#!/usr/bin/env python3
"""Docs link-and-anchor checker (CI gate, stdlib only).

Walks the repo's markdown docs (README.md, EXPERIMENTS.md, ROADMAP.md,
docs/*.md), extracts every inline link, and fails on:

* relative links to files that do not exist (external URLs are skipped —
  the checker must pass offline);
* fragment links (`path#anchor` or `#anchor`) whose anchor matches no
  heading in the target file, using GitHub's slug rules (lowercase,
  punctuation stripped, spaces to hyphens, `-N` suffixes for
  duplicates).

Usage: python3 python/tools/check_doc_links.py  (from the repo root;
exits non-zero listing every broken link).
"""

import re
import sys
from pathlib import Path

DOC_FILES = ["README.md", "EXPERIMENTS.md", "ROADMAP.md"]
DOC_GLOBS = ["docs/*.md"]

# Inline markdown links [text](target). Images (![alt](src)) are checked
# the same way — a missing image is as broken as a missing page.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown markup, lowercase, drop
    punctuation, spaces to hyphens."""
    # Inline code/emphasis markers contribute their text only.
    text = re.sub(r"[`*_]", "", heading)
    # Links in headings anchor on their text.
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = text.strip().lower()
    out = []
    for ch in text:
        if ch.isalnum():
            out.append(ch)
        elif ch in (" ", "-"):
            out.append("-" if ch == " " else ch)
        # Everything else (punctuation, em dashes, §, ...) drops out.
    return "".join(out)


def anchors_of(path: Path) -> set:
    """All heading anchors of a markdown file, with GitHub's -N
    deduplication for repeated headings."""
    slugs = {}
    anchors = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = slugs.get(slug, 0)
        slugs[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def doc_files(root: Path):
    files = [root / f for f in DOC_FILES if (root / f).exists()]
    for pattern in DOC_GLOBS:
        files.extend(sorted(root.glob(pattern)))
    return files


def links_of(path: Path):
    """(line_number, target) pairs for every inline link, skipping
    fenced code blocks (their example links are illustrative)."""
    links = []
    in_fence = False
    for i, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            links.append((i, m.group(1)))
    return links


def main() -> int:
    root = Path(__file__).resolve().parents[2]
    anchor_cache = {}

    def anchors(p: Path):
        key = p.resolve()
        if key not in anchor_cache:
            anchor_cache[key] = anchors_of(p)
        return anchor_cache[key]

    errors = []
    checked = 0
    for doc in doc_files(root):
        for line, target in links_of(doc):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
                continue
            checked += 1
            where = f"{doc.relative_to(root)}:{line}"
            path_part, _, fragment = target.partition("#")
            dest = doc if not path_part else (doc.parent / path_part)
            if not dest.exists():
                errors.append(f"{where}: dead link `{target}` ({path_part} not found)")
                continue
            if fragment:
                if dest.is_dir() or dest.suffix.lower() != ".md":
                    continue  # anchors only checkable in markdown
                if fragment.lower() not in anchors(dest):
                    errors.append(
                        f"{where}: missing anchor `#{fragment}` in {path_part or doc.name}"
                    )

    if errors:
        print(f"{len(errors)} broken doc link(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"doc links OK: {checked} relative links/anchors across {len(doc_files(root))} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
