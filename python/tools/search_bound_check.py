"""Independent cross-check of HeteroAuto's branch-and-bound lower bound.

A from-scratch Python port of the §4.3.2 cost model, the layer-sharding
heuristic and the search's admissible lower bound (mirroring
`rust/src/auto/search.rs` + `costmodel/`), used to hold the EXPERIMENTS.md
§Perf work counts and the pruning invariants without touching the Rust:

  * every leaf's bound must not exceed its true evaluated cost
    (admissibility — the "strict pruning => bit-identical winner" pillar);
  * the stronger bound must return the same winner as a compute-only
    bound (and it does, with evaluated+pruned partitioning the space);
  * the exp-mega fixture (1,280 chips, 4 vendors) must search feasibly.

Run:  python3 python/tools/search_bound_check.py          # exp-a-1 checks
      python3 python/tools/search_bound_check.py --mega   # + mega (slow, ~3 min)

Constants here are hand-copied from the Rust; if the cost model changes,
update both or the assertions below will say so.
"""
import math, itertools, sys

# ---------------- chip catalog (chip.rs) ----------------
class Link:
    def __init__(self, kind, **kw): self.kind=kind; self.__dict__.update(kw)
    def bw(self, a, b):
        if self.kind=='uniform': return self.gbps
        if self.kind=='numa':
            return self.local if a//self.isl==b//self.isl else self.cross
        if self.kind=='pcie':
            return self.local if a//self.group==b//self.group else self.cross
    def island_of(self, cpn):
        if self.kind=='uniform': return cpn
        if self.kind=='numa': return self.isl
        return self.grp

class Spec:
    def __init__(self, kind, tflops, mem, cpn, link, nics, nic_gbps, mfu, pcie, share):
        self.kind=kind; self.fp16=tflops; self.mem=mem; self.cpn=cpn
        self.link=link; self.nics=nics; self.nic_gbps=nic_gbps; self.mfu=mfu
        self.pcie=pcie; self.share=share
    def sustained(self): return self.fp16*self.mfu
    def tp_max(self):
        isl=self.link.island_of(self.cpn); tp=1
        while tp*2<=isl: tp*=2
        return tp
    def mem_bytes(self): return self.mem*1024**3

SPECS = {
 'A': Spec('A',182.0,96.0,16,Link('uniform',gbps=200.0),8,25.0,0.573,11.95,0.576),
 'B': Spec('B',256.0,64.0,8,Link('numa',local=160.0,cross=56.0,isl=4),4,25.0,0.570,12.39,0.528),
 'C': Spec('C',128.0,32.0,16,Link('pcie',local=64.0,cross=24.0,group=4,grp=4),2,12.5,0.367,8.2,0.50),
 'D': Spec('D',550.0,32.0,8,Link('uniform',gbps=180.0),8,25.0,0.30,12.39,0.55),
}
# fix link island/group attr naming
for s in SPECS.values():
    if s.link.kind=='numa': s.link.island_= s.link.isl
H2_100B = dict(n_layers=96, hidden=8192, n_heads=64, n_kv_heads=8,
               intermediate=36864, vocab=92544, seq_len=4096)

def head_dim(m): return m['hidden']//m['n_heads']
def kv_dim(m): return m['n_kv_heads']*head_dim(m)
def params_per_layer(m):
    h=m['hidden']; kd=kv_dim(m); i=m['intermediate']
    return 2.0*h*h + 2.0*h*kd + 3.0*h*i + 2.0*h
def fwd_flops_per_token_layer(m):
    return 2.0*params_per_layer(m) + 4.0*m['seq_len']*m['hidden']

RDMA_EFF=0.8; INTRA_LAT=0.8e-6; DDR_LAT=3.0e-6
DP_OVERLAP=0.7; ADAM=12.0; PCIE_OFF=12.0e9
MEM_SAFETY=0.92

def flow_bw_gbps(src, dst, affinity=True):
    def path(spec, aff):
        rate=spec.pcie*RDMA_EFF
        if not aff: rate*=spec.share
        cpn_per_nic=max(spec.cpn/spec.nics,1.0)
        return min(rate, spec.nic_gbps*RDMA_EFF/cpn_per_nic)
    return min(path(src,affinity), path(dst,True))

def whole_node_group(n_ranks, rpn):
    cap=max(1,min(rpn,max(n_ranks,1)))
    for k in range(cap,0,-1):
        if n_ranks%k==0: return k
    return 1

def co_located(spec, s_tp, dp):
    return whole_node_group(max(dp,1), max(spec.cpn//max(s_tp,1),1))

class Topo:
    def __init__(self, n, rpn, intra, inter):
        self.n=n; self.rpn=rpn; self.intra=intra; self.inter=inter
    def node_group(self): return whole_node_group(self.n, self.rpn)
    def nodes(self): return max(self.n,1)//self.node_group()

def link_time(lat,bw): return (lat,bw)
def lt(l,bytes_): return l[0]+bytes_/l[1]

def dp_group(spec, dp, s_tp):
    slot=min(max(s_tp,1), max(spec.cpn-1,1))
    intra_bw=spec.link.bw(0, min(slot, spec.cpn-1))
    return Topo(max(dp,1), co_located(spec,s_tp,dp),
                (INTRA_LAT, intra_bw*1e9),
                (DDR_LAT, flow_bw_gbps(spec,spec)*1e9))

def ring_cost(bytes_,n,link):
    if n<=1 or bytes_==0: return 0.0
    steps=2*(n-1)
    return steps*lt(link, -(-bytes_//n))
def tree_cost(bytes_,n,link):
    if n<=1 or bytes_==0: return 0.0
    rounds=(1<<((n-1).bit_length())).bit_length()-1  # log2 next_pow2
    return 2.0*rounds*lt(link,bytes_)
def rhd_cost(bytes_,n,link):
    if n<=1 or bytes_==0: return 0.0
    p = n if (n & (n-1))==0 else (1<<((n-1).bit_length()))//2
    extras=n-p; sec=0.0
    if extras>0: sec+=2.0*lt(link,bytes_)
    sizes=[]; block=bytes_
    steps=p.bit_length()-1
    for _ in range(steps):
        upper=block-block//2; sizes.append(upper); block=upper
    for s in sizes: sec+=lt(link,s)
    for s in reversed(sizes): sec+=lt(link,s)
    return sec
def allreduce_cost(algo, bytes_, topo):
    n=topo.n
    if n<=1 or bytes_==0: return 0.0
    k=topo.node_group(); m=n//k
    flat=topo.inter if m>1 else topo.intra
    if algo=='ring': return ring_cost(bytes_,n,flat)
    if algo=='tree': return tree_cost(bytes_,n,flat)
    if algo=='rhd': return rhd_cost(bytes_,n,flat)
    if algo=='hier':
        if m==1: return ring_cost(bytes_,n,topo.intra)
        if k==1: return ring_cost(bytes_,n,topo.inter)
        chunk=-(-bytes_//k)
        return 2.0*(k-1)*lt(topo.intra,chunk)+ring_cost(chunk,m,topo.inter)
    if algo=='auto':
        best=None;bestt=float('inf')
        for a in ['ring','tree','rhd','hier']:
            t=allreduce_cost(a,bytes_,topo)
            if t<bestt: bestt=t;best=a
        return bestt
    raise ValueError(algo)

def profile(spec, m, tp, micro_tokens, dp, algo='ring'):
    tpf=float(tp); sus=spec.sustained()*1e12
    ppc=params_per_layer(m)/tpf
    fwd_flops=micro_tokens*fwd_flops_per_token_layer(m)/tpf
    t_fwd_d=fwd_flops/sus
    if tp>1:
        isl=spec.link.island_of(spec.cpn)
        bw=spec.link.bw(0,min(tp-1,isl-1))*1e9
        bytes_=micro_tokens*m['hidden']*2.0
        t_tp=2.0*(2.0*(tpf-1.0)/tpf)*bytes_/bw + 2.0*3.0e-6
    else: t_tp=0.0
    t_fwd=t_fwd_d+t_tp; t_bwd=2.0*t_fwd_d+t_tp
    t_adam=ppc*ADAM/sus/dp
    if dp>1:
        topo=dp_group(spec,dp,tp)
        gb=int(ppc*2.0)
        t_sync=allreduce_cost(algo,gb,topo)*(1.0-DP_OVERLAP)
    else: t_sync=0.0
    return dict(t_fwd=t_fwd,t_bwd=t_bwd,t_rec=t_fwd,t_update=t_adam+t_sync,
                t_off=ppc*8.0/PCIE_OFF, t_offm=ppc*2.0/PCIE_OFF, ppc=ppc)

ACT=68.0
def act_residency(schedule, b, pp, pos):
    queue=max(pp-pos,1)
    if schedule[0] in ('1f1b','zbv'): return float(min(b,queue))
    v=schedule[1]
    chunks=min(b*v,(v-1)*pp+queue)
    return chunks/v
def bubble_coeff(schedule):
    if schedule[0]=='1f1b': return 1.0
    if schedule[0]=='zbv': return 0.0
    return 1.0/schedule[1]

def stage_mem(spec, m, plan, strat, pos, total_stages, micro_tokens, first, last):
    tp=float(plan['s_tp'])
    lps=-(-plan['layers']//plan['s_pp'])
    params_stage=lps*params_per_layer(m)/tp
    wg=params_stage*4.0; opt=params_stage*12.0/strat['s_dp']
    infl=act_residency(strat['schedule'],strat['micro_batches'],total_stages,pos)
    tokens=float(micro_tokens)
    apl=2.0*tokens*m['hidden'] if plan['rec'] else ACT*tokens*m['hidden']/tp
    acts=infl*lps*apl
    ep=m['vocab']*m['hidden']/tp*((1 if first else 0)+(1 if last else 0))
    logits=tokens*m['vocab']*6.0/tp if last else 0.0
    eh=ep*(4.0+12.0/strat['s_dp'])+logits
    total=wg+opt+acts+eh; off=False
    if total>spec.mem_bytes()*MEM_SAFETY:
        retry=params_stage*2.0+0.0+acts+ep*2.0+logits
        if retry<=spec.mem_bytes()*MEM_SAFETY:
            total=retry; off=True
    return total, off

def evaluate(m, groups, strat, micro_tokens, profs):
    alpha=bubble_coeff(strat['schedule']); b=float(strat['micro_batches'])
    total_stages=sum(p['s_pp'] for p in strat['plans'])
    compute=[];update=[];peak=[];feas=True
    fs=0
    for (spec,_),plan,prof in zip(groups,strat['plans'],profs):
        lps=float(-(-plan['layers']//plan['s_pp']))
        t_comp=lps*(prof['t_fwd']+prof['t_bwd']+(prof['t_rec'] if plan['rec'] else 0.0))
        t_up=lps*prof['t_update']
        mem,off=stage_mem(spec,m,plan,strat,fs,total_stages,micro_tokens,fs==0,fs+plan['s_pp']==total_stages)
        peak.append(mem)
        if mem>spec.mem_bytes()*MEM_SAFETY: feas=False
        if off:
            t_comp+=lps*prof['t_offm']; t_up+=lps*prof['t_off']
        compute.append(b*t_comp); update.append(t_up); fs+=plan['s_pp']
    stage_sum=sum(p['s_pp']*compute[g]/b for g,p in enumerate(strat['plans']))
    it=0.0
    for g in range(len(groups)):
        ts=compute[g]/b
        it=max(it, compute[g]+update[g]+alpha*(stage_sum-ts))
    return it, peak, feas

def shard_layers(m, groups, shapes, s_dp, mb, micro_tokens, schedule, algo, profs):
    n=len(groups); L=m['n_layers']
    t_layer=[p['t_fwd']+p['t_bwd'] for p in profs]
    denom=sum(s['s_pp']/t for s,t in zip(shapes,t_layer))
    k=L/denom
    lps=[max(int(round(k/t)),1) for t in t_layer]
    assigned=lambda: sum(l*s['s_pp'] for l,s in zip(lps,shapes))
    guard=0
    while assigned()!=L and guard<10000:
        guard+=1
        if assigned()>L:
            best=None
            for i in range(n):
                if lps[i]<=1: continue
                load=lps[i]*t_layer[i]
                if best is None or load>best[1]: best=(i,load)
            if best is None: break
            lps[best[0]]-=1
        else:
            best=None
            for i in range(n):
                load=(lps[i]+1)*t_layer[i]
                if best is None or load<best[1]: best=(i,load)
            lps[best[0]]+=1
    if assigned()!=L:
        return None
    plans=[dict(s_pp=s['s_pp'],s_tp=s['s_tp'],layers=l*s['s_pp'],rec=False)
           for s,l in zip(shapes,lps)]
    for _ in range(8):
        strat=dict(s_dp=s_dp,micro_batches=mb,schedule=schedule,plans=plans)
        it,peak,feas=evaluate(m,groups,strat,micro_tokens,profs)
        if feas: return plans
        changed=False
        for i,plan in enumerate(plans):
            budget=groups[i][0].mem_bytes()*MEM_SAFETY
            if peak[i]>budget:
                if not plan['rec']: plan['rec']=True; changed=True
                elif plan['layers']>plan['s_pp']:
                    plan['layers']-=plan['s_pp']; changed=True
        if changed:
            short=L-sum(p['layers'] for p in plans)
            if short>0:
                missing=short
                order=sorted(range(n), key=lambda i:t_layer[i])
                while missing>0:
                    prog=False
                    for i in order:
                        if missing<plans[i]['s_pp']: continue
                        plans[i]['layers']+=plans[i]['s_pp']; missing-=plans[i]['s_pp']; prog=True
                        if missing==0: break
                    if not prog: break
                if missing!=0: return None
        else:
            return None
    return None

def tp_candidates(n_chips, tp_max):
    v=[];tp=1
    while tp<=tp_max:
        if n_chips%tp==0: v.append(tp)
        tp*=2
    return v

def dp_table(m, groups, s_dp, cache):
    options=[]
    for spec,n_chips in groups:
        opts=[]
        for tp in tp_candidates(n_chips, spec.tp_max()):
            if n_chips%(tp*s_dp)==0 and n_chips//(tp*s_dp)>=1:
                key=(spec.kind,tp,s_dp,'ring')
                if key not in cache: cache[key]=profile(spec,m,tp,m['seq_len'],s_dp,'ring')
                p=cache[key]
                opts.append(dict(s_tp=tp,s_pp=n_chips//(tp*s_dp),t_layer=p['t_fwd']+p['t_bwd']))
        options.append(opts)
    n=len(groups)
    ratio=[0.0]*(n+1); sppt=[0.0]*(n+1); maxt=[0.0]*(n+1); leaf=[1]*(n+1)
    for idx in range(n-1,-1,-1):
        ratio[idx]=ratio[idx+1]+max([o['s_pp']/o['t_layer'] for o in options[idx]],default=0.0)
        ms=min([o['s_pp']*o['t_layer'] for o in options[idx]],default=float('inf'))
        sppt[idx]=sppt[idx+1]+(ms if math.isfinite(ms) else 0.0)
        maxt[idx]=max(maxt[idx+1], max([o['t_layer'] for o in options[idx]],default=0.0))
        leaf[idx]=leaf[idx+1]*len(options[idx])
    return dict(options=options,ratio=ratio,sppt=sppt,maxt=maxt,leaf=leaf)

def update_floor(m, groups, table, s_dp, algo, cache):
    fl=float('inf')
    for (spec,_),opts in zip(groups,table['options']):
        for o in opts:
            key=(spec.kind,o['s_tp'],s_dp,algo)
            if key not in cache: cache[key]=profile(spec,m,o['s_tp'],m['seq_len'],s_dp,algo)
            fl=min(fl,cache[key]['t_update'])
    return fl

LB_SAFETY=1.0-1e-9
def bound(mb,L,alpha,ufloor,denom,sweep,own):
    if denom<=0.0: return float('inf')
    comp=mb*L/denom
    bub=alpha*max(sweep-own,0.0)
    return (comp+bub+ufloor)*LB_SAFETY

def leaf_cost(m, groups, shapes, s_dp, mb, schedule, algo, cache):
    profs=[]
    for (spec,_),s in zip(groups,shapes):
        key=(spec.kind,s['s_tp'],s_dp,algo)
        if key not in cache: cache[key]=profile(spec,m,s['s_tp'],m['seq_len'],s_dp,algo)
        profs.append(cache[key])
    plans=shard_layers(m,groups,shapes,s_dp,mb,m['seq_len'],schedule,algo,profs)
    if plans is None: return None
    v = schedule[1] if schedule[0]=='il' else 1
    if v>1 and any((-(-p['layers']//p['s_pp']))%v!=0 for p in plans): return None
    strat=dict(s_dp=s_dp,micro_batches=mb,schedule=schedule,plans=plans)
    it,peak,feas=evaluate(m,groups,strat,m['seq_len'],profs)
    if not feas: return None
    return it,plans

def search(m, groups, sequences, schedules, monotone, seed_inc, cache, old_bound=False):
    # dp candidates
    dps=[]
    for d in range(1,int(math.isqrt(sequences))+1):
        if sequences%d==0:
            for dp in {d, sequences//d}:
                if all(nc%dp==0 for _,nc in groups): dps.append(dp)
    dps=sorted(set(dps))
    jobs=[(dp,sch) for dp in dps for sch in schedules]
    incumbent=[seed_inc]
    stats=dict(ev=0,pr=0)
    best=[None]
    tables={}
    for dp,sch in jobs:
        if dp not in tables: tables[dp]=dp_table(m,groups,dp,cache)
        table=tables[dp]
        ufloor=update_floor(m,groups,table,dp,'auto',cache)
        mb=sequences//dp
        alpha=bubble_coeff(sch)
        opts=table['options']; n=len(groups)
        def dfs(idx, shapes, ratio, sppt, maxt):
            if old_bound:
                denom=ratio+table['ratio'][idx]
                lb = float('inf') if denom<=0 else mb*m['n_layers']/denom
            else:
                lb=bound(mb,m['n_layers'],alpha,ufloor,
                         ratio+table['ratio'][idx], sppt+table['sppt'][idx],
                         max(maxt,table['maxt'][idx]))
            if lb>incumbent[0]:
                stats['pr']+=table['leaf'][idx]; return
            if idx==n:
                stats['ev']+=1
                r=leaf_cost(m,groups,shapes,dp,mb,sch,'auto',cache)
                if r is None: return
                t,plans=r
                if best[0] is None or t<best[0][0]:
                    best[0]=(t,dp,sch,[dict(p) for p in plans])
                incumbent[0]=min(incumbent[0],t)
                return
            for o in opts[idx]:
                if monotone and idx>0 and groups[idx-1][0].kind==groups[idx][0].kind \
                   and shapes[idx-1]['s_tp']<o['s_tp']: continue
                shapes.append(dict(s_tp=o['s_tp'],s_pp=o['s_pp']))
                dfs(idx+1,shapes,ratio+o['s_pp']/o['t_layer'],
                    sppt+o['s_pp']*o['t_layer'],max(maxt,o['t_layer']))
                shapes.pop()
        dfs(0,[],0.0,0.0,0.0)
    total=sum(tables[dp]['leaf'][0] for dp in dps)*len(schedules)
    return best[0], stats, total


SCHEDULES=[('1f1b',1),('il',2),('zbv',1)]

def check_exp_a():
    m=H2_100B
    expa=[(SPECS['A'],256),(SPECS['B'],256),(SPECS['C'],256)]
    cache={}
    seqs=2*1024*1024//4096
    dps=[d for d in range(1,seqs+1) if seqs%d==0 and all(nc%d==0 for _,nc in expa)]
    viol=0; checked=0; min_margin=float('inf')
    for dp in dps:
        table=dp_table(m,expa,dp,cache)
        uf=update_floor(m,expa,table,dp,'auto',cache)
        mb=seqs//dp
        for sch in SCHEDULES:
            alpha=bubble_coeff(sch)
            for combo in itertools.product(*table['options']):
                shapes=[dict(s_tp=o['s_tp'],s_pp=o['s_pp']) for o in combo]
                ratio=sum(o['s_pp']/o['t_layer'] for o in combo)
                sppt=sum(o['s_pp']*o['t_layer'] for o in combo)
                mx=max(o['t_layer'] for o in combo)
                lb=bound(mb,m['n_layers'],alpha,uf,ratio,sppt,mx)
                r=leaf_cost(m,expa,shapes,dp,mb,sch,'auto',cache)
                if r is None: continue
                checked+=1
                min_margin=min(min_margin,(r[0]-lb)/r[0])
                if lb>r[0]: viol+=1
    print(f"admissibility: {checked} leaves checked, {viol} violations, "
          f"min rel margin {min_margin:.3e}")
    assert viol==0 and checked>50

    b_new,st_new,total=search(m,expa,seqs,SCHEDULES,False,float('inf'),cache)
    b_old,st_old,_=search(m,expa,seqs,SCHEDULES,False,float('inf'),cache,old_bound=True)
    print(f"exp-a-1 coarse: winner new={b_new[0]:.9f} dp={b_new[1]} sch={b_new[2]}  "
          f"old={b_old[0]:.9f} dp={b_old[1]} sch={b_old[2]}")
    print(f"  new: evaluated={st_new['ev']} pruned={st_new['pr']} total={total}")
    print(f"  old: evaluated={st_old['ev']} pruned={st_old['pr']}")
    assert (b_new[0],b_new[1],b_new[2],b_new[3])==(b_old[0],b_old[1],b_old[2],b_old[3])
    assert st_new['ev']+st_new['pr']==total and st_new['pr']>0
    print("  winners identical, partition exact")

def check_mega():
    m=H2_100B
    # memory-descending order: A(96), B(64), D(32 GiB, faster), C(32 GiB)
    mega=[(SPECS['A'],256),(SPECS['B'],512),(SPECS['D'],256),(SPECS['C'],256)]
    seqs=4*1024*1024//4096
    cache={}
    best,st,total=search(m,mega,seqs,SCHEDULES,False,float('inf'),cache)
    print(f"mega coarse: best={best[0]:.6f}s dp={best[1]} sch={best[2]} "
          f"ev={st['ev']} pr={st['pr']} total={total}")
    assert best is not None and sum(p['layers'] for p in best[3])==m['n_layers']
    def split(groups, cut=128):
        out=[]
        for spec,n in groups:
            if n<=cut: out.append((spec,n)); continue
            node=spec.cpn; chunk=max(cut,node); chunk-=chunk%node
            rest=n
            while rest>0:
                take=min(chunk,rest); out.append((spec,take)); rest-=take
        return out
    fine=split(mega); dp=best[1]
    incumbent=[best[0]]; stats=dict(ev=0,pr=0); fbest=[None]
    table=dp_table(m,fine,dp,cache)
    print("fine option counts:", [len(o) for o in table['options']],
          "leaf product:", table['leaf'][0])
    sys.setrecursionlimit(10000)
    for sch in SCHEDULES:
        uf=update_floor(m,fine,table,dp,'auto',cache)
        mb=seqs//dp; alpha=bubble_coeff(sch); n=len(fine); opts=table['options']
        def dfs(idx, shapes, ratio, sppt, maxt):
            lb=bound(mb,m['n_layers'],alpha,uf,ratio+table['ratio'][idx],
                     sppt+table['sppt'][idx],max(maxt,table['maxt'][idx]))
            if lb>incumbent[0]:
                stats['pr']+=table['leaf'][idx]; return
            if idx==n:
                stats['ev']+=1
                r=leaf_cost(m,fine,shapes,dp,mb,sch,'auto',cache)
                if r is None: return
                t,plans=r
                if fbest[0] is None or t<fbest[0][0]: fbest[0]=(t,dp,sch,plans)
                incumbent[0]=min(incumbent[0],t)
                return
            for o in opts[idx]:
                if idx>0 and fine[idx-1][0].kind==fine[idx][0].kind \
                   and shapes[idx-1]['s_tp']<o['s_tp']: continue
                shapes.append(dict(s_tp=o['s_tp'],s_pp=o['s_pp']))
                dfs(idx+1,shapes,ratio+o['s_pp']/o['t_layer'],
                    sppt+o['s_pp']*o['t_layer'],max(maxt,o['t_layer']))
                shapes.pop()
        dfs(0,[],0.0,0.0,0.0)
    print(f"mega stage2: ev={stats['ev']} pr={stats['pr']}")
    win,wg=(fbest[0],fine) if fbest[0] is not None and fbest[0][0]<best[0] else (best,mega)
    for (spec,n),p in zip(wg,win[3]):
        assert n==p['s_pp']*p['s_tp']*win[1], (spec.kind,n,p)
    print(f"mega winner: {win[0]:.6f}s, chip accounting exact")

if __name__=='__main__':
    check_exp_a()
    if '--mega' in sys.argv:
        check_mega()
    print("OK")
