"""Model configurations for the H2 reproduction.

``h2_100b`` is the exact Table 4 architecture from the paper; it is consumed
by the cost model / simulator only (never instantiated on CPU). The smaller
configs are real, runnable shapes used by the AOT export path:

* ``h2_100m`` — the end-to-end training example (~107M params).
* ``h2_fig12`` — the paper's Figure 12 small-scale 8-decoder-layer model.
* ``h2_tiny`` — quickstart / unit-test scale.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    hidden: int
    n_heads: int
    n_kv_heads: int       # Group Query Attention (Table 4: 8 queries/head)
    intermediate: int     # SwiGLU FFN width
    vocab: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.n_heads == 0
        return self.hidden // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameter count (embedding untied from the LM head)."""
        h, kd, i = self.hidden, self.kv_dim, self.intermediate
        per_layer = (
            h * h + 2 * h * kd + h * h      # Wq, Wk, Wv, Wo
            + 3 * h * i                      # W_gate, W_up, W_down
            + 2 * h                          # two RMSNorm gains
        )
        return self.vocab * h * 2 + self.n_layers * per_layer + h

    def flops_per_token(self) -> int:
        """Approximate forward FLOPs per token (2*params + attention)."""
        return 2 * self.param_count() + 4 * self.n_layers * self.seq_len * self.hidden


# Table 4 of the paper: the 100B-parameter production model.
H2_100B = ModelConfig(
    name="h2_100b",
    n_layers=96,
    hidden=8192,
    n_heads=64,
    n_kv_heads=8,          # "# Queries per Head: 8" => 64/8 = 8 KV heads
    intermediate=36864,
    vocab=92544,
    seq_len=4096,
)

# The 20B model used for the Figure 5 / Table 1 precision-alignment study.
H2_20B = ModelConfig(
    name="h2_20b",
    n_layers=60,
    hidden=5120,
    n_heads=40,
    n_kv_heads=8,
    intermediate=13824,
    vocab=92544,
    seq_len=4096,
)

# Real runnable model for the end-to-end training example (~107M params).
H2_100M = ModelConfig(
    name="h2_100m",
    n_layers=16,
    hidden=768,
    n_heads=12,
    n_kv_heads=4,
    intermediate=2048,
    vocab=8192,
    seq_len=256,
)

# Figure 12: "small-scale 8-decoder-layer model".
H2_FIG12 = ModelConfig(
    name="h2_fig12",
    n_layers=8,
    hidden=512,
    n_heads=8,
    n_kv_heads=4,
    intermediate=1408,
    vocab=4096,
    seq_len=256,
)

# Quickstart / unit-test scale.
H2_TINY = ModelConfig(
    name="h2_tiny",
    n_layers=4,
    hidden=256,
    n_heads=4,
    n_kv_heads=2,
    intermediate=704,
    vocab=1024,
    seq_len=128,
)

CONFIGS = {c.name: c for c in [H2_100B, H2_20B, H2_100M, H2_FIG12, H2_TINY]}
