"""L2: the H2 transformer (LLaMA-style, GQA) as pipeline-stage functions.

The model is expressed the way the rust coordinator consumes it: as *stage*
functions over flat parameter lists. A pipeline stage has a role:

* ``first`` — token embedding + ``n_layers`` decoder layers,
* ``mid``   — ``n_layers`` decoder layers,
* ``last``  — ``n_layers`` decoder layers + final RMSNorm + LM head + loss.

Each role exports (via :mod:`compile.aot`):

* ``fwd(params, x) -> y``          (first takes int32 tokens),
* ``bwd(params, x, dy) -> (dx, grads)``  — recompute-based VJP, which is
  exactly the paper's activation-recomputation trade (Observation #4);
  ``first`` omits ``dx``; ``last`` fuses fwd+bwd and returns
  ``(loss, dx, grads)``,
* ``update`` / ``sqnorm`` — Adam step and gradient square-norm
  (:mod:`compile.optim`).

All hot-spot compute calls the L1 Pallas kernels, so the exported HLO
contains the kernel lowering (interpret mode) and the rust runtime executes
the same code path the kernels were validated on.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import flash_attention
from .kernels.rmsnorm import rmsnorm as pallas_rmsnorm
from .kernels.swiglu import swiglu as pallas_swiglu

# Per-decoder-layer parameter template: (name, shape-fn). Order is the ABI
# the rust coordinator relies on (recorded in the manifest).
LAYER_PARAMS = [
    ("attn_norm", lambda c: (c.hidden,)),
    ("wq", lambda c: (c.hidden, c.hidden)),
    ("wk", lambda c: (c.hidden, c.kv_dim)),
    ("wv", lambda c: (c.hidden, c.kv_dim)),
    ("wo", lambda c: (c.hidden, c.hidden)),
    ("mlp_norm", lambda c: (c.hidden,)),
    ("w_gate", lambda c: (c.hidden, c.intermediate)),
    ("w_up", lambda c: (c.hidden, c.intermediate)),
    ("w_down", lambda c: (c.intermediate, c.hidden)),
]
N_LAYER_PARAMS = len(LAYER_PARAMS)

ROLES = ("first", "mid", "last", "full")


def param_layout(cfg: ModelConfig, role: str, n_layers: int):
    """Flat (name, shape) list for one stage's parameters — the wire ABI."""
    out = []
    if role in ("first", "full"):
        out.append(("embed", (cfg.vocab, cfg.hidden)))
    for i in range(n_layers):
        for name, shape_fn in LAYER_PARAMS:
            out.append((f"layer{i}.{name}", shape_fn(cfg)))
    if role in ("last", "full"):
        out.append(("final_norm", (cfg.hidden,)))
        out.append(("head", (cfg.hidden, cfg.vocab)))
    return out


def init_params(cfg: ModelConfig, role: str, n_layers: int, key):
    """Scaled-normal init matching the layout of :func:`param_layout`."""
    layout = param_layout(cfg, role, n_layers)
    params = []
    for (name, shape), k in zip(layout, jax.random.split(key, len(layout))):
        if name.endswith("norm") or name.endswith("attn_norm") or name.endswith("mlp_norm"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name == "embed":
            params.append(jax.random.normal(k, shape, jnp.float32) * 0.02)
        else:
            fan_in = shape[0]
            params.append(jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5))
    return params


def _decoder_layer(cfg: ModelConfig, p, x, cos, sin, use_pallas=True):
    """One pre-norm decoder layer. p: the 9 layer params; x: [B,S,H]."""
    attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down = p
    b, s, h = x.shape
    d = cfg.head_dim

    norm = pallas_rmsnorm if use_pallas else ref.rmsnorm
    y = norm(x, attn_norm)
    q = (y @ wq).reshape(b, s, cfg.n_heads, d)
    k = (y @ wk).reshape(b, s, cfg.n_kv_heads, d)
    v = (y @ wv).reshape(b, s, cfg.n_kv_heads, d)
    q = ref.apply_rope(q, cos, sin)
    k = ref.apply_rope(k, cos, sin)
    if use_pallas:
        att = flash_attention(q, k, v, causal=True)
    else:
        att = ref.gqa_attention(q, k, v, causal=True)
    x = x + att.reshape(b, s, h) @ wo

    y = norm(x, mlp_norm)
    if use_pallas:
        ffn = pallas_swiglu(y, w_gate, w_up, w_down)
    else:
        ffn = ref.swiglu(y, w_gate, w_up, w_down)
    return x + ffn


def stage_forward(cfg: ModelConfig, role: str, n_layers: int, params, x,
                  use_pallas=True):
    """Forward pass of one pipeline stage.

    ``x`` is int32 tokens [B,S] for ``first``/``full``, else f32 [B,S,H].
    Returns hidden states [B,S,H] (``last``/``full`` return logits-input
    hidden, i.e. the caller applies the loss via :func:`stage_loss`).
    """
    params = list(params)
    idx = 0
    if role in ("first", "full"):
        embed = params[idx]
        idx += 1
        x = embed[x]  # [B,S] -> [B,S,H]
    cos, sin = ref.rope_angles(x.shape[1], cfg.head_dim)
    for i in range(n_layers):
        p = params[idx:idx + N_LAYER_PARAMS]
        idx += N_LAYER_PARAMS
        x = _decoder_layer(cfg, p, x, cos, sin, use_pallas)
    return x, params[idx:]


def stage_loss(cfg: ModelConfig, role: str, n_layers: int, params, x, targets,
               use_pallas=True):
    """Loss head for ``last``/``full`` stages: mean token cross-entropy."""
    h, rest = stage_forward(cfg, role, n_layers, params, x, use_pallas)
    final_norm, head = rest
    norm = pallas_rmsnorm if use_pallas else ref.rmsnorm
    h = norm(h, final_norm)
    logits = (h @ head).reshape(-1, cfg.vocab)
    return ref.softmax_cross_entropy(logits, targets.reshape(-1))


# ---------------------------------------------------------------------------
# Exported entry points (flat signatures over parameter lists).
# ---------------------------------------------------------------------------

def make_fwd(cfg, role, n_layers, use_pallas=True):
    def fwd(params, x):
        y, _ = stage_forward(cfg, role, n_layers, params, x, use_pallas)
        return (y,)
    return fwd


def make_bwd(cfg, role, n_layers, use_pallas=True):
    """Recompute-based stage VJP: (params, x, dy) -> (dx?, grads)."""
    if role == "first":
        def bwd(params, x, dy):
            def f(p):
                return stage_forward(cfg, role, n_layers, p, x, use_pallas)[0]
            _, vjp = jax.vjp(f, list(params))
            (grads,) = vjp(dy)
            return tuple(grads)
        return bwd

    def bwd(params, x, dy):
        def f(p, xx):
            return stage_forward(cfg, role, n_layers, p, xx, use_pallas)[0]
        _, vjp = jax.vjp(f, list(params), x)
        grads, dx = vjp(dy)
        return (dx, *grads)
    return bwd


def make_last_fwdbwd(cfg, n_layers, use_pallas=True):
    """Last stage fused fwd+bwd: (params, x, targets) -> (loss, dx, grads)."""
    def fwdbwd(params, x, targets):
        def f(p, xx):
            return stage_loss(cfg, "last", n_layers, p, xx, targets, use_pallas)
        loss, vjp = jax.vjp(f, list(params), x)
        grads, dx = vjp(jnp.float32(1.0))
        return (loss, dx, *grads)
    return fwdbwd


def make_loss(cfg, role, n_layers, use_pallas=True):
    def loss_fn(params, x, targets):
        return (stage_loss(cfg, role, n_layers, params, x, targets, use_pallas),)
    return loss_fn


def make_train_step(cfg, n_layers, use_pallas=True):
    """Fused single-host train step for the quickstart path.

    (params, m, v, tokens, targets, step, lr) -> (loss, params', m', v')
    """
    from .optim import adam_step

    def train_step(params, m, v, tokens, targets, step, lr):
        def f(p):
            return stage_loss(cfg, "full", n_layers, p, tokens, targets, use_pallas)
        loss, grads = jax.value_and_grad(f)(list(params))
        new_p, new_m, new_v = adam_step(params, grads, m, v, step, lr,
                                        gscale=jnp.float32(1.0))
        return (loss, *new_p, *new_m, *new_v)
    return train_step
