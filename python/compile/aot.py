"""AOT export: lower every stage entry point to HLO text + manifest.json.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``; python never runs again after this — the rust
coordinator is self-contained over ``artifacts/``.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, optim
from .configs import CONFIGS, ModelConfig

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _meta(specs):
    return [
        {"shape": list(s.shape), "dtype": DTYPE_NAMES[jnp.dtype(s.dtype)]}
        for s in specs
    ]


class Exporter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        # Merge with an existing manifest so profiles can be exported
        # incrementally (`--profiles fig12` keeps earlier entries).
        path = os.path.join(out_dir, "manifest.json")
        if os.path.exists(path):
            with open(path) as f:
                self.manifest = json.load(f)
        else:
            self.manifest = {"models": {}}

    def model_entry(self, cfg: ModelConfig):
        entry = self.manifest["models"].setdefault(
            cfg.name,
            {
                "config": {
                    "n_layers": cfg.n_layers,
                    "hidden": cfg.hidden,
                    "n_heads": cfg.n_heads,
                    "n_kv_heads": cfg.n_kv_heads,
                    "intermediate": cfg.intermediate,
                    "vocab": cfg.vocab,
                    "seq_len": cfg.seq_len,
                    "param_count": cfg.param_count(),
                },
                "artifacts": {},
            },
        )
        return entry

    def export(self, cfg, name, fn, in_specs, extra=None):
        """Trace/lower ``fn`` at ``in_specs``, dump HLO text + manifest row."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        rel = f"{cfg.name}/{name}.hlo.txt"
        path = os.path.join(self.out_dir, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *in_specs)
        row = {
            "file": rel,
            "inputs": _meta(in_specs),
            "outputs": _meta(list(out_specs)),
        }
        if extra:
            row.update(extra)
        self.model_entry(cfg)["artifacts"][name] = row
        print(f"  exported {cfg.name}/{name}: "
              f"{len(in_specs)} in / {len(out_specs)} out, {len(text)} chars")
        return row

    def export_stage(self, cfg, role, n_layers, micro_batch, seq):
        """Export the full artifact set for one pipeline-stage variant."""
        layout = model.param_layout(cfg, role, n_layers)
        p_specs = [_spec(shape) for _, shape in layout]
        n_params = len(p_specs)
        x_spec = (
            _spec((micro_batch, seq), jnp.int32)
            if role in ("first", "full")
            else _spec((micro_batch, seq, cfg.hidden))
        )
        h_spec = _spec((micro_batch, seq, cfg.hidden))
        t_spec = _spec((micro_batch, seq), jnp.int32)
        scalar = _spec((), jnp.float32)
        tag = f"{role}_l{n_layers}"
        stage_extra = {
            "role": role,
            "n_layers": n_layers,
            "micro_batch": micro_batch,
            "seq": seq,
            "params": [{"name": n, "shape": list(s)} for n, s in layout],
        }

        if role != "last":
            fwd = model.make_fwd(cfg, role, n_layers)
            self.export(cfg, f"{tag}_fwd",
                        lambda *a: fwd(a[:n_params], a[n_params]),
                        p_specs + [x_spec], extra=stage_extra)
            bwd = model.make_bwd(cfg, role, n_layers)
            self.export(cfg, f"{tag}_bwd",
                        lambda *a: bwd(a[:n_params], a[n_params], a[n_params + 1]),
                        p_specs + [x_spec, h_spec], extra=stage_extra)
        else:
            fwdbwd = model.make_last_fwdbwd(cfg, n_layers)
            self.export(cfg, f"{tag}_fwdbwd",
                        lambda *a: fwdbwd(a[:n_params], a[n_params], a[n_params + 1]),
                        p_specs + [x_spec, t_spec], extra=stage_extra)
            loss = model.make_loss(cfg, role, n_layers)
            self.export(cfg, f"{tag}_loss",
                        lambda *a: loss(a[:n_params], a[n_params], a[n_params + 1]),
                        p_specs + [x_spec, t_spec], extra=stage_extra)

        update = optim.make_update(n_params)
        self.export(
            cfg, f"{tag}_update",
            lambda *a: update(a[:n_params], a[n_params:2 * n_params],
                              a[2 * n_params:3 * n_params],
                              a[3 * n_params:4 * n_params],
                              a[4 * n_params], a[4 * n_params + 1],
                              a[4 * n_params + 2]),
            p_specs * 4 + [scalar, scalar, scalar], extra=stage_extra)
        sqnorm = optim.make_sqnorm(n_params)
        self.export(cfg, f"{tag}_sqnorm", lambda *a: sqnorm(a),
                    p_specs, extra=stage_extra)

    def export_full(self, cfg, batch, seq):
        """Fused single-host train/eval step (quickstart path)."""
        n_layers = cfg.n_layers
        layout = model.param_layout(cfg, "full", n_layers)
        p_specs = [_spec(shape) for _, shape in layout]
        n = len(p_specs)
        tok = _spec((batch, seq), jnp.int32)
        scalar = _spec((), jnp.float32)
        extra = {
            "role": "full",
            "n_layers": n_layers,
            "micro_batch": batch,
            "seq": seq,
            "params": [{"name": nm, "shape": list(s)} for nm, s in layout],
        }
        step_fn = model.make_train_step(cfg, n_layers)
        self.export(
            cfg, "train_step",
            lambda *a: step_fn(a[:n], a[n:2 * n], a[2 * n:3 * n],
                               a[3 * n], a[3 * n + 1], a[3 * n + 2], a[3 * n + 3]),
            p_specs * 3 + [tok, tok, scalar, scalar], extra=extra)
        loss = model.make_loss(cfg, "full", n_layers)
        self.export(cfg, "eval_loss",
                    lambda *a: loss(a[:n], a[n], a[n + 1]),
                    p_specs + [tok, tok], extra=extra)

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {path}")


def export_all(out_dir, profiles=("tiny", "fig12", "e2e100m")):
    ex = Exporter(out_dir)
    if "tiny" in profiles:
        cfg = CONFIGS["h2_tiny"]
        ex.export_full(cfg, batch=2, seq=cfg.seq_len)
        # PP=2 split and PP=3 split (exercises the `mid` role).
        ex.export_stage(cfg, "first", 2, 2, cfg.seq_len)
        ex.export_stage(cfg, "last", 2, 2, cfg.seq_len)
        ex.export_stage(cfg, "first", 1, 2, cfg.seq_len)
        ex.export_stage(cfg, "mid", 2, 2, cfg.seq_len)
        ex.export_stage(cfg, "last", 1, 2, cfg.seq_len)
    if "fig12" in profiles:
        cfg = CONFIGS["h2_fig12"]
        ex.export_stage(cfg, "first", 4, 1, cfg.seq_len)
        ex.export_stage(cfg, "last", 4, 1, cfg.seq_len)
    if "e2e100m" in profiles:
        cfg = CONFIGS["h2_100m"]
        # Uniform PP=2 split and the HeteroPP non-uniform split (10/6):
        # more layers on the large-memory early stage (Observation #4).
        ex.export_stage(cfg, "first", 8, 1, cfg.seq_len)
        ex.export_stage(cfg, "last", 8, 1, cfg.seq_len)
        ex.export_stage(cfg, "first", 10, 1, cfg.seq_len)
        ex.export_stage(cfg, "last", 6, 1, cfg.seq_len)
    ex.write_manifest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profiles", default="tiny,fig12,e2e100m")
    args = ap.parse_args()
    export_all(args.out, tuple(args.profiles.split(",")))


if __name__ == "__main__":
    main()
