"""Adam optimizer as exportable flat-signature functions.

The coordinator owns the distributed semantics (DP gradient allreduce over
DiComm, optional clipping); this module is the per-stage math:

* ``adam_step``  — one Adam update with a gradient pre-scale ``gscale``
  (used by rust for the 1/DP averaging factor and global-norm clipping),
* ``grad_sqnorm`` — sum of squared gradient entries, so the coordinator can
  assemble a *global* norm across pipeline stages before choosing the clip
  scale.
"""

import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.95
EPS = 1e-8


def adam_step(params, grads, m, v, step, lr, gscale):
    """One Adam update over flat lists. ``step`` is 1-based (f32 scalar)."""
    new_p, new_m, new_v = [], [], []
    b1t = 1.0 - jnp.power(BETA1, step)
    b2t = 1.0 - jnp.power(BETA2, step)
    for p, g, mi, vi in zip(params, grads, m, v):
        g = g * gscale
        mi = BETA1 * mi + (1.0 - BETA1) * g
        vi = BETA2 * vi + (1.0 - BETA2) * jnp.square(g)
        mhat = mi / b1t
        vhat = vi / b2t
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + EPS))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def make_update(n_params):
    """Exportable Adam step over ``n_params`` tensors.

    (params..., grads..., m..., v..., step, lr, gscale)
      -> (params'..., m'..., v'...)
    """
    def update(params, grads, m, v, step, lr, gscale):
        new_p, new_m, new_v = adam_step(params, grads, m, v, step, lr, gscale)
        return (*new_p, *new_m, *new_v)
    return update


def make_sqnorm(n_params):
    """Exportable gradient square-norm: (grads...) -> scalar."""
    def sqnorm(grads):
        acc = jnp.float32(0.0)
        for g in grads:
            acc = acc + jnp.sum(jnp.square(g))
        return (acc,)
    return sqnorm
