"""Fused RMSNorm Pallas kernel (forward + analytic backward).

Rows are processed in ``block_rows`` tiles; the normalization reduction
stays entirely in VMEM. The backward dx uses the closed form

    r  = 1/sqrt(mean(x^2) + eps)
    dx = g*dy*r - x * r^3 * mean(x * g*dy)

and is fused in a second kernel; dgain is a cheap column reduction done in
jnp (it is a cross-row reduction and would need a scratch accumulator on a
real TPU — noted in DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _fwd_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...]
    g = g_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(var + eps)) * g


def _bwd_kernel(x_ref, g_ref, dy_ref, dx_ref, *, eps):
    x = x_ref[...]
    g = g_ref[...]
    dy = dy_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    r = 1.0 / jnp.sqrt(var + eps)
    gdy = g * dy
    dx_ref[...] = gdy * r - x * (r ** 3) * jnp.mean(x * gdy, axis=-1, keepdims=True)


def _run(kernel, rows, dim, block_rows, n_in, args):
    grid = (rows // block_rows,)
    row_spec = pl.BlockSpec((block_rows, dim), lambda i: (i, 0))
    gain_spec = pl.BlockSpec((dim,), lambda i: (0,))
    specs = [row_spec, gain_spec] + [row_spec] * (n_in - 2)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((rows, dim), jnp.float32),
        interpret=True,
    )(*args)


@functools.lru_cache(maxsize=None)
def _make_rmsnorm(rows, dim, block_rows, eps):
    @jax.custom_vjp
    def norm(x, gain):
        return _run(functools.partial(_fwd_kernel, eps=eps),
                    rows, dim, block_rows, 2, (x, gain))

    def fwd(x, gain):
        return norm(x, gain), (x, gain)

    def bwd(res, dy):
        x, gain = res
        dx = _run(functools.partial(_bwd_kernel, eps=eps),
                  rows, dim, block_rows, 3, (x, gain, dy))
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        dgain = jnp.sum(dy * x / jnp.sqrt(var + eps), axis=0)
        return dx, dgain

    norm.defvjp(fwd, bwd)
    return norm


def rmsnorm(x, gain, eps=1e-5, block_rows=None):
    """Fused RMSNorm over the last axis. x: [..., dim]; gain: [dim]."""
    shape = x.shape
    dim = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    br = min(block_rows or DEFAULT_BLOCK_ROWS, rows)
    while rows % br != 0:
        br //= 2
    x2 = x.reshape(rows, dim)
    out = _make_rmsnorm(rows, dim, br, eps)(x2, gain)
    return out.reshape(shape)
