"""Fused SwiGLU feed-forward Pallas kernel.

One program per ``block_m`` rows computes

    h = silu(x @ Wg) * (x @ Wu);  out = h @ Wd

without materializing ``h`` in HBM — the intermediate lives in VMEM for the
lifetime of the tile, which is the TPU re-expression of the paper's fused
GPU MLP (DESIGN.md §Hardware-Adaptation). For large ``intermediate`` the
weights themselves exceed a 16 MiB VMEM budget and a second grid axis over
``intermediate`` tiles would be required on silicon; the structural estimate
lives in DESIGN.md §Perf.

Backward uses ``jax.vjp`` of the exact reference (recompute-based — the same
trade the paper's activation-recomputation path makes), so gradients are
mathematically exact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_M = 128


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = g * (1.0 / (1.0 + jnp.exp(-g))) * u
    o_ref[...] = jnp.dot(h, wd_ref[...], preferred_element_type=jnp.float32)


@functools.lru_cache(maxsize=None)
def _make_swiglu(rows, dim, inter, block_m):
    @jax.custom_vjp
    def ffn(x, wg, wu, wd):
        return pl.pallas_call(
            _ffn_kernel,
            grid=(rows // block_m,),
            in_specs=[
                pl.BlockSpec((block_m, dim), lambda i: (i, 0)),
                pl.BlockSpec((dim, inter), lambda i: (0, 0)),
                pl.BlockSpec((dim, inter), lambda i: (0, 0)),
                pl.BlockSpec((inter, dim), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, dim), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, dim), jnp.float32),
            interpret=True,
        )(x, wg, wu, wd)

    def fwd(x, wg, wu, wd):
        return ffn(x, wg, wu, wd), (x, wg, wu, wd)

    def bwd(res, dy):
        x, wg, wu, wd = res
        _, vjp = jax.vjp(ref.swiglu, x, wg, wu, wd)
        return vjp(dy)

    ffn.defvjp(fwd, bwd)
    return ffn


def swiglu(x, w_gate, w_up, w_down, block_m=None):
    """Fused SwiGLU FFN. x: [..., dim]; returns same shape."""
    shape = x.shape
    dim = shape[-1]
    inter = w_gate.shape[1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    bm = min(block_m or DEFAULT_BLOCK_M, rows)
    while rows % bm != 0:
        bm //= 2
    x2 = x.reshape(rows, dim)
    out = _make_swiglu(rows, dim, inter, bm)(x2, w_gate, w_up, w_down)
    return out.reshape(shape)
