"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package is validated against these functions by
``python/tests/test_kernels.py`` (exact-math references; tolerances are fp32).
"""

import jax.numpy as jnp


def rmsnorm(x, gain, eps=1e-5):
    """RMSNorm over the last axis: x * gain / rms(x)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * gain


def rope_angles(seq_len, head_dim, base=10000.0):
    """Rotary embedding cos/sin tables of shape [seq_len, head_dim//2]."""
    inv_freq = 1.0 / (base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv_freq)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin):
    """Apply rotary position embedding.

    x: [batch, seq, heads, head_dim]; cos/sin: [seq, head_dim//2].
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def gqa_attention(q, k, v, causal=True, scale=None):
    """Grouped-query attention, exact softmax reference.

    q: [B, S, Hq, D]; k, v: [B, S, Hkv, D] with Hq % Hkv == 0.
    Returns [B, S, Hq, D].
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    # Broadcast KV heads across their query group.
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward: (silu(x @ Wg) * (x @ Wu)) @ Wd."""
    g = x @ w_gate
    u = x @ w_up
    act = g * (1.0 / (1.0 + jnp.exp(-g)))  # silu
    return (act * u) @ w_down


def softmax_cross_entropy(logits, targets):
    """Mean token-level cross entropy. logits: [N, V]; targets: [N] int."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    picked = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)
