"""Pallas flash attention with Grouped-Query Attention (L1 hot-spot kernel).

TPU adaptation of the paper's GPU attention path (DESIGN.md
§Hardware-Adaptation): instead of a threadblock-per-tile CUDA decomposition,
the HBM→VMEM schedule is expressed with ``BlockSpec``s —

* the grid iterates over ``(batch × query-heads, query blocks)``;
* each program streams one ``(block_q, head_dim)`` query tile into VMEM and
  loops over ``(block_k, head_dim)`` key/value tiles with the online-softmax
  (running max / running sum) recurrence, so the ``S×S`` score matrix never
  materializes;
* block shapes default to 128 to match the MXU systolic-array tile;
* for causal masking the K-loop is truncated at the query block's diagonal
  (structural skip, not just a mask), halving the visited tiles.

The backward pass is two more Pallas kernels (dQ; fused dK/dV) using the
standard flash-attention recurrence with the saved logsumexp. Everything is
validated against ``ref.gqa_attention`` and ``jax.vjp`` of the reference in
``python/tests/test_kernels.py``.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the exported artifact
runs on the rust runtime. Real-TPU perf is estimated structurally in
DESIGN.md §Perf (VMEM footprint / MXU utilization per block shape).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _pick_block(size, default):
    return min(default, size)


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, block_k, causal):
    """One (batch·head, q-block) program of the forward pass."""
    qi = pl.program_id(1)
    bq, d = q_ref.shape[1], q_ref.shape[2]
    s = k_ref.shape[1]
    q = q_ref[0, :, :]

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, d), jnp.float32)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    if causal:
        # Structural skip: only K tiles at or below the diagonal are visited.
        n_kb = ((qi + 1) * bq + block_k - 1) // block_k
    else:
        n_kb = s // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :]
        v = v_ref[0, pl.ds(kb * block_k, block_k), :]
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
            logits = jnp.where(q_pos >= k_pos, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m, l, acc))
    o_ref[0, :, :] = acc / l[:, None]
    lse_ref[0, :] = m + jnp.log(l)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               *, scale, block_k, causal):
    """dQ for one (batch·head, q-block) program."""
    qi = pl.program_id(1)
    bq, d = q_ref.shape[1], q_ref.shape[2]
    s = k_ref.shape[1]
    q = q_ref[0, :, :]
    do = do_ref[0, :, :]
    lse = lse_ref[0, :]
    delta = delta_ref[0, :]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_kb = ((qi + 1) * bq + block_k - 1) // block_k if causal else s // block_k

    def body(kb, dq):
        k = k_ref[0, pl.ds(kb * block_k, block_k), :]
        v = v_ref[0, pl.ds(kb * block_k, block_k), :]
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
            logits = jnp.where(q_pos >= k_pos, logits, NEG_INF)
        p = jnp.exp(logits - lse[:, None])
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jnp.dot(ds, k, preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, n_kb, body, jnp.zeros((bq, d), jnp.float32))
    dq_ref[0, :, :] = dq


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
                *, scale, block_q, causal, group):
    """Fused dK/dV for one (batch·kv-head, k-block) program.

    The kv head serves ``group`` query heads; their contributions are
    accumulated in VMEM before a single write-back.
    """
    ki = pl.program_id(1)
    bk, d = k_ref.shape[1], k_ref.shape[2]
    s = q_ref.shape[2]
    k = k_ref[0, :, :]
    v = v_ref[0, :, :]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)

    dk = jnp.zeros((bk, d), jnp.float32)
    dv = jnp.zeros((bk, d), jnp.float32)
    n_qb = s // block_q
    first_qb = (ki * bk) // block_q if causal else 0

    for g in range(group):  # static unroll over the query-head group
        def body(qb, carry):
            dk, dv = carry
            q = q_ref[0, g, pl.ds(qb * block_q, block_q), :]
            do = do_ref[0, g, pl.ds(qb * block_q, block_q), :]
            lse = lse_ref[0, g, pl.ds(qb * block_q, block_q)]
            delta = delta_ref[0, g, pl.ds(qb * block_q, block_q)]
            logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            if causal:
                q_pos = qb * block_q + jax.lax.broadcasted_iota(
                    jnp.int32, (block_q, 1), 0)
                logits = jnp.where(q_pos >= k_pos, logits, NEG_INF)
            p = jnp.exp(logits - lse[:, None])
            dv = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
            dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
            ds = p * (dp - delta[:, None]) * scale
            dk = dk + jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
            return dk, dv

        dk, dv = jax.lax.fori_loop(first_qb, n_qb, body, (dk, dv))

    dk_ref[0, :, :] = dk
    dv_ref[0, :, :] = dv


def _flash_fwd(q, k, v, causal, block_q, block_k):
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = 1.0 / float(d) ** 0.5

    # [B, S, H, D] -> [B*H, S, D] so the grid can address (batch·head) rows.
    q2 = jnp.transpose(q, (0, 2, 1, 3)).reshape(b * hq, s, d)
    k2 = jnp.transpose(k, (0, 2, 1, 3)).reshape(b * hkv, s, d)
    v2 = jnp.transpose(v, (0, 2, 1, 3)).reshape(b * hkv, s, d)

    def kv_index(i, j):
        del j
        return (i // hq) * hkv + (i % hq) // group

    grid = (b * hq, s // block_q)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, block_k=block_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (kv_index(i, j), 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (kv_index(i, j), 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hq, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b * hq, s), jnp.float32),
        ],
        interpret=True,
    )(q2, k2, v2)

    o = out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    return o, (q2, k2, v2, out, lse)


def _flash_bwd(causal, block_q, block_k, shapes, res, do):
    b, s, hq, d, hkv = shapes
    group = hq // hkv
    scale = 1.0 / float(d) ** 0.5
    q2, k2, v2, o2, lse = res

    do2 = jnp.transpose(do, (0, 2, 1, 3)).reshape(b * hq, s, d)
    delta = jnp.sum(do2 * o2, axis=-1)  # [B*Hq, S]

    def kv_index(i, j):
        del j
        return (i // hq) * hkv + (i % hq) // group

    dq2 = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_k=block_k, causal=causal),
        grid=(b * hq, s // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (kv_index(i, j), 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (kv_index(i, j), 0, 0)),
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), jnp.float32),
        interpret=True,
    )(q2, k2, v2, do2, lse, delta)

    # Group-major views so each kv-head program sees its query-head group.
    qg = q2.reshape(b, hkv, group, s, d).reshape(b * hkv, group, s, d)
    dog = do2.reshape(b, hkv, group, s, d).reshape(b * hkv, group, s, d)
    lseg = lse.reshape(b, hkv, group, s).reshape(b * hkv, group, s)
    deltag = delta.reshape(b, hkv, group, s).reshape(b * hkv, group, s)

    dk2, dv2 = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=block_q,
                          causal=causal, group=group),
        grid=(b * hkv, s // block_k),
        in_specs=[
            pl.BlockSpec((1, group, s, d), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, group, s, d), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, group, s), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, group, s), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, s, d), jnp.float32),
        ],
        interpret=True,
    )(qg, k2, v2, dog, lseg, deltag)

    dq = dq2.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    dk = dk2.reshape(b, hkv, s, d).transpose(0, 2, 1, 3)
    dv = dv2.reshape(b, hkv, s, d).transpose(0, 2, 1, 3)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _make_attention(b, s, hq, hkv, d, causal, block_q, block_k):
    shapes = (b, s, hq, d, hkv)

    @jax.custom_vjp
    def att(q, k, v):
        return _flash_fwd(q, k, v, causal, block_q, block_k)[0]

    def fwd(q, k, v):
        o, res = _flash_fwd(q, k, v, causal, block_q, block_k)
        return o, res

    def bwd(res, do):
        return _flash_bwd(causal, block_q, block_k, shapes, res, do)

    att.defvjp(fwd, bwd)
    return att


def flash_attention(q, k, v, causal=True, block_q=None, block_k=None):
    """GQA flash attention. q: [B,S,Hq,D]; k,v: [B,S,Hkv,D]; Hq % Hkv == 0."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0, "query heads must be a multiple of kv heads"
    bq = _pick_block(s, block_q or DEFAULT_BLOCK_Q)
    bk = _pick_block(s, block_k or DEFAULT_BLOCK_K)
    assert s % bq == 0 and s % bk == 0, "seq len must divide block sizes"
    att = _make_attention(b, s, hq, hkv, d, causal, bq, bk)
    return att(q, k, v)


def vmem_bytes_estimate(s, d, group, block_q, block_k, dtype_bytes=4):
    """Structural VMEM footprint of one forward program (DESIGN.md §Perf)."""
    q_tile = block_q * d
    kv_stream = 2 * block_k * d            # double-buffered K and V tiles
    acc = block_q * d + 2 * block_q        # accumulator + m/l vectors
    scores = block_q * block_k
    return (q_tile + 2 * kv_stream + acc + scores) * dtype_bytes


def mxu_utilization_estimate(block_q, block_k, d, mxu=128):
    """Fraction of MXU lanes filled by the kernel's matmul tiles."""
    fill = lambda n: min(n, mxu) / mxu
    # Two matmuls per tile: (bq×d)@(d×bk) and (bq×bk)@(bk×d).
    u1 = fill(block_q) * fill(block_k) * fill(d)
    u2 = fill(block_q) * fill(d) * fill(block_k)
    return 0.5 * (u1 + u2)
