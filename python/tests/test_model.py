"""L2 correctness: stage composition, gradients, and pallas/ref equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, optim
from compile.configs import CONFIGS, H2_100B, H2_100M, H2_TINY

CFG = H2_TINY
B, S = 2, CFG.seq_len


def make_stage(role, n_layers, seed):
    key = jax.random.PRNGKey(seed)
    return model.init_params(CFG, role, n_layers, key)


def full_params_from_stages(stages):
    """Concatenate stage param lists into the equivalent `full` layout."""
    flat = []
    for role, _, params in stages:
        flat.extend(params)
    return flat


def rand_tokens(key, shape, vocab):
    return jax.random.randint(key, shape, 0, vocab, dtype=jnp.int32)


class TestStageComposition:
    """first(+mid)+last chained == monolithic `full` forward/loss."""

    @pytest.mark.parametrize("splits", [[("first", 2), ("last", 2)],
                                        [("first", 1), ("mid", 2), ("last", 1)]])
    def test_pipeline_equals_full(self, splits):
        stages = [(role, n, make_stage(role, n, 10 + i))
                  for i, (role, n) in enumerate(splits)]
        full = full_params_from_stages(stages)
        key = jax.random.PRNGKey(99)
        tokens = rand_tokens(key, (B, S), CFG.vocab)
        targets = rand_tokens(jax.random.PRNGKey(98), (B, S), CFG.vocab)

        # Chained stage execution (what the rust coordinator does).
        x = tokens
        for role, n, params in stages[:-1]:
            x, _ = model.stage_forward(CFG, role, n, params, x)
        role, n, params = stages[-1]
        loss_staged = model.stage_loss(CFG, role, n, params, x, targets)

        loss_full = model.stage_loss(CFG, "full", CFG.n_layers, full, tokens, targets)
        np.testing.assert_allclose(loss_staged, loss_full, atol=1e-5, rtol=1e-5)

    def test_loss_is_near_log_vocab_at_init(self):
        """Untrained model must sit near the uniform-prediction loss."""
        params = make_stage("full", CFG.n_layers, 0)
        tokens = rand_tokens(jax.random.PRNGKey(1), (B, S), CFG.vocab)
        targets = rand_tokens(jax.random.PRNGKey(2), (B, S), CFG.vocab)
        loss = model.stage_loss(CFG, "full", CFG.n_layers, params, tokens, targets)
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


class TestStagedBackward:
    """The exported bwd chain must equal monolithic autodiff."""

    def test_bwd_chain_matches_full_grad(self):
        stages = [("first", 2, make_stage("first", 2, 20)),
                  ("last", 2, make_stage("last", 2, 21))]
        full = full_params_from_stages(stages)
        tokens = rand_tokens(jax.random.PRNGKey(30), (B, S), CFG.vocab)
        targets = rand_tokens(jax.random.PRNGKey(31), (B, S), CFG.vocab)

        # Monolithic reference gradient.
        def f(p):
            return model.stage_loss(CFG, "full", CFG.n_layers, p, tokens, targets)
        ref_grads = jax.grad(f)(list(full))

        # Staged execution: fwd first -> fused last fwdbwd -> bwd first.
        fwd0 = model.make_fwd(CFG, "first", 2)
        (h0,) = fwd0(stages[0][2], tokens)
        fwdbwd1 = model.make_last_fwdbwd(CFG, 2)
        loss, dx, *g1 = fwdbwd1(stages[1][2], h0, targets)
        bwd0 = model.make_bwd(CFG, "first", 2)
        g0 = bwd0(stages[0][2], tokens, dx)

        staged = list(g0) + list(g1)
        assert len(staged) == len(ref_grads)
        for a, e in zip(staged, ref_grads):
            np.testing.assert_allclose(a, e, atol=2e-4, rtol=2e-4)

    def test_mid_stage_dx_matches_autodiff(self):
        params = make_stage("mid", 2, 40)
        x = jax.random.normal(jax.random.PRNGKey(41), (B, S, CFG.hidden))
        dy = jax.random.normal(jax.random.PRNGKey(42), (B, S, CFG.hidden))

        bwd = model.make_bwd(CFG, "mid", 2)
        dx, *grads = bwd(params, x, dy)

        def f(xx):
            y, _ = model.stage_forward(CFG, "mid", 2, params, xx)
            return jnp.sum(y * dy)
        dx_ref = jax.grad(f)(x)
        np.testing.assert_allclose(dx, dx_ref, atol=2e-4, rtol=2e-4)


class TestPallasRefEquivalence:
    def test_full_model_pallas_vs_ref(self):
        params = make_stage("full", CFG.n_layers, 50)
        tokens = rand_tokens(jax.random.PRNGKey(51), (B, S), CFG.vocab)
        targets = rand_tokens(jax.random.PRNGKey(52), (B, S), CFG.vocab)
        lp = model.stage_loss(CFG, "full", CFG.n_layers, params, tokens, targets,
                              use_pallas=True)
        lr = model.stage_loss(CFG, "full", CFG.n_layers, params, tokens, targets,
                              use_pallas=False)
        np.testing.assert_allclose(lp, lr, atol=1e-5, rtol=1e-5)


class TestOptim:
    def test_adam_decreases_loss(self):
        params = make_stage("full", CFG.n_layers, 60)
        n = len(params)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        tokens = rand_tokens(jax.random.PRNGKey(61), (B, S), CFG.vocab)
        targets = rand_tokens(jax.random.PRNGKey(62), (B, S), CFG.vocab)
        step_fn = model.make_train_step(CFG, CFG.n_layers)
        losses = []
        for step in range(1, 6):
            out = step_fn(params, m, v, tokens, targets,
                          jnp.float32(step), jnp.float32(3e-3))
            losses.append(float(out[0]))
            params = list(out[1:1 + n])
            m = list(out[1 + n:1 + 2 * n])
            v = list(out[1 + 2 * n:1 + 3 * n])
        assert losses[-1] < losses[0] - 0.2, losses

    def test_gscale_equivalence(self):
        """update(g, gscale=s) == update(g*s, gscale=1) — the DP-average ABI."""
        params = make_stage("first", 1, 70)
        grads = [jnp.ones_like(p) * 0.1 for p in params]
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        a = optim.adam_step(params, grads, m, v, jnp.float32(1), 1e-3,
                            gscale=jnp.float32(0.5))
        b = optim.adam_step(params, [g * 0.5 for g in grads], m, v,
                            jnp.float32(1), 1e-3, gscale=jnp.float32(1.0))
        for xs, ys in zip(a, b):
            for x, y in zip(xs, ys):
                np.testing.assert_allclose(x, y, atol=1e-7)

    def test_sqnorm(self):
        grads = [jnp.ones((3, 4)), 2.0 * jnp.ones((5,))]
        (out,) = optim.make_sqnorm(2)(grads)
        np.testing.assert_allclose(out, 12.0 + 20.0)


class TestParamLayout:
    def test_param_count_matches_config(self):
        for cfg in [H2_TINY, H2_100M, H2_100B]:
            layout = model.param_layout(cfg, "full", cfg.n_layers)
            total = sum(int(np.prod(s)) for _, s in layout)
            assert total == cfg.param_count(), cfg.name

    def test_100m_is_about_100m(self):
        assert 90e6 < H2_100M.param_count() < 130e6

    def test_stage_layouts_partition_full(self):
        full = model.param_layout(CFG, "full", 4)
        parts = (model.param_layout(CFG, "first", 1)
                 + model.param_layout(CFG, "mid", 2)
                 + model.param_layout(CFG, "last", 1))
        assert [s for _, s in full] == [s for _, s in parts]
