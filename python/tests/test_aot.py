"""AOT export sanity: manifest structure and HLO text properties."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_has_all_models():
    m = load()
    for name in ["h2_tiny", "h2_fig12", "h2_100m"]:
        assert name in m["models"], name


def test_tiny_artifact_set_complete():
    arts = load()["models"]["h2_tiny"]["artifacts"]
    expected = {"train_step", "eval_loss",
                "first_l2_fwd", "first_l2_bwd", "first_l2_update", "first_l2_sqnorm",
                "last_l2_fwdbwd", "last_l2_loss", "last_l2_update", "last_l2_sqnorm",
                "mid_l2_fwd", "mid_l2_bwd"}
    assert expected <= set(arts)


def test_hlo_files_exist_and_are_text():
    m = load()
    for model_name, entry in m["models"].items():
        for art_name, art in entry["artifacts"].items():
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), art["file"]
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, f"{art['file']} is not HLO text"


def test_input_output_arity_consistency():
    """fwd/bwd/update arities must obey the stage ABI the rust side assumes."""
    m = load()
    for model_name, entry in m["models"].items():
        for art_name, art in entry["artifacts"].items():
            n_in, n_out = len(art["inputs"]), len(art["outputs"])
            if "params" not in art:
                continue
            n_p = len(art["params"])
            if art_name.endswith("_fwd"):
                assert n_in == n_p + 1 and n_out == 1
            elif art_name.endswith("_bwd"):
                assert n_in == n_p + 2
                role = art["role"]
                assert n_out == (n_p if role == "first" else n_p + 1)
            elif art_name.endswith("_fwdbwd"):
                assert n_in == n_p + 2 and n_out == n_p + 2  # loss, dx, grads
            elif art_name.endswith("_update"):
                assert n_in == 4 * n_p + 3 and n_out == 3 * n_p
            elif art_name.endswith("_sqnorm"):
                assert n_in == n_p and n_out == 1


def test_param_shapes_match_metadata():
    m = load()
    for entry in m["models"].values():
        for name, art in entry["artifacts"].items():
            if not name.endswith("_fwd"):
                continue
            shapes = [p["shape"] for p in art["params"]]
            in_shapes = [i["shape"] for i in art["inputs"][:len(shapes)]]
            assert shapes == in_shapes
