"""L1 correctness: Pallas kernels vs the pure-jnp oracles in kernels/ref.py.

Includes hypothesis sweeps over shapes/dtypes per the repro brief: the
kernels must agree with the reference for every (batch, seq, heads, dim)
combination the model family can produce.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import (
    flash_attention, mxu_utilization_estimate, vmem_bytes_estimate)
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels.swiglu import swiglu

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5
RTOL = 2e-5


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestAttentionForward:
    @pytest.mark.parametrize("b,s,hq,hkv,d", [
        (1, 128, 4, 2, 32),
        (2, 128, 4, 4, 16),   # MHA special case
        (2, 256, 12, 4, 64),  # h2_100m shape
        (1, 256, 8, 4, 64),   # h2_fig12 shape
        (1, 128, 4, 1, 32),   # MQA special case
    ])
    def test_matches_reference(self, b, s, hq, hkv, d):
        k1, k2, k3 = keys(3)
        q, k, v = rand(k1, (b, s, hq, d)), rand(k2, (b, s, hkv, d)), rand(k3, (b, s, hkv, d))
        out = flash_attention(q, k, v, causal=True)
        expect = ref.gqa_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, expect, atol=ATOL, rtol=RTOL)

    def test_non_causal(self):
        k1, k2, k3 = keys(3, seed=1)
        q, k, v = rand(k1, (2, 128, 4, 32)), rand(k2, (2, 128, 2, 32)), rand(k3, (2, 128, 2, 32))
        out = flash_attention(q, k, v, causal=False)
        expect = ref.gqa_attention(q, k, v, causal=False)
        np.testing.assert_allclose(out, expect, atol=ATOL, rtol=RTOL)

    def test_block_shape_invariance(self):
        """Output must not depend on the VMEM tiling choice."""
        k1, k2, k3 = keys(3, seed=2)
        q, k, v = rand(k1, (1, 256, 4, 32)), rand(k2, (1, 256, 2, 32)), rand(k3, (1, 256, 2, 32))
        base = flash_attention(q, k, v, block_q=256, block_k=256)
        for bq, bk in [(64, 64), (128, 64), (64, 128), (32, 256)]:
            out = flash_attention(q, k, v, block_q=bq, block_k=bk)
            np.testing.assert_allclose(out, base, atol=ATOL, rtol=RTOL)

    def test_causal_first_token_attends_self_only(self):
        k1, k2, k3 = keys(3, seed=3)
        q, k, v = rand(k1, (1, 128, 2, 16)), rand(k2, (1, 128, 2, 16)), rand(k3, (1, 128, 2, 16))
        out = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out[0, 0], v[0, 0], atol=ATOL, rtol=RTOL)

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 2),
        s_pow=st.integers(5, 8),
        group=st.integers(1, 4),
        hkv=st.integers(1, 3),
        d=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_hypothesis_shape_sweep(self, b, s_pow, group, hkv, d, seed):
        s = 2 ** s_pow
        hq = group * hkv
        k1, k2, k3 = keys(3, seed=seed)
        q, k, v = rand(k1, (b, s, hq, d)), rand(k2, (b, s, hkv, d)), rand(k3, (b, s, hkv, d))
        out = flash_attention(q, k, v, causal=True)
        expect = ref.gqa_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, expect, atol=ATOL, rtol=RTOL)


class TestAttentionBackward:
    @pytest.mark.parametrize("b,s,hq,hkv,d", [
        (1, 128, 4, 2, 32),
        (2, 128, 6, 2, 16),
        (1, 256, 12, 4, 64),
    ])
    def test_grads_match_reference_vjp(self, b, s, hq, hkv, d):
        k1, k2, k3 = keys(3, seed=7)
        q, k, v = rand(k1, (b, s, hq, d)), rand(k2, (b, s, hkv, d)), rand(k3, (b, s, hkv, d))

        def f(q, k, v):
            return jnp.sum(jnp.sin(flash_attention(q, k, v)))

        def fr(q, k, v):
            return jnp.sum(jnp.sin(ref.gqa_attention(q, k, v)))

        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
        for a, e in zip(g, gr):
            np.testing.assert_allclose(a, e, atol=5e-5, rtol=5e-5)

    def test_grad_block_invariance(self):
        k1, k2, k3 = keys(3, seed=8)
        q, k, v = rand(k1, (1, 128, 2, 32)), rand(k2, (1, 128, 2, 32)), rand(k3, (1, 128, 2, 32))

        def make(bq, bk):
            return jax.grad(
                lambda q: jnp.sum(flash_attention(q, k, v, block_q=bq, block_k=bk) ** 2)
            )(q)

        np.testing.assert_allclose(make(128, 128), make(32, 64), atol=ATOL, rtol=RTOL)


class TestRmsNorm:
    @pytest.mark.parametrize("shape", [(4, 64), (2, 128, 256), (1, 7, 96)])
    def test_matches_reference(self, shape):
        k1, k2 = keys(2, seed=11)
        x = rand(k1, shape)
        gain = 1.0 + rand(k2, shape[-1:], 0.1)
        np.testing.assert_allclose(rmsnorm(x, gain), ref.rmsnorm(x, gain),
                                   atol=ATOL, rtol=RTOL)

    def test_grads(self):
        k1, k2 = keys(2, seed=12)
        x = rand(k1, (6, 96))
        gain = 1.0 + rand(k2, (96,), 0.1)
        g = jax.grad(lambda x, g: jnp.sum(jnp.cos(rmsnorm(x, g))), argnums=(0, 1))(x, gain)
        gr = jax.grad(lambda x, g: jnp.sum(jnp.cos(ref.rmsnorm(x, g))), argnums=(0, 1))(x, gain)
        np.testing.assert_allclose(g[0], gr[0], atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(g[1], gr[1], atol=5e-5, rtol=5e-5)

    def test_scale_invariance_property(self):
        """rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps)."""
        k1, _ = keys(2, seed=13)
        x = rand(k1, (4, 128), 3.0)
        gain = jnp.ones((128,))
        a = rmsnorm(x, gain, eps=0.0)
        b = rmsnorm(7.5 * x, gain, eps=0.0)
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(rows=st.integers(1, 16), dim=st.sampled_from([32, 64, 128, 256]),
           seed=st.integers(0, 2 ** 16))
    def test_hypothesis_sweep(self, rows, dim, seed):
        k1, k2 = keys(2, seed=seed)
        x = rand(k1, (rows, dim))
        gain = 1.0 + rand(k2, (dim,), 0.1)
        np.testing.assert_allclose(rmsnorm(x, gain), ref.rmsnorm(x, gain),
                                   atol=ATOL, rtol=RTOL)


class TestSwiGLU:
    @pytest.mark.parametrize("rows,dim,inter", [(8, 64, 160), (256, 96, 256), (3, 32, 80)])
    def test_matches_reference(self, rows, dim, inter):
        k1, k2, k3, k4 = keys(4, seed=21)
        x = rand(k1, (rows, dim))
        wg, wu = rand(k2, (dim, inter), 0.1), rand(k3, (dim, inter), 0.1)
        wd = rand(k4, (inter, dim), 0.1)
        np.testing.assert_allclose(swiglu(x, wg, wu, wd), ref.swiglu(x, wg, wu, wd),
                                   atol=ATOL, rtol=RTOL)

    def test_grads(self):
        k1, k2, k3, k4 = keys(4, seed=22)
        x = rand(k1, (8, 64))
        wg, wu = rand(k2, (64, 160), 0.1), rand(k3, (64, 160), 0.1)
        wd = rand(k4, (160, 64), 0.1)
        g = jax.grad(lambda *a: jnp.sum(jnp.tanh(swiglu(*a))), argnums=(0, 1, 2, 3))(x, wg, wu, wd)
        gr = jax.grad(lambda *a: jnp.sum(jnp.tanh(ref.swiglu(*a))), argnums=(0, 1, 2, 3))(x, wg, wu, wd)
        for a, e in zip(g, gr):
            np.testing.assert_allclose(a, e, atol=5e-5, rtol=5e-5)

    @settings(max_examples=8, deadline=None)
    @given(rows=st.integers(1, 32), dim=st.sampled_from([32, 64]),
           inter=st.sampled_from([64, 96]), seed=st.integers(0, 2 ** 16))
    def test_hypothesis_sweep(self, rows, dim, inter, seed):
        k1, k2, k3, k4 = keys(4, seed=seed)
        x = rand(k1, (rows, dim))
        wg, wu = rand(k2, (dim, inter), 0.1), rand(k3, (dim, inter), 0.1)
        wd = rand(k4, (inter, dim), 0.1)
        np.testing.assert_allclose(swiglu(x, wg, wu, wd), ref.swiglu(x, wg, wu, wd),
                                   atol=ATOL, rtol=RTOL)


class TestRope:
    def test_norm_preserving(self):
        """Rotary embedding is a rotation: per-pair norms are preserved."""
        k1, _ = keys(2, seed=31)
        x = rand(k1, (2, 64, 4, 32))
        cos, sin = ref.rope_angles(64, 32)
        y = ref.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            jnp.sum(x ** 2, axis=-1), jnp.sum(y ** 2, axis=-1), atol=1e-4, rtol=1e-4)

    def test_position_zero_identity(self):
        k1, _ = keys(2, seed=32)
        x = rand(k1, (1, 8, 2, 16))
        cos, sin = ref.rope_angles(8, 16)
        y = ref.apply_rope(x, cos, sin)
        np.testing.assert_allclose(y[:, 0], x[:, 0], atol=1e-6)


class TestStructuralEstimates:
    def test_vmem_under_budget_for_default_blocks(self):
        # h2_100b head_dim = 128; default 128x128 tiles must fit VMEM.
        assert vmem_bytes_estimate(4096, 128, 8, 128, 128) < 16 * 1024 * 1024

    def test_mxu_utilization_full_at_128(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(64, 128, 128) == 0.5
