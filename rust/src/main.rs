//! `h2` — CLI for the H2 hyper-heterogeneous training framework.
//!
//! Subcommands:
//!   train       real pipeline training over PJRT artifacts
//!   search      HeteroAuto strategy search (§4.3)
//!   simulate    discrete-event HeteroPP simulation at paper scale
//!   comm-bench  DiComm latency sweep (Fig 7)
//!   precision   DiTorch precision-alignment run (Fig 5 / Table 1)
//!   profile     analytic layer profile per chip/TP (the auto-profiler)
//!   report      paper-table reports (Table 6 baselines, Fig 11 ratios)

use anyhow::{bail, Result};

use h2::auto::{search, SearchConfig};
use h2::comm::{p2p_latency, CommMode};
use h2::coordinator::{train, StagePlan, TrainConfig};
use h2::costmodel::{evaluate, profile_layer, tgs, H2_100B};
use h2::hetero::{experiment, homogeneous_baseline, spec, ChipKind, Cluster, ALL_EXPERIMENTS};
use h2::precision::check_alignment;
use h2::runtime::Runtime;
use h2::sim::{simulate_iteration, ReshardStrategy, SimOptions};
use h2::topology::NicAssignment;
use h2::util::cli::Args;
use h2::util::table::{fmt_bytes, fmt_duration, Table};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "search" => cmd_search(&args),
        "simulate" => cmd_simulate(&args),
        "comm-bench" => cmd_comm_bench(&args),
        "precision" => cmd_precision(&args),
        "profile" => cmd_profile(&args),
        "report" => cmd_report(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command `{other}`"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!("h2 — hyper-heterogeneous LLM training (paper reproduction)\n");
    println!("usage: h2 <command> [flags]\n");
    println!("  train       --model h2_tiny --stages first_l2:A,last_l2:B --dp 1 \\");
    println!("              --micros 2 --steps 20 [--lr 1e-3] [--comm ddr|tcp|gloo]");
    println!("              [--no-overlap] [--perturb] [--artifacts DIR]");
    println!("  search      --exp exp-a-1 | --cluster A=256,B=256 --gbs-mtokens 2");
    println!("              [--alpha 1.0] [--no-two-stage] [--split 128]");
    println!("  simulate    --exp exp-c-1 [--comm ddr|tcp] [--reshard srag|bcast|naive]");
    println!("              [--no-overlap] [--uniform] [--non-affinity]");
    println!("  comm-bench  [--min-shift 8] [--max-shift 28]");
    println!("  precision   --chip A|B|C|D --steps 300 [--artifacts DIR]");
    println!("  profile     [--chip A] [--dp 4]");
    println!("  report      table6 | fig11");
}

fn parse_comm(args: &Args) -> Result<CommMode> {
    let s = args.str_or("comm", "ddr");
    CommMode::parse(&s).ok_or_else(|| anyhow::anyhow!("bad --comm `{s}`"))
}

fn parse_cluster(text: &str) -> Result<Cluster> {
    let mut groups = Vec::new();
    for part in text.split(',') {
        let (kind, n) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--cluster expects A=256,B=256 style"))?;
        let kind = ChipKind::parse(kind)
            .ok_or_else(|| anyhow::anyhow!("unknown chip `{kind}`"))?;
        groups.push((kind, n.parse()?));
    }
    Ok(Cluster::new("custom", groups))
}

fn parse_stages(text: &str) -> Result<Vec<StagePlan>> {
    let mut stages = Vec::new();
    for part in text.split(',') {
        let (prefix, chip) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("--stages expects prefix:CHIP,..."))?;
        let chip = ChipKind::parse(chip)
            .ok_or_else(|| anyhow::anyhow!("unknown chip `{chip}`"))?;
        stages.push(StagePlan { prefix: prefix.to_string(), chip });
    }
    Ok(stages)
}

fn cmd_train(args: &Args) -> Result<()> {
    if let Some(path) = args.get("config") {
        // JSON config file path (see `config` module docs for the schema).
        let file = h2::config::Config::load(path)?;
        let cfg = file.train
            .ok_or_else(|| anyhow::anyhow!("{path} has no `train` section"))?;
        let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
        let report = train(&rt, &cfg)?;
        println!("[h2] loss: first {:.4} last {:.4} ({:.0} tokens/s)",
                 report.losses.first().unwrap_or(&f64::NAN),
                 report.losses.last().unwrap_or(&f64::NAN),
                 report.tokens_per_second);
        return Ok(());
    }
    let model = args.str_or("model", "h2_tiny");
    let stages = parse_stages(&args.str_or("stages", "first_l2:A,last_l2:B"))?;
    let cfg = TrainConfig {
        model: model.clone(),
        stages,
        dp: args.usize_or("dp", 1)?,
        micro_batches: args.usize_or("micros", 2)?,
        steps: args.usize_or("steps", 20)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        seed: args.u64_or("seed", 42)?,
        comm: parse_comm(args)?,
        nic_assignment: if args.has("non-affinity") {
            NicAssignment::NonAffinity
        } else {
            NicAssignment::Affinity
        },
        fine_overlap: !args.has("no-overlap"),
        perturb: args.has("perturb"),
        log_every: args.usize_or("log-every", 10)?,
    };
    let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
    println!("[h2] platform={} model={model} stages={} dp={} micros={} steps={}",
             rt.platform(), cfg.stages.len(), cfg.dp, cfg.micro_batches, cfg.steps);
    let report = train(&rt, &cfg)?;
    println!("[h2] done: wall {:.1}s, modeled iter {:.4}s ({:.4}s comm), {:.0} tokens/s",
             report.wall_seconds,
             report.virtual_seconds / cfg.steps as f64,
             report.virtual_comm_seconds / cfg.steps as f64,
             report.tokens_per_second);
    println!("[h2] loss: first {:.4} last {:.4}",
             report.losses.first().unwrap_or(&f64::NAN),
             report.losses.last().unwrap_or(&f64::NAN));
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let (cluster, gbs) = if let Some(exp) = args.get("exp") {
        let e = experiment(exp)?;
        (e.cluster, e.gbs_tokens)
    } else {
        let c = parse_cluster(args.required("cluster")?)?;
        let gbs = args.usize_or("gbs-mtokens", 2)? * 1024 * 1024;
        (c, gbs)
    };
    let cfg = SearchConfig {
        alpha: args.f64_or("alpha", 1.0)?,
        group_split: args.usize_or("split", 128)?,
        two_stage: !args.has("no-two-stage"),
        max_dp: args.usize_or("max-dp", 0)?,
    };
    let r = search(&H2_100B, &cluster, gbs, &cfg)?;
    println!("HeteroAuto on `{}` ({} chips, GBS {}M tokens): {} candidates in {}",
             cluster.name, cluster.total_chips(), gbs >> 20,
             r.candidates_explored, fmt_duration(r.elapsed_seconds));
    let mut t = Table::new(&["group", "chips", "s_pp", "s_tp", "layers", "recompute"]);
    for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
        t.row(vec![
            g.spec.kind.to_string(),
            g.n_chips.to_string(),
            p.s_pp.to_string(),
            p.s_tp.to_string(),
            p.layers.to_string(),
            p.recompute.to_string(),
        ]);
    }
    t.print();
    println!("s_dp = {}, micro-batches = {}", r.strategy.s_dp, r.strategy.micro_batches);
    println!("estimated iteration: {} -> TGS {:.1}",
             fmt_duration(r.eval.iteration_seconds),
             tgs(&cluster, gbs, r.eval.iteration_seconds));
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let exp = experiment(&args.str_or("exp", "exp-c-1"))?;
    let scfg = SearchConfig::default();
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &scfg)?;
    let mut strategy = r.strategy.clone();
    if args.has("uniform") {
        // Uniform 1F1B baseline: equal layer count on every stage,
        // recomputation everywhere (the homogeneous-style configuration).
        let total_stages: usize = strategy.plans.iter().map(|p| p.s_pp).sum();
        let lps = H2_100B.n_layers / total_stages;
        for p in strategy.plans.iter_mut() {
            p.layers = lps * p.s_pp;
            p.recompute = true;
        }
        let mut total: usize = strategy.plans.iter().map(|p| p.layers).sum();
        let mut i = 0;
        while total < H2_100B.n_layers {
            let k = i % strategy.plans.len();
            strategy.plans[k].layers += strategy.plans[k].s_pp;
            total += strategy.plans[k].s_pp;
            i += 1;
        }
    }
    let reshard = match args.str_or("reshard", "srag").as_str() {
        "srag" => ReshardStrategy::SendRecvAllGather,
        "bcast" => ReshardStrategy::Broadcast,
        "naive" => ReshardStrategy::NaiveP2p,
        other => bail!("bad --reshard `{other}`"),
    };
    let opts = SimOptions {
        comm: parse_comm(args)?,
        reshard,
        nic_assignment: if args.has("non-affinity") {
            NicAssignment::NonAffinity
        } else {
            NicAssignment::Affinity
        },
        fine_overlap: !args.has("no-overlap"),
    };
    let grefs: Vec<&h2::hetero::ChipGroup> = r.groups.iter().collect();
    let sim = simulate_iteration(&H2_100B, &grefs, &strategy, H2_100B.seq_len, &opts);
    println!("simulated `{}`: iteration {} (bubble {:.1}%, exposed comm {})",
             exp.cluster.name,
             fmt_duration(sim.iteration_seconds),
             sim.bubble_fraction * 100.0,
             fmt_duration(sim.exposed_comm));
    println!("TGS {:.1}", tgs(&exp.cluster, exp.gbs_tokens, sim.iteration_seconds));
    Ok(())
}

fn cmd_comm_bench(args: &Args) -> Result<()> {
    let lo = args.usize_or("min-shift", 8)?;
    let hi = args.usize_or("max-shift", 28)?;
    let mut t = Table::new(&["size", "TCP", "CPU-RDMA", "DDR", "TCP/DDR"])
        .with_title("Fig 7 — cross-chip P2P latency by strategy");
    let mut ratios = Vec::new();
    let mut shift = lo;
    while shift <= hi {
        let bytes = 1usize << shift;
        let tcp = p2p_latency(CommMode::TcpCpu, bytes);
        let mid = p2p_latency(CommMode::RdmaCpu, bytes);
        let ddr = p2p_latency(CommMode::DeviceDirect, bytes);
        ratios.push(tcp / ddr);
        t.row(vec![
            fmt_bytes(bytes as f64),
            fmt_duration(tcp),
            fmt_duration(mid),
            fmt_duration(ddr),
            format!("{:.2}x", tcp / ddr),
        ]);
        shift += 2;
    }
    t.print();
    println!("average TCP/DDR ratio: {:.2}x (paper: 9.94x, range 1.79-16.0x)",
             ratios.iter().sum::<f64>() / ratios.len() as f64);
    Ok(())
}

fn cmd_precision(args: &Args) -> Result<()> {
    let chip = ChipKind::parse(args.str_or("chip", "A").as_str())
        .ok_or_else(|| anyhow::anyhow!("bad --chip"))?;
    let steps = args.usize_or("steps", 300)?;
    let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
    let stages = |c: ChipKind| vec![
        StagePlan { prefix: "first_l2".into(), chip: c },
        StagePlan { prefix: "last_l2".into(), chip: c },
    ];
    let mut cfg = TrainConfig::quick("h2_tiny", stages(ChipKind::A100), 1, 2, steps);
    cfg.log_every = 0;
    cfg.perturb = true;
    println!("[h2] reference run (A100, {steps} steps)...");
    let reference = train(&rt, &cfg)?;
    cfg.stages = stages(chip);
    println!("[h2] measured run ({chip}, {steps} steps)...");
    let measured = train(&rt, &cfg)?;
    let report = check_alignment(chip, &reference.losses, &measured.losses);
    println!("{chip}: MRE {:.3}% over {} iterations -> {}",
             report.mre * 100.0, report.n_iterations,
             if report.aligned { "ALIGNED (< 1.5%)" } else { "NOT ALIGNED" });
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let dp = args.usize_or("dp", 4)?;
    let mut t = Table::new(&["chip", "tp", "t_fwd", "t_bwd", "t_recomp", "t_update"])
        .with_title("Layer-wise analytic profile (100B model, 4096-token microbatch)");
    let chips: Vec<ChipKind> = match args.get("chip") {
        Some(c) => vec![ChipKind::parse(c).ok_or_else(|| anyhow::anyhow!("bad --chip"))?],
        None => ChipKind::ALL.to_vec(),
    };
    for kind in chips {
        let sp = spec(kind);
        let mut tp = 1;
        while tp <= sp.tp_max() {
            let p = profile_layer(&sp, &H2_100B, tp, 4096, dp);
            t.row(vec![
                kind.to_string(),
                tp.to_string(),
                fmt_duration(p.t_fwd),
                fmt_duration(p.t_bwd),
                fmt_duration(p.t_recompute),
                fmt_duration(p.t_update),
            ]);
            tp *= 2;
        }
    }
    t.print();
    Ok(())
}

/// Table 6 rows as (chip, PP, DP, TP, recompute, paper TGS).
pub const TABLE6_ROWS: [(ChipKind, usize, usize, usize, bool, f64); 4] = [
    (ChipKind::A, 16, 4, 4, false, 136.9),
    (ChipKind::B, 16, 4, 4, true, 143.7),
    (ChipKind::C, 32, 2, 4, true, 46.2),
    (ChipKind::D, 8, 4, 8, false, 99.5),
];

fn cmd_report(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()).unwrap_or("table6") {
        "table6" => {
            let mut t = Table::new(&["chip", "PP", "DP", "TP", "extra", "TGS (model)", "TGS (paper)"])
                .with_title("Table 6 — homogeneous 256-chip baselines, 100B model");
            for (kind, pp, dpd, tp, rec, paper) in TABLE6_ROWS {
                let exp = homogeneous_baseline(kind);
                let groups = exp.cluster.groups_by_memory_desc();
                let strategy = h2::costmodel::Strategy {
                    s_dp: dpd,
                    micro_batches: exp.gbs_tokens / H2_100B.seq_len / dpd,
                    plans: vec![h2::costmodel::GroupPlan {
                        s_pp: pp, s_tp: tp, layers: 96, recompute: rec,
                    }],
                };
                let eval = evaluate(&H2_100B, &groups, &strategy, H2_100B.seq_len, 1.0);
                let model_tgs = tgs(&exp.cluster, exp.gbs_tokens, eval.iteration_seconds);
                let extra = if rec { "recompute" } else if kind == ChipKind::D { "offload" } else { "-" };
                t.row(vec![
                    kind.to_string(), pp.to_string(), dpd.to_string(), tp.to_string(),
                    extra.to_string(), format!("{model_tgs:.1}"), format!("{paper:.1}"),
                ]);
            }
            t.print();
        }
        "fig11" => {
            for exp_name in ALL_EXPERIMENTS {
                let exp = experiment(exp_name)?;
                let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default())?;
                let hetero_tgs = tgs(&exp.cluster, exp.gbs_tokens, r.eval.iteration_seconds);
                println!("{exp_name}: TGS {hetero_tgs:.1} (search {}, {} candidates)",
                         fmt_duration(r.elapsed_seconds), r.candidates_explored);
            }
        }
        other => bail!("unknown report `{other}`"),
    }
    Ok(())
}
