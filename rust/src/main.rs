//! `h2` — CLI for the H2 hyper-heterogeneous training framework.
//!
//! The subcommands share one artifact: the serializable `ExecutionPlan`.
//! `search` produces one (`--emit-plan plan.json`), `simulate` and `train`
//! consume one (`--plan plan.json`), and every subcommand accepts
//! `--config file.json` for cluster/chip/search/sim defaults — including
//! user-defined chips that exist only in the config.
//!
//! Subcommands:
//!   train       real pipeline training over PJRT artifacts
//!   search      HeteroAuto strategy search (§4.3)
//!   replan      incremental re-planning after chip loss (elastic loop)
//!   simulate    discrete-event HeteroPP simulation at paper scale
//!   comm-bench  DiComm latency sweep (Fig 7)
//!   precision   DiTorch precision-alignment run (Fig 5 / Table 1)
//!   profile     analytic layer profile per chip/TP (the auto-profiler)
//!   fleet       pack a queue of jobs onto one cluster (fleet scheduler)
//!   report      paper-table reports (Table 6 baselines, Fig 11 ratios,
//!               recovery-vs-restart and fleet policies on exp-mega)

use anyhow::{bail, Result};

use h2::auto::{replan, search, ClusterDelta, ReplanOptions, SearchConfig};
use h2::comm::{p2p_latency, CommAlgo, CommMode};
use h2::config::Config;
use h2::coordinator::{
    train, train_plan, train_virtual, StagePlan, TrainConfig, TrainReport, VirtualOptions,
};
use h2::costmodel::{
    profile_layer, tgs, uniform_1f1b, ModelShape, ProfileCache, Schedule, H2_100B, H2_MOE,
};
use h2::elastic::FaultPlan;
use h2::fleet::{fleet_search_config, ClusterFaultPlan, FaultResponse, FleetOptions, JobTrace, Policy};
use h2::hetero::{experiment, spec, ChipKind, Cluster};
use h2::plan::{render_errors, ExecutionPlan};
use h2::precision::check_alignment;
use h2::runtime::Runtime;
use h2::sim::{simulate_plan, ReshardStrategy};
use h2::topology::NicAssignment;
use h2::util::cli::Args;
use h2::util::table::{fmt_bytes, fmt_duration, Table};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "search" => cmd_search(&args),
        "replan" => cmd_replan(&args),
        "simulate" => cmd_simulate(&args),
        "comm-bench" => cmd_comm_bench(&args),
        "precision" => cmd_precision(&args),
        "profile" => cmd_profile(&args),
        "fleet" => cmd_fleet(&args),
        "report" => cmd_report(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command `{other}`"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!("h2 — hyper-heterogeneous LLM training (paper reproduction)\n");
    println!("usage: h2 <command> [flags]   (every command accepts --config file.json)\n");
    println!("  train       --plan plan.json | --model h2_tiny --stages first_l2:A,last_l2:B");
    println!("              --dp 1 --micros 2 --steps 20 [--lr 1e-3] [--comm ddr|tcp|gloo]");
    println!("              [--schedule 1f1b|interleaved:V|zbv] [--comm-algo ring|...|auto]");
    println!("              [--virtual]  plan-driven virtual evaluator (no artifacts)");
    println!("              [--faults faults.json]  replay a fault-injection scenario");
    println!("              [--checkpoint-dir DIR] [--checkpoint-every N] [--keep-last K]");
    println!("              [--resume-from DIR]  (--virtual only)");
    println!("              [--no-overlap] [--perturb] [--artifacts DIR]");
    println!("  search      --exp exp-a-1 | --cluster A=256,B=256 --gbs-mtokens 2");
    println!("              [--schedule 1f1b|interleaved:V|zbv] [--no-two-stage]");
    println!("              [--comm-algo ring|tree|rhd|hierarchical|auto]");
    println!("              [--experts N]  MoE trunk (N-expert top-2 bank;");
    println!("                             --exp exp-moe implies the H2-MoE model)");
    println!("              [--ep CAP]  cap expert-parallel degrees (1 = off)");
    println!("              [--split 128] [--sequential] [--emit-plan plan.json]");
    println!("              [--progress]  periodic stderr progress lines (+ cache hits)");
    println!("  replan      --plan plan.json --exclude-chips B=8[,A=16]");
    println!("              [--full]  drop the hot-swap pipeline constraint");
    println!("              [--sequential] [--out newplan.json]");
    println!("  simulate    --plan plan.json | --exp exp-c-1 [--comm ddr|tcp]");
    println!("              [--experts N] [--ep CAP]  MoE trunk + EP cap (no --plan)");
    println!("              [--schedule 1f1b|interleaved:V|zbv] [--reshard srag|bcast|naive]");
    println!("              [--comm-algo ring|tree|rhd|hierarchical|auto]");
    println!("              [--no-overlap] [--uniform] [--non-affinity]");
    println!("  comm-bench  [--min-shift 8] [--max-shift 28]");
    println!("  precision   --chip A|B|C|D --steps 300 [--artifacts DIR]");
    println!("  profile     [--chip A] [--dp 4]");
    println!("  fleet       --exp exp-mega --trace <json|seed|pinned> [--policy fifo|priority]");
    println!("              [--jobs 12] [--workers N] [--schedule 1f1b|...] [--sequential]");
    println!("              [--faults <json|seed|pinned>]  cluster fault script");
    println!("              [--fault-response cascade|restart] [--ckpt-every 5]");
    println!("              [--emit-trace trace.json] [--out timeline.json]");
    println!("  report      table6 | fig11 | elastic | fleet [--exp exp-mega]");
}

/// Load `--config` if given (side effect: registers any custom chips).
fn load_config(args: &Args) -> Result<Option<Config>> {
    args.get("config").map(Config::load).transpose()
}

/// Resolve the model shape. The base follows the experiment: `--exp
/// exp-moe` carries its own model ([`H2_MOE`] — the cluster is sized for
/// that expert bank, not for the 100B trunk); everything else uses the
/// paper's dense 100B model. `--experts N` then swaps the base trunk's
/// FFN for an `N`-expert top-2 MoE bank (§4.3.2).
fn resolve_model(args: &Args) -> Result<ModelShape> {
    let base = match args.get("exp") {
        Some("exp-moe") | Some("moe") => H2_MOE,
        _ => H2_100B,
    };
    match args.get("experts") {
        Some(_) => {
            let n = args.usize_or("experts", 0)?;
            if n < 2 {
                bail!("--experts needs at least 2 experts (got {n})");
            }
            Ok(base.with_experts(n))
        }
        None => Ok(base),
    }
}

/// Resolve (cluster, gbs_tokens): `--exp` > `--cluster` flag > config
/// cluster > `default_exp` (if any).
fn resolve_cluster(
    args: &Args,
    config: Option<&Config>,
    default_exp: Option<&str>,
) -> Result<(Cluster, usize)> {
    // Flags > config > paper default, independently for cluster and GBS.
    // An experiment (explicit --exp or the default fallback) supplies its
    // own GBS, but an explicit user GBS still wins over it.
    let gbs_override = match args.get("gbs-mtokens") {
        Some(_) => Some(args.usize_or("gbs-mtokens", 2)? * 1024 * 1024),
        None => config.and_then(|c| c.gbs_tokens),
    };
    if let Some(exp) = args.get("exp") {
        let e = experiment(exp)?;
        return Ok((e.cluster, gbs_override.unwrap_or(e.gbs_tokens)));
    }
    let gbs = gbs_override.unwrap_or(2 * 1024 * 1024);
    if let Some(text) = args.get("cluster") {
        return Ok((parse_cluster(text)?, gbs));
    }
    if let Some(cluster) = config.and_then(|c| c.cluster.as_ref()) {
        return Ok((cluster.clone(), gbs));
    }
    if let Some(exp) = default_exp {
        let e = experiment(exp)?;
        return Ok((e.cluster, gbs_override.unwrap_or(e.gbs_tokens)));
    }
    bail!("no cluster: pass --exp, --cluster, or a --config with a `cluster` section")
}

/// Parse a `--schedule` token with a helpful error.
fn parse_schedule(s: &str) -> Result<Schedule> {
    Schedule::parse(s).ok_or_else(|| {
        anyhow::anyhow!("bad --schedule `{s}` (expected 1f1b, interleaved[:V] or zbv)")
    })
}

/// Parse a `--comm-algo` token with a helpful error.
fn parse_comm_algo(s: &str) -> Result<CommAlgo> {
    CommAlgo::parse(s).ok_or_else(|| {
        anyhow::anyhow!("bad --comm-algo `{s}` (expected ring, tree, rhd, \
                         hierarchical or auto)")
    })
}

/// Search options: config `search` section as the base, flags override.
/// `--schedule` pins the search to one schedule; the hidden legacy
/// `--alpha` maps through `Schedule::from_alpha`; the default explores
/// 1F1B, interleaved:2 and zbv. `--comm-algo` pins the DP-collective
/// algorithm the same way (default: the topology-aware auto selector).
/// `--ep` caps the expert-parallel degrees the search may try (1 pins
/// the axis off; only matters for MoE models, see `--experts`).
fn resolve_search_config(args: &Args, config: Option<&Config>) -> Result<SearchConfig> {
    let base = config.map(|c| c.search_config()).unwrap_or_default();
    let schedules = if let Some(tok) = args.get("schedule") {
        vec![parse_schedule(tok)?]
    } else if args.has("alpha") {
        vec![Schedule::from_alpha(args.f64_or("alpha", 1.0)?)]
    } else {
        base.schedules.clone()
    };
    let comm_algos = if let Some(tok) = args.get("comm-algo") {
        vec![parse_comm_algo(tok)?]
    } else {
        base.comm_algos.clone()
    };
    Ok(SearchConfig {
        schedules,
        comm_algos,
        group_split: args.usize_or("split", base.group_split)?,
        two_stage: if args.has("no-two-stage") { false } else { base.two_stage },
        max_dp: args.usize_or("max-dp", base.max_dp)?,
        max_ep: args.usize_or("ep", base.max_ep)?,
        parallel: if args.has("sequential") { false } else { base.parallel },
        progress: args.has("progress") || base.progress,
    })
}

/// Overlay the config's `sim` section and then any explicit flags onto a
/// plan's communication fields.
fn apply_sim_overrides(
    plan: &mut ExecutionPlan,
    args: &Args,
    config: Option<&Config>,
) -> Result<()> {
    if let Some(overrides) = config.and_then(|c| c.sim) {
        // Only the keys the config's `sim` section actually sets.
        let mut opts = plan.sim_options();
        overrides.apply(&mut opts);
        plan.comm = opts.comm;
        plan.reshard = opts.reshard;
        plan.nic_assignment = opts.nic_assignment;
        plan.fine_overlap = opts.fine_overlap;
        // The collective algorithm travels with the strategy, not the
        // SimOptions — land the override there.
        if let Some(algo) = overrides.comm_algo {
            plan.strategy.comm_algo = algo;
        }
    }
    if let Some(s) = args.get("comm") {
        plan.comm = CommMode::parse(s).ok_or_else(|| anyhow::anyhow!("bad --comm `{s}`"))?;
    }
    if let Some(s) = args.get("comm-algo") {
        plan.strategy.comm_algo = parse_comm_algo(s)?;
    }
    if let Some(s) = args.get("reshard") {
        plan.reshard =
            ReshardStrategy::parse(s).ok_or_else(|| anyhow::anyhow!("bad --reshard `{s}`"))?;
    }
    if args.has("non-affinity") {
        plan.nic_assignment = NicAssignment::NonAffinity;
    }
    if args.has("no-overlap") {
        plan.fine_overlap = false;
    }
    Ok(())
}

fn parse_comm(args: &Args) -> Result<CommMode> {
    let s = args.str_or("comm", "ddr");
    CommMode::parse(&s).ok_or_else(|| anyhow::anyhow!("bad --comm `{s}`"))
}

fn parse_cluster(text: &str) -> Result<Cluster> {
    let mut groups = Vec::new();
    for part in text.split(',') {
        let (kind, n) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--cluster expects A=256,B=256 style"))?;
        let kind = ChipKind::parse(kind)
            .ok_or_else(|| anyhow::anyhow!("unknown chip `{kind}`"))?;
        groups.push((kind, n.parse()?));
    }
    Cluster::try_build("custom", groups)
}

fn parse_stages(text: &str) -> Result<Vec<StagePlan>> {
    let mut stages = Vec::new();
    for part in text.split(',') {
        let (prefix, chip) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("--stages expects prefix:CHIP,..."))?;
        let chip = ChipKind::parse(chip)
            .ok_or_else(|| anyhow::anyhow!("unknown chip `{chip}`"))?;
        stages.push(StagePlan { prefix: prefix.to_string(), chip });
    }
    Ok(stages)
}

fn print_train_report(report: &TrainReport, steps: usize) {
    println!("[h2] done: wall {:.1}s, modeled iter {:.4}s ({:.4}s comm), {:.0} tokens/s",
             report.wall_seconds,
             report.virtual_seconds / steps.max(1) as f64,
             report.virtual_comm_seconds / steps.max(1) as f64,
             report.tokens_per_second);
    println!("[h2] loss: first {:.4} last {:.4}",
             report.losses.first().unwrap_or(&f64::NAN),
             report.losses.last().unwrap_or(&f64::NAN));
}

/// FNV-1a over the bit patterns of the final parameters — a compact
/// machine-readable fingerprint for cross-algorithm identity checks.
fn params_fingerprint(params: &[Vec<f32>]) -> u64 {
    h2::util::hash::fnv1a(
        params
            .iter()
            .flat_map(|stage| stage.iter().flat_map(|x| x.to_bits().to_le_bytes())),
    )
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    if let Some(path) = args.get("plan") {
        if args.has("model") || args.has("stages") {
            bail!("--model/--stages conflict with --plan; edit the plan's \
                   `train` section instead");
        }
        let mut plan = ExecutionPlan::load(path)?;
        // Explicit flags override what the plan searched/priced — warn
        // loudly so a run that diverges from its plan is visible.
        if let Some(s) = args.get("comm-algo") {
            let new = parse_comm_algo(s)?;
            if new != plan.strategy.comm_algo {
                eprintln!("[h2] warning: --comm-algo {new} overrides the plan's \
                           `{}`", plan.strategy.comm_algo);
            }
        }
        if let Some(tok) = args.get("schedule") {
            let new = parse_schedule(tok)?;
            if new != plan.strategy.schedule {
                eprintln!("[h2] warning: --schedule {new} overrides the plan's \
                           `{}`", plan.strategy.schedule);
            }
            plan.strategy.schedule = new;
            if let Err(errs) = plan.validate() {
                bail!("plan cannot run under --schedule {}:\n{}",
                      plan.strategy.schedule, render_errors(&errs));
            }
        }
        // The same config/flag overrides `simulate --plan` honors apply to
        // the real run too (comm, comm-algo, NIC affinity, overlap), plus
        // --perturb and the cheap run-shape scalars.
        apply_sim_overrides(&mut plan, args, config.as_ref())?;
        if args.has("perturb") {
            plan.precision.perturb = true;
        }
        if args.has("virtual") {
            // Plan-driven virtual evaluator: executes the plan's schedule
            // and collective algorithm with modeled compute — no PJRT
            // artifacts needed, comparable to simulate/evaluate.
            // Run shape comes from the plan's *strategy* (dp, micro
            // batches) — honoring --dp/--micros would break the plan's
            // batch arithmetic, and the synthetic model has no vendor
            // noise to perturb, so reject rather than silently ignore.
            for flag in ["dp", "micros", "perturb"] {
                if args.has(flag) {
                    bail!("--{flag} does not apply to --virtual (the virtual \
                           evaluator executes the plan's strategy as-is; edit \
                           the plan instead)");
                }
            }
            let mut vopts = VirtualOptions::from_plan(&plan);
            vopts.steps = args.usize_or("steps", vopts.steps)?;
            vopts.lr = args.f64_or("lr", vopts.lr as f64)? as f32;
            vopts.seed = args.u64_or("seed", vopts.seed)?;
            vopts.log_every = args.usize_or("log-every", vopts.log_every)?;
            // Config `elastic` section first, then flags on top: an
            // explicit --faults file overrides both the config's path and
            // any fault plan embedded in the execution plan.
            if let Some(e) = config.as_ref().and_then(|c| c.elastic.as_ref()) {
                if let Some(k) = e.keep_last {
                    vopts.keep_last = k;
                }
                if let Some(path) = &e.faults {
                    vopts.faults = Some(FaultPlan::load(path)?);
                }
            }
            if let Some(p) = args.get("faults") {
                vopts.faults = Some(FaultPlan::load(p)?);
            }
            if let Some(dir) = args.get("checkpoint-dir") {
                vopts.checkpoint_dir = Some(dir.into());
            }
            vopts.checkpoint_every = args.usize_or("checkpoint-every", vopts.checkpoint_every)?;
            vopts.keep_last = args.usize_or("keep-last", vopts.keep_last)?;
            if let Some(dir) = args.get("resume-from") {
                vopts.resume_from = Some(dir.into());
            }
            let report = train_virtual(&plan, &vopts)?;
            println!("[h2] virtual evaluator: plan `{}` ({} stages x dp {}, {} / {})",
                     plan.name, plan.strategy.total_stages(), plan.strategy.s_dp,
                     plan.schedule(), plan.strategy.comm_algo);
            println!("[h2] modeled step {:.6}s ({:.6}s comm); loss first {:.4} last {:.4}",
                     report.step_seconds, report.comm_seconds,
                     report.losses.first().unwrap_or(&f64::NAN),
                     report.losses.last().unwrap_or(&f64::NAN));
            if let Some(step) = report.halted_at {
                println!("[h2] chip death at step {step}: ran {} of {} steps — \
                          checkpoint, `h2 replan`, and resume",
                         report.losses.len(), vopts.steps.saturating_sub(report.start_step));
            }
            // Full-precision values for scripts and the parity tests.
            println!("virtual_step_seconds {:.17e}", report.step_seconds);
            println!("virtual_comm_seconds {:.17e}", report.comm_seconds);
            println!("params_fnv {:016x}", params_fingerprint(&report.final_params));
            return Ok(());
        }
        if let Some(t) = plan.train.as_mut() {
            t.steps = args.usize_or("steps", t.steps)?;
            t.micro_batches = args.usize_or("micros", t.micro_batches)?;
            t.dp = args.usize_or("dp", t.dp)?;
            t.seed = args.u64_or("seed", t.seed)?;
            t.lr = args.f64_or("lr", t.lr as f64)? as f32;
            t.log_every = args.usize_or("log-every", t.log_every)?;
        }
        let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
        println!("[h2] platform={} plan=`{}` ({} train stages)",
                 rt.platform(), plan.name,
                 plan.train.as_ref().map(|t| t.stages.len()).unwrap_or(0));
        let steps = plan.train.as_ref().map(|t| t.steps).unwrap_or(0);
        let report = train_plan(&rt, &plan)?;
        print_train_report(&report, steps);
        return Ok(());
    }
    if let Some(c) = config.as_ref() {
        if let Some(cfg) = c.train.clone() {
            let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
            let report = train(&rt, &cfg)?;
            print_train_report(&report, cfg.steps);
            return Ok(());
        }
        // A config without `train` only makes sense here if the job itself
        // comes from flags; otherwise it's almost certainly a typo'd
        // section name — fail loudly rather than train a default job.
        if !args.has("model") && !args.has("stages") {
            bail!("config `{}` has no `train` section (pass --model/--stages \
                   to train from flags)", args.str_or("config", "?"));
        }
    }
    let model = args.str_or("model", "h2_tiny");
    let stages = parse_stages(&args.str_or("stages", "first_l2:A,last_l2:B"))?;
    let cfg = TrainConfig {
        model: model.clone(),
        stages,
        dp: args.usize_or("dp", 1)?,
        micro_batches: args.usize_or("micros", 2)?,
        steps: args.usize_or("steps", 20)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        seed: args.u64_or("seed", 42)?,
        schedule: match args.get("schedule") {
            Some(s) => parse_schedule(s)?,
            None => Schedule::OneF1B,
        },
        comm_algo: match args.get("comm-algo") {
            Some(s) => parse_comm_algo(s)?,
            None => CommAlgo::Ring,
        },
        comm: parse_comm(args)?,
        nic_assignment: if args.has("non-affinity") {
            NicAssignment::NonAffinity
        } else {
            NicAssignment::Affinity
        },
        fine_overlap: !args.has("no-overlap"),
        perturb: args.has("perturb"),
        log_every: args.usize_or("log-every", 10)?,
    };
    let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
    println!("[h2] platform={} model={model} stages={} dp={} micros={} steps={}",
             rt.platform(), cfg.stages.len(), cfg.dp, cfg.micro_batches, cfg.steps);
    let report = train(&rt, &cfg)?;
    print_train_report(&report, cfg.steps);
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let (cluster, gbs) = resolve_cluster(args, config.as_ref(), None)?;
    let cfg = resolve_search_config(args, config.as_ref())?;
    let model = resolve_model(args)?;
    let r = search(&model, &cluster, gbs, &cfg)?;
    println!("HeteroAuto on `{}` ({} chips, GBS {}M tokens): {} candidates in {} \
              ({} leaves pruned, profile cache {} hits / {} misses)",
             cluster.name, cluster.total_chips(), gbs >> 20,
             r.candidates_explored, fmt_duration(r.elapsed_seconds), r.leaves_pruned,
             r.cache_hits, r.cache_misses);
    let mut t = Table::new(&["group", "chips", "s_pp", "s_tp", "layers", "recompute"]);
    for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
        t.row(vec![
            g.spec.kind.to_string(),
            g.n_chips.to_string(),
            p.s_pp.to_string(),
            p.s_tp.to_string(),
            p.layers.to_string(),
            p.recompute.to_string(),
        ]);
    }
    t.print();
    println!("s_dp = {}, s_ep = {}, micro-batches = {}, schedule = {}, comm-algo = {}",
             r.strategy.s_dp, r.strategy.s_ep, r.strategy.micro_batches,
             r.strategy.schedule, r.strategy.comm_algo);
    println!("estimated iteration: {} -> TGS {:.1}",
             fmt_duration(r.eval.iteration_seconds),
             tgs(&cluster, gbs, r.eval.iteration_seconds));
    if let Some(path) = args.get("emit-plan") {
        let mut plan = r.into_plan(&model, &cluster, gbs);
        apply_sim_overrides(&mut plan, args, config.as_ref())?;
        // The config's train section rides along so `h2 train --plan` works
        // from the emitted file alone.
        if let Some(c) = config.as_ref() {
            if let Some(spec) = c.train_spec() {
                plan.precision.perturb = c.train.as_ref().map(|t| t.perturb).unwrap_or(false);
                plan.train = Some(spec);
            }
        }
        if let Err(errs) = plan.validate() {
            bail!("emitted plan would be invalid:\n{}", render_errors(&errs));
        }
        plan.save(path)?;
        println!("[h2] wrote plan `{}` to {path}", plan.name);
    }
    Ok(())
}

/// Parse the `--exclude-chips B=8,A=16` list into a [`ClusterDelta`].
fn parse_exclusions(text: &str) -> Result<ClusterDelta> {
    let mut delta = ClusterDelta::default();
    for part in text.split(',') {
        let (kind, n) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--exclude-chips expects B=8,A=16 style"))?;
        let kind = ChipKind::parse(kind)
            .ok_or_else(|| anyhow::anyhow!("unknown chip `{kind}`"))?;
        delta.dead.push((kind, n.parse()?));
    }
    Ok(delta)
}

fn cmd_replan(args: &Args) -> Result<()> {
    let _config = load_config(args)?; // registers custom chips the plan may use
    let path = args
        .get("plan")
        .ok_or_else(|| anyhow::anyhow!("replan needs --plan plan.json"))?;
    let incumbent = ExecutionPlan::load(&path)?;
    let delta = match args.get("exclude-chips") {
        Some(text) => parse_exclusions(&text)?,
        None => ClusterDelta::default(),
    };
    let opts = ReplanOptions {
        keep_pipeline: !args.has("full"),
        parallel: !args.has("sequential"),
    };
    // A cold cache here: the CLI has no process to inherit warm profiles
    // from. In-process callers (the elastic loop, the benches) pass the
    // search's own cache and replan near-instantly.
    let cache = ProfileCache::new();
    let out = replan(&incumbent, &delta, &cache, &opts)?;
    if !out.changed {
        println!("[h2] cluster unchanged: keeping `{}` at plan_epoch {}",
                 incumbent.name, incumbent.plan_epoch);
        return Ok(());
    }
    println!("[h2] replanned `{}`: {} -> {} chips, plan_epoch {} -> {} \
              ({}, cache {} hits / {} misses, {})",
             incumbent.name,
             incumbent.cluster.total_chips(), out.plan.cluster.total_chips(),
             incumbent.plan_epoch, out.plan.plan_epoch,
             if opts.keep_pipeline { "pipeline-preserving" } else { "full re-search" },
             out.cache_hits, out.cache_misses,
             fmt_duration(out.elapsed_seconds));
    if out.idled_chips > 0 {
        println!("[h2] {} surviving chips idled (no complete s_pp x s_tp x s_dp \
                  slice left for them; a --full replan reclaims them)",
                 out.idled_chips);
    }
    let mut t = Table::new(&["group", "chips", "s_pp", "s_tp", "layers", "recompute"]);
    for (g, p) in out.plan.stage_groups.iter().zip(&out.plan.strategy.plans) {
        t.row(vec![
            g.spec.kind.to_string(),
            g.n_chips.to_string(),
            p.s_pp.to_string(),
            p.s_tp.to_string(),
            p.layers.to_string(),
            p.recompute.to_string(),
        ]);
    }
    t.print();
    let eval = out.plan.evaluate();
    println!("estimated iteration: {} -> TGS {:.1}",
             fmt_duration(eval.iteration_seconds),
             out.plan.tgs(eval.iteration_seconds));
    if let Some(dst) = args.get("out") {
        out.plan.save(&dst)?;
        println!("[h2] wrote plan `{}` (epoch {}) to {dst}",
                 out.plan.name, out.plan.plan_epoch);
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let mut plan = if let Some(path) = args.get("plan") {
        ExecutionPlan::load(path)?
    } else {
        let (cluster, gbs) = resolve_cluster(args, config.as_ref(), Some("exp-c-1"))?;
        let scfg = resolve_search_config(args, config.as_ref())?;
        let model = resolve_model(args)?;
        let r = search(&model, &cluster, gbs, &scfg)?;
        r.into_plan(&model, &cluster, gbs)
    };
    apply_sim_overrides(&mut plan, args, config.as_ref())?;
    if let Some(tok) = args.get("schedule") {
        // `--uniform` *defines* its baseline as plain 1F1B (and rewrites
        // the layer layout the schedule would validate against), so an
        // explicit schedule override cannot compose with it.
        if args.has("uniform") {
            bail!("--schedule conflicts with --uniform (the uniform baseline \
                   is 1F1B by definition)");
        }
        // Re-schedule a persisted plan without re-searching; the plan must
        // still validate (e.g. interleaving has to chunk every stage).
        plan.strategy.schedule = parse_schedule(tok)?;
        if let Err(errs) = plan.validate() {
            bail!("plan cannot run under --schedule {}:\n{}",
                  plan.strategy.schedule, render_errors(&errs));
        }
    }
    if args.has("uniform") {
        // Uniform 1F1B baseline: equal layer count on every stage,
        // recomputation everywhere (the homogeneous-style configuration).
        uniform_1f1b(&mut plan.strategy, plan.model.n_layers);
        let total = plan.strategy.total_layers();
        if total != plan.model.n_layers {
            bail!("uniform 1F1B baseline unreachable for this stage layout: \
                   closest layer total is {total} of {} — the reported time \
                   would correspond to the wrong amount of work",
                  plan.model.n_layers);
        }
    }
    let sim = simulate_plan(&plan);
    println!("simulated `{}` under {} / {} collectives: iteration {} (bubble {:.1}%, \
              exposed comm {})",
             plan.cluster.name,
             plan.schedule(),
             plan.strategy.comm_algo,
             fmt_duration(sim.iteration_seconds),
             sim.bubble_fraction * 100.0,
             fmt_duration(sim.exposed_comm));
    println!("TGS {:.1}", plan.tgs(sim.iteration_seconds));
    // Full-precision value for scripts (and the search->plan parity test).
    println!("iteration_seconds {:.17e}", sim.iteration_seconds);
    Ok(())
}

fn cmd_comm_bench(args: &Args) -> Result<()> {
    let _config = load_config(args)?; // registers custom chips for parity
    let lo = args.usize_or("min-shift", 8)?;
    let hi = args.usize_or("max-shift", 28)?;
    let mut t = Table::new(&["size", "TCP", "CPU-RDMA", "DDR", "TCP/DDR"])
        .with_title("Fig 7 — cross-chip P2P latency by strategy");
    let mut ratios = Vec::new();
    let mut shift = lo;
    while shift <= hi {
        let bytes = 1usize << shift;
        let tcp = p2p_latency(CommMode::TcpCpu, bytes);
        let mid = p2p_latency(CommMode::RdmaCpu, bytes);
        let ddr = p2p_latency(CommMode::DeviceDirect, bytes);
        ratios.push(tcp / ddr);
        t.row(vec![
            fmt_bytes(bytes as f64),
            fmt_duration(tcp),
            fmt_duration(mid),
            fmt_duration(ddr),
            format!("{:.2}x", tcp / ddr),
        ]);
        shift += 2;
    }
    t.print();
    println!("average TCP/DDR ratio: {:.2}x (paper: 9.94x, range 1.79-16.0x)",
             ratios.iter().sum::<f64>() / ratios.len() as f64);
    Ok(())
}

fn cmd_precision(args: &Args) -> Result<()> {
    let _config = load_config(args)?; // may declare the chip under test
    let chip = ChipKind::parse(args.str_or("chip", "A").as_str())
        .ok_or_else(|| anyhow::anyhow!("bad --chip"))?;
    let steps = args.usize_or("steps", 300)?;
    let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;
    let stages = |c: ChipKind| vec![
        StagePlan { prefix: "first_l2".into(), chip: c },
        StagePlan { prefix: "last_l2".into(), chip: c },
    ];
    let mut cfg = TrainConfig::quick("h2_tiny", stages(ChipKind::A100), 1, 2, steps);
    cfg.log_every = 0;
    cfg.perturb = true;
    println!("[h2] reference run (A100, {steps} steps)...");
    let reference = train(&rt, &cfg)?;
    cfg.stages = stages(chip);
    println!("[h2] measured run ({chip}, {steps} steps)...");
    let measured = train(&rt, &cfg)?;
    let report = check_alignment(chip, &reference.losses, &measured.losses);
    println!("{chip}: MRE {:.3}% over {} iterations -> {}",
             report.mre * 100.0, report.n_iterations,
             if report.aligned { "ALIGNED (< 1.5%)" } else { "NOT ALIGNED" });
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let dp = args.usize_or("dp", 4)?;
    let mut t = Table::new(&["chip", "tp", "t_fwd", "t_bwd", "t_recomp", "t_update"])
        .with_title("Layer-wise analytic profile (100B model, 4096-token microbatch)");
    let chips: Vec<ChipKind> = match args.get("chip") {
        Some(c) => vec![ChipKind::parse(c).ok_or_else(|| anyhow::anyhow!("bad --chip"))?],
        None => {
            // Built-ins plus any chips the config declared.
            let mut all = ChipKind::ALL.to_vec();
            if let Some(c) = &config {
                for def in &c.chips {
                    if let Some(k) = ChipKind::parse(&def.name) {
                        all.push(k);
                    }
                }
            }
            all
        }
    };
    for kind in chips {
        let sp = spec(kind);
        let mut tp = 1;
        while tp <= sp.tp_max() {
            let p = profile_layer(&sp, &H2_100B, tp, 4096, dp);
            t.row(vec![
                kind.to_string(),
                tp.to_string(),
                fmt_duration(p.t_fwd),
                fmt_duration(p.t_bwd),
                fmt_duration(p.t_recompute),
                fmt_duration(p.t_update),
            ]);
            tp *= 2;
        }
    }
    t.print();
    Ok(())
}

/// `h2 fleet` — pack a queue of jobs onto one cluster and print the
/// timeline + fleet metrics. `--trace` takes a JSON trace file, a
/// decimal seed for the generator, or `pinned` for the checked-in
/// contrast scenario; same trace + policy ⇒ bit-identical timeline.
fn cmd_fleet(args: &Args) -> Result<()> {
    let config = load_config(args)?;
    let fleet_cfg = config.as_ref().and_then(|c| c.fleet.clone()).unwrap_or_default();
    let (cluster, _gbs) = resolve_cluster(args, config.as_ref(), Some("exp-mega"))?;
    let jobs = args.usize_or("jobs", fleet_cfg.jobs.unwrap_or(12))?;
    let trace_tok = args.get("trace").map(str::to_string).or_else(|| fleet_cfg.trace.clone());
    let trace = match trace_tok.as_deref() {
        Some("pinned") => JobTrace::pinned(cluster.total_chips()),
        Some(tok) => match tok.parse::<u64>() {
            Ok(seed) => JobTrace::generate(seed, jobs, cluster.total_chips()),
            Err(_) => JobTrace::load(tok)?,
        },
        None => JobTrace::generate(fleet_cfg.seed.unwrap_or(42), jobs, cluster.total_chips()),
    };
    if let Some(path) = args.get("emit-trace") {
        trace.save(path)?;
        println!("trace ({} jobs, seed {}) written to {path}", trace.jobs.len(), trace.seed);
    }
    let policy = match args.get("policy") {
        Some(tok) => Policy::parse(tok)?,
        None => fleet_cfg.policy.unwrap_or_default(),
    };
    let mut search = fleet_search_config();
    if let Some(tok) = args.get("schedule") {
        search.schedules = vec![parse_schedule(tok)?];
    }
    if args.has("sequential") {
        search.parallel = false;
    }
    let workers = args.usize_or("workers", fleet_cfg.workers.unwrap_or(0))?;
    let response = match args.get("fault-response") {
        Some(tok) => FaultResponse::parse(tok)?,
        None => FaultResponse::default(),
    };
    let checkpoint_every = args.usize_or("ckpt-every", 5)? as u64;
    let base = FleetOptions { policy, workers, search, faults: None, response, checkpoint_every };
    // `--faults` takes a JSON fault-plan file, a decimal seed for the
    // generator, or `pinned` for the contrast scenario derived from a
    // healthy run of the same trace.
    let faults_tok = args.get("faults").map(str::to_string).or_else(|| fleet_cfg.faults.clone());
    let opts = match faults_tok.as_deref() {
        Some("pinned") => {
            let healthy = h2::fleet::run(&cluster, &trace, &base)?;
            let plan = ClusterFaultPlan::pinned_for(&cluster, &healthy)?;
            FleetOptions { faults: Some(plan), ..base }
        }
        Some(tok) => {
            let plan = match tok.parse::<u64>() {
                Ok(seed) => ClusterFaultPlan::generate(seed, &cluster, trace.horizon_seconds()),
                Err(_) => ClusterFaultPlan::load(tok)?,
            };
            FleetOptions { faults: Some(plan), ..base }
        }
        None => base,
    };
    let timeline = h2::fleet::run(&cluster, &trace, &opts)?;

    let mut t = Table::new(&["job", "prio", "arrival", "wait", "finish", "chips"])
        .with_title(&format!(
            "Fleet on `{}` ({} chips) — policy {}",
            cluster.name,
            cluster.total_chips(),
            policy.token()
        ));
    for j in &timeline.jobs {
        t.row(vec![
            j.id.to_string(),
            j.priority.to_string(),
            fmt_duration(j.arrival_seconds),
            j.wait_seconds.map(fmt_duration).unwrap_or_else(|| "rejected".into()),
            j.finish_seconds.map(fmt_duration).unwrap_or_else(|| "-".into()),
            j.chips.to_string(),
        ]);
    }
    t.print();
    let m = &timeline.metrics;
    println!(
        "{} events; {} completed, {} rejected, {} preemptions; makespan {}, \
         p99 wait {}, utilization {:.1}%",
        timeline.events.len(), m.completed, m.rejected, m.preemptions,
        fmt_duration(m.makespan_seconds), fmt_duration(m.p99_wait_seconds),
        100.0 * m.utilization
    );
    if opts.faults.is_some() {
        println!(
            "{} fault events ({} response); {} chips still dead, {} steps recomputed, \
             recovery {} total, goodput {:.1}%",
            m.faults, opts.response.token(), m.dead_chips, m.recomputed_steps,
            fmt_duration(m.recovery_seconds_total), 100.0 * m.goodput_fraction
        );
    }
    if let Some(path) = args.get("out") {
        timeline.save(path)?;
        println!("timeline written to {path}");
    }
    // Machine-readable lines (full precision, for scripts and tests).
    println!("fleet_policy {}", policy.token());
    println!("fleet_jobs {}", m.jobs);
    println!("fleet_completed {}", m.completed);
    println!("fleet_rejected {}", m.rejected);
    println!("fleet_preemptions {}", m.preemptions);
    println!("fleet_makespan_seconds {:.17e}", m.makespan_seconds);
    println!("fleet_mean_wait_seconds {:.17e}", m.mean_wait_seconds);
    println!("fleet_p99_wait_seconds {:.17e}", m.p99_wait_seconds);
    println!("fleet_utilization {:.17e}", m.utilization);
    println!("fleet_faults {}", m.faults);
    println!("fleet_dead_chips {}", m.dead_chips);
    println!("fleet_recomputed_steps {}", m.recomputed_steps);
    println!("fleet_recovery_seconds {:.17e}", m.recovery_seconds_total);
    println!("fleet_goodput {:.17e}", m.goodput_fraction);
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let _config = load_config(args)?; // registers custom chips for parity
    match args.positional.get(1).map(|s| s.as_str()).unwrap_or("table6") {
        "table6" => {
            let mut t = Table::new(&["chip", "PP", "DP", "TP", "extra", "TGS (model)",
                                     "TGS (sim)", "TGS (paper)"])
                .with_title("Table 6 — homogeneous 256-chip baselines, 100B model");
            for (row, &(_, pp, dpd, tp, rec, _)) in
                h2::report::table6_all().iter().zip(&h2::report::TABLE6)
            {
                let extra = if rec {
                    "recompute"
                } else if row.kind == ChipKind::D {
                    "offload"
                } else {
                    "-"
                };
                t.row(vec![
                    row.kind.to_string(), pp.to_string(), dpd.to_string(), tp.to_string(),
                    extra.to_string(),
                    format!("{:.1}", row.model_tgs),
                    format!("{:.1}", row.sim_tgs),
                    format!("{:.1}", row.paper_tgs),
                ]);
            }
            t.print();
        }
        "fig11" => {
            let baselines = h2::report::table6_all();
            for exp_name in h2::hetero::ALL_EXPERIMENTS {
                let row = h2::report::hetero_row(exp_name, &baselines)?;
                println!("{exp_name}: TGS {:.1}, HeteroSpeedupRatio {:.2}% (search {}, {} candidates)",
                         row.sim_tgs, row.speedup_ratio,
                         fmt_duration(row.search.elapsed_seconds),
                         row.search.candidates_explored);
            }
        }
        "elastic" => {
            let exp_name = args.str_or("exp", "exp-mega");
            let rep = h2::report::recovery_vs_restart(&exp_name)?;
            let (kind, n) = rep.killed;
            println!("kill-a-node on `{exp_name}`: {n} {kind} chips died; \
                      pipeline-preserving replan to plan_epoch {} in {} \
                      (cache {} hits / {} misses, {} survivors idled)",
                     rep.outcome.plan.plan_epoch,
                     fmt_duration(rep.outcome.elapsed_seconds),
                     rep.outcome.cache_hits, rep.outcome.cache_misses,
                     rep.outcome.idled_chips);
            let mut t = Table::new(&["evaluator", "step", "replan", "migrate",
                                     "recovery", "search", "restore", "restart",
                                     "win"])
                .with_title("Elastic recovery vs restart-from-checkpoint");
            for row in &rep.rows {
                let tl = &row.timeline;
                t.row(vec![
                    row.evaluator.to_string(),
                    fmt_duration(row.step_seconds),
                    fmt_duration(tl.replan_seconds),
                    fmt_duration(tl.migrate_seconds),
                    fmt_duration(tl.recovery_seconds()),
                    fmt_duration(tl.search_seconds),
                    fmt_duration(tl.restore_seconds),
                    fmt_duration(tl.restart_seconds()),
                    format!("{:.2}x", tl.restart_seconds() / tl.recovery_seconds()),
                ]);
            }
            t.print();
        }
        "fleet" => {
            let exp_name = args.str_or("exp", "exp-mega");
            let workers = args.usize_or("workers", 0)?;
            let rows = h2::report::fleet_metrics(&exp_name, workers)?;
            let mut t = Table::new(&["policy", "completed", "rejected", "preempt",
                                     "makespan", "mean wait", "p99 wait", "util"])
                .with_title(&format!("Fleet policies on `{exp_name}` — pinned trace"));
            for row in &rows {
                let m = &row.metrics;
                t.row(vec![
                    row.policy.token().to_string(),
                    format!("{}/{}", m.completed, m.jobs),
                    m.rejected.to_string(),
                    m.preemptions.to_string(),
                    fmt_duration(m.makespan_seconds),
                    fmt_duration(m.mean_wait_seconds),
                    fmt_duration(m.p99_wait_seconds),
                    format!("{:.1}%", 100.0 * m.utilization),
                ]);
            }
            t.print();
            let rows = h2::report::fleet_fault_metrics(&exp_name, workers)?;
            let mut t = Table::new(&["run", "completed", "makespan", "recomputed",
                                     "recovery", "util", "goodput"])
                .with_title(&format!(
                    "Fleet faults on `{exp_name}` — pinned fault plan, FIFO, ckpt every 10"
                ));
            for row in &rows {
                let m = &row.metrics;
                t.row(vec![
                    row.label.to_string(),
                    format!("{}/{}", m.completed, m.jobs),
                    fmt_duration(m.makespan_seconds),
                    m.recomputed_steps.to_string(),
                    fmt_duration(m.recovery_seconds_total),
                    format!("{:.1}%", 100.0 * m.utilization),
                    format!("{:.1}%", 100.0 * m.goodput_fraction),
                ]);
            }
            t.print();
        }
        other => bail!("unknown report `{other}`"),
    }
    Ok(())
}
