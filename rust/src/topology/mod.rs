//! Server/NIC topology model (§5, Table 3, Figure 3).
//!
//! Captures the two hyper-heterogeneity complications the paper's
//! topology-aware resharding addresses:
//!
//! 1. servers have *multiple NICs with varying counts and affinities* —
//!    a chip reaches its affine NIC over a short PCIe path, and a
//!    non-affine NIC only across the inter-switch uplink;
//! 2. PCIe links between switches and chips can bottleneck a NIC, so
//!    multiple chips must transmit concurrently to saturate one NIC.
//!
//! Per-flow constants are calibrated to the paper's own Table 3
//! measurements (affinity: 9.56 / 9.91 GB/s; non-affinity: 5.51 / 5.23) —
//! see EXPERIMENTS.md for the paper-vs-model comparison.

use crate::hetero::{ChipKind, ChipSpec};

/// How chips are mapped to NICs for cross-node communication.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NicAssignment {
    /// Each chip uses the NIC behind its own PCIe switch (paper's §5 fix).
    Affinity,
    /// Chips use whichever NIC was configured first — flows cross the
    /// inter-switch uplink and contend.
    NonAffinity,
}

impl NicAssignment {
    /// Parse a policy token (`affinity`, `non-affinity`).
    pub fn parse(s: &str) -> Option<NicAssignment> {
        match s.to_ascii_lowercase().as_str() {
            "affinity" => Some(NicAssignment::Affinity),
            "non-affinity" => Some(NicAssignment::NonAffinity),
            _ => None,
        }
    }

    /// Canonical token, accepted back by [`NicAssignment::parse`].
    pub fn token(self) -> &'static str {
        match self {
            NicAssignment::Affinity => "affinity",
            NicAssignment::NonAffinity => "non-affinity",
        }
    }
}

/// RDMA protocol efficiency on the wire (headers, MTU, ack overhead).
pub const RDMA_EFFICIENCY: f64 = 0.8;

/// Per-flow cross-node bandwidth (GB/s) for one chip-to-chip flow when all
/// chips of the source server transmit concurrently (the Table 3 workload).
///
/// The flow rate is the min of the source path and destination path; each
/// path is the chip↔NIC PCIe rate (possibly degraded by non-affinity) capped
/// by the per-chip share of the server's NIC capacity. The NIC-path
/// constants live on [`ChipSpec`] (chip-specific, Table 3 calibration), so
/// a snapshotted spec — e.g. inside a loaded plan's chip groups — stays
/// self-consistent even if the chip registry is later re-registered.
pub fn flow_bandwidth_gbps(src: &ChipSpec, dst: &ChipSpec, assign: NicAssignment) -> f64 {
    let path = |spec: &ChipSpec, a: NicAssignment| -> f64 {
        let mut chip_rate = spec.pcie_to_nic_gbps * RDMA_EFFICIENCY;
        if a == NicAssignment::NonAffinity {
            chip_rate *= spec.cross_switch_share;
        }
        // NIC capacity is shared by the chips concurrently mapped onto it
        // (the Table 3 workload drives all chips of the server at once).
        let chips_per_nic = (spec.chips_per_node as f64 / spec.nics_per_node as f64).max(1.0);
        let nic_share = spec.nic_gbps * RDMA_EFFICIENCY / chips_per_nic;
        chip_rate.min(nic_share)
    };
    // Destination side keeps its affinity configuration (the paper toggles
    // the source server's mapping).
    path(src, assign).min(path(dst, NicAssignment::Affinity))
}

/// Whole-node rounding rule shared by the collective engine: the largest
/// group size that divides `n_ranks` while staying within
/// `ranks_per_node`. One definition keeps the closed-form topology view
/// ([`crate::comm::CommTopology`]), the executable collective dispatcher
/// and [`co_located_replicas`] in exact agreement on group shape.
pub fn whole_node_group(n_ranks: usize, ranks_per_node: usize) -> usize {
    let cap = ranks_per_node.clamp(1, n_ranks.max(1));
    (1..=cap).rev().find(|k| n_ranks % k == 0).unwrap_or(1)
}

/// Data-parallel replicas of one pipeline stage that share a server: a
/// stage occupies `s_tp` chip slots, so `chips_per_node / s_tp` replicas
/// fit per node — clamped to the group size and rounded down to a divisor
/// of `dp` so the DP group always fills whole nodes. This is the
/// `ranks_per_node` input of the hierarchical collective's topology
/// ([`crate::comm::CommTopology`]).
pub fn co_located_replicas(spec: &ChipSpec, s_tp: usize, dp: usize) -> usize {
    whole_node_group(dp.max(1), (spec.chips_per_node / s_tp.max(1)).max(1))
}

/// Intra-node chip-to-chip bandwidth matrix for Fig 3.
pub fn intra_node_matrix(spec: &ChipSpec) -> Vec<Vec<f64>> {
    let n = spec.chips_per_node;
    (0..n)
        .map(|a| (0..n).map(|b| if a == b { 0.0 } else { spec.intra_node.bandwidth_gbps(a, b) }).collect())
        .collect()
}

/// Summary of one server design's intra-node behaviour (Fig 3 rows).
#[derive(Clone, Debug)]
pub struct IntraNodeProfile {
    /// The chip/server design profiled.
    pub kind: ChipKind,
    /// Slowest chip-to-chip bandwidth in the node.
    pub min_gbps: f64,
    /// Fastest chip-to-chip bandwidth in the node.
    pub max_gbps: f64,
    /// Whether every pair communicates at the same rate.
    pub uniform: bool,
    /// Largest uniform-bandwidth TP group.
    pub tp_max: usize,
}

/// Summarize one server design's intra-node bandwidth shape (Fig 3 row).
pub fn intra_node_profile(spec: &ChipSpec) -> IntraNodeProfile {
    let m = intra_node_matrix(spec);
    let mut lo = f64::INFINITY;
    let mut hi: f64 = 0.0;
    for (a, row) in m.iter().enumerate() {
        for (b, &bw) in row.iter().enumerate() {
            if a != b {
                lo = lo.min(bw);
                hi = hi.max(bw);
            }
        }
    }
    IntraNodeProfile {
        kind: spec.kind,
        min_gbps: lo,
        max_gbps: hi,
        uniform: (hi - lo).abs() < 1e-9,
        tp_max: spec.tp_max(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{spec, ChipKind};

    #[test]
    fn table3_affinity_rows_reproduced() {
        // Chip A -> B: 5.51 -> 9.56 GB/s (73.5% improvement).
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let aff = flow_bandwidth_gbps(&a, &b, NicAssignment::Affinity);
        let non = flow_bandwidth_gbps(&a, &b, NicAssignment::NonAffinity);
        assert!((aff - 9.56).abs() < 0.1, "affinity A->B {aff}");
        assert!((non - 5.51).abs() < 0.1, "non-affinity A->B {non}");
        let improvement = (aff - non) / non;
        assert!((improvement - 0.735).abs() < 0.05, "improvement {improvement}");

        // Chip B -> D: 5.23 -> 9.91 GB/s (89.5% improvement).
        let d = spec(ChipKind::D);
        let aff = flow_bandwidth_gbps(&b, &d, NicAssignment::Affinity);
        let non = flow_bandwidth_gbps(&b, &d, NicAssignment::NonAffinity);
        assert!((aff - 9.91).abs() < 0.1, "affinity B->D {aff}");
        assert!((non - 5.23).abs() < 0.1, "non-affinity B->D {non}");
    }

    #[test]
    fn affinity_never_hurts() {
        for &s in ChipKind::ALL.iter() {
            for &d in ChipKind::ALL.iter() {
                let ss = spec(s);
                let dd = spec(d);
                assert!(flow_bandwidth_gbps(&ss, &dd, NicAssignment::Affinity)
                        >= flow_bandwidth_gbps(&ss, &dd, NicAssignment::NonAffinity));
            }
        }
    }

    #[test]
    fn fig3_shapes() {
        // A-node: uniform; B-node: NUMA split; C-node: PCIe hierarchy.
        assert!(intra_node_profile(&spec(ChipKind::A)).uniform);
        let b = intra_node_profile(&spec(ChipKind::B));
        assert!(!b.uniform);
        assert!(b.max_gbps > 2.0 * b.min_gbps);
        let c = intra_node_profile(&spec(ChipKind::C));
        assert!(!c.uniform);
        assert!(c.max_gbps < intra_node_profile(&spec(ChipKind::A)).max_gbps);
    }

    #[test]
    fn co_located_replicas_fill_whole_nodes() {
        let a = spec(ChipKind::A); // 16 chips/node
        assert_eq!(co_located_replicas(&a, 4, 4), 4); // one full node
        assert_eq!(co_located_replicas(&a, 4, 8), 4); // two nodes of 4
        assert_eq!(co_located_replicas(&a, 16, 8), 1); // TP fills the node
        assert_eq!(co_located_replicas(&a, 4, 6), 3); // divisor of dp only
        let b = spec(ChipKind::B); // 8 chips/node
        assert_eq!(co_located_replicas(&b, 2, 8), 4);
        assert_eq!(co_located_replicas(&b, 1, 2), 2);
    }

    #[test]
    fn matrix_is_symmetric_with_zero_diagonal() {
        let m = intra_node_matrix(&spec(ChipKind::B));
        for i in 0..m.len() {
            assert_eq!(m[i][i], 0.0);
            for j in 0..m.len() {
                assert_eq!(m[i][j], m[j][i]);
            }
        }
    }
}
