//! The fleet-level event loop, timeline, metrics, and fault cascade.
//!
//! [`run`] drives a [`JobTrace`] through one cluster: arrivals,
//! completions, and cluster faults advance a modeled fleet clock, every
//! decision point runs a placement round under the configured
//! [`Policy`], and every plan the round produces (new placements and
//! resized victims alike) is priced in a single batched pass over the
//! simulator engine pool ([`crate::sim::simulate_plans`] semantics,
//! chunked across a configurable worker count with a fixed reduction
//! order, so workers = 1 ≡ workers = N bit for bit).
//!
//! # Fault domains and the graceful-degradation cascade
//!
//! A [`ClusterFaultPlan`] projects wall-clock faults onto whichever job
//! owns the struck node at that instant (a [`NodeLedger`] tracks
//! ownership at whole-node granularity). Each projected fault is also
//! replayed through the victim's own [`StepMonitor`] — the timeline
//! records whether the job's heartbeat telemetry *would have* detected
//! it — and then the scheduler walks the cascade:
//!
//! 1. **in-place re-plan** (pipeline-preserving [`crate::auto::replan`]
//!    plus hot-swap, priced by the elastic recovery ledger) — no steps
//!    lost;
//! 2. **shrink** (full-mode re-plan over the survivors, restart from the
//!    last checkpoint on the smaller sub-cluster) — recomputed steps
//!    charged;
//! 3. **requeue-from-checkpoint** — the job re-enters the queue *keeping
//!    its slot*, rolls back to its checkpoint grid, and re-places on the
//!    surviving pool once its drain window passes;
//! 4. **terminal reject** — only when the job is provably unplaceable:
//!    nothing is running, the whole surviving cluster is idle, and no
//!    future fault event can return capacity.
//!
//! Dead nodes leave the [`FreePool`] (vendor- and whole-node-aware) until
//! a recover event returns them; degradations (slowdown / NIC) re-price
//! the victim's iteration through the *same* fault-aware simulator the
//! per-job layer uses, so fleet time and per-job time never disagree.
//!
//! The output is a machine-readable [`FleetTimeline`] — every event,
//! per-job outcomes, and fleet metrics (makespan, p99 job wait,
//! chip-hour utilization, preemption count, plus the recovery ledger:
//! goodput fraction, recomputed steps, total recovery seconds). Same
//! trace + same fault plan + same options ⇒ bit-identical timeline JSON.

use std::thread;

use anyhow::{bail, Result};

use crate::auto::SearchConfig;
use crate::costmodel::Schedule;
use crate::elastic::{ElasticEvent, FaultEvent, FaultKind, FaultPlan, MonitorConfig, StepMonitor};
use crate::hetero::{ChipKind, Cluster};
use crate::plan::ExecutionPlan;
use crate::sim::{simulate_plan, simulate_plan_with_faults_workers, simulate_plans};
use crate::util::json::{self, Value};
use crate::util::stats;

use super::fault::{ClusterFault, ClusterFaultPlan};
use super::job::{JobSpec, JobTrace};
use super::sched::{FreePool, PlaceOutcome, Placement, Policy, Recovery, Scheduler};

/// The `job` field of a [`FleetEvent`] that concerns no job — a fault
/// that struck free or already-dead capacity. Serializes as `-1`.
pub const NO_JOB: usize = usize::MAX;

/// The inner-solver config the fleet uses by default: 1F1B pinned and no
/// two-stage refinement — sub-clusters are small enough that the coarse
/// pass is both fast (one search per placement decision) and close to
/// optimal, and the paper's schedule baseline keeps placements
/// comparable across jobs.
pub fn fleet_search_config() -> SearchConfig {
    SearchConfig { two_stage: false, ..SearchConfig::pinned(Schedule::OneF1B) }
}

/// How the fleet reacts to a chip-death fault on a running job.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultResponse {
    /// Walk the graceful-degradation cascade: in-place re-plan, then
    /// shrink, then requeue-from-checkpoint, then terminal reject.
    #[default]
    Cascade,
    /// Requeue every victim from its last checkpoint — the
    /// restart-every-victim baseline the cascade is measured against.
    RestartAlways,
}

impl FaultResponse {
    /// The wire/CLI token (`"cascade"` / `"restart"`).
    pub fn token(&self) -> &'static str {
        match self {
            FaultResponse::Cascade => "cascade",
            FaultResponse::RestartAlways => "restart",
        }
    }

    /// Parse a CLI/config token.
    pub fn parse(text: &str) -> Result<FaultResponse> {
        match text {
            "cascade" => Ok(FaultResponse::Cascade),
            "restart" | "restart-always" => Ok(FaultResponse::RestartAlways),
            other => bail!("unknown fault response `{other}` (expected cascade or restart)"),
        }
    }
}

/// Knobs for [`run`].
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Queue policy.
    pub policy: Policy,
    /// Worker threads for the batched plan-pricing pass (0 = one per
    /// available core). Purely a wall-clock knob: results are
    /// bit-identical for every value.
    pub workers: usize,
    /// Inner HeteroAuto solver config (default:
    /// [`fleet_search_config`]).
    pub search: SearchConfig,
    /// Cluster fault script to inject (`None` = healthy run).
    pub faults: Option<ClusterFaultPlan>,
    /// How chip-death faults on running jobs are handled.
    pub response: FaultResponse,
    /// Checkpoint cadence every job runs at, in steps — the rollback
    /// grid for shrink and requeue recoveries (matches the per-job
    /// `checkpoint_every` of the virtual coordinator).
    pub checkpoint_every: u64,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            policy: Policy::Fifo,
            workers: 0,
            search: fleet_search_config(),
            faults: None,
            response: FaultResponse::Cascade,
            checkpoint_every: 5,
        }
    }
}

/// What happened at one fleet event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FleetEventKind {
    /// The job joined the queue.
    Arrive,
    /// The job got a sub-cluster and started training.
    Start {
        /// Chips in the job's sub-cluster.
        chips: usize,
        /// The simulator-priced per-step time on that sub-cluster.
        iteration_seconds: f64,
    },
    /// A running job was shrunk (preempt-by-resize) to make room.
    Resize {
        /// Whole-node chips returned to the free pool.
        freed_chips: usize,
        /// The victim's new per-step time after the re-plan.
        iteration_seconds: f64,
        /// Hot-swap cost charged before the victim resumes.
        migrate_seconds: f64,
    },
    /// A cluster fault (or recovery) struck — on a running job (`job` is
    /// the victim) or on free/dead capacity (`job` is [`NO_JOB`]).
    Fault {
        /// Chip group of the struck node.
        chip: ChipKind,
        /// Node index within the group.
        node: usize,
        /// What happened to the node.
        fault: FaultKind,
        /// Whether the victim's own step monitor (heartbeats vs the
        /// plan's predicted stage compute) would have flagged it —
        /// telemetry only, the cascade always acts on ground truth.
        detected: bool,
    },
    /// Cascade rung 1: the victim re-planned in place around dead chips
    /// and hot-swapped; no steps lost.
    Replan {
        /// Chips the fault killed.
        dead_chips: usize,
        /// Per-step time on the surviving sub-cluster.
        iteration_seconds: f64,
        /// Drain + detect + migrate cost from the elastic recovery
        /// ledger, charged before the job resumes.
        recovery_seconds: f64,
    },
    /// Cascade rung 2: the victim's pipeline was reshaped over the
    /// survivors and restarted from its last checkpoint.
    FaultShrink {
        /// Chips the fault killed.
        dead_chips: usize,
        /// Per-step time on the reshaped sub-cluster.
        iteration_seconds: f64,
        /// Drain + detect + restore cost charged before the job resumes.
        recovery_seconds: f64,
        /// Steps since the last checkpoint, recomputed at the new rate.
        recomputed_steps: u64,
    },
    /// Cascade rung 3: the victim released its chips, rolled back to its
    /// checkpoint grid, and re-entered the queue (keeping its slot).
    Requeue {
        /// Steps since the last checkpoint, to be recomputed once the
        /// job re-places.
        recomputed_steps: u64,
        /// Drain window charged before the job becomes placeable again.
        recovery_seconds: f64,
    },
    /// The job finished its steps; its chips returned to the pool.
    Finish,
    /// The job can never run on this cluster (no feasible carve/strategy
    /// even with every surviving chip idle and no recovery coming) and
    /// left the queue.
    Reject,
}

impl FleetEventKind {
    fn token(&self) -> &'static str {
        match self {
            FleetEventKind::Arrive => "arrive",
            FleetEventKind::Start { .. } => "start",
            FleetEventKind::Resize { .. } => "resize",
            FleetEventKind::Fault { .. } => "fault",
            FleetEventKind::Replan { .. } => "replan",
            FleetEventKind::FaultShrink { .. } => "fault-shrink",
            FleetEventKind::Requeue { .. } => "requeue",
            FleetEventKind::Finish => "finish",
            FleetEventKind::Reject => "reject",
        }
    }
}

/// One entry in the [`FleetTimeline`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetEvent {
    /// Fleet-clock time of the event, in modeled seconds.
    pub t_seconds: f64,
    /// The job the event concerns ([`NO_JOB`] for faults on unowned
    /// capacity).
    pub job: usize,
    /// What happened.
    pub kind: FleetEventKind,
}

/// Per-job outcome row in the [`FleetTimeline`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobOutcome {
    /// Job id.
    pub id: usize,
    /// The job's priority (echoed for metric post-processing).
    pub priority: u8,
    /// Arrival time in fleet seconds.
    pub arrival_seconds: f64,
    /// Queue wait (first `start − arrival`), `None` for rejected jobs.
    pub wait_seconds: Option<f64>,
    /// Completion time, `None` for rejected jobs.
    pub finish_seconds: Option<f64>,
    /// Chips the job held at its most recent start (0 for rejected
    /// jobs).
    pub chips: usize,
}

/// Fleet-level metrics over one [`run`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetMetrics {
    /// Jobs in the trace.
    pub jobs: usize,
    /// Jobs that ran to completion.
    pub completed: usize,
    /// Jobs rejected as unplaceable on an idle cluster.
    pub rejected: usize,
    /// Successful preempt-by-resize operations.
    pub preemptions: usize,
    /// Fleet-clock time of the last non-fault event (normally the last
    /// finish — trailing recover events do not stretch the window).
    pub makespan_seconds: f64,
    /// Mean queue wait over completed jobs.
    pub mean_wait_seconds: f64,
    /// 99th-percentile queue wait over completed jobs (linear
    /// interpolation, the crate-wide [`stats::percentile`]).
    pub p99_wait_seconds: f64,
    /// Chip-seconds held by jobs (allocation-based: idled survivors of a
    /// resize still count against the job holding them).
    pub chip_seconds: f64,
    /// `chip_seconds / (total_chips × makespan)` — the chip-hour
    /// utilization of the whole fleet window.
    pub utilization: f64,
    /// Fault events recorded in the timeline (including recoveries).
    pub faults: usize,
    /// Chips still dead when the run ended.
    pub dead_chips: usize,
    /// Steps recomputed after checkpoint rollbacks (shrink + requeue).
    pub recomputed_steps: u64,
    /// Total drain/detect/migrate/restore seconds charged by the
    /// cascade.
    pub recovery_seconds_total: f64,
    /// `productive_chip_seconds / (total_chips × makespan)` — the
    /// fraction of the fleet window spent computing steps that were
    /// *kept* (each completed step credited at its job's healthy
    /// iteration time × chips held; rolled-back steps are debited). On a
    /// healthy run this equals `utilization` up to float noise.
    pub goodput_fraction: f64,
}

/// The machine-readable record of one fleet run: every event, per-job
/// outcomes, and the fleet metrics. Serializes deterministically —
/// [`FleetTimeline::to_json_string`] is bit-identical across repeats and
/// worker counts for the same trace + fault plan + options.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetTimeline {
    /// Policy the run used.
    pub policy: Policy,
    /// Seed of the trace (echoed from [`JobTrace::seed`]).
    pub trace_seed: u64,
    /// Cluster name.
    pub cluster: String,
    /// Total chips in the cluster.
    pub total_chips: usize,
    /// Every event, in fleet-clock order.
    pub events: Vec<FleetEvent>,
    /// Per-job outcomes, in job-id order.
    pub jobs: Vec<JobOutcome>,
    /// Fleet metrics.
    pub metrics: FleetMetrics,
}

impl FleetTimeline {
    /// Serialize (deterministic: key order is sorted, floats print in
    /// shortest-roundtrip form, and no wall-clock field exists).
    pub fn to_json(&self) -> Value {
        let events = self
            .events
            .iter()
            .map(|e| {
                let job = if e.job == NO_JOB { -1.0 } else { e.job as f64 };
                let mut fields = vec![
                    ("t_seconds", json::num(e.t_seconds)),
                    ("job", json::num(job)),
                    ("kind", json::s(e.kind.token())),
                ];
                match e.kind {
                    FleetEventKind::Start { chips, iteration_seconds } => {
                        fields.push(("chips", json::num(chips as f64)));
                        fields.push(("iteration_seconds", json::num(iteration_seconds)));
                    }
                    FleetEventKind::Resize { freed_chips, iteration_seconds, migrate_seconds } => {
                        fields.push(("freed_chips", json::num(freed_chips as f64)));
                        fields.push(("iteration_seconds", json::num(iteration_seconds)));
                        fields.push(("migrate_seconds", json::num(migrate_seconds)));
                    }
                    FleetEventKind::Fault { chip, node, fault, detected } => {
                        fields.push(("chip", json::s(chip.name())));
                        fields.push(("node", json::num(node as f64)));
                        fields.push(("fault", json::s(fault.token())));
                        fault.push_json_fields(&mut fields);
                        fields.push(("detected", Value::Bool(detected)));
                    }
                    FleetEventKind::Replan { dead_chips, iteration_seconds, recovery_seconds } => {
                        fields.push(("dead_chips", json::num(dead_chips as f64)));
                        fields.push(("iteration_seconds", json::num(iteration_seconds)));
                        fields.push(("recovery_seconds", json::num(recovery_seconds)));
                    }
                    FleetEventKind::FaultShrink {
                        dead_chips,
                        iteration_seconds,
                        recovery_seconds,
                        recomputed_steps,
                    } => {
                        fields.push(("dead_chips", json::num(dead_chips as f64)));
                        fields.push(("iteration_seconds", json::num(iteration_seconds)));
                        fields.push(("recovery_seconds", json::num(recovery_seconds)));
                        fields.push(("recomputed_steps", json::num(recomputed_steps as f64)));
                    }
                    FleetEventKind::Requeue { recomputed_steps, recovery_seconds } => {
                        fields.push(("recomputed_steps", json::num(recomputed_steps as f64)));
                        fields.push(("recovery_seconds", json::num(recovery_seconds)));
                    }
                    _ => {}
                }
                json::obj(fields)
            })
            .collect();
        let jobs = self
            .jobs
            .iter()
            .map(|j| {
                let mut fields = vec![
                    ("id", json::num(j.id as f64)),
                    ("priority", json::num(j.priority as f64)),
                    ("arrival_seconds", json::num(j.arrival_seconds)),
                    ("chips", json::num(j.chips as f64)),
                ];
                if let Some(w) = j.wait_seconds {
                    fields.push(("wait_seconds", json::num(w)));
                }
                if let Some(f) = j.finish_seconds {
                    fields.push(("finish_seconds", json::num(f)));
                }
                json::obj(fields)
            })
            .collect();
        let m = &self.metrics;
        json::obj(vec![
            ("policy", json::s(self.policy.token())),
            ("trace_seed", json::s(&self.trace_seed.to_string())),
            ("cluster", json::s(&self.cluster)),
            ("total_chips", json::num(self.total_chips as f64)),
            ("events", json::arr(events)),
            ("jobs", json::arr(jobs)),
            (
                "metrics",
                json::obj(vec![
                    ("jobs", json::num(m.jobs as f64)),
                    ("completed", json::num(m.completed as f64)),
                    ("rejected", json::num(m.rejected as f64)),
                    ("preemptions", json::num(m.preemptions as f64)),
                    ("makespan_seconds", json::num(m.makespan_seconds)),
                    ("mean_wait_seconds", json::num(m.mean_wait_seconds)),
                    ("p99_wait_seconds", json::num(m.p99_wait_seconds)),
                    ("chip_seconds", json::num(m.chip_seconds)),
                    ("utilization", json::num(m.utilization)),
                    ("faults", json::num(m.faults as f64)),
                    ("dead_chips", json::num(m.dead_chips as f64)),
                    ("recomputed_steps", json::num(m.recomputed_steps as f64)),
                    ("recovery_seconds_total", json::num(m.recovery_seconds_total)),
                    ("goodput_fraction", json::num(m.goodput_fraction)),
                ]),
            ),
        ])
    }

    /// The timeline as pretty JSON text — the determinism contract is on
    /// this string (bit-identical across repeats and worker counts).
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Write the timeline to a file (the CLI `--out` path).
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json_string())
            .map_err(|e| anyhow::anyhow!("writing timeline `{path}`: {e}"))
    }
}

/// Price a batch of plans on the engine pool: one [`simulate_plan`] per
/// plan, chunked contiguously over `workers` threads, results joined in
/// fixed worker order — the [`crate::sim::simulate_plans`] contract at a
/// controllable width. Identical output for every worker count.
fn price_plans(plans: &[&ExecutionPlan], workers: usize) -> Vec<f64> {
    if plans.is_empty() {
        return Vec::new();
    }
    let workers = if workers == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    }
    .min(plans.len());
    if workers >= plans.len() {
        // Full width: the shared engine-pool driver, one engine per plan.
        return simulate_plans(plans).iter().map(|r| r.iteration_seconds).collect();
    }
    let chunk = plans.len().div_ceil(workers);
    let mut out = Vec::with_capacity(plans.len());
    thread::scope(|scope| {
        let mut handles = Vec::new();
        for piece in plans.chunks(chunk) {
            handles.push(scope.spawn(move || {
                piece.iter().map(|p| simulate_plan(p).iteration_seconds).collect::<Vec<f64>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("fleet pricing worker panicked"));
        }
    });
    out
}

/// The per-step iteration time of `plan` with the given degradations
/// active — the *same* fault-aware simulator the per-job layer runs, one
/// step, one worker, so fleet pricing and per-job pricing never
/// disagree (and stay worker-count independent).
fn degraded_iteration(plan: &ExecutionPlan, active: &[(ChipKind, usize, FaultEvent)]) -> Option<f64> {
    let faults =
        FaultPlan { seed: 0, events: active.iter().map(|&(_, _, e)| e).collect() };
    let r = simulate_plan_with_faults_workers(plan, &faults, 1, 1).ok()?;
    r.step_seconds.first().copied()
}

/// The first global pipeline-stage index hosted on chips of `kind`, or
/// `None` when the plan does not place any stage on that kind (the fault
/// then cannot touch this job's pipeline). Stage groups are walked in
/// the plan's own (memory-descending) order, accumulating each group's
/// `s_pp`.
fn stage_of_kind(plan: &ExecutionPlan, kind: ChipKind) -> Option<usize> {
    let mut stage = 0usize;
    for (g, gp) in plan.stage_groups.iter().zip(&plan.strategy.plans) {
        if g.spec.kind == kind && gp.s_pp > 0 {
            return Some(stage);
        }
        stage += gp.s_pp;
    }
    None
}

/// Who holds one node of the cluster right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NodeOwner {
    /// Idle, in the free pool.
    Free,
    /// Held by the job with this id.
    Job(usize),
    /// Retired by a chip-death fault, awaiting recovery.
    Dead,
}

/// Whole-node ownership, per chip group — the projection table that maps
/// a cluster fault at `(chip kind, node)` onto the job owning it (or
/// onto the free pool). Kept exactly in sync with [`FreePool`]: free
/// node counts equal the pool's free chips, dead node counts equal the
/// pool's dead ledger.
struct NodeLedger {
    /// `(kind, chips per node, owners)` in memory-descending group
    /// order.
    groups: Vec<(ChipKind, usize, Vec<NodeOwner>)>,
}

impl NodeLedger {
    fn new(cluster: &Cluster) -> NodeLedger {
        NodeLedger {
            groups: cluster
                .groups_by_memory_desc()
                .into_iter()
                .map(|g| (g.spec.kind, g.spec.chips_per_node, vec![NodeOwner::Free; g.n_nodes()]))
                .collect(),
        }
    }

    fn entry(&mut self, kind: ChipKind) -> &mut Vec<NodeOwner> {
        &mut self
            .groups
            .iter_mut()
            .find(|(k, _, _)| *k == kind)
            .unwrap_or_else(|| panic!("node ledger has no {kind:?} group"))
            .2
    }

    /// Chips per node of `kind`.
    fn cpn(&self, kind: ChipKind) -> usize {
        self.groups
            .iter()
            .find(|(k, _, _)| *k == kind)
            .unwrap_or_else(|| panic!("node ledger has no {kind:?} group"))
            .1
    }

    fn owner(&self, kind: ChipKind, node: usize) -> NodeOwner {
        self.groups
            .iter()
            .find(|(k, _, _)| *k == kind)
            .unwrap_or_else(|| panic!("node ledger has no {kind:?} group"))
            .2[node]
    }

    /// Hand `nodes` free nodes of `kind` to `job` — lowest free indices
    /// first, mirroring the deterministic carve order.
    fn assign(&mut self, kind: ChipKind, nodes: usize, job: usize) {
        let owners = self.entry(kind);
        let mut left = nodes;
        for o in owners.iter_mut() {
            if left == 0 {
                break;
            }
            if *o == NodeOwner::Free {
                *o = NodeOwner::Job(job);
                left -= 1;
            }
        }
        assert!(left == 0, "assigning {nodes} {kind:?} nodes but the ledger ran dry");
    }

    /// Release every node `job` still holds (completion or requeue).
    fn free_all(&mut self, job: usize) {
        for (_, _, owners) in &mut self.groups {
            for o in owners.iter_mut() {
                if *o == NodeOwner::Job(job) {
                    *o = NodeOwner::Free;
                }
            }
        }
    }

    /// Release `nodes` of `job`'s nodes of `kind` — highest indices
    /// first (the mirror of [`NodeLedger::assign`], so shrink frees the
    /// most-recently-granted nodes).
    fn free_some(&mut self, kind: ChipKind, nodes: usize, job: usize) {
        let owners = self.entry(kind);
        let mut left = nodes;
        for o in owners.iter_mut().rev() {
            if left == 0 {
                break;
            }
            if *o == NodeOwner::Job(job) {
                *o = NodeOwner::Free;
                left -= 1;
            }
        }
        assert!(left == 0, "freeing {nodes} {kind:?} nodes of job {job} but it holds fewer");
    }

    /// Mark a node dead, returning who held it (a second strike on an
    /// already-dead node returns [`NodeOwner::Dead`] and changes
    /// nothing).
    fn kill(&mut self, kind: ChipKind, node: usize) -> NodeOwner {
        let owners = self.entry(kind);
        let prev = owners[node];
        owners[node] = NodeOwner::Dead;
        prev
    }

    /// Return a dead node to the free state; `false` (and no change)
    /// when the node was not dead.
    fn revive(&mut self, kind: ChipKind, node: usize) -> bool {
        let owners = self.entry(kind);
        if owners[node] == NodeOwner::Dead {
            owners[node] = NodeOwner::Free;
            true
        } else {
            false
        }
    }
}

/// One running job's live state.
struct Running {
    id: usize,
    /// Index of the job in the trace (outcome row index).
    ti: usize,
    priority: u8,
    alloc: Vec<(ChipKind, usize)>,
    /// Chips currently held (allocation minus freed/dead; includes
    /// idled).
    held: usize,
    plan: ExecutionPlan,
    /// The job's own heartbeat monitor — cluster faults are replayed
    /// through it so the timeline records what telemetry would have
    /// seen.
    monitor: StepMonitor,
    /// Effective per-step time (degraded when `active_faults` is
    /// non-empty).
    iteration_seconds: f64,
    /// Healthy per-step time of the current plan — the rate a kept step
    /// is credited at in the goodput ledger.
    healthy_iteration_seconds: f64,
    /// Start of the current rate segment (placement, or
    /// post-resize/recovery).
    seg_start: f64,
    steps_remaining: u64,
    /// Steps completed since the job last (re-)placed — the checkpoint
    /// rollback grid.
    done_steps: u64,
    /// Live degradations on nodes this job owns:
    /// `(kind, node, projected per-job fault event)`.
    active_faults: Vec<(ChipKind, usize, FaultEvent)>,
    finish: f64,
}

impl Running {
    /// Record `n` chips of `kind` as no longer held after a resize or a
    /// death.
    fn shed(&mut self, kind: ChipKind, n: usize) {
        if let Some(slot) = self.alloc.iter_mut().find(|(k, _)| *k == kind) {
            slot.1 -= n.min(slot.1);
        }
        self.alloc.retain(|&(_, n)| n > 0);
    }
}

/// A resize staged during a placement round, applied after pricing.
struct StagedResize {
    running_idx: usize,
    plan: ExecutionPlan,
    freed: Vec<(ChipKind, usize)>,
    migrate_seconds: f64,
}

/// All mutable state of one fleet run, so the fault cascade and the
/// placement round can share it without threading a dozen `&mut`
/// parameters around.
struct FleetState<'a> {
    cluster: &'a Cluster,
    /// Working copy of the trace's jobs — a requeue rewrites `steps` to
    /// remaining + recomputed.
    specs: Vec<JobSpec>,
    policy: Policy,
    workers: usize,
    response: FaultResponse,
    checkpoint_every: u64,
    /// Monitor debounce window — also the drain charge (`1 + debounce`
    /// steps) of a requeue.
    debounce: usize,
    sched: Scheduler,
    pool: FreePool,
    ledger: NodeLedger,
    events: Vec<FleetEvent>,
    running: Vec<Running>,
    /// Indices into `specs` of queued jobs.
    pending: Vec<usize>,
    /// Per-job earliest re-placement time (requeued jobs drain first).
    ready_at: Vec<f64>,
    outcomes: Vec<JobOutcome>,
    /// `(chips, t0, t1)` allocation segments for chip-second accounting.
    segments: Vec<(usize, f64, f64)>,
    preemptions: usize,
    rejected: usize,
    recovery_seconds_total: f64,
    recomputed_steps_total: u64,
    /// Kept-step chip-seconds: `+ done × healthy_iter × held` at each
    /// segment close, `− recompute × healthy_iter × held` at each
    /// rollback, `+ steps_remaining × healthy_iter × held` at each
    /// finish.
    productive_chip_seconds: f64,
}

impl FleetState<'_> {
    fn monitor_cfg(&self) -> MonitorConfig {
        MonitorConfig { debounce: self.debounce, ..MonitorConfig::default() }
    }

    /// Close the job's current rate segment at `t` (no earlier than its
    /// own `seg_start` — a job mid-recovery resumes later): push the
    /// chip-second segment, credit the whole steps it completed, and
    /// return the close time. The caller must set the new `seg_start`.
    fn close_segment(&mut self, ri: usize, t: f64) -> f64 {
        let r = &mut self.running[ri];
        let base = t.max(r.seg_start);
        let done = if base > r.seg_start && r.iteration_seconds > 0.0 {
            (((base - r.seg_start) / r.iteration_seconds).floor() as u64).min(r.steps_remaining)
        } else {
            0
        };
        self.segments.push((r.held, r.seg_start, base));
        r.steps_remaining -= done;
        r.done_steps += done;
        self.productive_chip_seconds +=
            done as f64 * r.healthy_iteration_seconds * r.held as f64;
        base
    }

    /// Completions at exactly `t`, in job-id order.
    fn complete_at(&mut self, t: f64) {
        let mut done: Vec<usize> = self
            .running
            .iter()
            .enumerate()
            .filter(|(_, r)| r.finish == t)
            .map(|(i, _)| i)
            .collect();
        done.sort_by_key(|&i| self.running[i].id);
        for &i in &done {
            let r = &self.running[i];
            self.pool.release(&r.alloc);
            self.ledger.free_all(r.id);
            self.segments.push((r.held, r.seg_start, t));
            // Credit the remaining steps directly: the final segment is
            // steps_remaining × iteration by construction, and crediting
            // the count (not the float quotient) keeps a healthy run's
            // goodput equal to its utilization.
            self.productive_chip_seconds +=
                r.steps_remaining as f64 * r.healthy_iteration_seconds * r.held as f64;
            self.outcomes[r.ti].finish_seconds = Some(t);
            self.events.push(FleetEvent { t_seconds: t, job: r.id, kind: FleetEventKind::Finish });
        }
        // Remove highest index first so the remaining indices stay valid
        // (the event order above is id order, which need not match).
        done.sort_unstable_by(|a, b| b.cmp(a));
        for i in done {
            self.running.remove(i);
        }
    }

    fn push_fault_event(&mut self, t: f64, job: usize, f: &ClusterFault, detected: bool) {
        self.events.push(FleetEvent {
            t_seconds: t,
            job,
            kind: FleetEventKind::Fault { chip: f.chip, node: f.node, fault: f.kind, detected },
        });
    }

    /// Apply one cluster fault at `t`: kill/degrade/recover the node(s),
    /// project onto the owning job, and walk the cascade for victims.
    fn apply_fault(&mut self, t: f64, f: &ClusterFault) -> Result<()> {
        match f.kind {
            FaultKind::ChipDeath { nodes } => {
                let cpn = self.ledger.cpn(f.chip);
                // Kill every node in the span; aggregate per owner so a
                // multi-node death cascades each victim exactly once.
                let mut free_nodes = 0usize;
                let mut victims: Vec<(usize, usize)> = Vec::new(); // (job id, nodes lost)
                for node in f.node..f.node + nodes {
                    match self.ledger.kill(f.chip, node) {
                        NodeOwner::Free => free_nodes += 1,
                        NodeOwner::Job(id) => match victims.iter_mut().find(|(j, _)| *j == id) {
                            Some(v) => v.1 += 1,
                            None => victims.push((id, 1)),
                        },
                        NodeOwner::Dead => {} // second strike: no-op
                    }
                }
                if free_nodes > 0 {
                    self.pool.retire(f.chip, free_nodes * cpn);
                    self.push_fault_event(t, NO_JOB, f, false);
                }
                for (id, nodes_lost) in victims {
                    // Look the victim up fresh: an earlier victim's
                    // requeue shifts `running` indices.
                    let ri = self
                        .running
                        .iter()
                        .position(|r| r.id == id)
                        .expect("ledger owner must be running");
                    self.owner_death(t, ri, f, nodes_lost * cpn)?;
                }
            }
            FaultKind::Slowdown { .. } | FaultKind::NicDegrade { .. } => {
                match self.ledger.owner(f.chip, f.node) {
                    NodeOwner::Job(id) => {
                        let ri = self
                            .running
                            .iter()
                            .position(|r| r.id == id)
                            .expect("ledger owner must be running");
                        self.owner_degrade(t, ri, f);
                    }
                    // Degrading idle or dead capacity changes nothing
                    // until someone owns it — record it and move on.
                    NodeOwner::Free | NodeOwner::Dead => self.push_fault_event(t, NO_JOB, f, false),
                }
            }
            FaultKind::Recover => match self.ledger.owner(f.chip, f.node) {
                NodeOwner::Dead => {
                    let cpn = self.ledger.cpn(f.chip);
                    self.ledger.revive(f.chip, f.node);
                    // Recovered chips rejoin the *pool*, not the job that
                    // lost them — it re-planned (or requeued) without
                    // them.
                    self.pool.recover(f.chip, cpn);
                    self.push_fault_event(t, NO_JOB, f, false);
                }
                NodeOwner::Job(id) => {
                    let ri = self
                        .running
                        .iter()
                        .position(|r| r.id == id)
                        .expect("ledger owner must be running");
                    self.owner_recover(t, ri, f);
                }
                NodeOwner::Free => self.push_fault_event(t, NO_JOB, f, false),
            },
        }
        Ok(())
    }

    /// A running job lost `dead_chips` chips of `f.chip`: synthesize the
    /// missed heartbeats through its monitor, then walk the cascade —
    /// in-place re-plan, shrink, or requeue.
    fn owner_death(&mut self, t: f64, ri: usize, f: &ClusterFault, dead_chips: usize) -> Result<()> {
        // The dead chips never pass through the free pool, but the dead
        // ledger has to know they exist so recovery can return them.
        self.pool.retire_held(f.chip, dead_chips);
        let base = self.close_segment(ri, t);
        let (detected, step_seconds, held_before, healthy_iter, id, ti);
        {
            let r = &mut self.running[ri];
            let stage = stage_of_kind(&r.plan, f.chip);
            let mut saw = false;
            if let Some(stage) = stage {
                for _ in 0..self.debounce {
                    if let Some(ElasticEvent::Dead { .. }) = r.monitor.observe(stage, 0, None) {
                        saw = true;
                    }
                }
            }
            detected = saw;
            step_seconds = r.iteration_seconds;
            held_before = r.held;
            healthy_iter = r.healthy_iteration_seconds;
            id = r.id;
            ti = r.ti;
            r.held -= dead_chips.min(r.held);
            r.shed(f.chip, dead_chips);
        }
        self.push_fault_event(t, id, f, detected);

        let survivors = held_before.saturating_sub(dead_chips);
        // Rung 1 preserves the job's placement contract, so it is always
        // allowed; rung 2 reshapes the pipeline — effectively a new
        // placement — and must still satisfy the job's chip floor.
        let allow_shrink = survivors >= self.specs[ti].min_chips;
        let recovery = if self.response == FaultResponse::RestartAlways {
            None
        } else {
            let r = &self.running[ri];
            self.sched.try_recover(&r.plan, step_seconds, self.debounce, f.chip, dead_chips, allow_shrink)
        };
        match recovery {
            Some(Recovery::InPlace { plan, recovery_seconds }) => {
                let iter_new = simulate_plan(&plan).iteration_seconds;
                let monitor = StepMonitor::for_plan_with(&plan, self.monitor_cfg())?;
                let r = &mut self.running[ri];
                r.plan = plan;
                r.monitor = monitor;
                r.active_faults.clear();
                r.iteration_seconds = iter_new;
                r.healthy_iteration_seconds = iter_new;
                r.seg_start = base + recovery_seconds;
                r.finish = r.seg_start + r.steps_remaining as f64 * iter_new;
                self.recovery_seconds_total += recovery_seconds;
                self.events.push(FleetEvent {
                    t_seconds: t,
                    job: id,
                    kind: FleetEventKind::Replan {
                        dead_chips,
                        iteration_seconds: iter_new,
                        recovery_seconds,
                    },
                });
            }
            Some(Recovery::Shrink { plan, recovery_seconds }) => {
                let iter_new = simulate_plan(&plan).iteration_seconds;
                let monitor = StepMonitor::for_plan_with(&plan, self.monitor_cfg())?;
                let every = self.checkpoint_every.max(1);
                let r = &mut self.running[ri];
                // Restart from the last checkpoint: the steps past it are
                // recomputed on the reshaped sub-cluster.
                let ckpt = r.done_steps - r.done_steps % every;
                let recompute = r.done_steps - ckpt;
                r.done_steps = ckpt;
                r.steps_remaining += recompute;
                r.plan = plan;
                r.monitor = monitor;
                r.active_faults.clear();
                r.iteration_seconds = iter_new;
                r.healthy_iteration_seconds = iter_new;
                r.seg_start = base + recovery_seconds;
                r.finish = r.seg_start + r.steps_remaining as f64 * iter_new;
                self.productive_chip_seconds -=
                    recompute as f64 * healthy_iter * held_before as f64;
                self.recomputed_steps_total += recompute;
                self.recovery_seconds_total += recovery_seconds;
                self.events.push(FleetEvent {
                    t_seconds: t,
                    job: id,
                    kind: FleetEventKind::FaultShrink {
                        dead_chips,
                        iteration_seconds: iter_new,
                        recovery_seconds,
                        recomputed_steps: recompute,
                    },
                });
            }
            None => self.requeue(t, ri, held_before, step_seconds),
        }
        Ok(())
    }

    /// Cascade rung 3: release the survivors, roll back to the
    /// checkpoint grid, and re-enter the queue keeping the original
    /// arrival slot. The job becomes placeable after its drain window.
    fn requeue(&mut self, t: f64, ri: usize, held_before: usize, step_seconds: f64) {
        let r = self.running.remove(ri);
        self.pool.release(&r.alloc);
        self.ledger.free_all(r.id);
        let every = self.checkpoint_every.max(1);
        let ckpt = r.done_steps - r.done_steps % every;
        let recompute = r.done_steps - ckpt;
        self.productive_chip_seconds -=
            recompute as f64 * r.healthy_iteration_seconds * held_before as f64;
        self.recomputed_steps_total += recompute;
        // The re-placed job runs its remaining steps plus the rollback.
        self.specs[r.ti].steps = r.steps_remaining + recompute;
        let recovery_seconds = (1 + self.debounce) as f64 * step_seconds;
        self.recovery_seconds_total += recovery_seconds;
        self.ready_at[r.ti] = t + recovery_seconds;
        self.pending.push(r.ti);
        self.events.push(FleetEvent {
            t_seconds: t,
            job: r.id,
            kind: FleetEventKind::Requeue { recomputed_steps: recompute, recovery_seconds },
        });
    }

    /// A slowdown or NIC degradation landed on a node a running job
    /// owns: re-price its iteration through the fault-aware simulator
    /// and replay the anomaly through its monitor.
    fn owner_degrade(&mut self, t: f64, ri: usize, f: &ClusterFault) {
        let Some(stage) = stage_of_kind(&self.running[ri].plan, f.chip) else {
            // The job hosts no pipeline stage on this chip kind; nothing
            // it runs gets slower.
            let id = self.running[ri].id;
            self.push_fault_event(t, id, f, false);
            return;
        };
        let base = self.close_segment(ri, t);
        let (id, detected);
        {
            let r = &mut self.running[ri];
            r.active_faults.push((f.chip, f.node, FaultEvent { step: 0, stage, kind: f.kind }));
            let iter_new =
                degraded_iteration(&r.plan, &r.active_faults).unwrap_or(r.iteration_seconds);
            let healthy = r.healthy_iteration_seconds;
            // What the heartbeat sees: a compute slowdown inflates the
            // stage's compute observation by its factor; a NIC fault only
            // shows up as the whole step stretching — compute heartbeats
            // alone usually cannot see it (the honest gap that motivates
            // per-stage step-time telemetry).
            let obs_ratio = match f.kind {
                FaultKind::Slowdown { factor } => factor,
                _ => {
                    if healthy > 0.0 {
                        iter_new / healthy
                    } else {
                        1.0
                    }
                }
            };
            let mut saw = false;
            for _ in 0..self.debounce {
                let expected = r.monitor.expected()[stage];
                if let Some(ElasticEvent::Straggler { .. }) =
                    r.monitor.observe(stage, 0, Some(expected * obs_ratio))
                {
                    saw = true;
                }
            }
            detected = saw;
            r.iteration_seconds = iter_new;
            r.seg_start = base;
            r.finish = base + r.steps_remaining as f64 * iter_new;
            id = r.id;
        }
        self.push_fault_event(t, id, f, detected);
    }

    /// A recover event landed on a node a running job owns: clear the
    /// matching degradation (if any) and re-price.
    fn owner_recover(&mut self, t: f64, ri: usize, f: &ClusterFault) {
        let had = self.running[ri]
            .active_faults
            .iter()
            .any(|&(k, n, _)| k == f.chip && n == f.node);
        if !had {
            // Nothing to clear (e.g. the degradation was wiped by a
            // re-plan) — record and move on.
            let id = self.running[ri].id;
            self.push_fault_event(t, id, f, false);
            return;
        }
        let base = self.close_segment(ri, t);
        let (id, detected);
        {
            let r = &mut self.running[ri];
            r.active_faults.retain(|&(k, n, _)| !(k == f.chip && n == f.node));
            let iter_new = if r.active_faults.is_empty() {
                r.healthy_iteration_seconds
            } else {
                degraded_iteration(&r.plan, &r.active_faults)
                    .unwrap_or(r.healthy_iteration_seconds)
            };
            let stage = stage_of_kind(&r.plan, f.chip);
            let mut saw = false;
            if let Some(stage) = stage {
                for _ in 0..self.debounce {
                    let expected = r.monitor.expected()[stage];
                    if let Some(ElasticEvent::Recovered { .. }) =
                        r.monitor.observe(stage, 0, Some(expected))
                    {
                        saw = true;
                    }
                }
            }
            detected = saw;
            r.iteration_seconds = iter_new;
            r.seg_start = base;
            r.finish = base + r.steps_remaining as f64 * iter_new;
            id = r.id;
        }
        self.push_fault_event(t, id, f, detected);
    }

    fn reject(&mut self, pi: usize, t: f64) {
        self.pending.retain(|&x| x != pi);
        self.events.push(FleetEvent {
            t_seconds: t,
            job: self.specs[pi].id,
            kind: FleetEventKind::Reject,
        });
        self.rejected += 1;
    }

    /// One placement round at `t` under the configured policy.
    /// `more_faults` gates the terminal reject: while fault events
    /// remain, dead capacity may still recover, so nothing is provably
    /// unplaceable.
    fn placement_round(&mut self, t: f64, more_faults: bool) -> Result<()> {
        let order = queue_order(self.policy, &self.specs, &self.pending);
        let mut placed: Vec<(usize, Placement)> = Vec::new();
        let mut resizes: Vec<StagedResize> = Vec::new();
        for &pi in &order {
            if self.ready_at[pi] > t {
                // A requeued job still draining holds its queue slot:
                // under FIFO it blocks the head of the line (no
                // queue-jumping past a fault victim), under priority the
                // round just skips it.
                if self.policy == Policy::Fifo {
                    break;
                }
                continue;
            }
            let job = self.specs[pi].clone();
            let mut outcome = self.sched.try_place(&job, &mut self.pool);
            if matches!(outcome, PlaceOutcome::NoCapacity) && self.policy == Policy::PriorityBackfill
            {
                // Preempt-by-resize: shrink strictly-lower-priority
                // running jobs (lowest priority first, latest start /
                // highest id breaking ties) until the job fits.
                let mut victims: Vec<usize> = (0..self.running.len())
                    .filter(|&i| self.running[i].priority < job.priority)
                    .collect();
                victims.sort_by_key(|&i| {
                    (self.running[i].priority, u64::MAX - self.running[i].id as u64)
                });
                for vi in victims {
                    let need = job.min_chips.saturating_sub(self.pool.total());
                    if need == 0 {
                        break;
                    }
                    if resizes.iter().any(|s| s.running_idx == vi) {
                        continue; // one shrink per victim per round
                    }
                    let shrink = {
                        let v = &self.running[vi];
                        self.sched.try_shrink(&v.plan, v.iteration_seconds, need)
                    };
                    if let Some(shrink) = shrink {
                        self.pool.release(&shrink.freed);
                        let vid = self.running[vi].id;
                        for &(kind, n) in &shrink.freed {
                            let nodes = n / self.ledger.cpn(kind);
                            self.ledger.free_some(kind, nodes, vid);
                        }
                        self.preemptions += 1;
                        resizes.push(StagedResize {
                            running_idx: vi,
                            plan: shrink.plan,
                            freed: shrink.freed,
                            migrate_seconds: shrink.migrate_seconds,
                        });
                    }
                }
                if job.min_chips <= self.pool.total() {
                    outcome = self.sched.try_place(&job, &mut self.pool);
                }
            }
            match outcome {
                PlaceOutcome::Placed(p) => placed.push((pi, p)),
                PlaceOutcome::NoCapacity | PlaceOutcome::SearchFailed(_) => {
                    let idle = self.running.is_empty()
                        && placed.is_empty()
                        && self.pool.total() + self.pool.dead_total()
                            == self.cluster.total_chips();
                    if idle && !more_faults {
                        // Every surviving chip is idle, none will ever
                        // come back, and the job still cannot place:
                        // terminal.
                        self.reject(pi, t);
                    } else if self.policy == Policy::Fifo {
                        break; // head-of-line blocking
                    }
                }
            }
        }

        // Price every plan this round produced in one batched pass.
        let mut plan_refs: Vec<&ExecutionPlan> = placed.iter().map(|(_, p)| &p.plan).collect();
        plan_refs.extend(resizes.iter().map(|s| &s.plan));
        let prices = price_plans(&plan_refs, self.workers);
        let (start_prices, resize_prices) = prices.split_at(placed.len());

        // Apply resizes (victims keep running at their new rate after
        // the migration penalty; the partially-done step restarts).
        for (s, &iter_new) in resizes.iter().zip(resize_prices) {
            let base = self.close_segment(s.running_idx, t);
            let monitor = StepMonitor::for_plan_with(&s.plan, self.monitor_cfg())?;
            let freed: usize = s.freed.iter().map(|&(_, n)| n).sum();
            // Keep only degradations on nodes the victim still owns,
            // re-projected onto the new plan's stages.
            let vid = self.running[s.running_idx].id;
            let mut kept: Vec<(ChipKind, usize, FaultEvent)> = Vec::new();
            for &(kind, node, ev) in &self.running[s.running_idx].active_faults {
                if self.ledger.owner(kind, node) == NodeOwner::Job(vid) {
                    if let Some(stage) = stage_of_kind(&s.plan, kind) {
                        kept.push((kind, node, FaultEvent { step: 0, stage, kind: ev.kind }));
                    }
                }
            }
            let iter_eff = if kept.is_empty() {
                iter_new
            } else {
                degraded_iteration(&s.plan, &kept).unwrap_or(iter_new)
            };
            let r = &mut self.running[s.running_idx];
            r.held -= freed;
            for &(kind, n) in &s.freed {
                r.shed(kind, n);
            }
            r.plan = s.plan.clone();
            r.monitor = monitor;
            r.active_faults = kept;
            r.iteration_seconds = iter_eff;
            r.healthy_iteration_seconds = iter_new;
            r.seg_start = base + s.migrate_seconds;
            r.finish = r.seg_start + r.steps_remaining as f64 * iter_eff;
            self.events.push(FleetEvent {
                t_seconds: t,
                job: r.id,
                kind: FleetEventKind::Resize {
                    freed_chips: freed,
                    iteration_seconds: iter_eff,
                    migrate_seconds: s.migrate_seconds,
                },
            });
        }

        // Apply placements.
        for ((pi, p), &iter) in placed.iter().zip(start_prices) {
            let pi = *pi;
            let (id, priority, steps, arrival) = {
                let job = &self.specs[pi];
                (job.id, job.priority, job.steps, job.arrival_step as f64)
            };
            self.pending.retain(|&x| x != pi);
            if self.outcomes[pi].wait_seconds.is_none() {
                // A requeued job keeps its original queue wait.
                self.outcomes[pi].wait_seconds = Some(t - arrival);
            }
            self.outcomes[pi].chips = p.chips;
            for &(kind, n) in &p.alloc {
                let nodes = n / self.ledger.cpn(kind);
                self.ledger.assign(kind, nodes, id);
            }
            let monitor = StepMonitor::for_plan_with(&p.plan, self.monitor_cfg())?;
            self.running.push(Running {
                id,
                ti: pi,
                priority,
                alloc: p.alloc.clone(),
                held: p.chips,
                plan: p.plan.clone(),
                monitor,
                iteration_seconds: iter,
                healthy_iteration_seconds: iter,
                seg_start: t,
                steps_remaining: steps,
                done_steps: 0,
                active_faults: Vec::new(),
                finish: t + steps as f64 * iter,
            });
            self.events.push(FleetEvent {
                t_seconds: t,
                job: id,
                kind: FleetEventKind::Start { chips: p.chips, iteration_seconds: iter },
            });
        }
        Ok(())
    }
}

/// Run a job trace through the fleet scheduler on `cluster`, injecting
/// the cluster fault script from `opts.faults` (if any).
///
/// Deterministic: same `cluster` + `trace` + fault plan + `opts.policy`
/// + `opts.search` + `opts.response` ⇒ bit-identical [`FleetTimeline`],
/// for any `opts.workers`.
pub fn run(cluster: &Cluster, trace: &JobTrace, opts: &FleetOptions) -> Result<FleetTimeline> {
    trace.validate()?;
    for j in &trace.jobs {
        if j.min_chips > cluster.total_chips() {
            // Caught up front so the queue never carries a job the
            // cluster axiomatically cannot host.
            bail!(
                "job {} needs {} chips but cluster `{}` has {}",
                j.id, j.min_chips, cluster.name, cluster.total_chips()
            );
        }
    }
    let faults = match &opts.faults {
        Some(f) => {
            f.validate(cluster)?;
            let mut f = f.clone();
            f.sort();
            f
        }
        None => ClusterFaultPlan::none(),
    };
    let n_jobs = trace.jobs.len();
    let mut st = FleetState {
        cluster,
        specs: trace.jobs.clone(),
        policy: opts.policy,
        workers: opts.workers,
        response: opts.response,
        checkpoint_every: opts.checkpoint_every,
        debounce: MonitorConfig::default().debounce,
        sched: Scheduler::new(opts.policy, opts.search.clone()),
        pool: FreePool::new(cluster),
        ledger: NodeLedger::new(cluster),
        events: Vec::new(),
        running: Vec::new(),
        pending: Vec::new(),
        ready_at: vec![0.0; n_jobs],
        outcomes: trace
            .jobs
            .iter()
            .map(|j| JobOutcome {
                id: j.id,
                priority: j.priority,
                arrival_seconds: j.arrival_step as f64,
                wait_seconds: None,
                finish_seconds: None,
                chips: 0,
            })
            .collect(),
        segments: Vec::new(),
        preemptions: 0,
        rejected: 0,
        recovery_seconds_total: 0.0,
        recomputed_steps_total: 0,
        productive_chip_seconds: 0.0,
    };
    let mut next_arrival = 0usize;
    let mut next_fault = 0usize;
    // Last processed decision point — requeued jobs whose ready time
    // already passed do not create new decision points.
    let mut now = -1.0f64;

    loop {
        // Next decision point: the earliest of the next arrival, the
        // earliest running finish, the next cluster fault, and the
        // earliest pending-job ready time still in the future.
        let arrival_t = trace.jobs.get(next_arrival).map(|j| j.arrival_step as f64);
        let finish_t = st
            .running
            .iter()
            .map(|r| r.finish)
            .min_by(|a, b| a.partial_cmp(b).expect("finish times are finite"));
        let fault_t = faults.events.get(next_fault).map(|e| e.t_seconds);
        let ready_t = st
            .pending
            .iter()
            .map(|&pi| st.ready_at[pi])
            .filter(|&r| r > now)
            .min_by(|a, b| a.partial_cmp(b).expect("ready times are finite"));
        let mut t = f64::INFINITY;
        for c in [arrival_t, finish_t, fault_t, ready_t].into_iter().flatten() {
            t = t.min(c);
        }
        if !t.is_finite() {
            break;
        }
        now = t;

        // Completions at exactly t first, so freed chips are visible to
        // everything else at the same instant.
        st.complete_at(t);

        // Cluster faults due at t, in script order.
        while next_fault < faults.events.len() && faults.events[next_fault].t_seconds <= t {
            let f = faults.events[next_fault];
            st.apply_fault(t, &f)?;
            next_fault += 1;
        }

        // Arrivals at exactly t, trace order.
        while let Some(j) = trace.jobs.get(next_arrival) {
            if j.arrival_step as f64 > t {
                break;
            }
            st.pending.push(next_arrival);
            st.events.push(FleetEvent { t_seconds: t, job: j.id, kind: FleetEventKind::Arrive });
            next_arrival += 1;
        }

        st.placement_round(t, next_fault < faults.events.len())?;
    }

    // Metrics. Makespan is the last *non-fault* event: trailing recover
    // events on an already-drained fleet do not stretch the window the
    // utilization and goodput denominators are measured over.
    let makespan = st
        .events
        .iter()
        .rev()
        .find(|e| !matches!(e.kind, FleetEventKind::Fault { .. }))
        .map(|e| e.t_seconds)
        .unwrap_or(0.0);
    let mut waits: Vec<f64> = st.outcomes.iter().filter_map(|o| o.wait_seconds).collect();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let chip_seconds: f64 = st.segments.iter().map(|&(c, t0, t1)| c as f64 * (t1 - t0)).sum();
    let denom = cluster.total_chips() as f64 * makespan;
    let metrics = FleetMetrics {
        jobs: trace.jobs.len(),
        completed: st.outcomes.iter().filter(|o| o.finish_seconds.is_some()).count(),
        rejected: st.rejected,
        preemptions: st.preemptions,
        makespan_seconds: makespan,
        mean_wait_seconds: if waits.is_empty() { 0.0 } else { stats::mean(&waits) },
        p99_wait_seconds: if waits.is_empty() { 0.0 } else { stats::percentile(&waits, 0.99) },
        chip_seconds,
        utilization: if denom > 0.0 { chip_seconds / denom } else { 0.0 },
        faults: st
            .events
            .iter()
            .filter(|e| matches!(e.kind, FleetEventKind::Fault { .. }))
            .count(),
        dead_chips: st.pool.dead_total(),
        recomputed_steps: st.recomputed_steps_total,
        recovery_seconds_total: st.recovery_seconds_total,
        goodput_fraction: if denom > 0.0 { st.productive_chip_seconds / denom } else { 0.0 },
    };
    Ok(FleetTimeline {
        policy: opts.policy,
        trace_seed: trace.seed,
        cluster: cluster.name.clone(),
        total_chips: cluster.total_chips(),
        events: st.events,
        jobs: st.outcomes,
        metrics,
    })
}

/// Queue order for one placement round, per policy. FIFO is
/// `(arrival, id)`; priority-with-backfill is
/// `(priority desc, arrival, id)`. Requeued jobs keep their original
/// arrival, so they keep their slot.
fn queue_order(policy: Policy, specs: &[JobSpec], pending: &[usize]) -> Vec<usize> {
    let mut order = pending.to_vec();
    match policy {
        Policy::Fifo => order.sort_by_key(|&i| (specs[i].arrival_step, specs[i].id)),
        Policy::PriorityBackfill => order.sort_by_key(|&i| {
            let j = &specs[i];
            (u8::MAX - j.priority, j.arrival_step, j.id)
        }),
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_response_tokens_roundtrip() {
        for r in [FaultResponse::Cascade, FaultResponse::RestartAlways] {
            assert_eq!(FaultResponse::parse(r.token()).unwrap(), r);
        }
        assert_eq!(FaultResponse::parse("restart-always").unwrap(), FaultResponse::RestartAlways);
        assert!(FaultResponse::parse("panic").is_err());
    }

    #[test]
    fn node_ledger_tracks_ownership_death_and_revival() {
        let cluster = Cluster::new("lab", vec![(ChipKind::A, 64), (ChipKind::B, 64)]);
        let mut l = NodeLedger::new(&cluster);
        assert_eq!(l.cpn(ChipKind::A), 16, "A nodes are 16 chips");
        assert_eq!(l.cpn(ChipKind::B), 8, "B nodes are 8 chips");
        l.assign(ChipKind::B, 3, 7);
        assert_eq!(l.owner(ChipKind::B, 0), NodeOwner::Job(7), "lowest free indices first");
        assert_eq!(l.owner(ChipKind::B, 2), NodeOwner::Job(7));
        assert_eq!(l.owner(ChipKind::B, 3), NodeOwner::Free);
        // Kill an owned node and a free node; a second strike is a no-op.
        assert_eq!(l.kill(ChipKind::B, 1), NodeOwner::Job(7));
        assert_eq!(l.kill(ChipKind::B, 5), NodeOwner::Free);
        assert_eq!(l.kill(ChipKind::B, 5), NodeOwner::Dead);
        // Shrink frees the highest-index held node.
        l.free_some(ChipKind::B, 1, 7);
        assert_eq!(l.owner(ChipKind::B, 2), NodeOwner::Free);
        assert_eq!(l.owner(ChipKind::B, 0), NodeOwner::Job(7));
        // A full release leaves dead nodes dead.
        l.free_all(7);
        assert_eq!(l.owner(ChipKind::B, 0), NodeOwner::Free);
        assert_eq!(l.owner(ChipKind::B, 1), NodeOwner::Dead, "death survives a release");
        assert!(l.revive(ChipKind::B, 1));
        assert_eq!(l.owner(ChipKind::B, 1), NodeOwner::Free);
        assert!(!l.revive(ChipKind::B, 1), "revive only acts on dead nodes");
    }
}
