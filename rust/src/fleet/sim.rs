//! The fleet-level event loop, timeline, and metrics.
//!
//! [`run`] drives a [`JobTrace`] through one cluster: arrivals and
//! completions advance a modeled fleet clock, every decision point runs
//! a placement round under the configured [`Policy`], and every plan the
//! round produces (new placements and resized victims alike) is priced
//! in a single batched pass over the simulator engine pool
//! ([`crate::sim::simulate_plans`] semantics, chunked across a
//! configurable worker count with a fixed reduction order, so
//! workers = 1 ≡ workers = N bit for bit).
//!
//! The output is a machine-readable [`FleetTimeline`] — every event,
//! per-job outcomes, and fleet metrics (makespan, p99 job wait,
//! chip-hour utilization, preemption count). Same trace + same options ⇒
//! bit-identical timeline JSON.

use std::thread;

use anyhow::{bail, Result};

use crate::auto::SearchConfig;
use crate::costmodel::Schedule;
use crate::hetero::{ChipKind, Cluster};
use crate::plan::ExecutionPlan;
use crate::sim::{simulate_plan, simulate_plans};
use crate::util::json::{self, Value};
use crate::util::stats;

use super::job::JobTrace;
use super::sched::{FreePool, PlaceOutcome, Policy, Scheduler};

/// The inner-solver config the fleet uses by default: 1F1B pinned and no
/// two-stage refinement — sub-clusters are small enough that the coarse
/// pass is both fast (one search per placement decision) and close to
/// optimal, and the paper's schedule baseline keeps placements
/// comparable across jobs.
pub fn fleet_search_config() -> SearchConfig {
    SearchConfig { two_stage: false, ..SearchConfig::pinned(Schedule::OneF1B) }
}

/// Knobs for [`run`].
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Queue policy.
    pub policy: Policy,
    /// Worker threads for the batched plan-pricing pass (0 = one per
    /// available core). Purely a wall-clock knob: results are
    /// bit-identical for every value.
    pub workers: usize,
    /// Inner HeteroAuto solver config (default:
    /// [`fleet_search_config`]).
    pub search: SearchConfig,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions { policy: Policy::Fifo, workers: 0, search: fleet_search_config() }
    }
}

/// What happened at one fleet event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FleetEventKind {
    /// The job joined the queue.
    Arrive,
    /// The job got a sub-cluster and started training.
    Start {
        /// Chips in the job's sub-cluster.
        chips: usize,
        /// The simulator-priced per-step time on that sub-cluster.
        iteration_seconds: f64,
    },
    /// A running job was shrunk (preempt-by-resize) to make room.
    Resize {
        /// Whole-node chips returned to the free pool.
        freed_chips: usize,
        /// The victim's new per-step time after the re-plan.
        iteration_seconds: f64,
        /// Hot-swap cost charged before the victim resumes.
        migrate_seconds: f64,
    },
    /// The job finished its steps; its chips returned to the pool.
    Finish,
    /// The job can never run on this cluster (no feasible carve/strategy
    /// even with the whole cluster idle) and left the queue.
    Reject,
}

impl FleetEventKind {
    fn token(&self) -> &'static str {
        match self {
            FleetEventKind::Arrive => "arrive",
            FleetEventKind::Start { .. } => "start",
            FleetEventKind::Resize { .. } => "resize",
            FleetEventKind::Finish => "finish",
            FleetEventKind::Reject => "reject",
        }
    }
}

/// One entry in the [`FleetTimeline`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetEvent {
    /// Fleet-clock time of the event, in modeled seconds.
    pub t_seconds: f64,
    /// The job the event concerns.
    pub job: usize,
    /// What happened.
    pub kind: FleetEventKind,
}

/// Per-job outcome row in the [`FleetTimeline`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobOutcome {
    /// Job id.
    pub id: usize,
    /// The job's priority (echoed for metric post-processing).
    pub priority: u8,
    /// Arrival time in fleet seconds.
    pub arrival_seconds: f64,
    /// Queue wait (`start − arrival`), `None` for rejected jobs.
    pub wait_seconds: Option<f64>,
    /// Completion time, `None` for rejected jobs.
    pub finish_seconds: Option<f64>,
    /// Chips the job held at start (0 for rejected jobs).
    pub chips: usize,
}

/// Fleet-level metrics over one [`run`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetMetrics {
    /// Jobs in the trace.
    pub jobs: usize,
    /// Jobs that ran to completion.
    pub completed: usize,
    /// Jobs rejected as unplaceable on an idle cluster.
    pub rejected: usize,
    /// Successful preempt-by-resize operations.
    pub preemptions: usize,
    /// Fleet-clock time of the last event (normally the last finish).
    pub makespan_seconds: f64,
    /// Mean queue wait over completed jobs.
    pub mean_wait_seconds: f64,
    /// 99th-percentile queue wait over completed jobs (linear
    /// interpolation, the crate-wide [`stats::percentile`]).
    pub p99_wait_seconds: f64,
    /// Chip-seconds held by jobs (allocation-based: idled survivors of a
    /// resize still count against the job holding them).
    pub chip_seconds: f64,
    /// `chip_seconds / (total_chips × makespan)` — the chip-hour
    /// utilization of the whole fleet window.
    pub utilization: f64,
}

/// The machine-readable record of one fleet run: every event, per-job
/// outcomes, and the fleet metrics. Serializes deterministically —
/// [`FleetTimeline::to_json_string`] is bit-identical across repeats and
/// worker counts for the same trace + options.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetTimeline {
    /// Policy the run used.
    pub policy: Policy,
    /// Seed of the trace (echoed from [`JobTrace::seed`]).
    pub trace_seed: u64,
    /// Cluster name.
    pub cluster: String,
    /// Total chips in the cluster.
    pub total_chips: usize,
    /// Every event, in fleet-clock order.
    pub events: Vec<FleetEvent>,
    /// Per-job outcomes, in job-id order.
    pub jobs: Vec<JobOutcome>,
    /// Fleet metrics.
    pub metrics: FleetMetrics,
}

impl FleetTimeline {
    /// Serialize (deterministic: key order is sorted, floats print in
    /// shortest-roundtrip form, and no wall-clock field exists).
    pub fn to_json(&self) -> Value {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("t_seconds", json::num(e.t_seconds)),
                    ("job", json::num(e.job as f64)),
                    ("kind", json::s(e.kind.token())),
                ];
                match e.kind {
                    FleetEventKind::Start { chips, iteration_seconds } => {
                        fields.push(("chips", json::num(chips as f64)));
                        fields.push(("iteration_seconds", json::num(iteration_seconds)));
                    }
                    FleetEventKind::Resize { freed_chips, iteration_seconds, migrate_seconds } => {
                        fields.push(("freed_chips", json::num(freed_chips as f64)));
                        fields.push(("iteration_seconds", json::num(iteration_seconds)));
                        fields.push(("migrate_seconds", json::num(migrate_seconds)));
                    }
                    _ => {}
                }
                json::obj(fields)
            })
            .collect();
        let jobs = self
            .jobs
            .iter()
            .map(|j| {
                let mut fields = vec![
                    ("id", json::num(j.id as f64)),
                    ("priority", json::num(j.priority as f64)),
                    ("arrival_seconds", json::num(j.arrival_seconds)),
                    ("chips", json::num(j.chips as f64)),
                ];
                if let Some(w) = j.wait_seconds {
                    fields.push(("wait_seconds", json::num(w)));
                }
                if let Some(f) = j.finish_seconds {
                    fields.push(("finish_seconds", json::num(f)));
                }
                json::obj(fields)
            })
            .collect();
        let m = &self.metrics;
        json::obj(vec![
            ("policy", json::s(self.policy.token())),
            ("trace_seed", json::s(&self.trace_seed.to_string())),
            ("cluster", json::s(&self.cluster)),
            ("total_chips", json::num(self.total_chips as f64)),
            ("events", json::arr(events)),
            ("jobs", json::arr(jobs)),
            (
                "metrics",
                json::obj(vec![
                    ("jobs", json::num(m.jobs as f64)),
                    ("completed", json::num(m.completed as f64)),
                    ("rejected", json::num(m.rejected as f64)),
                    ("preemptions", json::num(m.preemptions as f64)),
                    ("makespan_seconds", json::num(m.makespan_seconds)),
                    ("mean_wait_seconds", json::num(m.mean_wait_seconds)),
                    ("p99_wait_seconds", json::num(m.p99_wait_seconds)),
                    ("chip_seconds", json::num(m.chip_seconds)),
                    ("utilization", json::num(m.utilization)),
                ]),
            ),
        ])
    }

    /// The timeline as pretty JSON text — the determinism contract is on
    /// this string (bit-identical across repeats and worker counts).
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Write the timeline to a file (the CLI `--out` path).
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json_string())
            .map_err(|e| anyhow::anyhow!("writing timeline `{path}`: {e}"))
    }
}

/// Price a batch of plans on the engine pool: one [`simulate_plan`] per
/// plan, chunked contiguously over `workers` threads, results joined in
/// fixed worker order — the [`crate::sim::simulate_plans`] contract at a
/// controllable width. Identical output for every worker count.
fn price_plans(plans: &[&ExecutionPlan], workers: usize) -> Vec<f64> {
    if plans.is_empty() {
        return Vec::new();
    }
    let workers = if workers == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    }
    .min(plans.len());
    if workers >= plans.len() {
        // Full width: the shared engine-pool driver, one engine per plan.
        return simulate_plans(plans).iter().map(|r| r.iteration_seconds).collect();
    }
    let chunk = plans.len().div_ceil(workers);
    let mut out = Vec::with_capacity(plans.len());
    thread::scope(|scope| {
        let mut handles = Vec::new();
        for piece in plans.chunks(chunk) {
            handles.push(scope.spawn(move || {
                piece.iter().map(|p| simulate_plan(p).iteration_seconds).collect::<Vec<f64>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("fleet pricing worker panicked"));
        }
    });
    out
}

/// One running job's live state.
struct Running {
    id: usize,
    /// Index of the job in the trace (outcome row index).
    ti: usize,
    priority: u8,
    alloc: Vec<(ChipKind, usize)>,
    /// Chips currently held (allocation minus freed; includes idled).
    held: usize,
    plan: ExecutionPlan,
    iteration_seconds: f64,
    /// Start of the current rate segment (placement, or post-resize).
    seg_start: f64,
    steps_remaining: u64,
    finish: f64,
}

/// A resize staged during a placement round, applied after pricing.
struct StagedResize {
    running_idx: usize,
    plan: ExecutionPlan,
    freed: Vec<(ChipKind, usize)>,
    migrate_seconds: f64,
}

/// Run a job trace through the fleet scheduler on `cluster`.
///
/// Deterministic: same `cluster` + `trace` + `opts.policy` +
/// `opts.search` ⇒ bit-identical [`FleetTimeline`], for any
/// `opts.workers`.
pub fn run(cluster: &Cluster, trace: &JobTrace, opts: &FleetOptions) -> Result<FleetTimeline> {
    trace.validate()?;
    for j in &trace.jobs {
        if j.min_chips > cluster.total_chips() {
            // Caught up front so the queue never carries a job the
            // cluster axiomatically cannot host.
            bail!(
                "job {} needs {} chips but cluster `{}` has {}",
                j.id, j.min_chips, cluster.name, cluster.total_chips()
            );
        }
    }
    let sched = Scheduler::new(opts.policy, opts.search.clone());
    let mut pool = FreePool::new(cluster);
    let mut events: Vec<FleetEvent> = Vec::new();
    let mut running: Vec<Running> = Vec::new();
    let mut pending: Vec<usize> = Vec::new(); // indices into trace.jobs
    let mut next_arrival = 0usize;
    let mut outcomes: Vec<JobOutcome> = trace
        .jobs
        .iter()
        .map(|j| JobOutcome {
            id: j.id,
            priority: j.priority,
            arrival_seconds: j.arrival_step as f64,
            wait_seconds: None,
            finish_seconds: None,
            chips: 0,
        })
        .collect();
    let mut segments: Vec<(usize, f64, f64)> = Vec::new(); // (chips, t0, t1)
    let mut preemptions = 0usize;
    let mut rejected = 0usize;

    loop {
        // Next decision point: the earliest running finish or the next
        // arrival, whichever is sooner (finishes win ties so freed chips
        // are visible to jobs arriving at the same instant).
        let arrival_t = trace.jobs.get(next_arrival).map(|j| j.arrival_step as f64);
        let finish_t = running
            .iter()
            .map(|r| r.finish)
            .min_by(|a, b| a.partial_cmp(b).expect("finish times are finite"));
        let t = match (arrival_t, finish_t) {
            (Some(a), Some(f)) => a.min(f),
            (Some(a), None) => a,
            (None, Some(f)) => f,
            (None, None) => break,
        };

        // Completions at exactly t, in job-id order.
        let mut done: Vec<usize> = running
            .iter()
            .enumerate()
            .filter(|(_, r)| r.finish == t)
            .map(|(i, _)| i)
            .collect();
        done.sort_by_key(|&i| running[i].id);
        for &i in &done {
            let r = &running[i];
            pool.release(&r.alloc);
            segments.push((r.held, r.seg_start, t));
            outcomes[r.ti].finish_seconds = Some(t);
            events.push(FleetEvent { t_seconds: t, job: r.id, kind: FleetEventKind::Finish });
        }
        // Remove highest index first so the remaining indices stay valid
        // (the event order above is id order, which need not match).
        done.sort_unstable_by(|a, b| b.cmp(a));
        for i in done {
            running.remove(i);
        }

        // Arrivals at exactly t, trace order.
        while let Some(j) = trace.jobs.get(next_arrival) {
            if j.arrival_step as f64 > t {
                break;
            }
            pending.push(next_arrival);
            events.push(FleetEvent { t_seconds: t, job: j.id, kind: FleetEventKind::Arrive });
            next_arrival += 1;
        }

        // Placement round at t.
        let order = queue_order(opts.policy, trace, &pending);
        let mut placed: Vec<(usize, super::sched::Placement)> = Vec::new();
        let mut resizes: Vec<StagedResize> = Vec::new();
        for &pi in &order {
            let job = &trace.jobs[pi];
            let mut outcome = sched.try_place(job, &mut pool);
            if matches!(outcome, PlaceOutcome::NoCapacity)
                && opts.policy == Policy::PriorityBackfill
            {
                // Preempt-by-resize: shrink strictly-lower-priority
                // running jobs (lowest priority first, latest start /
                // highest id breaking ties) until the job fits.
                let mut victims: Vec<usize> = (0..running.len())
                    .filter(|&i| running[i].priority < job.priority)
                    .collect();
                victims.sort_by_key(|&i| {
                    (running[i].priority, u64::MAX - running[i].id as u64)
                });
                for vi in victims {
                    let need = job.min_chips.saturating_sub(pool.total());
                    if need == 0 {
                        break;
                    }
                    let already = resizes.iter().any(|s| s.running_idx == vi);
                    if already {
                        continue; // one shrink per victim per round
                    }
                    let v = &running[vi];
                    if let Some(shrink) =
                        sched.try_shrink(&v.plan, v.iteration_seconds, need)
                    {
                        pool.release(&shrink.freed);
                        preemptions += 1;
                        resizes.push(StagedResize {
                            running_idx: vi,
                            plan: shrink.plan,
                            freed: shrink.freed,
                            migrate_seconds: shrink.migrate_seconds,
                        });
                    }
                }
                if job.min_chips <= pool.total() {
                    outcome = sched.try_place(job, &mut pool);
                }
            }
            match outcome {
                PlaceOutcome::Placed(p) => placed.push((pi, p)),
                PlaceOutcome::NoCapacity => {
                    if running.is_empty() && placed.is_empty() && pool.total() == cluster.total_chips()
                    {
                        // Idle cluster and still no carve: terminal.
                        reject(job.id, t, &mut pending, pi, &mut events, &mut rejected);
                    } else if opts.policy == Policy::Fifo {
                        break; // head-of-line blocking
                    }
                }
                PlaceOutcome::SearchFailed(_) => {
                    if running.is_empty() && placed.is_empty() && pool.total() == cluster.total_chips()
                    {
                        reject(job.id, t, &mut pending, pi, &mut events, &mut rejected);
                    } else if opts.policy == Policy::Fifo {
                        break;
                    }
                }
            }
        }

        // Price every plan this round produced in one batched pass.
        let mut plan_refs: Vec<&ExecutionPlan> = placed.iter().map(|(_, p)| &p.plan).collect();
        plan_refs.extend(resizes.iter().map(|s| &s.plan));
        let prices = price_plans(&plan_refs, opts.workers);
        let (start_prices, resize_prices) = prices.split_at(placed.len());

        // Apply resizes (victims keep running at their new rate after
        // the migration penalty; the partially-done step restarts).
        for (s, &iter_new) in resizes.iter().zip(resize_prices) {
            let r = &mut running[s.running_idx];
            let freed: usize = s.freed.iter().map(|&(_, n)| n).sum();
            let base = t.max(r.seg_start); // a victim mid-migration resumes later
            let done = if base > r.seg_start && r.iteration_seconds > 0.0 {
                (((base - r.seg_start) / r.iteration_seconds).floor() as u64)
                    .min(r.steps_remaining)
            } else {
                0
            };
            segments.push((r.held, r.seg_start, base));
            r.steps_remaining -= done;
            r.held -= freed;
            r.plan = s.plan.clone();
            r.iteration_seconds = iter_new;
            r.seg_start = base + s.migrate_seconds;
            r.finish = r.seg_start + r.steps_remaining as f64 * iter_new;
            for &(kind, n) in &s.freed {
                r.shed(kind, n);
            }
            events.push(FleetEvent {
                t_seconds: t,
                job: r.id,
                kind: FleetEventKind::Resize {
                    freed_chips: freed,
                    iteration_seconds: iter_new,
                    migrate_seconds: s.migrate_seconds,
                },
            });
        }

        // Apply placements.
        for ((pi, p), &iter) in placed.iter().zip(start_prices) {
            let job = &trace.jobs[*pi];
            pending.retain(|&x| x != *pi);
            outcomes[*pi].wait_seconds = Some(t - job.arrival_step as f64);
            outcomes[*pi].chips = p.chips;
            running.push(Running {
                id: job.id,
                ti: *pi,
                priority: job.priority,
                alloc: p.alloc.clone(),
                held: p.chips,
                plan: p.plan.clone(),
                iteration_seconds: iter,
                seg_start: t,
                steps_remaining: job.steps,
                finish: t + job.steps as f64 * iter,
            });
            events.push(FleetEvent {
                t_seconds: t,
                job: job.id,
                kind: FleetEventKind::Start { chips: p.chips, iteration_seconds: iter },
            });
        }
    }

    // Metrics.
    let makespan = events.last().map(|e| e.t_seconds).unwrap_or(0.0);
    let mut waits: Vec<f64> = outcomes.iter().filter_map(|o| o.wait_seconds).collect();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let chip_seconds: f64 = segments.iter().map(|&(c, t0, t1)| c as f64 * (t1 - t0)).sum();
    let denom = cluster.total_chips() as f64 * makespan;
    let metrics = FleetMetrics {
        jobs: trace.jobs.len(),
        completed: outcomes.iter().filter(|o| o.finish_seconds.is_some()).count(),
        rejected,
        preemptions,
        makespan_seconds: makespan,
        mean_wait_seconds: if waits.is_empty() { 0.0 } else { stats::mean(&waits) },
        p99_wait_seconds: if waits.is_empty() { 0.0 } else { stats::percentile(&waits, 0.99) },
        chip_seconds,
        utilization: if denom > 0.0 { chip_seconds / denom } else { 0.0 },
    };
    Ok(FleetTimeline {
        policy: opts.policy,
        trace_seed: trace.seed,
        cluster: cluster.name.clone(),
        total_chips: cluster.total_chips(),
        events,
        jobs: outcomes,
        metrics,
    })
}

impl Running {
    /// Record `n` chips of `kind` as no longer held after a resize.
    fn shed(&mut self, kind: ChipKind, n: usize) {
        if let Some(slot) = self.alloc.iter_mut().find(|(k, _)| *k == kind) {
            slot.1 -= n.min(slot.1);
        }
        self.alloc.retain(|&(_, n)| n > 0);
    }
}

/// Queue order for one placement round, per policy. FIFO is
/// `(arrival, id)`; priority-with-backfill is
/// `(priority desc, arrival, id)`.
fn queue_order(policy: Policy, trace: &JobTrace, pending: &[usize]) -> Vec<usize> {
    let mut order = pending.to_vec();
    match policy {
        Policy::Fifo => order.sort_by_key(|&i| (trace.jobs[i].arrival_step, trace.jobs[i].id)),
        Policy::PriorityBackfill => order.sort_by_key(|&i| {
            let j = &trace.jobs[i];
            (u8::MAX - j.priority, j.arrival_step, j.id)
        }),
    }
    order
}

fn reject(
    job_id: usize,
    t: f64,
    pending: &mut Vec<usize>,
    pi: usize,
    events: &mut Vec<FleetEvent>,
    rejected: &mut usize,
) {
    pending.retain(|&x| x != pi);
    events.push(FleetEvent { t_seconds: t, job: job_id, kind: FleetEventKind::Reject });
    *rejected += 1;
}
