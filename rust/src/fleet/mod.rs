//! The fleet layer: pack a queue of heterogeneous jobs onto one cluster.
//!
//! The north star is a production system serving many concurrent
//! training jobs, not one. This module is the cluster-level scheduler
//! over everything below it:
//!
//! ```text
//!   JobTrace (seeded / JSON) ──► fleet::run event loop
//!                                     │ per decision point
//!                                     ▼
//!        FreePool::carve ──► auto::search_with_cache   (HeteroAuto inner
//!          (whole-node,           shared ProfileCache    solver per carve)
//!           vendor-aware)              │
//!                                      ▼
//!        preempt-by-resize ──► auto::replan + elastic migration ledger
//!                                      │
//!                                      ▼
//!        sim engine pool ──► price all new/resized plans in one batch
//!                                      │
//!                                      ▼
//!        FleetTimeline: events + per-job outcomes + fleet metrics
//! ```
//!
//! * [`job`] — [`JobSpec`], and [`JobTrace`]: the serializable job queue
//!   with a deterministic, seedable arrival-trace generator.
//! * [`sched`] — the free pool, vendor-aware whole-node carving, the
//!   HeteroAuto inner solver, and preempt-by-resize via
//!   [`crate::auto::replan`].
//! * [`sim`] — the fleet event loop, the batched plan-pricing pass, and
//!   the machine-readable [`FleetTimeline`] + [`FleetMetrics`].
//!
//! Everything is deterministic: same trace seed + policy ⇒ bit-identical
//! [`FleetTimeline`], for any simulator worker count. The narrative
//! guide (schema, policy semantics, metric definitions, a worked
//! `h2 fleet` walkthrough) is `docs/fleet.md`.

pub mod job;
pub mod sched;
pub mod sim;

pub use job::{JobModel, JobSpec, JobTrace};
pub use sched::{FreePool, PlaceOutcome, Placement, Policy, Scheduler, Shrink};
pub use sim::{
    fleet_search_config, run, FleetEvent, FleetEventKind, FleetMetrics, FleetOptions,
    FleetTimeline, JobOutcome,
};
