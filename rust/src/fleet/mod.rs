//! The fleet layer: pack a queue of heterogeneous jobs onto one cluster.
//!
//! The north star is a production system serving many concurrent
//! training jobs, not one. This module is the cluster-level scheduler
//! over everything below it:
//!
//! ```text
//!   JobTrace (seeded / JSON) ──► fleet::run event loop
//!                                     │ per decision point
//!                                     ▼
//!        FreePool::carve ──► auto::search_with_cache   (HeteroAuto inner
//!          (whole-node,           shared ProfileCache    solver per carve)
//!           vendor-aware)              │
//!                                      ▼
//!        preempt-by-resize ──► auto::replan + elastic migration ledger
//!                                      │
//!                                      ▼
//!        sim engine pool ──► price all new/resized plans in one batch
//!                                      │
//!                                      ▼
//!        FleetTimeline: events + per-job outcomes + fleet metrics
//! ```
//!
//! * [`job`] — [`JobSpec`], and [`JobTrace`]: the serializable job queue
//!   with a deterministic, seedable arrival-trace generator.
//! * [`sched`] — the free pool (with its dead-chip ledger), vendor-aware
//!   whole-node carving, the HeteroAuto inner solver, preempt-by-resize,
//!   and the first two cascade rungs via [`crate::auto::replan`].
//! * [`fault`] — [`ClusterFaultPlan`]: wall-clock cluster fault scripts
//!   (seedable, hand-authorable JSON, and the pinned contrast scenario).
//! * [`sim`] — the fleet event loop, the fault-projection node ledger,
//!   the graceful-degradation cascade, the batched plan-pricing pass,
//!   and the machine-readable [`FleetTimeline`] + [`FleetMetrics`]
//!   (including the recovery ledger: goodput fraction, recomputed
//!   steps, total recovery seconds).
//!
//! Everything is deterministic: same trace seed + fault plan + policy ⇒
//! bit-identical [`FleetTimeline`], for any simulator worker count. The
//! narrative guide (schema, policy semantics, fault semantics, metric
//! definitions, a worked `h2 fleet` walkthrough) is `docs/fleet.md`.

pub mod fault;
pub mod job;
pub mod sched;
pub mod sim;

pub use fault::{ClusterFault, ClusterFaultPlan};
pub use job::{JobModel, JobSpec, JobTrace};
pub use sched::{FreePool, PlaceOutcome, Placement, Policy, Recovery, Scheduler, Shrink};
pub use sim::{
    fleet_search_config, run, FaultResponse, FleetEvent, FleetEventKind, FleetMetrics,
    FleetOptions, FleetTimeline, JobOutcome, NO_JOB,
};
