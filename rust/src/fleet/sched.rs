//! Placement: carving vendor-aware sub-clusters and solving them.
//!
//! The fleet scheduler never plans a job itself — it carves a sub-cluster
//! out of the free pool ([`FreePool::carve`], whole nodes only, fewest
//! vendors first) and hands it to HeteroAuto
//! ([`crate::auto::search_with_cache`]) as the inner solver, over one
//! shared [`ProfileCache`] so repeated placements on the same chip kinds
//! hit warm per-layer profiles. Preemption is a *resize*: the victim's
//! incumbent plan is re-planned over a reduced cluster with
//! [`crate::auto::replan`] (pipeline-preserving, so the elastic
//! migration ledger prices the hot swap), and the freed whole nodes go
//! back to the pool.

use anyhow::Result;

use crate::auto::{replan, search_with_cache, ClusterDelta, ReplanOptions, SearchConfig};
use crate::costmodel::ProfileCache;
use crate::elastic::RecoveryTimeline;
use crate::hetero::{spec, ChipKind, Cluster};
use crate::plan::ExecutionPlan;

use super::job::JobSpec;

/// A fleet scheduling policy. Both are deterministic; they differ only
/// in queue order and in whether a stuck head blocks the queue.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Policy {
    /// Strict arrival order. A head job that does not fit blocks every
    /// job behind it until chips free up — the honest baseline.
    #[default]
    Fifo,
    /// Jobs are served in `(priority desc, arrival, id)` order, jobs
    /// that do not fit are skipped so smaller ones behind them backfill,
    /// and a job may shrink (preempt-by-resize) one or more
    /// strictly-lower-priority running jobs to make room.
    PriorityBackfill,
}

impl Policy {
    /// The wire/CLI token (`"fifo"` / `"priority"`).
    pub fn token(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::PriorityBackfill => "priority",
        }
    }

    /// Parse a CLI/config token.
    pub fn parse(text: &str) -> Result<Policy> {
        match text {
            "fifo" => Ok(Policy::Fifo),
            "priority" | "priority-backfill" | "backfill" => Ok(Policy::PriorityBackfill),
            other => anyhow::bail!("unknown fleet policy `{other}` (expected fifo or priority)"),
        }
    }
}

/// The cluster's idle chips, per kind, in the cluster's
/// memory-descending group order. Every count is a whole number of that
/// kind's nodes by construction: the pool starts from whole-node cluster
/// groups and only ever moves whole-node allocations in or out.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FreePool {
    free: Vec<(ChipKind, usize)>,
    /// Chips retired by cluster faults, per kind. Dead capacity is *not*
    /// free: [`FreePool::carve`] never sees it, and it only returns via
    /// [`FreePool::recover`]. Zero entries are pruned so a fully-recovered
    /// pool compares bit-for-bit equal to a never-faulted one.
    dead: Vec<(ChipKind, usize)>,
}

impl FreePool {
    /// A pool with the whole cluster idle.
    pub fn new(cluster: &Cluster) -> FreePool {
        FreePool {
            free: cluster
                .groups_by_memory_desc()
                .into_iter()
                .map(|g| (g.spec.kind, g.n_chips))
                .collect(),
            dead: Vec::new(),
        }
    }

    /// Total idle chips.
    pub fn total(&self) -> usize {
        self.free.iter().map(|&(_, n)| n).sum()
    }

    /// Total chips retired by faults and not yet recovered.
    pub fn dead_total(&self) -> usize {
        self.dead.iter().map(|&(_, n)| n).sum()
    }

    /// Idle chips of one kind (0 for kinds the pool has never seen).
    pub fn free_of(&self, kind: ChipKind) -> usize {
        self.free.iter().find(|&&(k, _)| k == kind).map_or(0, |&(_, n)| n)
    }

    /// Retire `chips` *idle* chips of `kind`: they leave the free pool and
    /// join the dead ledger. Panics on overdraw — the fleet loop only
    /// retires chips its node ledger says are free.
    pub fn retire(&mut self, kind: ChipKind, chips: usize) {
        let slot = self
            .free
            .iter_mut()
            .find(|(k, _)| *k == kind)
            .unwrap_or_else(|| panic!("retiring {chips} chips of unknown kind {kind:?}"));
        assert!(slot.1 >= chips, "retiring {chips} idle {kind:?} chips but only {} are free", slot.1);
        slot.1 -= chips;
        self.add_dead(kind, chips);
    }

    /// Retire `chips` chips of `kind` that a job currently holds: they
    /// never pass through the free pool (the job sheds them directly), but
    /// the dead ledger still has to know they exist so recovery can return
    /// them and [`FreePool::dead_total`] stays honest.
    pub fn retire_held(&mut self, kind: ChipKind, chips: usize) {
        self.add_dead(kind, chips);
    }

    /// Return `chips` previously-retired chips of `kind` to the free pool.
    /// Panics if the dead ledger holds fewer — recover events are
    /// validated against what actually died.
    pub fn recover(&mut self, kind: ChipKind, chips: usize) {
        let slot = self
            .dead
            .iter_mut()
            .find(|(k, _)| *k == kind)
            .unwrap_or_else(|| panic!("recovering {chips} chips of {kind:?} but none are dead"));
        assert!(slot.1 >= chips, "recovering {chips} dead {kind:?} chips but only {} died", slot.1);
        slot.1 -= chips;
        self.dead.retain(|&(_, n)| n > 0);
        self.release(&[(kind, chips)]);
    }

    fn add_dead(&mut self, kind: ChipKind, chips: usize) {
        match self.dead.iter_mut().find(|(k, _)| *k == kind) {
            Some(slot) => slot.1 += chips,
            None => self.dead.push((kind, chips)),
        }
    }

    /// Carve a whole-node allocation of at least `min_chips` and at most
    /// `max_chips` chips, or `None` if the pool cannot cover `min_chips`.
    ///
    /// Vendor-aware and deterministic: kinds are visited largest free
    /// pool first (ties in memory-descending order), each contributing
    /// whole nodes up to the remaining budget — so a job that fits in
    /// one vendor's pool gets a homogeneous sub-cluster, and a job that
    /// does not spans the fewest pools that cover it.
    pub fn carve(&self, min_chips: usize, max_chips: usize) -> Option<Vec<(ChipKind, usize)>> {
        let mut order: Vec<usize> = (0..self.free.len()).collect();
        order.sort_by_key(|&i| (usize::MAX - self.free[i].1, i));
        let mut alloc = Vec::new();
        let mut got = 0usize;
        for i in order {
            let (kind, free) = self.free[i];
            let node = spec(kind).chips_per_node;
            let take = free.min((max_chips - got) / node * node);
            if take > 0 {
                alloc.push((kind, take));
                got += take;
            }
            if max_chips - got < node {
                break;
            }
        }
        if got < min_chips {
            return None;
        }
        // Return in the pool's (memory-descending) kind order so the
        // sub-cluster names its groups the way every other cluster does.
        let mut out = Vec::new();
        for &(kind, _) in &self.free {
            if let Some(&(_, n)) = alloc.iter().find(|&&(k, _)| k == kind) {
                out.push((kind, n));
            }
        }
        Some(out)
    }

    /// Remove an allocation from the pool (panics if over-drawn — the
    /// scheduler only takes what [`FreePool::carve`] returned).
    pub fn take(&mut self, alloc: &[(ChipKind, usize)]) {
        for &(kind, n) in alloc {
            let slot = self
                .free
                .iter_mut()
                .find(|(k, _)| *k == kind)
                .unwrap_or_else(|| panic!("taking {n} chips of unknown kind {kind:?}"));
            assert!(slot.1 >= n, "over-drawing {n} chips of {kind:?} from a pool of {}", slot.1);
            slot.1 -= n;
        }
    }

    /// Return an allocation to the pool.
    pub fn release(&mut self, alloc: &[(ChipKind, usize)]) {
        for &(kind, n) in alloc {
            if let Some(slot) = self.free.iter_mut().find(|(k, _)| *k == kind) {
                slot.1 += n;
            } else {
                self.free.push((kind, n));
            }
        }
    }
}

/// A successful placement: the carved allocation and the solved plan
/// (iteration time still to be priced by the fleet's simulator pool).
#[derive(Clone, Debug)]
pub struct Placement {
    /// Whole-node chips taken from the pool, per kind.
    pub alloc: Vec<(ChipKind, usize)>,
    /// Total chips in the allocation.
    pub chips: usize,
    /// The HeteroAuto plan for the carved sub-cluster.
    pub plan: ExecutionPlan,
}

/// What one placement attempt produced.
#[derive(Clone, Debug)]
pub enum PlaceOutcome {
    /// The job got a sub-cluster and a plan; the chips are already taken
    /// from the pool.
    Placed(Placement),
    /// The free pool cannot cover the job's `min_chips` — wait for
    /// capacity (or preempt, under the priority policy).
    NoCapacity,
    /// The pool covered the chips but HeteroAuto found no feasible
    /// strategy on the carve (with the reason). On a fully idle cluster
    /// this is terminal; otherwise the job waits for a different carve.
    SearchFailed(String),
}

/// A successful preempt-by-resize of one running job.
#[derive(Clone, Debug)]
pub struct Shrink {
    /// The victim's re-planned (pipeline-preserving, epoch-bumped) plan
    /// over the reduced sub-cluster.
    pub plan: ExecutionPlan,
    /// Whole-node chips returned to the pool.
    pub freed: Vec<(ChipKind, usize)>,
    /// Hot-swap cost from the elastic migration ledger: the time to move
    /// displaced layer state onto the surviving stages.
    pub migrate_seconds: f64,
    /// Surviving chips the pipeline-preserving re-plan idles (still held
    /// by the victim, not returned to the pool).
    pub idled_chips: usize,
}

/// A successful in-place recovery of a fault-struck running job — the
/// first two rungs of the graceful-degradation cascade (the third,
/// requeue-from-checkpoint, is the fleet loop's own move).
#[derive(Clone, Debug)]
pub enum Recovery {
    /// Rung 1: a pipeline-preserving [`replan`] excluding the dead chips,
    /// hot-swapped in place. No steps are lost; the job pays the elastic
    /// recovery ledger (drain + detect + migrate).
    InPlace {
        /// The epoch-bumped survivor plan.
        plan: ExecutionPlan,
        /// Drain + detect + migrate seconds from [`RecoveryTimeline`].
        recovery_seconds: f64,
    },
    /// Rung 2: a full-mode replan (pipeline reshaped) over the survivors.
    /// The new pipeline is not swap-compatible, so the job restarts from
    /// its last checkpoint: it pays drain + detect + restore here and
    /// recomputes the steps since that checkpoint (charged by the caller).
    Shrink {
        /// The reshaped survivor plan.
        plan: ExecutionPlan,
        /// Drain + detect + restore seconds.
        recovery_seconds: f64,
    },
}

impl Recovery {
    /// The survivor plan either rung produced.
    pub fn plan(&self) -> &ExecutionPlan {
        match self {
            Recovery::InPlace { plan, .. } | Recovery::Shrink { plan, .. } => plan,
        }
    }
}

/// The placement engine: one policy, one inner-solver config, one warm
/// [`ProfileCache`] shared by every placement and resize decision.
#[derive(Debug, Default)]
pub struct Scheduler {
    /// Queue policy (used by the fleet loop, not by placement itself).
    pub policy: Policy,
    /// Inner HeteroAuto solver config (see
    /// [`super::fleet_search_config`] for the default).
    pub search: SearchConfig,
    cache: ProfileCache,
}

impl Scheduler {
    /// A scheduler with a fresh profile cache.
    pub fn new(policy: Policy, search: SearchConfig) -> Scheduler {
        Scheduler { policy, search, cache: ProfileCache::new() }
    }

    /// The shared profile cache (observability: hits/misses).
    pub fn cache(&self) -> &ProfileCache {
        &self.cache
    }

    /// Try to place `job`: carve from `pool`, solve with HeteroAuto, and
    /// on success take the chips. The pool is untouched on failure.
    pub fn try_place(&self, job: &JobSpec, pool: &mut FreePool) -> PlaceOutcome {
        let Some(alloc) = pool.carve(job.min_chips, job.max_chips) else {
            return PlaceOutcome::NoCapacity;
        };
        let chips = alloc.iter().map(|&(_, n)| n).sum();
        let sub = match Cluster::try_build(&job.name(), alloc.clone()) {
            Ok(c) => c,
            Err(e) => return PlaceOutcome::SearchFailed(e.to_string()),
        };
        match search_with_cache(job.model.shape(), &sub, job.gbs_tokens, &self.search, &self.cache)
        {
            Ok(r) => {
                pool.take(&alloc);
                let plan = r.into_plan(job.model.shape(), &sub, job.gbs_tokens);
                PlaceOutcome::Placed(Placement { alloc, chips, plan })
            }
            Err(e) => PlaceOutcome::SearchFailed(e.to_string()),
        }
    }

    /// Try to shrink a running job to free at least `need_chips` chips:
    /// a pipeline-preserving [`replan`] excluding whole nodes of the
    /// victim's largest chip group, priced by the elastic migration
    /// ledger (`step_seconds` is the victim's current per-step time).
    /// `None` when the victim cannot shrink that far (its plan would not
    /// survive) — the caller then tries the next victim or waits.
    pub fn try_shrink(
        &self,
        victim: &ExecutionPlan,
        step_seconds: f64,
        need_chips: usize,
    ) -> Option<Shrink> {
        // Shed from the victim's largest group (ties: memory-descending
        // order), keeping at least one node so the stage group survives.
        let groups = victim.cluster.groups_by_memory_desc();
        let g = groups.iter().max_by_key(|g| g.n_chips)?;
        let (kind, node) = (g.spec.kind, g.spec.chips_per_node);
        let exclude = (need_chips.div_ceil(node) * node).min(g.n_chips.saturating_sub(node));
        if exclude == 0 {
            return None;
        }
        let outcome =
            replan(victim, &ClusterDelta::exclude(kind, exclude), &self.cache, &ReplanOptions::default())
                .ok()?;
        if !outcome.changed {
            return None;
        }
        let migrate_seconds =
            RecoveryTimeline::new(victim, &outcome.plan, step_seconds, 0, 0.0, 0.0)
                .ok()?
                .migrate_seconds;
        Some(Shrink {
            plan: outcome.plan,
            freed: vec![(kind, exclude)],
            migrate_seconds,
            idled_chips: outcome.idled_chips,
        })
    }

    /// Walk the first two rungs of the fault cascade for a running job
    /// that just lost `dead_chips` chips of `kind`:
    ///
    /// 1. pipeline-preserving replan, priced by the elastic
    ///    [`RecoveryTimeline`] (drain + detect + migrate);
    /// 2. full-mode replan over the survivors, priced as a
    ///    checkpoint-restart (drain + detect + restore) — the caller
    ///    charges the recomputed steps.
    ///
    /// `None` when neither rung produces a plan (e.g. the whole chip
    /// group died) — the caller falls through to requeue-from-checkpoint.
    /// `step_seconds` is the victim's per-step time when the fault hit
    /// (the drain/detect basis); `debounce` is the monitor's window.
    /// Rung 1 preserves the job's placement contract, so it always runs;
    /// rung 2 reshapes the pipeline — effectively a new placement — and
    /// only runs when `allow_shrink` (the caller checks the job's
    /// `min_chips` against the survivors).
    pub fn try_recover(
        &self,
        victim: &ExecutionPlan,
        step_seconds: f64,
        debounce: usize,
        kind: ChipKind,
        dead_chips: usize,
        allow_shrink: bool,
    ) -> Option<Recovery> {
        let delta = ClusterDelta::exclude(kind, dead_chips);
        if let Ok(outcome) = replan(victim, &delta, &self.cache, &ReplanOptions::default()) {
            if outcome.changed {
                if let Ok(tl) =
                    RecoveryTimeline::new(victim, &outcome.plan, step_seconds, debounce, 0.0, 0.0)
                {
                    return Some(Recovery::InPlace {
                        plan: outcome.plan,
                        recovery_seconds: tl.recovery_seconds(),
                    });
                }
            }
        }
        if !allow_shrink {
            return None;
        }
        let outcome = replan(victim, &delta, &self.cache, &ReplanOptions::full()).ok()?;
        if !outcome.changed {
            return None;
        }
        let recovery_seconds = (1 + debounce) as f64 * step_seconds
            + crate::elastic::restore_seconds(&outcome.plan);
        Some(Recovery::Shrink { plan: outcome.plan, recovery_seconds })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::experiment;

    #[test]
    fn carve_prefers_one_vendor_and_whole_nodes() {
        let mega = experiment("exp-mega").unwrap().cluster;
        let pool = FreePool::new(&mega);
        // 128 chips fit inside the biggest single pool (B = 512).
        let alloc = pool.carve(128, 128).unwrap();
        assert_eq!(alloc.len(), 1, "homogeneous carve expected, got {alloc:?}");
        assert_eq!(alloc[0].1, 128);
        // A carve bigger than any one pool spans several, whole nodes each.
        let alloc = pool.carve(1024, 1024).unwrap();
        assert!(alloc.len() > 1);
        for &(kind, n) in &alloc {
            assert_eq!(n % spec(kind).chips_per_node, 0, "ragged node carve of {kind:?}");
        }
        assert_eq!(alloc.iter().map(|&(_, n)| n).sum::<usize>(), 1024);
    }

    #[test]
    fn take_and_release_are_inverse() {
        let mega = experiment("exp-mega").unwrap().cluster;
        let mut pool = FreePool::new(&mega);
        let before = pool.clone();
        let alloc = pool.carve(256, 256).unwrap();
        pool.take(&alloc);
        assert_eq!(pool.total(), mega.total_chips() - 256);
        pool.release(&alloc);
        assert_eq!(pool, before);
    }

    #[test]
    fn carve_fails_only_below_min() {
        let mega = experiment("exp-mega").unwrap().cluster;
        let pool = FreePool::new(&mega);
        assert!(pool.carve(mega.total_chips() + 64, mega.total_chips() + 64).is_none());
        assert!(pool.carve(mega.total_chips(), mega.total_chips()).is_some());
    }

    #[test]
    fn carve_never_hands_out_dead_nodes() {
        // The dead-node invariant: once the cascade retires nodes, no
        // carve — any min/max, any order — can allocate a dead node's
        // chips; and retire → recover round-trips the pool bit-for-bit,
        // including carve behavior.
        use crate::util::prop;
        let mega = experiment("exp-mega").unwrap().cluster;
        let total = mega.total_chips();
        let groups = mega.groups_by_memory_desc();
        prop::check(100, |rng| {
            let mut pool = FreePool::new(&mega);
            let before = pool.clone();
            // Retire whole nodes of a random kind (possibly the entire
            // group), as a node-death fault would.
            let g = groups[rng.usize(0, groups.len())];
            let node = g.spec.chips_per_node;
            let dead_nodes = rng.usize(1, g.n_nodes() + 1);
            let dead_chips = dead_nodes * node;
            pool.retire(g.spec.kind, dead_chips);
            prop::assert_prop(pool.dead_total() == dead_chips, "dead ledger must count the loss")?;
            prop::assert_prop(
                pool.total() + pool.dead_total() == total,
                "free + dead must cover the cluster",
            )?;
            // No carve can see the dead capacity.
            for _ in 0..4 {
                let max = rng.usize(1, total + 1);
                let min = rng.usize(1, max + 1);
                if let Some(alloc) = pool.carve(min, max) {
                    for &(kind, n) in &alloc {
                        prop::assert_prop(
                            n <= pool.free_of(kind),
                            format!("carve of {n} {kind:?} chips exceeds the surviving pool"),
                        )?;
                    }
                }
            }
            prop::assert_prop(
                pool.carve(total, total).is_none(),
                "a whole-cluster carve must fail while nodes are dead",
            )?;
            // Recovery restores the pool bit-for-bit — including what a
            // subsequent carve returns.
            pool.recover(g.spec.kind, dead_chips);
            prop::assert_prop(pool == before, "retire → recover must round-trip the pool")?;
            let max = rng.usize(1, total + 1);
            let min = rng.usize(1, max + 1);
            prop::assert_prop(
                pool.carve(min, max) == before.carve(min, max),
                "recovered pool must carve exactly like a never-faulted one",
            )
        });
    }
}
