//! Cluster-level fault domains: wall-clock fault scripts for the fleet.
//!
//! A [`ClusterFaultPlan`] is the fleet-scale sibling of the per-job
//! [`crate::elastic::FaultPlan`]: the same [`FaultKind`] vocabulary
//! (chip death, compute slowdown, NIC degradation, recovery), but keyed
//! by `(chip kind, node, wall-clock seconds)` instead of
//! `(step, stage)` — a cluster does not know which job's step it is
//! breaking. [`crate::fleet::run`] projects each fault onto whichever
//! job owns the struck node at that instant (or onto the free pool) and
//! walks the graceful-degradation cascade; see the module docs of
//! [`crate::fleet`].
//!
//! Plans are seedable ([`ClusterFaultPlan::generate`]), hand-authorable
//! (JSON, same kind tokens as per-job fault files), and — for the pinned
//! contrast scenario — derivable from a healthy timeline
//! ([`ClusterFaultPlan::pinned_for`]), which places one survivable
//! single-node death inside the first job's window and one unsurvivable
//! whole-group death inside the second job's window.

use anyhow::{anyhow, bail, ensure, Result};

use crate::elastic::FaultKind;
use crate::hetero::{ChipKind, Cluster};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

use super::sim::{FleetEventKind, FleetTimeline};

/// One scheduled cluster fault: `kind` strikes node `node` of the
/// cluster's `chip` group at wall-clock time `t_seconds`.
///
/// For [`FaultKind::ChipDeath`] the event kills `nodes` whole nodes
/// starting at `node`; every other kind targets the single node `node`.
/// A [`FaultKind::Recover`] on a dead node returns it to the free pool;
/// on a degraded node it clears the degradation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterFault {
    /// Wall-clock fleet time the fault strikes, seconds.
    pub t_seconds: f64,
    /// Chip group the struck node belongs to.
    pub chip: ChipKind,
    /// Node index within the chip group (whole-node granularity — chips
    /// share fate with their node, as in the elastic layer).
    pub node: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic, seedable, serializable cluster fault script.
///
/// Events are applied in `(t_seconds, chip, node)` order; the fleet loop
/// sorts its working copy, so hand-written files need not be sorted.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterFaultPlan {
    /// Seed the plan was generated from (informational for hand-written
    /// and pinned plans).
    pub seed: u64,
    /// The fault script.
    pub events: Vec<ClusterFault>,
}

impl ClusterFaultPlan {
    /// A plan with no events (healthy cluster).
    pub fn none() -> ClusterFaultPlan {
        ClusterFaultPlan::default()
    }

    /// Generate a small random fault script over `horizon_seconds` of
    /// fleet time: a few transient degradations (each paired with a
    /// recover) plus one single-node death that recovers before the
    /// horizon, so a capacity-blocked queue can always drain.
    /// Deterministic in `seed`.
    pub fn generate(seed: u64, cluster: &Cluster, horizon_seconds: f64) -> ClusterFaultPlan {
        let mut rng = Rng::new(seed ^ 0xC1F5_FA17_C1F5_FA17);
        let groups = cluster.groups_by_memory_desc();
        let horizon = if horizon_seconds.is_finite() && horizon_seconds > 1.0 {
            horizon_seconds
        } else {
            1.0
        };
        let mut events = Vec::new();
        let n = rng.usize(1, 4);
        for _ in 0..n {
            let g = groups[rng.usize(0, groups.len())];
            let node = rng.usize(0, g.n_nodes());
            let t = horizon * rng.usize(5, 70) as f64 / 100.0;
            let factor = 1.0 + rng.usize(5, 30) as f64 / 10.0;
            let kind = if rng.usize(0, 2) == 0 {
                FaultKind::Slowdown { factor }
            } else {
                FaultKind::NicDegrade { factor }
            };
            events.push(ClusterFault { t_seconds: t, chip: g.spec.kind, node, kind });
            events.push(ClusterFault {
                t_seconds: t + horizon * 0.08,
                chip: g.spec.kind,
                node,
                kind: FaultKind::Recover,
            });
        }
        let g = groups[rng.usize(0, groups.len())];
        let node = rng.usize(0, g.n_nodes());
        let t = horizon * rng.usize(40, 75) as f64 / 100.0;
        events.push(ClusterFault {
            t_seconds: t,
            chip: g.spec.kind,
            node,
            kind: FaultKind::ChipDeath { nodes: 1 },
        });
        events.push(ClusterFault {
            t_seconds: t + horizon * 0.2,
            chip: g.spec.kind,
            node,
            kind: FaultKind::Recover,
        });
        let mut plan = ClusterFaultPlan { seed, events };
        plan.sort();
        plan
    }

    /// The pinned contrast scenario, derived from a healthy run of the
    /// pinned trace: one *survivable* single-node death inside job 0's
    /// window (recovered one iteration later, so the cascade's in-place
    /// replan is the right answer) and one *unsurvivable* whole-group
    /// death of the smallest chip group inside job 1's window (recovered
    /// four iterations later, so requeue-from-checkpoint is the only
    /// answer). Fault times are placed off the healthy timeline's own
    /// start/finish/iteration observations, so the scenario lands inside
    /// both jobs' windows for any cluster the pinned trace fills.
    pub fn pinned_for(cluster: &Cluster, healthy: &FleetTimeline) -> Result<ClusterFaultPlan> {
        let window = |job: usize| -> Result<(f64, f64, f64)> {
            let mut start_iter = None;
            let mut finish = None;
            for e in &healthy.events {
                if e.job != job {
                    continue;
                }
                match e.kind {
                    FleetEventKind::Start { iteration_seconds, .. } if start_iter.is_none() => {
                        start_iter = Some((e.t_seconds, iteration_seconds));
                    }
                    FleetEventKind::Finish => finish = Some(e.t_seconds),
                    _ => {}
                }
            }
            match (start_iter, finish) {
                (Some((s, i)), Some(f)) if i > 0.0 && f > s => Ok((s, i, f)),
                _ => bail!(
                    "pinned fault plan needs job {job}'s start and finish in the healthy timeline"
                ),
            }
        };
        let (s0, i0, f0) = window(0)?;
        let (s1, i1, f1) = window(1)?;
        let groups = cluster.groups_by_memory_desc();
        ensure!(!groups.is_empty(), "cannot author faults for an empty cluster");
        let most = groups.iter().max_by_key(|g| g.n_nodes()).unwrap();
        let few = groups.iter().min_by_key(|g| g.n_nodes()).unwrap();
        ensure!(
            most.n_nodes() >= 2,
            "pinned fault plan needs a chip group with at least two nodes"
        );
        // Survivable death: one node of the largest group, ~10.5
        // iterations before job 0's healthy finish (so the remaining work
        // is long enough to make in-place recovery worth it), back one
        // iteration later.
        let t1 = (f0 - 10.5 * i0).max(s0 + 0.25 * i0);
        let n1 = most.n_nodes() - 1;
        // Unsurvivable death: the whole smallest group, half an iteration
        // before job 1's healthy finish — rolled back to its checkpoint
        // grid, requeued, and re-placed when the group recovers four
        // iterations later.
        let t2 = (f1 - 0.5 * i1).max(s1.max(t1 + 1.25 * i0) + 0.25 * i1);
        let t3 = t2 + 4.0 * i1;
        let mut events = vec![
            ClusterFault {
                t_seconds: t1,
                chip: most.spec.kind,
                node: n1,
                kind: FaultKind::ChipDeath { nodes: 1 },
            },
            ClusterFault {
                t_seconds: t1 + i0,
                chip: most.spec.kind,
                node: n1,
                kind: FaultKind::Recover,
            },
            ClusterFault {
                t_seconds: t2,
                chip: few.spec.kind,
                node: 0,
                kind: FaultKind::ChipDeath { nodes: few.n_nodes() },
            },
        ];
        for node in 0..few.n_nodes() {
            events.push(ClusterFault {
                t_seconds: t3,
                chip: few.spec.kind,
                node,
                kind: FaultKind::Recover,
            });
        }
        let mut plan = ClusterFaultPlan { seed: healthy.trace_seed, events };
        plan.sort();
        plan.validate(cluster)?;
        Ok(plan)
    }

    /// Sort events into the fleet loop's application order:
    /// `(t_seconds, chip, node)`, stable for ties.
    pub fn sort(&mut self) {
        self.events.sort_by(|a, b| {
            a.t_seconds
                .total_cmp(&b.t_seconds)
                .then_with(|| a.chip.name().cmp(b.chip.name()))
                .then_with(|| a.node.cmp(&b.node))
        });
    }

    /// Structural validation against the cluster the plan will strike.
    pub fn validate(&self, cluster: &Cluster) -> Result<()> {
        for e in &self.events {
            if !e.t_seconds.is_finite() || e.t_seconds < 0.0 {
                bail!("cluster fault at t={} is not a finite non-negative time", e.t_seconds);
            }
            let group = cluster.group(e.chip).map_err(|err| {
                anyhow!("cluster fault at t={} targets a missing group: {err}", e.t_seconds)
            })?;
            let n_nodes = group.n_nodes();
            let span = match e.kind {
                FaultKind::ChipDeath { nodes } => nodes,
                _ => 1,
            };
            if e.node >= n_nodes || n_nodes - e.node < span {
                bail!(
                    "cluster fault at t={} targets nodes {}..{} of a {n_nodes}-node {} group",
                    e.t_seconds,
                    e.node,
                    e.node + span,
                    e.chip
                );
            }
            e.kind
                .validate()
                .map_err(|err| anyhow!("{err} (cluster fault at t={})", e.t_seconds))?;
        }
        Ok(())
    }

    /// Serialize (seeds travel as decimal strings, like every other seed
    /// in the repo, so full-range u64 values survive the f64 JSON number
    /// space).
    pub fn to_json(&self) -> Value {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("t_seconds", json::num(e.t_seconds)),
                    ("chip", json::s(e.chip.name())),
                    ("node", json::num(e.node as f64)),
                    ("kind", json::s(e.kind.token())),
                ];
                e.kind.push_json_fields(&mut fields);
                json::obj(fields)
            })
            .collect();
        json::obj(vec![
            ("seed", json::s(&self.seed.to_string())),
            ("events", json::arr(events)),
        ])
    }

    /// Parse a serialized cluster fault plan.
    pub fn from_json(v: &Value) -> Result<ClusterFaultPlan> {
        let seed = match v.get("seed")? {
            Value::Str(s) => {
                s.parse::<u64>().map_err(|e| anyhow!("bad cluster fault seed `{s}`: {e}"))?
            }
            other => other.u64()?,
        };
        let mut events = Vec::new();
        for e in v.get("events")?.arr()? {
            let name = e.get("chip")?.str()?;
            let chip = ChipKind::parse(name)
                .ok_or_else(|| anyhow!("unknown chip kind `{name}` in cluster fault plan"))?;
            events.push(ClusterFault {
                t_seconds: e.get("t_seconds")?.num()?,
                chip,
                node: e.get("node")?.usize()?,
                kind: FaultKind::from_json(e)?,
            });
        }
        Ok(ClusterFaultPlan { seed, events })
    }

    /// Load a cluster fault plan from a JSON file (the `h2 fleet
    /// --faults <file>` path).
    pub fn load(path: &str) -> Result<ClusterFaultPlan> {
        ClusterFaultPlan::from_json(&Value::from_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::experiment;
    use crate::util::prop;

    fn lab() -> Cluster {
        Cluster::new("lab", vec![(ChipKind::A, 64), (ChipKind::B, 64)])
    }

    fn sample() -> ClusterFaultPlan {
        ClusterFaultPlan {
            seed: u64::MAX - 3, // exercises the decimal-string seed path
            events: vec![
                ClusterFault {
                    t_seconds: 10.0,
                    chip: ChipKind::B,
                    node: 7,
                    kind: FaultKind::ChipDeath { nodes: 1 },
                },
                ClusterFault {
                    t_seconds: 12.5,
                    chip: ChipKind::A,
                    node: 2,
                    kind: FaultKind::Slowdown { factor: 2.0 },
                },
                ClusterFault {
                    t_seconds: 20.0,
                    chip: ChipKind::A,
                    node: 2,
                    kind: FaultKind::Recover,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let plan = sample();
        let back = ClusterFaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back);
        let text = plan.to_json().to_string_pretty();
        let back = ClusterFaultPlan::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn validation_rejects_out_of_cluster_targets() {
        let lab = lab();
        assert!(sample().validate(&lab).is_ok());
        let mut bad = sample();
        bad.events[0].node = 8; // B has 64 / 8 = 8 nodes: 0..8
        assert!(bad.validate(&lab).is_err());
        let mut bad = sample();
        bad.events[0].kind = FaultKind::ChipDeath { nodes: 9 };
        bad.events[0].node = 0;
        assert!(bad.validate(&lab).is_err(), "death span must fit the group");
        let mut bad = sample();
        bad.events[1].kind = FaultKind::Slowdown { factor: 0.0 };
        assert!(bad.validate(&lab).is_err());
        let mut bad = sample();
        bad.events[0].chip = ChipKind::C;
        assert!(bad.validate(&lab).is_err(), "lab has no C group");
    }

    #[test]
    fn generated_plans_are_deterministic_valid_and_roundtrip() {
        let mega = experiment("exp-mega").unwrap().cluster;
        prop::check(50, |rng| {
            let seed = rng.next_u64();
            let horizon = 100.0 + rng.usize(0, 10_000) as f64;
            let a = ClusterFaultPlan::generate(seed, &mega, horizon);
            let b = ClusterFaultPlan::generate(seed, &mega, horizon);
            prop::assert_prop(a == b, "generation must be deterministic in the seed")?;
            a.validate(&mega).map_err(|e| format!("invalid: {e}"))?;
            prop::assert_prop(
                a.events
                    .iter()
                    .any(|e| matches!(e.kind, FaultKind::ChipDeath { .. })),
                "generated plans include a death",
            )?;
            prop::assert_prop(
                a.events.windows(2).all(|w| w[0].t_seconds <= w[1].t_seconds),
                "generated plans are sorted by time",
            )?;
            let back = ClusterFaultPlan::from_json(&a.to_json())
                .map_err(|e| format!("reparse failed: {e}"))?;
            prop::assert_prop(a == back, "JSON round-trip must be lossless")
        });
    }
}
