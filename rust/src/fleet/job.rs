//! Job specifications and the seedable arrival-trace generator.
//!
//! A [`JobSpec`] is one training job in the fleet queue: which model it
//! trains, its global batch, how many chips it needs (a `min..=max`
//! range the scheduler carves from the free pool), its priority, when it
//! arrives, and how many steps it runs. A [`JobTrace`] is a replayable
//! queue of jobs — generated from a seed ([`JobTrace::generate`], Poisson
//! inter-arrivals with bursts) or hand-written — that round-trips
//! losslessly through JSON, with the seed as a decimal string exactly
//! like [`crate::elastic::FaultPlan`] so full-range `u64` seeds survive
//! the f64 JSON number space.

use anyhow::{anyhow, bail, Result};

use crate::costmodel::{ModelShape, H2_100B, H2_20B};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Which model a fleet job trains. The fleet layer names models by token
/// rather than embedding a full [`ModelShape`] so traces stay small and
/// human-editable; both paper models are available.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobModel {
    /// The 100B flagship ([`H2_100B`]) — Table 6 / Table 8 scale.
    H100B,
    /// The 20B precision-study model ([`H2_20B`]) — cheap enough for
    /// small sub-clusters.
    H20B,
}

impl JobModel {
    /// The wire token (`"h2-100b"` / `"h2-20b"`).
    pub fn token(&self) -> &'static str {
        match self {
            JobModel::H100B => "h2-100b",
            JobModel::H20B => "h2-20b",
        }
    }

    /// Parse a wire token.
    pub fn parse(text: &str) -> Result<JobModel> {
        match text {
            "h2-100b" => Ok(JobModel::H100B),
            "h2-20b" => Ok(JobModel::H20B),
            other => bail!("unknown job model `{other}` (expected h2-100b or h2-20b)"),
        }
    }

    /// The concrete model shape the inner HeteroAuto solver searches.
    pub fn shape(&self) -> &'static ModelShape {
        match self {
            JobModel::H100B => &H2_100B,
            JobModel::H20B => &H2_20B,
        }
    }
}

/// One training job in the fleet queue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobSpec {
    /// Queue-unique id (also the deterministic tie-breaker everywhere
    /// the scheduler orders jobs).
    pub id: usize,
    /// Which model the job trains.
    pub model: JobModel,
    /// Global batch size in tokens (must be a whole number of the
    /// model's sequences).
    pub gbs_tokens: usize,
    /// Scheduling priority — larger is more urgent. Only the
    /// priority-with-backfill policy looks at it.
    pub priority: u8,
    /// Fleet-clock second the job joins the queue (the fleet clock runs
    /// in modeled seconds; an arrival step is one second).
    pub arrival_step: u64,
    /// Smallest sub-cluster the job accepts, in chips. The scheduler
    /// only ever allocates whole nodes, so the carve may exceed this.
    pub min_chips: usize,
    /// Largest sub-cluster the job can use, in chips.
    pub max_chips: usize,
    /// Training steps the job runs once placed.
    pub steps: u64,
}

impl JobSpec {
    /// The job's display name (`job-<id>`), used for sub-cluster names
    /// and timeline events.
    pub fn name(&self) -> String {
        format!("job-{}", self.id)
    }
}

/// A deterministic, seedable, serializable queue of jobs.
///
/// The `seed` records how a generated trace was derived (and salts
/// [`JobTrace::generate`]); hand-written traces may use any value. Jobs
/// are kept sorted by `(arrival_step, id)` so the trace is replayable
/// byte-for-byte.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobTrace {
    /// Seed the trace was generated from (informational for
    /// hand-written traces).
    pub seed: u64,
    /// The job queue, sorted by `(arrival_step, id)`.
    pub jobs: Vec<JobSpec>,
}

impl JobTrace {
    /// Generate a random trace of `n_jobs` jobs sized for a cluster of
    /// `cluster_chips` chips. Deterministic in `seed`.
    ///
    /// Arrivals are Poisson — exponential inter-arrival gaps with a mean
    /// of 60 fleet seconds, derived from the uniform PRNG as
    /// `-ln(1-u) · mean` — except that with probability ¼ a job starts a
    /// *burst*: the next one or two jobs arrive at the same step, the
    /// paper-cluster reality of a team submitting a sweep at once.
    ///
    /// Sizes are vendor-agnostic fractions of the cluster (1/16, 1/8 or
    /// 1/4 of `cluster_chips`, floored to a multiple of 64 so any
    /// vendor's whole-node carve fits), `max_chips` is 1–2× the minimum,
    /// and jobs needing ≥ 128 chips train the 100B model while smaller
    /// ones train the 20B model (which fits tight memory).
    pub fn generate(seed: u64, n_jobs: usize, cluster_chips: usize) -> JobTrace {
        let mut rng = Rng::new(seed ^ 0xF1EE_70B5_F1EE_70B5);
        let mut jobs = Vec::with_capacity(n_jobs);
        let mut t: u64 = 0;
        let mut burst_left = 0usize;
        for id in 0..n_jobs {
            if burst_left > 0 {
                burst_left -= 1; // same arrival step as the burst head
            } else {
                let u = rng.f64();
                t += (-(1.0 - u).ln() * 60.0).ceil() as u64;
                if rng.usize(0, 4) == 0 {
                    burst_left = rng.usize(1, 3);
                }
            }
            let frac = [16, 8, 4][rng.usize(0, 3)];
            let min_chips = ((cluster_chips / frac) / 64 * 64).max(64);
            let growth = rng.usize(1, 3);
            let max_chips = (min_chips * growth).min(cluster_chips / 64 * 64);
            let model = if min_chips >= 128 { JobModel::H100B } else { JobModel::H20B };
            let seq = model.shape().seq_len;
            let gbs_tokens = [128, 256, 512][rng.usize(0, 3)] * seq;
            jobs.push(JobSpec {
                id,
                model,
                gbs_tokens,
                priority: rng.usize(0, 4) as u8,
                arrival_step: t,
                min_chips,
                max_chips,
                steps: rng.usize(10, 51) as u64,
            });
        }
        jobs.sort_by_key(|j| (j.arrival_step, j.id));
        JobTrace { seed, jobs }
    }

    /// The pinned fleet scenario — the hand-authored trace behind
    /// EXPERIMENTS.md §Fleet, `rust/tests/fleet.rs`, and the
    /// `fleet: exp-mega pinned trace` bench (CLI: `--trace pinned`).
    ///
    /// It is built to make the policy contrast structural rather than
    /// seed-luck: two whole-cluster low-priority jobs arrive back to
    /// back (the second is long), then a burst of eight small
    /// high-priority jobs lands behind them. Under FIFO the second
    /// whole-cluster job blocks the head of the queue, so every small
    /// job's wait includes its long runtime; under priority-with-backfill
    /// the small jobs overtake it (shrinking the incumbent where the
    /// re-planner allows), so the long job's runtime drops out of all
    /// but its own wait — p99 wait falls accordingly.
    pub fn pinned(cluster_chips: usize) -> JobTrace {
        let whole = cluster_chips / 64 * 64;
        let mut jobs = vec![
            JobSpec {
                id: 0,
                model: JobModel::H100B,
                gbs_tokens: 512 * 4096,
                priority: 0,
                arrival_step: 0,
                min_chips: whole,
                max_chips: whole,
                steps: 30,
            },
            JobSpec {
                id: 1,
                model: JobModel::H100B,
                gbs_tokens: 512 * 4096,
                priority: 0,
                arrival_step: 1,
                min_chips: whole,
                max_chips: whole,
                steps: 60,
            },
        ];
        for id in 2..10 {
            jobs.push(JobSpec {
                id,
                model: JobModel::H20B,
                gbs_tokens: 128 * 4096,
                priority: 3,
                arrival_step: 2,
                min_chips: 64,
                max_chips: 64,
                steps: 3,
            });
        }
        JobTrace { seed: 0, jobs }
    }

    /// A fault-script horizon for this trace: the last arrival plus a
    /// generous training window. `h2 fleet --faults <seed>` generates
    /// its [`crate::fleet::ClusterFaultPlan`] over this span so seeded
    /// faults land while jobs are actually running.
    pub fn horizon_seconds(&self) -> f64 {
        self.jobs.iter().map(|j| j.arrival_step).max().unwrap_or(0) as f64 + 600.0
    }

    /// Structural validation: unique ids, sorted arrivals, sane chip
    /// ranges, whole-sequence batches, non-zero step counts.
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        let mut prev = (0u64, 0usize);
        for (i, j) in self.jobs.iter().enumerate() {
            if !seen.insert(j.id) {
                bail!("duplicate job id {}", j.id);
            }
            let key = (j.arrival_step, j.id);
            if i > 0 && key < prev {
                bail!("jobs out of (arrival_step, id) order at job {}", j.id);
            }
            prev = key;
            if j.min_chips == 0 || j.max_chips < j.min_chips {
                bail!("job {}: bad chip range {}..={}", j.id, j.min_chips, j.max_chips);
            }
            if j.gbs_tokens == 0 || j.gbs_tokens % j.model.shape().seq_len != 0 {
                bail!(
                    "job {}: gbs {} is not a whole number of {}-token sequences",
                    j.id, j.gbs_tokens, j.model.shape().seq_len
                );
            }
            if j.steps == 0 {
                bail!("job {}: zero training steps", j.id);
            }
        }
        Ok(())
    }

    /// Serialize (seeds travel as decimal strings, like plan train seeds
    /// and fault-plan seeds, so full-range u64 values survive the f64
    /// JSON number space).
    pub fn to_json(&self) -> Value {
        let jobs = self
            .jobs
            .iter()
            .map(|j| {
                json::obj(vec![
                    ("id", json::num(j.id as f64)),
                    ("model", json::s(j.model.token())),
                    ("gbs_tokens", json::num(j.gbs_tokens as f64)),
                    ("priority", json::num(j.priority as f64)),
                    ("arrival_step", json::num(j.arrival_step as f64)),
                    ("min_chips", json::num(j.min_chips as f64)),
                    ("max_chips", json::num(j.max_chips as f64)),
                    ("steps", json::num(j.steps as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("seed", json::s(&self.seed.to_string())),
            ("jobs", json::arr(jobs)),
        ])
    }

    /// Parse a serialized trace (validates on the way in).
    pub fn from_json(v: &Value) -> Result<JobTrace> {
        let seed = match v.get("seed")? {
            Value::Str(s) => s.parse::<u64>().map_err(|e| anyhow!("bad trace seed `{s}`: {e}"))?,
            other => other.u64()?,
        };
        let mut jobs = Vec::new();
        for j in v.get("jobs")?.arr()? {
            jobs.push(JobSpec {
                id: j.get("id")?.usize()?,
                model: JobModel::parse(j.get("model")?.str()?)?,
                gbs_tokens: j.get("gbs_tokens")?.usize()?,
                priority: u8::try_from(j.get("priority")?.u64()?)
                    .map_err(|_| anyhow!("job priority does not fit in u8"))?,
                arrival_step: j.get("arrival_step")?.u64()?,
                min_chips: j.get("min_chips")?.usize()?,
                max_chips: j.get("max_chips")?.usize()?,
                steps: j.get("steps")?.u64()?,
            });
        }
        let trace = JobTrace { seed, jobs };
        trace.validate()?;
        Ok(trace)
    }

    /// Load a trace from a JSON file (the CLI `--trace <path>` path).
    pub fn load(path: &str) -> Result<JobTrace> {
        JobTrace::from_json(&Value::from_file(path)?)
    }

    /// Write the trace to a JSON file (the CLI `--emit-trace` path).
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .map_err(|e| anyhow!("writing trace `{path}`: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample() -> JobTrace {
        JobTrace {
            seed: u64::MAX - 1, // exercises the decimal-string seed path
            jobs: vec![
                JobSpec {
                    id: 0,
                    model: JobModel::H100B,
                    gbs_tokens: 256 * 4096,
                    priority: 1,
                    arrival_step: 0,
                    min_chips: 128,
                    max_chips: 256,
                    steps: 20,
                },
                JobSpec {
                    id: 1,
                    model: JobModel::H20B,
                    gbs_tokens: 128 * 4096,
                    priority: 3,
                    arrival_step: 40,
                    min_chips: 64,
                    max_chips: 64,
                    steps: 10,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let trace = sample();
        let back = JobTrace::from_json(&trace.to_json()).unwrap();
        assert_eq!(trace, back);
        // And through text, the way a --trace file travels.
        let text = trace.to_json().to_string_pretty();
        let back = JobTrace::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(trace, back);
    }

    #[test]
    fn validation_rejects_bad_traces() {
        assert!(sample().validate().is_ok());
        let mut dup = sample();
        dup.jobs[1].id = 0;
        assert!(dup.validate().is_err(), "duplicate ids");
        let mut range = sample();
        range.jobs[0].max_chips = 1;
        assert!(range.validate().is_err(), "max below min");
        let mut gbs = sample();
        gbs.jobs[0].gbs_tokens = 4097;
        assert!(gbs.validate().is_err(), "ragged batch");
        let mut order = sample();
        order.jobs.swap(0, 1);
        assert!(order.validate().is_err(), "arrival order");
    }

    #[test]
    fn generated_traces_are_deterministic_valid_and_roundtrip() {
        prop::check(50, |rng| {
            let seed = rng.next_u64();
            let n = rng.usize(1, 16);
            let chips = 64 * rng.usize(4, 21);
            let a = JobTrace::generate(seed, n, chips);
            let b = JobTrace::generate(seed, n, chips);
            prop::assert_prop(a == b, "generation must be deterministic in the seed")?;
            prop::assert_prop(a.jobs.len() == n, "job count")?;
            prop::assert_prop(a.validate().is_ok(), format!("invalid: {a:?}"))?;
            prop::assert_prop(
                a.jobs.iter().all(|j| j.max_chips <= chips && j.min_chips % 64 == 0),
                "sizes fit the cluster on whole-node boundaries",
            )?;
            let back = JobTrace::from_json(&a.to_json())
                .map_err(|e| format!("reparse failed: {e}"))?;
            prop::assert_prop(a == back, "JSON round-trip must be lossless")
        });
    }
}
