//! Mini property-based testing harness (proptest is not in the vendor set).
//!
//! Runs a property over many PRNG-derived cases; on failure it reports the
//! seed and case index so the case can be replayed deterministically:
//!
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.usize(1, 100);
//!     let xs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
//!     prop::assert_prop(invariant(&xs), "invariant violated")
//! });
//! ```

use super::rng::Rng;

/// Result of one property case.
pub type CaseResult = Result<(), String>;

/// Assert helper returning a `CaseResult`.
pub fn assert_prop(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond { Ok(()) } else { Err(msg.into()) }
}

/// Assert two f64s are within tolerance.
pub fn assert_close(a: f64, b: f64, tol: f64, ctx: &str) -> CaseResult {
    let denom = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} !~ {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of `property` with a fixed master seed.
pub fn check<F>(cases: usize, property: F)
where
    F: Fn(&mut Rng) -> CaseResult,
{
    check_seeded(0xC0FFEE, cases, property)
}

/// Same, with an explicit seed (printed on failure for replay).
pub fn check_seeded<F>(seed: u64, cases: usize, property: F)
where
    F: Fn(&mut Rng) -> CaseResult,
{
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let mut rng = master.fork(case as u64);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed (seed={seed:#x}, case={case}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |rng| {
            let a = rng.usize(0, 1000);
            let b = rng.usize(0, 1000);
            assert_prop(a + b == b + a, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(50, |rng| {
            assert_prop(rng.usize(0, 10) < 5, "will eventually fail")
        });
    }

    #[test]
    fn assert_close_relative() {
        assert!(assert_close(1000.0, 1000.5, 1e-3, "x").is_ok());
        assert!(assert_close(1.0, 2.0, 1e-3, "x").is_err());
    }
}
