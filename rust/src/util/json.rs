//! Minimal JSON parser/serializer (the offline vendor set has no serde).
//!
//! Parses the full JSON grammar (RFC 8259) into a dynamic [`Value`]; used for
//! `artifacts/manifest.json`, cluster/experiment config files, and report
//! output. Accessors return `anyhow::Result` with path-aware messages so a
//! malformed manifest fails loudly at load time, not deep in training.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
/// A dynamically-typed JSON value.
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64, like the grammar).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object (sorted keys).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Read and parse a JSON file with path context.
    pub fn from_file(path: &str) -> Result<Value> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Value::parse(&text).with_context(|| format!("parsing {path}"))
    }

    // -- typed accessors ---------------------------------------------------

    /// Required object key, with a `missing key` error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("expected object while looking up `{key}`"),
        }
    }

    /// Optional object key.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, or a type error.
    pub fn str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The numeric payload, or a type error.
    pub fn num(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The payload as a non-negative integer, or an error.
    pub fn u64(&self) -> Result<u64> {
        let n = self.num()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as u64)
    }

    /// The payload as a usize, or an error.
    pub fn usize(&self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    /// The boolean payload, or a type error.
    pub fn bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// The array payload, or a type error.
    pub fn arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// The object payload, or a type error.
    pub fn obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // -- serialization -----------------------------------------------------

    /// Serialize with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 { out.push(','); }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() { pad(out, indent); }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 { out.push(','); }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() { pad(out, indent); }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by report/serialization code.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// An array value from items.
pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

/// A numeric value.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}

/// A string value.
pub fn s(text: &str) -> Value {
    Value::Str(text.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`",
                  c as char, self.i, self.b[self.i] as char);
        }
        self.i += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character `{}` at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => { self.i += 1; }
                b'}' => { self.i += 1; return Ok(Value::Obj(m)); }
                c => bail!("expected `,` or `}}`, found `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => { self.i += 1; }
                b']' => { self.i += 1; return Ok(Value::Arr(v)); }
                c => bail!("expected `,` or `]`, found `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate"))?);
                            } else {
                                out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        c => bail!("bad escape `\\{}`", c as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                b0 => {
                    // multi-byte UTF-8: char length from the leading byte
                    let start = self.i - 1;
                    let len = match b0 {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => bail!("invalid utf-8 lead byte at {start}"),
                    };
                    let end = start + len;
                    if end > self.b.len() {
                        bail!("truncated utf-8 at byte {start}");
                    }
                    let ch = std::str::from_utf8(&self.b[start..end])
                        .ok()
                        .and_then(|s| s.chars().next())
                        .ok_or_else(|| anyhow!("invalid utf-8 at byte {start}"))?;
                    out.push(ch);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            let d = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => bail!("bad hex digit"),
            };
            v = v * 16 + d as u32;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' { self.i += 1; }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>().with_context(|| format!("bad number `{text}`"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(Value::parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().str().unwrap(), "x");
    }

    #[test]
    fn escapes_roundtrip() {
        let src = Value::Str("a\"b\\c\nd\tü€".into());
        let text = src.to_string_pretty();
        assert_eq!(Value::parse(&text).unwrap(), src);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Value::parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(Value::parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Value::parse("{} x").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj(vec![
            ("x", num(3.0)),
            ("list", arr(vec![num(1.0), s("two"), Value::Bool(false)])),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(Value::parse(&text).unwrap(), v);
    }

    #[test]
    fn integer_formatting_stays_integral() {
        assert_eq!(num(42.0).to_string_pretty(), "42");
    }

    #[test]
    fn u64_rejects_fractions() {
        assert!(Value::Num(1.5).u64().is_err());
        assert_eq!(Value::Num(7.0).u64().unwrap(), 7);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(v) = Value::from_file("artifacts/manifest.json") {
            assert!(v.get("models").is_ok());
        }
    }
}
