//! Deterministic PRNGs (SplitMix64 seeding + xoshiro256** core).
//!
//! The offline vendor set has no `rand` crate, so the repository carries its
//! own generator. Everything that needs randomness (synthetic corpora,
//! perturbation models, property tests, workload generators) goes through
//! this module so runs are reproducible from a single `u64` seed.

/// xoshiro256** — fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 (as recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-worker/per-test seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [lo, hi).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal f32 with the given mean and standard deviation.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(0, std) floats.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Random token ids in [0, vocab).
    pub fn tokens(&mut self, n: usize, vocab: u32) -> Vec<i32> {
        (0..n).map(|_| (self.next_u64() % vocab as u64) as i32).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
