//! Small statistics helpers shared by the bench harness and reports.

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

/// Arithmetic mean (NaN for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return f64::NAN; }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 { return 0.0; }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() { return f64::NAN; }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi { return sorted[lo]; }
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Five-number summary of a sample.
pub fn summarize(xs: &[f64]) -> Summary {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: stddev(xs),
        min: *sorted.first().unwrap_or(&f64::NAN),
        p50: percentile(&sorted, 0.5),
        p90: percentile(&sorted, 0.9),
        p99: percentile(&sorted, 0.99),
        max: *sorted.last().unwrap_or(&f64::NAN),
    }
}

/// Mean Relative Error — the paper's Fig 5 / Table 1 alignment criterion:
/// `(1/n) Σ |y_i − ŷ_i| / |y_i|`.
pub fn mean_relative_error(reference: &[f64], measured: &[f64]) -> f64 {
    assert_eq!(reference.len(), measured.len());
    assert!(!reference.is_empty());
    let mut acc = 0.0;
    for (y, yhat) in reference.iter().zip(measured) {
        acc += ((y - yhat) / y).abs();
    }
    acc / reference.len() as f64
}

/// Geometric mean (used for Fig 7's "average 9.94× speedup" style claims).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return f64::NAN; }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summary() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mre_matches_hand_computation() {
        // reference 2.0 vs measured 2.02 -> 1%; 4.0 vs 3.96 -> 1%.
        let m = mean_relative_error(&[2.0, 4.0], &[2.02, 3.96]);
        assert!((m - 0.01).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[3.0, 3.0, 3.0]), 0.0);
    }
}
