//! Small non-cryptographic hashes (the offline vendor set has no hash
//! crates).

/// FNV-1a over a byte stream — the crate's one stable fingerprint/tag
/// hash (custom-chip seed tags, the virtual evaluator's parameter
/// fingerprint printed by `h2 train --virtual`).
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_published_vectors() {
        // The standard 64-bit FNV-1a test vectors.
        assert_eq!(fnv1a(std::iter::empty()), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(*b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar".iter().copied()), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn order_sensitive() {
        assert_ne!(fnv1a(*b"ab"), fnv1a(*b"ba"));
    }
}
