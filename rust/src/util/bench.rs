//! In-tree micro-benchmark harness (criterion is not in the vendor set).
//!
//! `cargo bench` targets use [`Bench`] for warmup + repeated timing with
//! robust statistics, printing one row per benchmark. Used both for the
//! paper-table benches (which mostly report *model* outputs) and for the
//! §Perf hot-path timings.

use std::time::Instant;

use super::stats::{summarize, Summary};
use super::table::{fmt_duration, Table};

/// A named set of repeated-timing micro-benchmarks.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    min_iters: usize,
    max_seconds: f64,
    rows: Vec<(String, Summary)>,
}

impl Bench {
    /// A bench set with default warmup/iteration budgets.
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup_iters: 3,
            min_iters: 10,
            max_seconds: 5.0,
            rows: Vec::new(),
        }
    }

    /// Set warmup iterations per case.
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Set the minimum timed iterations per case.
    pub fn min_iters(mut self, n: usize) -> Self {
        self.min_iters = n;
        self
    }

    /// Set the wall-clock budget per case, seconds.
    pub fn max_seconds(mut self, s: f64) -> Self {
        self.max_seconds = s;
        self
    }

    /// Time `f` repeatedly; returns the summary (seconds per iteration).
    pub fn run<F: FnMut()>(&mut self, label: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.max_seconds && samples.len() < 10_000)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if start.elapsed().as_secs_f64() > self.max_seconds && samples.len() >= self.min_iters {
                break;
            }
        }
        let s = summarize(&samples);
        self.rows.push((label.to_string(), s.clone()));
        s
    }

    /// Recorded `(label, summary)` rows, in run order — the machine-facing
    /// view the perf-regression guard compares against `BENCH_baseline.json`.
    pub fn rows(&self) -> &[(String, Summary)] {
        &self.rows
    }

    /// Render all recorded timings as a table.
    pub fn report(&self) {
        let mut t = Table::new(&["benchmark", "iters", "mean", "p50", "p90", "max"])
            .with_title(&format!("== {} ==", self.name));
        for (label, s) in &self.rows {
            t.row(vec![
                label.clone(),
                s.n.to_string(),
                fmt_duration(s.mean),
                fmt_duration(s.p50),
                fmt_duration(s.p90),
                fmt_duration(s.max),
            ]);
        }
        t.print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let mut b = Bench::new("t").warmup(1).min_iters(5).max_seconds(0.05);
        let s = b.run("noop", || {});
        assert!(s.n >= 5);
        assert!(s.mean >= 0.0);
        b.report();
    }
}
