//! ASCII table rendering for bench/report output (paper-style tables).

/// A simple left/right-aligned column table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    /// Add a title line above the table.
    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render to a string with padded columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Format a byte count (B/KiB/MiB/GiB).
pub fn fmt_bytes(bytes: f64) -> String {
    const K: f64 = 1024.0;
    if bytes < K {
        format!("{bytes:.0}B")
    } else if bytes < K * K {
        format!("{:.1}KiB", bytes / K)
    } else if bytes < K * K * K {
        format!("{:.1}MiB", bytes / (K * K))
    } else {
        format!("{:.2}GiB", bytes / (K * K * K))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| long-name |"));
        assert!(s.lines().all(|l| l.len() == s.lines().next().unwrap().len() || !l.starts_with('|')));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0025), "2.50ms");
        assert_eq!(fmt_duration(2.5e-7), "250.0ns");
    }

    #[test]
    fn byte_units() {
        assert_eq!(fmt_bytes(512.0), "512B");
        assert_eq!(fmt_bytes(64.0 * 1024.0 * 1024.0), "64.0MiB");
    }
}
