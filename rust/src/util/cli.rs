//! Tiny CLI argument parser (the offline vendor set has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default, Clone)]
/// Parsed command line: positionals plus `--key[=value]` flags.
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key` flags and their values.
    pub flags: BTreeMap<String, String>,
}

/// Sentinel value stored for value-less `--flag` switches.
pub const FLAG_SET: &str = "true";

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    let takes_value = it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    if takes_value {
                        out.flags.insert(rest.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(rest.to_string(), FLAG_SET.to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (argv[0] excluded).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether a flag was passed at all.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// A flag's raw value, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer flag with a default; errors on non-numeric input.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got `{v}`")),
        }
    }

    /// u64 flag with a default; errors on non-numeric input.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got `{v}`")),
        }
    }

    /// Float flag with a default; errors on non-numeric input.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got `{v}`")),
        }
    }

    /// A flag that must be present, with a helpful error.
    pub fn required(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{key}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["train", "--steps", "100", "--lr=0.001", "--verbose", "--out", "x.json"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.001);
        assert!(a.has("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn trailing_flag_has_sentinel_value() {
        let a = parse(&["--fast"]);
        assert_eq!(a.get("fast"), Some(FLAG_SET));
    }

    #[test]
    fn bad_int_errors() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn missing_required_errors() {
        let a = parse(&[]);
        assert!(a.required("model").is_err());
    }
}
