//! Infrastructure substrates built in-tree (no external crates available):
//! PRNG, statistics, JSON, CLI parsing, table rendering, micro-bench harness
//! and a small property-testing framework.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
