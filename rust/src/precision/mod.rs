//! DiTorch precision-alignment tooling (§3.1.2, Figure 5, Table 1).
//!
//! Different vendors implement the same operator with different data
//! layouts and accumulation orders, so identical training runs diverge
//! numerically chip by chip. DiTorch's pipeline (a) models/detects those
//! operator-level differences, (b) checks *model-level* alignment with the
//! Mean Relative Error of the training-loss curve against the A100
//! reference, accepting MRE < 1.5%.
//!
//! Here the vendor stacks are simulated: each chip kind carries an
//! `op_noise` scale (chip catalog) and [`Perturbation`] injects
//! accumulation-order-like relative noise into gradients during real
//! training runs driven by the coordinator. The tooling — MRE checker,
//! overflow detector, operator comparator — is the DiTorch deliverable.

use crate::hetero::{spec, ChipKind};
use crate::util::rng::Rng;
use crate::util::stats::mean_relative_error;

/// The paper's model-level alignment criterion (§3.1.2).
pub const MRE_THRESHOLD: f64 = 0.015;

/// Simulated vendor-stack numerics for one chip kind.
#[derive(Clone, Debug)]
pub struct Perturbation {
    /// The chip kind whose vendor stack is being simulated.
    pub kind: ChipKind,
    /// Relative per-element gradient noise scale (accumulation-order model).
    pub rel_noise: f64,
    rng: Rng,
}

impl Perturbation {
    /// Vendor-stack noise for `kind`, deterministic in `seed`.
    pub fn new(kind: ChipKind, seed: u64) -> Self {
        Perturbation { kind, rel_noise: spec(kind).op_noise, rng: Rng::new(seed ^ kind.seed_tag()) }
    }

    /// Perturb a gradient tensor in place: g ← g·(1 + ε·ξ), ξ ~ N(0,1).
    /// The A100 reference (op_noise = 0) is a strict no-op.
    ///
    /// ξ is drawn once per *tensor*, not per element: vendor operator
    /// discrepancies are systematic (data layout and accumulation order bias
    /// a whole matmul the same way), so the faithful model is correlated
    /// noise. Per-element iid noise averages out over millions of weights
    /// and produces no measurable trajectory divergence.
    pub fn apply(&mut self, grads: &mut [f32]) {
        if self.rel_noise == 0.0 {
            return;
        }
        let factor = 1.0 + self.rel_noise as f32 * self.rng.normal() as f32;
        for g in grads.iter_mut() {
            *g *= factor;
        }
    }

    /// Perturb a scalar the chip *computed* (e.g. the reported loss): the
    /// forward pass itself runs on vendor numerics, so the measured metric
    /// carries the operator noise directly — this is the dominant term in
    /// the paper's loss-curve MRE.
    pub fn perturb_scalar(&mut self, x: f64) -> f64 {
        if self.rel_noise == 0.0 {
            return x;
        }
        x * (1.0 + self.rel_noise * self.rng.normal())
    }

    /// Apply per-tensor perturbation across a stage's gradient list.
    pub fn apply_tensors(&mut self, grads: &mut [crate::runtime::HostTensor]) {
        if self.rel_noise == 0.0 {
            return;
        }
        for t in grads.iter_mut() {
            if let Ok(data) = t.as_f32_mut() {
                let factor = 1.0 + self.rel_noise as f32 * self.rng.normal() as f32;
                for g in data.iter_mut() {
                    *g *= factor;
                }
            }
        }
    }
}

/// Verdict of the model-level alignment check.
#[derive(Clone, Debug)]
pub struct AlignmentReport {
    /// The chip whose alignment was checked.
    pub kind: ChipKind,
    /// Mean relative error of the loss curve.
    pub mre: f64,
    /// Whether the MRE is under the 1.5% criterion.
    pub aligned: bool,
    /// Loss-curve length compared.
    pub n_iterations: usize,
}

/// Fig 5 / Table 1: MRE of a chip's loss curve against the A100 reference.
pub fn check_alignment(kind: ChipKind, reference: &[f64], measured: &[f64]) -> AlignmentReport {
    let mre = mean_relative_error(reference, measured);
    AlignmentReport { kind, mre, aligned: mre < MRE_THRESHOLD, n_iterations: reference.len() }
}

/// Overflow/NaN detector (DiTorch's per-operator debugging tool).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OverflowReport {
    /// NaN elements seen.
    pub n_nan: usize,
    /// Infinite elements seen.
    pub n_inf: usize,
    /// Largest finite magnitude seen.
    pub max_abs: f32,
}

/// Scan a tensor for NaN/Inf and the largest finite magnitude.
pub fn detect_overflow(xs: &[f32]) -> OverflowReport {
    let mut r = OverflowReport::default();
    for &x in xs {
        if x.is_nan() {
            r.n_nan += 1;
        } else if x.is_infinite() {
            r.n_inf += 1;
        } else {
            r.max_abs = r.max_abs.max(x.abs());
        }
    }
    r
}

/// Operator-level comparator: element-wise relative error summary between a
/// vendor operator's output and the reference implementation's.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpDiff {
    /// Worst element-wise relative error.
    pub max_rel: f64,
    /// Mean element-wise relative error.
    pub mean_rel: f64,
    /// Elements compared.
    pub n: usize,
}

/// Element-wise relative-error summary of a vendor op against the reference.
pub fn compare_operator(reference: &[f32], vendor: &[f32]) -> OpDiff {
    assert_eq!(reference.len(), vendor.len());
    let mut max_rel = 0.0f64;
    let mut sum = 0.0f64;
    for (&r, &v) in reference.iter().zip(vendor) {
        let denom = (r.abs() as f64).max(1e-12);
        let rel = ((r - v).abs() as f64) / denom;
        max_rel = max_rel.max(rel);
        sum += rel;
    }
    OpDiff { max_rel, mean_rel: sum / reference.len().max(1) as f64, n: reference.len() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_perturbation_is_identity() {
        let mut p = Perturbation::new(ChipKind::A100, 1);
        let mut g = vec![1.0f32, -2.0, 3.5];
        let orig = g.clone();
        p.apply(&mut g);
        assert_eq!(g, orig);
    }

    #[test]
    fn perturbation_scale_matches_catalog() {
        // Per-tensor correlated noise: repeated applications have stddev
        // equal to the catalog's op_noise.
        let mut p = Perturbation::new(ChipKind::D, 2);
        let n = 20_000;
        let mut factors = Vec::with_capacity(n);
        for _ in 0..n {
            let mut g = vec![1.0f32];
            p.apply(&mut g);
            factors.push((g[0] - 1.0) as f64);
        }
        let std = crate::util::stats::stddev(&factors);
        let expect = spec(ChipKind::D).op_noise;
        assert!((std - expect).abs() / expect < 0.05, "std {std} vs {expect}");
    }

    #[test]
    fn perturbation_deterministic_per_seed() {
        let mut a = Perturbation::new(ChipKind::B, 7);
        let mut b = Perturbation::new(ChipKind::B, 7);
        let mut ga = vec![1.0f32; 64];
        let mut gb = vec![1.0f32; 64];
        a.apply(&mut ga);
        b.apply(&mut gb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn alignment_threshold() {
        let reference = vec![2.0; 300];
        let close: Vec<f64> = reference.iter().map(|x| x * 1.005).collect();
        let far: Vec<f64> = reference.iter().map(|x| x * 1.02).collect();
        assert!(check_alignment(ChipKind::A, &reference, &close).aligned);
        assert!(!check_alignment(ChipKind::D, &reference, &far).aligned);
    }

    #[test]
    fn overflow_detection() {
        let r = detect_overflow(&[1.0, f32::NAN, f32::INFINITY, -5.0]);
        assert_eq!(r.n_nan, 1);
        assert_eq!(r.n_inf, 1);
        assert_eq!(r.max_abs, 5.0);
    }

    #[test]
    fn operator_comparator() {
        let d = compare_operator(&[1.0, 2.0], &[1.01, 2.0]);
        assert!((d.max_rel - 0.01).abs() < 1e-6);
        assert!((d.mean_rel - 0.005).abs() < 1e-6);
    }
}
