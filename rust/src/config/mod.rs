//! JSON-driven configuration for clusters, experiments and training jobs.
//!
//! The CLI accepts `--config <file.json>` anywhere it accepts inline flags;
//! this module is the typed layer over [`crate::util::json`]. Example:
//!
//! ```json
//! {
//!   "cluster": { "name": "lab", "groups": [{"chip": "A", "chips": 256},
//!                                           {"chip": "B", "chips": 256}] },
//!   "gbs_tokens": 2097152,
//!   "train": {
//!     "model": "h2_100m",
//!     "stages": [{"prefix": "first_l10", "chip": "A"},
//!                {"prefix": "last_l6", "chip": "B"}],
//!     "dp": 1, "micro_batches": 2, "steps": 100, "lr": 4e-4,
//!     "comm": "ddr", "fine_overlap": true
//!   }
//! }
//! ```

use anyhow::{anyhow, Context, Result};

use crate::comm::CommMode;
use crate::coordinator::{StagePlan, TrainConfig};
use crate::hetero::{ChipKind, Cluster};
use crate::topology::NicAssignment;
use crate::util::json::Value;

/// Top-level config file.
#[derive(Clone, Debug)]
pub struct Config {
    pub cluster: Option<Cluster>,
    pub gbs_tokens: Option<usize>,
    pub train: Option<TrainConfig>,
}

fn parse_chip(v: &Value) -> Result<ChipKind> {
    let s = v.str()?;
    ChipKind::parse(s).ok_or_else(|| anyhow!("unknown chip `{s}`"))
}

fn parse_cluster(v: &Value) -> Result<Cluster> {
    let name = v.opt("name").map(|n| n.str().map(str::to_string)).transpose()?
        .unwrap_or_else(|| "config".to_string());
    let mut groups = Vec::new();
    for g in v.get("groups")?.arr()? {
        groups.push((parse_chip(g.get("chip")?)?, g.get("chips")?.usize()?));
    }
    Ok(Cluster::new(&name, groups))
}

fn parse_train(v: &Value) -> Result<TrainConfig> {
    let mut stages = Vec::new();
    for s in v.get("stages")?.arr()? {
        stages.push(StagePlan {
            prefix: s.get("prefix")?.str()?.to_string(),
            chip: parse_chip(s.get("chip")?)?,
        });
    }
    let comm = match v.opt("comm") {
        Some(c) => {
            let text = c.str()?;
            CommMode::parse(text).ok_or_else(|| anyhow!("bad comm `{text}`"))?
        }
        None => CommMode::DeviceDirect,
    };
    let get_usize = |key: &str, default: usize| -> Result<usize> {
        v.opt(key).map(|x| x.usize()).transpose().map(|o| o.unwrap_or(default))
    };
    Ok(TrainConfig {
        model: v.get("model")?.str()?.to_string(),
        stages,
        dp: get_usize("dp", 1)?,
        micro_batches: get_usize("micro_batches", 2)?,
        steps: get_usize("steps", 20)?,
        lr: v.opt("lr").map(|x| x.num()).transpose()?.unwrap_or(1e-3) as f32,
        seed: v.opt("seed").map(|x| x.u64()).transpose()?.unwrap_or(42),
        comm,
        nic_assignment: match v.opt("nic_affinity").map(|x| x.bool()).transpose()? {
            Some(false) => NicAssignment::NonAffinity,
            _ => NicAssignment::Affinity,
        },
        fine_overlap: v.opt("fine_overlap").map(|x| x.bool()).transpose()?.unwrap_or(true),
        perturb: v.opt("perturb").map(|x| x.bool()).transpose()?.unwrap_or(false),
        log_every: get_usize("log_every", 10)?,
    })
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let v = Value::parse(text)?;
        Ok(Config {
            cluster: v.opt("cluster").map(parse_cluster).transpose()
                .context("parsing `cluster`")?,
            gbs_tokens: v.opt("gbs_tokens").map(|x| x.usize()).transpose()?,
            train: v.opt("train").map(parse_train).transpose()
                .context("parsing `train`")?,
        })
    }

    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Config::parse(&text).with_context(|| format!("parsing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"{
        "cluster": {"name": "lab", "groups": [{"chip": "A", "chips": 256},
                                               {"chip": "B", "chips": 512}]},
        "gbs_tokens": 2097152,
        "train": {"model": "h2_100m",
                  "stages": [{"prefix": "first_l10", "chip": "A"},
                             {"prefix": "last_l6", "chip": "B"}],
                  "dp": 2, "micro_batches": 4, "steps": 50, "lr": 0.0004,
                  "comm": "tcp", "fine_overlap": false, "nic_affinity": false}
    }"#;

    #[test]
    fn full_config_parses() {
        let c = Config::parse(FULL).unwrap();
        let cluster = c.cluster.unwrap();
        assert_eq!(cluster.total_chips(), 768);
        assert_eq!(c.gbs_tokens, Some(2097152));
        let t = c.train.unwrap();
        assert_eq!(t.model, "h2_100m");
        assert_eq!(t.stages.len(), 2);
        assert_eq!(t.dp, 2);
        assert_eq!(t.comm, crate::comm::CommMode::TcpCpu);
        assert!(!t.fine_overlap);
        assert_eq!(t.nic_assignment, crate::topology::NicAssignment::NonAffinity);
        assert!((t.lr - 4e-4).abs() < 1e-9);
    }

    #[test]
    fn defaults_fill_in() {
        let c = Config::parse(r#"{"train": {"model": "h2_tiny",
            "stages": [{"prefix": "first_l2", "chip": "A"},
                       {"prefix": "last_l2", "chip": "B"}]}}"#).unwrap();
        let t = c.train.unwrap();
        assert_eq!(t.dp, 1);
        assert_eq!(t.steps, 20);
        assert_eq!(t.comm, crate::comm::CommMode::DeviceDirect);
        assert!(t.fine_overlap);
    }

    #[test]
    fn bad_chip_errors() {
        let e = Config::parse(r#"{"cluster": {"groups": [{"chip": "Z", "chips": 8}]}}"#);
        assert!(e.is_err());
    }

    #[test]
    fn empty_config_is_fine() {
        let c = Config::parse("{}").unwrap();
        assert!(c.cluster.is_none() && c.train.is_none());
    }
}
