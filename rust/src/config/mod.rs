//! JSON-driven configuration: the front-end that lowers into the
//! plan-centric API.
//!
//! Every CLI subcommand accepts `--config <file.json>`; this module is the
//! typed layer over [`crate::util::json`]. A config can declare **custom
//! chips** (registered into the [`crate::hetero`] catalog at parse time, so
//! new cluster scenarios need no recompilation), a cluster, a global batch,
//! search and simulation options, and a train section. [`Config::plan_builder`]
//! lowers all of it into a [`crate::plan::PlanBuilder`]; the search CLI adds
//! the strategy and persists the resulting [`crate::plan::ExecutionPlan`].
//!
//! ```json
//! {
//!   "chips": [ { "name": "H9", "fp16_tflops": 300, "memory_gib": 80,
//!                "chips_per_node": 8,
//!                "intra_node": {"type": "uniform", "gbps": 300},
//!                "nics_per_node": 8, "nic_gbps": 25, "mfu": 0.5 } ],
//!   "cluster": { "name": "lab", "groups": [{"chip": "H9", "chips": 256},
//!                                           {"chip": "B", "chips": 256}] },
//!   "gbs_tokens": 2097152,
//!   "search": { "schedules": ["1f1b", "interleaved:2", "zbv"],
//!               "comm_algos": ["ring", "hierarchical"],
//!               "group_split": 128, "two_stage": true },
//!   "sim": { "comm": "ddr", "reshard": "srag", "comm_algo": "auto",
//!            "nic_affinity": true, "fine_overlap": true },
//!   "elastic": { "straggler_factor": 1.5, "debounce": 3,
//!                "keep_last": 4, "faults": "faults.json" },
//!   "train": {
//!     "model": "h2_100m",
//!     "stages": [{"prefix": "first_l10", "chip": "A"},
//!                {"prefix": "last_l6", "chip": "B"}],
//!     "dp": 1, "micro_batches": 2, "steps": 100, "lr": 4e-4,
//!     "schedule": "zbv", "comm_algo": "hierarchical",
//!     "comm": "ddr", "fine_overlap": true
//!   }
//! }
//! ```

use anyhow::{anyhow, Context, Result};

use crate::auto::SearchConfig;
use crate::comm::{CommAlgo, CommMode};
use crate::coordinator::{StagePlan, TrainConfig};
use crate::costmodel::Schedule;
use crate::elastic::MonitorConfig;
use crate::hetero::{register_custom, Cluster, CustomChipDef};
use crate::plan::{
    chip_def_from_json, parse_kind, parse_token, PlanBuilder, PrecisionPolicy, TrainSpec,
};
use crate::sim::{ReshardStrategy, SimOptions};
use crate::topology::NicAssignment;
use crate::util::json::Value;

/// Top-level config file.
#[derive(Clone, Debug)]
pub struct Config {
    /// Custom chips declared by this config (already registered).
    pub chips: Vec<CustomChipDef>,
    /// Cluster composition, if declared.
    pub cluster: Option<Cluster>,
    /// Global batch size in tokens, if declared.
    pub gbs_tokens: Option<usize>,
    /// HeteroAuto options, if declared.
    pub search: Option<SearchConfig>,
    /// An *explicitly* pinned DP-collective algorithm from the search
    /// section (`comm_algo` token, or a one-entry `comm_algos` list).
    /// Kept separate from [`SearchConfig::comm_algos`] because the default
    /// space is already the singleton `auto` — without this flag an
    /// explicit `"comm_algo": "auto"` pin would be indistinguishable from
    /// "nothing declared" when lowering into a plan.
    pub comm_algo_pin: Option<CommAlgo>,
    /// Simulation overrides, if declared.
    pub sim: Option<SimOverrides>,
    /// Elastic-loop options, if declared.
    pub elastic: Option<ElasticConfig>,
    /// Fleet-scheduler options, if declared.
    pub fleet: Option<FleetConfig>,
    /// Real-training job, if declared.
    pub train: Option<TrainConfig>,
}

/// The config's `elastic` section: step-monitor thresholds plus the
/// virtual evaluator's fault-replay and checkpoint-retention knobs. Every
/// key is optional; CLI flags override whatever the section sets.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ElasticConfig {
    /// [`MonitorConfig::straggler_factor`] override.
    pub straggler_factor: Option<f64>,
    /// [`MonitorConfig::debounce`] override.
    pub debounce: Option<usize>,
    /// Checkpoint retention for virtual runs
    /// ([`crate::coordinator::VirtualOptions::keep_last`]).
    pub keep_last: Option<usize>,
    /// Path of a fault-injection plan to replay
    /// ([`crate::elastic::FaultPlan`]).
    pub faults: Option<String>,
}

impl ElasticConfig {
    /// Monitor thresholds: the defaults with this section's keys applied.
    pub fn monitor_config(&self) -> MonitorConfig {
        let d = MonitorConfig::default();
        MonitorConfig {
            straggler_factor: self.straggler_factor.unwrap_or(d.straggler_factor),
            debounce: self.debounce.unwrap_or(d.debounce),
        }
    }
}

/// The config's `fleet` section: defaults for `h2 fleet`. Every key is
/// optional; CLI flags override whatever the section sets.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetConfig {
    /// Queue policy ([`crate::fleet::Policy`] token: `fifo` / `priority`).
    pub policy: Option<crate::fleet::Policy>,
    /// Path of a trace file to run (`--trace` overrides).
    pub trace: Option<String>,
    /// Generator seed when no trace file is given.
    pub seed: Option<u64>,
    /// Generated trace length in jobs.
    pub jobs: Option<usize>,
    /// Worker threads for the batched plan-pricing pass (0 = per core).
    pub workers: Option<usize>,
    /// Cluster fault script: a JSON file path, a decimal generator seed,
    /// or `pinned` (same grammar as `h2 fleet --faults`, which
    /// overrides).
    pub faults: Option<String>,
}

/// Partial overrides for [`SimOptions`]: only keys actually present in the
/// config's `sim` section are applied, so overlaying a config onto a loaded
/// plan never silently resets fields the section doesn't mention.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimOverrides {
    /// Communication strategy override.
    pub comm: Option<CommMode>,
    /// DP-collective algorithm override. Unlike the other keys this lands
    /// on the plan's *strategy* (where the algorithm travels), not on
    /// [`SimOptions`] — see `apply_sim_overrides` in the CLI and
    /// [`crate::config::Config::plan_builder`].
    pub comm_algo: Option<CommAlgo>,
    /// Resharding strategy override.
    pub reshard: Option<ReshardStrategy>,
    /// NIC affinity on/off override.
    pub nic_affinity: Option<bool>,
    /// Fine-grained overlap override.
    pub fine_overlap: Option<bool>,
}

impl SimOverrides {
    /// Apply only the keys this override set actually carries.
    pub fn apply(&self, opts: &mut SimOptions) {
        if let Some(c) = self.comm {
            opts.comm = c;
        }
        if let Some(r) = self.reshard {
            opts.reshard = r;
        }
        if let Some(a) = self.nic_affinity {
            opts.nic_assignment =
                if a { NicAssignment::Affinity } else { NicAssignment::NonAffinity };
        }
        if let Some(f) = self.fine_overlap {
            opts.fine_overlap = f;
        }
    }
}

fn parse_cluster(v: &Value) -> Result<Cluster> {
    let name = v.opt("name").map(|n| n.str().map(str::to_string)).transpose()?
        .unwrap_or_else(|| "config".to_string());
    let mut groups = Vec::new();
    for g in v.get("groups")?.arr()? {
        groups.push((parse_kind(g.get("chip")?)?, g.get("chips")?.usize()?));
    }
    Cluster::try_build(&name, groups)
}

fn parse_search(v: &Value) -> Result<SearchConfig> {
    let d = SearchConfig::default();
    // Collective-algorithm selection mirrors the schedule keys:
    // `comm_algos` (list) > `comm_algo` (single token) > the default
    // (the topology-aware auto selector).
    let comm_algos = if let Some(list) = v.opt("comm_algos") {
        let mut out = Vec::new();
        for a in list.arr()? {
            out.push(parse_token(a, "comm_algos", CommAlgo::parse)?);
        }
        if out.is_empty() {
            anyhow::bail!("`comm_algos` must name at least one algorithm");
        }
        out
    } else if let Some(tok) = v.opt("comm_algo") {
        vec![parse_token(tok, "comm_algo", CommAlgo::parse)?]
    } else {
        d.comm_algos.clone()
    };
    // Schedule selection, most specific key wins: `schedules` (list of
    // tokens) > `schedule` (single token) > legacy `alpha` (mapped through
    // `Schedule::from_alpha`) > the full default search space.
    let schedules = if let Some(list) = v.opt("schedules") {
        let mut out = Vec::new();
        for s in list.arr()? {
            out.push(parse_token(s, "schedules", Schedule::parse)?);
        }
        if out.is_empty() {
            anyhow::bail!("`schedules` must name at least one schedule");
        }
        out
    } else if let Some(tok) = v.opt("schedule") {
        vec![parse_token(tok, "schedule", Schedule::parse)?]
    } else if let Some(alpha) = v.opt("alpha") {
        vec![Schedule::from_alpha(alpha.num()?)]
    } else {
        d.schedules.clone()
    };
    Ok(SearchConfig {
        schedules,
        comm_algos,
        group_split: v.opt("group_split").map(|x| x.usize()).transpose()?
            .unwrap_or(d.group_split),
        two_stage: v.opt("two_stage").map(|x| x.bool()).transpose()?.unwrap_or(d.two_stage),
        max_dp: v.opt("max_dp").map(|x| x.usize()).transpose()?.unwrap_or(d.max_dp),
        max_ep: v.opt("max_ep").map(|x| x.usize()).transpose()?.unwrap_or(d.max_ep),
        parallel: v.opt("parallel").map(|x| x.bool()).transpose()?.unwrap_or(d.parallel),
        progress: v.opt("progress").map(|x| x.bool()).transpose()?.unwrap_or(d.progress),
    })
}

fn parse_sim(v: &Value) -> Result<SimOverrides> {
    Ok(SimOverrides {
        comm: v.opt("comm").map(|c| parse_token(c, "comm", CommMode::parse)).transpose()?,
        comm_algo: v
            .opt("comm_algo")
            .map(|a| parse_token(a, "comm_algo", CommAlgo::parse))
            .transpose()?,
        reshard: v
            .opt("reshard")
            .map(|r| parse_token(r, "reshard", ReshardStrategy::parse))
            .transpose()?,
        nic_affinity: v.opt("nic_affinity").map(|x| x.bool()).transpose()?,
        fine_overlap: v.opt("fine_overlap").map(|x| x.bool()).transpose()?,
    })
}

fn parse_elastic(v: &Value) -> Result<ElasticConfig> {
    Ok(ElasticConfig {
        straggler_factor: v.opt("straggler_factor").map(|x| x.num()).transpose()?,
        debounce: v.opt("debounce").map(|x| x.usize()).transpose()?,
        keep_last: v.opt("keep_last").map(|x| x.usize()).transpose()?,
        faults: v.opt("faults").map(|x| x.str().map(str::to_string)).transpose()?,
    })
}

fn parse_fleet(v: &Value) -> Result<FleetConfig> {
    Ok(FleetConfig {
        policy: v.opt("policy")
            .map(|x| crate::fleet::Policy::parse(x.str()?))
            .transpose()?,
        trace: v.opt("trace").map(|x| x.str().map(str::to_string)).transpose()?,
        seed: v.opt("seed").map(|x| x.u64()).transpose()?,
        jobs: v.opt("jobs").map(|x| x.usize()).transpose()?,
        workers: v.opt("workers").map(|x| x.usize()).transpose()?,
        faults: v.opt("faults").map(|x| x.str().map(str::to_string)).transpose()?,
    })
}

fn parse_train(v: &Value) -> Result<TrainConfig> {
    let mut stages = Vec::new();
    for s in v.get("stages")?.arr()? {
        stages.push(StagePlan {
            prefix: s.get("prefix")?.str()?.to_string(),
            chip: parse_kind(s.get("chip")?)?,
        });
    }
    let comm = match v.opt("comm") {
        Some(c) => parse_token(c, "comm", CommMode::parse)?,
        None => CommMode::DeviceDirect,
    };
    let schedule = match v.opt("schedule") {
        Some(s) => parse_token(s, "schedule", Schedule::parse)?,
        None => Schedule::OneF1B,
    };
    let comm_algo = match v.opt("comm_algo") {
        Some(a) => parse_token(a, "comm_algo", CommAlgo::parse)?,
        None => CommAlgo::Ring,
    };
    let get_usize = |key: &str, default: usize| -> Result<usize> {
        v.opt(key).map(|x| x.usize()).transpose().map(|o| o.unwrap_or(default))
    };
    Ok(TrainConfig {
        model: v.get("model")?.str()?.to_string(),
        stages,
        dp: get_usize("dp", 1)?,
        micro_batches: get_usize("micro_batches", 2)?,
        steps: get_usize("steps", 20)?,
        lr: v.opt("lr").map(|x| x.num()).transpose()?.unwrap_or(1e-3) as f32,
        seed: v.opt("seed").map(|x| x.u64()).transpose()?.unwrap_or(42),
        schedule,
        comm_algo,
        comm,
        nic_assignment: match v.opt("nic_affinity").map(|x| x.bool()).transpose()? {
            Some(false) => NicAssignment::NonAffinity,
            _ => NicAssignment::Affinity,
        },
        fine_overlap: v.opt("fine_overlap").map(|x| x.bool()).transpose()?.unwrap_or(true),
        perturb: v.opt("perturb").map(|x| x.bool()).transpose()?.unwrap_or(false),
        log_every: get_usize("log_every", 10)?,
    })
}

impl Config {
    /// Parse a config. Custom chips are registered into the process-wide
    /// registry *before* the other sections are parsed (the cluster/train
    /// sections may reference them by name), so a config whose later
    /// sections fail to parse still leaves its chip definitions registered
    /// — re-parsing a corrected config re-registers them idempotently.
    pub fn parse(text: &str) -> Result<Config> {
        let v = Value::parse(text)?;
        // Chips first: the cluster/train sections may reference them.
        let mut chips = Vec::new();
        if let Some(list) = v.opt("chips") {
            for c in list.arr().context("parsing `chips`")? {
                let def = chip_def_from_json(c).context("parsing `chips`")?;
                register_custom(&def)?;
                chips.push(def);
            }
        }
        let search = v.opt("search").map(parse_search).transpose()
            .context("parsing `search`")?;
        // A pin is explicit only when the section actually carried a
        // comm-algo key and it narrowed the space to one algorithm.
        let comm_algo_pin = match (&search, v.opt("search")) {
            (Some(cfg), Some(sv))
                if (sv.opt("comm_algo").is_some() || sv.opt("comm_algos").is_some())
                    && cfg.comm_algos.len() == 1 =>
            {
                Some(cfg.comm_algos[0])
            }
            _ => None,
        };
        Ok(Config {
            chips,
            cluster: v.opt("cluster").map(parse_cluster).transpose()
                .context("parsing `cluster`")?,
            gbs_tokens: v.opt("gbs_tokens").map(|x| x.usize()).transpose()?,
            search,
            comm_algo_pin,
            sim: v.opt("sim").map(parse_sim).transpose()
                .context("parsing `sim`")?,
            elastic: v.opt("elastic").map(parse_elastic).transpose()
                .context("parsing `elastic`")?,
            fleet: v.opt("fleet").map(parse_fleet).transpose()
                .context("parsing `fleet`")?,
            train: v.opt("train").map(parse_train).transpose()
                .context("parsing `train`")?,
        })
    }

    /// Read and parse a config file.
    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Config::parse(&text).with_context(|| format!("parsing {path}"))
    }

    /// Search options declared by the config, or the defaults.
    pub fn search_config(&self) -> SearchConfig {
        self.search.clone().unwrap_or_default()
    }

    /// Simulation options: the defaults with the config's `sim` keys applied.
    pub fn sim_options(&self) -> SimOptions {
        let mut opts = SimOptions::default();
        if let Some(s) = self.sim {
            s.apply(&mut opts);
        }
        opts
    }

    /// The `train` section lowered to a plan [`TrainSpec`] — the run shape
    /// only; the section's comm/NIC/overlap/perturb fields live on the plan
    /// itself (comm fields via the `sim` section, perturb via precision).
    pub fn train_spec(&self) -> Option<TrainSpec> {
        self.train.as_ref().map(|t| TrainSpec {
            model: t.model.clone(),
            stages: t.stages.clone(),
            dp: t.dp,
            micro_batches: t.micro_batches,
            steps: t.steps,
            lr: t.lr,
            seed: t.seed,
            log_every: t.log_every,
        })
    }

    /// Lower the config into a [`PlanBuilder`]: cluster, global batch,
    /// simulation options, and the train section (run shape + perturb
    /// flag) are applied; when the search section pins exactly one
    /// schedule, that schedule overrides the strategy's, and an explicit
    /// comm-algo pin (search section or `sim.comm_algo`) overrides the
    /// strategy's collective. The caller supplies the strategy (usually
    /// from `HeteroAuto`) and builds.
    pub fn plan_builder(&self, name: &str) -> Result<PlanBuilder> {
        let cluster = self
            .cluster
            .clone()
            .ok_or_else(|| anyhow!("config has no `cluster` section"))?;
        let sim = self.sim_options();
        let mut b = PlanBuilder::new(name)
            .cluster(cluster)
            .comm(sim.comm)
            .reshard(sim.reshard)
            .nic_assignment(sim.nic_assignment)
            .fine_overlap(sim.fine_overlap);
        let search = self.search_config();
        if search.schedules.len() == 1 {
            b = b.schedule(search.schedules[0]);
        }
        // Unlike schedules (whose default space has three entries), the
        // default comm-algo space is already a singleton, so only a pin
        // the config *explicitly* declared (any token, `auto` included)
        // overrides the caller's strategy.
        if let Some(algo) = self.comm_algo_pin {
            b = b.comm_algo(algo);
        }
        if let Some(algo) = self.sim.and_then(|s| s.comm_algo) {
            b = b.comm_algo(algo);
        }
        if let Some(gbs) = self.gbs_tokens {
            b = b.gbs_tokens(gbs);
        }
        if let Some(spec) = self.train_spec() {
            b = b.train(spec);
        }
        if self.train.as_ref().map(|t| t.perturb).unwrap_or(false) {
            b = b.precision(PrecisionPolicy { perturb: true, ..PrecisionPolicy::default() });
        }
        Ok(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"{
        "cluster": {"name": "lab", "groups": [{"chip": "A", "chips": 256},
                                               {"chip": "B", "chips": 512}]},
        "gbs_tokens": 2097152,
        "train": {"model": "h2_100m",
                  "stages": [{"prefix": "first_l10", "chip": "A"},
                             {"prefix": "last_l6", "chip": "B"}],
                  "dp": 2, "micro_batches": 4, "steps": 50, "lr": 0.0004,
                  "comm": "tcp", "fine_overlap": false, "nic_affinity": false}
    }"#;

    #[test]
    fn full_config_parses() {
        let c = Config::parse(FULL).unwrap();
        let cluster = c.cluster.unwrap();
        assert_eq!(cluster.total_chips(), 768);
        assert_eq!(c.gbs_tokens, Some(2097152));
        let t = c.train.unwrap();
        assert_eq!(t.model, "h2_100m");
        assert_eq!(t.stages.len(), 2);
        assert_eq!(t.dp, 2);
        assert_eq!(t.comm, crate::comm::CommMode::TcpCpu);
        assert!(!t.fine_overlap);
        assert_eq!(t.nic_assignment, crate::topology::NicAssignment::NonAffinity);
        assert!((t.lr - 4e-4).abs() < 1e-9);
    }

    #[test]
    fn defaults_fill_in() {
        let c = Config::parse(r#"{"train": {"model": "h2_tiny",
            "stages": [{"prefix": "first_l2", "chip": "A"},
                       {"prefix": "last_l2", "chip": "B"}]}}"#).unwrap();
        let t = c.train.unwrap();
        assert_eq!(t.dp, 1);
        assert_eq!(t.steps, 20);
        assert_eq!(t.comm, crate::comm::CommMode::DeviceDirect);
        assert!(t.fine_overlap);
        // The coordinator's pre-engine defaults: 1F1B order, flat ring.
        assert_eq!(t.schedule, Schedule::OneF1B);
        assert_eq!(t.comm_algo, CommAlgo::Ring);
    }

    #[test]
    fn train_schedule_and_comm_algo_keys_parse() {
        let c = Config::parse(r#"{"train": {"model": "h2_tiny",
            "stages": [{"prefix": "first_l2", "chip": "A"},
                       {"prefix": "last_l2", "chip": "B"}],
            "schedule": "zbv", "comm_algo": "hierarchical"}}"#).unwrap();
        let t = c.train.unwrap();
        assert_eq!(t.schedule, Schedule::ZeroBubbleV);
        assert_eq!(t.comm_algo, CommAlgo::Hierarchical);
        // Bad tokens fail loudly.
        assert!(Config::parse(r#"{"train": {"model": "m", "stages": [],
            "schedule": "bogus"}}"#).is_err());
        assert!(Config::parse(r#"{"train": {"model": "m", "stages": [],
            "comm_algo": "bogus"}}"#).is_err());
    }

    #[test]
    fn elastic_section_parses_and_defaults_fill_in() {
        let c = Config::parse(r#"{"elastic": {"straggler_factor": 1.5,
            "debounce": 3, "keep_last": 4, "faults": "faults.json"}}"#).unwrap();
        let e = c.elastic.unwrap();
        assert_eq!(e.debounce, Some(3));
        assert_eq!(e.keep_last, Some(4));
        assert_eq!(e.faults.as_deref(), Some("faults.json"));
        let m = e.monitor_config();
        assert_eq!(m.debounce, 3);
        assert!((m.straggler_factor - 1.5).abs() < 1e-12);
        // A partial section keeps the monitor defaults for absent keys.
        let c = Config::parse(r#"{"elastic": {"keep_last": 2}}"#).unwrap();
        let e = c.elastic.unwrap();
        assert_eq!(e.monitor_config().debounce, MonitorConfig::default().debounce);
        assert!(e.faults.is_none());
        // No section at all.
        assert!(Config::parse("{}").unwrap().elastic.is_none());
    }

    #[test]
    fn fleet_section_parses_and_is_optional() {
        let c = Config::parse(r#"{"fleet": {"policy": "priority", "seed": 42,
            "jobs": 12, "workers": 4, "trace": "trace.json", "faults": "pinned"}}"#).unwrap();
        let f = c.fleet.unwrap();
        assert_eq!(f.policy, Some(crate::fleet::Policy::PriorityBackfill));
        assert_eq!(f.seed, Some(42));
        assert_eq!(f.jobs, Some(12));
        assert_eq!(f.workers, Some(4));
        assert_eq!(f.trace.as_deref(), Some("trace.json"));
        assert_eq!(f.faults.as_deref(), Some("pinned"));
        // A partial section leaves the rest unset for the CLI defaults.
        let c = Config::parse(r#"{"fleet": {"policy": "fifo"}}"#).unwrap();
        let f = c.fleet.unwrap();
        assert_eq!(f.policy, Some(crate::fleet::Policy::Fifo));
        assert!(f.seed.is_none() && f.trace.is_none() && f.faults.is_none());
        // Bad policy tokens fail loudly; no section at all is fine.
        assert!(Config::parse(r#"{"fleet": {"policy": "bogus"}}"#).is_err());
        assert!(Config::parse("{}").unwrap().fleet.is_none());
    }

    #[test]
    fn bad_chip_errors() {
        let e = Config::parse(r#"{"cluster": {"groups": [{"chip": "Z", "chips": 8}]}}"#);
        assert!(e.is_err());
    }

    #[test]
    fn empty_config_is_fine() {
        let c = Config::parse("{}").unwrap();
        assert!(c.cluster.is_none() && c.train.is_none());
        assert!(c.search.is_none() && c.sim.is_none() && c.chips.is_empty());
    }

    #[test]
    fn custom_chips_register_and_are_usable_in_cluster() {
        let c = Config::parse(r#"{
            "chips": [{"name": "CfgTest-X1", "fp16_tflops": 220, "memory_gib": 96,
                       "chips_per_node": 16,
                       "intra_node": {"type": "numa", "local_gbps": 150,
                                      "cross_gbps": 50, "island": 8},
                       "mfu": 0.5}],
            "cluster": {"name": "xlab", "groups": [{"chip": "CfgTest-X1", "chips": 32}]}
        }"#).unwrap();
        assert_eq!(c.chips.len(), 1);
        let cluster = c.cluster.unwrap();
        assert_eq!(cluster.total_chips(), 32);
        let spec = &cluster.groups[0].spec;
        assert!(spec.kind.is_custom());
        assert_eq!(spec.fp16_tflops, 220.0);
        assert_eq!(spec.chips_per_node, 16);
        assert_eq!(spec.tp_max(), 8); // NUMA island of 8
    }

    #[test]
    fn search_and_sim_sections_parse() {
        let c = Config::parse(r#"{
            "search": {"schedule": "zbv", "max_dp": 8, "two_stage": false},
            "sim": {"comm": "tcp", "reshard": "naive", "fine_overlap": false}
        }"#).unwrap();
        let s = c.search_config();
        assert_eq!(s.schedules, vec![Schedule::ZeroBubbleV]);
        assert_eq!(s.max_dp, 8);
        assert!(!s.two_stage);
        assert_eq!(s.group_split, 128); // default fills in
        let o = c.sim_options();
        assert_eq!(o.comm, crate::comm::CommMode::TcpCpu);
        assert_eq!(o.reshard, crate::sim::ReshardStrategy::NaiveP2p);
        assert!(!o.fine_overlap);
    }

    #[test]
    fn schedule_keys_parse_with_legacy_alpha_fallback() {
        let c = Config::parse(r#"{"search": {"schedules": ["1f1b", "interleaved:4"]}}"#)
            .unwrap();
        assert_eq!(c.search_config().schedules,
                   vec![Schedule::OneF1B, Schedule::Interleaved { virtual_stages: 4 }]);
        // Legacy alpha maps through Schedule::from_alpha.
        let c = Config::parse(r#"{"search": {"alpha": 0.0}}"#).unwrap();
        assert_eq!(c.search_config().schedules, vec![Schedule::ZeroBubbleV]);
        let c = Config::parse(r#"{"search": {"alpha": 1.0}}"#).unwrap();
        assert_eq!(c.search_config().schedules, vec![Schedule::OneF1B]);
        // No key at all: the full default search space.
        let c = Config::parse(r#"{"search": {}}"#).unwrap();
        assert_eq!(c.search_config().schedules, Schedule::SEARCH_SPACE.to_vec());
        // Bad tokens fail loudly.
        assert!(Config::parse(r#"{"search": {"schedule": "bogus"}}"#).is_err());
        assert!(Config::parse(r#"{"search": {"schedules": []}}"#).is_err());
    }

    #[test]
    fn config_lowers_into_plan_builder() {
        use crate::costmodel::{GroupPlan, Strategy};
        let c = Config::parse(r#"{
            "cluster": {"name": "lab", "groups": [{"chip": "A", "chips": 256}]},
            "gbs_tokens": 2097152,
            "search": {"schedule": "zbv", "comm_algo": "hierarchical"},
            "sim": {"comm": "tcp"}
        }"#).unwrap();
        let plan = c.plan_builder("from-config").unwrap()
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 128,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
            })
            .build()
            .unwrap();
        assert_eq!(plan.gbs_tokens, 2097152);
        assert_eq!(plan.comm, crate::comm::CommMode::TcpCpu);
        assert_eq!(plan.cluster.name, "lab");
        // The pinned search schedule and comm algo override the strategy's.
        assert_eq!(plan.strategy.schedule, Schedule::ZeroBubbleV);
        assert_eq!(plan.strategy.comm_algo, CommAlgo::Hierarchical);
    }

    #[test]
    fn comm_algo_keys_parse_like_the_schedule_keys() {
        let c = Config::parse(r#"{"search": {"comm_algos": ["ring", "hier", "rhd"]}}"#)
            .unwrap();
        assert_eq!(c.search_config().comm_algos,
                   vec![CommAlgo::Ring, CommAlgo::Hierarchical,
                        CommAlgo::RecursiveHalvingDoubling]);
        let c = Config::parse(r#"{"search": {"comm_algo": "tree"}}"#).unwrap();
        assert_eq!(c.search_config().comm_algos, vec![CommAlgo::Tree]);
        // No key: the topology-aware auto selector alone.
        let c = Config::parse(r#"{"search": {}}"#).unwrap();
        assert_eq!(c.search_config().comm_algos, vec![CommAlgo::Auto]);
        // Bad tokens and empty lists fail loudly.
        assert!(Config::parse(r#"{"search": {"comm_algo": "bogus"}}"#).is_err());
        assert!(Config::parse(r#"{"search": {"comm_algos": []}}"#).is_err());
        // The sim section carries a per-run override.
        let c = Config::parse(r#"{"sim": {"comm_algo": "auto"}}"#).unwrap();
        assert_eq!(c.sim.unwrap().comm_algo, Some(CommAlgo::Auto));
        // Explicitness is tracked: an explicit `auto` pin is a pin, while
        // a search section without comm-algo keys (or a multi-entry
        // space) is not.
        let c = Config::parse(r#"{"search": {"comm_algo": "auto"}}"#).unwrap();
        assert_eq!(c.comm_algo_pin, Some(CommAlgo::Auto));
        let c = Config::parse(r#"{"search": {"two_stage": false}}"#).unwrap();
        assert_eq!(c.comm_algo_pin, None);
        let c = Config::parse(r#"{"search": {"comm_algos": ["ring", "tree"]}}"#).unwrap();
        assert_eq!(c.comm_algo_pin, None);
    }

    #[test]
    fn explicit_auto_pin_lowers_into_the_plan_builder() {
        use crate::costmodel::{GroupPlan, Strategy};
        let c = Config::parse(r#"{
            "cluster": {"name": "lab", "groups": [{"chip": "A", "chips": 256}]},
            "search": {"comm_algo": "auto"}
        }"#).unwrap();
        let plan = c.plan_builder("auto-pin").unwrap()
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 128,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![GroupPlan { s_pp: 16, s_tp: 4, layers: 96, recompute: false }],
            })
            .build()
            .unwrap();
        assert_eq!(plan.strategy.comm_algo, CommAlgo::Auto);
    }

    #[test]
    fn plan_builder_carries_train_section() {
        use crate::costmodel::{GroupPlan, Strategy};
        let c = Config::parse(FULL).unwrap();
        let plan = c
            .plan_builder("with-train")
            .unwrap()
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 128,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![
                    GroupPlan { s_pp: 16, s_tp: 4, layers: 32, recompute: false },
                    GroupPlan { s_pp: 32, s_tp: 4, layers: 64, recompute: true },
                ],
            })
            .build()
            .unwrap();
        let t = plan.train.as_ref().expect("train section must ride along");
        assert_eq!(t.model, "h2_100m");
        assert_eq!(t.dp, 2);
        assert!(!plan.precision.perturb);
        assert!(plan.train_config().is_ok());
    }

    #[test]
    fn plan_builder_without_cluster_errors() {
        let c = Config::parse("{}").unwrap();
        assert!(c.plan_builder("x").is_err());
    }
}
