//! Incremental re-planning after chip loss — the planner half of the
//! elastic loop (see [`crate::elastic`]).
//!
//! [`replan`] takes the incumbent [`ExecutionPlan`], a [`ClusterDelta`]
//! naming the chips that died, and the [`ProfileCache`] warmed by the
//! original search, and produces the next plan with its `plan_epoch`
//! bumped. Two modes:
//!
//! * **pipeline-preserving** (the default): keep the incumbent's `s_dp`,
//!   schedule, micro-batching and per-group stage counts, shrink the
//!   affected groups' tensor parallelism to fit the surviving chips, and
//!   re-shard layers over the cached profiles. Survivors that no longer
//!   form a complete `s_pp × s_tp × s_dp` slice are idled alongside the
//!   dead chips ([`ReplanOutcome::idled_chips`]) — at power-of-two group
//!   sizes a single lost node always strands some siblings; a later full
//!   re-plan reclaims them. The result is hot-swap compatible
//!   ([`crate::elastic::swap_compatible`]): training resumes by migrating
//!   per-stage state instead of restarting.
//! * **full**: re-run the DFS over the reduced cluster along the
//!   incumbent's `(s_dp, schedule, comm-algo)` slice, falling back to a
//!   pinned HeteroAuto search when that slice has no feasible point. The
//!   plan may change shape arbitrarily; resuming requires a checkpoint
//!   restart.
//!
//! Either way every profile lookup goes through the caller's cache, so a
//! replan right after a search is nearly all hits
//! ([`ReplanOutcome::cache_misses`] makes that observable). An empty
//! delta returns the incumbent bit-identically with its epoch untouched —
//! re-planning is a no-op unless the cluster actually changed.

use std::fmt;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::costmodel::{evaluate_with_profiles, LayerProfile, ProfileCache, Strategy};
use crate::hetero::{ChipGroup, ChipKind, Cluster};
use crate::plan::{ExecutionPlan, PlanBuilder};

use super::search::{run_jobs, search_with_cache, SearchConfig, SearchProgress};
use super::sharding::{shard_layers, GroupShape};

/// Typed failures of the pipeline-preserving replan path. They travel
/// inside the `anyhow::Error` that [`replan`] returns — callers that need
/// to dispatch on the cause (e.g. fall back to `keep_pipeline: false`)
/// use `err.downcast_ref::<ReplanError>()` instead of string-scraping.
#[derive(Clone, Debug, PartialEq)]
pub enum ReplanError {
    /// Whole-node rounding of a kind's losses drains the kind entirely —
    /// nothing of the group would survive, so no replan mode can help.
    GroupDrained { kind: ChipKind, requested: usize, rounded: usize, available: usize },
    /// A stage group's survivors (possibly zero) cannot fill its
    /// `s_pp × s_dp` slice even at TP 1. A pipeline-preserving replan
    /// cannot drop a stage; re-plan with `keep_pipeline: false`.
    StageUnfillable { group: usize, kind: ChipKind, survivors: usize, s_pp: usize, s_dp: usize },
}

impl fmt::Display for ReplanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplanError::GroupDrained { kind, requested, rounded, available } => {
                write!(
                    f,
                    "excluding {requested} {kind} chips drains {rounded} after \
                     whole-node rounding, but the cluster only has {available} — \
                     nothing of the group would survive"
                )
            }
            ReplanError::StageUnfillable { group, kind, survivors, s_pp, s_dp } => {
                write!(
                    f,
                    "{survivors} surviving {kind} chips cannot fill stage group \
                     {group}'s s_pp {s_pp} × s_dp {s_dp} slice even at TP 1; a \
                     pipeline-preserving replan cannot drop a stage (re-plan \
                     without keep_pipeline instead)"
                )
            }
        }
    }
}

impl std::error::Error for ReplanError {}

/// The cluster difference handed to [`replan`]: chips lost per type.
/// Losses are rounded **up to whole nodes** — a dead chip drains its node
/// (its surviving siblings lose their TP peers and their NIC shares).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterDelta {
    /// Chips lost per chip type (entries with a zero count are ignored;
    /// repeated kinds accumulate).
    pub dead: Vec<(ChipKind, usize)>,
}

impl ClusterDelta {
    /// A delta excluding `chips` chips of one `kind`.
    pub fn exclude(kind: ChipKind, chips: usize) -> ClusterDelta {
        ClusterDelta { dead: vec![(kind, chips)] }
    }

    /// True when no chips are excluded — [`replan`] is then the identity.
    pub fn is_empty(&self) -> bool {
        self.dead.iter().all(|&(_, n)| n == 0)
    }
}

/// Knobs for [`replan`].
#[derive(Clone, Copy, Debug)]
pub struct ReplanOptions {
    /// Preserve the incumbent's pipeline shape (`s_dp`, schedule,
    /// per-group stage counts) so the new plan is hot-swap compatible.
    /// Off, the DFS may reshape the pipeline freely (checkpoint-restart
    /// territory). Default: on.
    pub keep_pipeline: bool,
    /// Run any fallback search on worker threads (bit-identical result
    /// either way). Default: on.
    pub parallel: bool,
}

impl Default for ReplanOptions {
    fn default() -> Self {
        ReplanOptions { keep_pipeline: true, parallel: true }
    }
}

impl ReplanOptions {
    /// Full-mode options: the pipeline may be reshaped freely. The result
    /// is generally *not* swap-compatible with the incumbent, so callers
    /// price it as a checkpoint restart (the fleet cascade's shrink rung).
    pub fn full() -> ReplanOptions {
        ReplanOptions { keep_pipeline: false, parallel: true }
    }
}

/// What [`replan`] returns.
#[derive(Clone, Debug)]
pub struct ReplanOutcome {
    /// The plan to run next. On an empty delta this is the incumbent,
    /// bit for bit; otherwise a validated plan over the reduced cluster
    /// with `plan_epoch` bumped and any embedded fault plan consumed.
    pub plan: ExecutionPlan,
    /// False only for the empty-delta identity case.
    pub changed: bool,
    /// Profile-cache hits during this replan alone (a warm cache from the
    /// original search should make this ≈ every lookup).
    pub cache_hits: usize,
    /// Profile-cache misses during this replan alone.
    pub cache_misses: usize,
    /// Surviving chips the new plan cannot use: the pipeline-preserving
    /// mode idles survivors that no longer form a complete
    /// `s_pp × s_tp × s_dp` slice (zero in full mode and on exact fits).
    pub idled_chips: usize,
    /// Wall-clock re-planning time.
    pub elapsed_seconds: f64,
}

/// Re-plan `incumbent` after losing the chips in `delta`, reusing the
/// cached profiles in `cache`. See the module docs for the two modes.
pub fn replan(
    incumbent: &ExecutionPlan,
    delta: &ClusterDelta,
    cache: &ProfileCache,
    opts: &ReplanOptions,
) -> Result<ReplanOutcome> {
    let start = Instant::now();
    let (hits0, misses0) = (cache.hits(), cache.misses());
    if delta.is_empty() {
        return Ok(ReplanOutcome {
            plan: incumbent.clone(),
            changed: false,
            cache_hits: 0,
            cache_misses: 0,
            idled_chips: 0,
            elapsed_seconds: start.elapsed().as_secs_f64(),
        });
    }

    // Merge the delta per kind, then round each kind's loss up to whole
    // nodes and check something survives.
    let mut dead: Vec<(ChipKind, usize)> = Vec::new();
    for &(kind, chips) in &delta.dead {
        if chips == 0 {
            continue;
        }
        match dead.iter_mut().find(|(k, _)| *k == kind) {
            Some((_, n)) => *n += chips,
            None => dead.push((kind, chips)),
        }
    }
    let mut removed: Vec<(ChipKind, usize)> = Vec::new();
    for &(kind, chips) in &dead {
        let group = incumbent.cluster.group(kind)?;
        let node = group.spec.chips_per_node;
        let r = chips.div_ceil(node) * node;
        if r >= group.n_chips {
            return Err(ReplanError::GroupDrained {
                kind,
                requested: chips,
                rounded: r,
                available: group.n_chips,
            }
            .into());
        }
        removed.push((kind, r));
    }

    let reduced = Cluster::try_build(
        &incumbent.cluster.name,
        incumbent
            .cluster
            .groups
            .iter()
            .map(|g| {
                let r = removed
                    .iter()
                    .find(|(k, _)| *k == g.spec.kind)
                    .map(|&(_, r)| r)
                    .unwrap_or(0);
                (g.spec.kind, g.n_chips - r)
            })
            .collect(),
    )?;

    let plan = if opts.keep_pipeline {
        replan_keep_pipeline(incumbent, &removed, cache)?
    } else {
        replan_full(incumbent, reduced, cache, opts)?
    };
    let lost: usize = removed.iter().map(|&(_, r)| r).sum();
    Ok(ReplanOutcome {
        idled_chips: incumbent.cluster.total_chips() - lost - plan.cluster.total_chips(),
        plan,
        changed: true,
        cache_hits: cache.hits() - hits0,
        cache_misses: cache.misses() - misses0,
        elapsed_seconds: start.elapsed().as_secs_f64(),
    })
}

/// The hot-swap mode: charge each kind's loss to its stage groups (last
/// stage of the kind first — deterministic), shrink each affected group's
/// TP to the largest power of two whose `s_pp · s_tp · s_dp` slice the
/// survivors still fill (idling the remainder), and re-shard layers over
/// cached profiles. Stage counts never change, so the result passes
/// [`crate::elastic::swap_compatible`] against the incumbent.
fn replan_keep_pipeline(
    incumbent: &ExecutionPlan,
    removed: &[(ChipKind, usize)],
    cache: &ProfileCache,
) -> Result<ExecutionPlan> {
    let model = &incumbent.model;
    let s_dp = incumbent.strategy.s_dp;
    let s_ep = incumbent.strategy.s_ep;
    let schedule = incumbent.strategy.schedule;
    let comm_algo = incumbent.strategy.comm_algo;
    let micro_batches = incumbent.strategy.micro_batches;
    let micro_tokens = incumbent.micro_tokens;

    let mut groups = incumbent.stage_groups.clone();
    let mut shapes: Vec<GroupShape> = incumbent
        .strategy
        .plans
        .iter()
        .map(|p| GroupShape { s_tp: p.s_tp, s_pp: p.s_pp })
        .collect();
    for &(kind, loss) in removed {
        let mut remove = loss;
        for i in (0..groups.len()).rev() {
            if groups[i].spec.kind != kind || remove == 0 {
                continue;
            }
            let take = remove.min(groups[i].n_chips);
            remove -= take;
            let left = groups[i].n_chips - take;
            let s_pp = shapes[i].s_pp;
            let slice = s_pp * s_dp;
            // Shrink-to-fit: the widest power-of-two TP whose full
            // s_pp × s_tp × s_dp slice the survivors cover; the rest idle.
            let cap = (left / slice).min(groups[i].spec.tp_max());
            // Guard before the power-of-two rounding below: with cap == 0
            // (a group whose survivors — possibly none at all — cannot
            // fill the slice even at TP 1) `next_power_of_two() / 2`
            // yields s_tp = 0 and the zero-width group would limp on into
            // plan validation. Fail here, typed, instead.
            if cap == 0 {
                return Err(ReplanError::StageUnfillable {
                    group: i,
                    kind,
                    survivors: left,
                    s_pp,
                    s_dp,
                }
                .into());
            }
            let s_tp = if cap.is_power_of_two() { cap } else { cap.next_power_of_two() / 2 };
            let used = slice * s_tp;
            ensure!(
                used % groups[i].spec.chips_per_node == 0,
                "a pipeline-preserving replan would run stage group {i} on {used} \
                 {kind} chips — not whole {}-chip nodes (re-plan without \
                 keep_pipeline)",
                groups[i].spec.chips_per_node
            );
            groups[i].n_chips = used;
            shapes[i].s_tp = s_tp;
        }
        debug_assert_eq!(remove, 0, "per-kind totals were validated upstream");
    }

    // The plan's cluster must tally with its stage groups per kind, so the
    // idled chips leave the cluster too (they come back on a full re-plan
    // over the physical cluster).
    let cluster = Cluster::try_build(
        &incumbent.cluster.name,
        incumbent
            .cluster
            .groups
            .iter()
            .map(|cg| {
                let total: usize = groups
                    .iter()
                    .filter(|g| g.spec.kind == cg.spec.kind)
                    .map(|g| g.n_chips)
                    .sum();
                (cg.spec.kind, total)
            })
            .collect(),
    )?;

    let profiles: Vec<LayerProfile> = groups
        .iter()
        .zip(&shapes)
        .map(|(g, s)| {
            cache.profile(
                &g.spec,
                model,
                s.s_tp,
                micro_tokens,
                s_dp,
                s_ep,
                comm_algo,
                incumbent.nic_assignment,
            )
        })
        .collect();
    let sharding = shard_layers(
        model,
        &groups,
        &shapes,
        s_dp,
        s_ep,
        micro_batches,
        micro_tokens,
        schedule,
        comm_algo,
        &profiles,
    );
    ensure!(
        sharding.feasible,
        "no memory-feasible layer allocation on the reduced cluster with the \
         incumbent pipeline (re-plan without keep_pipeline)"
    );
    let v = schedule.virtual_stages();
    ensure!(
        v <= 1 || sharding.plans.iter().all(|p| p.layers_per_stage() % v == 0),
        "re-sharded allocation does not chunk into {v} virtual stages \
         (re-plan without keep_pipeline)"
    );
    let strategy =
        Strategy { s_ep, s_dp, micro_batches, schedule, comm_algo, plans: sharding.plans };
    let grefs: Vec<&ChipGroup> = groups.iter().collect();
    let eval = evaluate_with_profiles(model, &grefs, &strategy, micro_tokens, &profiles);
    ensure!(
        eval.feasible,
        "the re-sharded strategy is infeasible on the reduced cluster \
         (re-plan without keep_pipeline)"
    );
    build_plan(incumbent, cluster, groups, strategy)
}

/// The full mode: DFS over the reduced cluster along the incumbent's
/// `(s_dp, schedule, comm-algo)` slice; if that slice is dry (e.g. the
/// surviving chips no longer divide by the incumbent `s_dp`), fall back
/// to a HeteroAuto search pinned to the incumbent schedule + algorithm.
fn replan_full(
    incumbent: &ExecutionPlan,
    reduced: Cluster,
    cache: &ProfileCache,
    opts: &ReplanOptions,
) -> Result<ExecutionPlan> {
    let model = &incumbent.model;
    let sequences = incumbent.gbs_tokens / model.seq_len;
    let s_dp = incumbent.strategy.s_dp;
    let s_ep = incumbent.strategy.s_ep;
    let schedule = incumbent.strategy.schedule;
    let comm_algo = incumbent.strategy.comm_algo;
    let groups: Vec<ChipGroup> =
        reduced.groups_by_memory_desc().into_iter().cloned().collect();
    let dp_fits = sequences % s_dp == 0 && groups.iter().all(|g| g.n_chips % s_dp == 0);
    let best = if dp_fits {
        let jobs = [(s_dp, s_ep, schedule, comm_algo)];
        let progress = SearchProgress::new(false);
        let (_, best) = run_jobs(
            model,
            &groups,
            sequences,
            &jobs,
            false,
            opts.parallel,
            f64::INFINITY,
            cache,
            &progress,
        );
        best
    } else {
        None
    };
    let (stage_groups, strategy) = match best {
        Some((_, strategy, _)) => (groups, strategy),
        None => {
            let cfg = SearchConfig {
                schedules: vec![schedule],
                comm_algos: vec![comm_algo],
                parallel: opts.parallel,
                ..SearchConfig::default()
            };
            let r = search_with_cache(model, &reduced, incumbent.gbs_tokens, &cfg, cache)?;
            (r.groups, r.strategy)
        }
    };
    build_plan(incumbent, reduced, stage_groups, strategy)
}

/// Package a re-planned strategy as a validated [`ExecutionPlan`] carrying
/// the incumbent's communication options, a bumped `plan_epoch`, and no
/// fault plan (the fault that triggered the replan is consumed, not
/// inherited).
fn build_plan(
    incumbent: &ExecutionPlan,
    cluster: Cluster,
    stage_groups: Vec<ChipGroup>,
    strategy: Strategy,
) -> Result<ExecutionPlan> {
    let mut builder = PlanBuilder::new(&incumbent.name)
        .model(incumbent.model)
        .cluster(cluster)
        .stage_groups(stage_groups)
        .strategy(strategy)
        .gbs_tokens(incumbent.gbs_tokens)
        .micro_tokens(incumbent.micro_tokens)
        .comm(incumbent.comm)
        .reshard(incumbent.reshard)
        .nic_assignment(incumbent.nic_assignment)
        .fine_overlap(incumbent.fine_overlap)
        .precision(incumbent.precision);
    if let Some(train) = &incumbent.train {
        builder = builder.train(train.clone());
    }
    let mut plan = builder.build().map_err(|errs| {
        anyhow!(
            "replanned plan failed validation: {}",
            errs.iter().map(|e| e.to_string()).collect::<Vec<_>>().join("; ")
        )
    })?;
    plan.plan_epoch = incumbent.plan_epoch + 1;
    plan.fault_plan = None;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommAlgo;
    use crate::costmodel::{GroupPlan, ModelShape, Schedule};
    use crate::elastic::swap_compatible;
    use crate::util::prop;

    /// In-lib mirror of the integration suites' `tiny_model` /
    /// `two_stage_mixed_vendor_plan` fixture (keep in sync with
    /// `rust/tests/common.rs`).
    fn tiny_model() -> ModelShape {
        ModelShape {
            n_layers: 8,
            hidden: 2048,
            n_heads: 16,
            n_kv_heads: 16,
            intermediate: 8192,
            vocab: 32000,
            seq_len: 4096,
            n_experts: 0,
            top_k: 0,
            expert_intermediate: 0,
        }
    }

    fn mixed_plan(schedule: Schedule, comm_algo: CommAlgo) -> ExecutionPlan {
        let cluster =
            Cluster::new("parity-2stage", vec![(ChipKind::A, 16), (ChipKind::B, 16)]);
        PlanBuilder::new("parity")
            .model(tiny_model())
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 8,
                schedule,
                comm_algo,
                plans: vec![
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: false },
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: true },
                ],
            })
            .gbs_tokens(4 * 8 * 4096)
            .build()
            .unwrap()
    }

    #[test]
    fn empty_delta_returns_the_incumbent_bit_for_bit() {
        let plan = mixed_plan(Schedule::OneF1B, CommAlgo::Ring);
        let cache = ProfileCache::new();
        let out =
            replan(&plan, &ClusterDelta::default(), &cache, &ReplanOptions::default())
                .unwrap();
        assert!(!out.changed);
        assert_eq!(out.plan, plan);
        assert_eq!((out.cache_hits, out.cache_misses), (0, 0));
    }

    #[test]
    fn replan_on_unchanged_cluster_is_identity_for_any_incumbent() {
        // The satellite property: whatever the incumbent looks like —
        // schedule, comm algo, epoch, an embedded fault plan — an empty
        // delta must hand it back untouched (and a zero-count delta
        // counts as empty).
        prop::check(24, |rng| {
            let schedule =
                Schedule::SEARCH_SPACE[rng.usize(0, Schedule::SEARCH_SPACE.len() - 1)];
            let comm_algo = CommAlgo::ALL[rng.usize(0, CommAlgo::ALL.len() - 1)];
            let mut plan = mixed_plan(schedule, comm_algo);
            plan.plan_epoch = rng.range(0, 16);
            let delta = if rng.usize(0, 1) == 0 {
                ClusterDelta::default()
            } else {
                ClusterDelta::exclude(ChipKind::B, 0)
            };
            let cache = ProfileCache::new();
            let out = replan(&plan, &delta, &cache, &ReplanOptions::default())
                .map_err(|e| e.to_string())?;
            prop::assert_prop(!out.changed, "empty delta must not report change")?;
            prop::assert_prop(out.plan == plan, "incumbent must round-trip bit-identically")
        });
    }

    #[test]
    fn node_loss_preserves_the_pipeline_and_bumps_the_epoch() {
        let plan = mixed_plan(Schedule::OneF1B, CommAlgo::Ring);
        let cache = ProfileCache::new();
        // One dead B chip drains its whole 8-chip node: B 16 → 8.
        let delta = ClusterDelta::exclude(ChipKind::B, 1);
        let opts = ReplanOptions::default();
        let out = replan(&plan, &delta, &cache, &opts).unwrap();
        assert!(out.changed);
        let next = &out.plan;
        assert!(next.validate().is_ok());
        assert_eq!(next.plan_epoch, plan.plan_epoch + 1);
        assert_eq!(next.cluster.group(ChipKind::B).unwrap().n_chips, 8);
        assert_eq!(next.cluster.group(ChipKind::A).unwrap().n_chips, 16);
        // Same pipeline: hot-swap compatible, with B's TP shrunk to fit.
        swap_compatible(&plan, next).unwrap();
        assert_eq!(next.strategy.plans[1].s_tp, 2);
        assert_eq!(next.strategy.total_layers(), plan.model.n_layers);
        // A second replan over the now-warm cache re-profiles nothing.
        let again = replan(&plan, &delta, &cache, &opts).unwrap();
        assert_eq!(again.cache_misses, 0, "warm-cache replan re-profiled shapes");
        assert!(again.cache_hits > 0);
        assert_eq!(again.plan, out.plan);
    }

    #[test]
    fn odd_node_loss_idles_the_stranded_slice_remainder() {
        // A 3-stage plan whose B group spans two pipeline stages: losing
        // one of its four 8-chip nodes leaves 24 chips, which cannot fill
        // the s_pp 2 × s_dp 4 slice at any power-of-two TP except 2 — so
        // 16 chips run and 8 survivors idle until a full re-plan.
        let cluster =
            Cluster::new("idle-3stage", vec![(ChipKind::A, 16), (ChipKind::B, 32)]);
        let plan = PlanBuilder::new("idle")
            .model(tiny_model())
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 8,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: false },
                    GroupPlan { s_pp: 2, s_tp: 4, layers: 4, recompute: true },
                ],
            })
            .gbs_tokens(4 * 8 * 4096)
            .build()
            .unwrap();
        let cache = ProfileCache::new();
        let out = replan(
            &plan,
            &ClusterDelta::exclude(ChipKind::B, 1),
            &cache,
            &ReplanOptions::default(),
        )
        .unwrap();
        assert!(out.plan.validate().is_ok());
        swap_compatible(&plan, &out.plan).unwrap();
        assert_eq!(out.idled_chips, 8, "24 survivors, 16 usable at TP 2");
        assert_eq!(out.plan.cluster.group(ChipKind::B).unwrap().n_chips, 16);
        assert_eq!(out.plan.strategy.plans[1].s_tp, 2);
        assert_eq!(out.plan.plan_epoch, plan.plan_epoch + 1);
    }

    #[test]
    fn full_replan_reshapes_over_the_reduced_cluster() {
        let plan = mixed_plan(Schedule::OneF1B, CommAlgo::Ring);
        let cache = ProfileCache::new();
        let opts = ReplanOptions { keep_pipeline: false, ..Default::default() };
        let out =
            replan(&plan, &ClusterDelta::exclude(ChipKind::B, 8), &cache, &opts).unwrap();
        assert!(out.changed);
        assert!(out.plan.validate().is_ok());
        assert_eq!(out.plan.plan_epoch, plan.plan_epoch + 1);
        assert_eq!(out.plan.cluster.total_chips(), 24);
        assert_eq!(out.plan.strategy.total_layers(), plan.model.n_layers);
    }

    #[test]
    fn draining_a_whole_group_is_rejected() {
        let plan = mixed_plan(Schedule::OneF1B, CommAlgo::Ring);
        let cache = ProfileCache::new();
        let err = replan(
            &plan,
            &ClusterDelta::exclude(ChipKind::B, 16),
            &cache,
            &ReplanOptions::default(),
        )
        .unwrap_err();
        assert_eq!(
            err.downcast_ref::<ReplanError>(),
            Some(&ReplanError::GroupDrained {
                kind: ChipKind::B,
                requested: 16,
                rounded: 16,
                available: 16,
            }),
            "{err}"
        );
    }

    #[test]
    fn killing_every_chip_of_one_stage_group_is_a_typed_error_not_tp_zero() {
        // Regression: a chip kind split over two stage groups, with the
        // whole loss landing on the last one. Its survivor count is zero,
        // so the TP shrink-to-fit cap is 0 — without the guard,
        // `cap.next_power_of_two() / 2` underflows to s_tp = 0 and a
        // zero-chip group limps on into plan validation. The replan must
        // instead fail with a typed `StageUnfillable` naming that group.
        let cluster =
            Cluster::new("split-b", vec![(ChipKind::A, 16), (ChipKind::B, 16)]);
        let groups = vec![
            ChipGroup::new(ChipKind::A, 16),
            ChipGroup::new(ChipKind::B, 8),
            ChipGroup::new(ChipKind::B, 8),
        ];
        let plan = PlanBuilder::new("split-b")
            .model(tiny_model())
            .cluster(cluster)
            .stage_groups(groups)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 8,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: false },
                    GroupPlan { s_pp: 1, s_tp: 2, layers: 2, recompute: true },
                    GroupPlan { s_pp: 1, s_tp: 2, layers: 2, recompute: true },
                ],
            })
            .gbs_tokens(4 * 8 * 4096)
            .build()
            .unwrap();
        let cache = ProfileCache::new();
        // Eight dead B chips survive the kind-level check (16 - 8 > 0) but
        // drain the *last* B stage group completely.
        let err = replan(
            &plan,
            &ClusterDelta::exclude(ChipKind::B, 8),
            &cache,
            &ReplanOptions::default(),
        )
        .unwrap_err();
        assert_eq!(
            err.downcast_ref::<ReplanError>(),
            Some(&ReplanError::StageUnfillable {
                group: 2,
                kind: ChipKind::B,
                survivors: 0,
                s_pp: 1,
                s_dp: 4,
            }),
            "{err}"
        );
        // The full mode still re-plans the same loss successfully.
        let opts = ReplanOptions { keep_pipeline: false, ..Default::default() };
        let out = replan(
            &plan,
            &ClusterDelta::exclude(ChipKind::B, 8),
            &cache,
            &opts,
        )
        .unwrap();
        assert!(out.plan.validate().is_ok());
        assert_eq!(out.plan.cluster.total_chips(), 24);
    }

    #[test]
    fn unknown_kind_in_the_delta_is_rejected() {
        let plan = mixed_plan(Schedule::OneF1B, CommAlgo::Ring);
        let cache = ProfileCache::new();
        assert!(replan(
            &plan,
            &ClusterDelta::exclude(ChipKind::C, 8),
            &cache,
            &ReplanOptions::default(),
        )
        .is_err());
    }
}
