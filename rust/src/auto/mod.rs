//! HeteroAuto: automatic parallel-strategy search for HeteroPP (§4.3).
//!
//! Searches data parallelism, per-group tensor/pipeline shapes, layer
//! sharding, recomputation, *and* the pipeline schedule
//! ([`crate::costmodel::Schedule`]); the outer candidate loop runs on
//! worker threads with branch-and-bound pruning and a deterministic
//! reduction ([`SearchConfig::parallel`]).

pub mod search;
pub mod sharding;

pub use search::{search, SearchConfig, SearchResult};
pub use sharding::{shard_layers, GroupShape, Sharding};
