//! HeteroAuto: automatic parallel-strategy search for HeteroPP (§4.3).
//!
//! Searches data parallelism, per-group tensor/pipeline shapes, layer
//! sharding, recomputation, *and* the pipeline schedule
//! ([`crate::costmodel::Schedule`]); the outer candidate loop runs on
//! worker threads with branch-and-bound pruning and a deterministic
//! reduction ([`SearchConfig::parallel`]).
//!
//! [`replan`] is the incremental entry point of the elastic loop
//! ([`crate::elastic`]): it re-plans an incumbent execution plan after
//! chip loss, reusing the original search's
//! [`crate::costmodel::ProfileCache`].

pub mod replan;
pub mod search;
pub mod sharding;

pub use replan::{replan, ClusterDelta, ReplanError, ReplanOptions, ReplanOutcome};
pub use search::{search, search_with_cache, SearchConfig, SearchResult};
pub use sharding::{shard_layers, GroupShape, Sharding};
