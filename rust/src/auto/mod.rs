//! HeteroAuto: automatic parallel-strategy search for HeteroPP (§4.3).

pub mod search;
pub mod sharding;

pub use search::{search, SearchConfig, SearchResult};
pub use sharding::{shard_layers, GroupShape, Sharding};
