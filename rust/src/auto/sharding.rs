//! Optimal layer sharding (§4.3.3 step 2).
//!
//! Given per-group stage counts and per-layer times, find the integer layer
//! allocation `l_i = lps_i · s_pp,i` that (heuristically) minimizes the cost
//! model's iteration time:
//!
//! 1. continuous initialization equalizing compute time across groups,
//! 2. integer rounding,
//! 3. iterative refinement moving whole per-stage layers between groups
//!    while total ≠ L, always improving the bottleneck,
//! 4. memory repair: recomputation is enabled for groups whose stages
//!    cannot hold their activations (recompute is pure memory relief — it
//!    never reduces time — so it is only switched on under pressure).

use crate::comm::CommAlgo;
use crate::costmodel::{evaluate, GroupPlan, ModelShape, Schedule, Strategy};
use crate::hetero::ChipGroup;

/// Per-group immutable candidate: (s_tp, s_pp) already fixed by the DFS.
#[derive(Clone, Copy, Debug)]
pub struct GroupShape {
    /// Tensor-parallel degree fixed by the DFS.
    pub s_tp: usize,
    /// Pipeline-stage count fixed by the DFS.
    pub s_pp: usize,
}

/// Outcome of the sharding heuristic.
#[derive(Clone, Debug)]
pub struct Sharding {
    /// Per-group layer allocation (positionally matched with the groups).
    pub plans: Vec<GroupPlan>,
    /// Whether a memory-feasible allocation summing to the model was found.
    pub feasible: bool,
}

/// Compute the layer allocation for fixed (s_dp, shapes) under `schedule`
/// (whose bubble coefficient and activation residency shape both the cost
/// evaluation and the memory-repair loop) and `comm_algo` (which prices
/// the DP-sync term of the evaluations).
#[allow(clippy::too_many_arguments)]
pub fn shard_layers(
    model: &ModelShape,
    groups: &[ChipGroup],
    shapes: &[GroupShape],
    s_dp: usize,
    micro_batches: usize,
    micro_tokens: usize,
    schedule: Schedule,
    comm_algo: CommAlgo,
) -> Sharding {
    use crate::costmodel::profile_layer;

    let n = groups.len();
    assert_eq!(n, shapes.len());
    let total_layers = model.n_layers;

    // Per-layer single-microbatch time (fwd+bwd, no recompute) per group.
    let t_layer: Vec<f64> = groups
        .iter()
        .zip(shapes)
        .map(|(g, s)| {
            let p = profile_layer(&g.spec, model, s.s_tp, micro_tokens, s_dp);
            p.t_fwd + p.t_bwd
        })
        .collect();

    // 1) Continuous equalization: lps_i ∝ 1/t_i, scaled so layers sum to L.
    //    Σ s_pp_i · lps_i = L with lps_i = K / t_i  =>  K = L / Σ(s_pp_i/t_i).
    let denom: f64 = shapes.iter().zip(&t_layer).map(|(s, t)| s.s_pp as f64 / t).sum();
    let k = total_layers as f64 / denom;
    let mut lps: Vec<i64> = t_layer
        .iter()
        .map(|t| ((k / t).round() as i64).max(1))
        .collect();

    let assigned = |lps: &[i64]| -> i64 {
        lps.iter().zip(shapes).map(|(l, s)| l * s.s_pp as i64).sum()
    };

    // 2/3) Integer refinement: move stage-layers until the total matches L.
    //    Removing from the group with the highest per-stage load first;
    //    adding to the group with the lowest.
    let mut guard = 0;
    while assigned(&lps) != total_layers as i64 && guard < 10_000 {
        guard += 1;
        let diff = assigned(&lps) - total_layers as i64;
        if diff > 0 {
            // Drop one layer-per-stage from the group whose removal best
            // reduces the bottleneck but keeps lps >= 1 and doesn't
            // overshoot below L more than necessary.
            let mut best: Option<(usize, f64)> = None;
            for i in 0..n {
                if lps[i] <= 1 {
                    continue;
                }
                let load = lps[i] as f64 * t_layer[i];
                if best.map(|(_, l)| load > l).unwrap_or(true) {
                    best = Some((i, load));
                }
            }
            match best {
                Some((i, _)) => lps[i] -= 1,
                None => break, // cannot shrink further
            }
        } else {
            // Add one layer-per-stage to the group with the lowest load.
            let mut best: Option<(usize, f64)> = None;
            for i in 0..n {
                let load = (lps[i] + 1) as f64 * t_layer[i];
                if best.map(|(_, l)| load < l).unwrap_or(true) {
                    best = Some((i, load));
                }
            }
            lps[best.unwrap().0] += 1;
        }
    }

    // Exact match may be impossible (e.g. all stages at lps=1 already sums
    // above L). Declare infeasible if so.
    if assigned(&lps) != total_layers as i64 {
        return Sharding {
            plans: shapes
                .iter()
                .zip(&lps)
                .map(|(s, &l)| GroupPlan {
                    s_pp: s.s_pp,
                    s_tp: s.s_tp,
                    layers: (l as usize) * s.s_pp,
                    recompute: false,
                })
                .collect(),
            feasible: false,
        };
    }

    // 4) Memory repair: enable recompute per group under pressure, then (if
    // still infeasible) shift layers away from the offending group.
    let mut plans: Vec<GroupPlan> = shapes
        .iter()
        .zip(&lps)
        .map(|(s, &l)| GroupPlan {
            s_pp: s.s_pp,
            s_tp: s.s_tp,
            layers: (l as usize) * s.s_pp,
            recompute: false,
        })
        .collect();

    for _round in 0..8 {
        let strategy = Strategy { s_dp, micro_batches, schedule, comm_algo, plans: plans.clone() };
        let grefs: Vec<&ChipGroup> = groups.iter().collect();
        let eval = evaluate(model, &grefs, &strategy, micro_tokens);
        if eval.feasible {
            return Sharding { plans, feasible: true };
        }
        let mut changed = false;
        for (i, plan) in plans.iter_mut().enumerate() {
            let budget = groups[i].spec.memory_bytes() * crate::costmodel::MEMORY_SAFETY;
            if eval.peak_memory[i] > budget {
                if !plan.recompute {
                    plan.recompute = true;
                    changed = true;
                } else if plan.layers > plan.s_pp {
                    // Shed one layer-per-stage; the re-balance pass below
                    // hands the freed layers to groups with headroom.
                    plan.layers -= plan.s_pp;
                    changed = true;
                }
            }
        }
        if changed {
            // Re-balance the total after any layer removals.
            let short = total_layers as i64
                - plans.iter().map(|p| p.layers as i64).sum::<i64>();
            if short > 0 {
                // Give the missing layers to groups with memory headroom,
                // cheapest-load first.
                let mut missing = short;
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| t_layer[a].partial_cmp(&t_layer[b]).unwrap());
                'outer: while missing > 0 {
                    let mut progressed = false;
                    for &i in &order {
                        if missing < plans[i].s_pp as i64 {
                            continue;
                        }
                        plans[i].layers += plans[i].s_pp;
                        missing -= plans[i].s_pp as i64;
                        progressed = true;
                        if missing == 0 {
                            break 'outer;
                        }
                    }
                    if !progressed {
                        break;
                    }
                }
                if missing != 0 {
                    return Sharding { plans, feasible: false };
                }
            }
        } else {
            return Sharding { plans, feasible: false };
        }
    }
    Sharding { plans, feasible: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::H2_100B;
    use crate::hetero::{ChipGroup, ChipKind};

    fn groups_ab() -> Vec<ChipGroup> {
        vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 256)]
    }

    #[test]
    fn layers_sum_to_model_total() {
        let groups = groups_ab();
        let shapes = [GroupShape { s_tp: 4, s_pp: 16 }, GroupShape { s_tp: 4, s_pp: 16 }];
        let s = shard_layers(&H2_100B, &groups, &shapes, 4, 128, 4096, Schedule::OneF1B,
                             CommAlgo::Ring);
        assert_eq!(s.plans.iter().map(|p| p.layers).sum::<usize>(), 96);
    }

    #[test]
    fn faster_group_receives_more_layers() {
        let groups = groups_ab();
        let shapes = [GroupShape { s_tp: 4, s_pp: 16 }, GroupShape { s_tp: 4, s_pp: 16 }];
        let s = shard_layers(&H2_100B, &groups, &shapes, 4, 128, 4096, Schedule::OneF1B,
                             CommAlgo::Ring);
        // B is faster per layer than A, so B's stages should carry >= layers.
        assert!(s.plans[1].layers >= s.plans[0].layers,
                "A={} B={}", s.plans[0].layers, s.plans[1].layers);
    }

    #[test]
    fn uniform_within_group() {
        let groups = groups_ab();
        let shapes = [GroupShape { s_tp: 4, s_pp: 12 }, GroupShape { s_tp: 4, s_pp: 16 }];
        let s = shard_layers(&H2_100B, &groups, &shapes, 4, 128, 4096, Schedule::OneF1B,
                             CommAlgo::Ring);
        for p in &s.plans {
            assert_eq!(p.layers % p.s_pp, 0, "layers uniform across a type's stages");
        }
    }

    #[test]
    fn memory_pressure_enables_recompute() {
        // Chip C with little memory must end up recomputing.
        let groups = vec![ChipGroup::new(ChipKind::C, 256)];
        let shapes = [GroupShape { s_tp: 4, s_pp: 32 }];
        let s = shard_layers(&H2_100B, &groups, &shapes, 2, 256, 4096, Schedule::OneF1B,
                             CommAlgo::Ring);
        assert!(s.feasible);
        assert!(s.plans[0].recompute);
    }
}
