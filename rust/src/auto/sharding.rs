//! Optimal layer sharding (§4.3.3 step 2).
//!
//! Given per-group stage counts and per-layer profiles, find the integer
//! layer allocation `l_i = lps_i · s_pp,i` that (heuristically) minimizes
//! the cost model's iteration time:
//!
//! 1. continuous initialization equalizing compute time across groups,
//! 2. integer rounding,
//! 3. iterative refinement moving whole per-stage layers between groups
//!    while total ≠ L, always improving the bottleneck — driven by an
//!    incrementally maintained stage-time table (`StageTimes`) so a move
//!    costs an O(1) update instead of a full recomputation,
//! 4. memory repair: recomputation is enabled for groups whose stages
//!    cannot hold their activations (recompute is pure memory relief — it
//!    never reduces time — so it is only switched on under pressure).
//!
//! The caller supplies the per-group [`LayerProfile`]s (HeteroAuto holds
//! them in its per-dp tables / [`crate::costmodel::ProfileCache`]), so the
//! refinement never re-profiles: `t_layer` falls out of the profile and
//! every feasibility probe goes through
//! [`crate::costmodel::evaluate_with_profiles`].

use crate::comm::CommAlgo;
use crate::costmodel::{
    evaluate_with_profiles, GroupPlan, LayerProfile, ModelShape, Schedule, Strategy,
};
use crate::hetero::ChipGroup;

/// Per-group immutable candidate: (s_tp, s_pp) already fixed by the DFS.
#[derive(Clone, Copy, Debug)]
pub struct GroupShape {
    /// Tensor-parallel degree fixed by the DFS.
    pub s_tp: usize,
    /// Pipeline-stage count fixed by the DFS.
    pub s_pp: usize,
}

/// Outcome of the sharding heuristic.
#[derive(Clone, Debug)]
pub struct Sharding {
    /// Per-group layer allocation (positionally matched with the groups).
    pub plans: Vec<GroupPlan>,
    /// Whether a memory-feasible allocation summing to the model was found.
    pub feasible: bool,
}

/// Incrementally maintained state of the integer refinement: the assigned
/// layer total and each group's per-stage load `lps_i · t_i`. Moving one
/// layer-per-stage touches one entry and the total — O(1) — where the old
/// loop re-summed the whole allocation per move. The load is always
/// recomputed as the *same expression* (`lps as f64 * t`) a full rebuild
/// would use, so incremental and full evaluation are bit-identical (the
/// debug asserts below, and the `incremental_refinement_matches_full_
/// recompute` test, hold this).
struct StageTimes {
    /// Per-group per-stage compute load, seconds (`lps_i · t_i`).
    loads: Vec<f64>,
    /// Total layers currently assigned (`Σ lps_i · s_pp_i`).
    assigned: i64,
}

impl StageTimes {
    fn new(lps: &[i64], shapes: &[GroupShape], t_layer: &[f64]) -> StageTimes {
        StageTimes {
            loads: lps.iter().zip(t_layer).map(|(&l, &t)| l as f64 * t).collect(),
            assigned: lps.iter().zip(shapes).map(|(l, s)| l * s.s_pp as i64).sum(),
        }
    }

    /// Re-derive group `i`'s load after its `lps` changed by `delta`.
    fn apply_move(&mut self, i: usize, delta: i64, lps: &[i64], shapes: &[GroupShape],
                  t_layer: &[f64]) {
        self.loads[i] = lps[i] as f64 * t_layer[i];
        self.assigned += delta * shapes[i].s_pp as i64;
    }

    /// Debug-only: the incremental state must match a from-scratch rebuild
    /// bit for bit.
    fn debug_assert_matches(&self, lps: &[i64], shapes: &[GroupShape], t_layer: &[f64]) {
        if cfg!(debug_assertions) {
            let full = StageTimes::new(lps, shapes, t_layer);
            debug_assert_eq!(self.assigned, full.assigned, "incremental layer total drifted");
            for (i, (a, b)) in self.loads.iter().zip(&full.loads).enumerate() {
                debug_assert!(a.to_bits() == b.to_bits(),
                              "incremental load {i} drifted: {a} vs {b}");
            }
        }
    }
}

/// Compute the layer allocation for fixed (s_dp, s_ep, shapes) under `schedule`
/// (whose bubble coefficient and activation residency shape both the cost
/// evaluation and the memory-repair loop) and `comm_algo` (which prices
/// the DP-sync term of the evaluations). `profiles` carries one
/// [`LayerProfile`] per group for the chosen `s_tp` under `comm_algo` and
/// the affine NIC mapping — what the search's per-dp tables already own.
#[allow(clippy::too_many_arguments)]
pub fn shard_layers(
    model: &ModelShape,
    groups: &[ChipGroup],
    shapes: &[GroupShape],
    s_dp: usize,
    s_ep: usize,
    micro_batches: usize,
    micro_tokens: usize,
    schedule: Schedule,
    comm_algo: CommAlgo,
    profiles: &[LayerProfile],
) -> Sharding {
    let n = groups.len();
    assert_eq!(n, shapes.len());
    assert_eq!(n, profiles.len());
    let total_layers = model.n_layers;

    // Per-layer single-microbatch time (fwd+bwd, no recompute) per group —
    // read off the supplied profiles instead of re-profiling.
    let t_layer: Vec<f64> = profiles.iter().map(|p| p.t_fwd + p.t_bwd).collect();

    // 1) Continuous equalization: lps_i ∝ 1/t_i, scaled so layers sum to L.
    //    Σ s_pp_i · lps_i = L with lps_i = K / t_i  =>  K = L / Σ(s_pp_i/t_i).
    let denom: f64 = shapes.iter().zip(&t_layer).map(|(s, t)| s.s_pp as f64 / t).sum();
    let k = total_layers as f64 / denom;
    let mut lps: Vec<i64> = t_layer
        .iter()
        .map(|t| ((k / t).round() as i64).max(1))
        .collect();

    // 2/3) Integer refinement: move stage-layers until the total matches L.
    //    Removing from the group with the highest per-stage load first;
    //    adding to the group with the lowest. The table keeps the total
    //    and the loads incrementally (O(1) per move).
    let mut table = StageTimes::new(&lps, shapes, &t_layer);
    let mut guard = 0;
    while table.assigned != total_layers as i64 && guard < 10_000 {
        guard += 1;
        if table.assigned > total_layers as i64 {
            // Drop one layer-per-stage from the group whose removal best
            // reduces the bottleneck but keeps lps >= 1 and doesn't
            // overshoot below L more than necessary.
            let mut best: Option<(usize, f64)> = None;
            for i in 0..n {
                if lps[i] <= 1 {
                    continue;
                }
                let load = table.loads[i];
                if best.map(|(_, l)| load > l).unwrap_or(true) {
                    best = Some((i, load));
                }
            }
            match best {
                Some((i, _)) => {
                    lps[i] -= 1;
                    table.apply_move(i, -1, &lps, shapes, &t_layer);
                }
                None => break, // cannot shrink further
            }
        } else {
            // Add one layer-per-stage to the group with the lowest load.
            let mut best: Option<(usize, f64)> = None;
            for i in 0..n {
                let load = (lps[i] + 1) as f64 * t_layer[i];
                if best.map(|(_, l)| load < l).unwrap_or(true) {
                    best = Some((i, load));
                }
            }
            let i = best.unwrap().0;
            lps[i] += 1;
            table.apply_move(i, 1, &lps, shapes, &t_layer);
        }
        table.debug_assert_matches(&lps, shapes, &t_layer);
    }

    // Exact match may be impossible (e.g. all stages at lps=1 already sums
    // above L). Declare infeasible if so.
    if table.assigned != total_layers as i64 {
        return Sharding {
            plans: shapes
                .iter()
                .zip(&lps)
                .map(|(s, &l)| GroupPlan {
                    s_pp: s.s_pp,
                    s_tp: s.s_tp,
                    layers: (l as usize) * s.s_pp,
                    recompute: false,
                })
                .collect(),
            feasible: false,
        };
    }

    // 4) Memory repair: enable recompute per group under pressure, then (if
    // still infeasible) shift layers away from the offending group.
    let mut plans: Vec<GroupPlan> = shapes
        .iter()
        .zip(&lps)
        .map(|(s, &l)| GroupPlan {
            s_pp: s.s_pp,
            s_tp: s.s_tp,
            layers: (l as usize) * s.s_pp,
            recompute: false,
        })
        .collect();

    let grefs: Vec<&ChipGroup> = groups.iter().collect();
    for _round in 0..8 {
        let strategy =
            Strategy { s_ep, s_dp, micro_batches, schedule, comm_algo, plans: plans.clone() };
        let eval = evaluate_with_profiles(model, &grefs, &strategy, micro_tokens, profiles);
        if eval.feasible {
            return Sharding { plans, feasible: true };
        }
        let mut changed = false;
        for (i, plan) in plans.iter_mut().enumerate() {
            let budget = groups[i].spec.memory_bytes() * crate::costmodel::MEMORY_SAFETY;
            if eval.peak_memory[i] > budget {
                if !plan.recompute {
                    plan.recompute = true;
                    changed = true;
                } else if plan.layers > plan.s_pp {
                    // Shed one layer-per-stage; the re-balance pass below
                    // hands the freed layers to groups with headroom.
                    plan.layers -= plan.s_pp;
                    changed = true;
                }
            }
        }
        if changed {
            // Re-balance the total after any layer removals.
            let short = total_layers as i64
                - plans.iter().map(|p| p.layers as i64).sum::<i64>();
            if short > 0 {
                // Give the missing layers to groups with memory headroom,
                // cheapest-load first.
                let mut missing = short;
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| t_layer[a].partial_cmp(&t_layer[b]).unwrap());
                'outer: while missing > 0 {
                    let mut progressed = false;
                    for &i in &order {
                        if missing < plans[i].s_pp as i64 {
                            continue;
                        }
                        plans[i].layers += plans[i].s_pp;
                        missing -= plans[i].s_pp as i64;
                        progressed = true;
                        if missing == 0 {
                            break 'outer;
                        }
                    }
                    if !progressed {
                        break;
                    }
                }
                if missing != 0 {
                    return Sharding { plans, feasible: false };
                }
            }
        } else {
            return Sharding { plans, feasible: false };
        }
    }
    Sharding { plans, feasible: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{profile_layer_comm, H2_100B};
    use crate::hetero::{ChipGroup, ChipKind};
    use crate::topology::NicAssignment;

    fn groups_ab() -> Vec<ChipGroup> {
        vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 256)]
    }

    /// Profiles matching (groups, shapes, dp) under `comm_algo` — what the
    /// search's DFS hands to [`shard_layers`].
    fn profiles_for(
        groups: &[ChipGroup],
        shapes: &[GroupShape],
        s_dp: usize,
        comm_algo: CommAlgo,
    ) -> Vec<LayerProfile> {
        groups
            .iter()
            .zip(shapes)
            .map(|(g, s)| {
                profile_layer_comm(&g.spec, &H2_100B, s.s_tp, 4096, s_dp, 1, comm_algo,
                                   NicAssignment::Affinity)
            })
            .collect()
    }

    fn shard(
        groups: &[ChipGroup],
        shapes: &[GroupShape],
        s_dp: usize,
        micro_batches: usize,
    ) -> Sharding {
        let profiles = profiles_for(groups, shapes, s_dp, CommAlgo::Ring);
        shard_layers(&H2_100B, groups, shapes, s_dp, 1, micro_batches, 4096,
                     Schedule::OneF1B, CommAlgo::Ring, &profiles)
    }

    #[test]
    fn layers_sum_to_model_total() {
        let groups = groups_ab();
        let shapes = [GroupShape { s_tp: 4, s_pp: 16 }, GroupShape { s_tp: 4, s_pp: 16 }];
        let s = shard(&groups, &shapes, 4, 128);
        assert_eq!(s.plans.iter().map(|p| p.layers).sum::<usize>(), 96);
    }

    #[test]
    fn faster_group_receives_more_layers() {
        let groups = groups_ab();
        let shapes = [GroupShape { s_tp: 4, s_pp: 16 }, GroupShape { s_tp: 4, s_pp: 16 }];
        let s = shard(&groups, &shapes, 4, 128);
        // B is faster per layer than A, so B's stages should carry >= layers.
        assert!(s.plans[1].layers >= s.plans[0].layers,
                "A={} B={}", s.plans[0].layers, s.plans[1].layers);
    }

    #[test]
    fn uniform_within_group() {
        let groups = groups_ab();
        let shapes = [GroupShape { s_tp: 4, s_pp: 12 }, GroupShape { s_tp: 4, s_pp: 16 }];
        let s = shard(&groups, &shapes, 4, 128);
        for p in &s.plans {
            assert_eq!(p.layers % p.s_pp, 0, "layers uniform across a type's stages");
        }
    }

    #[test]
    fn memory_pressure_enables_recompute() {
        // Chip C with little memory must end up recomputing.
        let groups = vec![ChipGroup::new(ChipKind::C, 256)];
        let shapes = [GroupShape { s_tp: 4, s_pp: 32 }];
        let s = shard(&groups, &shapes, 2, 256);
        assert!(s.feasible);
        assert!(s.plans[0].recompute);
    }

    #[test]
    fn incremental_refinement_matches_full_recompute() {
        // Reference implementation of the integer refinement: the pre-table
        // loop that re-summed the allocation per move. The incremental
        // path must produce bit-identical lps trajectories — same moves,
        // same order — hence identical plans.
        fn reference_lps(shapes: &[GroupShape], t_layer: &[f64], total_layers: usize)
                         -> Vec<i64> {
            let n = shapes.len();
            let denom: f64 =
                shapes.iter().zip(t_layer).map(|(s, t)| s.s_pp as f64 / t).sum();
            let k = total_layers as f64 / denom;
            let mut lps: Vec<i64> =
                t_layer.iter().map(|t| ((k / t).round() as i64).max(1)).collect();
            let assigned = |lps: &[i64]| -> i64 {
                lps.iter().zip(shapes).map(|(l, s)| l * s.s_pp as i64).sum()
            };
            let mut guard = 0;
            while assigned(&lps) != total_layers as i64 && guard < 10_000 {
                guard += 1;
                if assigned(&lps) > total_layers as i64 {
                    let mut best: Option<(usize, f64)> = None;
                    for i in 0..n {
                        if lps[i] <= 1 {
                            continue;
                        }
                        let load = lps[i] as f64 * t_layer[i];
                        if best.map(|(_, l)| load > l).unwrap_or(true) {
                            best = Some((i, load));
                        }
                    }
                    match best {
                        Some((i, _)) => lps[i] -= 1,
                        None => break,
                    }
                } else {
                    let mut best: Option<(usize, f64)> = None;
                    for i in 0..n {
                        let load = (lps[i] + 1) as f64 * t_layer[i];
                        if best.map(|(_, l)| load < l).unwrap_or(true) {
                            best = Some((i, load));
                        }
                    }
                    lps[best.unwrap().0] += 1;
                }
            }
            lps
        }

        use crate::util::prop;
        use crate::util::rng::Rng;
        prop::check(100, |rng: &mut Rng| {
            let kinds = [ChipKind::A, ChipKind::B, ChipKind::C, ChipKind::D];
            let n = rng.usize(1, 5);
            let mut groups = Vec::new();
            let mut shapes = Vec::new();
            for _ in 0..n {
                let kind = *rng.choose(&kinds);
                groups.push(ChipGroup::new(kind, 256));
                let s_tp = 1usize << rng.usize(0, 3);
                let s_pp = *rng.choose(&[4usize, 8, 12, 16, 32]);
                shapes.push(GroupShape { s_tp, s_pp });
            }
            let s_dp = *rng.choose(&[1usize, 2, 4]);
            let profiles = profiles_for(&groups, &shapes, s_dp, CommAlgo::Ring);
            let t_layer: Vec<f64> = profiles.iter().map(|p| p.t_fwd + p.t_bwd).collect();
            let expect = reference_lps(&shapes, &t_layer, H2_100B.n_layers);
            let got = shard_layers(&H2_100B, &groups, &shapes, s_dp, 1, 64, 4096,
                                   Schedule::OneF1B, CommAlgo::Ring, &profiles);
            // Compare through the pre-repair allocation: layers = lps·s_pp.
            // Memory repair only runs when the totals match, and both paths
            // share it, so comparing the refined totals pins the loop.
            let expect_total: i64 =
                expect.iter().zip(&shapes).map(|(l, s)| l * s.s_pp as i64).sum();
            let got_total: i64 = got.plans.iter().map(|p| p.layers as i64).sum();
            if expect_total != H2_100B.n_layers as i64 {
                // Reference couldn't hit L either — shard_layers must agree
                // it is infeasible.
                return prop::assert_prop(!got.feasible, "feasibility drifted");
            }
            if got.feasible && got.plans.iter().all(|p| !p.recompute) {
                // No memory repair touched the allocation: the incremental
                // refinement's result must equal the reference exactly.
                for (i, (p, l)) in got.plans.iter().zip(&expect).enumerate() {
                    prop::assert_prop(
                        p.layers as i64 == l * shapes[i].s_pp as i64,
                        format!("group {i}: {} != {}", p.layers,
                                l * shapes[i].s_pp as i64),
                    )?;
                }
            }
            // Whatever repair did, a feasible sharding places every layer.
            prop::assert_prop(!got.feasible || got_total == H2_100B.n_layers as i64,
                              format!("feasible sharding totals {got_total}"))
        });
    }
}
