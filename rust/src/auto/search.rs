//! HeteroAuto DFS strategy search (§4.3.3).
//!
//! Step 1 — depth-first search over the parallelism space: data-parallel
//! candidates dividing the global batch; per chip type, tensor-parallel
//! degrees in powers of two up to `TP_MAX_i`; pipeline degree from
//! `N_i = s_pp,i · s_tp,i · s_dp`. Types are visited in descending memory
//! order (the HeteroPP stage order).
//!
//! Step 2 — optimal layer sharding per configuration (see [`super::sharding`]).
//!
//! Step 3 — cost estimation with the §4.3.2 model; the feasible minimum wins.
//!
//! The **two-stage** refinement fixes `s_dp` from a coarse pass, then splits
//! each homogeneous group into pseudo-heterogeneous subgroups (128 chips in
//! the paper) re-searched with the monotone-TP pruning rule
//! (`s_tp,a ≥ s_tp,b` for earlier subgroups of the same type).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::costmodel::{evaluate, Evaluation, ModelShape, Strategy};
use crate::hetero::{ChipGroup, Cluster};

use super::sharding::{shard_layers, GroupShape};

/// Search configuration.
#[derive(Clone, Copy, Debug)]
pub struct SearchConfig {
    /// Pipeline bubble coefficient (1.0 = 1F1B, 0.0 = ZB-V).
    pub alpha: f64,
    /// Subgroup size for the two-stage refinement (paper: 128 chips).
    pub group_split: usize,
    /// Run the two-stage refinement.
    pub two_stage: bool,
    /// Cap on candidate data-parallel degrees (0 = no cap).
    pub max_dp: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig { alpha: 1.0, group_split: 128, two_stage: true, max_dp: 0 }
    }
}

/// Result of a HeteroAuto search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub strategy: Strategy,
    pub eval: Evaluation,
    /// Groups (memory-descending) matching strategy.plans — includes the
    /// pseudo-subgroups if the two-stage refinement produced them.
    pub groups: Vec<ChipGroup>,
    pub candidates_explored: usize,
    pub elapsed_seconds: f64,
}

impl SearchResult {
    /// Package the searched strategy as a serializable
    /// [`crate::plan::ExecutionPlan`] — the HeteroAuto → HeteroPP handoff.
    /// Communication options take the plan defaults (device-direct RDMA,
    /// SR&AG, NIC affinity, overlap on); callers adjust the returned plan's
    /// fields for ablations.
    pub fn to_plan(
        &self,
        model: &ModelShape,
        cluster: &Cluster,
        gbs_tokens: usize,
        cfg: &SearchConfig,
    ) -> crate::plan::ExecutionPlan {
        // The search floors the batch to whole sequences; the plan records
        // the tokens actually scheduled so its TGS matches the modeled work.
        let whole = (gbs_tokens / model.seq_len) * model.seq_len;
        crate::plan::PlanBuilder::new(&format!("{}-heteroauto", cluster.name))
            .model(*model)
            .cluster(cluster.clone())
            .stage_groups(self.groups.clone())
            .strategy(self.strategy.clone())
            .gbs_tokens(whole)
            .micro_tokens(model.seq_len)
            .alpha(cfg.alpha)
            .build()
            .expect("HeteroAuto produced a structurally invalid strategy")
    }

    /// Consuming form of [`SearchResult::to_plan`] for callers done with
    /// the search result.
    pub fn into_plan(
        self,
        model: &ModelShape,
        cluster: &Cluster,
        gbs_tokens: usize,
        cfg: &SearchConfig,
    ) -> crate::plan::ExecutionPlan {
        self.to_plan(model, cluster, gbs_tokens, cfg)
    }
}

/// Powers of two 1..=tp_max that divide `n`.
fn tp_candidates(n_chips: usize, tp_max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut tp = 1;
    while tp <= tp_max {
        if n_chips % tp == 0 {
            v.push(tp);
        }
        tp *= 2;
    }
    v
}

/// Divisors of `sequences` usable as s_dp (every group must split evenly).
///
/// Divisors come in pairs `(d, sequences/d)`, so scanning `d` up to
/// `sqrt(sequences)` finds them all — O(sqrt n) instead of the O(n) scan
/// that dominated large-GBS searches (sequences is GBS/seq_len, easily
/// in the thousands).
fn dp_candidates(sequences: usize, groups: &[ChipGroup], max_dp: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut accept = |dp: usize| {
        if max_dp > 0 && dp > max_dp {
            return;
        }
        // Every group must be divisible by dp (leaving >= 1 chip per stage).
        if groups.iter().all(|g| g.n_chips % dp == 0 && g.n_chips / dp >= 1) {
            v.push(dp);
        }
    };
    let mut d = 1;
    while d * d <= sequences {
        if sequences % d == 0 {
            accept(d);
            if d != sequences / d {
                accept(sequences / d);
            }
        }
        d += 1;
    }
    v.sort_unstable();
    v
}

struct DfsCtx<'a> {
    model: &'a ModelShape,
    groups: &'a [ChipGroup],
    s_dp: usize,
    micro_batches: usize,
    micro_tokens: usize,
    alpha: f64,
    monotone_tp: bool,
    explored: usize,
    best: Option<(f64, Strategy, Evaluation)>,
}

impl<'a> DfsCtx<'a> {
    fn dfs(&mut self, idx: usize, shapes: &mut Vec<GroupShape>) {
        if idx == self.groups.len() {
            self.explored += 1;
            let sharding = shard_layers(
                self.model, self.groups, shapes, self.s_dp,
                self.micro_batches, self.micro_tokens, self.alpha,
            );
            if !sharding.feasible {
                return;
            }
            let strategy = Strategy {
                s_dp: self.s_dp,
                micro_batches: self.micro_batches,
                plans: sharding.plans,
            };
            let grefs: Vec<&ChipGroup> = self.groups.iter().collect();
            let eval = evaluate(self.model, &grefs, &strategy, self.micro_tokens, self.alpha);
            if !eval.feasible {
                return;
            }
            let t = eval.iteration_seconds;
            if self.best.as_ref().map(|(bt, _, _)| t < *bt).unwrap_or(true) {
                self.best = Some((t, strategy, eval));
            }
            return;
        }
        let g = &self.groups[idx];
        for tp in tp_candidates(g.n_chips, g.spec.tp_max()) {
            if g.n_chips % (tp * self.s_dp) != 0 {
                continue;
            }
            let s_pp = g.n_chips / (tp * self.s_dp);
            if s_pp == 0 {
                continue;
            }
            // Monotone-TP pruning within a chip type (two-stage constraint).
            if self.monotone_tp && idx > 0 {
                let prev = &self.groups[idx - 1];
                if prev.spec.kind == g.spec.kind && shapes[idx - 1].s_tp < tp {
                    continue;
                }
            }
            shapes.push(GroupShape { s_tp: tp, s_pp });
            self.dfs(idx + 1, shapes);
            shapes.pop();
        }
    }
}

fn run_dfs(
    model: &ModelShape,
    groups: &[ChipGroup],
    sequences: usize,
    dp_choices: &[usize],
    cfg: &SearchConfig,
    monotone_tp: bool,
) -> (usize, Option<(f64, Strategy, Evaluation)>) {
    let mut explored = 0;
    let mut best: Option<(f64, Strategy, Evaluation)> = None;
    for &dp in dp_choices {
        let micro_batches = sequences / dp;
        let mut ctx = DfsCtx {
            model,
            groups,
            s_dp: dp,
            micro_batches,
            micro_tokens: model.seq_len, // paper: micro batch size pinned to 1
            alpha: cfg.alpha,
            monotone_tp,
            explored: 0,
            best: None,
        };
        let mut shapes = Vec::with_capacity(groups.len());
        ctx.dfs(0, &mut shapes);
        explored += ctx.explored;
        if let Some((t, s, e)) = ctx.best {
            if best.as_ref().map(|(bt, _, _)| t < *bt).unwrap_or(true) {
                best = Some((t, s, e));
            }
        }
    }
    (explored, best)
}

/// Split each homogeneous group into `split`-chip pseudo-heterogeneous
/// subgroups (two-stage refinement, §4.3.3).
fn split_groups(groups: &[ChipGroup], split: usize) -> Vec<ChipGroup> {
    let mut out = Vec::new();
    for g in groups {
        if g.n_chips <= split {
            out.push(g.clone());
            continue;
        }
        let node = g.spec.chips_per_node;
        let mut chunk = split.max(node);
        chunk -= chunk % node; // whole nodes
        let mut rest = g.n_chips;
        while rest > 0 {
            let take = chunk.min(rest);
            out.push(ChipGroup::new(g.spec.kind, take));
            rest -= take;
        }
    }
    out
}

/// Run HeteroAuto over a cluster for a global batch of `gbs_tokens`.
pub fn search(
    model: &ModelShape,
    cluster: &Cluster,
    gbs_tokens: usize,
    cfg: &SearchConfig,
) -> Result<SearchResult> {
    let start = Instant::now();
    let sequences = gbs_tokens / model.seq_len;
    if sequences == 0 {
        bail!("global batch smaller than one sequence");
    }
    // Memory-descending group order = HeteroPP stage order (Observation #4).
    let groups: Vec<ChipGroup> = cluster
        .groups_by_memory_desc()
        .into_iter()
        .cloned()
        .collect();

    let dp_choices = dp_candidates(sequences, &groups, cfg.max_dp);
    if dp_choices.is_empty() {
        bail!("no feasible data-parallel degree for cluster `{}`", cluster.name);
    }

    // Stage 1: coarse search, one group per chip type.
    let (mut explored, coarse) = run_dfs(model, &groups, sequences, &dp_choices, cfg, false);
    let coarse = match coarse {
        Some(c) => c,
        None => bail!("no feasible strategy found for `{}`", cluster.name),
    };

    if !cfg.two_stage {
        let (t, strategy, eval) = coarse;
        let _ = t;
        return Ok(SearchResult {
            strategy,
            eval,
            groups,
            candidates_explored: explored,
            elapsed_seconds: start.elapsed().as_secs_f64(),
        });
    }

    // Stage 2: fix s_dp, split homogeneous groups into pseudo-heterogeneous
    // subgroups, and re-search with monotone-TP pruning.
    let fixed_dp = [coarse.1.s_dp];
    let fine_groups = split_groups(&groups, cfg.group_split);
    let (explored2, fine) = run_dfs(model, &fine_groups, sequences, &fixed_dp, cfg, true);
    explored += explored2;

    // Keep whichever stage produced the better feasible strategy.
    let use_fine = fine.as_ref().map(|(t, _, _)| *t < coarse.0).unwrap_or(false);
    let (strategy, eval, out_groups) = if use_fine {
        let (_, s, e) = fine.unwrap();
        (s, e, fine_groups)
    } else {
        let (_, s, e) = coarse;
        (s, e, groups)
    };

    Ok(SearchResult {
        strategy,
        eval,
        groups: out_groups,
        candidates_explored: explored,
        elapsed_seconds: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::H2_100B;
    use crate::hetero::{experiment, homogeneous_baseline, ChipKind};

    #[test]
    fn tp_candidates_respect_max() {
        assert_eq!(tp_candidates(256, 4), vec![1, 2, 4]);
        assert_eq!(tp_candidates(256, 16), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn dp_candidates_divide_everything() {
        let groups = vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 256)];
        let dps = dp_candidates(512, &groups, 0);
        assert!(dps.contains(&1) && dps.contains(&4) && dps.contains(&256));
        for dp in dps {
            assert_eq!(512 % dp, 0);
            assert_eq!(256 % dp, 0);
        }
    }

    #[test]
    fn dp_candidates_match_naive_scan() {
        // The sqrt divisor-pair walk must agree exactly with the O(n)
        // reference on sequences both square and not, with and without caps.
        let naive = |sequences: usize, groups: &[ChipGroup], max_dp: usize| -> Vec<usize> {
            (1..=sequences)
                .filter(|dp| {
                    sequences % dp == 0
                        && (max_dp == 0 || *dp <= max_dp)
                        && groups.iter().all(|g| g.n_chips % dp == 0)
                })
                .collect()
        };
        let groups = vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 512)];
        for sequences in [1usize, 2, 12, 256, 511, 512, 1024, 1536, 4096] {
            for max_dp in [0usize, 1, 3, 16, 10_000] {
                assert_eq!(
                    dp_candidates(sequences, &groups, max_dp),
                    naive(sequences, &groups, max_dp),
                    "sequences={sequences} max_dp={max_dp}"
                );
            }
        }
    }

    #[test]
    fn into_plan_roundtrips_the_search() {
        let exp = experiment("exp-a-1").unwrap();
        let cfg = SearchConfig::default();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        let strategy = r.strategy.clone();
        let eval_iter = r.eval.iteration_seconds;
        let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg);
        assert_eq!(plan.strategy, strategy);
        assert_eq!(plan.gbs_tokens, exp.gbs_tokens);
        assert!(plan.validate().is_ok());
        // The plan's cost-model view is bit-identical to the search's.
        assert_eq!(plan.evaluate().iteration_seconds, eval_iter);
    }

    #[test]
    fn split_groups_whole_nodes() {
        let groups = vec![ChipGroup::new(ChipKind::B, 1024)];
        let sub = split_groups(&groups, 128);
        assert_eq!(sub.len(), 8);
        assert!(sub.iter().all(|g| g.n_chips == 128));
    }

    #[test]
    fn homogeneous_search_finds_table6_like_config() {
        let exp = homogeneous_baseline(ChipKind::A);
        let cfg = SearchConfig { two_stage: false, ..Default::default() };
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        assert!(r.eval.feasible);
        let plan = r.strategy.plans[0];
        assert_eq!(plan.s_pp * plan.s_tp * r.strategy.s_dp, 256);
        assert_eq!(plan.layers, 96);
    }

    #[test]
    fn hetero_search_exp_a_runs_and_is_feasible() {
        let exp = experiment("exp-a-1").unwrap();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default()).unwrap();
        assert!(r.eval.feasible);
        assert_eq!(r.strategy.total_layers(), 96);
        assert!(r.candidates_explored > 0);
        // All chips of every group must be used exactly.
        for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
            assert_eq!(g.n_chips, p.s_pp * p.s_tp * r.strategy.s_dp,
                       "group {} chip accounting", g.spec.kind);
        }
    }

    #[test]
    fn two_stage_never_worse_than_coarse() {
        let exp = experiment("exp-c-1").unwrap();
        let coarse = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                            &SearchConfig { two_stage: false, ..Default::default() }).unwrap();
        let fine = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig::default()).unwrap();
        assert!(fine.eval.iteration_seconds <= coarse.eval.iteration_seconds * 1.0001);
    }
}
