//! HeteroAuto DFS strategy search (§4.3.3), schedule- and comm-algo-aware
//! and parallel.
//!
//! Step 1 — depth-first search over the parallelism space: data-parallel
//! candidates dividing the global batch; per chip type, tensor-parallel
//! degrees in powers of two up to `TP_MAX_i`; pipeline degree from
//! `N_i = s_pp,i · s_tp,i · s_dp`; for MoE models an expert-parallel
//! degree dividing both `s_dp` and the expert count; and the pipeline
//! [`Schedule`] plus the DP-collective [`CommAlgo`] as extra search
//! dimensions. Types are visited in descending memory order (the HeteroPP
//! stage order).
//!
//! Step 2 — optimal layer sharding per configuration (see [`super::sharding`]).
//!
//! Step 3 — cost estimation with the §4.3.2 model; the feasible minimum wins.
//!
//! # The hot path
//!
//! Every per-layer profile the search consumes goes through one shared
//! [`ProfileCache`], so `profile_layer`-style work is done once per
//! *distinct* `(chip, s_tp, micro_tokens, s_dp, comm-algo)` shape instead
//! of per DFS leaf; the leaves hand those profiles straight to
//! [`shard_layers`] and [`evaluate_with_profiles`].
//!
//! The outer (s_dp × schedule × comm-algo) candidates are decomposed onto
//! a shared work queue of tasks — a whole job, or one top-level DFS branch
//! of a large job (see `SPLIT_MIN_LEAVES`) — drained by scoped worker
//! threads (the offline vendor set has no rayon; `std::thread::scope`
//! plays its role) with incumbent-cost branch-and-bound pruning: a shared
//! atomic incumbent tracks the best feasible iteration time, and any DFS
//! subtree whose admissible lower bound already exceeds it is cut. The
//! bound combines a compute packing floor with a schedule-aware bubble
//! floor and a DP-sync/update floor (see `DfsCtx::lower_bound`), each
//! provably optimistic, so pruning is *strict*: only subtrees provably
//! worse than the incumbent are cut, and the final reduction takes the
//! minimum in deterministic task order (s_dp ascending, schedules then
//! comm algos in configured order, top-level branches then DFS order
//! within), so the parallel search returns bit-identically the same
//! strategy as the sequential one regardless of thread timing.
//!
//! The **two-stage** refinement fixes `s_dp` from a coarse pass, then splits
//! each homogeneous group into pseudo-heterogeneous subgroups (128 chips in
//! the paper) re-searched with the monotone-TP pruning rule
//! (`s_tp,a ≥ s_tp,b` for earlier subgroups of the same type).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::comm::CommAlgo;
use crate::costmodel::{
    evaluate_with_profiles, Evaluation, LayerProfile, ModelShape, ProfileCache, Schedule,
    Strategy,
};
use crate::hetero::{ChipGroup, Cluster};
use crate::topology::NicAssignment;

use super::sharding::shard_layers;
pub use super::sharding::GroupShape;

/// Search configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Pipeline schedules to search over (default: 1F1B, interleaved:2 and
    /// the zero-bubble schedule). Pin a single entry to fix the schedule.
    pub schedules: Vec<Schedule>,
    /// DP-collective algorithms to search over (default: the topology-aware
    /// [`CommAlgo::Auto`] selector alone, which prices every candidate with
    /// its best algorithm without growing the job count). List concrete
    /// algorithms to measure the axis explicitly, or pin one to fix it.
    pub comm_algos: Vec<CommAlgo>,
    /// Subgroup size for the two-stage refinement (paper: 128 chips).
    pub group_split: usize,
    /// Run the two-stage refinement.
    pub two_stage: bool,
    /// Cap on candidate data-parallel degrees (0 = no cap).
    pub max_dp: usize,
    /// Cap on candidate expert-parallel degrees (0 = no cap; the axis is
    /// model-driven — dense models only ever search `s_ep = 1`). Pin to 1
    /// to measure what the EP axis buys on an MoE model.
    pub max_ep: usize,
    /// Run the outer (s_dp × schedule) loop on worker threads. The result
    /// is bit-identical to the sequential path either way.
    pub parallel: bool,
    /// Emit progress lines on stderr — a periodic line (leaves evaluated /
    /// pruned, incumbent seconds, elapsed) while workers run, plus one
    /// summary per search stage — so long mega-cluster searches are
    /// observable. Off by default; purely observational (no effect on the
    /// searched result).
    pub progress: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            schedules: Schedule::SEARCH_SPACE.to_vec(),
            comm_algos: vec![CommAlgo::Auto],
            group_split: 128,
            two_stage: true,
            max_dp: 0,
            max_ep: 0,
            parallel: true,
            progress: false,
        }
    }
}

impl SearchConfig {
    /// A config pinned to one schedule (other knobs at their defaults) —
    /// what `--schedule` lowers to and what the paper-table drivers use to
    /// stay on the paper's 1F1B baseline.
    pub fn pinned(schedule: Schedule) -> SearchConfig {
        SearchConfig { schedules: vec![schedule], ..SearchConfig::default() }
    }
}

/// Result of a HeteroAuto search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// The winning strategy (its `schedule` field records the winning
    /// pipeline schedule).
    pub strategy: Strategy,
    /// Cost-model evaluation of the winning strategy.
    pub eval: Evaluation,
    /// Groups (memory-descending) matching strategy.plans — includes the
    /// pseudo-subgroups if the two-stage refinement produced them.
    pub groups: Vec<ChipGroup>,
    /// Leaf configurations *reached* — fully evaluated past every bound
    /// cut. Deterministic for a sequential search (pinned by
    /// `evaluated_plus_pruned_covers_the_whole_space`); under the parallel
    /// search the exact evaluated/pruned split depends on incumbent timing
    /// while the winning strategy does not (pinned by
    /// `parallel_search_matches_sequential_bit_for_bit`).
    pub candidates_explored: usize,
    /// Leaf configurations skipped by branch-and-bound subtree cuts,
    /// counted from the per-group option products below each cut point.
    /// Together with [`SearchResult::candidates_explored`] this splits the
    /// whole candidate space into reached vs pruned work (exactly, for the
    /// coarse stage; the monotone-TP rule of the refinement stage makes
    /// its pruned counts an upper accounting of the restricted subtrees).
    pub leaves_pruned: usize,
    /// Wall-clock search time.
    pub elapsed_seconds: f64,
    /// Profile-cache lookups served from the cache during this search.
    /// With a fresh per-search cache most lookups are hits already; a
    /// re-plan over a caller-supplied warm cache ([`search_with_cache`])
    /// should see near-100% hits — this is how that reuse is observed.
    pub cache_hits: usize,
    /// Profile-cache lookups that ran the profiler during this search.
    pub cache_misses: usize,
}

impl SearchResult {
    /// Package the searched strategy as a serializable
    /// [`crate::plan::ExecutionPlan`] — the HeteroAuto → HeteroPP handoff.
    /// Communication options take the plan defaults (device-direct RDMA,
    /// SR&AG, NIC affinity, overlap on); callers adjust the returned plan's
    /// fields for ablations. The winning schedule and DP-collective
    /// algorithm travel inside the strategy, so the search config is not
    /// needed here.
    pub fn to_plan(
        &self,
        model: &ModelShape,
        cluster: &Cluster,
        gbs_tokens: usize,
    ) -> crate::plan::ExecutionPlan {
        // The search floors the batch to whole sequences; the plan records
        // the tokens actually scheduled so its TGS matches the modeled work.
        let whole = (gbs_tokens / model.seq_len) * model.seq_len;
        crate::plan::PlanBuilder::new(&format!("{}-heteroauto", cluster.name))
            .model(*model)
            .cluster(cluster.clone())
            .stage_groups(self.groups.clone())
            .strategy(self.strategy.clone())
            .gbs_tokens(whole)
            .micro_tokens(model.seq_len)
            .build()
            .expect("HeteroAuto produced a structurally invalid strategy")
    }

    /// Consuming form of [`SearchResult::to_plan`] for callers done with
    /// the search result.
    pub fn into_plan(
        self,
        model: &ModelShape,
        cluster: &Cluster,
        gbs_tokens: usize,
    ) -> crate::plan::ExecutionPlan {
        self.to_plan(model, cluster, gbs_tokens)
    }
}

/// Powers of two 1..=tp_max that divide `n`.
fn tp_candidates(n_chips: usize, tp_max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut tp = 1;
    while tp <= tp_max {
        if n_chips % tp == 0 {
            v.push(tp);
        }
        tp *= 2;
    }
    v
}

/// Expert-parallel candidates at a fixed s_dp: divisors of the expert
/// count that also divide the data-parallel degree (EP groups are carved
/// out of the DP replicas and the expert bank must shard evenly). Dense
/// models search only the degenerate `s_ep = 1`.
fn ep_candidates(model: &ModelShape, s_dp: usize, max_ep: usize) -> Vec<usize> {
    if !model.is_moe() {
        return vec![1];
    }
    (1..=model.n_experts)
        .filter(|&ep| {
            (max_ep == 0 || ep <= max_ep) && model.n_experts % ep == 0 && s_dp % ep == 0
        })
        .collect()
}

/// Divisors of `sequences` usable as s_dp (every group must split evenly).
///
/// Divisors come in pairs `(d, sequences/d)`, so scanning `d` up to
/// `sqrt(sequences)` finds them all — O(sqrt n) instead of the O(n) scan
/// that dominated large-GBS searches (sequences is GBS/seq_len, easily
/// in the thousands).
fn dp_candidates(sequences: usize, groups: &[ChipGroup], max_dp: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut accept = |dp: usize| {
        if max_dp > 0 && dp > max_dp {
            return;
        }
        // Every group must be divisible by dp (leaving >= 1 chip per stage).
        if groups.iter().all(|g| g.n_chips % dp == 0 && g.n_chips / dp >= 1) {
            v.push(dp);
        }
    };
    let mut d = 1;
    while d * d <= sequences {
        if sequences % d == 0 {
            accept(d);
            if d != sequences / d {
                accept(sequences / d);
            }
        }
        d += 1;
    }
    v.sort_unstable();
    v
}

/// Shared branch-and-bound incumbent: the best feasible iteration time
/// seen by any worker, as f64 bits in an atomic (all values are positive
/// finite, so float order and the CAS loop agree).
struct Incumbent(AtomicU64);

impl Incumbent {
    fn new(seed: f64) -> Incumbent {
        Incumbent(AtomicU64::new(seed.to_bits()))
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    fn observe(&self, t: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        while t < f64::from_bits(cur) {
            match self.0.compare_exchange_weak(
                cur,
                t.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Leaf accounting for one task / stage: leaves fully evaluated vs leaves
/// skipped under branch-and-bound subtree cuts.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct SearchStats {
    pub(crate) evaluated: usize,
    pub(crate) pruned: usize,
}

/// Milliseconds between `--progress` stderr lines.
const PROGRESS_INTERVAL_MS: u64 = 500;

/// Shared live counters behind `--progress`: workers bump these as they
/// evaluate and prune, and whichever worker crosses the reporting interval
/// first claims the next stderr line via compare-exchange. Disabled, every
/// call is a single branch on a bool.
pub(crate) struct SearchProgress {
    enabled: bool,
    start: Instant,
    evaluated: AtomicUsize,
    pruned: AtomicUsize,
    /// Milliseconds since `start` of the last printed line.
    last_report_ms: AtomicU64,
}

impl SearchProgress {
    pub(crate) fn new(enabled: bool) -> SearchProgress {
        SearchProgress {
            enabled,
            start: Instant::now(),
            evaluated: AtomicUsize::new(0),
            pruned: AtomicUsize::new(0),
            last_report_ms: AtomicU64::new(0),
        }
    }

    /// One leaf evaluated; every 64th leaf checks whether a periodic line
    /// is due (keeping the hot path to a counter bump).
    fn leaf(&self, incumbent: &Incumbent, cache: &ProfileCache) {
        if !self.enabled {
            return;
        }
        let n = self.evaluated.fetch_add(1, Ordering::Relaxed) + 1;
        if n % 64 == 0 {
            self.maybe_report(incumbent, cache);
        }
    }

    /// `leaves` skipped under one subtree cut.
    fn prune(&self, leaves: usize) {
        if !self.enabled {
            return;
        }
        self.pruned.fetch_add(leaves, Ordering::Relaxed);
    }

    fn maybe_report(&self, incumbent: &Incumbent, cache: &ProfileCache) {
        let elapsed_ms = self.start.elapsed().as_millis() as u64;
        let last = self.last_report_ms.load(Ordering::Relaxed);
        if elapsed_ms < last.saturating_add(PROGRESS_INTERVAL_MS) {
            return;
        }
        if self
            .last_report_ms
            .compare_exchange(last, elapsed_ms, Ordering::Relaxed, Ordering::Relaxed)
            .is_err()
        {
            return; // another worker just printed
        }
        let inc = incumbent.get();
        let inc = if inc.is_finite() { format!("{inc:.4}s") } else { "-".to_string() };
        eprintln!(
            "[h2 search] progress: {} leaves evaluated, {} pruned, incumbent {inc}, \
             cache {} hits / {} misses, elapsed {:.1}s",
            self.evaluated.load(Ordering::Relaxed),
            self.pruned.load(Ordering::Relaxed),
            cache.hits(),
            cache.misses(),
            elapsed_ms as f64 / 1000.0,
        );
    }

    /// One line per completed search stage (always printed when enabled,
    /// so even sub-interval searches are observable).
    pub(crate) fn stage_summary(
        &self,
        label: &str,
        stats: SearchStats,
        best: f64,
        cache: &ProfileCache,
    ) {
        if !self.enabled {
            return;
        }
        let best = if best.is_finite() { format!("{best:.4}s") } else { "none".to_string() };
        eprintln!(
            "[h2 search] {label}: {} leaves evaluated, {} pruned, best {best}, \
             cache {} hits / {} misses, elapsed {:.2}s",
            stats.evaluated,
            stats.pruned,
            cache.hits(),
            cache.misses(),
            self.start.elapsed().as_secs_f64(),
        );
    }
}

/// One (tp, s_pp) option for a group at a fixed s_dp, with its per-layer
/// fwd+bwd time and its best-case `s_pp/t` packing ratio contribution.
#[derive(Clone, Copy, Debug)]
struct TpOption {
    s_tp: usize,
    s_pp: usize,
    t_layer: f64,
}

/// Shrinks the lower bound by one part per billion so float rounding in
/// the bound arithmetic can never nudge an exactly-tight bound past the
/// true cost (which would break the strict-pruning ⇒ bit-identical-winner
/// invariant): a *relative* 1e-9 shave dwarfs the relative f64 rounding
/// error of the few dozen operations on either side (~1e-14) while giving
/// up a negligible sliver of pruning power.
const LB_SAFETY: f64 = 1.0 - 1e-9;

/// The admissible-bound arithmetic shared by [`DfsCtx::lower_bound`] and
/// the admissibility tests. `denom` is the optimistic `Σ s_pp/t` packing
/// capacity, `sweep` the optimistic `Σ s_pp·t` one-sweep floor, `own` an
/// upper bound on the unknown bottleneck stage's own per-layer time, and
/// `update_floor` the cheapest per-layer optimizer update anywhere.
fn bound_value(
    micro_batches: f64,
    n_layers: f64,
    alpha: f64,
    update_floor: f64,
    denom: f64,
    sweep: f64,
    own: f64,
) -> f64 {
    if denom <= 0.0 {
        return f64::INFINITY;
    }
    let compute = micro_batches * n_layers / denom;
    let bubble = alpha * (sweep - own).max(0.0);
    (compute + bubble + update_floor) * LB_SAFETY
}

struct DfsCtx<'a> {
    model: &'a ModelShape,
    groups: &'a [ChipGroup],
    /// Per group: the usable (tp, s_pp, t_layer) options at this s_dp.
    options: &'a [Vec<TpOption>],
    /// Per group: suffix sums of the maximal `s_pp/t_layer` ratio over the
    /// group's options — the optimistic packing capacity of the not-yet
    /// assigned groups, used in the compute term of the lower bound.
    ratio_suffix: &'a [f64],
    /// Per group: suffix sums of the *minimal* `s_pp·t_layer` over the
    /// group's options — an optimistic floor on the open groups'
    /// contribution to one full pipeline sweep (every stage holds ≥ 1
    /// layer), used in the bubble term of the lower bound.
    sppt_suffix: &'a [f64],
    /// Per group: suffix max of `t_layer` over the group's options —
    /// bounds the unknown bottleneck stage's own per-layer time that the
    /// bubble term subtracts from the sweep.
    max_t_suffix: &'a [f64],
    /// Per group: suffix product of option counts — the leaves below a
    /// node, charged to [`SearchStats::pruned`] on a subtree cut.
    leaf_suffix: &'a [usize],
    s_dp: usize,
    s_ep: usize,
    micro_batches: usize,
    micro_tokens: usize,
    schedule: Schedule,
    /// `schedule.bubble_coefficient()`, hoisted out of the bound.
    alpha: f64,
    comm_algo: CommAlgo,
    /// Admissible floor on the bottleneck group's update term under this
    /// job's collective algorithm (min `t_update` over every group option).
    update_floor: f64,
    monotone_tp: bool,
    incumbent: &'a Incumbent,
    progress: &'a SearchProgress,
    cache: &'a ProfileCache,
    /// `groups` as refs, built once (the evaluator's calling convention).
    grefs: Vec<&'a ChipGroup>,
    /// Scratch: the current leaf's per-group profiles (cache hits).
    profiles: Vec<LayerProfile>,
    stats: SearchStats,
    best: Option<(f64, Strategy, Evaluation)>,
}

impl<'a> DfsCtx<'a> {
    /// Admissible lower bound on any completion of the current partial
    /// assignment. Three provably optimistic terms:
    ///
    /// * **compute** — every layer must run somewhere, so the bottleneck
    ///   stage computes at least `L / Σ_g (s_pp_g / t_g)` per microbatch
    ///   (assigned groups contribute their actual ratio, open groups their
    ///   best case) and the iteration pays `b ×` that;
    /// * **bubble** — each of the `Σ s_pp_g` stages holds ≥ 1
    ///   layer-per-stage, so one pipeline sweep costs ≥ `Σ_g s_pp_g·t_g`
    ///   (assigned actual, open per-group minimum) and the bottleneck
    ///   stage idles through `α ×` (that sweep minus its own stage time,
    ///   optimistically bounded by the largest per-layer time anywhere);
    /// * **update** — the bottleneck group pays ≥ one layer-per-stage of
    ///   its cheapest option's `t_update` (Adam + the exposed DP-sync
    ///   slice under this job's collective algorithm), floored over every
    ///   group since the bottleneck is unknown.
    ///
    /// Recompute and offload taxes only add, so the bound holds whatever
    /// the sharding decides; `lower_bound_is_admissible_on_every_leaf`
    /// checks it against the true evaluated cost leaf by leaf.
    fn lower_bound(&self, idx: usize, ratio_sum: f64, sppt_sum: f64, max_t: f64) -> f64 {
        bound_value(
            self.micro_batches as f64,
            self.model.n_layers as f64,
            self.alpha,
            self.update_floor,
            ratio_sum + self.ratio_suffix[idx],
            sppt_sum + self.sppt_suffix[idx],
            max_t.max(self.max_t_suffix[idx]),
        )
    }

    fn dfs(
        &mut self,
        idx: usize,
        shapes: &mut Vec<GroupShape>,
        ratio_sum: f64,
        sppt_sum: f64,
        max_t: f64,
    ) {
        if self.lower_bound(idx, ratio_sum, sppt_sum, max_t) > self.incumbent.get() {
            // Provably worse than the incumbent — cut the whole subtree.
            let cut = self.leaf_suffix[idx];
            self.stats.pruned += cut;
            self.progress.prune(cut);
            return;
        }
        let groups = self.groups;
        if idx == groups.len() {
            self.stats.evaluated += 1;
            self.progress.leaf(self.incumbent, self.cache);
            self.profiles.clear();
            for (g, shape) in groups.iter().zip(shapes.iter()) {
                let p = self.cache.profile(
                    &g.spec, self.model, shape.s_tp, self.micro_tokens, self.s_dp,
                    self.s_ep, self.comm_algo, NicAssignment::Affinity,
                );
                self.profiles.push(p);
            }
            let sharding = shard_layers(
                self.model, groups, shapes, self.s_dp, self.s_ep,
                self.micro_batches, self.micro_tokens, self.schedule, self.comm_algo,
                &self.profiles,
            );
            if !sharding.feasible {
                return;
            }
            // Interleaving chunks every stage's layers: reject allocations
            // the virtual-stage count does not divide.
            let v = self.schedule.virtual_stages();
            if v > 1 && sharding.plans.iter().any(|p| p.layers_per_stage() % v != 0) {
                return;
            }
            let strategy = Strategy {
                s_ep: self.s_ep,
                s_dp: self.s_dp,
                micro_batches: self.micro_batches,
                schedule: self.schedule,
                comm_algo: self.comm_algo,
                plans: sharding.plans,
            };
            let eval = evaluate_with_profiles(
                self.model, &self.grefs, &strategy, self.micro_tokens, &self.profiles,
            );
            if !eval.feasible {
                return;
            }
            let t = eval.iteration_seconds;
            if self.best.as_ref().map(|(bt, _, _)| t < *bt).unwrap_or(true) {
                self.best = Some((t, strategy, eval));
            }
            self.incumbent.observe(t);
            return;
        }
        let opts: &[TpOption] = &self.options[idx];
        for opt in opts {
            // Monotone-TP pruning within a chip type (two-stage constraint).
            if self.monotone_tp && idx > 0 {
                let prev = &groups[idx - 1];
                if prev.spec.kind == groups[idx].spec.kind
                    && shapes[idx - 1].s_tp < opt.s_tp
                {
                    continue;
                }
            }
            shapes.push(GroupShape { s_tp: opt.s_tp, s_pp: opt.s_pp });
            self.dfs(
                idx + 1,
                shapes,
                ratio_sum + opt.s_pp as f64 / opt.t_layer,
                sppt_sum + opt.s_pp as f64 * opt.t_layer,
                max_t.max(opt.t_layer),
            );
            shapes.pop();
        }
    }
}

/// One outer-loop candidate: a data-parallel degree, an expert-parallel
/// degree, a schedule and a DP-collective algorithm.
pub(crate) type Job = (usize, usize, Schedule, CommAlgo);

/// One unit of work on the shared queue: a whole job, or (for large jobs)
/// one top-level DFS branch of it.
#[derive(Clone, Copy, Debug)]
struct Task {
    /// Index into the job list.
    job: usize,
    /// `Some(i)` pins the first group to its i-th TP option (a split
    /// branch); `None` runs the job's full DFS.
    root: Option<usize>,
}

/// What one task reports back: its leaf accounting plus its best feasible
/// (cost, strategy, evaluation), if any.
type JobOutcome = (SearchStats, Option<(f64, Strategy, Evaluation)>);

/// Schedule-independent search tables for one (s_dp, s_ep): per-group TP
/// options plus the optimistic suffix tables behind the branch-and-bound
/// lower bound — built once per distinct (s_dp, s_ep) and shared across
/// that pair's schedule and comm-algo jobs. (For MoE models t_fwd/t_bwd
/// carry the EP-dependent all-to-all and hot-rank terms, so the tables
/// cannot be shared across expert-parallel degrees.)
struct DpTable {
    s_dp: usize,
    s_ep: usize,
    options: Vec<Vec<TpOption>>,
    ratio_suffix: Vec<f64>,
    sppt_suffix: Vec<f64>,
    max_t_suffix: Vec<f64>,
    leaf_suffix: Vec<usize>,
}

fn dp_table(
    model: &ModelShape,
    groups: &[ChipGroup],
    s_dp: usize,
    s_ep: usize,
    cache: &ProfileCache,
) -> DpTable {
    let micro_tokens = model.seq_len; // paper: micro batch size pinned to 1
    let options: Vec<Vec<TpOption>> = groups
        .iter()
        .map(|g| {
            tp_candidates(g.n_chips, g.spec.tp_max())
                .into_iter()
                .filter(|tp| g.n_chips % (tp * s_dp) == 0 && g.n_chips / (tp * s_dp) >= 1)
                .map(|tp| {
                    // t_fwd/t_bwd are collective-independent, so one
                    // flat-ring profile prices every job's packing ratio.
                    let p = cache.profile(&g.spec, model, tp, micro_tokens, s_dp, s_ep,
                                          CommAlgo::Ring, NicAssignment::Affinity);
                    TpOption {
                        s_tp: tp,
                        s_pp: g.n_chips / (tp * s_dp),
                        t_layer: p.t_fwd + p.t_bwd,
                    }
                })
                .collect()
        })
        .collect();
    let n = groups.len();
    let mut ratio_suffix = vec![0.0f64; n + 1];
    let mut sppt_suffix = vec![0.0f64; n + 1];
    let mut max_t_suffix = vec![0.0f64; n + 1];
    let mut leaf_suffix = vec![1usize; n + 1];
    for idx in (0..n).rev() {
        let best_ratio = options[idx]
            .iter()
            .map(|o| o.s_pp as f64 / o.t_layer)
            .fold(0.0f64, f64::max);
        ratio_suffix[idx] = ratio_suffix[idx + 1] + best_ratio;
        // A group with no options has no completions at all; contribute
        // nothing rather than poisoning the floor (the DFS dead-ends there
        // with zero leaves anyway).
        let min_sppt = options[idx]
            .iter()
            .map(|o| o.s_pp as f64 * o.t_layer)
            .fold(f64::INFINITY, f64::min);
        sppt_suffix[idx] = sppt_suffix[idx + 1] + if min_sppt.is_finite() { min_sppt } else { 0.0 };
        let max_t = options[idx].iter().map(|o| o.t_layer).fold(0.0f64, f64::max);
        max_t_suffix[idx] = max_t_suffix[idx + 1].max(max_t);
        leaf_suffix[idx] = leaf_suffix[idx + 1].saturating_mul(options[idx].len());
    }
    DpTable { s_dp, s_ep, options, ratio_suffix, sppt_suffix, max_t_suffix, leaf_suffix }
}

/// Admissible floor on any completion's per-layer update term for one job:
/// whichever group bottlenecks pays at least one layer-per-stage of its
/// cheapest option's `t_update` (Adam + the exposed DP-sync slice under
/// the job's collective algorithm), so the min over every group option is
/// a true floor. Also pre-warms the cache with every (option, comm-algo)
/// shape the job's leaves will request.
#[allow(clippy::too_many_arguments)]
fn update_floor(
    model: &ModelShape,
    groups: &[ChipGroup],
    table: &DpTable,
    s_dp: usize,
    s_ep: usize,
    comm_algo: CommAlgo,
    cache: &ProfileCache,
) -> f64 {
    let micro_tokens = model.seq_len;
    let mut floor = f64::INFINITY;
    for (g, opts) in groups.iter().zip(&table.options) {
        for opt in opts {
            let p = cache.profile(&g.spec, model, opt.s_tp, micro_tokens, s_dp, s_ep,
                                  comm_algo, NicAssignment::Affinity);
            floor = floor.min(p.t_update);
        }
    }
    floor
}

/// Run the DFS for one task over its dp's shared tables.
#[allow(clippy::too_many_arguments)]
fn run_one_task(
    model: &ModelShape,
    groups: &[ChipGroup],
    sequences: usize,
    job: Job,
    task_root: Option<usize>,
    table: &DpTable,
    update_floor: f64,
    monotone_tp: bool,
    incumbent: &Incumbent,
    cache: &ProfileCache,
    progress: &SearchProgress,
) -> JobOutcome {
    let (s_dp, s_ep, schedule, comm_algo) = job;
    debug_assert_eq!(s_dp, table.s_dp);
    debug_assert_eq!(s_ep, table.s_ep);
    let mut ctx = DfsCtx {
        model,
        groups,
        options: &table.options,
        ratio_suffix: &table.ratio_suffix,
        sppt_suffix: &table.sppt_suffix,
        max_t_suffix: &table.max_t_suffix,
        leaf_suffix: &table.leaf_suffix,
        s_dp,
        s_ep,
        micro_batches: sequences / s_dp,
        micro_tokens: model.seq_len,
        schedule,
        alpha: schedule.bubble_coefficient(),
        comm_algo,
        update_floor,
        monotone_tp,
        incumbent,
        progress,
        cache,
        grefs: groups.iter().collect(),
        profiles: Vec::with_capacity(groups.len()),
        stats: SearchStats::default(),
        best: None,
    };
    let mut shapes = Vec::with_capacity(groups.len());
    match task_root {
        None => ctx.dfs(0, &mut shapes, 0.0, 0.0, 0.0),
        Some(r) => {
            // One top-level branch of a split job: pin the first group's
            // option and run the subtree below it (the idx-1 bound check
            // inside dfs is at least as tight as the job-level one).
            let opt = table.options[0][r];
            shapes.push(GroupShape { s_tp: opt.s_tp, s_pp: opt.s_pp });
            ctx.dfs(
                1,
                &mut shapes,
                opt.s_pp as f64 / opt.t_layer,
                opt.s_pp as f64 * opt.t_layer,
                opt.t_layer,
            );
        }
    }
    (ctx.stats, ctx.best)
}

/// Minimum estimated leaf count before a job's top-level DFS branches are
/// split into separate queue tasks. Splitting makes the work units fine
/// enough that a couple of huge jobs cannot serialize the pool, while
/// small jobs stay whole (one queue slot each). The threshold only shapes
/// scheduling — results are reduced in deterministic task order either
/// way.
const SPLIT_MIN_LEAVES: usize = 256;

/// Run every (s_dp × schedule × comm-algo) job through the shared task
/// queue — drained by scoped worker threads when `parallel` — and reduce
/// to the minimum in deterministic task order.
///
/// `seed_incumbent` primes the branch-and-bound bound (`f64::INFINITY` for
/// a fresh search; the coarse best for the two-stage refinement, whose
/// results are only accepted when strictly better anyway, so seeding
/// cannot change the outcome — only skip provably useless work).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_jobs(
    model: &ModelShape,
    groups: &[ChipGroup],
    sequences: usize,
    jobs: &[Job],
    monotone_tp: bool,
    parallel: bool,
    seed_incumbent: f64,
    cache: &ProfileCache,
    progress: &SearchProgress,
) -> (SearchStats, Option<(f64, Strategy, Evaluation)>) {
    let incumbent = Incumbent::new(seed_incumbent);
    // The TP-option tables are schedule-independent: one per distinct
    // (dp, ep) pair, shared by every schedule/comm-algo job at that pair.
    let mut tables: Vec<DpTable> = Vec::new();
    for &(dp, ep, _, _) in jobs {
        if !tables.iter().any(|t| t.s_dp == dp && t.s_ep == ep) {
            tables.push(dp_table(model, groups, dp, ep, cache));
        }
    }
    fn table_for(tables: &[DpTable], dp: usize, ep: usize) -> &DpTable {
        tables
            .iter()
            .find(|t| t.s_dp == dp && t.s_ep == ep)
            .expect("table built for every job (dp, ep)")
    }
    // Per-job admissible update floors (also pre-warm the profile cache).
    // The floor depends only on (dp, ep, comm algo) — dedup across
    // schedules exactly like the dp tables above.
    let mut floors: Vec<f64> = Vec::with_capacity(jobs.len());
    for (i, &(dp, ep, _, algo)) in jobs.iter().enumerate() {
        let f = match jobs[..i]
            .iter()
            .position(|&(d, e, _, a)| d == dp && e == ep && a == algo)
        {
            Some(j) => floors[j],
            None => {
                update_floor(model, groups, table_for(&tables, dp, ep), dp, ep, algo, cache)
            }
        };
        floors.push(f);
    }

    // The shared work queue, in deterministic order: jobs as configured,
    // large jobs fanned into one task per top-level DFS branch.
    let mut tasks: Vec<Task> = Vec::new();
    for (j, &(dp, ep, _, _)) in jobs.iter().enumerate() {
        let table = table_for(&tables, dp, ep);
        let roots = table.options.first().map(|o| o.len()).unwrap_or(0);
        if groups.len() > 1 && roots > 1 && table.leaf_suffix[0] >= SPLIT_MIN_LEAVES {
            for r in 0..roots {
                tasks.push(Task { job: j, root: Some(r) });
            }
        } else {
            tasks.push(Task { job: j, root: None });
        }
    }

    let workers = if parallel {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(tasks.len())
    } else {
        1
    };

    let mut slots: Vec<Option<JobOutcome>> = vec![None; tasks.len()];
    if workers <= 1 {
        for (i, task) in tasks.iter().enumerate() {
            let job = jobs[task.job];
            slots[i] = Some(run_one_task(model, groups, sequences, job, task.root,
                                         table_for(&tables, job.0), floors[task.job],
                                         monotone_tp, &incumbent, cache, progress));
        }
    } else {
        let next = AtomicUsize::new(0);
        let tasks_ref = &tasks;
        let finished = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                let next = &next;
                let incumbent = &incumbent;
                let tables = &tables;
                let floors = &floors;
                handles.push(scope.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks_ref.len() {
                            break;
                        }
                        let task = tasks_ref[i];
                        let job = jobs[task.job];
                        out.push((
                            i,
                            run_one_task(model, groups, sequences, job, task.root,
                                         table_for(tables, job.0), floors[task.job],
                                         monotone_tp, incumbent, cache, progress),
                        ));
                    }
                    out
                }));
            }
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("search worker panicked"))
                .collect::<Vec<_>>()
        });
        for (i, result) in finished {
            slots[i] = Some(result);
        }
    }

    // Deterministic reduction: min by cost with ties broken by task order
    // (s_dp ascending, schedules then comm algos in configured order,
    // top-level branches then DFS order within) — identical to the
    // sequential scan whatever the thread interleaving was.
    let mut stats = SearchStats::default();
    let mut best: Option<(f64, Strategy, Evaluation)> = None;
    for slot in slots {
        let (s, task_best) = slot.expect("every task produces a result");
        stats.evaluated += s.evaluated;
        stats.pruned += s.pruned;
        if let Some((t, st, e)) = task_best {
            if best.as_ref().map(|(bt, _, _)| t < *bt).unwrap_or(true) {
                best = Some((t, st, e));
            }
        }
    }
    (stats, best)
}

/// Split each homogeneous group into `split`-chip pseudo-heterogeneous
/// subgroups (two-stage refinement, §4.3.3).
fn split_groups(groups: &[ChipGroup], split: usize) -> Vec<ChipGroup> {
    let mut out = Vec::new();
    for g in groups {
        if g.n_chips <= split {
            out.push(g.clone());
            continue;
        }
        let node = g.spec.chips_per_node;
        let mut chunk = split.max(node);
        chunk -= chunk % node; // whole nodes
        let mut rest = g.n_chips;
        while rest > 0 {
            let take = chunk.min(rest);
            out.push(ChipGroup::new(g.spec.kind, take));
            rest -= take;
        }
    }
    out
}

/// Run HeteroAuto over a cluster for a global batch of `gbs_tokens`.
pub fn search(
    model: &ModelShape,
    cluster: &Cluster,
    gbs_tokens: usize,
    cfg: &SearchConfig,
) -> Result<SearchResult> {
    // One profile cache for the whole search: both stages, every worker.
    let cache = ProfileCache::new();
    search_with_cache(model, cluster, gbs_tokens, cfg, &cache)
}

/// [`search`] over a caller-supplied [`ProfileCache`] — the re-planning
/// entry point: a warm cache from a previous search over the same chips
/// turns almost every profile lookup into a hit, and the returned
/// [`SearchResult::cache_hits`] / [`SearchResult::cache_misses`] count
/// only *this* search's lookups so the reuse is measurable.
pub fn search_with_cache(
    model: &ModelShape,
    cluster: &Cluster,
    gbs_tokens: usize,
    cfg: &SearchConfig,
    cache: &ProfileCache,
) -> Result<SearchResult> {
    let start = Instant::now();
    let (hits0, misses0) = (cache.hits(), cache.misses());
    let sequences = gbs_tokens / model.seq_len;
    if sequences == 0 {
        bail!("global batch smaller than one sequence");
    }
    if cfg.schedules.is_empty() {
        bail!("search config has no pipeline schedules to explore");
    }
    if cfg.comm_algos.is_empty() {
        bail!("search config has no collective algorithms to explore");
    }
    // Memory-descending group order = HeteroPP stage order (Observation #4).
    let groups: Vec<ChipGroup> = cluster
        .groups_by_memory_desc()
        .into_iter()
        .cloned()
        .collect();

    let dp_choices = dp_candidates(sequences, &groups, cfg.max_dp);
    if dp_choices.is_empty() {
        bail!("no feasible data-parallel degree for cluster `{}`", cluster.name);
    }
    let mut jobs: Vec<Job> = Vec::new();
    for &dp in &dp_choices {
        for ep in ep_candidates(model, dp, cfg.max_ep) {
            for &schedule in &cfg.schedules {
                for &algo in &cfg.comm_algos {
                    jobs.push((dp, ep, schedule, algo));
                }
            }
        }
    }

    let progress = SearchProgress::new(cfg.progress);

    // Stage 1: coarse search, one group per chip type.
    let (stats, coarse) =
        run_jobs(model, &groups, sequences, &jobs, false, cfg.parallel, f64::INFINITY,
                 cache, &progress);
    progress.stage_summary(
        "coarse stage",
        stats,
        coarse.as_ref().map(|c| c.0).unwrap_or(f64::INFINITY),
        cache,
    );
    let coarse = match coarse {
        Some(c) => c,
        None => bail!("no feasible strategy found for `{}`", cluster.name),
    };

    if !cfg.two_stage {
        let (_, strategy, eval) = coarse;
        return Ok(SearchResult {
            strategy,
            eval,
            groups,
            candidates_explored: stats.evaluated,
            leaves_pruned: stats.pruned,
            elapsed_seconds: start.elapsed().as_secs_f64(),
            cache_hits: cache.hits() - hits0,
            cache_misses: cache.misses() - misses0,
        });
    }

    // Stage 2: fix s_dp, split homogeneous groups into pseudo-heterogeneous
    // subgroups, and re-search (still over every schedule) with monotone-TP
    // pruning.
    let mut fine_jobs: Vec<Job> = Vec::new();
    for &schedule in &cfg.schedules {
        for &algo in &cfg.comm_algos {
            fine_jobs.push((coarse.1.s_dp, coarse.1.s_ep, schedule, algo));
        }
    }
    let fine_groups = split_groups(&groups, cfg.group_split);
    let (stats2, fine) =
        run_jobs(model, &fine_groups, sequences, &fine_jobs, true, cfg.parallel, coarse.0,
                 cache, &progress);
    progress.stage_summary(
        "refine stage",
        stats2,
        fine.as_ref().map(|f| f.0).unwrap_or(coarse.0),
        cache,
    );

    // Keep whichever stage produced the better feasible strategy.
    let use_fine = fine.as_ref().map(|(t, _, _)| *t < coarse.0).unwrap_or(false);
    let (strategy, eval, out_groups) = if use_fine {
        let (_, s, e) = fine.unwrap();
        (s, e, fine_groups)
    } else {
        let (_, s, e) = coarse;
        (s, e, groups)
    };

    Ok(SearchResult {
        strategy,
        eval,
        groups: out_groups,
        candidates_explored: stats.evaluated + stats2.evaluated,
        leaves_pruned: stats.pruned + stats2.pruned,
        elapsed_seconds: start.elapsed().as_secs_f64(),
        cache_hits: cache.hits() - hits0,
        cache_misses: cache.misses() - misses0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::H2_100B;
    use crate::hetero::{experiment, homogeneous_baseline, ChipKind};

    #[test]
    fn tp_candidates_respect_max() {
        assert_eq!(tp_candidates(256, 4), vec![1, 2, 4]);
        assert_eq!(tp_candidates(256, 16), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn ep_candidates_follow_experts_and_dp() {
        use crate::costmodel::H2_MOE;
        // Dense models have no expert axis.
        assert_eq!(ep_candidates(&H2_100B, 8, 0), vec![1]);
        // MoE: every ep dividing both n_experts and s_dp.
        assert_eq!(ep_candidates(&H2_MOE, 8, 0), vec![1, 2, 4, 8]);
        assert_eq!(ep_candidates(&H2_MOE, 6, 0), vec![1, 2]);
        assert_eq!(ep_candidates(&H2_MOE, 1, 0), vec![1]);
        // The cap pins the axis (what `SearchConfig::max_ep = 1` lowers to).
        assert_eq!(ep_candidates(&H2_MOE, 8, 1), vec![1]);
        assert_eq!(ep_candidates(&H2_MOE, 8, 4), vec![1, 2, 4]);
    }

    #[test]
    fn dp_candidates_divide_everything() {
        let groups = vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 256)];
        let dps = dp_candidates(512, &groups, 0);
        assert!(dps.contains(&1) && dps.contains(&4) && dps.contains(&256));
        for dp in dps {
            assert_eq!(512 % dp, 0);
            assert_eq!(256 % dp, 0);
        }
    }

    #[test]
    fn dp_candidates_match_naive_scan() {
        // The sqrt divisor-pair walk must agree exactly with the O(n)
        // reference on sequences both square and not, with and without caps.
        let naive = |sequences: usize, groups: &[ChipGroup], max_dp: usize| -> Vec<usize> {
            (1..=sequences)
                .filter(|dp| {
                    sequences % dp == 0
                        && (max_dp == 0 || *dp <= max_dp)
                        && groups.iter().all(|g| g.n_chips % dp == 0)
                })
                .collect()
        };
        let groups = vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 512)];
        for sequences in [1usize, 2, 12, 256, 511, 512, 1024, 1536, 4096] {
            for max_dp in [0usize, 1, 3, 16, 10_000] {
                assert_eq!(
                    dp_candidates(sequences, &groups, max_dp),
                    naive(sequences, &groups, max_dp),
                    "sequences={sequences} max_dp={max_dp}"
                );
            }
        }
    }

    #[test]
    fn into_plan_roundtrips_the_search() {
        let exp = experiment("exp-a-1").unwrap();
        let cfg = SearchConfig::default();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        let strategy = r.strategy.clone();
        let eval_iter = r.eval.iteration_seconds;
        let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
        assert_eq!(plan.strategy, strategy);
        assert_eq!(plan.gbs_tokens, exp.gbs_tokens);
        assert!(plan.validate().is_ok());
        // The plan's cost-model view is bit-identical to the search's.
        assert_eq!(plan.evaluate().iteration_seconds, eval_iter);
    }

    #[test]
    fn split_groups_whole_nodes() {
        let groups = vec![ChipGroup::new(ChipKind::B, 1024)];
        let sub = split_groups(&groups, 128);
        assert_eq!(sub.len(), 8);
        assert!(sub.iter().all(|g| g.n_chips == 128));
    }

    #[test]
    fn homogeneous_search_finds_table6_like_config() {
        let exp = homogeneous_baseline(ChipKind::A);
        let cfg = SearchConfig { two_stage: false, ..Default::default() };
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        assert!(r.eval.feasible);
        let plan = r.strategy.plans[0];
        assert_eq!(plan.s_pp * plan.s_tp * r.strategy.s_dp, 256);
        assert_eq!(plan.layers, 96);
    }

    #[test]
    fn hetero_search_exp_a_runs_and_is_feasible() {
        let exp = experiment("exp-a-1").unwrap();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default()).unwrap();
        assert!(r.eval.feasible);
        assert_eq!(r.strategy.total_layers(), 96);
        assert!(r.candidates_explored > 0);
        // All chips of every group must be used exactly.
        for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
            assert_eq!(g.n_chips, p.s_pp * p.s_tp * r.strategy.s_dp,
                       "group {} chip accounting", g.spec.kind);
        }
    }

    #[test]
    fn parallel_search_matches_sequential_bit_for_bit() {
        // The Table 8 fixture: the work-queue path with shared-incumbent
        // pruning and branch-split tasks must return the identical strategy
        // and cost as the sequential scan.
        let exp = experiment("exp-a-1").unwrap();
        let par = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: true, ..Default::default() }).unwrap();
        let seq = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: false, ..Default::default() }).unwrap();
        assert_eq!(par.strategy, seq.strategy);
        assert_eq!(par.eval.iteration_seconds, seq.eval.iteration_seconds);
    }

    #[test]
    fn search_over_schedules_never_loses_to_any_pinned_schedule() {
        // The full search min over schedules equals the min of the pinned
        // searches — i.e. the schedule dimension is genuinely explored.
        let exp = homogeneous_baseline(ChipKind::A);
        let full = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig { two_stage: false, ..Default::default() }).unwrap();
        let mut pinned_best = f64::INFINITY;
        for schedule in Schedule::SEARCH_SPACE {
            let cfg = SearchConfig {
                two_stage: false,
                ..SearchConfig::pinned(schedule)
            };
            if let Ok(r) = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg) {
                pinned_best = pinned_best.min(r.eval.iteration_seconds);
            }
        }
        assert!(pinned_best.is_finite());
        assert_eq!(full.eval.iteration_seconds, pinned_best);
    }

    #[test]
    fn zero_bubble_schedule_wins_on_the_homogeneous_fixture() {
        // With identical chips and no memory cliff between schedules, the
        // zero-bubble variant's missing bubble term must win the search.
        let exp = homogeneous_baseline(ChipKind::A);
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                       &SearchConfig { two_stage: false, ..Default::default() }).unwrap();
        assert_eq!(r.strategy.schedule, Schedule::ZeroBubbleV,
                   "winner {:?}", r.strategy.schedule);
    }

    #[test]
    fn parallel_comm_algo_search_matches_sequential_bit_for_bit() {
        // The comm-algo axis rides the same work-queue machinery: with
        // every algorithm (and the auto selector) in the job list, the
        // parallel reduction must return exactly the sequential winner.
        let exp = experiment("exp-a-1").unwrap();
        let base = SearchConfig {
            comm_algos: CommAlgo::ALL.to_vec(),
            two_stage: false,
            ..SearchConfig::default()
        };
        let par = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: true, ..base.clone() }).unwrap();
        let seq = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: false, ..base }).unwrap();
        assert_eq!(par.strategy, seq.strategy);
        assert_eq!(par.eval.iteration_seconds, seq.eval.iteration_seconds);
    }

    #[test]
    fn auto_selector_never_loses_to_any_pinned_algorithm() {
        // Auto resolves per collective group, so its winner is at least as
        // good as the best whole-strategy pin of a concrete algorithm.
        let exp = homogeneous_baseline(ChipKind::B);
        let auto = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig { two_stage: false, ..SearchConfig::default() })
            .unwrap();
        let mut pinned_best = f64::INFINITY;
        for algo in CommAlgo::CONCRETE {
            let cfg = SearchConfig {
                comm_algos: vec![algo],
                two_stage: false,
                ..SearchConfig::default()
            };
            if let Ok(r) = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg) {
                pinned_best = pinned_best.min(r.eval.iteration_seconds);
            }
        }
        assert!(pinned_best.is_finite());
        assert!(auto.eval.iteration_seconds <= pinned_best * (1.0 + 1e-12),
                "auto {} vs best pin {pinned_best}", auto.eval.iteration_seconds);
    }

    #[test]
    fn empty_comm_algo_space_is_rejected() {
        let exp = homogeneous_baseline(ChipKind::A);
        let cfg = SearchConfig { comm_algos: vec![], ..SearchConfig::default() };
        assert!(search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).is_err());
    }

    #[test]
    fn two_stage_never_worse_than_coarse() {
        let exp = experiment("exp-c-1").unwrap();
        let coarse = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                            &SearchConfig { two_stage: false, ..Default::default() }).unwrap();
        let fine = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig::default()).unwrap();
        assert!(fine.eval.iteration_seconds <= coarse.eval.iteration_seconds * 1.0001);
    }

    #[test]
    fn lower_bound_is_admissible_on_every_leaf() {
        // The pruning invariant in one test: for every complete assignment
        // of the Exp-A space (several dps × every schedule), the bound at
        // the leaf must not exceed the true evaluated iteration time.
        // Internal-node bounds are ≤ their leaves' bounds by construction
        // (suffix tables are per-group optima), so leaf admissibility
        // covers the whole tree.
        let exp = experiment("exp-a-1").unwrap();
        let groups: Vec<ChipGroup> =
            exp.cluster.groups_by_memory_desc().into_iter().cloned().collect();
        let sequences = exp.gbs_tokens / H2_100B.seq_len;
        let cache = ProfileCache::new();
        let mut checked = 0usize;
        for &s_dp in &[2usize, 8] {
            let table = dp_table(&H2_100B, &groups, s_dp, 1, &cache);
            let counts: Vec<usize> = table.options.iter().map(|o| o.len()).collect();
            assert!(counts.iter().all(|&c| c > 0));
            for schedule in Schedule::SEARCH_SPACE {
                let comm_algo = CommAlgo::Auto;
                let floor = update_floor(&H2_100B, &groups, &table, s_dp, 1, comm_algo, &cache);
                assert!(floor.is_finite() && floor > 0.0);
                // Odometer over every option combination.
                let mut idxs = vec![0usize; counts.len()];
                loop {
                    let mut shapes = Vec::with_capacity(counts.len());
                    let (mut ratio, mut sppt, mut max_t) = (0.0f64, 0.0f64, 0.0f64);
                    for (g, &oi) in idxs.iter().enumerate() {
                        let opt = table.options[g][oi];
                        shapes.push(GroupShape { s_tp: opt.s_tp, s_pp: opt.s_pp });
                        ratio += opt.s_pp as f64 / opt.t_layer;
                        sppt += opt.s_pp as f64 * opt.t_layer;
                        max_t = max_t.max(opt.t_layer);
                    }
                    let micro_batches = sequences / s_dp;
                    let lb = bound_value(
                        micro_batches as f64,
                        H2_100B.n_layers as f64,
                        schedule.bubble_coefficient(),
                        floor,
                        ratio + table.ratio_suffix[counts.len()],
                        sppt + table.sppt_suffix[counts.len()],
                        max_t.max(table.max_t_suffix[counts.len()]),
                    );
                    let profiles: Vec<LayerProfile> = groups
                        .iter()
                        .zip(&shapes)
                        .map(|(g, s)| {
                            cache.profile(&g.spec, &H2_100B, s.s_tp, H2_100B.seq_len,
                                          s_dp, 1, comm_algo, NicAssignment::Affinity)
                        })
                        .collect();
                    let sharding = shard_layers(
                        &H2_100B, &groups, &shapes, s_dp, 1, micro_batches, H2_100B.seq_len,
                        schedule, comm_algo, &profiles,
                    );
                    if sharding.feasible {
                        let strategy = Strategy {
                            s_ep: 1,
                            s_dp,
                            micro_batches,
                            schedule,
                            comm_algo,
                            plans: sharding.plans,
                        };
                        let grefs: Vec<&ChipGroup> = groups.iter().collect();
                        let eval = evaluate_with_profiles(
                            &H2_100B, &grefs, &strategy, H2_100B.seq_len, &profiles,
                        );
                        checked += 1;
                        assert!(
                            lb <= eval.iteration_seconds,
                            "bound {lb} exceeds true cost {} (dp {s_dp}, {schedule}, \
                             shapes {shapes:?})",
                            eval.iteration_seconds
                        );
                        // The bound should also be doing real work: within
                        // an order of magnitude of the truth, not a
                        // degenerate 0.
                        assert!(lb > 0.0);
                    }
                    // Advance the odometer.
                    let mut g = counts.len();
                    loop {
                        if g == 0 {
                            break;
                        }
                        g -= 1;
                        idxs[g] += 1;
                        if idxs[g] < counts[g] {
                            break;
                        }
                        idxs[g] = 0;
                        if g == 0 {
                            break;
                        }
                    }
                    if idxs.iter().all(|&i| i == 0) {
                        break;
                    }
                }
            }
        }
        assert!(checked > 50, "only {checked} feasible leaves checked");
    }

    #[test]
    fn warm_cache_search_reports_hits_not_misses() {
        // First search over a fresh cache profiles every distinct shape
        // (misses > 0); re-searching the same cluster over the same cache
        // is all hits — the observable core of incremental re-planning.
        let exp = homogeneous_baseline(ChipKind::A);
        let cfg = SearchConfig { two_stage: false, ..Default::default() };
        let cache = ProfileCache::new();
        let cold = search_with_cache(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg, &cache)
            .unwrap();
        assert!(cold.cache_misses > 0, "fresh cache must profile something");
        let warm = search_with_cache(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg, &cache)
            .unwrap();
        assert_eq!(warm.cache_misses, 0, "warm cache re-profiled {} shapes",
                   warm.cache_misses);
        assert!(warm.cache_hits > 0);
        // Counters are per-search deltas, so the cold run's are untouched.
        assert_eq!(warm.strategy, cold.strategy);
    }

    #[test]
    fn evaluated_plus_pruned_covers_the_whole_space() {
        // Sequentially (fixed config), the reported (evaluated, pruned)
        // pair is deterministic and partitions the entire coarse candidate
        // space: every leaf is either reached or under exactly one cut.
        let exp = experiment("exp-a-1").unwrap();
        let cfg = SearchConfig { parallel: false, two_stage: false, ..Default::default() };
        let r1 = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        let r2 = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        assert_eq!(r1.candidates_explored, r2.candidates_explored);
        assert_eq!(r1.leaves_pruned, r2.leaves_pruned);

        let groups: Vec<ChipGroup> =
            exp.cluster.groups_by_memory_desc().into_iter().cloned().collect();
        let sequences = exp.gbs_tokens / H2_100B.seq_len;
        let cache = ProfileCache::new();
        let mut total = 0usize;
        for dp in dp_candidates(sequences, &groups, cfg.max_dp) {
            let table = dp_table(&H2_100B, &groups, dp, 1, &cache);
            total += table.leaf_suffix[0] * cfg.schedules.len() * cfg.comm_algos.len();
        }
        assert_eq!(r1.candidates_explored + r1.leaves_pruned, total,
                   "evaluated {} + pruned {} != space {total}",
                   r1.candidates_explored, r1.leaves_pruned);
        // The tightened bound must actually cut most of the space here.
        assert!(r1.leaves_pruned > 0, "no pruning on the Exp-A fixture?");
    }
}
