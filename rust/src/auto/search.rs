//! HeteroAuto DFS strategy search (§4.3.3), schedule- and comm-algo-aware
//! and parallel.
//!
//! Step 1 — depth-first search over the parallelism space: data-parallel
//! candidates dividing the global batch; per chip type, tensor-parallel
//! degrees in powers of two up to `TP_MAX_i`; pipeline degree from
//! `N_i = s_pp,i · s_tp,i · s_dp`; and the pipeline [`Schedule`] plus the
//! DP-collective [`CommAlgo`] as extra search dimensions. Types are
//! visited in descending memory order (the HeteroPP stage order).
//!
//! Step 2 — optimal layer sharding per configuration (see [`super::sharding`]).
//!
//! Step 3 — cost estimation with the §4.3.2 model; the feasible minimum wins.
//!
//! The outer (s_dp × schedule × comm-algo) candidate loop runs on scoped
//! worker threads (the offline vendor set has no rayon; `std::thread::scope`
//! plays its role) with incumbent-cost branch-and-bound pruning: a shared
//! atomic incumbent tracks the best feasible iteration time, and any DFS
//! subtree whose compute lower bound already exceeds it is cut. Pruning is
//! *strict* (only subtrees provably worse than the incumbent are cut — the
//! bound is compute-only, which comm and update terms only add to) and
//! the final reduction takes the minimum in deterministic candidate order
//! (s_dp ascending, schedules then comm algos in configured order, DFS
//! order within), so the parallel search returns bit-identically the same
//! strategy as the sequential one regardless of thread timing.
//!
//! The **two-stage** refinement fixes `s_dp` from a coarse pass, then splits
//! each homogeneous group into pseudo-heterogeneous subgroups (128 chips in
//! the paper) re-searched with the monotone-TP pruning rule
//! (`s_tp,a ≥ s_tp,b` for earlier subgroups of the same type).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::comm::CommAlgo;
use crate::costmodel::{evaluate, profile_layer, Evaluation, ModelShape, Schedule, Strategy};
use crate::hetero::{ChipGroup, Cluster};

use super::sharding::shard_layers;
pub use super::sharding::GroupShape;

/// Search configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Pipeline schedules to search over (default: 1F1B, interleaved:2 and
    /// the zero-bubble schedule). Pin a single entry to fix the schedule.
    pub schedules: Vec<Schedule>,
    /// DP-collective algorithms to search over (default: the topology-aware
    /// [`CommAlgo::Auto`] selector alone, which prices every candidate with
    /// its best algorithm without growing the job count). List concrete
    /// algorithms to measure the axis explicitly, or pin one to fix it.
    pub comm_algos: Vec<CommAlgo>,
    /// Subgroup size for the two-stage refinement (paper: 128 chips).
    pub group_split: usize,
    /// Run the two-stage refinement.
    pub two_stage: bool,
    /// Cap on candidate data-parallel degrees (0 = no cap).
    pub max_dp: usize,
    /// Run the outer (s_dp × schedule) loop on worker threads. The result
    /// is bit-identical to the sequential path either way.
    pub parallel: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            schedules: Schedule::SEARCH_SPACE.to_vec(),
            comm_algos: vec![CommAlgo::Auto],
            group_split: 128,
            two_stage: true,
            max_dp: 0,
            parallel: true,
        }
    }
}

impl SearchConfig {
    /// A config pinned to one schedule (other knobs at their defaults) —
    /// what `--schedule` lowers to and what the paper-table drivers use to
    /// stay on the paper's 1F1B baseline.
    pub fn pinned(schedule: Schedule) -> SearchConfig {
        SearchConfig { schedules: vec![schedule], ..SearchConfig::default() }
    }
}

/// Result of a HeteroAuto search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// The winning strategy (its `schedule` field records the winning
    /// pipeline schedule).
    pub strategy: Strategy,
    /// Cost-model evaluation of the winning strategy.
    pub eval: Evaluation,
    /// Groups (memory-descending) matching strategy.plans — includes the
    /// pseudo-subgroups if the two-stage refinement produced them.
    pub groups: Vec<ChipGroup>,
    /// Leaf configurations evaluated. With branch-and-bound pruning this
    /// varies with thread timing; the winning strategy does not.
    pub candidates_explored: usize,
    /// Wall-clock search time.
    pub elapsed_seconds: f64,
}

impl SearchResult {
    /// Package the searched strategy as a serializable
    /// [`crate::plan::ExecutionPlan`] — the HeteroAuto → HeteroPP handoff.
    /// Communication options take the plan defaults (device-direct RDMA,
    /// SR&AG, NIC affinity, overlap on); callers adjust the returned plan's
    /// fields for ablations. The winning schedule and DP-collective
    /// algorithm travel inside the strategy, so the search config is not
    /// needed here.
    pub fn to_plan(
        &self,
        model: &ModelShape,
        cluster: &Cluster,
        gbs_tokens: usize,
    ) -> crate::plan::ExecutionPlan {
        // The search floors the batch to whole sequences; the plan records
        // the tokens actually scheduled so its TGS matches the modeled work.
        let whole = (gbs_tokens / model.seq_len) * model.seq_len;
        crate::plan::PlanBuilder::new(&format!("{}-heteroauto", cluster.name))
            .model(*model)
            .cluster(cluster.clone())
            .stage_groups(self.groups.clone())
            .strategy(self.strategy.clone())
            .gbs_tokens(whole)
            .micro_tokens(model.seq_len)
            .build()
            .expect("HeteroAuto produced a structurally invalid strategy")
    }

    /// Consuming form of [`SearchResult::to_plan`] for callers done with
    /// the search result.
    pub fn into_plan(
        self,
        model: &ModelShape,
        cluster: &Cluster,
        gbs_tokens: usize,
    ) -> crate::plan::ExecutionPlan {
        self.to_plan(model, cluster, gbs_tokens)
    }
}

/// Powers of two 1..=tp_max that divide `n`.
fn tp_candidates(n_chips: usize, tp_max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut tp = 1;
    while tp <= tp_max {
        if n_chips % tp == 0 {
            v.push(tp);
        }
        tp *= 2;
    }
    v
}

/// Divisors of `sequences` usable as s_dp (every group must split evenly).
///
/// Divisors come in pairs `(d, sequences/d)`, so scanning `d` up to
/// `sqrt(sequences)` finds them all — O(sqrt n) instead of the O(n) scan
/// that dominated large-GBS searches (sequences is GBS/seq_len, easily
/// in the thousands).
fn dp_candidates(sequences: usize, groups: &[ChipGroup], max_dp: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut accept = |dp: usize| {
        if max_dp > 0 && dp > max_dp {
            return;
        }
        // Every group must be divisible by dp (leaving >= 1 chip per stage).
        if groups.iter().all(|g| g.n_chips % dp == 0 && g.n_chips / dp >= 1) {
            v.push(dp);
        }
    };
    let mut d = 1;
    while d * d <= sequences {
        if sequences % d == 0 {
            accept(d);
            if d != sequences / d {
                accept(sequences / d);
            }
        }
        d += 1;
    }
    v.sort_unstable();
    v
}

/// Shared branch-and-bound incumbent: the best feasible iteration time
/// seen by any worker, as f64 bits in an atomic (all values are positive
/// finite, so float order and the CAS loop agree).
struct Incumbent(AtomicU64);

impl Incumbent {
    fn new(seed: f64) -> Incumbent {
        Incumbent(AtomicU64::new(seed.to_bits()))
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    fn observe(&self, t: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        while t < f64::from_bits(cur) {
            match self.0.compare_exchange_weak(
                cur,
                t.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// One (tp, s_pp) option for a group at a fixed s_dp, with its per-layer
/// fwd+bwd time and its best-case `s_pp/t` packing ratio contribution.
#[derive(Clone, Copy, Debug)]
struct TpOption {
    s_tp: usize,
    s_pp: usize,
    t_layer: f64,
}

struct DfsCtx<'a> {
    model: &'a ModelShape,
    groups: &'a [ChipGroup],
    /// Per group: the usable (tp, s_pp, t_layer) options at this s_dp.
    options: &'a [Vec<TpOption>],
    /// Per group: suffix sums of the maximal `s_pp/t_layer` ratio over the
    /// group's options — the optimistic packing capacity of the not-yet
    /// assigned groups, used in the compute lower bound.
    ratio_suffix: &'a [f64],
    s_dp: usize,
    micro_batches: usize,
    micro_tokens: usize,
    schedule: Schedule,
    comm_algo: CommAlgo,
    monotone_tp: bool,
    incumbent: &'a Incumbent,
    explored: usize,
    best: Option<(f64, Strategy, Evaluation)>,
}

impl<'a> DfsCtx<'a> {
    /// Lower bound on any completion of the current partial assignment:
    /// every layer must run somewhere, so the bottleneck stage computes at
    /// least `L / Σ_g (s_pp_g / t_g)` per microbatch — assigned groups
    /// contribute their actual ratio, open groups their best case — and
    /// the iteration costs at least `b ×` that, whatever the schedule
    /// (bubble, update, recompute and offload terms only add).
    fn lower_bound(&self, idx: usize, ratio_sum: f64) -> f64 {
        let denom = ratio_sum + self.ratio_suffix[idx];
        if denom <= 0.0 {
            return f64::INFINITY;
        }
        self.micro_batches as f64 * self.model.n_layers as f64 / denom
    }

    fn dfs(&mut self, idx: usize, shapes: &mut Vec<GroupShape>, ratio_sum: f64) {
        if self.lower_bound(idx, ratio_sum) > self.incumbent.get() {
            return; // provably worse than the incumbent — prune
        }
        if idx == self.groups.len() {
            self.explored += 1;
            let sharding = shard_layers(
                self.model, self.groups, shapes, self.s_dp,
                self.micro_batches, self.micro_tokens, self.schedule, self.comm_algo,
            );
            if !sharding.feasible {
                return;
            }
            // Interleaving chunks every stage's layers: reject allocations
            // the virtual-stage count does not divide.
            let v = self.schedule.virtual_stages();
            if v > 1 && sharding.plans.iter().any(|p| p.layers_per_stage() % v != 0) {
                return;
            }
            let strategy = Strategy {
                s_dp: self.s_dp,
                micro_batches: self.micro_batches,
                schedule: self.schedule,
                comm_algo: self.comm_algo,
                plans: sharding.plans,
            };
            let grefs: Vec<&ChipGroup> = self.groups.iter().collect();
            let eval = evaluate(self.model, &grefs, &strategy, self.micro_tokens);
            if !eval.feasible {
                return;
            }
            let t = eval.iteration_seconds;
            if self.best.as_ref().map(|(bt, _, _)| t < *bt).unwrap_or(true) {
                self.best = Some((t, strategy, eval));
            }
            self.incumbent.observe(t);
            return;
        }
        for opt in &self.options[idx] {
            // Monotone-TP pruning within a chip type (two-stage constraint).
            if self.monotone_tp && idx > 0 {
                let prev = &self.groups[idx - 1];
                if prev.spec.kind == self.groups[idx].spec.kind
                    && shapes[idx - 1].s_tp < opt.s_tp
                {
                    continue;
                }
            }
            shapes.push(GroupShape { s_tp: opt.s_tp, s_pp: opt.s_pp });
            self.dfs(idx + 1, shapes, ratio_sum + opt.s_pp as f64 / opt.t_layer);
            shapes.pop();
        }
    }
}

/// One outer-loop candidate: a data-parallel degree, a schedule and a
/// DP-collective algorithm.
type Job = (usize, Schedule, CommAlgo);

/// What one job reports back: leaves explored plus its best feasible
/// (cost, strategy, evaluation), if any.
type JobOutcome = (usize, Option<(f64, Strategy, Evaluation)>);

/// Schedule-independent search tables for one s_dp: per-group TP options
/// plus the optimistic ratio suffix for the branch-and-bound lower bound —
/// built once per distinct s_dp and shared across that dp's schedule jobs.
struct DpTable {
    s_dp: usize,
    options: Vec<Vec<TpOption>>,
    ratio_suffix: Vec<f64>,
}

fn dp_table(model: &ModelShape, groups: &[ChipGroup], s_dp: usize) -> DpTable {
    let micro_tokens = model.seq_len; // paper: micro batch size pinned to 1
    let options: Vec<Vec<TpOption>> = groups
        .iter()
        .map(|g| {
            tp_candidates(g.n_chips, g.spec.tp_max())
                .into_iter()
                .filter(|tp| g.n_chips % (tp * s_dp) == 0 && g.n_chips / (tp * s_dp) >= 1)
                .map(|tp| {
                    let p = profile_layer(&g.spec, model, tp, micro_tokens, s_dp);
                    TpOption {
                        s_tp: tp,
                        s_pp: g.n_chips / (tp * s_dp),
                        t_layer: p.t_fwd + p.t_bwd,
                    }
                })
                .collect()
        })
        .collect();
    let mut ratio_suffix = vec![0.0f64; groups.len() + 1];
    for idx in (0..groups.len()).rev() {
        let best_ratio = options[idx]
            .iter()
            .map(|o| o.s_pp as f64 / o.t_layer)
            .fold(0.0f64, f64::max);
        ratio_suffix[idx] = ratio_suffix[idx + 1] + best_ratio;
    }
    DpTable { s_dp, options, ratio_suffix }
}

/// Run the DFS for one (s_dp, schedule, comm-algo) job over its dp's
/// shared tables.
fn run_one_job(
    model: &ModelShape,
    groups: &[ChipGroup],
    sequences: usize,
    job: Job,
    table: &DpTable,
    monotone_tp: bool,
    incumbent: &Incumbent,
) -> JobOutcome {
    let (s_dp, schedule, comm_algo) = job;
    debug_assert_eq!(s_dp, table.s_dp);
    let mut ctx = DfsCtx {
        model,
        groups,
        options: &table.options,
        ratio_suffix: &table.ratio_suffix,
        s_dp,
        micro_batches: sequences / s_dp,
        micro_tokens: model.seq_len,
        schedule,
        comm_algo,
        monotone_tp,
        incumbent,
        explored: 0,
        best: None,
    };
    let mut shapes = Vec::with_capacity(groups.len());
    ctx.dfs(0, &mut shapes, 0.0);
    (ctx.explored, ctx.best)
}

/// Run every (s_dp × schedule × comm-algo) job — on scoped worker threads
/// when `parallel` — and reduce to the minimum in deterministic job order.
///
/// `seed_incumbent` primes the branch-and-bound bound (`f64::INFINITY` for
/// a fresh search; the coarse best for the two-stage refinement, whose
/// results are only accepted when strictly better anyway, so seeding
/// cannot change the outcome — only skip provably useless work).
fn run_jobs(
    model: &ModelShape,
    groups: &[ChipGroup],
    sequences: usize,
    jobs: &[Job],
    monotone_tp: bool,
    parallel: bool,
    seed_incumbent: f64,
) -> (usize, Option<(f64, Strategy, Evaluation)>) {
    let incumbent = Incumbent::new(seed_incumbent);
    // The TP-option tables are schedule-independent: one per distinct dp,
    // shared by every schedule job at that dp.
    let mut tables: Vec<DpTable> = Vec::new();
    for &(dp, _, _) in jobs {
        if !tables.iter().any(|t| t.s_dp == dp) {
            tables.push(dp_table(model, groups, dp));
        }
    }
    fn table_for(tables: &[DpTable], dp: usize) -> &DpTable {
        tables.iter().find(|t| t.s_dp == dp).expect("table built for every job dp")
    }
    let workers = if parallel {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(jobs.len())
    } else {
        1
    };

    let mut slots: Vec<Option<JobOutcome>> = vec![None; jobs.len()];
    if workers <= 1 {
        for (i, job) in jobs.iter().enumerate() {
            slots[i] = Some(run_one_job(model, groups, sequences, *job,
                                        table_for(&tables, job.0), monotone_tp, &incumbent));
        }
    } else {
        let next = AtomicUsize::new(0);
        let finished = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                let next = &next;
                let incumbent = &incumbent;
                let tables = &tables;
                handles.push(scope.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        out.push((
                            i,
                            run_one_job(model, groups, sequences, jobs[i],
                                        table_for(tables, jobs[i].0), monotone_tp,
                                        incumbent),
                        ));
                    }
                    out
                }));
            }
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("search worker panicked"))
                .collect::<Vec<_>>()
        });
        for (i, result) in finished {
            slots[i] = Some(result);
        }
    }

    // Deterministic reduction: min by cost with ties broken by job order
    // (s_dp ascending, schedules then comm algos in configured order) —
    // identical to the sequential scan whatever the thread interleaving
    // was.
    let mut explored = 0;
    let mut best: Option<(f64, Strategy, Evaluation)> = None;
    for slot in slots {
        let (n, job_best) = slot.expect("every job produces a result");
        explored += n;
        if let Some((t, s, e)) = job_best {
            if best.as_ref().map(|(bt, _, _)| t < *bt).unwrap_or(true) {
                best = Some((t, s, e));
            }
        }
    }
    (explored, best)
}

/// Split each homogeneous group into `split`-chip pseudo-heterogeneous
/// subgroups (two-stage refinement, §4.3.3).
fn split_groups(groups: &[ChipGroup], split: usize) -> Vec<ChipGroup> {
    let mut out = Vec::new();
    for g in groups {
        if g.n_chips <= split {
            out.push(g.clone());
            continue;
        }
        let node = g.spec.chips_per_node;
        let mut chunk = split.max(node);
        chunk -= chunk % node; // whole nodes
        let mut rest = g.n_chips;
        while rest > 0 {
            let take = chunk.min(rest);
            out.push(ChipGroup::new(g.spec.kind, take));
            rest -= take;
        }
    }
    out
}

/// Run HeteroAuto over a cluster for a global batch of `gbs_tokens`.
pub fn search(
    model: &ModelShape,
    cluster: &Cluster,
    gbs_tokens: usize,
    cfg: &SearchConfig,
) -> Result<SearchResult> {
    let start = Instant::now();
    let sequences = gbs_tokens / model.seq_len;
    if sequences == 0 {
        bail!("global batch smaller than one sequence");
    }
    if cfg.schedules.is_empty() {
        bail!("search config has no pipeline schedules to explore");
    }
    if cfg.comm_algos.is_empty() {
        bail!("search config has no collective algorithms to explore");
    }
    // Memory-descending group order = HeteroPP stage order (Observation #4).
    let groups: Vec<ChipGroup> = cluster
        .groups_by_memory_desc()
        .into_iter()
        .cloned()
        .collect();

    let dp_choices = dp_candidates(sequences, &groups, cfg.max_dp);
    if dp_choices.is_empty() {
        bail!("no feasible data-parallel degree for cluster `{}`", cluster.name);
    }
    let mut jobs: Vec<Job> = Vec::new();
    for &dp in &dp_choices {
        for &schedule in &cfg.schedules {
            for &algo in &cfg.comm_algos {
                jobs.push((dp, schedule, algo));
            }
        }
    }

    // Stage 1: coarse search, one group per chip type.
    let (mut explored, coarse) =
        run_jobs(model, &groups, sequences, &jobs, false, cfg.parallel, f64::INFINITY);
    let coarse = match coarse {
        Some(c) => c,
        None => bail!("no feasible strategy found for `{}`", cluster.name),
    };

    if !cfg.two_stage {
        let (_, strategy, eval) = coarse;
        return Ok(SearchResult {
            strategy,
            eval,
            groups,
            candidates_explored: explored,
            elapsed_seconds: start.elapsed().as_secs_f64(),
        });
    }

    // Stage 2: fix s_dp, split homogeneous groups into pseudo-heterogeneous
    // subgroups, and re-search (still over every schedule) with monotone-TP
    // pruning.
    let mut fine_jobs: Vec<Job> = Vec::new();
    for &schedule in &cfg.schedules {
        for &algo in &cfg.comm_algos {
            fine_jobs.push((coarse.1.s_dp, schedule, algo));
        }
    }
    let fine_groups = split_groups(&groups, cfg.group_split);
    let (explored2, fine) =
        run_jobs(model, &fine_groups, sequences, &fine_jobs, true, cfg.parallel, coarse.0);
    explored += explored2;

    // Keep whichever stage produced the better feasible strategy.
    let use_fine = fine.as_ref().map(|(t, _, _)| *t < coarse.0).unwrap_or(false);
    let (strategy, eval, out_groups) = if use_fine {
        let (_, s, e) = fine.unwrap();
        (s, e, fine_groups)
    } else {
        let (_, s, e) = coarse;
        (s, e, groups)
    };

    Ok(SearchResult {
        strategy,
        eval,
        groups: out_groups,
        candidates_explored: explored,
        elapsed_seconds: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::H2_100B;
    use crate::hetero::{experiment, homogeneous_baseline, ChipKind};

    #[test]
    fn tp_candidates_respect_max() {
        assert_eq!(tp_candidates(256, 4), vec![1, 2, 4]);
        assert_eq!(tp_candidates(256, 16), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn dp_candidates_divide_everything() {
        let groups = vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 256)];
        let dps = dp_candidates(512, &groups, 0);
        assert!(dps.contains(&1) && dps.contains(&4) && dps.contains(&256));
        for dp in dps {
            assert_eq!(512 % dp, 0);
            assert_eq!(256 % dp, 0);
        }
    }

    #[test]
    fn dp_candidates_match_naive_scan() {
        // The sqrt divisor-pair walk must agree exactly with the O(n)
        // reference on sequences both square and not, with and without caps.
        let naive = |sequences: usize, groups: &[ChipGroup], max_dp: usize| -> Vec<usize> {
            (1..=sequences)
                .filter(|dp| {
                    sequences % dp == 0
                        && (max_dp == 0 || *dp <= max_dp)
                        && groups.iter().all(|g| g.n_chips % dp == 0)
                })
                .collect()
        };
        let groups = vec![ChipGroup::new(ChipKind::A, 256), ChipGroup::new(ChipKind::B, 512)];
        for sequences in [1usize, 2, 12, 256, 511, 512, 1024, 1536, 4096] {
            for max_dp in [0usize, 1, 3, 16, 10_000] {
                assert_eq!(
                    dp_candidates(sequences, &groups, max_dp),
                    naive(sequences, &groups, max_dp),
                    "sequences={sequences} max_dp={max_dp}"
                );
            }
        }
    }

    #[test]
    fn into_plan_roundtrips_the_search() {
        let exp = experiment("exp-a-1").unwrap();
        let cfg = SearchConfig::default();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        let strategy = r.strategy.clone();
        let eval_iter = r.eval.iteration_seconds;
        let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
        assert_eq!(plan.strategy, strategy);
        assert_eq!(plan.gbs_tokens, exp.gbs_tokens);
        assert!(plan.validate().is_ok());
        // The plan's cost-model view is bit-identical to the search's.
        assert_eq!(plan.evaluate().iteration_seconds, eval_iter);
    }

    #[test]
    fn split_groups_whole_nodes() {
        let groups = vec![ChipGroup::new(ChipKind::B, 1024)];
        let sub = split_groups(&groups, 128);
        assert_eq!(sub.len(), 8);
        assert!(sub.iter().all(|g| g.n_chips == 128));
    }

    #[test]
    fn homogeneous_search_finds_table6_like_config() {
        let exp = homogeneous_baseline(ChipKind::A);
        let cfg = SearchConfig { two_stage: false, ..Default::default() };
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).unwrap();
        assert!(r.eval.feasible);
        let plan = r.strategy.plans[0];
        assert_eq!(plan.s_pp * plan.s_tp * r.strategy.s_dp, 256);
        assert_eq!(plan.layers, 96);
    }

    #[test]
    fn hetero_search_exp_a_runs_and_is_feasible() {
        let exp = experiment("exp-a-1").unwrap();
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default()).unwrap();
        assert!(r.eval.feasible);
        assert_eq!(r.strategy.total_layers(), 96);
        assert!(r.candidates_explored > 0);
        // All chips of every group must be used exactly.
        for (g, p) in r.groups.iter().zip(&r.strategy.plans) {
            assert_eq!(g.n_chips, p.s_pp * p.s_tp * r.strategy.s_dp,
                       "group {} chip accounting", g.spec.kind);
        }
    }

    #[test]
    fn parallel_search_matches_sequential_bit_for_bit() {
        // The Table 8 fixture: the worker-thread path with shared-incumbent
        // pruning must return the identical strategy and cost as the
        // sequential scan.
        let exp = experiment("exp-a-1").unwrap();
        let par = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: true, ..Default::default() }).unwrap();
        let seq = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: false, ..Default::default() }).unwrap();
        assert_eq!(par.strategy, seq.strategy);
        assert_eq!(par.eval.iteration_seconds, seq.eval.iteration_seconds);
    }

    #[test]
    fn search_over_schedules_never_loses_to_any_pinned_schedule() {
        // The full search min over schedules equals the min of the pinned
        // searches — i.e. the schedule dimension is genuinely explored.
        let exp = homogeneous_baseline(ChipKind::A);
        let full = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig { two_stage: false, ..Default::default() }).unwrap();
        let mut pinned_best = f64::INFINITY;
        for schedule in Schedule::SEARCH_SPACE {
            let cfg = SearchConfig {
                two_stage: false,
                ..SearchConfig::pinned(schedule)
            };
            if let Ok(r) = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg) {
                pinned_best = pinned_best.min(r.eval.iteration_seconds);
            }
        }
        assert!(pinned_best.is_finite());
        assert_eq!(full.eval.iteration_seconds, pinned_best);
    }

    #[test]
    fn zero_bubble_schedule_wins_on_the_homogeneous_fixture() {
        // With identical chips and no memory cliff between schedules, the
        // zero-bubble variant's missing bubble term must win the search.
        let exp = homogeneous_baseline(ChipKind::A);
        let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                       &SearchConfig { two_stage: false, ..Default::default() }).unwrap();
        assert_eq!(r.strategy.schedule, Schedule::ZeroBubbleV,
                   "winner {:?}", r.strategy.schedule);
    }

    #[test]
    fn parallel_comm_algo_search_matches_sequential_bit_for_bit() {
        // The comm-algo axis rides the same worker-thread machinery: with
        // every algorithm (and the auto selector) in the job list, the
        // parallel reduction must return exactly the sequential winner.
        let exp = experiment("exp-a-1").unwrap();
        let base = SearchConfig {
            comm_algos: CommAlgo::ALL.to_vec(),
            two_stage: false,
            ..SearchConfig::default()
        };
        let par = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: true, ..base.clone() }).unwrap();
        let seq = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                         &SearchConfig { parallel: false, ..base }).unwrap();
        assert_eq!(par.strategy, seq.strategy);
        assert_eq!(par.eval.iteration_seconds, seq.eval.iteration_seconds);
    }

    #[test]
    fn auto_selector_never_loses_to_any_pinned_algorithm() {
        // Auto resolves per collective group, so its winner is at least as
        // good as the best whole-strategy pin of a concrete algorithm.
        let exp = homogeneous_baseline(ChipKind::B);
        let auto = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig { two_stage: false, ..SearchConfig::default() })
            .unwrap();
        let mut pinned_best = f64::INFINITY;
        for algo in CommAlgo::CONCRETE {
            let cfg = SearchConfig {
                comm_algos: vec![algo],
                two_stage: false,
                ..SearchConfig::default()
            };
            if let Ok(r) = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg) {
                pinned_best = pinned_best.min(r.eval.iteration_seconds);
            }
        }
        assert!(pinned_best.is_finite());
        assert!(auto.eval.iteration_seconds <= pinned_best * (1.0 + 1e-12),
                "auto {} vs best pin {pinned_best}", auto.eval.iteration_seconds);
    }

    #[test]
    fn empty_comm_algo_space_is_rejected() {
        let exp = homogeneous_baseline(ChipKind::A);
        let cfg = SearchConfig { comm_algos: vec![], ..SearchConfig::default() };
        assert!(search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg).is_err());
    }

    #[test]
    fn two_stage_never_worse_than_coarse() {
        let exp = experiment("exp-c-1").unwrap();
        let coarse = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                            &SearchConfig { two_stage: false, ..Default::default() }).unwrap();
        let fine = search(&H2_100B, &exp.cluster, exp.gbs_tokens,
                          &SearchConfig::default()).unwrap();
        assert!(fine.eval.iteration_seconds <= coarse.eval.iteration_seconds * 1.0001);
    }
}
