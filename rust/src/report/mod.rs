//! Paper-experiment drivers shared by `h2 report`, the benches, and the
//! examples: Table 6 baselines, Fig 11 HeteroSpeedupRatio, Table 9
//! ablations, and the kill-a-node recovery-vs-restart comparison — each
//! returning paper-vs-measured pairs.

use std::time::Instant;

use anyhow::Result;

use crate::auto::{
    replan, search, search_with_cache, ClusterDelta, ReplanOptions, ReplanOutcome,
    SearchConfig, SearchResult,
};
use crate::comm::{CommAlgo, CommMode};
use crate::coordinator::{train_virtual, VirtualOptions};
use crate::costmodel::{uniform_1f1b, GroupPlan, ProfileCache, Schedule, Strategy, H2_100B};
use crate::elastic::{swap_compatible, MonitorConfig, RecoveryTimeline};
use crate::hetero::{experiment, homogeneous_baseline, ChipKind};
use crate::plan::{ExecutionPlan, PlanBuilder};
use crate::sim::{simulate_plan, simulate_plans, ReshardStrategy};

/// The paper ran everything on 1F1B with flat-ring collectives; its tables
/// are reproduced under a search pinned to both so the comparisons stay
/// like-for-like. The axes themselves are measured by [`schedule_axis`]
/// and [`comm_algo_axis`].
fn paper_search_config() -> SearchConfig {
    SearchConfig {
        comm_algos: vec![CommAlgo::Ring],
        ..SearchConfig::pinned(Schedule::OneF1B)
    }
}

/// Table 6 rows: (chip, PP, DP, TP, recompute, paper TGS).
pub const TABLE6: [(ChipKind, usize, usize, usize, bool, f64); 4] = [
    (ChipKind::A, 16, 4, 4, false, 136.9),
    (ChipKind::B, 16, 4, 4, true, 143.7),
    (ChipKind::C, 32, 2, 4, true, 46.2),
    (ChipKind::D, 8, 4, 8, false, 99.5),
];

/// Fig 11 paper ratios: (experiment, HeteroSpeedupRatio %).
pub const FIG11_PAPER: [(&str, f64); 4] = [
    ("exp-a-1", 89.56),
    ("exp-a-2", 109.03),
    ("exp-b-1", 77.45),
    ("exp-b-2", 104.29),
];

/// Table 8 paper search times (seconds): Exp-A, Exp-B, Exp-C.
pub const TABLE8_PAPER: [(&str, f64); 3] =
    [("exp-a-1", 0.62), ("exp-b-1", 5.48), ("exp-c-1", 12.29)];

/// One Table 6 evaluation (homogeneous baseline).
#[derive(Clone, Debug)]
pub struct BaselineRow {
    /// Which homogeneous chip this row measures.
    pub kind: ChipKind,
    /// The Table 6 strategy behind the row.
    pub strategy: Strategy,
    /// Closed-form cost-model TGS.
    pub model_tgs: f64,
    /// Discrete-event simulator TGS.
    pub sim_tgs: f64,
    /// The paper's measured TGS.
    pub paper_tgs: f64,
}

/// The homogeneous-baseline plan behind one Table 6 row.
pub fn table6_plan(kind: ChipKind, pp: usize, dp: usize, tp: usize, rec: bool) -> ExecutionPlan {
    let exp = homogeneous_baseline(kind);
    let strategy = Strategy {
        s_ep: 1,
        s_dp: dp,
        micro_batches: exp.gbs_tokens / H2_100B.seq_len / dp,
        schedule: Schedule::OneF1B,
        comm_algo: CommAlgo::Ring,
        plans: vec![GroupPlan { s_pp: pp, s_tp: tp, layers: 96, recompute: rec }],
    };
    PlanBuilder::new(&format!("table6-{kind}"))
        .model(H2_100B)
        .cluster(exp.cluster)
        .strategy(strategy)
        .gbs_tokens(exp.gbs_tokens)
        .build()
        .expect("Table 6 configurations are valid")
}

/// Evaluate one Table 6 row with both the cost model and the simulator.
pub fn table6_row(kind: ChipKind, pp: usize, dp: usize, tp: usize, rec: bool,
                  paper: f64) -> BaselineRow {
    let plan = table6_plan(kind, pp, dp, tp, rec);
    let eval = plan.evaluate();
    let sim = plan.simulate();
    BaselineRow {
        kind,
        model_tgs: plan.tgs(eval.iteration_seconds),
        sim_tgs: plan.tgs(sim.iteration_seconds),
        paper_tgs: paper,
        strategy: plan.strategy,
    }
}

/// Evaluate every Table 6 homogeneous baseline.
pub fn table6_all() -> Vec<BaselineRow> {
    TABLE6
        .iter()
        .map(|&(k, pp, dp, tp, rec, paper)| table6_row(k, pp, dp, tp, rec, paper))
        .collect()
}

/// A Fig 11 heterogeneous result.
#[derive(Clone, Debug)]
pub struct HeteroRow {
    /// Experiment index (Table 7).
    pub exp: String,
    /// The HeteroAuto result behind the row.
    pub search: SearchResult,
    /// Simulated TGS of the searched heterogeneous plan.
    pub sim_tgs: f64,
    /// HeteroSpeedupRatio against *our* simulated baselines (the paper's
    /// definition: N·TGS / Σ N_i·TGS_i).
    pub speedup_ratio: f64,
    /// The paper's Fig 11 ratio, when reported.
    pub paper_ratio: Option<f64>,
}

/// Run HeteroAuto + the simulator for one Table 7 experiment and compute
/// the HeteroSpeedupRatio against the Table 6 baselines. The searched
/// strategy flows to the simulator as an [`ExecutionPlan`] — the same
/// artifact `h2 search --emit-plan` persists.
pub fn hetero_row(exp_name: &str, baselines: &[BaselineRow]) -> Result<HeteroRow> {
    let exp = experiment(exp_name)?;
    let cfg = paper_search_config();
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg)?;
    let plan = r.to_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
    let sim = simulate_plan(&plan);
    let hetero_tgs = plan.tgs(sim.iteration_seconds);

    let mut denom = 0.0;
    for g in &exp.cluster.groups {
        let base = baselines
            .iter()
            .find(|b| b.kind == g.spec.kind)
            .map(|b| b.sim_tgs)
            .unwrap_or(0.0);
        denom += g.n_chips as f64 * base;
    }
    let ratio = hetero_tgs * exp.cluster.total_chips() as f64 / denom * 100.0;
    let paper = FIG11_PAPER
        .iter()
        .find(|(n, _)| *n == exp_name)
        .map(|(_, v)| *v);
    Ok(HeteroRow {
        exp: exp_name.to_string(),
        search: r,
        sim_tgs: hetero_tgs,
        speedup_ratio: ratio,
        paper_ratio: paper,
    })
}

/// Table 9 ablation variants on Exp-C-1 (relative iteration time, % of full).
#[derive(Clone, Debug)]
pub struct AblationRow {
    /// Human-readable ablation label.
    pub label: &'static str,
    /// Iteration time relative to the full system, percent.
    pub relative_percent: f64,
    /// The paper's Table 9 number, percent.
    pub paper_percent: f64,
}

/// The Table 9 component ablations on Exp-C-1 (1F1B baseline, as in the
/// paper; the schedule axis is measured by [`schedule_axis`]).
pub fn table9_ablation() -> Result<Vec<AblationRow>> {
    let exp = experiment("exp-c-1")?;
    let cfg = paper_search_config();
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg)?;
    let base = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);

    // Each ablation is the base plan with one field flipped — exactly what
    // a user does to a persisted plan.json. The five variants are
    // independent, so the batch runs on the simulator's deterministic
    // parallel driver (one arena engine per plan, results in input order).
    let mut tcp = base.clone();
    tcp.comm = CommMode::TcpCpu;
    let mut uniform = base.clone();
    uniform_1f1b(&mut uniform.strategy, H2_100B.n_layers);
    let mut naive = base.clone();
    naive.reshard = ReshardStrategy::NaiveP2p;
    let mut no_overlap = base.clone();
    no_overlap.fine_overlap = false;

    let sims = simulate_plans(&[&base, &tcp, &uniform, &naive, &no_overlap]);
    let full = sims[0].iteration_seconds;

    let rows = vec![
        AblationRow { label: "DDR + HeteroAuto + HeteroPP 1F1B (full)",
                      relative_percent: 100.0, paper_percent: 100.0 },
        AblationRow {
            label: "TCP instead of DDR",
            relative_percent: sims[1].iteration_seconds / full * 100.0,
            paper_percent: 110.1,
        },
        AblationRow {
            label: "Uniform 1F1B instead of HeteroPP",
            relative_percent: sims[2].iteration_seconds / full * 100.0,
            paper_percent: 126.4,
        },
        AblationRow {
            label: "w/o SR&AG resharding (naive P2P)",
            relative_percent: sims[3].iteration_seconds / full * 100.0,
            paper_percent: 104.8,
        },
        AblationRow {
            label: "w/o fine-grained overlap",
            relative_percent: sims[4].iteration_seconds / full * 100.0,
            paper_percent: 101.8,
        },
    ];
    Ok(rows)
}

/// One point on the pipeline-schedule axis of the Table 9 cluster.
#[derive(Clone, Debug)]
pub struct ScheduleAxisRow {
    /// The schedule the search was pinned to.
    pub schedule: Schedule,
    /// Simulated iteration seconds of the best plan under that pin, or
    /// `None` when no feasible strategy exists (interleaving can fail when
    /// no layer allocation chunks evenly).
    pub iteration_seconds: Option<f64>,
    /// Simulated TGS for the same plan.
    pub tgs: Option<f64>,
}

/// The schedule axis on the Table 9 cluster (Exp-C-1): HeteroAuto pinned
/// to each schedule in turn (ring collectives, the paper baseline),
/// winner simulated by the discrete-event executor. This is the
/// measurement the paper's single-`α` ablation could not make — the
/// schedules now differ in issue order, not just a coefficient.
pub fn schedule_axis(exp_name: &str) -> Result<Vec<ScheduleAxisRow>> {
    let exp = experiment(exp_name)?;
    let mut rows = Vec::new();
    for schedule in Schedule::SEARCH_SPACE {
        let cfg = SearchConfig {
            comm_algos: vec![CommAlgo::Ring],
            ..SearchConfig::pinned(schedule)
        };
        let row = match search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg) {
            Ok(r) => {
                let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
                let sim = simulate_plan(&plan);
                ScheduleAxisRow {
                    schedule,
                    iteration_seconds: Some(sim.iteration_seconds),
                    tgs: Some(plan.tgs(sim.iteration_seconds)),
                }
            }
            Err(_) => ScheduleAxisRow { schedule, iteration_seconds: None, tgs: None },
        };
        rows.push(row);
    }
    Ok(rows)
}

/// One point on the collective-algorithm axis of the Table 9 cluster.
#[derive(Clone, Debug)]
pub struct CommAlgoAxisRow {
    /// The DP-collective algorithm the search was pinned to.
    pub algo: CommAlgo,
    /// Simulated iteration seconds of the best plan under that pin, or
    /// `None` when no feasible strategy exists.
    pub iteration_seconds: Option<f64>,
    /// Simulated TGS for the same plan.
    pub tgs: Option<f64>,
}

/// The comm-algo axis on a Table 7 cluster: HeteroAuto pinned to 1F1B and
/// to each DiComm collective algorithm in turn (plus the auto selector),
/// winner simulated by the discrete-event executor — the hierarchical-vs-
/// flat gap of the DiComm §3 story, measured end to end.
pub fn comm_algo_axis(exp_name: &str) -> Result<Vec<CommAlgoAxisRow>> {
    let exp = experiment(exp_name)?;
    let mut rows = Vec::new();
    for algo in CommAlgo::ALL {
        let cfg = SearchConfig {
            comm_algos: vec![algo],
            ..SearchConfig::pinned(Schedule::OneF1B)
        };
        let row = match search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg) {
            Ok(r) => {
                let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
                let sim = simulate_plan(&plan);
                CommAlgoAxisRow {
                    algo,
                    iteration_seconds: Some(sim.iteration_seconds),
                    tgs: Some(plan.tgs(sim.iteration_seconds)),
                }
            }
            Err(_) => CommAlgoAxisRow { algo, iteration_seconds: None, tgs: None },
        };
        rows.push(row);
    }
    Ok(rows)
}

/// One evaluator's pricing of the kill-a-node elastic scenario from
/// [`recovery_vs_restart`].
#[derive(Clone, Debug)]
pub struct RecoveryRow {
    /// Which evaluator priced the incumbent's step time.
    pub evaluator: &'static str,
    /// The incumbent's per-step seconds under that evaluator.
    pub step_seconds: f64,
    /// The elastic-vs-restart timeline assembled at that step time.
    pub timeline: RecoveryTimeline,
}

/// Everything the kill-a-node scenario produced.
#[derive(Clone, Debug)]
pub struct RecoveryReport {
    /// The searched incumbent plan the node died under.
    pub incumbent: ExecutionPlan,
    /// The chip kind and count the scenario killed (one whole node).
    pub killed: (ChipKind, usize),
    /// The pipeline-preserving re-plan over the warm profile cache.
    pub outcome: ReplanOutcome,
    /// Measured wall-clock of the restart baseline's cold search.
    pub cold_search_seconds: f64,
    /// One row per evaluator (cost model, simulator, virtual coordinator).
    pub rows: Vec<RecoveryRow>,
}

/// The elastic tentpole scenario on a Table 7 cluster: search the
/// incumbent, kill one node of the widest-TP stage group, re-plan over
/// the still-warm [`ProfileCache`], and price elastic recovery (drain +
/// detect + warm re-plan + diff-only state migration) against a
/// restart-from-checkpoint (drain + detect + cold search + full-state
/// restore) under all three evaluators. The re-plan is hot-swap
/// compatible by construction, so the comparison is pure time — the loss
/// trajectory is bit-identical either way (`rust/tests/elastic.rs` holds
/// that end to end).
pub fn recovery_vs_restart(exp_name: &str) -> Result<RecoveryReport> {
    let exp = experiment(exp_name)?;
    let cfg = paper_search_config();
    let cache = ProfileCache::new();
    let r = search_with_cache(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg, &cache)?;
    let incumbent = r.to_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
    // Kill one whole node, preferring the largest stage group that still
    // has TP width to give up — a one-node loss in a TP-1 group cannot
    // keep the pipeline shape. Not every victim admits a
    // pipeline-preserving re-plan (the shrunk slice must still cover
    // whole nodes), so candidates are tried in preference order.
    let mut candidates: Vec<_> = incumbent
        .stage_groups
        .iter()
        .zip(&incumbent.strategy.plans)
        .collect();
    candidates.sort_by_key(|(g, p)| (p.s_tp < 2, std::cmp::Reverse(g.n_chips)));
    let mut chosen = None;
    let mut last_err = None;
    for (victim, _) in candidates {
        let killed = (victim.spec.kind, victim.spec.chips_per_node);
        let delta = ClusterDelta::exclude(killed.0, killed.1);
        match replan(&incumbent, &delta, &cache, &ReplanOptions::default()) {
            Ok(outcome) => {
                chosen = Some((killed, outcome));
                break;
            }
            Err(e) => last_err = Some(e),
        }
    }
    let (killed, outcome) = chosen.ok_or_else(|| {
        last_err.unwrap_or_else(|| {
            anyhow::anyhow!("plan `{}` has no stage groups to kill", incumbent.name)
        })
    })?;
    swap_compatible(&incumbent, &outcome.plan)?;
    // The restart baseline re-plans from scratch: a cold-cache search
    // over the surviving cluster.
    let t = Instant::now();
    search(&H2_100B, &outcome.plan.cluster, exp.gbs_tokens, &cfg)?;
    let cold_search_seconds = t.elapsed().as_secs_f64();
    let debounce = MonitorConfig::default().debounce;
    let virtual_step = {
        let vopts = VirtualOptions { steps: 1, log_every: 0, ..VirtualOptions::default() };
        train_virtual(&incumbent, &vopts)?.step_seconds
    };
    let mut rows = Vec::new();
    for (evaluator, step_seconds) in [
        ("cost model", incumbent.evaluate().iteration_seconds),
        ("simulator", simulate_plan(&incumbent).iteration_seconds),
        ("virtual coordinator", virtual_step),
    ] {
        let timeline = RecoveryTimeline::new(
            &incumbent,
            &outcome.plan,
            step_seconds,
            debounce,
            outcome.elapsed_seconds,
            cold_search_seconds,
        )?;
        rows.push(RecoveryRow { evaluator, step_seconds, timeline });
    }
    Ok(RecoveryReport { incumbent, killed, outcome, cold_search_seconds, rows })
}

/// One policy's fleet metrics on the pinned trace (`h2 report fleet`).
#[derive(Clone, Debug)]
pub struct FleetPolicyRow {
    /// The queue policy the run used.
    pub policy: crate::fleet::Policy,
    /// The fleet metrics the run produced.
    pub metrics: crate::fleet::FleetMetrics,
}

/// Run the pinned fleet trace (`JobTrace::pinned`) on `exp_name` under
/// both policies and return one metrics row each — the FIFO-vs-backfill
/// comparison behind EXPERIMENTS.md §Fleet. Deterministic for any
/// `workers` (0 = one per core); `rust/tests/fleet.rs` pins the
/// relationship the comparison exists to show: priority-with-backfill
/// beats FIFO on p99 job wait.
pub fn fleet_metrics(exp_name: &str, workers: usize) -> Result<Vec<FleetPolicyRow>> {
    use crate::fleet::{run, FleetOptions, JobTrace, Policy};
    let exp = experiment(exp_name)?;
    let trace = JobTrace::pinned(exp.cluster.total_chips());
    let mut rows = Vec::new();
    for policy in [Policy::Fifo, Policy::PriorityBackfill] {
        let opts = FleetOptions { policy, workers, ..FleetOptions::default() };
        let timeline = run(&exp.cluster, &trace, &opts)?;
        rows.push(FleetPolicyRow { policy, metrics: timeline.metrics });
    }
    Ok(rows)
}

/// One labeled fleet run in the faulty-vs-healthy comparison behind
/// `h2 report fleet` (and EXPERIMENTS.md §Fleet-faults).
#[derive(Clone, Debug)]
pub struct FleetFaultRow {
    /// Which run this is: `healthy`, `cascade`, or `restart`.
    pub label: &'static str,
    /// The fleet metrics the run produced.
    pub metrics: crate::fleet::FleetMetrics,
}

/// Run the pinned fleet trace on `exp_name` healthy, then under the
/// pinned cluster fault plan with the graceful-degradation cascade, then
/// under the same faults with the restart-every-victim baseline — the
/// side-by-side that shows what the cascade buys. Deterministic for any
/// `workers`. The contrast uses a 10-step checkpoint grid so the
/// requeued job has real recompute to pay.
pub fn fleet_fault_metrics(exp_name: &str, workers: usize) -> Result<Vec<FleetFaultRow>> {
    use crate::fleet::{run, ClusterFaultPlan, FaultResponse, FleetOptions, JobTrace, Policy};
    let exp = experiment(exp_name)?;
    let trace = JobTrace::pinned(exp.cluster.total_chips());
    let base = FleetOptions {
        policy: Policy::Fifo,
        workers,
        checkpoint_every: 10,
        ..FleetOptions::default()
    };
    let healthy = run(&exp.cluster, &trace, &base)?;
    let faults = ClusterFaultPlan::pinned_for(&exp.cluster, &healthy)?;
    let mut rows = vec![FleetFaultRow { label: "healthy", metrics: healthy.metrics }];
    for (label, response) in
        [("cascade", FaultResponse::Cascade), ("restart", FaultResponse::RestartAlways)]
    {
        let opts =
            FleetOptions { faults: Some(faults.clone()), response, ..base.clone() };
        let timeline = run(&exp.cluster, &trace, &opts)?;
        rows.push(FleetFaultRow { label, metrics: timeline.metrics });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_within_10_percent_of_paper() {
        for row in table6_all() {
            let rel = (row.model_tgs - row.paper_tgs).abs() / row.paper_tgs;
            assert!(rel < 0.10, "{}: model {} vs paper {}", row.kind,
                    row.model_tgs, row.paper_tgs);
        }
    }

    #[test]
    fn fig11_shape_holds() {
        let baselines = table6_all();
        // Constant-GBS runs stay below 100%; summed-GBS runs exceed 100%
        // (the paper's superlinear headline).
        let a1 = hetero_row("exp-a-1", &baselines).unwrap();
        let a2 = hetero_row("exp-a-2", &baselines).unwrap();
        assert!(a2.speedup_ratio > 100.0, "exp-a-2 ratio {}", a2.speedup_ratio);
        assert!(a1.speedup_ratio < a2.speedup_ratio);
    }

    #[test]
    fn schedule_axis_covers_every_variant() {
        let rows = schedule_axis("exp-a-1").unwrap();
        assert_eq!(rows.len(), Schedule::SEARCH_SPACE.len());
        // The paper's 1F1B baseline always exists on the Table 7 clusters.
        let f1b1 = rows[0].iteration_seconds.expect("1F1B must be feasible");
        assert!(f1b1.is_finite() && f1b1 > 0.0);
        // The zero-bubble schedule shares 1F1B's memory envelope, so it is
        // feasible whenever 1F1B is.
        assert!(rows[2].iteration_seconds.is_some());
    }

    #[test]
    fn comm_algo_axis_measures_the_hierarchical_win() {
        let rows = comm_algo_axis("exp-a-1").unwrap();
        assert_eq!(rows.len(), CommAlgo::ALL.len());
        let get = |algo| {
            rows.iter()
                .find(|r| r.algo == algo)
                .and_then(|r| r.iteration_seconds)
                .unwrap_or_else(|| panic!("{algo} must be feasible"))
        };
        let ring = get(CommAlgo::Ring);
        let hier = get(CommAlgo::Hierarchical);
        let auto = get(CommAlgo::Auto);
        // The two-level collective never loses to the flat ring, and the
        // selector never loses to either. (Each pin may search out a
        // slightly different strategy shape, so the simulated comparison
        // carries a small slack; the strict same-plan ordering is covered
        // by the integration fixture.)
        assert!(hier <= ring * 1.02, "hier {hier} vs ring {ring}");
        assert!(auto <= ring * 1.02, "auto {auto} vs ring {ring}");
        assert!(auto <= hier * 1.02, "auto {auto} vs hier {hier}");
    }

    #[test]
    fn table9_ordering_holds() {
        let rows = table9_ablation().unwrap();
        assert_eq!(rows[0].relative_percent, 100.0);
        for row in &rows[1..] {
            assert!(row.relative_percent > 100.0, "{}: {}", row.label,
                    row.relative_percent);
        }
        // Uniform 1F1B is the worst variant, as in the paper.
        let uniform = rows.iter().find(|r| r.label.contains("Uniform")).unwrap();
        for row in &rows[1..] {
            assert!(uniform.relative_percent >= row.relative_percent - 1e-9);
        }
    }
}
