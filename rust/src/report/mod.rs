//! Paper-experiment drivers shared by `h2 report`, the benches, and the
//! examples: Table 6 baselines, Fig 11 HeteroSpeedupRatio, Table 9
//! ablations — each returning paper-vs-measured pairs.

use anyhow::Result;

use crate::auto::{search, SearchConfig, SearchResult};
use crate::comm::CommMode;
use crate::costmodel::{uniform_1f1b, GroupPlan, Strategy, H2_100B};
use crate::hetero::{experiment, homogeneous_baseline, ChipKind};
use crate::plan::{ExecutionPlan, PlanBuilder};
use crate::sim::{simulate_plan, ReshardStrategy};

/// Table 6 rows: (chip, PP, DP, TP, recompute, paper TGS).
pub const TABLE6: [(ChipKind, usize, usize, usize, bool, f64); 4] = [
    (ChipKind::A, 16, 4, 4, false, 136.9),
    (ChipKind::B, 16, 4, 4, true, 143.7),
    (ChipKind::C, 32, 2, 4, true, 46.2),
    (ChipKind::D, 8, 4, 8, false, 99.5),
];

/// Fig 11 paper ratios: (experiment, HeteroSpeedupRatio %).
pub const FIG11_PAPER: [(&str, f64); 4] = [
    ("exp-a-1", 89.56),
    ("exp-a-2", 109.03),
    ("exp-b-1", 77.45),
    ("exp-b-2", 104.29),
];

/// Table 8 paper search times (seconds): Exp-A, Exp-B, Exp-C.
pub const TABLE8_PAPER: [(&str, f64); 3] =
    [("exp-a-1", 0.62), ("exp-b-1", 5.48), ("exp-c-1", 12.29)];

/// One Table 6 evaluation (homogeneous baseline).
#[derive(Clone, Debug)]
pub struct BaselineRow {
    pub kind: ChipKind,
    pub strategy: Strategy,
    pub model_tgs: f64,
    pub sim_tgs: f64,
    pub paper_tgs: f64,
}

/// The homogeneous-baseline plan behind one Table 6 row.
pub fn table6_plan(kind: ChipKind, pp: usize, dp: usize, tp: usize, rec: bool) -> ExecutionPlan {
    let exp = homogeneous_baseline(kind);
    let strategy = Strategy {
        s_dp: dp,
        micro_batches: exp.gbs_tokens / H2_100B.seq_len / dp,
        plans: vec![GroupPlan { s_pp: pp, s_tp: tp, layers: 96, recompute: rec }],
    };
    PlanBuilder::new(&format!("table6-{kind}"))
        .model(H2_100B)
        .cluster(exp.cluster)
        .strategy(strategy)
        .gbs_tokens(exp.gbs_tokens)
        .build()
        .expect("Table 6 configurations are valid")
}

/// Evaluate one Table 6 row with both the cost model and the simulator.
pub fn table6_row(kind: ChipKind, pp: usize, dp: usize, tp: usize, rec: bool,
                  paper: f64) -> BaselineRow {
    let plan = table6_plan(kind, pp, dp, tp, rec);
    let eval = plan.evaluate();
    let sim = plan.simulate();
    BaselineRow {
        kind,
        model_tgs: plan.tgs(eval.iteration_seconds),
        sim_tgs: plan.tgs(sim.iteration_seconds),
        paper_tgs: paper,
        strategy: plan.strategy,
    }
}

pub fn table6_all() -> Vec<BaselineRow> {
    TABLE6
        .iter()
        .map(|&(k, pp, dp, tp, rec, paper)| table6_row(k, pp, dp, tp, rec, paper))
        .collect()
}

/// A Fig 11 heterogeneous result.
#[derive(Clone, Debug)]
pub struct HeteroRow {
    pub exp: String,
    pub search: SearchResult,
    pub sim_tgs: f64,
    /// HeteroSpeedupRatio against *our* simulated baselines (the paper's
    /// definition: N·TGS / Σ N_i·TGS_i).
    pub speedup_ratio: f64,
    pub paper_ratio: Option<f64>,
}

/// Run HeteroAuto + the simulator for one Table 7 experiment and compute
/// the HeteroSpeedupRatio against the Table 6 baselines. The searched
/// strategy flows to the simulator as an [`ExecutionPlan`] — the same
/// artifact `h2 search --emit-plan` persists.
pub fn hetero_row(exp_name: &str, baselines: &[BaselineRow]) -> Result<HeteroRow> {
    let exp = experiment(exp_name)?;
    let cfg = SearchConfig::default();
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg)?;
    let plan = r.to_plan(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg);
    let sim = simulate_plan(&plan);
    let hetero_tgs = plan.tgs(sim.iteration_seconds);

    let mut denom = 0.0;
    for g in &exp.cluster.groups {
        let base = baselines
            .iter()
            .find(|b| b.kind == g.spec.kind)
            .map(|b| b.sim_tgs)
            .unwrap_or(0.0);
        denom += g.n_chips as f64 * base;
    }
    let ratio = hetero_tgs * exp.cluster.total_chips() as f64 / denom * 100.0;
    let paper = FIG11_PAPER
        .iter()
        .find(|(n, _)| *n == exp_name)
        .map(|(_, v)| *v);
    Ok(HeteroRow {
        exp: exp_name.to_string(),
        search: r,
        sim_tgs: hetero_tgs,
        speedup_ratio: ratio,
        paper_ratio: paper,
    })
}

/// Table 9 ablation variants on Exp-C-1 (relative iteration time, % of full).
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub label: &'static str,
    pub relative_percent: f64,
    pub paper_percent: f64,
}

pub fn table9_ablation() -> Result<Vec<AblationRow>> {
    let exp = experiment("exp-c-1")?;
    let cfg = SearchConfig::default();
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg)?;
    let base = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg);
    let run = |plan: &ExecutionPlan| simulate_plan(plan).iteration_seconds;
    let full = run(&base);

    // Each ablation is the base plan with one field flipped — exactly what
    // a user does to a persisted plan.json.
    let mut tcp = base.clone();
    tcp.comm = CommMode::TcpCpu;
    let mut uniform = base.clone();
    uniform_1f1b(&mut uniform.strategy, H2_100B.n_layers);
    let mut naive = base.clone();
    naive.reshard = ReshardStrategy::NaiveP2p;
    let mut no_overlap = base.clone();
    no_overlap.fine_overlap = false;

    let rows = vec![
        AblationRow { label: "DDR + HeteroAuto + HeteroPP 1F1B (full)",
                      relative_percent: 100.0, paper_percent: 100.0 },
        AblationRow {
            label: "TCP instead of DDR",
            relative_percent: run(&tcp) / full * 100.0,
            paper_percent: 110.1,
        },
        AblationRow {
            label: "Uniform 1F1B instead of HeteroPP",
            relative_percent: run(&uniform) / full * 100.0,
            paper_percent: 126.4,
        },
        AblationRow {
            label: "w/o SR&AG resharding (naive P2P)",
            relative_percent: run(&naive) / full * 100.0,
            paper_percent: 104.8,
        },
        AblationRow {
            label: "w/o fine-grained overlap",
            relative_percent: run(&no_overlap) / full * 100.0,
            paper_percent: 101.8,
        },
    ];
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_within_10_percent_of_paper() {
        for row in table6_all() {
            let rel = (row.model_tgs - row.paper_tgs).abs() / row.paper_tgs;
            assert!(rel < 0.10, "{}: model {} vs paper {}", row.kind,
                    row.model_tgs, row.paper_tgs);
        }
    }

    #[test]
    fn fig11_shape_holds() {
        let baselines = table6_all();
        // Constant-GBS runs stay below 100%; summed-GBS runs exceed 100%
        // (the paper's superlinear headline).
        let a1 = hetero_row("exp-a-1", &baselines).unwrap();
        let a2 = hetero_row("exp-a-2", &baselines).unwrap();
        assert!(a2.speedup_ratio > 100.0, "exp-a-2 ratio {}", a2.speedup_ratio);
        assert!(a1.speedup_ratio < a2.speedup_ratio);
    }

    #[test]
    fn table9_ordering_holds() {
        let rows = table9_ablation().unwrap();
        assert_eq!(rows[0].relative_percent, 100.0);
        for row in &rows[1..] {
            assert!(row.relative_percent > 100.0, "{}: {}", row.label,
                    row.relative_percent);
        }
        // Uniform 1F1B is the worst variant, as in the paper.
        let uniform = rows.iter().find(|r| r.label.contains("Uniform")).unwrap();
        for row in &rows[1..] {
            assert!(uniform.relative_percent >= row.relative_percent - 1e-9);
        }
    }
}
