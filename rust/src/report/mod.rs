//! Paper-experiment drivers shared by `h2 report`, the benches, and the
//! examples: Table 6 baselines, Fig 11 HeteroSpeedupRatio, Table 9
//! ablations — each returning paper-vs-measured pairs.

use anyhow::Result;

use crate::auto::{search, SearchConfig, SearchResult};
use crate::comm::CommMode;
use crate::costmodel::{evaluate, tgs, GroupPlan, Strategy, H2_100B};
use crate::hetero::{experiment, homogeneous_baseline, ChipGroup, ChipKind};
use crate::sim::{simulate_iteration, ReshardStrategy, SimOptions};

/// Table 6 rows: (chip, PP, DP, TP, recompute, paper TGS).
pub const TABLE6: [(ChipKind, usize, usize, usize, bool, f64); 4] = [
    (ChipKind::A, 16, 4, 4, false, 136.9),
    (ChipKind::B, 16, 4, 4, true, 143.7),
    (ChipKind::C, 32, 2, 4, true, 46.2),
    (ChipKind::D, 8, 4, 8, false, 99.5),
];

/// Fig 11 paper ratios: (experiment, HeteroSpeedupRatio %).
pub const FIG11_PAPER: [(&str, f64); 4] = [
    ("exp-a-1", 89.56),
    ("exp-a-2", 109.03),
    ("exp-b-1", 77.45),
    ("exp-b-2", 104.29),
];

/// Table 8 paper search times (seconds): Exp-A, Exp-B, Exp-C.
pub const TABLE8_PAPER: [(&str, f64); 3] =
    [("exp-a-1", 0.62), ("exp-b-1", 5.48), ("exp-c-1", 12.29)];

/// One Table 6 evaluation (homogeneous baseline).
#[derive(Clone, Debug)]
pub struct BaselineRow {
    pub kind: ChipKind,
    pub strategy: Strategy,
    pub model_tgs: f64,
    pub sim_tgs: f64,
    pub paper_tgs: f64,
}

/// Evaluate one Table 6 row with both the cost model and the simulator.
pub fn table6_row(kind: ChipKind, pp: usize, dp: usize, tp: usize, rec: bool,
                  paper: f64) -> BaselineRow {
    let exp = homogeneous_baseline(kind);
    let groups = exp.cluster.groups_by_memory_desc();
    let strategy = Strategy {
        s_dp: dp,
        micro_batches: exp.gbs_tokens / H2_100B.seq_len / dp,
        plans: vec![GroupPlan { s_pp: pp, s_tp: tp, layers: 96, recompute: rec }],
    };
    let eval = evaluate(&H2_100B, &groups, &strategy, H2_100B.seq_len, 1.0);
    let sim = simulate_iteration(&H2_100B, &groups, &strategy, H2_100B.seq_len,
                                 &SimOptions::default());
    BaselineRow {
        kind,
        model_tgs: tgs(&exp.cluster, exp.gbs_tokens, eval.iteration_seconds),
        sim_tgs: tgs(&exp.cluster, exp.gbs_tokens, sim.iteration_seconds),
        paper_tgs: paper,
        strategy,
    }
}

pub fn table6_all() -> Vec<BaselineRow> {
    TABLE6
        .iter()
        .map(|&(k, pp, dp, tp, rec, paper)| table6_row(k, pp, dp, tp, rec, paper))
        .collect()
}

/// A Fig 11 heterogeneous result.
#[derive(Clone, Debug)]
pub struct HeteroRow {
    pub exp: String,
    pub search: SearchResult,
    pub sim_tgs: f64,
    /// HeteroSpeedupRatio against *our* simulated baselines (the paper's
    /// definition: N·TGS / Σ N_i·TGS_i).
    pub speedup_ratio: f64,
    pub paper_ratio: Option<f64>,
}

/// Run HeteroAuto + the simulator for one Table 7 experiment and compute
/// the HeteroSpeedupRatio against the Table 6 baselines.
pub fn hetero_row(exp_name: &str, baselines: &[BaselineRow]) -> Result<HeteroRow> {
    let exp = experiment(exp_name)?;
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default())?;
    let grefs: Vec<&ChipGroup> = r.groups.iter().collect();
    let sim = simulate_iteration(&H2_100B, &grefs, &r.strategy, H2_100B.seq_len,
                                 &SimOptions::default());
    let hetero_tgs = tgs(&exp.cluster, exp.gbs_tokens, sim.iteration_seconds);

    let mut denom = 0.0;
    for g in &exp.cluster.groups {
        let base = baselines
            .iter()
            .find(|b| b.kind == g.spec.kind)
            .map(|b| b.sim_tgs)
            .unwrap_or(0.0);
        denom += g.n_chips as f64 * base;
    }
    let ratio = hetero_tgs * exp.cluster.total_chips() as f64 / denom * 100.0;
    let paper = FIG11_PAPER
        .iter()
        .find(|(n, _)| *n == exp_name)
        .map(|(_, v)| *v);
    Ok(HeteroRow {
        exp: exp_name.to_string(),
        search: r,
        sim_tgs: hetero_tgs,
        speedup_ratio: ratio,
        paper_ratio: paper,
    })
}

/// Table 9 ablation variants on Exp-C-1 (relative iteration time, % of full).
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub label: &'static str,
    pub relative_percent: f64,
    pub paper_percent: f64,
}

pub fn table9_ablation() -> Result<Vec<AblationRow>> {
    let exp = experiment("exp-c-1")?;
    let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &SearchConfig::default())?;
    let grefs: Vec<&ChipGroup> = r.groups.iter().collect();
    let run = |opts: &SimOptions, strategy: &Strategy| {
        simulate_iteration(&H2_100B, &grefs, strategy, H2_100B.seq_len, opts)
            .iteration_seconds
    };
    let full = run(&SimOptions::default(), &r.strategy);

    // Uniform 1F1B: equal layers per stage, recompute everywhere.
    let mut uniform = r.strategy.clone();
    let total_stages: usize = uniform.plans.iter().map(|p| p.s_pp).sum();
    let lps = H2_100B.n_layers / total_stages;
    for p in uniform.plans.iter_mut() {
        p.layers = lps * p.s_pp;
        p.recompute = true;
    }
    let mut assigned: usize = uniform.plans.iter().map(|p| p.layers).sum();
    let mut i = 0;
    while assigned < H2_100B.n_layers {
        let k = i % uniform.plans.len();
        uniform.plans[k].layers += uniform.plans[k].s_pp;
        assigned += uniform.plans[k].s_pp;
        i += 1;
    }

    let rows = vec![
        AblationRow { label: "DDR + HeteroAuto + HeteroPP 1F1B (full)",
                      relative_percent: 100.0, paper_percent: 100.0 },
        AblationRow {
            label: "TCP instead of DDR",
            relative_percent: run(&SimOptions { comm: CommMode::TcpCpu,
                                                ..Default::default() }, &r.strategy)
                / full * 100.0,
            paper_percent: 110.1,
        },
        AblationRow {
            label: "Uniform 1F1B instead of HeteroPP",
            relative_percent: run(&SimOptions::default(), &uniform) / full * 100.0,
            paper_percent: 126.4,
        },
        AblationRow {
            label: "w/o SR&AG resharding (naive P2P)",
            relative_percent: run(&SimOptions { reshard: ReshardStrategy::NaiveP2p,
                                                ..Default::default() }, &r.strategy)
                / full * 100.0,
            paper_percent: 104.8,
        },
        AblationRow {
            label: "w/o fine-grained overlap",
            relative_percent: run(&SimOptions { fine_overlap: false,
                                                ..Default::default() }, &r.strategy)
                / full * 100.0,
            paper_percent: 101.8,
        },
    ];
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_within_10_percent_of_paper() {
        for row in table6_all() {
            let rel = (row.model_tgs - row.paper_tgs).abs() / row.paper_tgs;
            assert!(rel < 0.10, "{}: model {} vs paper {}", row.kind,
                    row.model_tgs, row.paper_tgs);
        }
    }

    #[test]
    fn fig11_shape_holds() {
        let baselines = table6_all();
        // Constant-GBS runs stay below 100%; summed-GBS runs exceed 100%
        // (the paper's superlinear headline).
        let a1 = hetero_row("exp-a-1", &baselines).unwrap();
        let a2 = hetero_row("exp-a-2", &baselines).unwrap();
        assert!(a2.speedup_ratio > 100.0, "exp-a-2 ratio {}", a2.speedup_ratio);
        assert!(a1.speedup_ratio < a2.speedup_ratio);
    }

    #[test]
    fn table9_ordering_holds() {
        let rows = table9_ablation().unwrap();
        assert_eq!(rows[0].relative_percent, 100.0);
        for row in &rows[1..] {
            assert!(row.relative_percent > 100.0, "{}: {}", row.label,
                    row.relative_percent);
        }
        // Uniform 1F1B is the worst variant, as in the paper.
        let uniform = rows.iter().find(|r| r.label.contains("Uniform")).unwrap();
        for row in &rows[1..] {
            assert!(uniform.relative_percent >= row.relative_percent - 1e-9);
        }
    }
}
