//! PJRT runtime: loads the AOT artifacts produced by `make artifacts` and
//! executes them on the CPU PJRT client.
//!
//! Interchange format is HLO *text* (see `python/compile/aot.py` and
//! DESIGN.md): `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile` → `execute`. Python never runs at request time; this
//! module is the only boundary between the rust coordinator and the
//! compiled L1/L2 compute.
//!
//! The real backend needs the `xla` crate (xla-rs) and its native XLA
//! libraries, which offline/CI builds don't have, so it is gated behind
//! the off-by-default `pjrt` cargo feature (enabling it requires adding
//! `xla` to `[dependencies]`). Without the feature, manifests, tensors
//! and metadata all still work; only [`Runtime::load`] / [`Executable::run`]
//! report that execution is unavailable. Search, simulation, cost model
//! and plan tooling never touch this path.

pub mod manifest;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::anyhow;
use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactMeta, Manifest, ModelEntry, ParamMeta, TensorMeta};

/// A host-side tensor (f32 or i32), the coordinator's working currency.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    /// Dense f32 tensor (row-major).
    F32 { shape: Vec<usize>, data: Vec<f32> },
    /// Dense i32 tensor (row-major, token ids).
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    /// Build an f32 tensor; panics if `data` does not fill `shape`.
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    /// Build an i32 tensor; panics if `data` does not fill `shape`.
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    /// A rank-0 f32 scalar.
    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    /// Same shape and dtype, zero-filled.
    pub fn zeros_like(&self) -> Self {
        match self {
            HostTensor::F32 { shape, data } =>
                HostTensor::F32 { shape: shape.clone(), data: vec![0.0; data.len()] },
            HostTensor::I32 { shape, data } =>
                HostTensor::I32 { shape: shape.clone(), data: vec![0; data.len()] },
        }
    }

    /// Tensor dimensions.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow the f32 payload, or error for i32 tensors.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Mutably borrow the f32 payload, or error for i32 tensors.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    #[cfg(feature = "pjrt")]
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let buf = match self {
            HostTensor::F32 { shape, data } =>
                client.buffer_from_host_buffer(data, shape, None)?,
            HostTensor::I32 { shape, data } =>
                client.buffer_from_host_buffer(data, shape, None)?,
        };
        Ok(buf)
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            ty => bail!("unsupported artifact output dtype {ty:?}"),
        }
    }
}

/// One compiled artifact, ready to execute.
#[cfg(feature = "pjrt")]
pub struct Executable {
    /// `model/artifact` identifier.
    pub name: String,
    /// Input/output signature and parameter list.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

/// Stub executable (crate built without the `pjrt` feature): carries the
/// artifact metadata so planning/arity code works, but cannot run.
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    /// `model/artifact` identifier.
    pub name: String,
    /// Input/output signature and parameter list.
    pub meta: ArtifactMeta,
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    /// Always errors: execution needs the xla-backed build.
    pub fn run(&self, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        bail!("{}: built without the `pjrt` feature — real execution needs \
               the xla-backed runtime (add the `xla` crate and build with \
               --features pjrt)", self.name)
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with host tensors; returns the decomposed outputs.
    ///
    /// Inputs are staged through explicit `PjRtBuffer`s and `execute_b`
    /// rather than the crate's `execute(&[Literal])`: the latter leaks every
    /// input device buffer (`buffer.release()` in the C++ shim with no
    /// owner), which at 100M-model scale is ~4 GB per training step.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: expected {} inputs, got {}", self.name,
                  self.meta.inputs.len(), inputs.len());
        }
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<Result<_>>()?;
        let out = self.exe.execute_b::<xla::PjRtBuffer>(&bufs)
            .with_context(|| format!("executing {}", self.name))?;
        let row = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output device row", self.name))?;
        let mut tensors = Vec::new();
        for buf in row {
            let mut lit = buf.to_literal_sync()?;
            // Lowered with return_tuple=True: decompose tuple outputs.
            let shape = lit.shape()?;
            if matches!(shape, xla::Shape::Tuple(_)) {
                for el in lit.decompose_tuple()? {
                    tensors.push(HostTensor::from_literal(&el)?);
                }
            } else {
                tensors.push(HostTensor::from_literal(&lit)?);
            }
        }
        if tensors.len() != self.meta.outputs.len() {
            bail!("{}: expected {} outputs, got {}", self.name,
                  self.meta.outputs.len(), tensors.len());
        }
        Ok(tensors)
    }
}

/// The runtime: one PJRT CPU client plus a compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    root: PathBuf,
    /// The validated artifact manifest.
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

/// Stub runtime (crate built without the `pjrt` feature): opens and
/// validates the artifact manifest, but cannot compile or execute.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    #[allow(dead_code)]
    root: PathBuf,
    /// The validated artifact manifest.
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Open `artifacts/` (the directory holding `manifest.json`).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&root.join("manifest.json"))
            .with_context(|| format!("opening artifact set {root:?}"))?;
        Ok(Runtime { root, manifest })
    }

    /// PJRT platform name (the stub reports itself as such).
    pub fn platform(&self) -> String {
        "stub (no pjrt feature)".to_string()
    }

    /// Always errors: execution needs the xla-backed build.
    pub fn load(&self, model: &str, artifact: &str) -> Result<std::sync::Arc<Executable>> {
        let _ = self.manifest.artifact(model, artifact)?;
        bail!("{model}/{artifact}: built without the `pjrt` feature — real \
               execution needs the xla-backed runtime (add the `xla` crate \
               and build with --features pjrt)")
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open `artifacts/` (the directory holding `manifest.json`).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&root.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, root, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and cache) one artifact of a model.
    pub fn load(&self, model: &str, artifact: &str) -> Result<std::sync::Arc<Executable>> {
        let key = format!("{model}/{artifact}");
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(model, artifact)?.clone();
        let path = self.root.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("XLA-compiling {key}"))?;
        let executable = std::sync::Arc::new(Executable {
            name: key.clone(),
            meta,
            exe,
            client: self.client.clone(),
        });
        self.cache.lock().unwrap().insert(key, executable.clone());
        Ok(executable)
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        if p.join("manifest.json").exists() { Some(p) } else { None }
    }

    #[test]
    fn tiny_sqnorm_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(dir).unwrap();
        let exe = rt.load("h2_tiny", "first_l1_sqnorm").unwrap();
        // sqnorm(grads...) = sum of squares over all inputs.
        let inputs: Vec<HostTensor> = exe.meta.inputs.iter()
            .map(|t| HostTensor::f32(&t.shape, vec![1.0; t.shape.iter().product()]))
            .collect();
        let total: usize = inputs.iter().map(|t| t.len()).sum();
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].as_f32().unwrap()[0];
        assert!((v - total as f32).abs() / (total as f32) < 1e-6, "{v} vs {total}");
    }

    #[test]
    fn executable_cache_hits() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(dir).unwrap();
        let a = rt.load("h2_tiny", "first_l1_sqnorm").unwrap();
        let b = rt.load("h2_tiny", "first_l1_sqnorm").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(dir).unwrap();
        let exe = rt.load("h2_tiny", "first_l1_sqnorm").unwrap();
        assert!(exe.run(&[]).is_err());
    }
}
