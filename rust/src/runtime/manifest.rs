//! Typed view of `artifacts/manifest.json` (written by `compile/aot.py`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// Tensor metadata (shape + dtype) for artifact inputs/outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Element type (`f32`, `i32`, ...).
    pub dtype: String,
}

/// Named parameter in a stage's flat parameter list (the wire ABI).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamMeta {
    /// Parameter name (artifact input order).
    pub name: String,
    /// Parameter dimensions.
    pub shape: Vec<usize>,
}

impl ParamMeta {
    /// Total element count of the parameter.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// HLO text file, relative to the artifact root.
    pub file: String,
    /// Input signature in call order.
    pub inputs: Vec<TensorMeta>,
    /// Output signature in return order.
    pub outputs: Vec<TensorMeta>,
    /// Pipeline role hint (`first`/`mid`/`last`), when exported.
    pub role: Option<String>,
    /// Layer count of the stage, when exported.
    pub n_layers: Option<usize>,
    /// Micro-batch rows baked into the artifact, when exported.
    pub micro_batch: Option<usize>,
    /// Sequence length baked into the artifact, when exported.
    pub seq: Option<usize>,
    /// Trainable parameters in artifact input order.
    pub params: Vec<ParamMeta>,
}

/// One exported model (config + artifact set).
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Decoder layer count.
    pub n_layers: usize,
    /// Model width.
    pub hidden: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Key/value heads (GQA).
    pub n_kv_heads: usize,
    /// MLP intermediate width.
    pub intermediate: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length the artifacts were compiled for.
    pub seq_len: usize,
    /// Total trainable parameters.
    pub param_count: usize,
    /// Every compiled artifact of the model, by name.
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

/// Full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Every model in the artifact set, by name.
    pub models: BTreeMap<String, ModelEntry>,
}

fn tensor_meta(v: &Value) -> Result<TensorMeta> {
    let shape = v.get("shape")?.arr()?
        .iter().map(|d| d.usize()).collect::<Result<Vec<_>>>()?;
    Ok(TensorMeta { shape, dtype: v.get("dtype")?.str()?.to_string() })
}

impl Manifest {
    /// Read and validate `manifest.json`.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let v = Value::from_file(path.to_str().unwrap())
            .with_context(|| format!("loading manifest {path:?}"))?;
        let mut models = BTreeMap::new();
        for (name, entry) in v.get("models")?.obj()? {
            let cfg = entry.get("config")?;
            let mut artifacts = BTreeMap::new();
            for (aname, a) in entry.get("artifacts")?.obj()? {
                let params = match a.opt("params") {
                    Some(ps) => ps.arr()?.iter().map(|p| {
                        Ok(ParamMeta {
                            name: p.get("name")?.str()?.to_string(),
                            shape: p.get("shape")?.arr()?
                                .iter().map(|d| d.usize()).collect::<Result<Vec<_>>>()?,
                        })
                    }).collect::<Result<Vec<_>>>()?,
                    None => Vec::new(),
                };
                artifacts.insert(aname.clone(), ArtifactMeta {
                    file: a.get("file")?.str()?.to_string(),
                    inputs: a.get("inputs")?.arr()?.iter()
                        .map(tensor_meta).collect::<Result<_>>()?,
                    outputs: a.get("outputs")?.arr()?.iter()
                        .map(tensor_meta).collect::<Result<_>>()?,
                    role: a.opt("role").and_then(|r| r.str().ok()).map(|s| s.to_string()),
                    n_layers: a.opt("n_layers").and_then(|x| x.usize().ok()),
                    micro_batch: a.opt("micro_batch").and_then(|x| x.usize().ok()),
                    seq: a.opt("seq").and_then(|x| x.usize().ok()),
                    params,
                });
            }
            models.insert(name.clone(), ModelEntry {
                n_layers: cfg.get("n_layers")?.usize()?,
                hidden: cfg.get("hidden")?.usize()?,
                n_heads: cfg.get("n_heads")?.usize()?,
                n_kv_heads: cfg.get("n_kv_heads")?.usize()?,
                intermediate: cfg.get("intermediate")?.usize()?,
                vocab: cfg.get("vocab")?.usize()?,
                seq_len: cfg.get("seq_len")?.usize()?,
                param_count: cfg.get("param_count")?.usize()?,
                artifacts,
            });
        }
        Ok(Manifest { models })
    }

    /// Look up a model entry by name.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        match self.models.get(name) {
            Some(m) => Ok(m),
            None => bail!("manifest has no model `{name}` (have: {:?})",
                          self.models.keys().collect::<Vec<_>>()),
        }
    }

    /// Look up one artifact of a model.
    pub fn artifact(&self, model: &str, artifact: &str) -> Result<&ArtifactMeta> {
        let m = self.model(model)?;
        match m.artifacts.get(artifact) {
            Some(a) => Ok(a),
            None => bail!("model `{model}` has no artifact `{artifact}`"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_present() {
        let path = Path::new("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(path).unwrap();
        let tiny = m.model("h2_tiny").unwrap();
        assert_eq!(tiny.n_layers, 4);
        let fwd = m.artifact("h2_tiny", "first_l2_fwd").unwrap();
        assert_eq!(fwd.role.as_deref(), Some("first"));
        assert_eq!(fwd.inputs.len(), fwd.params.len() + 1);
        // Param metadata matches declared input shapes.
        for (p, t) in fwd.params.iter().zip(&fwd.inputs) {
            assert_eq!(p.shape, t.shape, "{}", p.name);
        }
    }

    #[test]
    fn missing_model_errors() {
        let path = Path::new("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(path).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.artifact("h2_tiny", "nope").is_err());
    }
}
