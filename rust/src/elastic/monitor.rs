//! Per-stage step-time monitoring with debounced, typed events.
//!
//! The coordinator (and any outer training loop) feeds the monitor one
//! observation per (stage × DP replica) per step — the stage's *compute*
//! seconds for that step, or `None` for a missed heartbeat. The monitor
//! compares each observation against the plan's predicted per-stage
//! compute time (the same [`crate::sim::pipeline`] timing table the
//! simulator and virtual coordinator execute) and raises a typed
//! [`ElasticEvent`] once an anomaly survives a debounce window —
//! transient hiccups never trigger a re-plan.

use anyhow::Result;

use crate::plan::ExecutionPlan;

/// Monitor thresholds and debounce window.
#[derive(Clone, Copy, Debug)]
pub struct MonitorConfig {
    /// Observed/predicted compute ratio above which a step counts as
    /// straggling.
    pub straggler_factor: f64,
    /// Consecutive anomalous (or missed) steps before an event fires.
    pub debounce: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig { straggler_factor: 1.3, debounce: 2 }
    }
}

/// A debounced monitor verdict for one (stage × DP replica).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ElasticEvent {
    /// The replica missed `debounce` consecutive heartbeats: treat its
    /// chips as dead and re-plan without them.
    Dead {
        /// Pipeline stage of the failed replica.
        stage: usize,
        /// DP replica index.
        dp_rank: usize,
    },
    /// The replica ran ≥ `straggler_factor` × its predicted compute time
    /// for `debounce` consecutive steps.
    Straggler {
        /// Pipeline stage of the slow replica.
        stage: usize,
        /// DP replica index.
        dp_rank: usize,
        /// Observed/predicted ratio of the step that fired the event.
        factor: f64,
    },
    /// A previously-flagged replica ran healthily for `debounce`
    /// consecutive steps.
    Recovered {
        /// Pipeline stage of the recovered replica.
        stage: usize,
        /// DP replica index.
        dp_rank: usize,
    },
}

/// Per-replica debounce state.
#[derive(Clone, Copy, Debug, Default)]
struct ReplicaState {
    slow_streak: usize,
    miss_streak: usize,
    healthy_streak: usize,
    /// An un-recovered straggler/dead event has fired.
    flagged: bool,
}

/// The per-stage timing monitor: one [`ReplicaState`] per
/// (stage × DP replica), compared against the plan's predicted per-stage
/// compute seconds.
#[derive(Clone, Debug)]
pub struct StepMonitor {
    cfg: MonitorConfig,
    /// Predicted healthy compute seconds per stage per step.
    expected: Vec<f64>,
    dp: usize,
    states: Vec<ReplicaState>,
}

impl StepMonitor {
    /// Build a monitor from explicit per-stage predictions.
    pub fn new(expected: Vec<f64>, dp: usize, cfg: MonitorConfig) -> StepMonitor {
        let states = vec![ReplicaState::default(); expected.len() * dp];
        StepMonitor { cfg, expected, dp, states }
    }

    /// Build a monitor from a plan's own timing tables: the predicted
    /// per-stage compute seconds per step are exactly what the virtual
    /// coordinator advances its clock by on a healthy step
    /// (`b·(t_fwd + t_bwd) + t_update − t_update_comm`), so a fault
    /// factor of k shows up as an observed/predicted ratio of ≈ k.
    pub fn for_plan(plan: &ExecutionPlan) -> Result<StepMonitor> {
        let expected = predicted_stage_compute(plan)?;
        Ok(StepMonitor::new(expected, plan.strategy.s_dp, MonitorConfig::default()))
    }

    /// Same, with explicit thresholds.
    pub fn for_plan_with(plan: &ExecutionPlan, cfg: MonitorConfig) -> Result<StepMonitor> {
        let expected = predicted_stage_compute(plan)?;
        Ok(StepMonitor::new(expected, plan.strategy.s_dp, cfg))
    }

    /// Number of monitored pipeline stages.
    pub fn stages(&self) -> usize {
        self.expected.len()
    }

    /// Predicted healthy compute seconds per stage — the baseline every
    /// observation is compared against. The fleet layer uses this to
    /// synthesize observations when it projects a cluster fault onto a
    /// running job's monitor.
    pub fn expected(&self) -> &[f64] {
        &self.expected
    }

    /// Feed one observation: `seconds` is the replica's compute time for
    /// this step, `None` a missed heartbeat. Returns the debounced event
    /// this observation fires, if any.
    pub fn observe(
        &mut self,
        stage: usize,
        dp_rank: usize,
        seconds: Option<f64>,
    ) -> Option<ElasticEvent> {
        let idx = stage * self.dp + dp_rank;
        let expected = self.expected[stage];
        let st = &mut self.states[idx];
        match seconds {
            None => {
                st.miss_streak += 1;
                st.slow_streak = 0;
                st.healthy_streak = 0;
                if st.miss_streak == self.cfg.debounce {
                    st.flagged = true;
                    return Some(ElasticEvent::Dead { stage, dp_rank });
                }
            }
            Some(t) => {
                st.miss_streak = 0;
                let ratio = if expected > 0.0 { t / expected } else { 1.0 };
                if ratio >= self.cfg.straggler_factor {
                    st.slow_streak += 1;
                    st.healthy_streak = 0;
                    if st.slow_streak == self.cfg.debounce {
                        st.flagged = true;
                        return Some(ElasticEvent::Straggler { stage, dp_rank, factor: ratio });
                    }
                } else {
                    st.slow_streak = 0;
                    if st.flagged {
                        st.healthy_streak += 1;
                        if st.healthy_streak == self.cfg.debounce {
                            st.flagged = false;
                            st.healthy_streak = 0;
                            return Some(ElasticEvent::Recovered { stage, dp_rank });
                        }
                    }
                }
            }
        }
        None
    }
}

/// Predicted healthy compute seconds per stage per step, from the same
/// timing table the simulator and virtual coordinator execute.
pub fn predicted_stage_compute(plan: &ExecutionPlan) -> Result<Vec<f64>> {
    if let Err(errs) = plan.validate() {
        anyhow::bail!(
            "plan `{}` is invalid:\n{}",
            plan.name,
            crate::plan::render_errors(&errs)
        );
    }
    let groups = plan.group_refs();
    let sim_opts = plan.sim_options();
    let stages = crate::sim::pipeline::plan_stage_sims(
        &plan.model,
        &groups,
        &plan.strategy,
        plan.micro_tokens,
        &sim_opts,
    );
    let b = plan.strategy.micro_batches as f64;
    Ok(stages
        .iter()
        .map(|st| b * (st.t_fwd + st.t_bwd) + st.t_update - st.t_update_comm)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(debounce: usize) -> StepMonitor {
        StepMonitor::new(
            vec![1.0, 2.0],
            2,
            MonitorConfig { straggler_factor: 1.5, debounce },
        )
    }

    #[test]
    fn transient_hiccups_are_debounced_away() {
        let mut m = monitor(2);
        assert_eq!(m.observe(0, 0, Some(3.0)), None, "first slow step only starts a streak");
        assert_eq!(m.observe(0, 0, Some(1.0)), None, "healthy step resets it");
        assert_eq!(m.observe(0, 0, Some(3.0)), None);
        assert_eq!(m.observe(0, 0, None), None, "one miss only starts a streak");
        assert_eq!(m.observe(0, 0, Some(1.0)), None);
    }

    #[test]
    fn sustained_slowdown_fires_once_then_recovers() {
        let mut m = monitor(2);
        assert_eq!(m.observe(1, 1, Some(4.0)), None);
        let e = m.observe(1, 1, Some(4.0));
        match e {
            Some(ElasticEvent::Straggler { stage: 1, dp_rank: 1, factor }) => {
                assert!((factor - 2.0).abs() < 1e-12, "{factor}");
            }
            other => panic!("expected straggler, got {other:?}"),
        }
        // Still slow: no re-fire.
        assert_eq!(m.observe(1, 1, Some(4.0)), None);
        // Two healthy steps: recovered.
        assert_eq!(m.observe(1, 1, Some(2.0)), None);
        assert_eq!(
            m.observe(1, 1, Some(2.0)),
            Some(ElasticEvent::Recovered { stage: 1, dp_rank: 1 })
        );
        // Healthy and unflagged: silence.
        assert_eq!(m.observe(1, 1, Some(2.0)), None);
    }

    #[test]
    fn missed_heartbeats_fire_dead() {
        let mut m = monitor(3);
        assert_eq!(m.observe(0, 1, None), None);
        assert_eq!(m.observe(0, 1, None), None);
        assert_eq!(m.observe(0, 1, None), Some(ElasticEvent::Dead { stage: 0, dp_rank: 1 }));
        // Replicas are independent.
        assert_eq!(m.observe(0, 0, None), None);
    }

    #[test]
    fn event_observed_exactly_debounce_times_fires_once_and_exactly_once() {
        // Regression pin for the debounce boundary: an anomaly sustained
        // for exactly `debounce` observations fires on observation number
        // `debounce` — not `debounce - 1` (too eager: transient blips
        // would trigger re-plans), not `debounce + 1` (too lazy: the
        // config's contract is "N consecutive anomalous steps"), and never
        // a second time while the anomaly persists.
        for debounce in 1..=4 {
            // Missed heartbeats → Dead.
            let mut m = monitor(debounce);
            let mut fired_at = None;
            for obs in 1..=debounce + 3 {
                let e = m.observe(0, 0, None);
                if e.is_some() {
                    assert_eq!(e, Some(ElasticEvent::Dead { stage: 0, dp_rank: 0 }));
                    assert_eq!(fired_at, None,
                               "debounce {debounce}: re-fired at observation {obs}");
                    fired_at = Some(obs);
                }
            }
            assert_eq!(fired_at, Some(debounce), "debounce {debounce}: Dead");

            // Sustained slowdown → Straggler, same boundary.
            let mut m = monitor(debounce);
            let mut fired_at = None;
            for obs in 1..=debounce + 3 {
                let e = m.observe(0, 0, Some(3.0));
                if let Some(ev) = e {
                    assert!(matches!(ev, ElasticEvent::Straggler { stage: 0, dp_rank: 0, .. }),
                            "debounce {debounce}: {ev:?}");
                    assert_eq!(fired_at, None,
                               "debounce {debounce}: re-fired at observation {obs}");
                    fired_at = Some(obs);
                }
            }
            assert_eq!(fired_at, Some(debounce), "debounce {debounce}: Straggler");
        }
    }
}
