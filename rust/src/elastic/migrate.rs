//! Hot-swap state migration and the recovery-vs-restart timeline.
//!
//! After a re-plan, each pipeline stage's parameter/optimizer state must
//! land on the stage that owns those layers under the *new* plan. The
//! layer→stage mapping diff between the incumbent and the replanned plan
//! tells each stage exactly what to send and receive; the transfer is
//! executed over the DiComm fabric with hop latencies derived from the
//! plans' own link tables, so migration time is modeled with the same
//! machinery as everything else.
//!
//! Bit-identity: the virtual coordinator's trainable state is per-stage
//! virtual chunks keyed by *global* chunk index, and a swap-compatible
//! re-plan preserves the global chunk layout (same pipeline depth, same
//! schedule, same DP degree — see [`swap_compatible`]). Migrating a
//! checkpoint and resuming is therefore exactly restart-from-checkpoint
//! on the surviving cluster; the elastic win is *time* (a warm-cache
//! incremental re-plan plus a diff-only state transfer versus a cold
//! search plus a full-state restore), never numerics.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::comm::{fabric, LatencyFn};
use crate::coordinator::checkpoint;
use crate::coordinator::exec::{chunk_metas, stage_ckpt_path};
use crate::plan::ExecutionPlan;

/// One layer whose owning stage changes between plans.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerMove {
    /// Model layer index.
    pub layer: usize,
    /// Owning stage under the incumbent plan.
    pub from_stage: usize,
    /// Owning stage under the new plan.
    pub to_stage: usize,
    /// Parameter + optimizer state bytes to move (fp32 p, m, v).
    pub bytes: f64,
}

/// What a hot-swap migration did (or would do).
#[derive(Clone, Debug)]
pub struct MigrationReport {
    /// Layers whose owning stage changed.
    pub moves: Vec<LayerMove>,
    /// Total state bytes transferred between stages.
    pub bytes: f64,
    /// Modeled transfer seconds over the DiComm fabric (max rank clock).
    pub seconds: f64,
}

/// Check that `new` can take over `old`'s training state mid-run with a
/// bit-identical trajectory: the virtual coordinator's state layout is
/// keyed by (global chunk index, DP degree, micro-batches, schedule), so
/// all four must survive the re-plan. Layer counts, TP widths and chip
/// assignments may change freely — they move time, not numerics.
pub fn swap_compatible(old: &ExecutionPlan, new: &ExecutionPlan) -> Result<()> {
    ensure!(
        old.model == new.model,
        "hot-swap requires the same model shape (`{}` vs `{}`)",
        old.name,
        new.name
    );
    ensure!(
        old.strategy.s_dp == new.strategy.s_dp,
        "hot-swap requires the same DP degree ({} vs {})",
        old.strategy.s_dp,
        new.strategy.s_dp
    );
    ensure!(
        old.strategy.micro_batches == new.strategy.micro_batches,
        "hot-swap requires the same micro-batch count ({} vs {})",
        old.strategy.micro_batches,
        new.strategy.micro_batches
    );
    ensure!(
        old.strategy.schedule == new.strategy.schedule,
        "hot-swap requires the same pipeline schedule ({} vs {})",
        old.strategy.schedule,
        new.strategy.schedule
    );
    let (old_pp, new_pp) = (total_stages(old), total_stages(new));
    ensure!(
        old_pp == new_pp,
        "hot-swap requires the same pipeline depth ({old_pp} vs {new_pp} stages)"
    );
    Ok(())
}

/// Total pipeline stages of a plan (Σ per-group `s_pp`).
pub fn total_stages(plan: &ExecutionPlan) -> usize {
    plan.strategy.plans.iter().map(|p| p.s_pp).sum()
}

/// Owning stage per model layer, in layer order (stages are groups in
/// order, `s_pp` stages within each, layers contiguous).
fn layer_stage_map(plan: &ExecutionPlan) -> Vec<usize> {
    let mut map = Vec::with_capacity(plan.model.n_layers);
    let mut stage = 0usize;
    for gp in &plan.strategy.plans {
        let lps = gp.layers / gp.s_pp;
        for _ in 0..gp.s_pp {
            map.extend(std::iter::repeat(stage).take(lps));
            stage += 1;
        }
    }
    map
}

/// Per-stage fp32 parameter+optimizer state bytes per layer (p, m, v =
/// 12 bytes/param; the timing table carries bf16 gradient bytes, 2/param).
fn state_bytes_per_layer(plan: &ExecutionPlan) -> Vec<f64> {
    let groups = plan.group_refs();
    let sim_opts = plan.sim_options();
    let stages = crate::sim::pipeline::plan_stage_sims(
        &plan.model,
        &groups,
        &plan.strategy,
        plan.micro_tokens,
        &sim_opts,
    );
    stages.iter().map(|st| st.grad_bytes_per_layer * 6.0).collect()
}

/// Per-hop seconds-per-byte out of each stage, from the plan's own link
/// table (the table prices one activation hop of known size).
fn per_byte_hops(plan: &ExecutionPlan) -> Vec<f64> {
    let groups = plan.group_refs();
    let sim_opts = plan.sim_options();
    let stages = crate::sim::pipeline::plan_stage_sims(
        &plan.model,
        &groups,
        &plan.strategy,
        plan.micro_tokens,
        &sim_opts,
    );
    let (links, wrap) =
        crate::sim::pipeline::stage_links(&stages, &groups, &plan.model, plan.micro_tokens,
                                          &sim_opts);
    let act_bytes = (plan.micro_tokens * plan.model.hidden * 2) as f64;
    let mut per_byte: Vec<f64> = links.iter().map(|l| l / act_bytes).collect();
    if let Some(last) = per_byte.last_mut() {
        *last = wrap / act_bytes;
    }
    per_byte
}

/// The layer→stage mapping diff between two swap-compatible plans: every
/// layer whose owning stage changes, with its state bytes (priced at the
/// source stage's sharding).
pub fn migration_moves(old: &ExecutionPlan, new: &ExecutionPlan) -> Result<Vec<LayerMove>> {
    swap_compatible(old, new)?;
    let from = layer_stage_map(old);
    let to = layer_stage_map(new);
    ensure!(
        from.len() == to.len() && from.len() == old.model.n_layers,
        "layer maps must cover the model ({} vs {} vs {} layers)",
        from.len(),
        to.len(),
        old.model.n_layers
    );
    let bytes = state_bytes_per_layer(old);
    Ok(from
        .iter()
        .zip(&to)
        .enumerate()
        .filter(|(_, (f, t))| f != t)
        .map(|(layer, (&f, &t))| LayerMove {
            layer,
            from_stage: f,
            to_stage: t,
            bytes: bytes[f],
        })
        .collect())
}

/// Execute the migration's sends/receives over a DiComm fabric — one
/// endpoint per stage, hop latency per transfer derived from the old
/// plan's link table — and return the modeled transfer time (the slowest
/// rank's clock).
fn execute_moves(old: &ExecutionPlan, moves: &[LayerMove]) -> Result<f64> {
    if moves.is_empty() {
        return Ok(0.0);
    }
    let per_byte = per_byte_hops(old);
    let s_n = per_byte.len();
    let zero: LatencyFn = Arc::new(|_, _, _| 0.0);
    let mut endpoints = fabric(s_n, zero);
    // All sends first (non-blocking), then the receives: the fabric's
    // arrival rule (arrive = depart + latency, receiver clock = max)
    // models every stage shipping its outgoing layers concurrently.
    for (i, mv) in moves.iter().enumerate() {
        let (lo, hi) = (mv.from_stage.min(mv.to_stage), mv.from_stage.max(mv.to_stage));
        let latency: f64 = (lo..hi).map(|h| mv.bytes * per_byte[h]).sum();
        endpoints[mv.from_stage].send_with_latency(mv.to_stage, i as u64, Vec::new(), latency)?;
    }
    for (i, mv) in moves.iter().enumerate() {
        endpoints[mv.to_stage].recv(mv.from_stage, i as u64)?;
    }
    Ok(endpoints
        .iter()
        .map(|ep| ep.now())
        .fold(0.0f64, f64::max))
}

/// Migrate a `train_virtual` checkpoint from `old`'s stage layout into
/// `new`'s at `new_dir`, and model the hot-swap transfer time from the
/// layer→stage diff. The plans must be [`swap_compatible`]; the global
/// virtual-chunk layout is then preserved, so the migrated checkpoint
/// resumes bit-identically to restart-from-checkpoint on the surviving
/// cluster.
pub fn migrate_state(
    old: &ExecutionPlan,
    new: &ExecutionPlan,
    old_dir: &Path,
    new_dir: &Path,
) -> Result<MigrationReport> {
    let moves = migration_moves(old, new)?;
    let s_n = total_stages(old);
    let v = old.strategy.schedule.virtual_stages();
    let metas = chunk_metas(v);
    std::fs::create_dir_all(new_dir)?;
    let mut step = None;
    for stage in 0..s_n {
        let state = checkpoint::load(stage_ckpt_path(old_dir, stage), &metas)?;
        match step {
            None => step = Some(state.step),
            Some(s) => ensure!(
                s == state.step,
                "stage {stage} checkpoint is at step {}, stage 0 at {s}",
                state.step
            ),
        }
        checkpoint::save(stage_ckpt_path(new_dir, stage), &metas, &state)?;
    }
    if step.is_none() {
        bail!("plan `{}` has no pipeline stages to migrate", old.name);
    }
    let seconds = execute_moves(old, &moves)?;
    let bytes = moves.iter().map(|m| m.bytes).sum();
    Ok(MigrationReport { moves, bytes, seconds })
}

/// Modeled seconds for a cold restart to restore *every* stage's full
/// parameter/optimizer state (all stages restore concurrently; per-byte
/// cost as the interconnect's, a deliberately generous assumption in the
/// restart baseline's favor).
pub fn restore_seconds(plan: &ExecutionPlan) -> f64 {
    let per_byte = per_byte_hops(plan);
    let bytes = state_bytes_per_layer(plan);
    let map = layer_stage_map(plan);
    let s_n = per_byte.len();
    (0..s_n)
        .map(|s| {
            let layers = map.iter().filter(|&&m| m == s).count() as f64;
            layers * bytes[s] * per_byte[s.min(per_byte.len() - 1)]
        })
        .fold(0.0f64, f64::max)
}

/// The recovery-vs-restart comparison for one kill-a-chip scenario, per
/// evaluator: feed it the evaluator's step seconds plus the measured
/// re-plan and cold-search times, read back both totals. Detection
/// (the debounce window) is paid on both sides, so it cancels out of the
/// margin.
#[derive(Clone, Copy, Debug)]
pub struct RecoveryTimeline {
    /// Seconds to drain in-flight micro-batches (one step boundary).
    pub drain_seconds: f64,
    /// Seconds for the debounced detection window.
    pub detect_seconds: f64,
    /// Measured incremental re-plan wall-clock.
    pub replan_seconds: f64,
    /// Modeled diff-only state migration over the fabric.
    pub migrate_seconds: f64,
    /// Measured cold two-stage search wall-clock (restart path).
    pub search_seconds: f64,
    /// Modeled full-state restore from the checkpoint (restart path).
    pub restore_seconds: f64,
}

impl RecoveryTimeline {
    /// Assemble a timeline: `step_seconds` is one evaluator's per-step
    /// time of the *incumbent* plan, `debounce` the monitor's window.
    pub fn new(
        old: &ExecutionPlan,
        new: &ExecutionPlan,
        step_seconds: f64,
        debounce: usize,
        replan_seconds: f64,
        search_seconds: f64,
    ) -> Result<RecoveryTimeline> {
        let moves = migration_moves(old, new)?;
        let migrate_seconds = execute_moves(old, &moves)?;
        Ok(RecoveryTimeline {
            drain_seconds: step_seconds,
            detect_seconds: debounce as f64 * step_seconds,
            replan_seconds,
            migrate_seconds,
            search_seconds,
            restore_seconds: restore_seconds(new),
        })
    }

    /// Elastic path: drain + detect + warm re-plan + diff migration.
    pub fn recovery_seconds(&self) -> f64 {
        self.drain_seconds + self.detect_seconds + self.replan_seconds + self.migrate_seconds
    }

    /// Restart path: drain + detect + cold search + full-state restore.
    pub fn restart_seconds(&self) -> f64 {
        self.drain_seconds + self.detect_seconds + self.search_seconds + self.restore_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommAlgo;
    use crate::costmodel::{GroupPlan, ModelShape, Schedule, Strategy};
    use crate::hetero::{ChipKind, Cluster};
    use crate::plan::PlanBuilder;

    fn plan(layers_a: usize, layers_b: usize, tp_b: usize, chips_b: usize) -> ExecutionPlan {
        let model = ModelShape {
            n_layers: 8,
            hidden: 2048,
            n_heads: 16,
            n_kv_heads: 16,
            intermediate: 8192,
            vocab: 32000,
            seq_len: 4096,
            n_experts: 0,
            top_k: 0,
            expert_intermediate: 0,
        };
        let cluster = Cluster::new(
            "mig-2stage",
            vec![(ChipKind::A, 16), (ChipKind::B, chips_b)],
        );
        PlanBuilder::new("mig")
            .model(model)
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 8,
                schedule: Schedule::OneF1B,
                comm_algo: CommAlgo::Ring,
                plans: vec![
                    GroupPlan { s_pp: 1, s_tp: 4, layers: layers_a, recompute: false },
                    GroupPlan { s_pp: 1, s_tp: tp_b, layers: layers_b, recompute: true },
                ],
            })
            .gbs_tokens(4 * 8 * 4096)
            .build()
            .unwrap()
    }

    #[test]
    fn identical_plans_have_no_moves() {
        let p = plan(4, 4, 4, 16);
        let moves = migration_moves(&p, &p).unwrap();
        assert!(moves.is_empty(), "{moves:?}");
        assert_eq!(execute_moves(&p, &moves).unwrap(), 0.0);
    }

    #[test]
    fn resharded_layers_move_with_positive_modeled_time() {
        // The re-plan shifts two layers from stage 1 (B, halved) onto
        // stage 0 (A): exactly layers 4 and 5 change owner.
        let old = plan(4, 4, 4, 16);
        let new = plan(6, 2, 2, 8);
        swap_compatible(&old, &new).unwrap();
        let moves = migration_moves(&old, &new).unwrap();
        assert_eq!(
            moves.iter().map(|m| (m.layer, m.from_stage, m.to_stage)).collect::<Vec<_>>(),
            vec![(4, 1, 0), (5, 1, 0)]
        );
        assert!(moves.iter().all(|m| m.bytes > 0.0));
        let seconds = execute_moves(&old, &moves).unwrap();
        assert!(seconds > 0.0 && seconds.is_finite());
        // A diff-only migration beats a full restore.
        assert!(seconds < restore_seconds(&new), "{seconds} vs {}", restore_seconds(&new));
    }

    #[test]
    fn incompatible_plans_are_rejected() {
        let old = plan(4, 4, 4, 16);
        let mut new = plan(6, 2, 2, 8);
        new.strategy.s_dp = 2;
        new.strategy.micro_batches = 16;
        assert!(swap_compatible(&old, &new).is_err());
    }
}
