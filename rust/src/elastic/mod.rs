//! Elastic training: survive chip loss and stragglers without losing the
//! run.
//!
//! Production hyper-heterogeneous clusters lose nodes and degrade NICs
//! mid-run as a matter of course; H2's answer is a closed loop over the
//! existing evaluators rather than a separate system:
//!
//! ```text
//!   FaultPlan ──► train_virtual / simulator (deterministic replay)
//!                      │ per-stage compute seconds
//!                      ▼
//!   StepMonitor ──► ElasticEvent (dead / straggler / recovered)
//!                      │ debounced
//!                      ▼
//!   auto::replan ──► v4 plan (plan_epoch + 1, dead chips excluded)
//!                      │ seeded B&B + warm ProfileCache
//!                      ▼
//!   migrate_state ──► hot-swap resume (bit-identical to
//!                      restart-from-checkpoint on the survivors)
//! ```
//!
//! * [`fault`] — deterministic, seedable fault injection shared by the
//!   simulator and the virtual coordinator, so a kill-chip-at-step-N
//!   scenario replays identically across evaluators.
//! * [`monitor`] — per-(stage × DP replica) step-time drift detection
//!   against the plan's predicted `StageSim` times, with debounce.
//! * [`migrate`] — the layer→stage mapping diff, the DiComm-modeled
//!   state transfer, checkpoint migration, and the recovery-vs-restart
//!   timeline.
//!
//! Re-planning itself lives in [`crate::auto::replan`], next to the
//! search it reuses.

pub mod fault;
pub mod migrate;
pub mod monitor;

pub use fault::{FaultEvent, FaultKind, FaultPlan};
pub use migrate::{
    migrate_state, migration_moves, restore_seconds, swap_compatible, total_stages, LayerMove,
    MigrationReport, RecoveryTimeline,
};
pub use monitor::{predicted_stage_compute, ElasticEvent, MonitorConfig, StepMonitor};
