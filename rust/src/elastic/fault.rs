//! Deterministic, seedable fault injection.
//!
//! A [`FaultPlan`] is a replayable script of hardware misbehavior — chip
//! death, persistent compute slowdown, NIC degradation, recovery — keyed
//! by training step and pipeline stage. Both the discrete-event simulator
//! (`sim::simulate_plan_under_faults`) and the coordinator's virtual
//! evaluator (`coordinator::train_virtual`) consume the *same* plan, so a
//! kill-chip-at-step-N scenario replays identically across evaluators.
//!
//! Faults scale *time*, never numerics: a slowed or NIC-degraded stage
//! computes exactly what a healthy one computes, only later — which is
//! what keeps the elastic hot-swap loss trajectory bit-comparable to an
//! uninterrupted run. A [`FaultKind::ChipDeath`] is the one exception:
//! the dead stage cannot execute at all, so the run drains at the step
//! boundary before the death and hands off to the elastic loop
//! (detect → replan → migrate, see [`crate::elastic`]).

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// What goes wrong (or right again) at one [`FaultEvent`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Whole nodes of the stage's chip group die permanently. The run
    /// drains at the step boundary *before* `step`; the elastic loop
    /// excludes the dead chips and re-plans.
    ChipDeath {
        /// Number of whole nodes lost (chips = nodes × chips-per-node).
        nodes: usize,
    },
    /// Persistent compute slowdown: the stage's forward/backward/update
    /// compute takes `factor` × its healthy time until a
    /// [`FaultKind::Recover`] event on the same stage.
    Slowdown {
        /// Multiplier on the stage's compute time (≥ 1 slows it down).
        factor: f64,
    },
    /// NIC degradation: the stage's P2P hops and exposed DP-sync slice
    /// take `factor` × their healthy time until recovery.
    NicDegrade {
        /// Multiplier on the stage's communication time (≥ 1 degrades).
        factor: f64,
    },
    /// The stage returns to healthy timing (clears any active slowdown
    /// and NIC degradation).
    Recover,
}

impl FaultKind {
    /// Stable serialization token, shared by per-job [`FaultPlan`] JSON and
    /// the fleet-level `ClusterFaultPlan` JSON (`crate::fleet`).
    pub fn token(&self) -> &'static str {
        match self {
            FaultKind::ChipDeath { .. } => "chip-death",
            FaultKind::Slowdown { .. } => "slowdown",
            FaultKind::NicDegrade { .. } => "nic-degrade",
            FaultKind::Recover => "recover",
        }
    }

    /// Push the kind's payload fields (`nodes` / `factor`) onto a JSON
    /// object under construction — the inverse of [`FaultKind::from_json`].
    pub fn push_json_fields(&self, fields: &mut Vec<(&'static str, Value)>) {
        match *self {
            FaultKind::ChipDeath { nodes } => fields.push(("nodes", json::num(nodes as f64))),
            FaultKind::Slowdown { factor } | FaultKind::NicDegrade { factor } => {
                fields.push(("factor", json::num(factor)));
            }
            FaultKind::Recover => {}
        }
    }

    /// Parse a kind from an event object carrying a `kind` token plus the
    /// payload fields written by [`FaultKind::push_json_fields`].
    pub fn from_json(e: &Value) -> Result<FaultKind> {
        Ok(match e.get("kind")?.str()? {
            "chip-death" => FaultKind::ChipDeath { nodes: e.get("nodes")?.usize()? },
            "slowdown" => FaultKind::Slowdown { factor: e.get("factor")?.num()? },
            "nic-degrade" => FaultKind::NicDegrade { factor: e.get("factor")?.num()? },
            "recover" => FaultKind::Recover,
            other => bail!("unknown fault kind `{other}`"),
        })
    }

    /// Structural validation shared by both fault-plan layers: factors must
    /// be positive finite, a death must kill at least one node.
    pub fn validate(&self) -> Result<()> {
        match *self {
            FaultKind::Slowdown { factor } | FaultKind::NicDegrade { factor } => {
                if !factor.is_finite() || factor <= 0.0 {
                    bail!("fault factor {factor} is not positive finite");
                }
            }
            FaultKind::ChipDeath { nodes } => {
                if nodes == 0 {
                    bail!("chip-death event kills zero nodes");
                }
            }
            FaultKind::Recover => {}
        }
        Ok(())
    }
}

/// One scheduled fault: `kind` hits pipeline stage `stage` at the start
/// of training step `step`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Training step the event fires at (start-of-step semantics).
    pub step: usize,
    /// Global pipeline stage index the event hits.
    pub stage: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic, seedable, serializable fault script.
///
/// The `seed` records how a generated plan was derived (and salts
/// [`FaultPlan::generate`]); hand-written plans may use any value. Events
/// are applied in list order, so the plan is replayable byte-for-byte —
/// it round-trips through JSON losslessly and can travel inside an
/// [`crate::plan::ExecutionPlan`] (format v4) or a standalone
/// `--faults` file.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed the plan was generated from (informational for hand-written
    /// plans).
    pub seed: u64,
    /// The fault script, applied in order.
    pub events: Vec<FaultEvent>,
}

/// Per-stage multiplicative timing state at one step, folded from every
/// event at or before it: `(compute factor, nic factor)`.
pub type FaultFactors = (f64, f64);

impl FaultPlan {
    /// A plan with no events (healthy cluster).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Generate a small random fault script: a few slowdown / NIC /
    /// recover events over `steps` steps and `stages` stages, plus — when
    /// `with_death` — one chip-death event in the back half of the run.
    /// Deterministic in `seed`.
    pub fn generate(seed: u64, steps: usize, stages: usize, with_death: bool) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_017_FA_017);
        let mut events = Vec::new();
        let n = rng.usize(1, 4);
        for _ in 0..n {
            let step = rng.usize(1, steps.max(2));
            let stage = rng.usize(0, stages.saturating_sub(1));
            let kind = match rng.usize(0, 2) {
                0 => FaultKind::Slowdown { factor: 1.0 + rng.usize(5, 30) as f64 / 10.0 },
                1 => FaultKind::NicDegrade { factor: 1.0 + rng.usize(5, 30) as f64 / 10.0 },
                _ => FaultKind::Recover,
            };
            events.push(FaultEvent { step, stage, kind });
        }
        if with_death {
            let step = (steps / 2).max(1) + rng.usize(0, steps.saturating_sub(steps / 2 + 1));
            let stage = rng.usize(0, stages.saturating_sub(1));
            events.push(FaultEvent { step, stage, kind: FaultKind::ChipDeath { nodes: 1 } });
        }
        events.sort_by_key(|e| (e.step, e.stage));
        FaultPlan { seed, events }
    }

    /// The effective `(compute, nic)` time multipliers for `stage` at
    /// `step`: every event at or before `step` on that stage is folded in
    /// list order (later events override earlier ones of the same class;
    /// recover resets both to 1.0). Chip death carries no factor — it
    /// halts the run instead (see [`FaultPlan::first_death`]).
    pub fn factors_at(&self, step: usize, stage: usize) -> FaultFactors {
        let (mut compute, mut nic) = (1.0f64, 1.0f64);
        for e in &self.events {
            if e.step > step || e.stage != stage {
                continue;
            }
            match e.kind {
                FaultKind::Slowdown { factor } => compute = factor,
                FaultKind::NicDegrade { factor } => nic = factor,
                FaultKind::Recover => {
                    compute = 1.0;
                    nic = 1.0;
                }
                FaultKind::ChipDeath { .. } => {}
            }
        }
        (compute, nic)
    }

    /// The earliest chip-death event, if any — the step the run must
    /// drain at (start-of-step semantics: steps `0..step` complete).
    pub fn first_death(&self) -> Option<&FaultEvent> {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::ChipDeath { .. }))
            .min_by_key(|e| e.step)
    }

    /// Structural validation against a pipeline of `s_n` stages.
    pub fn validate(&self, s_n: usize) -> Result<()> {
        for e in &self.events {
            if e.stage >= s_n {
                bail!("fault event at step {} targets stage {} of a {s_n}-stage pipeline",
                      e.step, e.stage);
            }
            e.kind
                .validate()
                .map_err(|err| anyhow!("{err} (event at step {})", e.step))?;
        }
        Ok(())
    }

    /// Serialize (seeds travel as decimal strings, like plan train seeds,
    /// so full-range u64 values survive the f64 JSON number space).
    pub fn to_json(&self) -> Value {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("step", json::num(e.step as f64)),
                    ("stage", json::num(e.stage as f64)),
                    ("kind", json::s(e.kind.token())),
                ];
                e.kind.push_json_fields(&mut fields);
                json::obj(fields)
            })
            .collect();
        json::obj(vec![
            ("seed", json::s(&self.seed.to_string())),
            ("events", json::arr(events)),
        ])
    }

    /// Parse a serialized fault plan.
    pub fn from_json(v: &Value) -> Result<FaultPlan> {
        let seed = match v.get("seed")? {
            Value::Str(s) => s.parse::<u64>().map_err(|e| anyhow!("bad fault seed `{s}`: {e}"))?,
            other => other.u64()?,
        };
        let mut events = Vec::new();
        for e in v.get("events")?.arr()? {
            events.push(FaultEvent {
                step: e.get("step")?.usize()?,
                stage: e.get("stage")?.usize()?,
                kind: FaultKind::from_json(e)?,
            });
        }
        Ok(FaultPlan { seed, events })
    }

    /// Load a fault plan from a JSON file (the CLI `--faults` path).
    pub fn load(path: &str) -> Result<FaultPlan> {
        FaultPlan::from_json(&Value::from_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample() -> FaultPlan {
        FaultPlan {
            seed: u64::MAX - 1, // exercises the decimal-string seed path
            events: vec![
                FaultEvent { step: 2, stage: 0, kind: FaultKind::Slowdown { factor: 1.5 } },
                FaultEvent { step: 3, stage: 1, kind: FaultKind::NicDegrade { factor: 2.0 } },
                FaultEvent { step: 4, stage: 0, kind: FaultKind::Recover },
                FaultEvent { step: 5, stage: 1, kind: FaultKind::ChipDeath { nodes: 1 } },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let plan = sample();
        let back = FaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back);
        // And through text, the way a --faults file travels.
        let text = plan.to_json().to_string_pretty();
        let back = FaultPlan::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn factors_fold_in_order_and_recover_resets() {
        let plan = sample();
        assert_eq!(plan.factors_at(1, 0), (1.0, 1.0));
        assert_eq!(plan.factors_at(2, 0), (1.5, 1.0));
        assert_eq!(plan.factors_at(3, 0), (1.5, 1.0));
        assert_eq!(plan.factors_at(3, 1), (1.0, 2.0));
        assert_eq!(plan.factors_at(4, 0), (1.0, 1.0), "recover clears the slowdown");
        // Death carries no factor.
        assert_eq!(plan.factors_at(9, 1), (1.0, 2.0));
    }

    #[test]
    fn first_death_finds_the_earliest() {
        assert_eq!(sample().first_death().unwrap().step, 5);
        assert!(FaultPlan::none().first_death().is_none());
    }

    #[test]
    fn validation_rejects_bad_events() {
        let plan = sample();
        assert!(plan.validate(2).is_ok());
        assert!(plan.validate(1).is_err(), "stage 1 out of a 1-stage pipeline");
        let bad = FaultPlan {
            seed: 0,
            events: vec![FaultEvent {
                step: 0,
                stage: 0,
                kind: FaultKind::Slowdown { factor: 0.0 },
            }],
        };
        assert!(bad.validate(1).is_err());
    }

    #[test]
    fn generated_plans_are_deterministic_valid_and_roundtrip() {
        prop::check(100, |rng| {
            let seed = rng.next_u64();
            let steps = rng.usize(2, 20);
            let stages = rng.usize(1, 8);
            let with_death = rng.usize(0, 1) == 1;
            let a = FaultPlan::generate(seed, steps, stages, with_death);
            let b = FaultPlan::generate(seed, steps, stages, with_death);
            prop::assert_prop(a == b, "generation must be deterministic in the seed")?;
            prop::assert_prop(a.validate(stages).is_ok(), format!("invalid: {a:?}"))?;
            prop::assert_prop(
                with_death == a.first_death().is_some(),
                "death present iff requested",
            )?;
            let back = FaultPlan::from_json(&a.to_json())
                .map_err(|e| format!("reparse failed: {e}"))?;
            prop::assert_prop(a == back, "JSON round-trip must be lossless")
        });
    }
}
