//! Cluster specifications: groups of homogeneous nodes per chip type, plus
//! the paper's experiment configurations (Table 7).

use anyhow::{bail, Result};

use super::chip::{spec, ChipKind, ChipSpec};

/// One homogeneous group inside a hyper-heterogeneous cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct ChipGroup {
    /// Chip architecture shared by every chip in the group.
    pub spec: ChipSpec,
    /// Total chips in the group (a whole number of nodes).
    pub n_chips: usize,
}

impl ChipGroup {
    /// Infallible constructor for known-good literals; panics on partial nodes.
    pub fn new(kind: ChipKind, n_chips: usize) -> Self {
        ChipGroup::try_new(kind, n_chips).unwrap()
    }

    /// Fallible constructor for data-driven paths (config / plan files).
    pub fn try_new(kind: ChipKind, n_chips: usize) -> Result<Self> {
        let spec = spec(kind);
        if n_chips == 0 {
            bail!("{kind}: a chip group needs at least one node");
        }
        if n_chips % spec.chips_per_node != 0 {
            bail!("{kind}: {n_chips} chips is not a whole number of {}-chip nodes",
                  spec.chips_per_node);
        }
        Ok(ChipGroup { spec, n_chips })
    }

    /// Servers in the group.
    pub fn n_nodes(&self) -> usize {
        self.n_chips / self.spec.chips_per_node
    }
}

/// A hyper-heterogeneous cluster: one group per chip type.
#[derive(Clone, Debug, PartialEq)]
pub struct Cluster {
    /// Cluster name (shows up in CLI output and plan files).
    pub name: String,
    /// One homogeneous group per chip type.
    pub groups: Vec<ChipGroup>,
}

impl Cluster {
    /// Infallible constructor for known-good literals; panics on partial nodes.
    pub fn new(name: &str, groups: Vec<(ChipKind, usize)>) -> Self {
        Cluster::try_build(name, groups).unwrap()
    }

    /// Fallible constructor for data-driven paths (config / plan files).
    pub fn try_build(name: &str, groups: Vec<(ChipKind, usize)>) -> Result<Self> {
        let groups = groups
            .into_iter()
            .map(|(k, n)| ChipGroup::try_new(k, n))
            .collect::<Result<Vec<_>>>()?;
        Ok(Cluster { name: name.to_string(), groups })
    }

    /// Total accelerators across every group.
    pub fn total_chips(&self) -> usize {
        self.groups.iter().map(|g| g.n_chips).sum()
    }

    /// Number of distinct chip groups.
    pub fn n_types(&self) -> usize {
        self.groups.len()
    }

    /// The group of a given chip kind, or an error naming the cluster.
    pub fn group(&self, kind: ChipKind) -> Result<&ChipGroup> {
        match self.groups.iter().find(|g| g.spec.kind == kind) {
            Some(g) => Ok(g),
            None => bail!("cluster `{}` has no {kind} group", self.name),
        }
    }

    /// Groups sorted by descending memory capacity — HeteroPP's stage
    /// ordering rule (Observation #4: big-memory chips take early stages).
    pub fn groups_by_memory_desc(&self) -> Vec<&ChipGroup> {
        let mut gs: Vec<&ChipGroup> = self.groups.iter().collect();
        gs.sort_by(|a, b| {
            b.spec.memory_gib.partial_cmp(&a.spec.memory_gib).unwrap()
                .then(b.spec.fp16_tflops.partial_cmp(&a.spec.fp16_tflops).unwrap())
        });
        gs
    }
}

/// Table 7 experiment configurations (+ global batch sizes in tokens).
#[derive(Clone, Debug)]
pub struct Experiment {
    /// Experiment identifier (`exp-a-1` .. `exp-d`).
    pub index: &'static str,
    /// The Table 7 cluster composition.
    pub cluster: Cluster,
    /// Global batch size in tokens.
    pub gbs_tokens: usize,
}

/// Look up an experiment by its index string: the Table 7 configurations
/// (`exp-a-1` .. `exp-d`) plus two beyond-Table-7 fixtures — `exp-mega`,
/// the paper-scale scenario backing the §4.3.3 headline claim (1,280
/// chips across all four vendors), and `exp-moe`, a 128-chip two-vendor
/// cluster sized for [`crate::costmodel::H2_MOE`]: at EP 1 every chip
/// carries the full 32-expert bank, which overflows the memory budget and
/// forces PCIe optimizer offload on every layout, so the expert-parallel
/// axis (sharding the bank across DP replicas) has decisive headroom.
pub fn experiment(index: &str) -> Result<Experiment> {
    let m = 1024 * 1024;
    let (cluster, gbs) = match index {
        "exp-a-1" => (Cluster::new("Exp-A", vec![(ChipKind::A, 256), (ChipKind::B, 256), (ChipKind::C, 256)]), 2 * m),
        "exp-a-2" => (Cluster::new("Exp-A", vec![(ChipKind::A, 256), (ChipKind::B, 256), (ChipKind::C, 256)]), 6 * m),
        "exp-b-1" => (Cluster::new("Exp-B", vec![(ChipKind::A, 256), (ChipKind::B, 256), (ChipKind::C, 256), (ChipKind::D, 256)]), 2 * m),
        "exp-b-2" => (Cluster::new("Exp-B", vec![(ChipKind::A, 256), (ChipKind::B, 256), (ChipKind::C, 256), (ChipKind::D, 256)]), 8 * m),
        "exp-c-1" => (Cluster::new("Exp-C", vec![(ChipKind::A, 384), (ChipKind::B, 1024)]), 4 * m),
        "exp-c-2" => (Cluster::new("Exp-C", vec![(ChipKind::A, 384), (ChipKind::B, 1024)]), 8 * m),
        "exp-d" => (Cluster::new("Exp-D", vec![(ChipKind::A, 384), (ChipKind::B, 2048)]), 8 * m),
        "exp-mega" | "mega" => (
            Cluster::new(
                "Exp-Mega",
                vec![(ChipKind::A, 256), (ChipKind::B, 512), (ChipKind::C, 256),
                     (ChipKind::D, 256)],
            ),
            4 * m,
        ),
        "exp-moe" | "moe" => (
            Cluster::new("Exp-MoE", vec![(ChipKind::A, 64), (ChipKind::B, 64)]),
            m,
        ),
        _ => bail!("unknown experiment `{index}` (expected exp-a-1 .. exp-d, \
                    exp-mega, or exp-moe)"),
    };
    Ok(Experiment { index: Box::leak(index.to_string().into_boxed_str()), cluster, gbs_tokens: gbs })
}

/// Every Table 7 experiment index, in paper order (`exp-mega` and
/// `exp-moe` are beyond-Table-7 fixtures and deliberately not listed —
/// the paper reports no baseline numbers for them).
pub const ALL_EXPERIMENTS: [&str; 7] =
    ["exp-a-1", "exp-a-2", "exp-b-1", "exp-b-2", "exp-c-1", "exp-c-2", "exp-d"];

/// The Table 6 homogeneous baselines: 256 chips of one type, GBS = 2M tokens.
pub fn homogeneous_baseline(kind: ChipKind) -> Experiment {
    Experiment {
        index: "table6",
        cluster: Cluster::new(&format!("Homog-{kind}"), vec![(kind, 256)]),
        gbs_tokens: 2 * 1024 * 1024,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_chip_counts() {
        assert_eq!(experiment("exp-a-1").unwrap().cluster.total_chips(), 768);
        assert_eq!(experiment("exp-b-1").unwrap().cluster.total_chips(), 1024);
        assert_eq!(experiment("exp-c-1").unwrap().cluster.total_chips(), 1408);
        assert_eq!(experiment("exp-d").unwrap().cluster.total_chips(), 2432);
    }

    #[test]
    fn exp_b_is_the_1024_chip_4_type_run() {
        let e = experiment("exp-b-1").unwrap();
        assert_eq!(e.cluster.n_types(), 4);
        assert_eq!(e.cluster.total_chips(), 1024);
    }

    #[test]
    fn memory_ordering_puts_a_first() {
        let e = experiment("exp-b-1").unwrap();
        let order: Vec<ChipKind> = e.cluster.groups_by_memory_desc()
            .iter().map(|g| g.spec.kind).collect();
        assert_eq!(order[0], ChipKind::A); // 96 GB
        assert_eq!(order[1], ChipKind::B); // 64 GB
    }

    #[test]
    fn mega_fixture_is_paper_scale() {
        // The §4.3.3 headline scenario: over 1,000 chips, all four vendors,
        // every group a whole number of nodes and big enough that the
        // 128-chip two-stage split fragments it.
        let e = experiment("exp-mega").unwrap();
        assert_eq!(e.cluster.total_chips(), 1280);
        assert!(e.cluster.total_chips() > 1000);
        assert_eq!(e.cluster.n_types(), 4);
        for g in &e.cluster.groups {
            assert_eq!(g.n_chips % g.spec.chips_per_node, 0, "{}", g.spec.kind);
            assert!(g.n_chips > 128, "{} should split in stage 2", g.spec.kind);
        }
        // The short alias resolves to the same fixture.
        assert_eq!(experiment("mega").unwrap().cluster.total_chips(), 1280);
        // Not a Table 7 row: the paper-table drivers must not pick it up.
        assert!(!ALL_EXPERIMENTS.contains(&"exp-mega"));
    }

    #[test]
    fn whole_nodes_enforced() {
        let result = std::panic::catch_unwind(|| ChipGroup::new(ChipKind::A, 100));
        assert!(result.is_err()); // 100 % 16 != 0
    }

    #[test]
    fn exp_moe_is_the_128_chip_two_vendor_moe_fixture() {
        let e = experiment("exp-moe").unwrap();
        assert_eq!(e.cluster.total_chips(), 128);
        assert_eq!(e.cluster.n_types(), 2);
        assert_eq!(e.gbs_tokens, 1024 * 1024);
        // The short alias resolves to the same fixture.
        assert_eq!(experiment("moe").unwrap().cluster.total_chips(), 128);
        // Not a Table 7 row: the paper-table drivers must not pick it up.
        assert!(!ALL_EXPERIMENTS.contains(&"exp-moe"));
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(experiment("exp-z").is_err());
    }
}
