//! Hyper-heterogeneous cluster modeling: the chip catalog (Table 5) and
//! cluster/experiment definitions (Table 7).

pub mod chip;
pub mod cluster;

pub use chip::{spec, ChipKind, ChipSpec, IntraNodeLink};
pub use cluster::{experiment, homogeneous_baseline, ChipGroup, Cluster, Experiment, ALL_EXPERIMENTS};
