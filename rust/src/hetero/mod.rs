//! Hyper-heterogeneous cluster modeling: the chip catalog (Table 5) and
//! cluster/experiment definitions (Table 7).

pub mod chip;
pub mod cluster;

pub use chip::{
    custom_def, def_from_spec, register_custom, spec, ChipKind, ChipSpec, CustomChipDef,
    IntraNodeLink,
};
pub use cluster::{experiment, homogeneous_baseline, ChipGroup, Cluster, Experiment, ALL_EXPERIMENTS};
