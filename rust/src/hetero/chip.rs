//! Chip catalog: the paper's four AI-chip architectures plus the A100
//! reference (Table 5, Figure 1, §2.3).
//!
//! The paper anonymizes vendors and gives capability *bands* relative to the
//! A100 (312 TFLOPS FP16). Concrete values inside those bands were chosen
//! once, documented here, and calibrated so the homogeneous-baseline cost
//! model lands near Table 6's measured TGS (see EXPERIMENTS.md):
//!
//! | Chip | band (×A100)   | chosen FP16 | memory | chips/node |
//! |------|----------------|-------------|--------|------------|
//! | A    | 0.5–1.0        | 182 TFLOPS  | 96 GB  | 16         |  (§2.3 quotes 182)
//! | B    | 0.5–1.0        | 256 TFLOPS  | 64 GB  | 8          |
//! | C    | 0.0–0.5        | 128 TFLOPS  | 32 GB  | 16         |
//! | D    | 1.5–2.0        | 550 TFLOPS  | 32 GB  | 8          |

use std::fmt;
use std::sync::{OnceLock, RwLock};

use anyhow::{bail, Result};

/// Identity of a chip architecture in the hyper-heterogeneous cluster.
///
/// The four paper chips plus the A100 reference are built in; `Custom`
/// kinds are declared at runtime through [`register_custom`] (typically
/// from a config file's `chips` section), so new heterogeneous-cluster
/// scenarios need no recompilation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ChipKind {
    /// Paper Chip-A: large memory (96 GiB), mid compute, 16-chip nodes.
    A,
    /// Paper Chip-B: 64 GiB, mid compute, NUMA-split 8-chip nodes.
    B,
    /// Paper Chip-C: small memory (32 GiB), low compute, PCIe-switch nodes.
    C,
    /// Paper Chip-D: fastest compute, small memory (32 GiB).
    D,
    /// NVIDIA A100 — the homogeneous reference used for precision alignment.
    A100,
    /// A user-declared chip; the index points into the process-wide registry.
    Custom(u16),
}

impl ChipKind {
    /// The four anonymized paper chips (A100 excluded).
    pub const ALL: [ChipKind; 4] = [ChipKind::A, ChipKind::B, ChipKind::C, ChipKind::D];

    /// Canonical display/parse name (`Chip-A`, `A100`, or the custom name).
    pub fn name(self) -> &'static str {
        match self {
            ChipKind::A => "Chip-A",
            ChipKind::B => "Chip-B",
            ChipKind::C => "Chip-C",
            ChipKind::D => "Chip-D",
            ChipKind::A100 => "A100",
            ChipKind::Custom(i) => {
                let reg = registry().read().unwrap();
                reg.get(i as usize).map(|e| e.name).unwrap_or("Custom-?")
            }
        }
    }

    /// Parse a chip name, case-insensitively; customs resolve via the registry.
    pub fn parse(s: &str) -> Option<ChipKind> {
        match s.to_ascii_uppercase().as_str() {
            "A" | "CHIP-A" => Some(ChipKind::A),
            "B" | "CHIP-B" => Some(ChipKind::B),
            "C" | "CHIP-C" => Some(ChipKind::C),
            "D" | "CHIP-D" => Some(ChipKind::D),
            "A100" => Some(ChipKind::A100),
            _ => {
                let reg = registry().read().unwrap();
                reg.iter()
                    .position(|e| e.name.eq_ignore_ascii_case(s))
                    .map(|i| ChipKind::Custom(i as u16))
            }
        }
    }

    /// Stable integer distinguishing kinds — used for RNG seeding
    /// (`ChipKind` carries data, so it cannot be cast with `as`).
    ///
    /// Custom kinds hash their *name* rather than their registry index, so
    /// perturbation streams are reproducible across processes regardless of
    /// the order chips were declared in.
    pub fn seed_tag(self) -> u64 {
        match self {
            ChipKind::A => 0,
            ChipKind::B => 1,
            ChipKind::C => 2,
            ChipKind::D => 3,
            ChipKind::A100 => 4,
            ChipKind::Custom(_) => {
                // FNV-1a over the lower-cased name (parse is case-insensitive).
                let h = crate::util::hash::fnv1a(
                    self.name().bytes().map(|b| b.to_ascii_lowercase()),
                );
                // Setting a high bit keeps custom tags clear of the
                // built-in 0..=4 range (and avoids overflow).
                h | (1 << 32)
            }
        }
    }

    /// Whether this kind lives in the runtime registry rather than the catalog.
    pub fn is_custom(self) -> bool {
        matches!(self, ChipKind::Custom(_))
    }
}

impl fmt::Display for ChipKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Intra-node interconnect classes observed across vendors (§2.3, Fig 3):
/// some nodes have uniform high-speed links, some degrade across NUMA
/// domains or PCIe switches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum IntraNodeLink {
    /// NVLink-class uniform all-to-all (bandwidth GB/s).
    Uniform { gbps: f64 },
    /// Full bandwidth inside a NUMA island, degraded across (Fig 3 "B"-like).
    NumaSplit { local_gbps: f64, cross_gbps: f64, island: usize },
    /// PCIe-switch hierarchy: full inside a switch group, degraded across.
    PcieSwitch { local_gbps: f64, cross_gbps: f64, group: usize },
}

impl IntraNodeLink {
    /// Point-to-point bandwidth between two chip slots in the same node.
    pub fn bandwidth_gbps(&self, a: usize, b: usize) -> f64 {
        match *self {
            IntraNodeLink::Uniform { gbps } => gbps,
            IntraNodeLink::NumaSplit { local_gbps, cross_gbps, island } => {
                if a / island == b / island { local_gbps } else { cross_gbps }
            }
            IntraNodeLink::PcieSwitch { local_gbps, cross_gbps, group } => {
                if a / group == b / group { local_gbps } else { cross_gbps }
            }
        }
    }

    /// Largest chip group with full-bandwidth all-to-all — the paper's
    /// `TP_MAX` constraint source (§4.3.2 requirement 2).
    pub fn uniform_island(&self, chips_per_node: usize) -> usize {
        match *self {
            IntraNodeLink::Uniform { .. } => chips_per_node,
            IntraNodeLink::NumaSplit { island, .. } => island,
            IntraNodeLink::PcieSwitch { group, .. } => group,
        }
    }
}

/// Full specification of one chip architecture + its server design.
#[derive(Clone, Debug, PartialEq)]
pub struct ChipSpec {
    /// Which chip architecture this spec describes.
    pub kind: ChipKind,
    /// Peak FP16 throughput, TFLOPS.
    pub fp16_tflops: f64,
    /// Device memory, GiB.
    pub memory_gib: f64,
    /// Accelerators per server.
    pub chips_per_node: usize,
    /// Intra-node interconnect class and bandwidths.
    pub intra_node: IntraNodeLink,
    /// NICs per server and per-NIC bandwidth (RoCE-v2), GB/s.
    pub nics_per_node: usize,
    /// Per-NIC bandwidth, GB/s.
    pub nic_gbps: f64,
    /// Sustained fraction of peak for dense transformer layers (calibrated
    /// against Table 6; stands in for the paper's auto-profiler measurements).
    pub mfu: f64,
    /// Numerical perturbation scale of this vendor's operator stack relative
    /// to the A100 (drives the Fig 5 / Table 1 precision study).
    pub op_noise: f64,
    /// PCIe-path bandwidth from a chip to its *affine* NIC, GB/s
    /// (chip-specific: vendors wire x8/x16 Gen4 differently; Table 3 model).
    pub pcie_to_nic_gbps: f64,
    /// Share of the affine-path bandwidth left when a flow must cross the
    /// inter-switch uplink (calibrated to Table 3's non-affinity rows).
    pub cross_switch_share: f64,
}

impl ChipSpec {
    /// Effective sustained TFLOPS for dense compute.
    pub fn sustained_tflops(&self) -> f64 {
        self.fp16_tflops * self.mfu
    }

    /// `TP_MAX` for this server design (§4.3.2 requirement 2): the largest
    /// power of two whose TP group stays inside a uniform-bandwidth island.
    pub fn tp_max(&self) -> usize {
        let island = self.intra_node.uniform_island(self.chips_per_node);
        let mut tp = 1;
        while tp * 2 <= island {
            tp *= 2;
        }
        tp
    }

    /// Device memory in bytes.
    pub fn memory_bytes(&self) -> f64 {
        self.memory_gib * 1024.0 * 1024.0 * 1024.0
    }
}

/// A user-declared chip architecture: everything [`ChipSpec`] carries plus
/// the NIC-path constants the topology model needs. Declared in config JSON
/// (`"chips": [...]`) and registered with [`register_custom`].
#[derive(Clone, Debug, PartialEq)]
pub struct CustomChipDef {
    /// Unique chip name (rejects built-in names).
    pub name: String,
    /// Peak FP16 throughput, TFLOPS.
    pub fp16_tflops: f64,
    /// Device memory, GiB.
    pub memory_gib: f64,
    /// Accelerators per server.
    pub chips_per_node: usize,
    /// Intra-node interconnect class and bandwidths.
    pub intra_node: IntraNodeLink,
    /// NICs per server.
    pub nics_per_node: usize,
    /// Per-NIC bandwidth, GB/s.
    pub nic_gbps: f64,
    /// Sustained fraction of peak for dense transformer layers.
    pub mfu: f64,
    /// Numerical perturbation scale of the vendor operator stack.
    pub op_noise: f64,
    /// PCIe-path bandwidth from a chip to its affine NIC, GB/s (Table 3 model).
    pub pcie_to_nic_gbps: f64,
    /// Bandwidth share left when a flow crosses the inter-switch uplink.
    pub cross_switch_share: f64,
}

impl CustomChipDef {
    /// A mid-range starting point (A100-class server, modest fabric);
    /// callers override the fields they care about.
    pub fn new(name: &str) -> CustomChipDef {
        CustomChipDef {
            name: name.to_string(),
            fp16_tflops: 200.0,
            memory_gib: 64.0,
            chips_per_node: 8,
            intra_node: IntraNodeLink::Uniform { gbps: 200.0 },
            nics_per_node: 8,
            nic_gbps: 25.0,
            mfu: 0.45,
            op_noise: 0.005,
            pcie_to_nic_gbps: 12.0,
            cross_switch_share: 0.55,
        }
    }
}

struct RegistryEntry {
    name: &'static str,
    spec: ChipSpec,
}

fn registry() -> &'static RwLock<Vec<RegistryEntry>> {
    static REGISTRY: OnceLock<RwLock<Vec<RegistryEntry>>> = OnceLock::new();
    REGISTRY.get_or_init(|| RwLock::new(Vec::new()))
}

const BUILTIN_NAMES: [&str; 10] = [
    "A", "B", "C", "D", "A100", "CHIP-A", "CHIP-B", "CHIP-C", "CHIP-D", "Custom-?",
];

/// Register (or update) a user-declared chip and return its kind.
///
/// Re-registering an existing name updates the stored definition in place
/// and returns the same `ChipKind`, so reloading a config or a plan file is
/// idempotent. Names shadowing the built-in catalog are rejected.
pub fn register_custom(def: &CustomChipDef) -> Result<ChipKind> {
    if def.name.is_empty() {
        bail!("custom chip needs a non-empty name");
    }
    if BUILTIN_NAMES.iter().any(|b| b.eq_ignore_ascii_case(&def.name)) {
        bail!("custom chip name `{}` shadows a built-in chip", def.name);
    }
    if def.chips_per_node == 0 || def.nics_per_node == 0 {
        bail!("custom chip `{}`: chips_per_node and nics_per_node must be > 0", def.name);
    }
    let rates_ok = [def.fp16_tflops, def.memory_gib, def.mfu, def.nic_gbps]
        .into_iter()
        .all(|x| x > 0.0);
    if !rates_ok {
        bail!("custom chip `{}`: tflops/memory/mfu/nic_gbps must be > 0", def.name);
    }
    let nic_path_ok = def.pcie_to_nic_gbps > 0.0
        && def.cross_switch_share > 0.0
        && def.cross_switch_share <= 1.0;
    if !nic_path_ok {
        bail!("custom chip `{}`: pcie_to_nic_gbps must be > 0 and \
               cross_switch_share in (0, 1]", def.name);
    }
    let mut reg = registry().write().unwrap();
    if let Some(i) = reg.iter().position(|e| e.name.eq_ignore_ascii_case(&def.name)) {
        let kind = ChipKind::Custom(i as u16);
        reg[i].spec = spec_from_def(kind, def);
        return Ok(kind);
    }
    if reg.len() >= u16::MAX as usize {
        bail!("custom chip registry full");
    }
    let kind = ChipKind::Custom(reg.len() as u16);
    reg.push(RegistryEntry {
        name: Box::leak(def.name.clone().into_boxed_str()),
        spec: spec_from_def(kind, def),
    });
    Ok(kind)
}

fn spec_from_def(kind: ChipKind, def: &CustomChipDef) -> ChipSpec {
    ChipSpec {
        kind,
        fp16_tflops: def.fp16_tflops,
        memory_gib: def.memory_gib,
        chips_per_node: def.chips_per_node,
        intra_node: def.intra_node,
        nics_per_node: def.nics_per_node,
        nic_gbps: def.nic_gbps,
        mfu: def.mfu,
        op_noise: def.op_noise,
        pcie_to_nic_gbps: def.pcie_to_nic_gbps,
        cross_switch_share: def.cross_switch_share,
    }
}

/// Rebuild the declaration from a (possibly snapshotted) spec — the inverse
/// of [`spec_from_def`], used to embed self-contained chip definitions in
/// plan files without consulting the live registry's current state.
pub fn def_from_spec(name: &str, spec: &ChipSpec) -> CustomChipDef {
    CustomChipDef {
        name: name.to_string(),
        fp16_tflops: spec.fp16_tflops,
        memory_gib: spec.memory_gib,
        chips_per_node: spec.chips_per_node,
        intra_node: spec.intra_node,
        nics_per_node: spec.nics_per_node,
        nic_gbps: spec.nic_gbps,
        mfu: spec.mfu,
        op_noise: spec.op_noise,
        pcie_to_nic_gbps: spec.pcie_to_nic_gbps,
        cross_switch_share: spec.cross_switch_share,
    }
}

/// The full definition of a custom kind (None for built-ins / stale indices).
pub fn custom_def(kind: ChipKind) -> Option<CustomChipDef> {
    match kind {
        ChipKind::Custom(i) => registry()
            .read()
            .unwrap()
            .get(i as usize)
            .map(|e| def_from_spec(e.name, &e.spec)),
        _ => None,
    }
}

/// The catalog (Table 5 bands; see module docs for the chosen points).
/// Custom kinds resolve through the registry.
///
/// Panics on a `Custom` kind that was never registered in this process —
/// plans and configs always register their chips before building kinds, so
/// that indicates a caller bug.
pub fn spec(kind: ChipKind) -> ChipSpec {
    if let ChipKind::Custom(i) = kind {
        let reg = registry().read().unwrap();
        return reg
            .get(i as usize)
            .unwrap_or_else(|| panic!("unregistered custom chip index {i}"))
            .spec
            .clone();
    }
    match kind {
        ChipKind::A => ChipSpec {
            kind,
            fp16_tflops: 182.0,
            memory_gib: 96.0,
            chips_per_node: 16,
            intra_node: IntraNodeLink::Uniform { gbps: 200.0 },
            nics_per_node: 8,
            nic_gbps: 25.0, // 200 Gbps RoCE
            mfu: 0.573,
            op_noise: 0.0049,
            pcie_to_nic_gbps: 11.95,
            cross_switch_share: 0.576,
        },
        ChipKind::B => ChipSpec {
            kind,
            fp16_tflops: 256.0,
            memory_gib: 64.0,
            chips_per_node: 8,
            intra_node: IntraNodeLink::NumaSplit { local_gbps: 160.0, cross_gbps: 56.0, island: 4 },
            nics_per_node: 4,
            nic_gbps: 25.0,
            mfu: 0.570,
            op_noise: 0.0060,
            pcie_to_nic_gbps: 12.39,
            cross_switch_share: 0.528,
        },
        ChipKind::C => ChipSpec {
            kind,
            fp16_tflops: 128.0,
            memory_gib: 32.0,
            chips_per_node: 16,
            intra_node: IntraNodeLink::PcieSwitch { local_gbps: 64.0, cross_gbps: 24.0, group: 4 },
            nics_per_node: 2,
            nic_gbps: 12.5, // 100 Gbps
            mfu: 0.367,
            op_noise: 0.0064,
            pcie_to_nic_gbps: 8.2,
            cross_switch_share: 0.50,
        },
        ChipKind::D => ChipSpec {
            kind,
            fp16_tflops: 550.0,
            memory_gib: 32.0,
            chips_per_node: 8,
            intra_node: IntraNodeLink::Uniform { gbps: 180.0 },
            nics_per_node: 8,
            nic_gbps: 25.0,
            mfu: 0.30,
            op_noise: 0.0152,
            pcie_to_nic_gbps: 12.39,
            cross_switch_share: 0.55,
        },
        ChipKind::A100 => ChipSpec {
            kind,
            fp16_tflops: 312.0,
            memory_gib: 80.0,
            chips_per_node: 8,
            intra_node: IntraNodeLink::Uniform { gbps: 600.0 },
            nics_per_node: 8,
            nic_gbps: 25.0,
            mfu: 0.50,
            op_noise: 0.0,
            pcie_to_nic_gbps: 12.8,
            cross_switch_share: 0.90, // NVSwitch-class fabrics degrade least
        },
        ChipKind::Custom(_) => unreachable!("handled above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_bands_hold() {
        let a100 = spec(ChipKind::A100).fp16_tflops;
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let c = spec(ChipKind::C);
        let d = spec(ChipKind::D);
        assert!(a.fp16_tflops > 0.5 * a100 && a.fp16_tflops < 1.0 * a100);
        assert!(b.fp16_tflops > 0.5 * a100 && b.fp16_tflops < 1.0 * a100);
        assert!(c.fp16_tflops > 0.0 && c.fp16_tflops < 0.5 * a100);
        assert!(d.fp16_tflops > 1.5 * a100 && d.fp16_tflops < 2.0 * a100);
        assert_eq!((a.memory_gib, b.memory_gib, c.memory_gib, d.memory_gib),
                   (96.0, 64.0, 32.0, 32.0));
        assert_eq!((a.chips_per_node, b.chips_per_node, c.chips_per_node, d.chips_per_node),
                   (16, 8, 16, 8));
    }

    #[test]
    fn hyper_heterogeneity_no_total_order() {
        // Figure 1's point: no chip dominates on all three axes.
        let d = spec(ChipKind::D);
        let a = spec(ChipKind::A);
        assert!(d.fp16_tflops > a.fp16_tflops); // D wins compute
        assert!(a.memory_gib > d.memory_gib);   // A wins memory
    }

    #[test]
    fn tp_max_respects_islands() {
        assert_eq!(spec(ChipKind::A).tp_max(), 16);
        assert_eq!(spec(ChipKind::B).tp_max(), 4);  // NUMA island of 4
        assert_eq!(spec(ChipKind::C).tp_max(), 4);  // PCIe group of 4
        assert_eq!(spec(ChipKind::D).tp_max(), 8);
    }

    #[test]
    fn numa_split_bandwidth() {
        let link = IntraNodeLink::NumaSplit { local_gbps: 160.0, cross_gbps: 56.0, island: 4 };
        assert_eq!(link.bandwidth_gbps(0, 3), 160.0);
        assert_eq!(link.bandwidth_gbps(0, 4), 56.0);
        assert_eq!(link.bandwidth_gbps(5, 7), 160.0);
    }

    #[test]
    fn parse_roundtrip() {
        for k in ChipKind::ALL {
            assert_eq!(ChipKind::parse(k.name()), Some(k));
        }
        assert_eq!(ChipKind::parse("a100"), Some(ChipKind::A100));
        assert_eq!(ChipKind::parse("z"), None);
    }

    #[test]
    fn custom_chip_registers_and_resolves() {
        let mut def = CustomChipDef::new("UnitTest-H9");
        def.fp16_tflops = 400.0;
        def.memory_gib = 48.0;
        let kind = register_custom(&def).unwrap();
        assert!(kind.is_custom());
        assert_eq!(kind.name(), "UnitTest-H9");
        assert_eq!(ChipKind::parse("unittest-h9"), Some(kind));
        let s = spec(kind);
        assert_eq!(s.kind, kind);
        assert_eq!(s.fp16_tflops, 400.0);
        assert_eq!(s.memory_gib, 48.0);
        // Re-registration with new numbers updates in place, same kind.
        def.fp16_tflops = 410.0;
        assert_eq!(register_custom(&def).unwrap(), kind);
        assert_eq!(spec(kind).fp16_tflops, 410.0);
        assert_eq!(custom_def(kind).unwrap().fp16_tflops, 410.0);
    }

    #[test]
    fn custom_chip_rejects_builtin_names() {
        assert!(register_custom(&CustomChipDef::new("A")).is_err());
        assert!(register_custom(&CustomChipDef::new("chip-c")).is_err());
        assert!(register_custom(&CustomChipDef::new("a100")).is_err());
        assert!(register_custom(&CustomChipDef::new("")).is_err());
        let mut bad = CustomChipDef::new("UnitTest-BadChip");
        bad.mfu = 0.0;
        assert!(register_custom(&bad).is_err());
        let mut bad = CustomChipDef::new("UnitTest-BadNic");
        bad.pcie_to_nic_gbps = 0.0;
        assert!(register_custom(&bad).is_err());
        let mut bad = CustomChipDef::new("UnitTest-BadShare");
        bad.cross_switch_share = 1.5;
        assert!(register_custom(&bad).is_err());
    }

    #[test]
    fn seed_tags_are_distinct() {
        let kind = register_custom(&CustomChipDef::new("UnitTest-SeedTag")).unwrap();
        let mut tags: Vec<u64> = ChipKind::ALL.iter().map(|k| k.seed_tag()).collect();
        tags.push(ChipKind::A100.seed_tag());
        tags.push(kind.seed_tag());
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), 6);
    }
}
