//! Chip catalog: the paper's four AI-chip architectures plus the A100
//! reference (Table 5, Figure 1, §2.3).
//!
//! The paper anonymizes vendors and gives capability *bands* relative to the
//! A100 (312 TFLOPS FP16). Concrete values inside those bands were chosen
//! once, documented here, and calibrated so the homogeneous-baseline cost
//! model lands near Table 6's measured TGS (see EXPERIMENTS.md):
//!
//! | Chip | band (×A100)   | chosen FP16 | memory | chips/node |
//! |------|----------------|-------------|--------|------------|
//! | A    | 0.5–1.0        | 182 TFLOPS  | 96 GB  | 16         |  (§2.3 quotes 182)
//! | B    | 0.5–1.0        | 256 TFLOPS  | 64 GB  | 8          |
//! | C    | 0.0–0.5        | 128 TFLOPS  | 32 GB  | 16         |
//! | D    | 1.5–2.0        | 550 TFLOPS  | 32 GB  | 8          |

use std::fmt;

/// Identity of a chip architecture in the hyper-heterogeneous cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ChipKind {
    A,
    B,
    C,
    D,
    /// NVIDIA A100 — the homogeneous reference used for precision alignment.
    A100,
}

impl ChipKind {
    pub const ALL: [ChipKind; 4] = [ChipKind::A, ChipKind::B, ChipKind::C, ChipKind::D];

    pub fn name(self) -> &'static str {
        match self {
            ChipKind::A => "Chip-A",
            ChipKind::B => "Chip-B",
            ChipKind::C => "Chip-C",
            ChipKind::D => "Chip-D",
            ChipKind::A100 => "A100",
        }
    }

    pub fn parse(s: &str) -> Option<ChipKind> {
        match s.to_ascii_uppercase().as_str() {
            "A" | "CHIP-A" => Some(ChipKind::A),
            "B" | "CHIP-B" => Some(ChipKind::B),
            "C" | "CHIP-C" => Some(ChipKind::C),
            "D" | "CHIP-D" => Some(ChipKind::D),
            "A100" => Some(ChipKind::A100),
            _ => None,
        }
    }
}

impl fmt::Display for ChipKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Intra-node interconnect classes observed across vendors (§2.3, Fig 3):
/// some nodes have uniform high-speed links, some degrade across NUMA
/// domains or PCIe switches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum IntraNodeLink {
    /// NVLink-class uniform all-to-all (bandwidth GB/s).
    Uniform { gbps: f64 },
    /// Full bandwidth inside a NUMA island, degraded across (Fig 3 "B"-like).
    NumaSplit { local_gbps: f64, cross_gbps: f64, island: usize },
    /// PCIe-switch hierarchy: full inside a switch group, degraded across.
    PcieSwitch { local_gbps: f64, cross_gbps: f64, group: usize },
}

impl IntraNodeLink {
    /// Point-to-point bandwidth between two chip slots in the same node.
    pub fn bandwidth_gbps(&self, a: usize, b: usize) -> f64 {
        match *self {
            IntraNodeLink::Uniform { gbps } => gbps,
            IntraNodeLink::NumaSplit { local_gbps, cross_gbps, island } => {
                if a / island == b / island { local_gbps } else { cross_gbps }
            }
            IntraNodeLink::PcieSwitch { local_gbps, cross_gbps, group } => {
                if a / group == b / group { local_gbps } else { cross_gbps }
            }
        }
    }

    /// Largest chip group with full-bandwidth all-to-all — the paper's
    /// `TP_MAX` constraint source (§4.3.2 requirement 2).
    pub fn uniform_island(&self, chips_per_node: usize) -> usize {
        match *self {
            IntraNodeLink::Uniform { .. } => chips_per_node,
            IntraNodeLink::NumaSplit { island, .. } => island,
            IntraNodeLink::PcieSwitch { group, .. } => group,
        }
    }
}

/// Full specification of one chip architecture + its server design.
#[derive(Clone, Debug)]
pub struct ChipSpec {
    pub kind: ChipKind,
    /// Peak FP16 throughput, TFLOPS.
    pub fp16_tflops: f64,
    /// Device memory, GiB.
    pub memory_gib: f64,
    pub chips_per_node: usize,
    pub intra_node: IntraNodeLink,
    /// NICs per server and per-NIC bandwidth (RoCE-v2), GB/s.
    pub nics_per_node: usize,
    pub nic_gbps: f64,
    /// Sustained fraction of peak for dense transformer layers (calibrated
    /// against Table 6; stands in for the paper's auto-profiler measurements).
    pub mfu: f64,
    /// Numerical perturbation scale of this vendor's operator stack relative
    /// to the A100 (drives the Fig 5 / Table 1 precision study).
    pub op_noise: f64,
}

impl ChipSpec {
    /// Effective sustained TFLOPS for dense compute.
    pub fn sustained_tflops(&self) -> f64 {
        self.fp16_tflops * self.mfu
    }

    /// `TP_MAX` for this server design (§4.3.2 requirement 2): the largest
    /// power of two whose TP group stays inside a uniform-bandwidth island.
    pub fn tp_max(&self) -> usize {
        let island = self.intra_node.uniform_island(self.chips_per_node);
        let mut tp = 1;
        while tp * 2 <= island {
            tp *= 2;
        }
        tp
    }

    pub fn memory_bytes(&self) -> f64 {
        self.memory_gib * 1024.0 * 1024.0 * 1024.0
    }
}

/// The catalog (Table 5 bands; see module docs for the chosen points).
pub fn spec(kind: ChipKind) -> ChipSpec {
    match kind {
        ChipKind::A => ChipSpec {
            kind,
            fp16_tflops: 182.0,
            memory_gib: 96.0,
            chips_per_node: 16,
            intra_node: IntraNodeLink::Uniform { gbps: 200.0 },
            nics_per_node: 8,
            nic_gbps: 25.0, // 200 Gbps RoCE
            mfu: 0.573,
            op_noise: 0.0049,
        },
        ChipKind::B => ChipSpec {
            kind,
            fp16_tflops: 256.0,
            memory_gib: 64.0,
            chips_per_node: 8,
            intra_node: IntraNodeLink::NumaSplit { local_gbps: 160.0, cross_gbps: 56.0, island: 4 },
            nics_per_node: 4,
            nic_gbps: 25.0,
            mfu: 0.570,
            op_noise: 0.0060,
        },
        ChipKind::C => ChipSpec {
            kind,
            fp16_tflops: 128.0,
            memory_gib: 32.0,
            chips_per_node: 16,
            intra_node: IntraNodeLink::PcieSwitch { local_gbps: 64.0, cross_gbps: 24.0, group: 4 },
            nics_per_node: 2,
            nic_gbps: 12.5, // 100 Gbps
            mfu: 0.367,
            op_noise: 0.0064,
        },
        ChipKind::D => ChipSpec {
            kind,
            fp16_tflops: 550.0,
            memory_gib: 32.0,
            chips_per_node: 8,
            intra_node: IntraNodeLink::Uniform { gbps: 180.0 },
            nics_per_node: 8,
            nic_gbps: 25.0,
            mfu: 0.30,
            op_noise: 0.0152,
        },
        ChipKind::A100 => ChipSpec {
            kind,
            fp16_tflops: 312.0,
            memory_gib: 80.0,
            chips_per_node: 8,
            intra_node: IntraNodeLink::Uniform { gbps: 600.0 },
            nics_per_node: 8,
            nic_gbps: 25.0,
            mfu: 0.50,
            op_noise: 0.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_bands_hold() {
        let a100 = spec(ChipKind::A100).fp16_tflops;
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let c = spec(ChipKind::C);
        let d = spec(ChipKind::D);
        assert!(a.fp16_tflops > 0.5 * a100 && a.fp16_tflops < 1.0 * a100);
        assert!(b.fp16_tflops > 0.5 * a100 && b.fp16_tflops < 1.0 * a100);
        assert!(c.fp16_tflops > 0.0 && c.fp16_tflops < 0.5 * a100);
        assert!(d.fp16_tflops > 1.5 * a100 && d.fp16_tflops < 2.0 * a100);
        assert_eq!((a.memory_gib, b.memory_gib, c.memory_gib, d.memory_gib),
                   (96.0, 64.0, 32.0, 32.0));
        assert_eq!((a.chips_per_node, b.chips_per_node, c.chips_per_node, d.chips_per_node),
                   (16, 8, 16, 8));
    }

    #[test]
    fn hyper_heterogeneity_no_total_order() {
        // Figure 1's point: no chip dominates on all three axes.
        let d = spec(ChipKind::D);
        let a = spec(ChipKind::A);
        assert!(d.fp16_tflops > a.fp16_tflops); // D wins compute
        assert!(a.memory_gib > d.memory_gib);   // A wins memory
    }

    #[test]
    fn tp_max_respects_islands() {
        assert_eq!(spec(ChipKind::A).tp_max(), 16);
        assert_eq!(spec(ChipKind::B).tp_max(), 4);  // NUMA island of 4
        assert_eq!(spec(ChipKind::C).tp_max(), 4);  // PCIe group of 4
        assert_eq!(spec(ChipKind::D).tp_max(), 8);
    }

    #[test]
    fn numa_split_bandwidth() {
        let link = IntraNodeLink::NumaSplit { local_gbps: 160.0, cross_gbps: 56.0, island: 4 };
        assert_eq!(link.bandwidth_gbps(0, 3), 160.0);
        assert_eq!(link.bandwidth_gbps(0, 4), 56.0);
        assert_eq!(link.bandwidth_gbps(5, 7), 160.0);
    }

    #[test]
    fn parse_roundtrip() {
        for k in ChipKind::ALL {
            assert_eq!(ChipKind::parse(k.name()), Some(k));
        }
        assert_eq!(ChipKind::parse("a100"), Some(ChipKind::A100));
        assert_eq!(ChipKind::parse("z"), None);
    }
}
