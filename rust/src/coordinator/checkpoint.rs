//! Checkpointing: save/restore stage parameters and optimizer state.
//!
//! Binary format (little-endian), one file per pipeline stage:
//!
//! ```text
//! magic "H2CKPT01" | step u64 | n_tensors u64 |
//!   per tensor: name_len u64, name bytes, rank u64, dims u64..., f32 data
//! ```
//!
//! Params, Adam m and Adam v are stored as three named sections
//! (`p.<name>`, `m.<name>`, `v.<name>`), so a checkpoint restores training
//! exactly (bitwise) on the same artifact set.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{HostTensor, ParamMeta};

const MAGIC: &[u8; 8] = b"H2CKPT01";

/// A stage's full training state.
#[derive(Clone, Debug, PartialEq)]
pub struct StageState {
    /// Training step the state was captured at.
    pub step: u64,
    /// Model parameters.
    pub params: Vec<HostTensor>,
    /// Adam first-moment state.
    pub m: Vec<HostTensor>,
    /// Adam second-moment state.
    pub v: Vec<HostTensor>,
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_tensor(w: &mut impl Write, name: &str, t: &HostTensor) -> Result<()> {
    write_u64(w, name.len() as u64)?;
    w.write_all(name.as_bytes())?;
    write_u64(w, t.shape().len() as u64)?;
    for &d in t.shape() {
        write_u64(w, d as u64)?;
    }
    let data = t.as_f32()?;
    // Safe little-endian serialization.
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)?;
    Ok(())
}

fn read_tensor(r: &mut impl Read) -> Result<(String, HostTensor)> {
    let name_len = read_u64(r)? as usize;
    if name_len > 4096 {
        bail!("corrupt checkpoint: name length {name_len}");
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("tensor name not utf-8")?;
    let rank = read_u64(r)? as usize;
    if rank > 8 {
        bail!("corrupt checkpoint: rank {rank}");
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u64(r)? as usize);
    }
    let n: usize = shape.iter().product();
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((name, HostTensor::F32 { shape, data }))
}

/// Save one stage's state.
pub fn save(path: impl AsRef<Path>, metas: &[ParamMeta], state: &StageState) -> Result<()> {
    let mut w = std::io::BufWriter::new(
        std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?,
    );
    w.write_all(MAGIC)?;
    write_u64(&mut w, state.step)?;
    write_u64(&mut w, 3 * metas.len() as u64)?;
    for (section, tensors) in [("p", &state.params), ("m", &state.m), ("v", &state.v)] {
        anyhow::ensure!(tensors.len() == metas.len(), "tensor/meta arity mismatch");
        for (meta, t) in metas.iter().zip(tensors.iter()) {
            write_tensor(&mut w, &format!("{section}.{}", meta.name), t)?;
        }
    }
    Ok(())
}

/// Load one stage's state, validating against the artifact's param layout.
pub fn load(path: impl AsRef<Path>, metas: &[ParamMeta]) -> Result<StageState> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an H2 checkpoint (bad magic)");
    }
    let step = read_u64(&mut r)?;
    let n = read_u64(&mut r)? as usize;
    if n != 3 * metas.len() {
        bail!("checkpoint has {n} tensors, artifact expects {}", 3 * metas.len());
    }
    let mut sections: Vec<Vec<HostTensor>> = vec![Vec::new(), Vec::new(), Vec::new()];
    for (si, section) in ["p", "m", "v"].iter().enumerate() {
        for meta in metas {
            let (name, t) = read_tensor(&mut r)?;
            let expect = format!("{section}.{}", meta.name);
            if name != expect {
                bail!("checkpoint tensor `{name}` where `{expect}` expected");
            }
            if t.shape() != meta.shape.as_slice() {
                bail!("`{name}` shape {:?} != artifact {:?}", t.shape(), meta.shape);
            }
            sections[si].push(t);
        }
    }
    let v = sections.pop().unwrap();
    let m = sections.pop().unwrap();
    let params = sections.pop().unwrap();
    Ok(StageState { step, params, m, v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::params::{init_params, zeros_like};

    fn metas() -> Vec<ParamMeta> {
        vec![
            ParamMeta { name: "embed".into(), shape: vec![16, 8] },
            ParamMeta { name: "layer0.wq".into(), shape: vec![8, 8] },
        ]
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("h2_ckpt_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn roundtrip_is_bitwise_exact() {
        let metas = metas();
        let state = StageState {
            step: 42,
            params: init_params(&metas, 7),
            m: init_params(&metas, 8),
            v: zeros_like(&metas),
        };
        let p = tmp("roundtrip.ckpt");
        save(&p, &metas, &state).unwrap();
        let loaded = load(&p, &metas).unwrap();
        assert_eq!(loaded, state);
    }

    #[test]
    fn wrong_layout_rejected() {
        let metas = metas();
        let state = StageState {
            step: 1,
            params: init_params(&metas, 1),
            m: zeros_like(&metas),
            v: zeros_like(&metas),
        };
        let p = tmp("layout.ckpt");
        save(&p, &metas, &state).unwrap();
        // Loading against a different layout must fail loudly.
        let other = vec![ParamMeta { name: "embed".into(), shape: vec![16, 8] },
                         ParamMeta { name: "layer0.wk".into(), shape: vec![8, 8] }];
        assert!(load(&p, &other).is_err());
        let fewer = &metas[..1];
        assert!(load(&p, fewer).is_err());
    }

    #[test]
    fn corrupt_magic_rejected() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        let err = load(&p, &metas()).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn truncated_file_rejected() {
        let metas = metas();
        let state = StageState {
            step: 3,
            params: init_params(&metas, 2),
            m: zeros_like(&metas),
            v: zeros_like(&metas),
        };
        let p = tmp("trunc.ckpt");
        save(&p, &metas, &state).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p, &metas).is_err());
    }
}
