//! Checkpointing: save/restore stage parameters and optimizer state.
//!
//! Binary format (little-endian), one file per pipeline stage:
//!
//! ```text
//! magic "H2CKPT02" | step u64 | n_tensors u64 |
//!   per tensor: name_len u64, name bytes, rank u64, dims u64..., f32 data
//! | fnv1a u64 over everything after the magic
//! ```
//!
//! Params, Adam m and Adam v are stored as three named sections
//! (`p.<name>`, `m.<name>`, `v.<name>`), so a checkpoint restores training
//! exactly (bitwise) on the same artifact set.
//!
//! The trailing checksum (the crate-wide [`fnv1a`]) makes payload
//! corruption — a flipped bit on disk, a torn write — a typed
//! [`CheckpointError::ChecksumMismatch`] instead of a garbage restore or
//! an incidental parse error. V1 checkpoints (`H2CKPT01`, no trailer)
//! still load unchanged; everything saves as v2. The resume path treats
//! a checksum failure like a missing file and falls back to the previous
//! generation retained by `keep_last` (see
//! [`crate::coordinator::train_virtual`]).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{HostTensor, ParamMeta};
use crate::util::hash::fnv1a;

const MAGIC_V1: &[u8; 8] = b"H2CKPT01";
const MAGIC: &[u8; 8] = b"H2CKPT02";

/// A typed checkpoint-integrity failure, downcastable from the anyhow
/// error chain so callers can tell corruption apart from layout
/// mismatches or I/O errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckpointError {
    /// The v2 trailer checksum did not match the payload: the file was
    /// corrupted after it was written.
    ChecksumMismatch {
        /// Checksum stored in the file's trailer.
        stored: u64,
        /// Checksum recomputed over the payload.
        computed: u64,
    },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::ChecksumMismatch { stored, computed } => write!(
                f,
                "checkpoint payload corrupt: stored checksum {stored:#018x} != computed {computed:#018x}"
            ),
        }
    }
}

impl std::error::Error for CheckpointError {}

/// A stage's full training state.
#[derive(Clone, Debug, PartialEq)]
pub struct StageState {
    /// Training step the state was captured at.
    pub step: u64,
    /// Model parameters.
    pub params: Vec<HostTensor>,
    /// Adam first-moment state.
    pub m: Vec<HostTensor>,
    /// Adam second-moment state.
    pub v: Vec<HostTensor>,
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_tensor(w: &mut impl Write, name: &str, t: &HostTensor) -> Result<()> {
    write_u64(w, name.len() as u64)?;
    w.write_all(name.as_bytes())?;
    write_u64(w, t.shape().len() as u64)?;
    for &d in t.shape() {
        write_u64(w, d as u64)?;
    }
    let data = t.as_f32()?;
    // Safe little-endian serialization.
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&bytes)?;
    Ok(())
}

fn read_tensor(r: &mut impl Read) -> Result<(String, HostTensor)> {
    let name_len = read_u64(r)? as usize;
    if name_len > 4096 {
        bail!("corrupt checkpoint: name length {name_len}");
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("tensor name not utf-8")?;
    let rank = read_u64(r)? as usize;
    if rank > 8 {
        bail!("corrupt checkpoint: rank {rank}");
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u64(r)? as usize);
    }
    let n: usize = shape.iter().product();
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((name, HostTensor::F32 { shape, data }))
}

/// Save one stage's state (always the checksummed v2 format). The file
/// is assembled in memory and written in one call, so a crash mid-save
/// leaves either the old file or a file whose trailer will fail
/// verification — never a silently-half-written checkpoint that parses.
pub fn save(path: impl AsRef<Path>, metas: &[ParamMeta], state: &StageState) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    write_u64(&mut buf, state.step)?;
    write_u64(&mut buf, 3 * metas.len() as u64)?;
    for (section, tensors) in [("p", &state.params), ("m", &state.m), ("v", &state.v)] {
        anyhow::ensure!(tensors.len() == metas.len(), "tensor/meta arity mismatch");
        for (meta, t) in metas.iter().zip(tensors.iter()) {
            write_tensor(&mut buf, &format!("{section}.{}", meta.name), t)?;
        }
    }
    let sum = fnv1a(buf[MAGIC.len()..].iter().copied());
    buf.extend_from_slice(&sum.to_le_bytes());
    std::fs::write(path.as_ref(), &buf).with_context(|| format!("writing {:?}", path.as_ref()))?;
    Ok(())
}

/// Load one stage's state, validating against the artifact's param
/// layout. V2 files verify their trailing checksum first (a mismatch is
/// a typed [`CheckpointError::ChecksumMismatch`]); v1 files parse as
/// before.
pub fn load(path: impl AsRef<Path>, metas: &[ParamMeta]) -> Result<StageState> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    if bytes.len() < MAGIC.len() {
        bail!("not an H2 checkpoint (bad magic)");
    }
    let (magic, rest) = bytes.split_at(MAGIC.len());
    let body: &[u8] = if magic == MAGIC {
        // V2: the last 8 bytes are the fnv1a of everything between the
        // magic and the trailer.
        if rest.len() < 8 {
            bail!("corrupt checkpoint: v2 file too short for its checksum trailer");
        }
        let (payload, trailer) = rest.split_at(rest.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().expect("8-byte trailer"));
        let computed = fnv1a(payload.iter().copied());
        if stored != computed {
            return Err(CheckpointError::ChecksumMismatch { stored, computed }.into());
        }
        payload
    } else if magic == MAGIC_V1 {
        rest
    } else {
        bail!("not an H2 checkpoint (bad magic)");
    };
    let mut r: &[u8] = body;
    let step = read_u64(&mut r)?;
    let n = read_u64(&mut r)? as usize;
    if n != 3 * metas.len() {
        bail!("checkpoint has {n} tensors, artifact expects {}", 3 * metas.len());
    }
    let mut sections: Vec<Vec<HostTensor>> = vec![Vec::new(), Vec::new(), Vec::new()];
    for (si, section) in ["p", "m", "v"].iter().enumerate() {
        for meta in metas {
            let (name, t) = read_tensor(&mut r)?;
            let expect = format!("{section}.{}", meta.name);
            if name != expect {
                bail!("checkpoint tensor `{name}` where `{expect}` expected");
            }
            if t.shape() != meta.shape.as_slice() {
                bail!("`{name}` shape {:?} != artifact {:?}", t.shape(), meta.shape);
            }
            sections[si].push(t);
        }
    }
    let v = sections.pop().unwrap();
    let m = sections.pop().unwrap();
    let params = sections.pop().unwrap();
    Ok(StageState { step, params, m, v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::params::{init_params, zeros_like};

    fn metas() -> Vec<ParamMeta> {
        vec![
            ParamMeta { name: "embed".into(), shape: vec![16, 8] },
            ParamMeta { name: "layer0.wq".into(), shape: vec![8, 8] },
        ]
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("h2_ckpt_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    fn sample(step: u64, seed: u64) -> (Vec<ParamMeta>, StageState) {
        let metas = metas();
        let state = StageState {
            step,
            params: init_params(&metas, seed),
            m: init_params(&metas, seed + 1),
            v: zeros_like(&metas),
        };
        (metas, state)
    }

    #[test]
    fn roundtrip_is_bitwise_exact() {
        let (metas, state) = sample(42, 7);
        let p = tmp("roundtrip.ckpt");
        save(&p, &metas, &state).unwrap();
        let loaded = load(&p, &metas).unwrap();
        assert_eq!(loaded, state);
        // And the file on disk really is v2 with a verifying trailer.
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], MAGIC);
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        assert_eq!(stored, fnv1a(bytes[8..bytes.len() - 8].iter().copied()));
    }

    #[test]
    fn payload_bit_flip_is_a_typed_checksum_mismatch() {
        let (metas, state) = sample(9, 3);
        let p = tmp("bitflip.ckpt");
        save(&p, &metas, &state).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip one bit deep in the tensor payload: the shapes and names
        // still parse, so only the checksum can catch this.
        let i = bytes.len() / 2;
        bytes[i] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p, &metas).unwrap_err();
        let ck = err.downcast_ref::<CheckpointError>();
        assert!(
            matches!(ck, Some(CheckpointError::ChecksumMismatch { .. })),
            "expected a typed checksum mismatch, got: {err}"
        );
    }

    #[test]
    fn v1_files_without_trailer_still_load() {
        let (metas, state) = sample(17, 5);
        let p = tmp("v1compat.ckpt");
        save(&p, &metas, &state).unwrap();
        // A v1 file is exactly a v2 file minus the trailer, with the old
        // magic — the payload encoding never changed.
        let bytes = std::fs::read(&p).unwrap();
        let mut v1 = bytes[..bytes.len() - 8].to_vec();
        v1[..8].copy_from_slice(MAGIC_V1);
        std::fs::write(&p, &v1).unwrap();
        let loaded = load(&p, &metas).unwrap();
        assert_eq!(loaded, state);
    }

    #[test]
    fn wrong_layout_rejected() {
        let (metas, state) = sample(1, 1);
        let p = tmp("layout.ckpt");
        save(&p, &metas, &state).unwrap();
        // Loading against a different layout must fail loudly.
        let other = vec![ParamMeta { name: "embed".into(), shape: vec![16, 8] },
                         ParamMeta { name: "layer0.wk".into(), shape: vec![8, 8] }];
        assert!(load(&p, &other).is_err());
        let fewer = &metas[..1];
        assert!(load(&p, fewer).is_err());
    }

    #[test]
    fn corrupt_magic_rejected() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        let err = load(&p, &metas()).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn truncated_file_rejected() {
        let (metas, state) = sample(3, 2);
        let p = tmp("trunc.ckpt");
        save(&p, &metas, &state).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p, &metas).is_err());
    }
}
