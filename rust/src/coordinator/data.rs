//! Synthetic pre-training corpus.
//!
//! A seeded Zipf-weighted bigram language: every batch is sampled from a
//! fixed random bigram transition table, so the corpus has real learnable
//! structure (the model's loss can drop well below `ln(vocab)` toward the
//! bigram entropy) while remaining fully deterministic and shared between
//! the first stage (inputs) and last stage (targets) without communication.

use crate::util::rng::Rng;

/// Deterministic corpus generator.
#[derive(Clone, Debug)]
pub struct Corpus {
    vocab: u32,
    seed: u64,
    /// Per-state candidate successor sets (sparse bigram table).
    successors: Vec<Vec<u32>>,
}

/// Successors per token: small so the bigram structure is easy to learn.
const BRANCHING: usize = 8;

impl Corpus {
    /// A synthetic corpus over `vocab` tokens, deterministic in `seed`.
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ 0xB1647A);
        let vocab = vocab as u32;
        let successors = (0..vocab)
            .map(|_| (0..BRANCHING).map(|_| (rng.next_u64() % vocab as u64) as u32).collect())
            .collect();
        Corpus { vocab, seed, successors }
    }

    /// Sequence of `len + 1` tokens for (step, micro, dp_rank, row); the
    /// caller slices inputs `[0..len]` and targets `[1..len+1]`.
    pub fn sequence(&self, step: usize, micro: usize, dp_rank: usize, row: usize,
                    len: usize) -> Vec<i32> {
        let tag = (step as u64) << 40 | (micro as u64) << 24
            | (dp_rank as u64) << 12 | row as u64;
        let mut rng = Rng::new(self.seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15));
        let mut out = Vec::with_capacity(len + 1);
        let mut state = (rng.next_u64() % self.vocab as u64) as u32;
        out.push(state as i32);
        for _ in 0..len {
            let cands = &self.successors[state as usize];
            // Zipf-ish skew: prefer low-index successors.
            let r = rng.f64();
            let idx = ((r * r) * cands.len() as f64) as usize;
            state = cands[idx.min(cands.len() - 1)];
            out.push(state as i32);
        }
        out
    }

    /// Micro-batch of `mb` rows: (inputs [mb*len], targets [mb*len]).
    pub fn microbatch(&self, step: usize, micro: usize, dp_rank: usize,
                      mb: usize, len: usize) -> (Vec<i32>, Vec<i32>) {
        let mut inputs = Vec::with_capacity(mb * len);
        let mut targets = Vec::with_capacity(mb * len);
        for row in 0..mb {
            let seq = self.sequence(step, micro, dp_rank, row, len);
            inputs.extend_from_slice(&seq[..len]);
            targets.extend_from_slice(&seq[1..]);
        }
        (inputs, targets)
    }

    /// Empirical bigram entropy bound (nats/token) of the skewed sampler —
    /// the loss floor a perfect bigram model would reach.
    pub fn entropy_bound(&self) -> f64 {
        // P(idx) for idx in 0..BRANCHING under the r^2 skew.
        let n = BRANCHING as f64;
        let mut h = 0.0;
        for idx in 0..BRANCHING {
            // r^2 in [idx/n,(idx+1)/n] => r in [sqrt(idx/n), sqrt((idx+1)/n)]
            let p = ((idx as f64 + 1.0) / n).sqrt() - (idx as f64 / n).sqrt();
            h -= p * p.ln();
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let c1 = Corpus::new(1024, 5);
        let c2 = Corpus::new(1024, 5);
        assert_eq!(c1.sequence(3, 2, 1, 0, 64), c2.sequence(3, 2, 1, 0, 64));
    }

    #[test]
    fn distinct_microbatches_differ() {
        let c = Corpus::new(1024, 5);
        assert_ne!(c.sequence(0, 0, 0, 0, 64), c.sequence(0, 1, 0, 0, 64));
        assert_ne!(c.sequence(0, 0, 0, 0, 64), c.sequence(1, 0, 0, 0, 64));
        assert_ne!(c.sequence(0, 0, 0, 0, 64), c.sequence(0, 0, 1, 0, 64));
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let c = Corpus::new(512, 9);
        let (inp, tgt) = c.microbatch(1, 2, 0, 2, 32);
        assert_eq!(inp.len(), 64);
        // Within each row, target[t] == input[t+1].
        for row in 0..2 {
            for t in 0..31 {
                assert_eq!(tgt[row * 32 + t], inp[row * 32 + t + 1]);
            }
        }
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = Corpus::new(128, 3);
        let (inp, tgt) = c.microbatch(0, 0, 0, 4, 64);
        assert!(inp.iter().chain(&tgt).all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn entropy_bound_below_uniform() {
        let c = Corpus::new(1024, 1);
        assert!(c.entropy_bound() < (BRANCHING as f64).ln() + 1e-9);
        assert!(c.entropy_bound() > 0.5);
    }
}
