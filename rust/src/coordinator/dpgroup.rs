//! Data-parallel gradient synchronization group.
//!
//! All DP replicas of one pipeline stage deposit their flattened gradients;
//! the last depositor runs the DiComm ring allreduce (real byte math +
//! modeled wire time) and wakes the group. Every member leaves with the
//! summed gradient and the collective's modeled cost.

use std::sync::{Arc, Condvar, Mutex};

use crate::comm::collectives::{ring_allreduce, CollectiveCost};

struct State {
    slots: Vec<Option<Vec<f32>>>,
    generation: u64,
    done: usize,
    cost: CollectiveCost,
}

/// Reusable DP allreduce rendezvous for one stage.
pub struct DpGroup {
    state: Mutex<State>,
    cond: Condvar,
    hop_seconds_per_byte: f64,
    hop_base: f64,
}

impl DpGroup {
    /// `hop(bytes) = hop_base + bytes * hop_seconds_per_byte` is the DiComm
    /// per-hop model for the DP ring links of this stage.
    pub fn new(dp: usize, hop_base: f64, hop_seconds_per_byte: f64) -> Arc<DpGroup> {
        Arc::new(DpGroup {
            state: Mutex::new(State {
                slots: vec![None; dp],
                generation: 0,
                done: 0,
                cost: CollectiveCost::default(),
            }),
            cond: Condvar::new(),
            hop_seconds_per_byte,
            hop_base,
        })
    }

    /// Allreduce (sum) `grads` across the group; blocks until all ranks
    /// arrive. Returns the modeled collective cost.
    pub fn allreduce(&self, dp_rank: usize, grads: &mut Vec<f32>) -> CollectiveCost {
        let mut st = self.state.lock().unwrap();
        let gen = st.generation;
        st.slots[dp_rank] = Some(std::mem::take(grads));
        st.done += 1;
        let dp = st.slots.len();
        if st.done == dp {
            // Last arrival performs the reduction for the whole group.
            let mut bufs: Vec<Vec<f32>> = st.slots.iter_mut().map(|s| s.take().unwrap()).collect();
            let base = self.hop_base;
            let per_byte = self.hop_seconds_per_byte;
            let cost = ring_allreduce(&mut bufs, &|bytes| base + bytes as f64 * per_byte);
            for (slot, buf) in st.slots.iter_mut().zip(bufs) {
                *slot = Some(buf);
            }
            st.cost = cost;
            st.generation += 1;
            st.done = 0;
            self.cond.notify_all();
        } else {
            while st.generation == gen {
                st = self.cond.wait(st).unwrap();
            }
        }
        *grads = st.slots[dp_rank].take().unwrap();
        st.cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn allreduce_across_threads_sums() {
        let dp = 4;
        let group = DpGroup::new(dp, 1e-6, 1e-9);
        let mut handles = Vec::new();
        for rank in 0..dp {
            let g = group.clone();
            handles.push(thread::spawn(move || {
                let mut grads = vec![(rank + 1) as f32; 16];
                let cost = g.allreduce(rank, &mut grads);
                (grads, cost)
            }));
        }
        for h in handles {
            let (grads, cost) = h.join().unwrap();
            assert!(grads.iter().all(|&x| x == 10.0)); // 1+2+3+4
            assert!(cost.seconds > 0.0);
        }
    }

    #[test]
    fn reusable_across_steps() {
        let dp = 2;
        let group = DpGroup::new(dp, 0.0, 0.0);
        for step in 0..3 {
            let g0 = group.clone();
            let t = thread::spawn(move || {
                let mut a = vec![step as f32; 4];
                g0.allreduce(0, &mut a);
                a
            });
            let mut b = vec![1.0f32; 4];
            group.allreduce(1, &mut b);
            let a = t.join().unwrap();
            assert_eq!(a, b);
            assert!(a.iter().all(|&x| x == step as f32 + 1.0));
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let group = DpGroup::new(1, 1e-6, 1e-9);
        let mut grads = vec![3.0f32; 8];
        let cost = group.allreduce(0, &mut grads);
        assert!(grads.iter().all(|&x| x == 3.0));
        assert_eq!(cost.seconds, 0.0);
    }
}
