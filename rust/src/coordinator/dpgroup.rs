//! Data-parallel gradient synchronization group.
//!
//! All DP replicas of one pipeline stage deposit their flattened gradients;
//! the last depositor runs the DiComm collective engine (real byte math +
//! modeled wire time) under the strategy's [`CommAlgo`] over the stage's
//! [`CommTopology`], and wakes the group. Every member leaves with the
//! summed gradient and the collective's modeled cost.
//!
//! The topology comes from the stage's chip spec
//! ([`CommTopology::dp_group`] / [`CommTopology::dp_group_mode`]) — the
//! intra-node fabric and the Table 3 per-flow NIC path price each hop, so
//! co-located replicas sync over the fast fabric and only node-crossing
//! hops pay the wire. `auto` resolves exactly like the cost model: the
//! executable dispatcher probes the hop functions and picks the
//! closed-form argmin ([`crate::comm::collectives::allreduce`]).

use std::sync::{Arc, Condvar, Mutex};

use crate::comm::algo::{CommAlgo, CommTopology};
use crate::comm::collectives::{allreduce, CollectiveCost};

struct State {
    slots: Vec<Option<Vec<f32>>>,
    generation: u64,
    done: usize,
    cost: CollectiveCost,
}

/// Reusable DP allreduce rendezvous for one stage.
pub struct DpGroup {
    state: Mutex<State>,
    cond: Condvar,
    algo: CommAlgo,
    topo: CommTopology,
    /// Actual payload bytes are multiplied by this before pricing a hop,
    /// so a small stand-in gradient can carry the modeled gradient
    /// volume's wire time (1.0 for real runs).
    byte_scale: f64,
}

impl DpGroup {
    /// A DP group of `dp` replicas running `algo` over `topo` — hop times
    /// come from the topology's intra/inter [`crate::comm::LinkTime`]s,
    /// derived from the stage's chip spec rather than hardwired constants.
    pub fn new(dp: usize, algo: CommAlgo, topo: CommTopology) -> Arc<DpGroup> {
        DpGroup::with_byte_scale(dp, algo, topo, 1.0)
    }

    /// [`DpGroup::new`] with a payload scale: each hop of `bytes` is
    /// priced as `bytes * byte_scale`. The plan-driven virtual evaluator
    /// moves small synthetic gradients but charges the plan's modeled
    /// per-layer gradient volume through this scale.
    pub fn with_byte_scale(
        dp: usize,
        algo: CommAlgo,
        topo: CommTopology,
        byte_scale: f64,
    ) -> Arc<DpGroup> {
        Arc::new(DpGroup {
            state: Mutex::new(State {
                slots: vec![None; dp],
                generation: 0,
                done: 0,
                cost: CollectiveCost::default(),
            }),
            cond: Condvar::new(),
            algo,
            topo,
            byte_scale,
        })
    }

    /// The collective algorithm this group dispatches (before `auto`
    /// resolution).
    pub fn algo(&self) -> CommAlgo {
        self.algo
    }

    /// Allreduce (sum) `grads` across the group; blocks until all ranks
    /// arrive. Returns the modeled collective cost.
    pub fn allreduce(&self, dp_rank: usize, grads: &mut Vec<f32>) -> CollectiveCost {
        let mut st = self.state.lock().unwrap();
        let gen = st.generation;
        st.slots[dp_rank] = Some(std::mem::take(grads));
        st.done += 1;
        let dp = st.slots.len();
        if st.done == dp {
            // Last arrival performs the reduction for the whole group.
            let mut bufs: Vec<Vec<f32>> = st.slots.iter_mut().map(|s| s.take().unwrap()).collect();
            let scale = self.byte_scale;
            let intra = self.topo.intra;
            let inter = self.topo.inter;
            let intra_hop =
                move |bytes: usize| intra.latency + bytes as f64 * scale / intra.bytes_per_sec;
            let inter_hop =
                move |bytes: usize| inter.latency + bytes as f64 * scale / inter.bytes_per_sec;
            let cost = allreduce(
                self.algo,
                &mut bufs,
                self.topo.ranks_per_node,
                &intra_hop,
                &inter_hop,
            );
            for (slot, buf) in st.slots.iter_mut().zip(bufs) {
                *slot = Some(buf);
            }
            st.cost = cost;
            st.generation += 1;
            st.done = 0;
            self.cond.notify_all();
        } else {
            while st.generation == gen {
                st = self.cond.wait(st).unwrap();
            }
        }
        *grads = st.slots[dp_rank].take().unwrap();
        st.cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::LinkTime;
    use crate::hetero::{spec, ChipKind};
    use crate::topology::NicAssignment;
    use std::thread;

    /// A fully scattered group: every hop on a 1 GB/s inter link.
    fn flat_topo(dp: usize) -> CommTopology {
        CommTopology {
            n_ranks: dp,
            ranks_per_node: 1,
            intra: LinkTime { latency: 1e-6, bytes_per_sec: 100e9 },
            inter: LinkTime { latency: 1e-6, bytes_per_sec: 1e9 },
        }
    }

    #[test]
    fn allreduce_across_threads_sums() {
        let dp = 4;
        let group = DpGroup::new(dp, CommAlgo::Ring, flat_topo(dp));
        let mut handles = Vec::new();
        for rank in 0..dp {
            let g = group.clone();
            handles.push(thread::spawn(move || {
                let mut grads = vec![(rank + 1) as f32; 16];
                let cost = g.allreduce(rank, &mut grads);
                (grads, cost)
            }));
        }
        for h in handles {
            let (grads, cost) = h.join().unwrap();
            assert!(grads.iter().all(|&x| x == 10.0)); // 1+2+3+4
            assert!(cost.seconds > 0.0);
        }
    }

    #[test]
    fn reusable_across_steps() {
        let dp = 2;
        let group = DpGroup::new(dp, CommAlgo::Ring, flat_topo(dp));
        for step in 0..3 {
            let g0 = group.clone();
            let t = thread::spawn(move || {
                let mut a = vec![step as f32; 4];
                g0.allreduce(0, &mut a);
                a
            });
            let mut b = vec![1.0f32; 4];
            group.allreduce(1, &mut b);
            let a = t.join().unwrap();
            assert_eq!(a, b);
            assert!(a.iter().all(|&x| x == step as f32 + 1.0));
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let group = DpGroup::new(1, CommAlgo::Ring, flat_topo(1));
        let mut grads = vec![3.0f32; 8];
        let cost = group.allreduce(0, &mut grads);
        assert!(grads.iter().all(|&x| x == 3.0));
        assert_eq!(cost.seconds, 0.0);
    }

    #[test]
    fn every_algorithm_sums_identically_on_integer_grads() {
        // Integer-valued payloads make f32 addition exact in any order:
        // every collective algorithm must produce bit-identical sums (the
        // bedrock of the parity suite's cross-algorithm guarantee).
        let dp = 4;
        let topo = CommTopology::dp_group(&spec(ChipKind::B), dp, 4, NicAssignment::Affinity);
        let expect: Vec<f32> = (0..32).map(|i| (4 * (i % 7)) as f32 - 8.0).collect();
        for algo in CommAlgo::ALL {
            let group = DpGroup::new(dp, algo, topo);
            let mut handles = Vec::new();
            for rank in 0..dp {
                let g = group.clone();
                handles.push(thread::spawn(move || {
                    let mut grads: Vec<f32> =
                        (0..32).map(|i| ((i % 7) as f32) - 2.0).collect();
                    g.allreduce(rank, &mut grads);
                    grads
                }));
            }
            for h in handles {
                let grads = h.join().unwrap();
                for (x, e) in grads.iter().zip(&expect) {
                    assert_eq!(x.to_bits(), e.to_bits(), "{algo}");
                }
            }
        }
    }

    #[test]
    fn spec_derived_topology_makes_hierarchical_beat_ring() {
        // Chip B at TP 4 co-locates 2 of 4 replicas per node: the flat
        // ring pays the NIC on every hop, the two-level schedule keeps
        // half its steps on the intra fabric.
        let dp = 4;
        let topo = CommTopology::dp_group(&spec(ChipKind::B), dp, 4, NicAssignment::Affinity);
        let run = |algo: CommAlgo| {
            let group = DpGroup::new(dp, algo, topo);
            let mut handles = Vec::new();
            for rank in 0..dp {
                let g = group.clone();
                handles.push(thread::spawn(move || {
                    let mut grads = vec![1.0f32; 1 << 16];
                    g.allreduce(rank, &mut grads)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap().seconds).fold(0.0, f64::max)
        };
        let ring = run(CommAlgo::Ring);
        let hier = run(CommAlgo::Hierarchical);
        assert!(hier < ring, "hier {hier} !< ring {ring}");
    }

    #[test]
    fn byte_scale_amplifies_the_modeled_cost_only() {
        let dp = 2;
        let run = |scale: f64| {
            let group = DpGroup::with_byte_scale(dp, CommAlgo::Ring, flat_topo(dp), scale);
            let g = group.clone();
            let t = thread::spawn(move || {
                let mut a = vec![1.0f32; 64];
                g.allreduce(0, &mut a)
            });
            let mut b = vec![2.0f32; 64];
            let cost = group.allreduce(1, &mut b);
            t.join().unwrap();
            assert!(b.iter().all(|&x| x == 3.0), "data unchanged by scale");
            cost.seconds
        };
        let base = run(1.0);
        let scaled = run(1024.0);
        assert!(scaled > base, "scaled {scaled} !> base {base}");
    }
}
