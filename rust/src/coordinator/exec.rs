//! The plan-driven virtual evaluator: the coordinator as the *third
//! evaluator* of an [`ExecutionPlan`], next to the closed-form cost model
//! (`costmodel::evaluate_plan`) and the discrete-event simulator
//! (`sim::simulate_plan`).
//!
//! [`train_virtual`] spawns one worker thread per (pipeline stage × DP
//! replica) and executes the plan's `strategy.schedule` op-for-op from the
//! shared order generators (`coordinator::schedule`), moving real tensors
//! through the DiComm fabric and synchronizing gradients through the
//! [`DpGroup`] collective engine under the plan's `strategy.comm_algo`.
//! Compute advances each rank's virtual clock by the *modeled* stage
//! durations — the same per-stage timing table the simulator executes
//! (`sim::pipeline`) — so the reported step/comm seconds are directly
//! comparable to `simulate_plan` and `evaluate_plan`. The three-evaluator
//! parity suite (`rust/tests/parity.rs`) holds all three together for
//! every (schedule × comm-algo) pair.
//!
//! The synthetic stage model is small but real: each virtual chunk owns a
//! weight vector `w`, forward is `y = w ⊙ x`, the loss is the mean squared
//! error against a deterministic target, and backward produces genuine
//! input and weight gradients (the zero-bubble schedule executes the
//! B/W split for real here). Accumulated gradients are rounded onto the
//! 2⁻⁸ dyadic grid before DP synchronization, which makes f32 summation
//! exact in *any* order — so all five collective algorithms produce
//! bit-identical gradients, and therefore bit-identical parameters.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::comm::{fabric, CommTopology, Endpoint, LatencyFn};
use crate::costmodel::profile::DP_OVERLAP;
use crate::elastic::FaultPlan;
use crate::plan::ExecutionPlan;
use crate::runtime::{HostTensor, ParamMeta};
use crate::sim::pipeline::{plan_stage_sims, stage_links, StageSim};
use crate::util::rng::Rng;

use super::checkpoint::{self, StageState};
use super::dpgroup::DpGroup;
use super::schedule::{stage_orders, PipeOp};

/// Elements per virtual-chunk weight vector (and per activation). 64
/// splits evenly over every practical DP group and node shape, so the
/// executed collective walks exactly the closed form's hop sequence.
pub const VIRTUAL_WIDTH: usize = 64;

/// Run-shape options of the virtual evaluator (the plan supplies the
/// cluster, strategy and communication configuration).
#[derive(Clone, Debug)]
pub struct VirtualOptions {
    /// Training steps to run (resume runs continue up to this step).
    pub steps: usize,
    /// Adam learning rate of the synthetic model.
    pub lr: f32,
    /// Parameter-init and data seed.
    pub seed: u64,
    /// Print a loss line every N steps (0 = silent).
    pub log_every: usize,
    /// Directory to write per-stage checkpoints into.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint every N steps (0 = never).
    pub checkpoint_every: usize,
    /// Keep only the newest N complete archived checkpoint generations
    /// (0 = keep all). The prune never touches an incomplete generation
    /// or the newest complete one, and the flat per-stage files (what
    /// `resume_from` reads) always hold the latest state.
    pub keep_last: usize,
    /// Directory to resume per-stage checkpoints from.
    pub resume_from: Option<PathBuf>,
    /// Fault-injection scenario to replay (overrides the plan's embedded
    /// `fault_plan` when both are set).
    pub faults: Option<FaultPlan>,
}

impl Default for VirtualOptions {
    fn default() -> Self {
        VirtualOptions {
            steps: 4,
            lr: 1e-2,
            seed: 42,
            log_every: 0,
            checkpoint_dir: None,
            checkpoint_every: 0,
            keep_last: 0,
            resume_from: None,
            faults: None,
        }
    }
}

impl VirtualOptions {
    /// Defaults overlaid with the plan's `train` section scalars (steps,
    /// lr, seed, log_every) when the plan carries one.
    pub fn from_plan(plan: &ExecutionPlan) -> VirtualOptions {
        let mut o = VirtualOptions::default();
        if let Some(t) = &plan.train {
            o.steps = t.steps;
            o.lr = t.lr;
            o.seed = t.seed;
            o.log_every = t.log_every;
        }
        o.faults = plan.fault_plan.clone();
        o
    }
}

/// Result of a virtual training run.
#[derive(Clone, Debug)]
pub struct VirtualReport {
    /// Mean loss per executed step (averaged over micro-batches and DP
    /// replicas, folded in deterministic rank order).
    pub losses: Vec<f64>,
    /// First step this run executed (> 0 after a checkpoint resume).
    pub start_step: usize,
    /// Modeled seconds per step on the slowest rank — the coordinator's
    /// answer to `iteration_seconds` from the simulator and cost model.
    pub step_seconds: f64,
    /// Modeled communication-only seconds per step on the most-charged
    /// rank (P2P arrivals + the exposed DP-sync slice).
    pub comm_seconds: f64,
    /// Total modeled seconds on the slowest rank for the whole run.
    pub virtual_seconds: f64,
    /// Final weights per physical stage (virtual chunks concatenated,
    /// identical across DP replicas after synchronization).
    pub final_params: Vec<Vec<f32>>,
    /// `Some(step)` when a `ChipDeath` fault drained the run at that step
    /// boundary before `steps` completed (steps `start_step..step` ran).
    pub halted_at: Option<usize>,
    /// DP-rank-0 compute-only seconds per stage per executed step
    /// (`[stage][step - start_step]`) — the heartbeat stream the
    /// [`crate::elastic::StepMonitor`] compares against its predictions;
    /// a fault factor of k shows up as a ×k ratio here.
    pub stage_compute_seconds: Vec<Vec<f64>>,
    /// DP-rank-0 full-step seconds per stage per executed step
    /// (`[stage][step - start_step]`): compute plus the exposed DP-sync
    /// slice — what a wall-clock step heartbeat would time. A
    /// `NicDegrade` never touches compute, so this is the stream where
    /// it becomes observable (the sync slice scales by the NIC factor).
    pub stage_step_seconds: Vec<Vec<f64>>,
}

const DIR_FWD: u64 = 0;
const DIR_BWD: u64 = 1;
const SALT_X: u64 = 0x78;
const SALT_T: u64 = 0x74;

fn tag(step: usize, d: usize, micro: usize, dir: u64) -> u64 {
    (step as u64) << 32 | (d as u64) << 20 | (micro as u64) << 1 | dir
}

/// Deterministic per-(step, micro, replica) data stream.
fn gen_values(seed: u64, step: usize, micro: usize, dp_rank: usize, salt: u64) -> Vec<f32> {
    let mut rng = Rng::new(
        seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (micro as u64).wrapping_mul(0xA24B_AED4_963E_E407)
            ^ (dp_rank as u64).wrapping_mul(0x5851_F42D_4C95_7F2D)
            ^ salt.wrapping_mul(0xD6E8_FEB8_6659_FD93),
    );
    (0..VIRTUAL_WIDTH).map(|_| (rng.usize(0, 9) as f32 - 4.0) / 4.0).collect()
}

/// Round onto the 2⁻⁸ dyadic grid: bounded multiples of 2⁻⁸ sum exactly
/// in f32 whatever the association, so the DP reduction is bit-identical
/// across collective algorithms.
fn quantize_dyadic(g: &mut [f32]) {
    for x in g.iter_mut() {
        *x = (*x * 256.0).round() / 256.0;
    }
}

/// One virtual chunk's trainable state.
struct ChunkState {
    w: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl ChunkState {
    /// Identical across DP replicas (seed + global chunk index only).
    fn init(seed: u64, d: usize) -> ChunkState {
        let mut rng =
            Rng::new(seed ^ 0xC0FF_EE00 ^ (d as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let w = (0..VIRTUAL_WIDTH).map(|_| (rng.usize(0, 17) as f32 - 8.0) / 16.0).collect();
        ChunkState {
            w,
            m: vec![0.0; VIRTUAL_WIDTH],
            v: vec![0.0; VIRTUAL_WIDTH],
        }
    }

    /// Standard Adam over the (already summed) gradient, scaled by
    /// `gscale` — deterministic f32 math, identical on every replica.
    fn adam(&mut self, grad: &[f32], gscale: f32, lr: f32, t: i32) {
        const BETA1: f32 = 0.9;
        const BETA2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        let b1t = 1.0 - BETA1.powi(t);
        let b2t = 1.0 - BETA2.powi(t);
        for i in 0..self.w.len() {
            let g = grad[i] * gscale;
            self.m[i] = BETA1 * self.m[i] + (1.0 - BETA1) * g;
            self.v[i] = BETA2 * self.v[i] + (1.0 - BETA2) * g * g;
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            self.w[i] -= lr * mh / (vh.sqrt() + EPS);
        }
    }
}

/// Checkpoint layout of one stage: `v` chunk weight vectors (shared with
/// the elastic hot-swap migration, which copies these files).
pub(crate) fn chunk_metas(v: usize) -> Vec<ParamMeta> {
    (0..v)
        .map(|c| ParamMeta { name: format!("chunk{c}.w"), shape: vec![VIRTUAL_WIDTH] })
        .collect()
}

/// Per-stage checkpoint file inside a checkpoint directory.
pub(crate) fn stage_ckpt_path(dir: &std::path::Path, stage: usize) -> PathBuf {
    dir.join(format!("stage{stage}.ckpt"))
}

/// Archived generation directory for the checkpoint written at `step`.
fn gen_dir(dir: &std::path::Path, step: u64) -> PathBuf {
    dir.join(format!("step{step}"))
}

/// Resolve a resume directory to the newest usable checkpoint
/// generation: one whose every stage file loads (checksum-verified, see
/// [`checkpoint::CheckpointError`]) and agrees on the step. The flat
/// per-stage files are probed first; if any is corrupt, missing, or
/// inconsistent, the archived `step{N}/` generations are scanned
/// newest-first. A bit-flipped latest checkpoint therefore degrades the
/// resume to the previous generation retained by `keep_last` instead of
/// aborting the run.
pub(crate) fn resolve_resume(
    dir: &std::path::Path,
    s_n: usize,
    metas: &[ParamMeta],
) -> Result<(u64, PathBuf)> {
    fn probe(dir: &std::path::Path, s_n: usize, metas: &[ParamMeta]) -> Option<u64> {
        let mut step = None;
        for s in 0..s_n {
            let state = checkpoint::load(stage_ckpt_path(dir, s), metas).ok()?;
            match step {
                None => step = Some(state.step),
                Some(prev) if prev != state.step => return None,
                Some(_) => {}
            }
        }
        step
    }
    if let Some(step) = probe(dir, s_n, metas) {
        return Ok((step, dir.to_path_buf()));
    }
    let mut gens: Vec<u64> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            if let Some(step) = name
                .to_str()
                .and_then(|n| n.strip_prefix("step"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                gens.push(step);
            }
        }
    }
    gens.sort_unstable_by(|a, b| b.cmp(a));
    for &step in &gens {
        let gen = gen_dir(dir, step);
        // A generation dir must agree with its own name — anything else
        // is corruption, not a candidate.
        if probe(&gen, s_n, metas) == Some(step) {
            return Ok((step, gen));
        }
    }
    bail!(
        "no usable checkpoint under {dir:?}: the flat stage files and {} archived \
         generation(s) all failed integrity or consistency checks",
        gens.len()
    )
}

/// Prune archived checkpoint generations down to the newest `keep_last`
/// *complete* ones (a generation is complete when all `s_n` stage files
/// exist). Incomplete generations are never touched — a concurrently
/// written one must not be half-deleted — and with `keep_last >= 1` the
/// newest complete generation always survives. Races between the
/// per-stage workers (both pruning, or re-listing a dir the other just
/// removed) are benign: removal errors are ignored.
fn prune_generations(dir: &std::path::Path, s_n: usize, keep_last: usize) {
    if keep_last == 0 {
        return;
    }
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut complete: Vec<u64> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(step) = name.to_str().and_then(|n| n.strip_prefix("step")) else {
            continue;
        };
        let Ok(step) = step.parse::<u64>() else { continue };
        if (0..s_n).all(|s| stage_ckpt_path(&gen_dir(dir, step), s).exists()) {
            complete.push(step);
        }
    }
    complete.sort_unstable_by(|a, b| b.cmp(a));
    for &step in complete.iter().skip(keep_last) {
        let _ = std::fs::remove_dir_all(gen_dir(dir, step));
    }
}

struct VShared {
    /// losses[dp_rank][step - start_step]; folded in rank order after join.
    losses: Mutex<Vec<Vec<f64>>>,
    virtual_ns: AtomicU64,
    comm_ns: AtomicU64,
    /// Final concatenated chunk weights per stage (written by dp rank 0).
    params: Mutex<Vec<Vec<f32>>>,
    /// compute[stage][step - start_step], dp rank 0's compute-only seconds.
    compute: Mutex<Vec<Vec<f64>>>,
    /// step_secs[stage][step - start_step], dp rank 0's compute + exposed
    /// DP-sync seconds (the wall-clock heartbeat stream).
    step_secs: Mutex<Vec<Vec<f64>>>,
}

struct VCtx {
    stage: usize,
    s_n: usize,
    dp_rank: usize,
    dp: usize,
    v: usize,
    b: usize,
    steps: usize,
    start_step: usize,
    lr: f32,
    seed: u64,
    log_every: usize,
    split_backward: bool,
    timing: StageSim,
    links: Arc<Vec<f64>>,
    wrap: f64,
    order: Vec<PipeOp>,
    dp_group: Arc<DpGroup>,
    shared: Arc<VShared>,
    checkpoint: Option<(PathBuf, usize)>,
    keep_last: usize,
    resume_from: Option<PathBuf>,
    faults: Arc<FaultPlan>,
}

impl VCtx {
    /// Hop time leaving virtual stage `d` toward `d + 1` (or back, for
    /// gradients) — the simulator's link table, wrap included.
    fn hop(&self, d: usize) -> f64 {
        if d % self.s_n == self.s_n - 1 { self.wrap } else { self.links[d % self.s_n] }
    }
}

/// Execute `plan` on the virtual coordinator: real schedule, real
/// collectives, modeled time. See the module docs for the model; see
/// [`VirtualOptions`] for run-shape knobs (steps, checkpointing, resume).
pub fn train_virtual(plan: &ExecutionPlan, opts: &VirtualOptions) -> Result<VirtualReport> {
    if let Err(errs) = plan.validate() {
        bail!("plan `{}` is invalid:\n{}", plan.name, crate::plan::render_errors(&errs));
    }
    let groups = plan.group_refs();
    let strategy = &plan.strategy;
    let sim_opts = plan.sim_options();
    let stages = plan_stage_sims(&plan.model, &groups, strategy, plan.micro_tokens, &sim_opts);
    let (links, wrap) = stage_links(&stages, &groups, &plan.model, plan.micro_tokens, &sim_opts);
    let s_n = stages.len();
    if s_n == 0 {
        bail!("plan `{}` has no pipeline stages", plan.name);
    }
    let dp = strategy.s_dp;
    let b = strategy.micro_batches;
    let v = strategy.schedule.virtual_stages();
    let orders = stage_orders(strategy.schedule, s_n, b);

    // Fault scenario: an explicit option wins over the plan's embedded
    // one. A `ChipDeath` drains the run at that step boundary — steps
    // `start_step..death` execute normally, then every worker stops at
    // the same synchronized point (the post-step checkpoint is the state
    // the elastic hot-swap migrates).
    let faults = Arc::new(
        opts.faults
            .clone()
            .or_else(|| plan.fault_plan.clone())
            .unwrap_or_default(),
    );
    faults.validate(s_n)?;
    let (steps, halted_at) = match faults.first_death() {
        Some(death) if death.step < opts.steps => (death.step, Some(death.step)),
        _ => (opts.steps, None),
    };

    // Resume: the leader resolves the newest usable generation (falling
    // back past corrupt flat files), then every worker loads + validates
    // its own stage file from that resolved directory.
    let resume = match &opts.resume_from {
        Some(dir) => {
            let (step, from) = resolve_resume(dir, s_n, &chunk_metas(v))
                .context("resolving resume checkpoint")?;
            Some((step as usize, from))
        }
        None => None,
    };
    let start_step = resume.as_ref().map_or(0, |(step, _)| *step);
    ensure!(
        start_step < steps,
        "resume checkpoint is at step {start_step}, nothing left of a {steps}-step run",
    );

    // One DP rendezvous per stage: the plan's collective algorithm over
    // the stage's chip-derived topology; hop bytes scale from the small
    // synthetic gradient up to one layer's modeled gradient volume.
    let dp_groups: Vec<Arc<DpGroup>> = stages
        .iter()
        .map(|st| {
            let topo = CommTopology::dp_group(
                &groups[st.group].spec,
                dp,
                st.s_tp,
                plan.nic_assignment,
            );
            let actual_bytes = (v * VIRTUAL_WIDTH * 4) as f64;
            DpGroup::with_byte_scale(
                dp,
                strategy.comm_algo,
                topo,
                st.grad_bytes_per_layer / actual_bytes,
            )
        })
        .collect();

    let executed = steps - start_step;
    let shared = Arc::new(VShared {
        losses: Mutex::new(vec![vec![0.0; executed]; dp]),
        virtual_ns: AtomicU64::new(0),
        comm_ns: AtomicU64::new(0),
        params: Mutex::new(vec![Vec::new(); s_n]),
        compute: Mutex::new(vec![vec![0.0; executed]; s_n]),
        step_secs: Mutex::new(vec![vec![0.0; executed]; s_n]),
    });

    // Hop latencies are charged per logical edge through
    // `send_with_latency`; the fabric's own model is unused here.
    let zero: LatencyFn = Arc::new(|_, _, _| 0.0);
    let mut endpoints = fabric(dp * s_n, zero);
    let links = Arc::new(links);

    let mut handles = Vec::new();
    // Spawn in reverse so we can pop endpoints by rank.
    for dp_rank in (0..dp).rev() {
        for si in (0..s_n).rev() {
            let ep = endpoints.pop().expect("endpoint per rank");
            debug_assert_eq!(ep.rank(), dp_rank * s_n + si);
            let ctx = VCtx {
                stage: si,
                s_n,
                dp_rank,
                dp,
                v,
                b,
                steps,
                start_step,
                lr: opts.lr,
                seed: opts.seed,
                log_every: opts.log_every,
                split_backward: strategy.schedule
                    == crate::costmodel::Schedule::ZeroBubbleV,
                timing: stages[si].clone(),
                links: links.clone(),
                wrap,
                order: orders[si].clone(),
                dp_group: dp_groups[si].clone(),
                shared: shared.clone(),
                checkpoint: opts
                    .checkpoint_dir
                    .as_ref()
                    .map(|d| (d.clone(), opts.checkpoint_every)),
                keep_last: opts.keep_last,
                resume_from: resume.as_ref().map(|(_, from)| from.clone()),
                faults: faults.clone(),
            };
            handles.push(std::thread::spawn(move || vworker(ctx, ep)));
        }
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("virtual worker panicked"))??;
    }

    let grid = shared.losses.lock().unwrap().clone();
    let losses: Vec<f64> = (0..executed)
        .map(|i| (0..dp).map(|r| grid[r][i]).sum::<f64>() / dp as f64)
        .collect();
    let virtual_seconds = shared.virtual_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    let comm_seconds = shared.comm_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    Ok(VirtualReport {
        losses,
        start_step,
        step_seconds: virtual_seconds / executed.max(1) as f64,
        comm_seconds: comm_seconds / executed.max(1) as f64,
        virtual_seconds,
        final_params: shared.params.lock().unwrap().clone(),
        halted_at,
        stage_compute_seconds: shared.compute.lock().unwrap().clone(),
        stage_step_seconds: shared.step_secs.lock().unwrap().clone(),
    })
}

fn vworker(ctx: VCtx, mut ep: Endpoint) -> Result<()> {
    let s_n = ctx.s_n;
    let v = ctx.v;
    let b = ctx.b;
    let d_n = s_n * v;
    let w_len = VIRTUAL_WIDTH;
    let loss_stage = (d_n - 1) % s_n;
    let vf = v as f64;

    let mut chunks: Vec<ChunkState> = (0..v)
        .map(|c| ChunkState::init(ctx.seed, c * s_n + ctx.stage))
        .collect();
    if let Some(dir) = &ctx.resume_from {
        let metas = chunk_metas(v);
        let state = checkpoint::load(stage_ckpt_path(dir, ctx.stage), &metas)
            .with_context(|| format!("resuming stage {}", ctx.stage))?;
        ensure!(
            state.step as usize == ctx.start_step,
            "stage {} checkpoint is at step {}, stage 0 at {}",
            ctx.stage,
            state.step,
            ctx.start_step
        );
        for (c, chunk) in chunks.iter_mut().enumerate() {
            chunk.w = state.params[c].as_f32()?.to_vec();
            chunk.m = state.m[c].as_f32()?.to_vec();
            chunk.v = state.v[c].as_f32()?.to_vec();
        }
    }

    for step in ctx.start_step..ctx.steps {
        let mut grads: Vec<Vec<f32>> = vec![vec![0.0f32; w_len]; v];
        let mut stash: Vec<Vec<Option<Vec<f32>>>> = vec![(0..b).map(|_| None).collect(); v];
        let mut dy_stash: Vec<Vec<Option<Vec<f32>>>> = vec![(0..b).map(|_| None).collect(); v];
        let mut w_stash: Vec<Vec<Option<(Vec<f32>, Vec<f32>)>>> =
            vec![(0..b).map(|_| None).collect(); v];
        let mut step_loss = 0.0f64;
        // Faults scale *time only* — compute advances by `cf`, hop
        // latencies and the exposed DP-sync slice by `nf`. The numeric
        // stream (activations, gradients, Adam) never sees them, so a
        // faulty run's losses stay bit-identical to a healthy run's.
        let (cf, nf) = ctx.faults.factors_at(step, ctx.stage);
        let mut step_compute = 0.0f64;

        for &op in &ctx.order {
            match op {
                PipeOp::Fwd { chunk, micro } => {
                    let d = chunk * s_n + ctx.stage;
                    let x: Vec<f32> = if d == 0 {
                        gen_values(ctx.seed, step, micro, ctx.dp_rank, SALT_X)
                    } else {
                        let src = ctx.dp_rank * s_n + (d - 1) % s_n;
                        let data = ep.recv(src, tag(step, d, micro, DIR_FWD))?;
                        ensure!(data.len() == w_len, "activation size mismatch");
                        data
                    };
                    let y: Vec<f32> =
                        chunks[chunk].w.iter().zip(&x).map(|(w, xi)| w * xi).collect();
                    let dur = ctx.timing.t_fwd / vf * cf;
                    ep.advance(dur);
                    step_compute += dur;
                    if d == d_n - 1 {
                        let t = gen_values(ctx.seed, step, micro, ctx.dp_rank, SALT_T);
                        let mut loss = 0.0f64;
                        let mut dy = vec![0.0f32; w_len];
                        for i in 0..w_len {
                            let diff = y[i] - t[i];
                            loss += diff as f64 * diff as f64;
                            dy[i] = diff / w_len as f32;
                        }
                        step_loss += loss / (2.0 * w_len as f64);
                        dy_stash[chunk][micro] = Some(dy);
                    } else {
                        let dst = ctx.dp_rank * s_n + (d + 1) % s_n;
                        ep.send_with_latency(
                            dst,
                            tag(step, d + 1, micro, DIR_FWD),
                            y,
                            ctx.hop(d) * nf,
                        )?;
                    }
                    stash[chunk][micro] = Some(x);
                }
                PipeOp::Bwd { chunk, micro } => {
                    let d = chunk * s_n + ctx.stage;
                    let dy: Vec<f32> = if d == d_n - 1 {
                        dy_stash[chunk][micro]
                            .take()
                            .ok_or_else(|| anyhow!("missing dy for micro {micro}"))?
                    } else {
                        let src = ctx.dp_rank * s_n + (d + 1) % s_n;
                        let data = ep.recv(src, tag(step, d, micro, DIR_BWD))?;
                        ensure!(data.len() == w_len, "gradient size mismatch");
                        data
                    };
                    let x = stash[chunk][micro]
                        .take()
                        .ok_or_else(|| anyhow!("missing stash for micro {micro}"))?;
                    let dur = cf
                        * if ctx.split_backward {
                            ctx.timing.t_bwd_input
                        } else {
                            ctx.timing.t_bwd / vf
                        };
                    let dx: Vec<f32> =
                        chunks[chunk].w.iter().zip(&dy).map(|(w, g)| w * g).collect();
                    ep.advance(dur);
                    step_compute += dur;
                    if d > 0 {
                        let dst = ctx.dp_rank * s_n + (d - 1) % s_n;
                        ep.send_with_latency(
                            dst,
                            tag(step, d - 1, micro, DIR_BWD),
                            dx,
                            ctx.hop(d - 1) * nf,
                        )?;
                    }
                    if ctx.split_backward {
                        w_stash[chunk][micro] = Some((x, dy));
                    } else {
                        for i in 0..w_len {
                            grads[chunk][i] += x[i] * dy[i];
                        }
                    }
                }
                PipeOp::BwdWeight { chunk, micro } => {
                    let (x, dy) = w_stash[chunk][micro]
                        .take()
                        .ok_or_else(|| anyhow!("missing weight-phase stash {micro}"))?;
                    for i in 0..w_len {
                        grads[chunk][i] += x[i] * dy[i];
                    }
                    let dur = ctx.timing.t_bwd_weight * cf;
                    ep.advance(dur);
                    step_compute += dur;
                }
            }
        }

        // DP gradient synchronization: the executed DiComm collective.
        // Charged time is the exposed slice of one layer's sync scaled to
        // this stage's layer count — the executed twin of the closed-form
        // `t_dp_sync` the cost model and simulator fold into t_update.
        let mut flat: Vec<f32> = Vec::with_capacity(v * w_len);
        for g in &grads {
            flat.extend_from_slice(g);
        }
        quantize_dyadic(&mut flat);
        let cost = ctx.dp_group.allreduce(ctx.dp_rank, &mut flat);
        let sync = ctx.timing.lps * cost.seconds * (1.0 - DP_OVERLAP) * nf;
        let update = (ctx.timing.t_update - ctx.timing.t_update_comm) * cf;
        ep.advance(update + sync);
        ep.add_wire(sync);
        step_compute += update;
        if ctx.dp_rank == 0 {
            let rel = step - ctx.start_step;
            ctx.shared.compute.lock().unwrap()[ctx.stage][rel] = step_compute;
            ctx.shared.step_secs.lock().unwrap()[ctx.stage][rel] = step_compute + sync;
        }

        // Adam update (gradient averaged over the global batch).
        let gscale = 1.0 / (b * ctx.dp) as f32;
        for (c, chunk) in chunks.iter_mut().enumerate() {
            chunk.adam(&flat[c * w_len..(c + 1) * w_len], gscale, ctx.lr, (step + 1) as i32);
        }

        if ctx.stage == loss_stage {
            let mean = step_loss / b as f64;
            ctx.shared.losses.lock().unwrap()[ctx.dp_rank][step - ctx.start_step] = mean;
            if ctx.dp_rank == 0
                && ctx.log_every > 0
                && (step % ctx.log_every == 0 || step + 1 == ctx.steps)
            {
                eprintln!("[h2] virtual step {step:>4}  loss {mean:.4}");
            }
        }

        if let Some((dir, every)) = &ctx.checkpoint {
            if ctx.dp_rank == 0 && *every > 0 && (step + 1) % every == 0 {
                let metas = chunk_metas(v);
                let state = StageState {
                    step: (step + 1) as u64,
                    params: chunks
                        .iter()
                        .map(|c| HostTensor::f32(&[w_len], c.w.clone()))
                        .collect(),
                    m: chunks
                        .iter()
                        .map(|c| HostTensor::f32(&[w_len], c.m.clone()))
                        .collect(),
                    v: chunks
                        .iter()
                        .map(|c| HostTensor::f32(&[w_len], c.v.clone()))
                        .collect(),
                };
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
                // Flat per-stage file: always the latest state (what
                // `resume_from` and the hot-swap migration read) — then
                // an archived generation, pruned to `keep_last`.
                checkpoint::save(stage_ckpt_path(dir, ctx.stage), &metas, &state)?;
                let gen = gen_dir(dir, state.step);
                std::fs::create_dir_all(&gen)
                    .with_context(|| format!("creating checkpoint dir {gen:?}"))?;
                checkpoint::save(stage_ckpt_path(&gen, ctx.stage), &metas, &state)?;
                prune_generations(dir, ctx.s_n, ctx.keep_last);
            }
        }
    }

    if ctx.dp_rank == 0 {
        let mut all = Vec::with_capacity(v * w_len);
        for c in &chunks {
            all.extend_from_slice(&c.w);
        }
        ctx.shared.params.lock().unwrap()[ctx.stage] = all;
    }

    // Record the slowest rank's virtual clock + comm-only time.
    let ns = (ep.now() * 1e9) as u64;
    ctx.shared.virtual_ns.fetch_max(ns, Ordering::Relaxed);
    let cns = (ep.wire_total() * 1e9) as u64;
    ctx.shared.comm_ns.fetch_max(cns, Ordering::Relaxed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommAlgo;
    use crate::costmodel::{GroupPlan, ModelShape, Schedule, Strategy};
    use crate::hetero::{ChipKind, Cluster};
    use crate::plan::PlanBuilder;

    fn tiny_model() -> ModelShape {
        ModelShape {
            n_layers: 8,
            hidden: 2048,
            n_heads: 16,
            n_kv_heads: 16,
            intermediate: 8192,
            vocab: 32000,
            seq_len: 4096,
            n_experts: 0,
            top_k: 0,
            expert_intermediate: 0,
        }
    }

    fn fixture(schedule: Schedule, comm_algo: CommAlgo) -> ExecutionPlan {
        // 2-stage mixed-vendor pipeline: Chip A (96 GiB, stage 0) then
        // Chip B (64 GiB, stage 1); TP 4, DP 4 — on Chip B only 2 of the
        // 4 replicas share a node, so the DP sync crosses nodes. This
        // mirrors `rust/tests/common.rs::two_stage_mixed_vendor_plan`
        // (the integration suites' shared fixture, unreachable from unit
        // tests); keep the two in sync.
        let model = tiny_model();
        let cluster =
            Cluster::new("virt-2stage", vec![(ChipKind::A, 16), (ChipKind::B, 16)]);
        PlanBuilder::new("virt-fixture")
            .model(model)
            .cluster(cluster)
            .strategy(Strategy {
                s_ep: 1,
                s_dp: 4,
                micro_batches: 8,
                schedule,
                comm_algo,
                plans: vec![
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: false },
                    GroupPlan { s_pp: 1, s_tp: 4, layers: 4, recompute: true },
                ],
            })
            .gbs_tokens(4 * 8 * 4096)
            .build()
            .unwrap()
    }

    #[test]
    fn virtual_run_is_deterministic() {
        let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
        let opts = VirtualOptions { steps: 3, ..Default::default() };
        let a = train_virtual(&plan, &opts).unwrap();
        let b = train_virtual(&plan, &opts).unwrap();
        assert_eq!(a.losses, b.losses);
        assert_eq!(a.final_params, b.final_params);
        assert!(a.step_seconds > 0.0 && a.step_seconds.is_finite());
        assert!(a.comm_seconds > 0.0);
        // The synthetic model actually trains (params move, loss moves).
        assert!(a.losses.windows(2).any(|w| w[0] != w[1]), "{:?}", a.losses);
    }

    #[test]
    fn every_schedule_executes_virtually() {
        for schedule in Schedule::SEARCH_SPACE {
            let plan = fixture(schedule, CommAlgo::Auto);
            let opts = VirtualOptions { steps: 2, ..Default::default() };
            let r = train_virtual(&plan, &opts).unwrap();
            assert_eq!(r.losses.len(), 2, "{schedule}");
            assert!(r.losses.iter().all(|l| l.is_finite()), "{schedule}");
            assert!(r.step_seconds > 0.0, "{schedule}");
            assert_eq!(r.final_params.len(), 2, "{schedule}");
        }
    }

    #[test]
    fn checkpoint_resume_reproduces_the_uninterrupted_run() {
        // Under both the interleaved and zero-bubble schedules, a run
        // checkpointed at step 3 and resumed must replay steps 3..6 with
        // a bit-identical loss trajectory and final parameters.
        for schedule in [Schedule::Interleaved { virtual_stages: 2 }, Schedule::ZeroBubbleV] {
            let plan = fixture(schedule, CommAlgo::Hierarchical);
            let dir = std::env::temp_dir()
                .join("h2_virt_ckpt")
                .join(schedule.token().replace(':', "_"));
            let _ = std::fs::remove_dir_all(&dir);
            std::fs::create_dir_all(&dir).unwrap();

            let full = train_virtual(
                &plan,
                &VirtualOptions { steps: 6, ..Default::default() },
            )
            .unwrap();

            let first = train_virtual(
                &plan,
                &VirtualOptions {
                    steps: 3,
                    checkpoint_dir: Some(dir.clone()),
                    checkpoint_every: 3,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(first.losses, full.losses[..3], "{schedule}: pre-resume drifted");

            let resumed = train_virtual(
                &plan,
                &VirtualOptions {
                    steps: 6,
                    resume_from: Some(dir.clone()),
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(resumed.start_step, 3, "{schedule}");
            assert_eq!(resumed.losses, full.losses[3..], "{schedule}: resume drifted");
            for (a, b) in resumed.final_params.iter().zip(&full.final_params) {
                assert_eq!(a, b, "{schedule}: final params drifted");
            }
        }
    }

    #[test]
    fn faults_scale_time_but_never_numerics() {
        use crate::elastic::fault::{FaultEvent, FaultKind, FaultPlan};
        let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
        let opts = VirtualOptions { steps: 3, ..Default::default() };
        let healthy = train_virtual(&plan, &opts).unwrap();
        let faults = FaultPlan {
            seed: 1,
            events: vec![
                FaultEvent { step: 0, stage: 1, kind: FaultKind::Slowdown { factor: 2.0 } },
                FaultEvent { step: 0, stage: 0, kind: FaultKind::NicDegrade { factor: 3.0 } },
            ],
        };
        let faulty = train_virtual(
            &plan,
            &VirtualOptions { faults: Some(faults), ..opts.clone() },
        )
        .unwrap();
        assert_eq!(faulty.losses, healthy.losses, "faults must not touch numerics");
        assert_eq!(faulty.final_params, healthy.final_params);
        assert!(faulty.virtual_seconds > healthy.virtual_seconds);
        assert_eq!(faulty.halted_at, None);
        // The slowdown shows up in the heartbeat stream at exactly ×2 on
        // the faulty stage and ×1 on the healthy one.
        for step in 0..3 {
            let r1 = faulty.stage_compute_seconds[1][step] / healthy.stage_compute_seconds[1][step];
            let r0 = faulty.stage_compute_seconds[0][step] / healthy.stage_compute_seconds[0][step];
            assert!((r1 - 2.0).abs() < 1e-9, "stage 1 step {step}: {r1}");
            assert!((r0 - 1.0).abs() < 1e-9, "stage 0 step {step}: {r0}");
        }
    }

    #[test]
    fn chip_death_drains_at_the_step_boundary() {
        use crate::elastic::fault::{FaultEvent, FaultKind, FaultPlan};
        let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
        let healthy = train_virtual(
            &plan,
            &VirtualOptions { steps: 5, ..Default::default() },
        )
        .unwrap();
        let faults = FaultPlan {
            seed: 2,
            events: vec![FaultEvent {
                step: 3,
                stage: 1,
                kind: FaultKind::ChipDeath { nodes: 1 },
            }],
        };
        let halted = train_virtual(
            &plan,
            &VirtualOptions { steps: 5, faults: Some(faults), ..Default::default() },
        )
        .unwrap();
        assert_eq!(halted.halted_at, Some(3));
        assert_eq!(halted.losses, healthy.losses[..3], "pre-death steps must match");
    }

    #[test]
    fn keep_last_prunes_old_generations_but_never_the_newest() {
        let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
        let dir = std::env::temp_dir().join("h2_virt_keep_last");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let full = train_virtual(
            &plan,
            &VirtualOptions { steps: 6, ..Default::default() },
        )
        .unwrap();
        let pruned = train_virtual(
            &plan,
            &VirtualOptions {
                steps: 6,
                checkpoint_dir: Some(dir.clone()),
                checkpoint_every: 1,
                keep_last: 2,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(pruned.losses, full.losses);
        // Generations 1..=6 were written; only the newest two survive.
        for step in 1..=4u64 {
            assert!(!gen_dir(&dir, step).exists(), "step{step} should be pruned");
        }
        for step in 5..=6u64 {
            for stage in 0..2 {
                assert!(
                    stage_ckpt_path(&gen_dir(&dir, step), stage).exists(),
                    "step{step}/stage{stage} must survive"
                );
            }
        }
        // The flat files still hold the latest state and resume cleanly.
        let resumed = train_virtual(
            &plan,
            &VirtualOptions { steps: 8, resume_from: Some(dir.clone()), ..Default::default() },
        )
        .unwrap();
        assert_eq!(resumed.start_step, 6);

        // Default keep-all is preserved: no pruning without keep_last.
        let dir_all = std::env::temp_dir().join("h2_virt_keep_all");
        let _ = std::fs::remove_dir_all(&dir_all);
        std::fs::create_dir_all(&dir_all).unwrap();
        train_virtual(
            &plan,
            &VirtualOptions {
                steps: 4,
                checkpoint_dir: Some(dir_all.clone()),
                checkpoint_every: 1,
                ..Default::default()
            },
        )
        .unwrap();
        for step in 1..=4u64 {
            assert!(gen_dir(&dir_all, step).exists(), "keep-all must keep step{step}");
        }
    }

    #[test]
    fn corrupt_flat_checkpoint_falls_back_to_previous_generation() {
        let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
        let dir = std::env::temp_dir().join("h2_virt_ckpt_fallback");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let full = train_virtual(
            &plan,
            &VirtualOptions { steps: 8, ..Default::default() },
        )
        .unwrap();
        train_virtual(
            &plan,
            &VirtualOptions {
                steps: 6,
                checkpoint_dir: Some(dir.clone()),
                checkpoint_every: 2,
                keep_last: 2,
                ..Default::default()
            },
        )
        .unwrap();
        // Flip one payload byte in every step-6 copy of stage 0 — the
        // flat file and the archived generation — so the only intact
        // checkpoint is the step-4 generation kept by `keep_last`.
        for p in [stage_ckpt_path(&dir, 0), stage_ckpt_path(&gen_dir(&dir, 6), 0)] {
            let mut bytes = std::fs::read(&p).unwrap();
            let i = bytes.len() - 16;
            bytes[i] ^= 0xFF;
            std::fs::write(&p, &bytes).unwrap();
        }
        let resumed = train_virtual(
            &plan,
            &VirtualOptions { steps: 8, resume_from: Some(dir.clone()), ..Default::default() },
        )
        .unwrap();
        assert_eq!(resumed.start_step, 4, "must fall back to the step-4 generation");
        assert_eq!(resumed.losses, full.losses[4..], "fallback resume drifted");
        for (a, b) in resumed.final_params.iter().zip(&full.final_params) {
            assert_eq!(a, b, "fallback final params drifted");
        }
    }

    #[test]
    fn resume_past_the_end_is_rejected() {
        let plan = fixture(Schedule::OneF1B, CommAlgo::Ring);
        let dir = std::env::temp_dir().join("h2_virt_ckpt_end");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        train_virtual(
            &plan,
            &VirtualOptions {
                steps: 2,
                checkpoint_dir: Some(dir.clone()),
                checkpoint_every: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let err = train_virtual(
            &plan,
            &VirtualOptions { steps: 2, resume_from: Some(dir), ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("nothing left"), "{err}");
    }
}
