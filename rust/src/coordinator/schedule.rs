//! 1F1B micro-batch issue order (shared by the coordinator's stage workers;
//! mirrors the simulator's schedule so real runs and simulated runs execute
//! the same op sequence).

/// One operation in a stage's static 1F1B schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Forward of micro-batch `m`.
    Fwd(usize),
    /// Backward of micro-batch `m`.
    Bwd(usize),
}

/// The classic 1F1B order for `stage` of `n_stages` with `b` micro-batches:
/// `min(n_stages - stage, b)` warm-up forwards, then alternating
/// backward/forward, then the drain of remaining backwards.
pub fn one_f1b_order(stage: usize, n_stages: usize, b: usize) -> Vec<Op> {
    let warm = (n_stages - stage).min(b);
    let mut q = Vec::with_capacity(2 * b);
    for m in 0..warm {
        q.push(Op::Fwd(m));
    }
    let mut next_f = warm;
    let mut next_b = 0;
    while next_f < b {
        q.push(Op::Bwd(next_b));
        next_b += 1;
        q.push(Op::Fwd(next_f));
        next_f += 1;
    }
    while next_b < b {
        q.push(Op::Bwd(next_b));
        next_b += 1;
    }
    q
}

/// Peak number of in-flight micro-batches at `stage` under this schedule
/// (the memory model's warm-up depth).
pub fn in_flight(stage: usize, n_stages: usize, b: usize) -> usize {
    (n_stages - stage).min(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn each_micro_forward_and_backward_once() {
        prop::check(50, |rng| {
            let s_n = rng.usize(1, 8);
            let b = rng.usize(1, 20);
            let stage = rng.usize(0, s_n);
            let q = one_f1b_order(stage, s_n, b);
            let fwds: Vec<usize> = q.iter().filter_map(|o| match o {
                Op::Fwd(m) => Some(*m), _ => None }).collect();
            let bwds: Vec<usize> = q.iter().filter_map(|o| match o {
                Op::Bwd(m) => Some(*m), _ => None }).collect();
            prop::assert_prop(fwds == (0..b).collect::<Vec<_>>(), "fwd order")?;
            prop::assert_prop(bwds == (0..b).collect::<Vec<_>>(), "bwd order")?;
            Ok(())
        });
    }

    #[test]
    fn bwd_never_precedes_own_fwd() {
        prop::check(50, |rng| {
            let s_n = rng.usize(1, 8);
            let b = rng.usize(1, 20);
            let stage = rng.usize(0, s_n);
            let q = one_f1b_order(stage, s_n, b);
            let mut fwd_seen = vec![false; b];
            for op in q {
                match op {
                    Op::Fwd(m) => fwd_seen[m] = true,
                    Op::Bwd(m) => prop::assert_prop(fwd_seen[m], "bwd before fwd")?,
                }
            }
            Ok(())
        });
    }

    #[test]
    fn in_flight_bound_holds() {
        // The schedule never holds more than in_flight() forward activations.
        prop::check(50, |rng| {
            let s_n = rng.usize(1, 8);
            let b = rng.usize(1, 20);
            let stage = rng.usize(0, s_n);
            let q = one_f1b_order(stage, s_n, b);
            let mut live = 0usize;
            let mut peak = 0usize;
            for op in q {
                match op {
                    Op::Fwd(_) => { live += 1; peak = peak.max(live); }
                    Op::Bwd(_) => { live -= 1; }
                }
            }
            prop::assert_prop(peak == in_flight(stage, s_n, b),
                              format!("peak {peak} != {}", in_flight(stage, s_n, b)))
        });
    }

    #[test]
    fn last_stage_strictly_alternates() {
        let q = one_f1b_order(3, 4, 4);
        assert_eq!(q, vec![Op::Fwd(0), Op::Bwd(0), Op::Fwd(1), Op::Bwd(1),
                           Op::Fwd(2), Op::Bwd(2), Op::Fwd(3), Op::Bwd(3)]);
    }
}
