//! Pipeline issue orders shared by the simulator and the training
//! coordinator.
//!
//! Every schedule the crate knows ([`Schedule`]) has exactly one order
//! generator here, and both evaluators consume it: the discrete-event
//! simulator replays the orders with modeled durations, the real and
//! virtual coordinators execute them over the DiComm fabric. Because the
//! generators live in one module, the simulator and the coordinator cannot
//! drift apart — a plan's `strategy.schedule` means the same op sequence
//! to every evaluator.
//!
//! * 1F1B: the classic static per-stage queue ([`one_f1b_order`]).
//! * Interleaved: per-physical-stage queues derived from a unit-duration
//!   1F1B run of the virtual pipeline ([`interleaved_orders`]), which is
//!   deadlock-free by construction.
//! * Zero-bubble: the greedy B/F/W executor ([`zero_bubble_events`]);
//!   [`zero_bubble_orders`] freezes its unit-duration decisions into
//!   static per-stage queues for the coordinator.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::costmodel::Schedule;

/// One operation in a stage's static 1F1B schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Forward of micro-batch `m`.
    Fwd(usize),
    /// Backward of micro-batch `m`.
    Bwd(usize),
}

/// One operation in a stage's static pipeline schedule, for any schedule:
/// `chunk` is the virtual-stage index within the physical stage (always 0
/// outside interleaved schedules), and the zero-bubble schedule splits
/// backward into [`PipeOp::Bwd`] (input-gradient phase) plus
/// [`PipeOp::BwdWeight`] (weight-gradient phase).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeOp {
    /// Forward of micro-batch `micro` on virtual chunk `chunk`.
    Fwd {
        /// Virtual chunk within the physical stage (interleaving).
        chunk: usize,
        /// Micro-batch index.
        micro: usize,
    },
    /// Backward of micro-batch `micro` on virtual chunk `chunk` — the full
    /// backward for 1F1B/interleaved, the input-gradient phase under the
    /// zero-bubble schedule.
    Bwd {
        /// Virtual chunk within the physical stage (interleaving).
        chunk: usize,
        /// Micro-batch index.
        micro: usize,
    },
    /// Zero-bubble weight-gradient phase of micro-batch `micro` (local
    /// work scheduled into what would otherwise be bubble time).
    BwdWeight {
        /// Virtual chunk within the physical stage (always 0 today).
        chunk: usize,
        /// Micro-batch index.
        micro: usize,
    },
}

/// The classic 1F1B order for `stage` of `n_stages` with `b` micro-batches:
/// `min(n_stages - stage, b)` warm-up forwards, then alternating
/// backward/forward, then the drain of remaining backwards.
pub fn one_f1b_order(stage: usize, n_stages: usize, b: usize) -> Vec<Op> {
    let warm = (n_stages - stage).min(b);
    let mut q = Vec::with_capacity(2 * b);
    for m in 0..warm {
        q.push(Op::Fwd(m));
    }
    let mut next_f = warm;
    let mut next_b = 0;
    while next_f < b {
        q.push(Op::Bwd(next_b));
        next_b += 1;
        q.push(Op::Fwd(next_f));
        next_f += 1;
    }
    while next_b < b {
        q.push(Op::Bwd(next_b));
        next_b += 1;
    }
    q
}

/// [`one_f1b_order`] lifted into the schedule-generic [`PipeOp`] currency
/// (chunk 0 everywhere — plain 1F1B has no virtual chunks).
pub fn one_f1b_pipe_order(stage: usize, n_stages: usize, b: usize) -> Vec<PipeOp> {
    one_f1b_order(stage, n_stages, b)
        .into_iter()
        .map(|op| match op {
            Op::Fwd(m) => PipeOp::Fwd { chunk: 0, micro: m },
            Op::Bwd(m) => PipeOp::Bwd { chunk: 0, micro: m },
        })
        .collect()
}

/// Peak number of in-flight micro-batches at `stage` under this schedule
/// (the memory model's warm-up depth).
pub fn in_flight(stage: usize, n_stages: usize, b: usize) -> usize {
    (n_stages - stage).min(b)
}

/// End times of every op in a unit-duration, zero-latency 1F1B run over
/// `s_n` stages — the canonical order the interleaved executor derives its
/// per-physical-stage queues from. Returns `(fwd_end, bwd_end)` indexed
/// `[m][stage]`.
///
/// Sorting each physical executor's ops by these end times yields a
/// deadlock-free real schedule: dependency edges strictly increase the
/// unit end time (every op takes one unit), and executor-order edges never
/// decrease it, so the union of both edge sets is acyclic.
pub fn unit_1f1b_end_times(s_n: usize, b: usize) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    // The 1F1B list scheduler with unit durations and zero link latency,
    // over the same per-stage queues as the real simulator/coordinator,
    // recording end times (cheap: 2·b·s_n unit ops).
    const UNSET: f64 = -1.0;
    let mut fwd_done = vec![vec![UNSET; s_n]; b];
    let mut bwd_done = vec![vec![UNSET; s_n]; b];
    let queues: Vec<Vec<Op>> = (0..s_n).map(|s| one_f1b_order(s, s_n, b)).collect();
    let mut head = vec![0usize; s_n];
    let mut clock = vec![0.0f64; s_n];
    let mut progressed = true;
    while progressed {
        progressed = false;
        for s in 0..s_n {
            while head[s] < queues[s].len() {
                let op = queues[s][head[s]];
                let ready = match op {
                    Op::Fwd(m) => {
                        if s == 0 {
                            Some(0.0)
                        } else if fwd_done[m][s - 1] >= 0.0 {
                            Some(fwd_done[m][s - 1])
                        } else {
                            None
                        }
                    }
                    Op::Bwd(m) => {
                        if fwd_done[m][s] < 0.0 {
                            None
                        } else if s == s_n - 1 {
                            Some(fwd_done[m][s])
                        } else if bwd_done[m][s + 1] >= 0.0 {
                            Some(bwd_done[m][s + 1])
                        } else {
                            None
                        }
                    }
                };
                let Some(ready) = ready else { break };
                let end = clock[s].max(ready) + 1.0;
                clock[s] = end;
                match op {
                    Op::Fwd(m) => fwd_done[m][s] = end,
                    Op::Bwd(m) => bwd_done[m][s] = end,
                }
                head[s] += 1;
                progressed = true;
            }
        }
    }
    debug_assert!(head.iter().zip(&queues).all(|(h, q)| *h == q.len()),
                  "unit 1F1B pre-pass deadlocked");
    (fwd_done, bwd_done)
}

/// Per-physical-stage issue orders of the interleaved schedule: virtual
/// stage `d` of the `s_n·v`-deep virtual pipeline runs on physical stage
/// `d % s_n` as chunk `d / s_n`; each physical executor's ops are merged
/// by their end time in a unit-duration 1F1B run of the virtual pipeline
/// ([`unit_1f1b_end_times`]), which is deadlock-free by construction.
/// `v <= 1` degenerates to plain 1F1B.
pub fn interleaved_orders(s_n: usize, v: usize, b: usize) -> Vec<Vec<PipeOp>> {
    if v <= 1 || s_n == 0 {
        return (0..s_n).map(|s| one_f1b_pipe_order(s, s_n, b)).collect();
    }
    let d_n = s_n * v;
    let (unit_f, unit_b) = unit_1f1b_end_times(d_n, b);
    struct VOp {
        end: f64,
        d: usize,
        m: usize,
        fwd: bool,
    }
    let mut queues: Vec<Vec<VOp>> = (0..s_n).map(|_| Vec::with_capacity(2 * b * v)).collect();
    for d in 0..d_n {
        let s = d % s_n;
        for m in 0..b {
            queues[s].push(VOp { end: unit_f[m][d], d, m, fwd: true });
            queues[s].push(VOp { end: unit_b[m][d], d, m, fwd: false });
        }
    }
    queues
        .into_iter()
        .map(|mut q| {
            // (end, d) is unique within an executor: ops of one virtual
            // stage serialize on its unit clock, distinct virtual stages
            // differ in d.
            q.sort_by(|a, b| a.end.total_cmp(&b.end).then(a.d.cmp(&b.d)));
            q.into_iter()
                .map(|o| {
                    let chunk = o.d / s_n;
                    if o.fwd {
                        PipeOp::Fwd { chunk, micro: o.m }
                    } else {
                        PipeOp::Bwd { chunk, micro: o.m }
                    }
                })
                .collect()
        })
        .collect()
}

/// Per-stage timing inputs of the zero-bubble greedy scheduler.
#[derive(Clone, Copy, Debug)]
pub struct ZbStage {
    /// Forward seconds per micro-batch.
    pub t_fwd: f64,
    /// Input-gradient backward phase seconds (the inter-stage critical
    /// path; includes any activation recompute that must precede it).
    pub t_bwd_input: f64,
    /// Weight-gradient backward phase seconds (local bubble filler).
    pub t_bwd_weight: f64,
}

/// One scheduled op of the zero-bubble greedy executor.
#[derive(Clone, Copy, Debug)]
pub struct ZbEvent {
    /// Physical stage the op ran on.
    pub stage: usize,
    /// The op ([`PipeOp::Bwd`] is the input-gradient phase).
    pub op: PipeOp,
    /// When the op's inputs were available.
    pub ready: f64,
    /// When the op started (stage busy-until ∨ ready).
    pub start: f64,
    /// When the op finished.
    pub end: f64,
    /// Stage idle time attributable to the op's inbound hop (exposed
    /// communication).
    pub wait_comm: f64,
}

/// Zero-bubble schedule: backward split into an input-gradient phase `B`
/// (on the inter-stage critical path) and a weight-gradient phase `W`
/// (local, deferred into what would otherwise be bubble time).
///
/// A greedy discrete-event scheduler executes, globally earliest first,
/// the per-stage candidate ops under 1F1B's warm-up cap (so activation
/// memory stays within the 1F1B envelope, as ZB-V guarantees): `B` when
/// its downstream input gradient has arrived, `F` while the warm-up cap
/// allows, and `W` whenever the stage would otherwise idle. Ties prefer
/// `B` over `F` over `W`, then the lower stage index — fully
/// deterministic. `link[s]` is the hop time between stages `s` and `s+1`.
///
/// Returns the full event list in execution order; the simulator folds it
/// into clocks, the coordinator freezes the unit-duration variant into
/// static orders ([`zero_bubble_orders`]). A thin wrapper over
/// [`ZbRunner`], which hot callers (the arena engine) hold and re-run
/// without reallocating.
pub fn zero_bubble_events(stages: &[ZbStage], link: &[f64], b: usize) -> Vec<ZbEvent> {
    let mut runner = ZbRunner::new(stages.len(), b);
    runner.run(stages, link).to_vec()
}

/// One stage's current best candidate op in the [`ZbRunner`] heap, keyed
/// exactly like the reference scan's global pick: `(start, priority,
/// stage)`. `gen` is the stage's generation counter — it lazily
/// invalidates stale entries (only the entry whose `gen` matches the
/// stage's current counter is live) and never orders live entries, since
/// each stage has at most one.
#[derive(Clone, Copy, Debug)]
struct ZbCand {
    start: f64,
    prio: u8,
    stage: usize,
    gen: u64,
    ready: f64,
}

impl PartialEq for ZbCand {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for ZbCand {}

impl PartialOrd for ZbCand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ZbCand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // `ready` is derived from (stage, gen) state, not part of the key.
        self.start
            .total_cmp(&other.start)
            .then(self.prio.cmp(&other.prio))
            .then(self.stage.cmp(&other.stage))
            .then(self.gen.cmp(&other.gen))
    }
}

/// Reusable zero-bubble greedy executor over pre-sized flat arenas.
///
/// Replaces the original `O(ops × stages)` rescan-everything loop with a
/// binary heap of per-stage best candidates under lazy invalidation:
/// executing an op on stage `s` only refreshes the stages whose candidate
/// inputs it touched (`B` → `{s−1, s}`, `F` → `{s, s+1}`, `W` → `{s}`),
/// bumping their generation counters so stale heap entries are skipped on
/// pop. Because every live entry's key equals its stage's current
/// candidate and ties are broken `(start, priority, stage)` exactly as
/// the scan did, the event stream is bit-identical to the original
/// executor (pinned by `heap_greedy_matches_the_reference_scan` and the
/// `sim_differential` suite).
///
/// All state lives in flat `micro × stage` arenas sized once in
/// [`ZbRunner::new`]; [`ZbRunner::run`] re-runs without allocating beyond
/// incidental heap growth on the first call.
#[derive(Clone, Debug)]
pub struct ZbRunner {
    s_n: usize,
    b: usize,
    /// Forward end times, `[micro * s_n + stage]` (−1 = not executed).
    fwd_done: Vec<f64>,
    /// Input-gradient-phase end times, same layout.
    bwd_done: Vec<f64>,
    next_f: Vec<usize>,
    next_b: Vec<usize>,
    next_w: Vec<usize>,
    cap: Vec<usize>,
    clock: Vec<f64>,
    gen: Vec<u64>,
    heap: BinaryHeap<Reverse<ZbCand>>,
    events: Vec<ZbEvent>,
}

impl ZbRunner {
    /// Size the arenas for a `s_n`-stage pipeline with `b` micro-batches.
    pub fn new(s_n: usize, b: usize) -> ZbRunner {
        ZbRunner {
            s_n,
            b,
            fwd_done: vec![0.0; s_n * b],
            bwd_done: vec![0.0; s_n * b],
            next_f: vec![0; s_n],
            next_b: vec![0; s_n],
            next_w: vec![0; s_n],
            cap: (0..s_n).map(|s| (s_n - s).min(b).max(1)).collect(),
            clock: vec![0.0; s_n],
            gen: vec![0; s_n],
            heap: BinaryHeap::with_capacity(2 * s_n + 1),
            events: Vec::with_capacity(3 * b * s_n),
        }
    }

    /// Stage `s`'s best candidate `(start, priority, ready)` — the
    /// reference scan's per-stage `consider` calls (B, then F, then W,
    /// strict `<` on `(start, priority)`), verbatim.
    fn candidate(&self, s: usize, link: &[f64]) -> Option<(f64, u8, f64)> {
        let (s_n, b) = (self.s_n, self.b);
        let mut best: Option<(f64, u8, f64)> = None;
        let mut consider = |start: f64, prio: u8, ready: f64| {
            let better = match &best {
                None => true,
                Some((bs, bp, _)) => (start, prio) < (*bs, *bp),
            };
            if better {
                best = Some((start, prio, ready));
            }
        };
        if self.next_b[s] < b {
            let m = self.next_b[s];
            if self.fwd_done[m * s_n + s] >= 0.0 {
                let ready = if s == s_n - 1 {
                    Some(self.fwd_done[m * s_n + s])
                } else if self.bwd_done[m * s_n + s + 1] >= 0.0 {
                    Some(self.bwd_done[m * s_n + s + 1] + link[s])
                } else {
                    None
                };
                if let Some(r) = ready {
                    consider(self.clock[s].max(r), 0, r);
                }
            }
        }
        if self.next_f[s] < b && self.next_f[s] - self.next_b[s] < self.cap[s] {
            let m = self.next_f[s];
            let ready = if s == 0 {
                Some(0.0)
            } else if self.fwd_done[m * s_n + s - 1] >= 0.0 {
                Some(self.fwd_done[m * s_n + s - 1] + link[s - 1])
            } else {
                None
            };
            if let Some(r) = ready {
                consider(self.clock[s].max(r), 1, r);
            }
        }
        if self.next_w[s] < self.next_b[s] {
            consider(self.clock[s], 2, self.clock[s]);
        }
        best
    }

    /// Invalidate stage `s`'s heap entry and push its fresh candidate.
    fn refresh(&mut self, s: usize, link: &[f64]) {
        self.gen[s] += 1;
        if let Some((start, prio, ready)) = self.candidate(s, link) {
            let gen = self.gen[s];
            self.heap.push(Reverse(ZbCand { start, prio, stage: s, gen, ready }));
        }
    }

    /// Run the greedy schedule over real durations; returns the event list
    /// in execution order (borrowed from the runner's arena — it is
    /// overwritten by the next call).
    pub fn run(&mut self, stages: &[ZbStage], link: &[f64]) -> &[ZbEvent] {
        let (s_n, b) = (self.s_n, self.b);
        assert_eq!(stages.len(), s_n, "stage count changed under the runner");
        self.events.clear();
        if s_n == 0 || b == 0 {
            return &self.events;
        }
        const UNSET: f64 = -1.0;
        self.fwd_done.fill(UNSET);
        self.bwd_done.fill(UNSET);
        self.next_f.fill(0);
        self.next_b.fill(0);
        self.next_w.fill(0);
        self.clock.fill(0.0);
        self.gen.fill(0);
        self.heap.clear();
        for s in 0..s_n {
            if let Some((start, prio, ready)) = self.candidate(s, link) {
                self.heap.push(Reverse(ZbCand { start, prio, stage: s, gen: 0, ready }));
            }
        }

        // Op kinds by tie-break priority: B (0) > F (1) > W (2).
        let total_ops = 3 * b * s_n;
        for _ in 0..total_ops {
            let cand = loop {
                let Reverse(c) = self.heap.pop().expect("zero-bubble schedule deadlocked");
                if c.gen == self.gen[c.stage] {
                    break c;
                }
            };
            let (s, prio, start, ready) = (cand.stage, cand.prio, cand.start, cand.ready);
            let dur = match prio {
                0 => stages[s].t_bwd_input,
                1 => stages[s].t_fwd,
                _ => stages[s].t_bwd_weight,
            };
            // Exposed comm: the wait attributable to the inbound hop.
            let wait_comm = if prio < 2 {
                let hop = match prio {
                    0 if s < s_n - 1 => link[s],
                    1 if s > 0 => link[s - 1],
                    _ => 0.0,
                };
                (ready - self.clock[s]).max(0.0).min(hop)
            } else {
                0.0
            };
            let end = start + dur;
            self.clock[s] = end;
            let op = match prio {
                0 => {
                    let m = self.next_b[s];
                    self.bwd_done[m * s_n + s] = end;
                    self.next_b[s] += 1;
                    PipeOp::Bwd { chunk: 0, micro: m }
                }
                1 => {
                    let m = self.next_f[s];
                    self.fwd_done[m * s_n + s] = end;
                    self.next_f[s] += 1;
                    PipeOp::Fwd { chunk: 0, micro: m }
                }
                _ => {
                    let m = self.next_w[s];
                    self.next_w[s] += 1;
                    PipeOp::BwdWeight { chunk: 0, micro: m }
                }
            };
            self.events.push(ZbEvent { stage: s, op, ready, start, end, wait_comm });
            // Refresh every stage whose candidate inputs this op touched.
            match prio {
                0 => {
                    if s > 0 {
                        self.refresh(s - 1, link);
                    }
                    self.refresh(s, link);
                }
                1 => {
                    self.refresh(s, link);
                    if s + 1 < s_n {
                        self.refresh(s + 1, link);
                    }
                }
                _ => self.refresh(s, link),
            }
        }
        &self.events
    }
}

/// Static per-stage zero-bubble orders: the greedy executor's decisions
/// under unit durations and zero link latency, frozen into queues the
/// coordinator executes. Deadlock-free under arbitrary real durations by
/// the same argument as [`unit_1f1b_end_times`]: dependency edges strictly
/// increase the unit end time, executor-order edges never decrease it.
pub fn zero_bubble_orders(s_n: usize, b: usize) -> Vec<Vec<PipeOp>> {
    let unit = vec![ZbStage { t_fwd: 1.0, t_bwd_input: 1.0, t_bwd_weight: 1.0 }; s_n];
    let link = vec![0.0f64; s_n.saturating_sub(1)];
    let mut orders: Vec<Vec<PipeOp>> =
        (0..s_n).map(|_| Vec::with_capacity(3 * b)).collect();
    for e in zero_bubble_events(&unit, &link, b) {
        orders[e.stage].push(e.op);
    }
    orders
}

/// The per-stage issue orders of `schedule` over `s_n` physical stages and
/// `b` micro-batches — the single entry point the simulator and both
/// coordinators (real and virtual) derive their op sequences from.
pub fn stage_orders(schedule: Schedule, s_n: usize, b: usize) -> Vec<Vec<PipeOp>> {
    match schedule {
        Schedule::OneF1B => (0..s_n).map(|s| one_f1b_pipe_order(s, s_n, b)).collect(),
        Schedule::Interleaved { virtual_stages } => {
            interleaved_orders(s_n, virtual_stages.max(1), b)
        }
        Schedule::ZeroBubbleV => zero_bubble_orders(s_n, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn each_micro_forward_and_backward_once() {
        prop::check(50, |rng| {
            let s_n = rng.usize(1, 8);
            let b = rng.usize(1, 20);
            let stage = rng.usize(0, s_n);
            let q = one_f1b_order(stage, s_n, b);
            let fwds: Vec<usize> = q.iter().filter_map(|o| match o {
                Op::Fwd(m) => Some(*m), _ => None }).collect();
            let bwds: Vec<usize> = q.iter().filter_map(|o| match o {
                Op::Bwd(m) => Some(*m), _ => None }).collect();
            prop::assert_prop(fwds == (0..b).collect::<Vec<_>>(), "fwd order")?;
            prop::assert_prop(bwds == (0..b).collect::<Vec<_>>(), "bwd order")?;
            Ok(())
        });
    }

    #[test]
    fn bwd_never_precedes_own_fwd() {
        prop::check(50, |rng| {
            let s_n = rng.usize(1, 8);
            let b = rng.usize(1, 20);
            let stage = rng.usize(0, s_n);
            let q = one_f1b_order(stage, s_n, b);
            let mut fwd_seen = vec![false; b];
            for op in q {
                match op {
                    Op::Fwd(m) => fwd_seen[m] = true,
                    Op::Bwd(m) => prop::assert_prop(fwd_seen[m], "bwd before fwd")?,
                }
            }
            Ok(())
        });
    }

    #[test]
    fn in_flight_bound_holds() {
        // The schedule never holds more than in_flight() forward activations.
        prop::check(50, |rng| {
            let s_n = rng.usize(1, 8);
            let b = rng.usize(1, 20);
            let stage = rng.usize(0, s_n);
            let q = one_f1b_order(stage, s_n, b);
            let mut live = 0usize;
            let mut peak = 0usize;
            for op in q {
                match op {
                    Op::Fwd(_) => { live += 1; peak = peak.max(live); }
                    Op::Bwd(_) => { live -= 1; }
                }
            }
            prop::assert_prop(peak == in_flight(stage, s_n, b),
                              format!("peak {peak} != {}", in_flight(stage, s_n, b)))
        });
    }

    #[test]
    fn last_stage_strictly_alternates() {
        let q = one_f1b_order(3, 4, 4);
        assert_eq!(q, vec![Op::Fwd(0), Op::Bwd(0), Op::Fwd(1), Op::Bwd(1),
                           Op::Fwd(2), Op::Bwd(2), Op::Fwd(3), Op::Bwd(3)]);
    }

    /// Every schedule's per-stage orders must be complete and
    /// dependency-consistent: each (chunk, micro) forwards exactly once
    /// and backwards exactly once per stage, and no backward precedes its
    /// own forward within a stage queue.
    #[test]
    fn stage_orders_are_complete_for_every_schedule() {
        use crate::costmodel::Schedule;
        prop::check(40, |rng| {
            let s_n = rng.usize(1, 6);
            let b = rng.usize(1, 12);
            let v = rng.usize(2, 5);
            for schedule in [
                Schedule::OneF1B,
                Schedule::Interleaved { virtual_stages: v },
                Schedule::ZeroBubbleV,
            ] {
                let chunks = schedule.virtual_stages();
                let orders = stage_orders(schedule, s_n, b);
                prop::assert_prop(orders.len() == s_n, "one order per stage")?;
                for (s, q) in orders.iter().enumerate() {
                    let mut fwd = vec![vec![false; b]; chunks];
                    let mut bwd = vec![vec![false; b]; chunks];
                    let mut w = vec![vec![false; b]; chunks];
                    for op in q {
                        match *op {
                            PipeOp::Fwd { chunk, micro } => {
                                prop::assert_prop(!fwd[chunk][micro], "fwd twice")?;
                                fwd[chunk][micro] = true;
                            }
                            PipeOp::Bwd { chunk, micro } => {
                                prop::assert_prop(
                                    fwd[chunk][micro],
                                    format!("{schedule}: bwd before fwd at stage {s}"),
                                )?;
                                prop::assert_prop(!bwd[chunk][micro], "bwd twice")?;
                                bwd[chunk][micro] = true;
                            }
                            PipeOp::BwdWeight { chunk, micro } => {
                                prop::assert_prop(
                                    bwd[chunk][micro],
                                    "weight phase before input phase",
                                )?;
                                prop::assert_prop(!w[chunk][micro], "w twice")?;
                                w[chunk][micro] = true;
                            }
                        }
                    }
                    let all_fwd = fwd.iter().all(|c| c.iter().all(|&x| x));
                    let all_bwd = bwd.iter().all(|c| c.iter().all(|&x| x));
                    prop::assert_prop(all_fwd && all_bwd,
                                      format!("{schedule}: incomplete at stage {s}"))?;
                    if schedule == Schedule::ZeroBubbleV {
                        prop::assert_prop(w.iter().all(|c| c.iter().all(|&x| x)),
                                          "missing weight phases")?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_bubble_orders_respect_the_warmup_cap() {
        // In-flight forwards (fwd issued minus input-phase backwards done)
        // never exceed the 1F1B warm-up depth — the ZB-V memory guarantee.
        prop::check(30, |rng| {
            let s_n = rng.usize(1, 6);
            let b = rng.usize(1, 12);
            for (s, q) in zero_bubble_orders(s_n, b).iter().enumerate() {
                let cap = (s_n - s).min(b).max(1);
                let mut live = 0i64;
                for op in q {
                    match op {
                        PipeOp::Fwd { .. } => {
                            live += 1;
                            prop::assert_prop(live as usize <= cap,
                                              format!("cap exceeded at stage {s}"))?;
                        }
                        PipeOp::Bwd { .. } => live -= 1,
                        PipeOp::BwdWeight { .. } => {}
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn interleaved_orders_degenerate_to_1f1b() {
        for s_n in 1..4 {
            for b in 1..5 {
                assert_eq!(interleaved_orders(s_n, 1, b),
                           stage_orders(Schedule::OneF1B, s_n, b));
            }
        }
    }

    #[test]
    fn heap_greedy_matches_the_reference_scan() {
        // The lazy-invalidation heap must reproduce the original
        // rescan-everything greedy bit-for-bit: same ops in the same
        // order with identical ready/start/end/wait_comm timestamps.
        prop::check(40, |rng| {
            let s_n = rng.usize(1, 7);
            let b = rng.usize(1, 14);
            let stages: Vec<ZbStage> = (0..s_n)
                .map(|_| ZbStage {
                    t_fwd: 0.5 + rng.f64(),
                    t_bwd_input: 0.5 + rng.f64(),
                    t_bwd_weight: 0.25 + rng.f64(),
                })
                .collect();
            let link: Vec<f64> = (0..s_n).map(|_| rng.f64() * 0.5).collect();
            let heap_events = zero_bubble_events(&stages, &link, b);
            let scan_events = crate::sim::reference::zb_events_scan(&stages, &link, b);
            prop::assert_prop(heap_events.len() == scan_events.len(), "event count")?;
            for (a, e) in heap_events.iter().zip(scan_events.iter()) {
                prop::assert_prop(a.stage == e.stage, "stage")?;
                prop::assert_prop(a.op == e.op, "op")?;
                prop::assert_prop(a.ready == e.ready, "ready")?;
                prop::assert_prop(a.start == e.start, "start")?;
                prop::assert_prop(a.end == e.end, "end")?;
                prop::assert_prop(a.wait_comm == e.wait_comm, "wait_comm")?;
            }
            Ok(())
        });
    }
}
