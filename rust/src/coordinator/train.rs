//! The HeteroPP training coordinator: leader + per-stage worker threads.
//!
//! Each (pipeline stage × DP replica) runs as a worker thread executing
//! the plan's pipeline schedule (1F1B or zero-bubble order; the
//! interleaved schedule needs per-chunk artifacts and runs on the virtual
//! evaluator instead) over AOT-compiled PJRT stage executables: forward
//! activations and backward gradients are real tensors moving through the
//! DiComm fabric (real bytes + modeled wire time), DP gradients are
//! summed by the DiComm collective engine under the configured
//! [`CommAlgo`] over the stage's chip-derived topology, and Adam updates
//! run through the exported `*_update` executables. Python is never on
//! this path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::{cross_node_time, fabric, CommAlgo, CommMode, CommTopology, Endpoint};
use crate::costmodel::profile::DP_OVERLAP;
use crate::costmodel::Schedule;
use crate::hetero::{spec, ChipKind};
use crate::precision::Perturbation;
use crate::runtime::{Executable, HostTensor, Runtime};
use crate::sim::FINE_OVERLAP_HIDDEN;
use crate::topology::NicAssignment;

use super::data::Corpus;
use super::dpgroup::DpGroup;
use super::params::{accumulate, flatten, init_params, unflatten, zeros_like};
use super::schedule::{stage_orders, PipeOp};

/// PJRT executables are thread-safe for concurrent execution (the TFRT CPU
/// client serializes internally as needed); the raw pointers inside the
/// `xla` crate types make them `!Send` by default, so the coordinator wraps
/// them. See DESIGN.md §Runtime.
struct SharedExe(Arc<Executable>);
unsafe impl Send for SharedExe {}
unsafe impl Sync for SharedExe {}

/// One pipeline stage of the training plan.
#[derive(Clone, Debug, PartialEq)]
pub struct StagePlan {
    /// Artifact prefix, e.g. `first_l8` (expects `{prefix}_fwd` etc.).
    pub prefix: String,
    /// Chip type this stage is mapped to (drives comm modeling + precision).
    pub chip: ChipKind,
}

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Artifact model name (resolved via the manifest).
    pub model: String,
    /// Pipeline stages in order (first → last).
    pub stages: Vec<StagePlan>,
    /// Data-parallel replica count.
    pub dp: usize,
    /// Micro-batches per pipeline per step.
    pub micro_batches: usize,
    /// Training steps to run.
    pub steps: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Parameter-init and data seed.
    pub seed: u64,
    /// Pipeline schedule the workers execute (the plan's
    /// `strategy.schedule`). 1F1B and zero-bubble run on the real
    /// executables; the interleaved schedule needs one artifact per
    /// virtual chunk and is executed by the virtual evaluator
    /// ([`crate::coordinator::train_virtual`]).
    pub schedule: Schedule,
    /// DP gradient-sync collective algorithm (the plan's
    /// `strategy.comm_algo`), dispatched through the DiComm engine.
    pub comm_algo: CommAlgo,
    /// Cross-node communication strategy for the modeled wire time.
    pub comm: CommMode,
    /// NIC selection policy.
    pub nic_assignment: NicAssignment,
    /// Fine-grained P2P/compute overlap (§5) enabled.
    pub fine_overlap: bool,
    /// Inject per-chip operator noise (the Fig 5 vendor-stack model).
    pub perturb: bool,
    /// Print a loss line every N steps (0 = silent).
    pub log_every: usize,
}

impl TrainConfig {
    /// A short smoke-test run with sensible defaults.
    pub fn quick(model: &str, stages: Vec<StagePlan>, dp: usize, micros: usize,
                 steps: usize) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            stages,
            dp,
            micro_batches: micros,
            steps,
            lr: 1e-3,
            seed: 42,
            schedule: Schedule::OneF1B,
            comm_algo: CommAlgo::Ring,
            comm: CommMode::DeviceDirect,
            nic_assignment: NicAssignment::Affinity,
            fine_overlap: true,
            perturb: false,
            log_every: 10,
        }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Mean loss per step (averaged over micro-batches and DP replicas).
    pub losses: Vec<f64>,
    /// Wall-clock seconds for the whole run.
    pub wall_seconds: f64,
    /// Modeled (virtual) seconds accumulated on the slowest rank.
    pub virtual_seconds: f64,
    /// Modeled communication-only seconds on the most-charged rank.
    pub virtual_comm_seconds: f64,
    /// Tokens processed per step.
    pub tokens_per_step: usize,
    /// Tokens per second (wall clock).
    pub tokens_per_second: f64,
}

struct WorkerShared {
    losses: Mutex<Vec<f64>>,
    virtual_ns: AtomicU64,
    comm_ns: AtomicU64,
}

/// Run a serialized [`crate::plan::ExecutionPlan`]'s train section — the
/// plan-centric entry point. The plan's schedule, DP-collective
/// algorithm, comm mode, NIC assignment, overlap and precision policy all
/// apply; errors if the plan has no train section.
pub fn train_plan(rt: &Runtime, plan: &crate::plan::ExecutionPlan) -> Result<TrainReport> {
    train(rt, &plan.train_config()?)
}

/// Run a full training job; blocks until all steps finish.
pub fn train(rt: &Runtime, cfg: &TrainConfig) -> Result<TrainReport> {
    let n_stages = cfg.stages.len();
    if n_stages == 0 {
        bail!("no stages configured");
    }
    if let Schedule::Interleaved { virtual_stages } = cfg.schedule {
        if virtual_stages > 1 {
            bail!("the real coordinator maps artifacts 1:1 onto physical stages and \
                   cannot split them into {virtual_stages} virtual chunks — run the \
                   interleaved schedule on the plan-driven virtual evaluator \
                   (`h2 train --plan ... --virtual`) or re-schedule to 1f1b/zbv");
        }
    }
    let entry = rt.manifest.model(&cfg.model)?.clone();

    // Load all executables up front (compile once, share across DP ranks).
    let mut stage_exes: Vec<Vec<SharedExe>> = Vec::new();
    let mut stage_meta = Vec::new();
    for (si, sp) in cfg.stages.iter().enumerate() {
        let is_first = si == 0;
        let is_last = si == n_stages - 1;
        let role = if is_first { "first" } else if is_last { "last" } else { "mid" };
        if !sp.prefix.starts_with(role) {
            bail!("stage {si} prefix `{}` does not match role `{role}`", sp.prefix);
        }
        let mut exes = Vec::new();
        if is_last {
            exes.push(SharedExe(rt.load(&cfg.model, &format!("{}_fwdbwd", sp.prefix))?));
        } else {
            exes.push(SharedExe(rt.load(&cfg.model, &format!("{}_fwd", sp.prefix))?));
            exes.push(SharedExe(rt.load(&cfg.model, &format!("{}_bwd", sp.prefix))?));
        }
        exes.push(SharedExe(rt.load(&cfg.model, &format!("{}_update", sp.prefix))?));
        let meta = exes[0].0.meta.clone();
        stage_exes.push(exes);
        stage_meta.push(meta);
    }

    // Fabric: rank = dp_rank * n_stages + stage.
    let chips: Vec<ChipKind> = (0..cfg.dp * n_stages)
        .map(|r| cfg.stages[r % n_stages].chip)
        .collect();
    let mode = cfg.comm;
    let assign = cfg.nic_assignment;
    let hidden_frac = if cfg.fine_overlap { 1.0 - FINE_OVERLAP_HIDDEN } else { 1.0 };
    let lat_chips = chips.clone();
    let latency: crate::comm::LatencyFn = Arc::new(move |s, d, bytes| {
        cross_node_time(mode, bytes, &spec(lat_chips[s]), &spec(lat_chips[d]), assign)
            * hidden_frac
    });
    let endpoints = fabric(cfg.dp * n_stages, latency);

    // One DP rendezvous per stage, running the configured collective
    // algorithm over the stage's chip-derived topology (hop latency and
    // bandwidth from the DiComm timing model under the run's comm mode —
    // no hardwired hop constants).
    let dp_groups: Vec<Arc<DpGroup>> = (0..n_stages)
        .map(|si| {
            let sp = spec(cfg.stages[si].chip);
            let topo = CommTopology::dp_group_mode(&sp, cfg.dp, 1, assign, mode);
            DpGroup::new(cfg.dp, cfg.comm_algo, topo)
        })
        .collect();

    // Per-stage issue orders of the configured schedule — the same
    // generators the simulator replays (`coordinator::schedule`).
    let orders = stage_orders(cfg.schedule, n_stages, cfg.micro_batches);

    let shared = Arc::new(WorkerShared {
        losses: Mutex::new(vec![0.0; cfg.steps]),
        virtual_ns: AtomicU64::new(0),
        comm_ns: AtomicU64::new(0),
    });
    let corpus = Arc::new(Corpus::new(entry.vocab, cfg.seed));

    let start = Instant::now();
    let mut handles = Vec::new();
    let mut endpoints = endpoints;
    // Spawn in reverse so we can pop endpoints by rank.
    for dp_rank in (0..cfg.dp).rev() {
        for si in (0..n_stages).rev() {
            let ep = endpoints.pop().expect("endpoint per rank");
            debug_assert_eq!(ep.rank(), dp_rank * n_stages + si);
            let ctx = WorkerCtx {
                stage: si,
                n_stages,
                dp_rank,
                dp: cfg.dp,
                cfg: cfg.clone(),
                exes: stage_exes[si]
                    .iter()
                    .map(|e| SharedExe(e.0.clone()))
                    .collect(),
                meta_params: stage_meta[si].params.clone(),
                micro_batch: stage_meta[si].micro_batch.unwrap_or(1),
                seq: stage_meta[si].seq.unwrap_or(entry.seq_len),
                hidden: entry.hidden,
                order: orders[si].clone(),
                dp_group: dp_groups[si].clone(),
                shared: shared.clone(),
                corpus: corpus.clone(),
            };
            handles.push(std::thread::spawn(move || worker(ctx, ep)));
        }
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }
    let wall = start.elapsed().as_secs_f64();

    let losses = shared.losses.lock().unwrap().clone();
    let tokens_per_step = cfg.micro_batches * cfg.dp
        * stage_meta[0].micro_batch.unwrap_or(1) * stage_meta[0].seq.unwrap_or(entry.seq_len);
    Ok(TrainReport {
        losses,
        wall_seconds: wall,
        virtual_seconds: shared.virtual_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        virtual_comm_seconds: shared.comm_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        tokens_per_step,
        tokens_per_second: tokens_per_step as f64 * cfg.steps as f64 / wall,
    })
}

struct WorkerCtx {
    stage: usize,
    n_stages: usize,
    dp_rank: usize,
    dp: usize,
    cfg: TrainConfig,
    exes: Vec<SharedExe>,
    meta_params: Vec<crate::runtime::ParamMeta>,
    micro_batch: usize,
    seq: usize,
    hidden: usize,
    order: Vec<PipeOp>,
    dp_group: Arc<DpGroup>,
    shared: Arc<WorkerShared>,
    corpus: Arc<Corpus>,
}

const DIR_FWD: u64 = 0;
const DIR_BWD: u64 = 1;

fn tag(step: usize, micro: usize, dir: u64) -> u64 {
    (step as u64) << 24 | (micro as u64) << 1 | dir
}

fn worker(ctx: WorkerCtx, mut ep: Endpoint) -> Result<()> {
    let is_first = ctx.stage == 0;
    let is_last = ctx.stage == ctx.n_stages - 1;
    let prev = ctx.dp_rank * ctx.n_stages + ctx.stage - (!is_first as usize);
    let next = ctx.dp_rank * ctx.n_stages + ctx.stage + (!is_last as usize);

    // Identical seed across DP ranks => identical initial replicas.
    let mut params = init_params(&ctx.meta_params, ctx.cfg.seed ^ (ctx.stage as u64) << 8);
    let mut m = zeros_like(&ctx.meta_params);
    let mut v = zeros_like(&ctx.meta_params);
    let mut perturb = ctx.cfg.perturb.then(|| {
        Perturbation::new(ctx.cfg.stages[ctx.stage].chip,
                          ctx.cfg.seed ^ ((ctx.stage * 31 + ctx.dp_rank) as u64))
    });

    let n_p = ctx.meta_params.len();
    let act_shape = [ctx.micro_batch, ctx.seq, ctx.hidden];
    let h_elems: usize = act_shape.iter().product();

    for step in 0..ctx.cfg.steps {
        let mut grad_acc = zeros_like(&ctx.meta_params);
        let mut stash: Vec<Option<HostTensor>> = vec![None; ctx.cfg.micro_batches];
        let mut dx_stash: Vec<Option<HostTensor>> = vec![None; ctx.cfg.micro_batches];
        let mut step_loss = 0.0f64;

        for &op in &ctx.order {
            match op {
                PipeOp::Fwd { micro, .. } => {
                    // Input: tokens (first stage) or upstream activations.
                    let x = if is_first {
                        let (inp, _) = ctx.corpus.microbatch(step, micro, ctx.dp_rank,
                                                             ctx.micro_batch, ctx.seq);
                        HostTensor::i32(&[ctx.micro_batch, ctx.seq], inp)
                    } else {
                        let data = ep.recv(prev, tag(step, micro, DIR_FWD))?;
                        anyhow::ensure!(data.len() == h_elems, "activation size mismatch");
                        HostTensor::f32(&act_shape, data)
                    };

                    if is_last {
                        // Fused fwd+bwd on the last stage.
                        let (_, tgt) = ctx.corpus.microbatch(step, micro, ctx.dp_rank,
                                                             ctx.micro_batch, ctx.seq);
                        let targets = HostTensor::i32(&[ctx.micro_batch, ctx.seq], tgt);
                        let mut inputs = params.clone();
                        inputs.push(x);
                        inputs.push(targets);
                        let t0 = Instant::now();
                        let out = ctx.exes[0].0.run(&inputs)
                            .context("last-stage fwdbwd")?;
                        ep.advance(t0.elapsed().as_secs_f64());
                        step_loss += out[0].as_f32()?[0] as f64;
                        dx_stash[micro] = Some(out[1].clone());
                        accumulate(&mut grad_acc, &out[2..2 + n_p])?;
                    } else {
                        let mut inputs = params.clone();
                        inputs.push(x.clone());
                        let t0 = Instant::now();
                        let out = ctx.exes[0].0.run(&inputs).context("stage fwd")?;
                        ep.advance(t0.elapsed().as_secs_f64());
                        stash[micro] = Some(x);
                        ep.send(next, tag(step, micro, DIR_FWD),
                                out[0].as_f32()?.to_vec())?;
                    }
                }
                PipeOp::Bwd { micro, .. } => {
                    if is_last {
                        let dx = dx_stash[micro].take()
                            .ok_or_else(|| anyhow!("missing dx for micro {micro}"))?;
                        if ctx.n_stages > 1 {
                            ep.send(prev, tag(step, micro, DIR_BWD), dx.as_f32()?.to_vec())?;
                        }
                    } else {
                        let dy_data = ep.recv(next, tag(step, micro, DIR_BWD))?;
                        let dy = HostTensor::f32(&act_shape, dy_data);
                        let x = stash[micro].take()
                            .ok_or_else(|| anyhow!("missing stash for micro {micro}"))?;
                        let mut inputs = params.clone();
                        inputs.push(x);
                        inputs.push(dy);
                        let t0 = Instant::now();
                        let out = ctx.exes[1].0.run(&inputs).context("stage bwd")?;
                        ep.advance(t0.elapsed().as_secs_f64());
                        if is_first {
                            accumulate(&mut grad_acc, &out[..n_p])?;
                        } else {
                            ep.send(prev, tag(step, micro, DIR_BWD),
                                    out[0].as_f32()?.to_vec())?;
                            accumulate(&mut grad_acc, &out[1..1 + n_p])?;
                        }
                    }
                }
                // The real backward executable computes input and weight
                // gradients together, so the zero-bubble weight phase is
                // fused into `Bwd` here; the op stays in the order (the
                // virtual evaluator executes it as a real split phase).
                PipeOp::BwdWeight { .. } => {}
            }
        }

        // DP gradient synchronization: the DiComm collective engine under
        // the configured algorithm. Only the exposed slice is charged —
        // the paper overlaps gradient sync with backward compute
        // (§4.3.2's t_update convention, shared with the cost model).
        let mut flat = flatten(&grad_acc)?;
        let cost = ctx.dp_group.allreduce(ctx.dp_rank, &mut flat);
        let exposed = cost.seconds * (1.0 - DP_OVERLAP);
        ep.advance(exposed);
        ep.add_wire(exposed);
        unflatten(&mut grad_acc, &flat)?;
        if let Some(p) = perturb.as_mut() {
            // Vendor-stack numerics model: correlated per-tensor noise.
            p.apply_tensors(&mut grad_acc);
        }

        // Adam update through the exported executable.
        let gscale = 1.0 / (ctx.cfg.micro_batches * ctx.dp) as f32;
        let mut inputs = Vec::with_capacity(4 * n_p + 3);
        inputs.extend(params.iter().cloned());
        inputs.extend(grad_acc.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(HostTensor::scalar_f32((step + 1) as f32));
        inputs.push(HostTensor::scalar_f32(ctx.cfg.lr));
        inputs.push(HostTensor::scalar_f32(gscale));
        let t0 = Instant::now();
        let update_exe = &ctx.exes[ctx.exes.len() - 1].0;
        let out = update_exe.run(&inputs).context("update")?;
        ep.advance(t0.elapsed().as_secs_f64());
        params = out[..n_p].to_vec();
        m = out[n_p..2 * n_p].to_vec();
        v = out[2 * n_p..3 * n_p].to_vec();

        if is_last {
            let mut mean_loss = step_loss / ctx.cfg.micro_batches as f64 / ctx.dp as f64;
            if let Some(p) = perturb.as_mut() {
                // The chip's own forward numerics perturb the metric it
                // reports (DiTorch §3.1.2: op-level noise surfaces in the
                // observed loss before any trajectory divergence).
                mean_loss = p.perturb_scalar(mean_loss);
            }
            let mut losses = ctx.shared.losses.lock().unwrap();
            losses[step] += mean_loss;
            if ctx.dp_rank == 0 && ctx.cfg.log_every > 0
                && (step % ctx.cfg.log_every == 0 || step + 1 == ctx.cfg.steps)
            {
                eprintln!("[h2] step {:>4}  loss {:.4}", step, losses[step] * ctx.dp as f64
                          / (ctx.dp_rank + 1) as f64);
            }
        }
    }

    // Record the slowest rank's virtual clock + comm-only time.
    let ns = (ep.now() * 1e9) as u64;
    ctx.shared.virtual_ns.fetch_max(ns, Ordering::Relaxed);
    let cns = (ep.wire_total() * 1e9) as u64;
    ctx.shared.comm_ns.fetch_max(cns, Ordering::Relaxed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Runtime::open("artifacts").unwrap())
        } else {
            None
        }
    }

    fn tiny_stages_pp2() -> Vec<StagePlan> {
        vec![
            StagePlan { prefix: "first_l2".into(), chip: ChipKind::A },
            StagePlan { prefix: "last_l2".into(), chip: ChipKind::B },
        ]
    }

    #[test]
    fn tiny_pp2_training_decreases_loss() {
        let Some(rt) = runtime() else { return };
        let mut cfg = TrainConfig::quick("h2_tiny", tiny_stages_pp2(), 1, 2, 12);
        cfg.lr = 3e-3;
        cfg.log_every = 0;
        let report = train(&rt, &cfg).unwrap();
        let first = report.losses[0];
        let last = *report.losses.last().unwrap();
        assert!(first > 6.5 && first < 7.5, "init loss ~ln(1024): {first}");
        assert!(last < first - 0.3, "loss should fall: {first} -> {last}");
        assert!(report.virtual_seconds > 0.0);
    }

    #[test]
    fn tiny_pp3_with_mid_stage_runs() {
        let Some(rt) = runtime() else { return };
        let stages = vec![
            StagePlan { prefix: "first_l1".into(), chip: ChipKind::A },
            StagePlan { prefix: "mid_l2".into(), chip: ChipKind::B },
            StagePlan { prefix: "last_l1".into(), chip: ChipKind::C },
        ];
        let mut cfg = TrainConfig::quick("h2_tiny", stages, 1, 3, 4);
        cfg.log_every = 0;
        let report = train(&rt, &cfg).unwrap();
        assert_eq!(report.losses.len(), 4);
        assert!(report.losses.iter().all(|l| l.is_finite() && *l > 0.0));
    }

    #[test]
    fn dp2_matches_dp1_with_double_micros() {
        // DP=2 with b micro-batches must produce the same loss trajectory
        // as DP=1 with 2b micro-batches (same global batch, same data up to
        // dp_rank seeding) — here we just check DP=2 runs and losses fall.
        let Some(rt) = runtime() else { return };
        let mut cfg = TrainConfig::quick("h2_tiny", tiny_stages_pp2(), 2, 2, 8);
        cfg.lr = 3e-3;
        cfg.log_every = 0;
        let report = train(&rt, &cfg).unwrap();
        assert!(report.losses.last().unwrap() < &report.losses[0]);
    }

    #[test]
    fn zbv_order_reproduces_1f1b_numerics() {
        // The zero-bubble order fuses the weight phase into `Bwd` on the
        // real backend, so it is a pure reordering: losses must be
        // identical to the 1F1B run.
        let Some(rt) = runtime() else { return };
        let mut cfg = TrainConfig::quick("h2_tiny", tiny_stages_pp2(), 1, 4, 6);
        cfg.log_every = 0;
        let f1b = train(&rt, &cfg).unwrap();
        cfg.schedule = Schedule::ZeroBubbleV;
        let zbv = train(&rt, &cfg).unwrap();
        for (a, b) in f1b.losses.iter().zip(&zbv.losses) {
            assert!((a - b).abs() < 1e-9, "losses must be identical: {a} vs {b}");
        }
    }

    #[test]
    fn interleaved_is_rejected_on_the_real_path() {
        let Some(rt) = runtime() else { return };
        let mut cfg = TrainConfig::quick("h2_tiny", tiny_stages_pp2(), 1, 2, 2);
        cfg.schedule = Schedule::Interleaved { virtual_stages: 2 };
        let err = train(&rt, &cfg).unwrap_err().to_string();
        assert!(err.contains("virtual"), "{err}");
    }

    #[test]
    fn hierarchical_collective_runs_and_matches_ring_losses() {
        let Some(rt) = runtime() else { return };
        let mut cfg = TrainConfig::quick("h2_tiny", tiny_stages_pp2(), 2, 2, 4);
        cfg.log_every = 0;
        let ring = train(&rt, &cfg).unwrap();
        cfg.comm_algo = CommAlgo::Hierarchical;
        let hier = train(&rt, &cfg).unwrap();
        // Same data, same reduction values (integer-exactness is not
        // guaranteed on real gradients, so allow float-level slack).
        for (a, b) in ring.losses.iter().zip(&hier.losses) {
            assert!((a - b).abs() < 1e-3, "losses diverged: {a} vs {b}");
        }
    }

    #[test]
    fn tcp_has_higher_virtual_time_than_ddr() {
        let Some(rt) = runtime() else { return };
        let mut cfg = TrainConfig::quick("h2_tiny", tiny_stages_pp2(), 1, 4, 2);
        cfg.log_every = 0;
        cfg.fine_overlap = false;
        let ddr = train(&rt, &cfg).unwrap();
        cfg.comm = CommMode::TcpCpu;
        let tcp = train(&rt, &cfg).unwrap();
        // Same real numerics...
        for (a, b) in ddr.losses.iter().zip(&tcp.losses) {
            assert!((a - b).abs() < 1e-9, "losses must be identical");
        }
        // ...but more modeled wire time (compute advances are measured
        // wall time and noisy, so compare the comm-only accounting).
        assert!(tcp.virtual_comm_seconds > ddr.virtual_comm_seconds,
                "tcp {} vs ddr {}", tcp.virtual_comm_seconds, ddr.virtual_comm_seconds);
    }
}
