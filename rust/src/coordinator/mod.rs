//! The H2 training coordinator (L3): real 1F1B pipeline training over PJRT
//! stage executables with DiComm-modeled communication.

pub mod checkpoint;
pub mod data;
pub mod dpgroup;
pub mod params;
pub mod schedule;
pub mod train;

pub use data::Corpus;
pub use dpgroup::DpGroup;
pub use schedule::{in_flight, one_f1b_order, Op};
pub use train::{train, train_plan, StagePlan, TrainConfig, TrainReport};
