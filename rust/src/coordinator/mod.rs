//! The H2 training coordinator (L3): pipeline training over PJRT stage
//! executables with DiComm-modeled communication — plus the plan-driven
//! *virtual* evaluator ([`train_virtual`]), which executes an
//! [`crate::plan::ExecutionPlan`]'s schedule and collective algorithm
//! with modeled compute so the coordinator can be held to the same
//! numbers as the cost model and the simulator (the third evaluator).

pub mod checkpoint;
pub mod data;
pub mod dpgroup;
pub mod exec;
pub mod params;
pub mod schedule;
pub mod train;

pub use data::Corpus;
pub use dpgroup::DpGroup;
pub use exec::{train_virtual, VirtualOptions, VirtualReport};
pub use schedule::{in_flight, one_f1b_order, stage_orders, Op, PipeOp};
pub use train::{train, train_plan, StagePlan, TrainConfig, TrainReport};
