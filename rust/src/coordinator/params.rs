//! Parameter store for pipeline-stage workers: initialization matching the
//! L2 model's init scheme, plus flatten/unflatten helpers for DiComm
//! collectives.

use anyhow::Result;

use crate::runtime::{HostTensor, ParamMeta};
use crate::util::rng::Rng;

/// Initialize stage parameters to the same scheme as
/// `compile/model.py::init_params`: ones for norm gains, N(0, 0.02) for the
/// embedding, N(0, fan_in^-1/2) for matmul weights.
pub fn init_params(metas: &[ParamMeta], seed: u64) -> Vec<HostTensor> {
    let mut rng = Rng::new(seed);
    metas
        .iter()
        .map(|m| {
            let n = m.numel();
            let mut data = vec![0.0f32; n];
            let base = m.name.rsplit('.').next().unwrap_or(&m.name);
            match base {
                "attn_norm" | "mlp_norm" | "final_norm" => data.fill(1.0),
                "embed" => rng.fill_normal(&mut data, 0.02),
                _ => {
                    let fan_in = *m.shape.first().unwrap_or(&1) as f32;
                    rng.fill_normal(&mut data, fan_in.powf(-0.5));
                }
            }
            HostTensor::f32(&m.shape, data)
        })
        .collect()
}

/// Zero tensors with the same shapes (optimizer state / grad accumulators).
pub fn zeros_like(metas: &[ParamMeta]) -> Vec<HostTensor> {
    metas
        .iter()
        .map(|m| HostTensor::f32(&m.shape, vec![0.0; m.numel()]))
        .collect()
}

/// Accumulate `src` into `acc` elementwise (gradient accumulation).
pub fn accumulate(acc: &mut [HostTensor], src: &[HostTensor]) -> Result<()> {
    assert_eq!(acc.len(), src.len());
    for (a, s) in acc.iter_mut().zip(src) {
        let a = a.as_f32_mut()?;
        let s = s.as_f32()?;
        for (x, y) in a.iter_mut().zip(s) {
            *x += *y;
        }
    }
    Ok(())
}

/// Concatenate f32 tensors into one flat buffer (for ring allreduce).
pub fn flatten(tensors: &[HostTensor]) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(tensors.iter().map(|t| t.len()).sum());
    for t in tensors {
        out.extend_from_slice(t.as_f32()?);
    }
    Ok(out)
}

/// Scatter a flat buffer back into the tensor list (inverse of `flatten`).
pub fn unflatten(tensors: &mut [HostTensor], flat: &[f32]) -> Result<()> {
    let mut off = 0;
    for t in tensors.iter_mut() {
        let dst = t.as_f32_mut()?;
        dst.copy_from_slice(&flat[off..off + dst.len()]);
        off += dst.len();
    }
    assert_eq!(off, flat.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas() -> Vec<ParamMeta> {
        vec![
            ParamMeta { name: "embed".into(), shape: vec![8, 4] },
            ParamMeta { name: "layer0.attn_norm".into(), shape: vec![4] },
            ParamMeta { name: "layer0.wq".into(), shape: vec![4, 4] },
        ]
    }

    #[test]
    fn init_is_deterministic() {
        let a = init_params(&metas(), 42);
        let b = init_params(&metas(), 42);
        assert_eq!(a, b);
        let c = init_params(&metas(), 43);
        assert_ne!(a, c);
    }

    #[test]
    fn norm_gains_are_ones() {
        let p = init_params(&metas(), 1);
        assert!(p[1].as_f32().unwrap().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn flatten_roundtrip() {
        let p = init_params(&metas(), 7);
        let flat = flatten(&p).unwrap();
        assert_eq!(flat.len(), 8 * 4 + 4 + 16);
        let mut q = zeros_like(&metas());
        unflatten(&mut q, &flat).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn accumulate_adds() {
        let mut acc = zeros_like(&metas());
        let p = init_params(&metas(), 3);
        accumulate(&mut acc, &p).unwrap();
        accumulate(&mut acc, &p).unwrap();
        for (a, b) in acc.iter().zip(&p) {
            for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
                assert!((x - 2.0 * y).abs() < 1e-6);
            }
        }
    }
}
