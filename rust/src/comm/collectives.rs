//! DiComm collective primitives (§3.2): implemented for real over rank
//! buffers (byte-accurate results) with virtual wire-time accounting from
//! the timing model.
//!
//! The paper's DiComm builds collectives "via a combination of send/receive
//! operations and native communication operators"; here the ring/tree
//! algorithms are implemented explicitly so the coordinator's DP gradient
//! synchronization and the SR&AG resharding path run the same code the
//! timing model accounts for.

/// Per-hop wire time for a message of `bytes` between ring neighbours.
pub type HopTime<'a> = &'a dyn Fn(usize) -> f64;

/// Timing result of a collective.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CollectiveCost {
    /// Modeled wall-clock seconds on the critical path.
    pub seconds: f64,
    /// Total bytes crossing links (all ranks summed).
    pub wire_bytes: usize,
}

const F32: usize = 4;

/// Ring allreduce (sum): 2·(N−1) chunk steps, exactly the classic schedule.
/// Buffers are modified in place; every rank ends with the elementwise sum.
pub fn ring_allreduce(bufs: &mut [Vec<f32>], hop: HopTime) -> CollectiveCost {
    let n = bufs.len();
    assert!(n > 0);
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "rank buffer lengths differ");
    if n == 1 || len == 0 {
        return CollectiveCost::default();
    }

    // Chunk boundaries (last chunk absorbs the remainder).
    let chunk = len.div_ceil(n);
    let bounds: Vec<(usize, usize)> =
        (0..n).map(|c| (c * chunk, ((c + 1) * chunk).min(len))).collect();

    let mut seconds = 0.0;
    let mut wire_bytes = 0usize;

    // Within one ring step every rank touches a *different* chunk (the
    // written chunk (r−s) of dst r+1 is never the chunk (r+1−s) that rank
    // reads as a source), so transfers can be applied in place through one
    // reusable scratch buffer — no per-step allocations (§Perf).
    let mut scratch = vec![0.0f32; chunk];

    // Phase 1: reduce-scatter. Step s: rank r sends chunk (r - s) to r+1.
    for s in 0..n - 1 {
        let mut max_hop = 0.0f64;
        for r in 0..n {
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi { continue; }
            let len = hi - lo;
            scratch[..len].copy_from_slice(&bufs[r][lo..hi]);
            let dst = (r + 1) % n;
            for (d, v) in bufs[dst][lo..hi].iter_mut().zip(&scratch[..len]) {
                *d += *v;
            }
            max_hop = max_hop.max(hop(len * F32));
            wire_bytes += len * F32;
        }
        seconds += max_hop;
    }

    // Phase 2: allgather of the reduced chunks. After reduce-scatter, rank r
    // holds the fully reduced chunk (r + 1) mod n.
    for s in 0..n - 1 {
        let mut max_hop = 0.0f64;
        for r in 0..n {
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi { continue; }
            let len = hi - lo;
            scratch[..len].copy_from_slice(&bufs[r][lo..hi]);
            bufs[(r + 1) % n][lo..hi].copy_from_slice(&scratch[..len]);
            max_hop = max_hop.max(hop(len * F32));
            wire_bytes += len * F32;
        }
        seconds += max_hop;
    }

    CollectiveCost { seconds, wire_bytes }
}

/// Ring allgather: every rank contributes its buffer; all ranks end with the
/// concatenation (rank-major). Returns (gathered, cost).
pub fn ring_allgather(bufs: &[Vec<f32>], hop: HopTime) -> (Vec<Vec<f32>>, CollectiveCost) {
    let n = bufs.len();
    let total: usize = bufs.iter().map(|b| b.len()).sum();
    let mut out: Vec<Vec<f32>> = vec![Vec::with_capacity(total); n];
    let mut gathered: Vec<f32> = Vec::with_capacity(total);
    for b in bufs {
        gathered.extend_from_slice(b);
    }
    for o in out.iter_mut() {
        o.extend_from_slice(&gathered);
    }
    let mut seconds = 0.0;
    let mut wire = 0usize;
    for s in 0..n.saturating_sub(1) {
        let mut max_hop = 0.0f64;
        for r in 0..n {
            let c = (r + n - s) % n;
            let bytes = bufs[c].len() * F32;
            max_hop = max_hop.max(hop(bytes));
            wire += bytes;
        }
        seconds += max_hop;
        let _ = s;
    }
    (out, CollectiveCost { seconds, wire_bytes: wire })
}

/// Binomial-tree broadcast from `root`. Buffers of non-root ranks are
/// overwritten with the root's data.
pub fn tree_broadcast(bufs: &mut [Vec<f32>], root: usize, hop: HopTime) -> CollectiveCost {
    let n = bufs.len();
    assert!(root < n);
    let data = bufs[root].clone();
    let bytes = data.len() * F32;
    let mut seconds = 0.0;
    let mut wire = 0usize;
    // Rounds double the informed set; each round is one hop deep.
    let mut informed = 1usize;
    while informed < n {
        let senders = informed.min(n - informed);
        seconds += hop(bytes);
        wire += senders * bytes;
        informed += senders;
    }
    for (r, b) in bufs.iter_mut().enumerate() {
        if r != root {
            b.clear();
            b.extend_from_slice(&data);
        }
    }
    CollectiveCost { seconds, wire_bytes: wire }
}

/// Plain point-to-point copy (the pipeline's activation hand-off).
pub fn send_recv(src: &[f32], dst: &mut Vec<f32>, hop: HopTime) -> CollectiveCost {
    dst.clear();
    dst.extend_from_slice(src);
    CollectiveCost { seconds: hop(src.len() * F32), wire_bytes: src.len() * F32 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn unit_hop(_bytes: usize) -> f64 {
        1.0
    }

    #[test]
    fn allreduce_sums_all_ranks() {
        let mut bufs = vec![
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![100.0, 200.0, 300.0, 400.0, 500.0],
        ];
        ring_allreduce(&mut bufs, &unit_hop);
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0, 333.0, 444.0, 555.0]);
        }
    }

    #[test]
    fn allreduce_cost_is_2n_minus_2_steps() {
        let mut bufs = vec![vec![0.0f32; 64]; 4];
        let c = ring_allreduce(&mut bufs, &unit_hop);
        assert_eq!(c.seconds, 6.0); // 2*(4-1) steps of unit time
    }

    #[test]
    fn allreduce_single_rank_noop() {
        let mut bufs = vec![vec![7.0f32; 3]];
        let c = ring_allreduce(&mut bufs, &unit_hop);
        assert_eq!(c.seconds, 0.0);
        assert_eq!(bufs[0], vec![7.0, 7.0, 7.0]);
    }

    #[test]
    fn allreduce_property_matches_naive_sum() {
        prop::check(40, |rng: &mut Rng| {
            let n = rng.usize(2, 7);
            let len = rng.usize(1, 40);
            let mut bufs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect();
            let expect: Vec<f32> = (0..len)
                .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>())
                .collect();
            ring_allreduce(&mut bufs, &unit_hop);
            for b in &bufs {
                for (x, e) in b.iter().zip(&expect) {
                    prop::assert_close(*x as f64, *e as f64, 1e-4, "allreduce sum")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn allgather_concatenates_rank_major() {
        let bufs = vec![vec![1.0f32], vec![2.0], vec![3.0]];
        let (out, cost) = ring_allgather(&bufs, &unit_hop);
        for o in &out {
            assert_eq!(o, &vec![1.0, 2.0, 3.0]);
        }
        assert_eq!(cost.seconds, 2.0);
    }

    #[test]
    fn broadcast_copies_root() {
        let mut bufs = vec![vec![0.0f32; 4]; 5];
        bufs[2] = vec![9.0, 8.0, 7.0, 6.0];
        let c = tree_broadcast(&mut bufs, 2, &unit_hop);
        for b in &bufs {
            assert_eq!(b, &vec![9.0, 8.0, 7.0, 6.0]);
        }
        // ceil(log2(5)) = 3 rounds.
        assert_eq!(c.seconds, 3.0);
    }

    #[test]
    fn wire_bytes_accounting() {
        let mut bufs = vec![vec![0.0f32; 8]; 2];
        let c = ring_allreduce(&mut bufs, &unit_hop);
        // n=2: chunks of 4 floats; 2 steps, each moving 2 ranks * 16 bytes.
        assert_eq!(c.wire_bytes, 2 * 2 * 16);
    }
}
