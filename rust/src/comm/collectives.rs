//! DiComm collective primitives (§3.2): implemented for real over rank
//! buffers (byte-accurate results) with virtual wire-time accounting from
//! the timing model.
//!
//! The paper's DiComm builds collectives "via a combination of send/receive
//! operations and native communication operators"; here the ring, binomial
//! tree, recursive halving-doubling and two-level hierarchical algorithms
//! are implemented explicitly so the coordinator's DP gradient
//! synchronization and the SR&AG resharding path run the same code the
//! timing model accounts for. Each executable collective has a closed-form
//! twin in [`super::algo`] (see `allreduce_cost`), kept honest by parity
//! tests; [`allreduce`] dispatches on [`CommAlgo`].

use crate::topology::whole_node_group;

use super::algo::{AllToAllAlgo, CommAlgo, LinkTime};

/// Per-hop wire time for a message of `bytes` between ring neighbours.
pub type HopTime<'a> = &'a dyn Fn(usize) -> f64;

/// Timing result of a collective.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CollectiveCost {
    /// Modeled wall-clock seconds on the critical path.
    pub seconds: f64,
    /// Total bytes crossing links (all ranks summed).
    pub wire_bytes: usize,
}

pub(crate) const F32: usize = 4;

/// Ring allreduce (sum): 2·(N−1) chunk steps, exactly the classic schedule.
/// Buffers are modified in place; every rank ends with the elementwise sum.
pub fn ring_allreduce(bufs: &mut [Vec<f32>], hop: HopTime) -> CollectiveCost {
    let mut slices: Vec<&mut [f32]> = bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
    ring_allreduce_slices(&mut slices, hop)
}

/// [`ring_allreduce`] over borrowed rank slices — the form the hierarchical
/// collective's concurrent per-chunk inter-node rings run on.
fn ring_allreduce_slices(bufs: &mut [&mut [f32]], hop: HopTime) -> CollectiveCost {
    let n = bufs.len();
    assert!(n > 0);
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "rank buffer lengths differ");
    if n == 1 || len == 0 {
        return CollectiveCost::default();
    }

    // Chunk boundaries (last chunk absorbs the remainder).
    let chunk = len.div_ceil(n);
    let bounds: Vec<(usize, usize)> =
        (0..n).map(|c| (c * chunk, ((c + 1) * chunk).min(len))).collect();

    let mut seconds = 0.0;
    let mut wire_bytes = 0usize;

    // Within one ring step every rank touches a *different* chunk (the
    // written chunk (r−s) of dst r+1 is never the chunk (r+1−s) that rank
    // reads as a source), so transfers can be applied in place through one
    // reusable scratch buffer — no per-step allocations (§Perf).
    let mut scratch = vec![0.0f32; chunk];

    // Phase 1: reduce-scatter. Step s: rank r sends chunk (r - s) to r+1.
    for s in 0..n - 1 {
        let mut max_hop = 0.0f64;
        for r in 0..n {
            let c = (r + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi { continue; }
            let len = hi - lo;
            scratch[..len].copy_from_slice(&bufs[r][lo..hi]);
            let dst = (r + 1) % n;
            for (d, v) in bufs[dst][lo..hi].iter_mut().zip(&scratch[..len]) {
                *d += *v;
            }
            max_hop = max_hop.max(hop(len * F32));
            wire_bytes += len * F32;
        }
        seconds += max_hop;
    }

    // Phase 2: allgather of the reduced chunks. After reduce-scatter, rank r
    // holds the fully reduced chunk (r + 1) mod n.
    for s in 0..n - 1 {
        let mut max_hop = 0.0f64;
        for r in 0..n {
            let c = (r + 1 + n - s) % n;
            let (lo, hi) = bounds[c];
            if lo >= hi { continue; }
            let len = hi - lo;
            scratch[..len].copy_from_slice(&bufs[r][lo..hi]);
            bufs[(r + 1) % n][lo..hi].copy_from_slice(&scratch[..len]);
            max_hop = max_hop.max(hop(len * F32));
            wire_bytes += len * F32;
        }
        seconds += max_hop;
    }

    CollectiveCost { seconds, wire_bytes }
}

/// Binomial-tree allreduce: reduce toward rank 0 along a binomial tree,
/// then [`tree_broadcast`] the sum back — 2·⌈log₂ N⌉ full-size hops.
/// Latency-optimal step count, bandwidth-poor for large payloads.
pub fn tree_allreduce(bufs: &mut [Vec<f32>], hop: HopTime) -> CollectiveCost {
    let n = bufs.len();
    assert!(n > 0);
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "rank buffer lengths differ");
    if n == 1 || len == 0 {
        return CollectiveCost::default();
    }
    let bytes = len * F32;
    let mut seconds = 0.0;
    let mut wire = 0usize;
    // Round d: every live rank r ≡ d (mod 2d) folds into r − d. One hop
    // deep per round, pairs transfer concurrently.
    let mut d = 1;
    while d < n {
        let mut senders = 0usize;
        let mut r = 0;
        while r + d < n {
            let (head, tail) = bufs.split_at_mut(r + d);
            for (x, y) in head[r].iter_mut().zip(tail[0].iter()) {
                *x += *y;
            }
            senders += 1;
            r += 2 * d;
        }
        seconds += hop(bytes);
        wire += senders * bytes;
        d *= 2;
    }
    let bcast = tree_broadcast(bufs, 0, hop);
    CollectiveCost { seconds: seconds + bcast.seconds, wire_bytes: wire + bcast.wire_bytes }
}

/// Recursive halving-doubling allreduce: ⌈log₂ P⌉ reduce-scatter steps with
/// halving payloads, then the mirror-image allgather — over the largest
/// power-of-two subgroup `P`, with the `N − P` extra ranks folding their
/// buffer into a partner first and receiving the result back afterwards.
pub fn rhd_allreduce(bufs: &mut [Vec<f32>], hop: HopTime) -> CollectiveCost {
    let n = bufs.len();
    assert!(n > 0);
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "rank buffer lengths differ");
    if n == 1 || len == 0 {
        return CollectiveCost::default();
    }
    let mut seconds = 0.0;
    let mut wire = 0usize;
    let p = if n.is_power_of_two() { n } else { n.next_power_of_two() / 2 };
    let extras = n - p;
    if extras > 0 {
        // Pre-step: rank p+i folds its whole buffer into rank i.
        for i in p..n {
            let (head, tail) = bufs.split_at_mut(i);
            for (x, y) in head[i - p].iter_mut().zip(tail[0].iter()) {
                *x += *y;
            }
        }
        seconds += hop(len * F32);
        wire += extras * len * F32;
    }

    // Recursive halving (reduce-scatter) among ranks 0..p: at each step the
    // partners i and i^mask share one block [lo, hi); the lower rank keeps
    // (and accumulates) the lower half, the upper rank the upper half.
    let mut lo = vec![0usize; p];
    let mut hi = vec![len; p];
    let mut mask = p / 2;
    while mask >= 1 {
        let mut step_max = 0usize;
        for i in 0..p {
            let partner = i | mask;
            if i == partner {
                continue; // i has the mask bit set; its partner visits it
            }
            debug_assert_eq!((lo[i], hi[i]), (lo[partner], hi[partner]));
            let (l, h) = (lo[i], hi[i]);
            let mid = l + (h - l) / 2;
            let (head, tail) = bufs.split_at_mut(partner);
            let a = &mut head[i];
            let b = &mut tail[0];
            for (x, y) in a[l..mid].iter_mut().zip(b[l..mid].iter()) {
                *x += *y;
            }
            for (y, x) in b[mid..h].iter_mut().zip(a[mid..h].iter()) {
                *y += *x;
            }
            wire += (h - l) * F32; // both directions of the pair
            step_max = step_max.max((mid - l).max(h - mid) * F32);
            hi[i] = mid;
            lo[partner] = mid;
        }
        seconds += hop(step_max);
        mask /= 2;
    }

    // Recursive doubling (allgather): reverse the halving steps, partners
    // exchanging their owned blocks and merging.
    let mut mask = 1;
    while mask < p {
        let mut step_max = 0usize;
        for i in 0..p {
            let partner = i | mask;
            if i == partner {
                continue;
            }
            let (head, tail) = bufs.split_at_mut(partner);
            let a = &mut head[i];
            let b = &mut tail[0];
            b[lo[i]..hi[i]].copy_from_slice(&a[lo[i]..hi[i]]);
            a[lo[partner]..hi[partner]].copy_from_slice(&b[lo[partner]..hi[partner]]);
            wire += (hi[i] - lo[i] + hi[partner] - lo[partner]) * F32;
            step_max = step_max.max((hi[i] - lo[i]).max(hi[partner] - lo[partner]) * F32);
            let (nl, nh) = (lo[i].min(lo[partner]), hi[i].max(hi[partner]));
            lo[i] = nl;
            hi[i] = nh;
            lo[partner] = nl;
            hi[partner] = nh;
        }
        seconds += hop(step_max);
        mask *= 2;
    }

    if extras > 0 {
        // Post-step: partners return the finished sum to the extras.
        for i in p..n {
            let (head, tail) = bufs.split_at_mut(i);
            tail[0].copy_from_slice(&head[i - p]);
        }
        seconds += hop(len * F32);
        wire += extras * len * F32;
    }
    CollectiveCost { seconds, wire_bytes: wire }
}

/// Two-level hierarchical allreduce (HetCCL/Holmes-style, §3): an
/// intra-node ring reduce-scatter on the fast fabric, a leader-based
/// inter-node ring exchange per chunk over the NIC path (the `k` chunk
/// rings run concurrently), and an intra-node ring allgather to
/// re-assemble. Ranks are node-major: rank `node·k + j` is chip `j` of
/// `node`, with `k = ranks_per_node` dividing the rank count.
pub fn hierarchical_allreduce(
    bufs: &mut [Vec<f32>],
    ranks_per_node: usize,
    intra_hop: HopTime,
    inter_hop: HopTime,
) -> CollectiveCost {
    let n = bufs.len();
    assert!(n > 0);
    let k = ranks_per_node.clamp(1, n);
    assert_eq!(n % k, 0, "ranks ({n}) must fill whole nodes of {k}");
    let m = n / k;
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "rank buffer lengths differ");
    if n == 1 || len == 0 {
        return CollectiveCost::default();
    }
    // Degenerate shapes collapse to a flat ring on the only link in play.
    if m == 1 {
        return ring_allreduce(bufs, intra_hop);
    }
    if k == 1 {
        return ring_allreduce(bufs, inter_hop);
    }

    let chunk = len.div_ceil(k);
    let bounds: Vec<(usize, usize)> =
        (0..k).map(|c| (c * chunk, ((c + 1) * chunk).min(len))).collect();
    // After an intra-node reduce-scatter, local rank j leads chunk (j+1)%k
    // (the classic ring ownership); invert it to find a chunk's leader.
    let leader = |c: usize| (c + k - 1) % k;
    let mut seconds = 0.0;
    let mut wire = 0usize;
    let mut scratch = vec![0.0f32; chunk];

    // Phase 1 — intra-node ring reduce-scatter, all nodes concurrently:
    // step s, local rank j sends chunk (j−s) to j+1 which accumulates.
    for s in 0..k - 1 {
        let mut max_hop = 0.0f64;
        for node in 0..m {
            for j in 0..k {
                let c = (j + k - s) % k;
                let (lo, hi) = bounds[c];
                if lo >= hi {
                    continue;
                }
                let l = hi - lo;
                let src = node * k + j;
                let dst = node * k + (j + 1) % k;
                scratch[..l].copy_from_slice(&bufs[src][lo..hi]);
                for (x, y) in bufs[dst][lo..hi].iter_mut().zip(&scratch[..l]) {
                    *x += *y;
                }
                max_hop = max_hop.max(intra_hop(l * F32));
                wire += l * F32;
            }
        }
        seconds += max_hop;
    }

    // Phase 2 — leader-based inter-node exchange: chunk c's leaders (one
    // per node) ring-allreduce that chunk across the m nodes. The k chunk
    // rings run concurrently over distinct NIC flows, so the phase costs
    // the slowest ring once; wire bytes sum over all of them.
    let mut phase2 = 0.0f64;
    for c in 0..k {
        let (lo, hi) = bounds[c];
        if lo >= hi {
            continue;
        }
        let j = leader(c);
        let mut slices: Vec<&mut [f32]> = bufs
            .iter_mut()
            .enumerate()
            .filter(|(r, _)| r % k == j)
            .map(|(_, b)| &mut b[lo..hi])
            .collect();
        let cost = ring_allreduce_slices(&mut slices, inter_hop);
        phase2 = phase2.max(cost.seconds);
        wire += cost.wire_bytes;
    }
    seconds += phase2;

    // Phase 3 — intra-node ring allgather of the k reduced chunks: k−1
    // steps, every local rank forwarding one chunk per step (so each node
    // circulates the full payload once per step).
    let max_chunk_hop = bounds
        .iter()
        .filter(|(lo, hi)| lo < hi)
        .map(|(lo, hi)| intra_hop((hi - lo) * F32))
        .fold(0.0f64, f64::max);
    seconds += (k - 1) as f64 * max_chunk_hop;
    wire += m * (k - 1) * len * F32;
    for node in 0..m {
        for c in 0..k {
            let (lo, hi) = bounds[c];
            if lo >= hi {
                continue;
            }
            let owner = node * k + leader(c);
            scratch[..hi - lo].copy_from_slice(&bufs[owner][lo..hi]);
            for j in 0..k {
                let r = node * k + j;
                if r != owner {
                    bufs[r][lo..hi].copy_from_slice(&scratch[..hi - lo]);
                }
            }
        }
    }

    CollectiveCost { seconds, wire_bytes: wire }
}

/// Execute an allreduce under `algo`. `ranks_per_node` describes the group
/// layout (node-major: consecutive ranks share a server); the flat
/// algorithms run every hop on the inter-node link whenever the group
/// spans nodes, while [`CommAlgo::Hierarchical`] splits its phases between
/// the two links. [`CommAlgo::Auto`] resolves against the closed-form
/// costs by probing the two hop functions (exact for affine hops).
pub fn allreduce(
    algo: CommAlgo,
    bufs: &mut [Vec<f32>],
    ranks_per_node: usize,
    intra_hop: HopTime,
    inter_hop: HopTime,
) -> CollectiveCost {
    let n = bufs.len();
    assert!(n > 0);
    // Whole nodes only: the same rounding rule the closed-form topology
    // applies, so model and executable agree on the group shape.
    let k = whole_node_group(n, ranks_per_node);
    let algo = match algo {
        CommAlgo::Auto => {
            let topo = super::algo::CommTopology {
                n_ranks: n,
                ranks_per_node: k,
                intra: LinkTime::probe(intra_hop),
                inter: LinkTime::probe(inter_hop),
            };
            let bytes = bufs[0].len() * F32;
            algo.resolve(bytes, &topo)
        }
        concrete => concrete,
    };
    let flat: HopTime = if n > k { inter_hop } else { intra_hop };
    match algo {
        CommAlgo::Ring => ring_allreduce(bufs, flat),
        CommAlgo::Tree => tree_allreduce(bufs, flat),
        CommAlgo::RecursiveHalvingDoubling => rhd_allreduce(bufs, flat),
        CommAlgo::Hierarchical => hierarchical_allreduce(bufs, k, intra_hop, inter_hop),
        CommAlgo::Auto => unreachable!("Auto resolved above"),
    }
}

/// Ring allgather: every rank contributes its buffer; all ranks end with the
/// concatenation (rank-major). Returns (gathered, cost).
pub fn ring_allgather(bufs: &[Vec<f32>], hop: HopTime) -> (Vec<Vec<f32>>, CollectiveCost) {
    let n = bufs.len();
    let total: usize = bufs.iter().map(|b| b.len()).sum();
    let mut out: Vec<Vec<f32>> = vec![Vec::with_capacity(total); n];
    let mut gathered: Vec<f32> = Vec::with_capacity(total);
    for b in bufs {
        gathered.extend_from_slice(b);
    }
    for o in out.iter_mut() {
        o.extend_from_slice(&gathered);
    }
    let mut seconds = 0.0;
    let mut wire = 0usize;
    for s in 0..n.saturating_sub(1) {
        let mut max_hop = 0.0f64;
        for r in 0..n {
            let c = (r + n - s) % n;
            let bytes = bufs[c].len() * F32;
            max_hop = max_hop.max(hop(bytes));
            wire += bytes;
        }
        seconds += max_hop;
    }
    (out, CollectiveCost { seconds, wire_bytes: wire })
}

/// Binomial-tree broadcast from `root`. Buffers of non-root ranks are
/// overwritten with the root's data.
pub fn tree_broadcast(bufs: &mut [Vec<f32>], root: usize, hop: HopTime) -> CollectiveCost {
    let n = bufs.len();
    assert!(root < n);
    let data = bufs[root].clone();
    let bytes = data.len() * F32;
    let mut seconds = 0.0;
    let mut wire = 0usize;
    // Rounds double the informed set; each round is one hop deep.
    let mut informed = 1usize;
    while informed < n {
        let senders = informed.min(n - informed);
        seconds += hop(bytes);
        wire += senders * bytes;
        informed += senders;
    }
    for (r, b) in bufs.iter_mut().enumerate() {
        if r != root {
            b.clear();
            b.extend_from_slice(&data);
        }
    }
    CollectiveCost { seconds, wire_bytes: wire }
}

/// Plain point-to-point copy (the pipeline's activation hand-off).
pub fn send_recv(src: &[f32], dst: &mut Vec<f32>, hop: HopTime) -> CollectiveCost {
    dst.clear();
    dst.extend_from_slice(src);
    CollectiveCost { seconds: hop(src.len() * F32), wire_bytes: src.len() * F32 }
}

/// Partition bounds of one rank's `len`-element all-to-all send buffer:
/// partition `d` (destined to rank `d`) is `[d·chunk, (d+1)·chunk) ∩
/// [0, len)` with `chunk = ⌈len/n⌉` — the ring-collective split, trailing
/// partitions absorb the shortfall.
fn a2a_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let chunk = len.div_ceil(n);
    (0..n).map(|d| ((d * chunk).min(len), ((d + 1) * chunk).min(len))).collect()
}

/// The all-to-all result: rank `d` receives every source's partition `d`,
/// source-major — the fixed output layout both variants must produce.
fn a2a_output(bufs: &[Vec<f32>], bounds: &[(usize, usize)]) -> Vec<Vec<f32>> {
    bounds
        .iter()
        .map(|&(lo, hi)| {
            let mut out = Vec::with_capacity((hi - lo) * bufs.len());
            for src in bufs {
                out.extend_from_slice(&src[lo..hi]);
            }
            out
        })
        .collect()
}

/// Pairwise-exchange all-to-all: `n−1` steps, step `s` wiring rank `r`'s
/// partition `(r+s) mod n` to that rank — the `n` transfers of one step
/// run concurrently, so each step costs its largest in-flight partition.
/// Works for any group size. Returns (received, cost).
pub fn pairwise_alltoall(bufs: &[Vec<f32>], hop: HopTime) -> (Vec<Vec<f32>>, CollectiveCost) {
    let n = bufs.len();
    assert!(n > 0);
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "rank buffer lengths differ");
    let bounds = a2a_bounds(len, n);
    let out = a2a_output(bufs, &bounds);
    let mut seconds = 0.0;
    let mut wire = 0usize;
    for s in 1..n {
        let mut max_hop = 0.0f64;
        for r in 0..n {
            let (lo, hi) = bounds[(r + s) % n];
            if lo >= hi {
                continue;
            }
            max_hop = max_hop.max(hop((hi - lo) * F32));
            wire += (hi - lo) * F32;
        }
        seconds += max_hop;
    }
    (out, CollectiveCost { seconds, wire_bytes: wire })
}

/// Two-level hierarchical all-to-all (node-major ranks, `rank = node·k + j`
/// with `k = ranks_per_node` dividing the rank count): an intra-node
/// all-to-all regroups each rank's partitions by destination *local
/// index* (`k−1` steps, each message bundling the `m` partitions bound
/// for one row), then the `k` per-row inter-node all-to-alls run
/// concurrently over distinct NIC flows (`m−1` steps of `k`-partition
/// bundles) and land every partition at its destination — no third phase.
pub fn hierarchical_alltoall(
    bufs: &[Vec<f32>],
    ranks_per_node: usize,
    intra_hop: HopTime,
    inter_hop: HopTime,
) -> (Vec<Vec<f32>>, CollectiveCost) {
    let n = bufs.len();
    assert!(n > 0);
    let k = ranks_per_node.clamp(1, n);
    assert_eq!(n % k, 0, "ranks ({n}) must fill whole nodes of {k}");
    let m = n / k;
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "rank buffer lengths differ");
    // Degenerate shapes collapse to pairwise on the only link in play.
    if m == 1 {
        return pairwise_alltoall(bufs, intra_hop);
    }
    if k == 1 {
        return pairwise_alltoall(bufs, inter_hop);
    }
    let bounds = a2a_bounds(len, n);
    let out = a2a_output(bufs, &bounds);
    // Row j's share of one send buffer: the m partitions destined to
    // local index j, one per node.
    let row = |j: usize| -> usize {
        (0..m).map(|node| bounds[node * k + j]).map(|(lo, hi)| hi - lo).sum()
    };
    let mut seconds = 0.0;
    let mut wire = 0usize;
    // Phase 1 — intra-node regroup: step s, local rank i bundles row
    // (i+s) mod k to that local rank; all nodes and pairs concurrent
    // (the pair pattern repeats identically on every node).
    for s in 1..k {
        let mut max_hop = 0.0f64;
        for i in 0..k {
            let r = row((i + s) % k);
            if r == 0 {
                continue;
            }
            max_hop = max_hop.max(intra_hop(r * F32));
            wire += m * r * F32;
        }
        seconds += max_hop;
    }
    // Phase 2 — per-row inter-node exchange: row j's m ranks swap their
    // k-bundled partitions pairwise; the k rows run concurrently, so the
    // phase costs the slowest row once; wire bytes sum over all of them.
    let mut phase2 = 0.0f64;
    for j in 0..k {
        let mut row_seconds = 0.0;
        for s in 1..m {
            let mut max_hop = 0.0f64;
            for t in 0..m {
                let (lo, hi) = bounds[((t + s) % m) * k + j];
                if lo >= hi {
                    continue;
                }
                max_hop = max_hop.max(inter_hop(k * (hi - lo) * F32));
                wire += k * (hi - lo) * F32;
            }
            row_seconds += max_hop;
        }
        phase2 = phase2.max(row_seconds);
    }
    seconds += phase2;
    (out, CollectiveCost { seconds, wire_bytes: wire })
}

/// Execute an all-to-all under `algo`. `ranks_per_node` describes the
/// group layout exactly as for [`allreduce`]; [`AllToAllAlgo::Auto`]
/// resolves against the closed-form costs by probing the two hop
/// functions (exact for affine hops).
pub fn alltoall(
    algo: AllToAllAlgo,
    bufs: &[Vec<f32>],
    ranks_per_node: usize,
    intra_hop: HopTime,
    inter_hop: HopTime,
) -> (Vec<Vec<f32>>, CollectiveCost) {
    let n = bufs.len();
    assert!(n > 0);
    let k = whole_node_group(n, ranks_per_node);
    let algo = match algo {
        AllToAllAlgo::Auto => {
            let topo = super::algo::CommTopology {
                n_ranks: n,
                ranks_per_node: k,
                intra: LinkTime::probe(intra_hop),
                inter: LinkTime::probe(inter_hop),
            };
            algo.resolve(bufs[0].len() * F32, &topo)
        }
        concrete => concrete,
    };
    let flat: HopTime = if n > k { inter_hop } else { intra_hop };
    match algo {
        AllToAllAlgo::Pairwise => pairwise_alltoall(bufs, flat),
        AllToAllAlgo::Hierarchical => hierarchical_alltoall(bufs, k, intra_hop, inter_hop),
        AllToAllAlgo::Auto => unreachable!("Auto resolved above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn unit_hop(_bytes: usize) -> f64 {
        1.0
    }

    #[test]
    fn allreduce_sums_all_ranks() {
        let mut bufs = vec![
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![100.0, 200.0, 300.0, 400.0, 500.0],
        ];
        ring_allreduce(&mut bufs, &unit_hop);
        for b in &bufs {
            assert_eq!(b, &[111.0, 222.0, 333.0, 444.0, 555.0]);
        }
    }

    #[test]
    fn allreduce_cost_is_2n_minus_2_steps() {
        let mut bufs = vec![vec![0.0f32; 64]; 4];
        let c = ring_allreduce(&mut bufs, &unit_hop);
        assert_eq!(c.seconds, 6.0); // 2*(4-1) steps of unit time
    }

    #[test]
    fn allreduce_single_rank_noop() {
        let mut bufs = vec![vec![7.0f32; 3]];
        let c = ring_allreduce(&mut bufs, &unit_hop);
        assert_eq!(c.seconds, 0.0);
        assert_eq!(bufs[0], vec![7.0, 7.0, 7.0]);
    }

    #[test]
    fn allreduce_property_matches_naive_sum() {
        prop::check(40, |rng: &mut Rng| {
            let n = rng.usize(2, 7);
            let len = rng.usize(1, 40);
            let mut bufs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect();
            let expect: Vec<f32> = (0..len)
                .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>())
                .collect();
            ring_allreduce(&mut bufs, &unit_hop);
            for b in &bufs {
                for (x, e) in b.iter().zip(&expect) {
                    prop::assert_close(*x as f64, *e as f64, 1e-4, "allreduce sum")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn allgather_concatenates_rank_major() {
        let bufs = vec![vec![1.0f32], vec![2.0], vec![3.0]];
        let (out, cost) = ring_allgather(&bufs, &unit_hop);
        for o in &out {
            assert_eq!(o, &[1.0, 2.0, 3.0]);
        }
        assert_eq!(cost.seconds, 2.0);
    }

    #[test]
    fn broadcast_copies_root() {
        let mut bufs = vec![vec![0.0f32; 4]; 5];
        bufs[2] = vec![9.0, 8.0, 7.0, 6.0];
        let c = tree_broadcast(&mut bufs, 2, &unit_hop);
        for b in &bufs {
            assert_eq!(b, &[9.0, 8.0, 7.0, 6.0]);
        }
        // ceil(log2(5)) = 3 rounds.
        assert_eq!(c.seconds, 3.0);
    }

    #[test]
    fn wire_bytes_accounting() {
        let mut bufs = vec![vec![0.0f32; 8]; 2];
        let c = ring_allreduce(&mut bufs, &unit_hop);
        // n=2: chunks of 4 floats; 2 steps, each moving 2 ranks * 16 bytes.
        assert_eq!(c.wire_bytes, 2 * 2 * 16);
    }

    /// Random small-integer buffers: every addition order yields the same
    /// bits, so reduction results can be compared exactly across
    /// algorithms.
    fn integer_bufs(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..len).map(|_| rng.usize(0, 17) as f32 - 8.0).collect())
            .collect()
    }

    fn naive_sum(bufs: &[Vec<f32>]) -> Vec<f32> {
        (0..bufs[0].len())
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>())
            .collect()
    }

    #[test]
    fn every_algorithm_matches_the_naive_sum_bit_for_bit() {
        // Integer-valued payloads make f32 addition exact, so ring, tree,
        // halving-doubling and hierarchical must all reproduce the naive
        // per-element sum bit for bit, on every rank.
        prop::check(60, |rng: &mut Rng| {
            let n = rng.usize(1, 13);
            let len = rng.usize(1, 70);
            let reference = integer_bufs(rng, n, len);
            let expect = naive_sum(&reference);
            let rpn = rng.usize(1, n + 1);
            for algo in CommAlgo::CONCRETE {
                let mut bufs = reference.clone();
                allreduce(algo, &mut bufs, rpn, &unit_hop, &unit_hop);
                for (r, b) in bufs.iter().enumerate() {
                    for (i, (x, e)) in b.iter().zip(&expect).enumerate() {
                        prop::assert_prop(
                            x.to_bits() == e.to_bits(),
                            format!("{algo} rank {r} elem {i}: {x} != {e} \
                                     (n={n}, len={len}, rpn={rpn})"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn auto_dispatch_also_sums_exactly() {
        prop::check(20, |rng: &mut Rng| {
            let n = rng.usize(2, 10);
            let len = rng.usize(1, 40);
            let mut bufs = integer_bufs(rng, n, len);
            let expect = naive_sum(&bufs);
            let slow = |bytes: usize| 3.0e-6 + bytes as f64 / 10e9;
            let fast = |bytes: usize| 0.8e-6 + bytes as f64 / 200e9;
            allreduce(CommAlgo::Auto, &mut bufs, 2, &fast, &slow);
            for b in &bufs {
                for (x, e) in b.iter().zip(&expect) {
                    prop::assert_prop(x.to_bits() == e.to_bits(), "auto dispatch sum")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wire_bytes_per_algorithm() {
        // 4 ranks x 16 floats (64 bytes each): the textbook totals.
        let mk = || vec![vec![1.0f32; 16]; 4];
        let b = 64usize;
        let ring = ring_allreduce(&mut mk(), &unit_hop);
        assert_eq!(ring.wire_bytes, 2 * 3 * b); // 2(n-1) x full payload
        let tree = tree_allreduce(&mut mk(), &unit_hop);
        assert_eq!(tree.wire_bytes, 2 * 3 * b); // 2(n-1) edges x full payload
        let rhd = rhd_allreduce(&mut mk(), &unit_hop);
        assert_eq!(rhd.wire_bytes, 2 * 3 * b); // 2(p-1) x full payload
        let hier = hierarchical_allreduce(&mut mk(), 2, &unit_hop, &unit_hop);
        // 2 nodes x 1 intra step x 64B, twice (RS + AG), + 2 chunk rings
        // of 2 nodes x 2(m-1)=2 steps x 16B sub-chunks.
        assert_eq!(hier.wire_bytes, 2 * 2 * 64 + 2 * 2 * 32);
    }

    #[test]
    fn tree_allreduce_steps_are_logarithmic() {
        let mut bufs = vec![vec![0.0f32; 4]; 8];
        let c = tree_allreduce(&mut bufs, &unit_hop);
        assert_eq!(c.seconds, 6.0); // 3 reduce + 3 broadcast rounds
        let mut bufs = vec![vec![0.0f32; 4]; 5];
        let c = tree_allreduce(&mut bufs, &unit_hop);
        assert_eq!(c.seconds, 6.0); // ceil(log2 5) = 3 each way
    }

    #[test]
    fn rhd_handles_non_power_of_two_groups() {
        for n in [2usize, 3, 5, 6, 7, 8, 12] {
            let mut bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![(r + 1) as f32; 24]).collect();
            let expect = (n * (n + 1) / 2) as f32;
            let c = rhd_allreduce(&mut bufs, &unit_hop);
            for b in &bufs {
                assert!(b.iter().all(|&x| x == expect), "n={n}");
            }
            let p = if n.is_power_of_two() { n } else { n.next_power_of_two() / 2 };
            assert_eq!(c.wire_bytes, (2 * (p - 1) + 2 * (n - p)) * 24 * 4, "n={n}");
        }
    }

    #[test]
    fn closed_form_costs_match_the_executable_collectives() {
        // On evenly-splitting payloads the closed forms in comm::algo walk
        // the identical hop sequence: seconds match to rounding, wire
        // bytes match exactly.
        use crate::comm::algo::{allreduce_cost, CommTopology, LinkTime};
        let intra = LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 };
        let inter = LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 };
        let intra_hop = |b: usize| intra.time(b);
        let inter_hop = |b: usize| inter.time(b);
        for (k, m) in [(2usize, 2usize), (4, 2), (2, 4), (8, 2), (3, 3)] {
            let n = k * m;
            let len = k * m * 32; // divisible by n, k, and m per chunk
            let topo = CommTopology { n_ranks: n, ranks_per_node: k, intra, inter };
            for algo in CommAlgo::CONCRETE {
                let mut bufs = vec![vec![1.0f32; len]; n];
                let run = allreduce(algo, &mut bufs, k, &intra_hop, &inter_hop);
                let model = allreduce_cost(algo, len * F32, &topo);
                assert!(
                    (run.seconds - model.seconds).abs() <= 1e-12 * model.seconds.max(1e-12),
                    "{algo} k={k} m={m}: run {} vs model {}",
                    run.seconds,
                    model.seconds
                );
                assert_eq!(run.wire_bytes, model.wire_bytes, "{algo} k={k} m={m}");
            }
        }
    }

    #[test]
    fn wire_bytes_and_sums_match_closed_forms_on_arbitrary_shapes() {
        // For ANY group size (non-power-of-two included), ranks-per-node
        // and payload length: every executable collective must (a) sum
        // bit-exactly on integer payloads and (b) put exactly the closed
        // form's byte count on the wire — the chunk boundaries telescope,
        // so ceil-split payloads change per-hop seconds but never totals.
        use crate::comm::algo::{allreduce_cost, CommTopology, LinkTime};
        use crate::topology::whole_node_group;
        let intra = LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 };
        let inter = LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 };
        let intra_hop = |b: usize| intra.time(b);
        let inter_hop = |b: usize| inter.time(b);
        prop::check(80, |rng: &mut Rng| {
            let n = rng.usize(1, 14);
            let len = rng.usize(1, 97);
            let rpn = rng.usize(1, n + 1);
            let k = whole_node_group(n, rpn);
            let topo = CommTopology { n_ranks: n, ranks_per_node: k, intra, inter };
            let reference = integer_bufs(rng, n, len);
            let expect = naive_sum(&reference);
            for algo in CommAlgo::CONCRETE {
                let mut bufs = reference.clone();
                let run = allreduce(algo, &mut bufs, rpn, &intra_hop, &inter_hop);
                let model = allreduce_cost(algo, len * F32, &topo);
                prop::assert_prop(
                    run.wire_bytes == model.wire_bytes,
                    format!("{algo} wire {} != closed form {} (n={n}, len={len}, rpn={rpn})",
                            run.wire_bytes, model.wire_bytes),
                )?;
                for (r, b) in bufs.iter().enumerate() {
                    for (x, e) in b.iter().zip(&expect) {
                        prop::assert_prop(
                            x.to_bits() == e.to_bits(),
                            format!("{algo} rank {r} sum mismatch (n={n}, len={len})"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hierarchical_beats_flat_ring_end_to_end() {
        // Executable collectives, 2 nodes x 4 ranks, intra 20x the NIC
        // path: the two-level schedule must finish first.
        let slow = |bytes: usize| 3.0e-6 + bytes as f64 / 10e9;
        let fast = |bytes: usize| 0.8e-6 + bytes as f64 / 200e9;
        let mk = || vec![vec![1.0f32; 1 << 16]; 8];
        let ring = ring_allreduce(&mut mk(), &slow);
        let hier = hierarchical_allreduce(&mut mk(), 4, &fast, &slow);
        assert!(hier.seconds < ring.seconds, "hier {} !< ring {}", hier.seconds, ring.seconds);
    }

    #[test]
    #[should_panic(expected = "whole nodes")]
    fn hierarchical_rejects_partial_nodes() {
        let mut bufs = vec![vec![0.0f32; 4]; 6];
        hierarchical_allreduce(&mut bufs, 4, &unit_hop, &unit_hop);
    }

    #[test]
    fn tree_and_rhd_closed_forms_match_on_non_power_of_two_groups() {
        // Regression: the rhd closed form halved blocks at *byte*
        // granularity while the executable splits f32 *elements*, so any
        // odd-element block drifted the modeled seconds. Pin hop-for-hop
        // parity (seconds AND wire bytes) for tree and rhd on every
        // non-power-of-two group size with payloads whose halving chain
        // splits unevenly at every step.
        use crate::comm::algo::{allreduce_cost, CommTopology, LinkTime};
        let intra = LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 };
        let inter = LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 };
        let intra_hop = |b: usize| intra.time(b);
        let inter_hop = |b: usize| inter.time(b);
        for n in [3usize, 5, 6, 7, 12] {
            for len in [7usize, 25, 33, 64] {
                for rpn in [1usize, n] {
                    let k = whole_node_group(n, rpn);
                    let topo = CommTopology { n_ranks: n, ranks_per_node: k, intra, inter };
                    for algo in [CommAlgo::Tree, CommAlgo::RecursiveHalvingDoubling] {
                        let mut bufs: Vec<Vec<f32>> =
                            (0..n).map(|r| vec![r as f32; len]).collect();
                        let run = allreduce(algo, &mut bufs, rpn, &intra_hop, &inter_hop);
                        let model = allreduce_cost(algo, len * F32, &topo);
                        assert!(
                            (run.seconds - model.seconds).abs()
                                <= 1e-12 * model.seconds.max(1e-12),
                            "{algo} n={n} len={len} rpn={rpn}: run {} vs model {}",
                            run.seconds,
                            model.seconds
                        );
                        assert_eq!(
                            run.wire_bytes, model.wire_bytes,
                            "{algo} n={n} len={len} rpn={rpn}"
                        );
                    }
                }
            }
        }
    }

    // -----------------------------------------------------------------
    // All-to-all: correctness, closed-form parity, auto dispatch.

    /// The reference all-to-all: rank d gets every source's partition d.
    fn naive_alltoall(bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let bounds = a2a_bounds(bufs[0].len(), bufs.len());
        a2a_output(bufs, &bounds)
    }

    #[test]
    fn alltoall_transposes_partitions() {
        // 3 ranks x 6 elements: partitions of 2; rank d must end with the
        // three source partitions d, source-major.
        let bufs: Vec<Vec<f32>> = (0..3)
            .map(|r| (0..6).map(|i| (10 * r + i) as f32).collect())
            .collect();
        let (out, cost) = pairwise_alltoall(&bufs, &unit_hop);
        assert_eq!(out[0], vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        assert_eq!(out[1], vec![2.0, 3.0, 12.0, 13.0, 22.0, 23.0]);
        assert_eq!(out[2], vec![4.0, 5.0, 14.0, 15.0, 24.0, 25.0]);
        assert_eq!(cost.seconds, 2.0); // n-1 unit steps
        assert_eq!(cost.wire_bytes, 2 * 6 * F32); // each rank wires 2 of 3 partitions
    }

    #[test]
    fn alltoall_single_rank_is_identity() {
        let bufs = vec![vec![1.0f32, 2.0, 3.0]];
        let (out, cost) = pairwise_alltoall(&bufs, &unit_hop);
        assert_eq!(out, bufs);
        assert_eq!(cost, CollectiveCost::default());
    }

    #[test]
    fn alltoall_closed_forms_match_the_executables() {
        // Evenly-splitting payloads: seconds match to rounding, wire bytes
        // exactly — on power-of-two AND non-power-of-two (k, m) layouts.
        use crate::comm::algo::{alltoall_cost, CommTopology, LinkTime};
        let intra = LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 };
        let inter = LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 };
        let intra_hop = |b: usize| intra.time(b);
        let inter_hop = |b: usize| inter.time(b);
        for (k, m) in [(2usize, 2usize), (4, 2), (2, 4), (8, 2), (3, 3), (3, 4), (5, 2), (1, 7)] {
            let n = k * m;
            let len = n * 16;
            let topo = CommTopology { n_ranks: n, ranks_per_node: k, intra, inter };
            let bufs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32 + 1.0; len]).collect();
            for algo in AllToAllAlgo::CONCRETE {
                let (out, run) = alltoall(algo, &bufs, k, &intra_hop, &inter_hop);
                let model = alltoall_cost(algo, len * F32, &topo);
                assert!(
                    (run.seconds - model.seconds).abs() <= 1e-12 * model.seconds.max(1e-12),
                    "{algo} k={k} m={m}: run {} vs model {}",
                    run.seconds,
                    model.seconds
                );
                assert_eq!(run.wire_bytes, model.wire_bytes, "{algo} k={k} m={m}");
                assert_eq!(out, naive_alltoall(&bufs), "{algo} k={k} m={m} data");
            }
        }
    }

    #[test]
    fn alltoall_wire_bytes_and_data_match_on_arbitrary_shapes() {
        // ANY group size, ranks-per-node and payload length: both
        // variants must land the exact transpose and wire exactly the
        // closed form's byte count (ragged partitions telescope).
        use crate::comm::algo::{alltoall_cost, CommTopology, LinkTime};
        use crate::topology::whole_node_group;
        let intra = LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 };
        let inter = LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 };
        let intra_hop = |b: usize| intra.time(b);
        let inter_hop = |b: usize| inter.time(b);
        prop::check(80, |rng: &mut Rng| {
            let n = rng.usize(1, 14);
            let len = rng.usize(1, 97);
            let rpn = rng.usize(1, n + 1);
            let k = whole_node_group(n, rpn);
            let topo = CommTopology { n_ranks: n, ranks_per_node: k, intra, inter };
            let bufs = integer_bufs(rng, n, len);
            let expect = naive_alltoall(&bufs);
            for algo in AllToAllAlgo::CONCRETE {
                let (out, run) = alltoall(algo, &bufs, rpn, &intra_hop, &inter_hop);
                let model = alltoall_cost(algo, len * F32, &topo);
                prop::assert_prop(
                    run.wire_bytes == model.wire_bytes,
                    format!(
                        "{algo} wire {} != closed form {} (n={n}, len={len}, rpn={rpn})",
                        run.wire_bytes, model.wire_bytes
                    ),
                )?;
                prop::assert_prop(
                    out == expect,
                    format!("{algo} data mismatch (n={n}, len={len}, rpn={rpn})"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn alltoall_pairwise_seconds_match_closed_form_on_any_shape() {
        // Pairwise's critical hop is always the ceil-share partition, so
        // its seconds parity holds even on ragged payloads.
        use crate::comm::algo::{alltoall_cost, AllToAllAlgo, CommTopology, LinkTime};
        let inter = LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 };
        let hop = |b: usize| inter.time(b);
        for n in [2usize, 3, 5, 7, 12] {
            for len in [5usize, 26, 33, 96] {
                let topo = CommTopology { n_ranks: n, ranks_per_node: 1, intra: inter, inter };
                let bufs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; len]).collect();
                let (_, run) = pairwise_alltoall(&bufs, &hop);
                let model = alltoall_cost(AllToAllAlgo::Pairwise, len * F32, &topo);
                assert!(
                    (run.seconds - model.seconds).abs() <= 1e-12 * model.seconds.max(1e-12),
                    "n={n} len={len}: run {} vs model {}",
                    run.seconds,
                    model.seconds
                );
            }
        }
    }

    #[test]
    fn hierarchical_alltoall_beats_pairwise_on_fast_intra_fabrics() {
        // 4 nodes x 8 ranks, intra 20x the NIC flow: bundling partitions
        // through the fast fabric must win for bandwidth-relevant payloads.
        let slow = |bytes: usize| 3.0e-6 + bytes as f64 / 10e9;
        let fast = |bytes: usize| 0.8e-6 + bytes as f64 / 200e9;
        let bufs: Vec<Vec<f32>> = (0..32).map(|_| vec![1.0f32; 1 << 15]).collect();
        let (_, pair) = pairwise_alltoall(&bufs, &slow);
        let (_, hier) = hierarchical_alltoall(&bufs, 8, &fast, &slow);
        assert!(hier.seconds < pair.seconds, "hier {} !< pair {}", hier.seconds, pair.seconds);
    }

    #[test]
    fn alltoall_auto_dispatch_is_the_concrete_minimum() {
        use crate::comm::algo::{alltoall_cost, CommTopology, LinkTime};
        let intra = LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 };
        let inter = LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 };
        let intra_hop = |b: usize| intra.time(b);
        let inter_hop = |b: usize| inter.time(b);
        let topo = CommTopology { n_ranks: 16, ranks_per_node: 4, intra, inter };
        for shift in [4usize, 10, 16, 22] {
            let len = 1usize << shift;
            let bufs: Vec<Vec<f32>> = (0..16).map(|_| vec![1.0; len]).collect();
            let (out, run) = alltoall(AllToAllAlgo::Auto, &bufs, 4, &intra_hop, &inter_hop);
            let min = AllToAllAlgo::CONCRETE
                .iter()
                .map(|&a| alltoall_cost(a, len * F32, &topo).seconds)
                .fold(f64::INFINITY, f64::min);
            assert!(
                (run.seconds - min).abs() <= 1e-12 * min.max(1e-12),
                "len {len}: auto {} vs min {}",
                run.seconds,
                min
            );
            assert_eq!(out, naive_alltoall(&bufs), "len {len}");
        }
    }
}
