//! In-process DiComm fabric: real data movement between worker threads plus
//! a modeled (virtual) wall clock per rank.
//!
//! The coordinator's pipeline-stage workers exchange *actual tensors*
//! through this fabric (so training numerics are real), while every message
//! also advances the ranks' virtual clocks using the DiComm timing model.
//! Experiments that compare strategies (Fig 12, Table 9) read the virtual
//! clocks; correctness-oriented callers just use the data.
//!
//! Clock semantics (LogP-style):
//!   depart  = clock[src]                    (send is non-blocking)
//!   arrive  = depart + latency(bytes)
//!   clock[dst] = max(clock[dst], arrive)    (applied at recv)

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

/// Message latency model: f(src, dst, bytes) -> seconds.
pub type LatencyFn = Arc<dyn Fn(usize, usize, usize) -> f64 + Send + Sync>;

struct Wire {
    src: usize,
    tag: u64,
    depart: f64,
    latency: f64,
    data: Vec<f32>,
}

struct Shared {
    clocks: Mutex<Vec<f64>>,
    /// Total wire latency charged to each rank (comm-only accounting).
    wire: Mutex<Vec<f64>>,
    latency: LatencyFn,
}

/// One rank's handle onto the fabric.
pub struct Endpoint {
    rank: usize,
    txs: Vec<Sender<Wire>>,
    rx: Receiver<Wire>,
    stash: HashMap<(usize, u64), Vec<Wire>>,
    shared: Arc<Shared>,
}

/// Build a fabric of `n` endpoints with the given latency model.
pub fn fabric(n: usize, latency: LatencyFn) -> Vec<Endpoint> {
    let shared = Arc::new(Shared {
        clocks: Mutex::new(vec![0.0; n]),
        wire: Mutex::new(vec![0.0; n]),
        latency,
    });
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| Endpoint {
            rank,
            txs: txs.clone(),
            rx,
            stash: HashMap::new(),
            shared: shared.clone(),
        })
        .collect()
}

impl Endpoint {
    /// This endpoint's rank in the fabric.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Current virtual time of this rank.
    pub fn now(&self) -> f64 {
        self.shared.clocks.lock().unwrap()[self.rank]
    }

    /// Advance this rank's virtual clock by `dt` seconds (compute time).
    pub fn advance(&self, dt: f64) {
        self.shared.clocks.lock().unwrap()[self.rank] += dt;
    }

    /// Non-blocking send of `data` to `dst` with a user tag.
    pub fn send(&self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        let bytes = data.len() * 4;
        let latency = (self.shared.latency)(self.rank, dst, bytes);
        self.send_with_latency(dst, tag, data, latency)
    }

    /// [`Endpoint::send`] with an explicit hop latency in place of the
    /// fabric's latency model — for callers that price hops per logical
    /// edge rather than per rank pair (the virtual evaluator's interleaved
    /// wrap hand-off shares a rank pair with the neighbour link).
    pub fn send_with_latency(
        &self,
        dst: usize,
        tag: u64,
        data: Vec<f32>,
        latency: f64,
    ) -> Result<()> {
        let depart = self.shared.clocks.lock().unwrap()[self.rank];
        self.txs[dst]
            .send(Wire { src: self.rank, tag, depart, latency, data })
            .map_err(|_| anyhow!("rank {dst} hung up"))
    }

    /// Blocking receive of the next message from `src` with `tag`.
    pub fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        loop {
            if let Some(q) = self.stash.get_mut(&(src, tag)) {
                if !q.is_empty() {
                    let w = q.remove(0);
                    self.apply_arrival(&w);
                    return Ok(w.data);
                }
            }
            let w = self.rx.recv().map_err(|_| anyhow!("fabric closed"))?;
            if w.src == src && w.tag == tag {
                self.apply_arrival(&w);
                return Ok(w.data);
            }
            self.stash.entry((w.src, w.tag)).or_default().push(w);
        }
    }

    fn apply_arrival(&self, w: &Wire) {
        let mut clocks = self.shared.clocks.lock().unwrap();
        let arrive = w.depart + w.latency;
        if arrive > clocks[self.rank] {
            clocks[self.rank] = arrive;
        }
        self.shared.wire.lock().unwrap()[self.rank] += w.latency;
    }

    /// Total wire latency charged to this rank (comm-only virtual time).
    pub fn wire_total(&self) -> f64 {
        self.shared.wire.lock().unwrap()[self.rank]
    }

    /// Charge extra wire time to this rank (e.g. collective costs).
    pub fn add_wire(&self, dt: f64) {
        self.shared.wire.lock().unwrap()[self.rank] += dt;
    }

    /// Snapshot of every rank's virtual clock (for reports).
    pub fn all_clocks(&self) -> Vec<f64> {
        self.shared.clocks.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn flat_latency(secs: f64) -> LatencyFn {
        Arc::new(move |_s, _d, _b| secs)
    }

    #[test]
    fn data_roundtrip() {
        let mut eps = fabric(2, flat_latency(0.001));
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 7, vec![1.0, 2.0, 3.0]).unwrap();
        let got = e0.recv(1, 7).unwrap();
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn clocks_advance_with_messages() {
        let mut eps = fabric(2, flat_latency(0.5));
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.advance(1.0); // sender busy until t=1.0
        e1.send(0, 0, vec![0.0; 10]).unwrap();
        e0.recv(1, 0).unwrap();
        assert!((e0.now() - 1.5).abs() < 1e-12, "receiver clock {}", e0.now());
    }

    #[test]
    fn receiver_clock_never_goes_backwards() {
        let mut eps = fabric(2, flat_latency(0.1));
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.advance(5.0);
        e1.send(0, 0, vec![1.0]).unwrap();
        e0.recv(1, 0).unwrap();
        assert_eq!(e0.now(), 5.0);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let mut eps = fabric(2, flat_latency(0.0));
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, 1, vec![1.0]).unwrap();
        e1.send(0, 2, vec![2.0]).unwrap();
        assert_eq!(e0.recv(1, 2).unwrap(), vec![2.0]);
        assert_eq!(e0.recv(1, 1).unwrap(), vec![1.0]);
    }

    #[test]
    fn threaded_pipeline_hand_off() {
        let mut eps = fabric(3, flat_latency(0.01));
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let t1 = thread::spawn(move || {
            let mut e1 = e1;
            let x = e1.recv(0, 0).unwrap();
            e1.advance(0.1); // compute
            e1.send(2, 0, x.iter().map(|v| v * 2.0).collect()).unwrap();
        });
        let t2 = thread::spawn(move || {
            let mut e2 = e2;
            let x = e2.recv(1, 0).unwrap();
            (x, e2.now())
        });
        e0.send(1, 0, vec![1.0, 2.0]).unwrap();
        t1.join().unwrap();
        let (x, t) = t2.join().unwrap();
        assert_eq!(x, vec![2.0, 4.0]);
        // 0.01 (hop) + 0.1 (compute) + 0.01 (hop)
        assert!((t - 0.12).abs() < 1e-9, "virtual time {t}");
    }
}
