//! DiComm: the unified heterogeneous communication library (§3.2).
//!
//! * [`model`] — calibrated timing model for the three strategies
//!   (CPU-mediated TCP, CPU-mediated RDMA, device-direct RDMA).
//! * [`algo`] — the collective-algorithm engine: closed-form
//!   latency/bandwidth costs for ring / tree / recursive halving-doubling
//!   / hierarchical allreduces and pairwise / hierarchical all-to-alls
//!   (the MoE dispatch/combine axis) over a [`CommTopology`], plus the
//!   topology-aware [`CommAlgo::Auto`] / [`AllToAllAlgo::Auto`] selectors.
//! * [`collectives`] — byte-accurate executable collectives (the same
//!   algorithm library, moving real rank buffers) with critical-path
//!   timing.
//! * [`fabric`] — in-process transport for the coordinator's stage workers:
//!   real tensors + LogP-style virtual clocks.

pub mod algo;
pub mod collectives;
pub mod fabric;
pub mod model;

pub use algo::{allreduce_cost, alltoall_cost, AllToAllAlgo, CommAlgo, CommTopology, LinkTime};
pub use collectives::{
    allreduce, alltoall, hierarchical_allreduce, hierarchical_alltoall, pairwise_alltoall,
    rhd_allreduce, ring_allgather, ring_allreduce, send_recv, tree_allreduce, tree_broadcast,
    CollectiveCost,
};
pub use fabric::{fabric, Endpoint, LatencyFn};
pub use model::{cross_node_bandwidth, cross_node_time, intra_node_time, p2p_latency, CommMode};
