//! DiComm: the unified heterogeneous communication library (§3.2).
//!
//! * [`model`] — calibrated timing model for the three strategies
//!   (CPU-mediated TCP, CPU-mediated RDMA, device-direct RDMA).
//! * [`collectives`] — byte-accurate ring allreduce / allgather / broadcast
//!   with critical-path timing.
//! * [`fabric`] — in-process transport for the coordinator's stage workers:
//!   real tensors + LogP-style virtual clocks.

pub mod collectives;
pub mod fabric;
pub mod model;

pub use collectives::{ring_allgather, ring_allreduce, send_recv, tree_broadcast, CollectiveCost};
pub use fabric::{fabric, Endpoint, LatencyFn};
pub use model::{cross_node_time, intra_node_time, p2p_latency, CommMode};
