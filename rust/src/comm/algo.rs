//! DiComm collective-algorithm engine (§3): a library of allreduce
//! algorithms — flat ring, binomial tree, recursive halving-doubling and
//! the two-level hierarchical scheme — each priced by a closed-form
//! latency/bandwidth model over a [`CommTopology`], plus a message-size-
//! and topology-aware selector ([`CommAlgo::Auto`]).
//!
//! The closed forms are the planning-side twins of the executable
//! collectives in [`super::collectives`]: [`allreduce_cost`] walks exactly
//! the hop sequence the data-moving implementations execute (bit-exact
//! whenever the payload splits evenly over the group; parity-tested), so
//! the §4.3.2 cost model, the HeteroPP simulator and the HeteroAuto
//! search all price a [`crate::costmodel::Strategy`]'s `comm_algo` the
//! same way.
//!
//! The decisive case on hyper-heterogeneous fabrics is the hierarchical
//! algorithm (HetCCL, Holmes): a flat ring pays the slow NIC path on
//! every one of its `2(N−1)` steps, while the two-level schedule keeps
//! `2(k−1)` steps on the intra-node fabric and crosses nodes only
//! `2(m−1)` times per chunk — with intra-node bandwidth several times the
//! per-flow NIC rate (Fig 3 vs Table 3), that is a structural win the
//! cost model and simulator can now both measure.

use std::fmt;

use crate::hetero::ChipSpec;
use crate::topology::{co_located_replicas, whole_node_group, NicAssignment};

use super::collectives::{CollectiveCost, HopTime, F32};
use super::model::{base_latency, cross_node_bandwidth, CommMode, INTRA_NODE_LATENCY};

/// Collective algorithm run by a communication group (the DP gradient
/// allreduce axis of the Table 9 ablation). Carried by
/// [`crate::costmodel::Strategy`], searched by HeteroAuto, serialized as a
/// plan-file token (`ring`, `tree`, `rhd`, `hierarchical`, `auto`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum CommAlgo {
    /// Flat ring over the whole group — the classic bandwidth-optimal
    /// schedule, but every hop pays the slowest link once the group spans
    /// nodes. The pre-engine hardwired behaviour and the v2-plan default.
    #[default]
    Ring,
    /// Binomial tree reduce + broadcast: `2·⌈log₂ N⌉` full-payload hops —
    /// latency-optimal step count, bandwidth-poor for large payloads.
    Tree,
    /// Recursive halving-doubling: `⌈log₂ N⌉` steps each way with halving
    /// payloads (non-power-of-two groups fold the extras into partners
    /// first) — the small-message sweet spot between ring and tree.
    RecursiveHalvingDoubling,
    /// Two-level (HetCCL/Holmes-style): intra-node ring reduce-scatter on
    /// the fast fabric, leader-based inter-node exchange per chunk over
    /// the NIC path, intra-node allgather to re-assemble.
    Hierarchical,
    /// Resolve per collective to the concrete algorithm with the lowest
    /// closed-form cost for the payload and topology at hand.
    Auto,
}

impl CommAlgo {
    /// The four concrete (executable) algorithms, in the deterministic
    /// order [`CommAlgo::resolve`] breaks cost ties by.
    pub const CONCRETE: [CommAlgo; 4] = [
        CommAlgo::Ring,
        CommAlgo::Tree,
        CommAlgo::RecursiveHalvingDoubling,
        CommAlgo::Hierarchical,
    ];

    /// Every algorithm token a plan/config can carry: the concrete four
    /// plus the `auto` selector.
    pub const ALL: [CommAlgo; 5] = [
        CommAlgo::Ring,
        CommAlgo::Tree,
        CommAlgo::RecursiveHalvingDoubling,
        CommAlgo::Hierarchical,
        CommAlgo::Auto,
    ];

    /// Human-readable algorithm name.
    pub fn name(self) -> &'static str {
        match self {
            CommAlgo::Ring => "flat ring",
            CommAlgo::Tree => "binomial tree",
            CommAlgo::RecursiveHalvingDoubling => "recursive halving-doubling",
            CommAlgo::Hierarchical => "hierarchical (two-level)",
            CommAlgo::Auto => "auto (topology-selected)",
        }
    }

    /// Canonical short token, accepted back by [`CommAlgo::parse`] — the
    /// serialization currency of plan files, configs and `--comm-algo`.
    pub fn token(self) -> &'static str {
        match self {
            CommAlgo::Ring => "ring",
            CommAlgo::Tree => "tree",
            CommAlgo::RecursiveHalvingDoubling => "rhd",
            CommAlgo::Hierarchical => "hierarchical",
            CommAlgo::Auto => "auto",
        }
    }

    /// Parse an algorithm token (`ring`, `tree`, `rhd`/`halving-doubling`,
    /// `hierarchical`/`hier`, `auto`).
    pub fn parse(s: &str) -> Option<CommAlgo> {
        match s.to_ascii_lowercase().as_str() {
            "ring" => Some(CommAlgo::Ring),
            "tree" => Some(CommAlgo::Tree),
            "rhd" | "halving-doubling" | "recursive-halving-doubling" => {
                Some(CommAlgo::RecursiveHalvingDoubling)
            }
            "hierarchical" | "hier" | "two-level" => Some(CommAlgo::Hierarchical),
            "auto" => Some(CommAlgo::Auto),
            _ => None,
        }
    }

    /// Resolve [`CommAlgo::Auto`] to the concrete algorithm with the
    /// lowest closed-form cost for this payload and topology (ties broken
    /// deterministically in [`CommAlgo::CONCRETE`] order). Concrete
    /// algorithms return themselves.
    pub fn resolve(self, bytes: usize, topo: &CommTopology) -> CommAlgo {
        if self != CommAlgo::Auto {
            return self;
        }
        let mut best = CommAlgo::Ring;
        let mut best_seconds = f64::INFINITY;
        for algo in CommAlgo::CONCRETE {
            let t = allreduce_cost(algo, bytes, topo).seconds;
            if t < best_seconds {
                best = algo;
                best_seconds = t;
            }
        }
        best
    }
}

impl fmt::Display for CommAlgo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.token())
    }
}

/// Affine timing of one link class: `time(bytes) = latency + bytes/bw`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkTime {
    /// Per-hop base latency, seconds.
    pub latency: f64,
    /// Streaming bandwidth, bytes/second.
    pub bytes_per_sec: f64,
}

impl LinkTime {
    /// Seconds to move `bytes` across the link once.
    pub fn time(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bytes_per_sec
    }

    /// Recover an affine link model from an opaque hop function by probing
    /// it at zero and at 1 MiB — exact for the affine hops the simulator,
    /// fabric and timing model use.
    pub fn probe(hop: HopTime) -> LinkTime {
        const PROBE: usize = 1 << 20;
        let latency = hop(0).max(0.0);
        let slope = (hop(PROBE) - latency).max(1e-30);
        LinkTime { latency, bytes_per_sec: PROBE as f64 / slope }
    }
}

/// Shape of one collective group over the cluster fabric: `n_ranks` ranks
/// laid out node-major with `ranks_per_node` of them sharing each server,
/// and the two link classes a hop can take.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommTopology {
    /// Ranks participating in the collective.
    pub n_ranks: usize,
    /// Co-located ranks per server (1 = fully scattered across nodes).
    pub ranks_per_node: usize,
    /// Intra-node link (the fast fabric, Fig 3).
    pub intra: LinkTime,
    /// Inter-node link (the per-flow NIC path, Table 3).
    pub inter: LinkTime,
}

impl CommTopology {
    /// Co-located ranks rounded down to a divisor of the group size, so
    /// the group always fills whole nodes ([`whole_node_group`]).
    pub fn node_group(&self) -> usize {
        whole_node_group(self.n_ranks, self.ranks_per_node)
    }

    /// Whole nodes the group spans.
    pub fn nodes(&self) -> usize {
        self.n_ranks.max(1) / self.node_group()
    }

    /// The DP gradient-sync group of one pipeline stage on `spec` chips:
    /// `dp` replicas whose ring neighbours sit `s_tp` chip slots apart
    /// inside a server, with [`co_located_replicas`] of them per node.
    /// Inter-node hops run device-direct on the Table 3 per-flow NIC
    /// bandwidth under `assign`.
    pub fn dp_group(
        spec: &ChipSpec,
        dp: usize,
        s_tp: usize,
        assign: NicAssignment,
    ) -> CommTopology {
        CommTopology::dp_group_mode(spec, dp, s_tp, assign, CommMode::DeviceDirect)
    }

    /// [`CommTopology::dp_group`] under an explicit cross-node
    /// communication strategy: the inter-node link takes `mode`'s base
    /// latency and effective per-flow streaming bandwidth from the DiComm
    /// timing model (`comm/model.rs`), so the real coordinator can price
    /// its DP collective under the run's `--comm` mode while the
    /// closed-form cost model stays pinned to device-direct RDMA.
    pub fn dp_group_mode(
        spec: &ChipSpec,
        dp: usize,
        s_tp: usize,
        assign: NicAssignment,
        mode: CommMode,
    ) -> CommTopology {
        let slot = s_tp.clamp(1, spec.chips_per_node.saturating_sub(1).max(1));
        let intra_bw = spec.intra_node.bandwidth_gbps(0, slot.min(spec.chips_per_node - 1));
        CommTopology {
            n_ranks: dp.max(1),
            ranks_per_node: co_located_replicas(spec, s_tp, dp),
            intra: LinkTime { latency: INTRA_NODE_LATENCY, bytes_per_sec: intra_bw * 1e9 },
            inter: LinkTime {
                latency: base_latency(mode),
                bytes_per_sec: cross_node_bandwidth(mode, spec, spec, assign),
            },
        }
    }
}

/// Closed-form cost of one allreduce of `bytes` under `algo` on `topo` —
/// the planning twin of [`super::collectives::allreduce`], walking the
/// same hop sequence (`Auto` resolves first, see [`CommAlgo::resolve`]).
pub fn allreduce_cost(algo: CommAlgo, bytes: usize, topo: &CommTopology) -> CollectiveCost {
    let n = topo.n_ranks;
    if n <= 1 || bytes == 0 {
        return CollectiveCost::default();
    }
    let k = topo.node_group();
    let m = n / k;
    let flat = if m > 1 { topo.inter } else { topo.intra };
    match algo {
        CommAlgo::Ring => ring_cost(bytes, n, flat),
        CommAlgo::Tree => tree_cost(bytes, n, flat),
        CommAlgo::RecursiveHalvingDoubling => rhd_cost(bytes, n, flat),
        CommAlgo::Hierarchical => {
            if m == 1 {
                ring_cost(bytes, n, topo.intra)
            } else if k == 1 {
                ring_cost(bytes, n, topo.inter)
            } else {
                let chunk = bytes.div_ceil(k);
                // Intra-node reduce-scatter and allgather: k−1 chunk-size
                // steps each on the fast fabric.
                let intra_steps = 2.0 * (k - 1) as f64 * topo.intra.time(chunk);
                // Leader-based inter-node exchange: k concurrent per-chunk
                // rings across the m nodes; wall clock pays one ring.
                let inter_ring = ring_cost(chunk, m, topo.inter);
                CollectiveCost {
                    seconds: intra_steps + inter_ring.seconds,
                    // Both intra phases circulate the payload once per step
                    // on every node; the inter rings together move the
                    // whole payload like one ring over m ranks.
                    wire_bytes: 2 * m * (k - 1) * bytes + 2 * (m - 1) * bytes,
                }
            }
        }
        CommAlgo::Auto => allreduce_cost(algo.resolve(bytes, topo), bytes, topo),
    }
}

/// Flat ring allreduce: `2(n−1)` steps of one `bytes/n` chunk each.
fn ring_cost(bytes: usize, n: usize, link: LinkTime) -> CollectiveCost {
    if n <= 1 || bytes == 0 {
        return CollectiveCost::default();
    }
    let steps = 2 * (n - 1);
    CollectiveCost {
        seconds: steps as f64 * link.time(bytes.div_ceil(n)),
        wire_bytes: steps * bytes,
    }
}

/// Binomial tree reduce + broadcast: `2·⌈log₂ n⌉` full-payload rounds.
fn tree_cost(bytes: usize, n: usize, link: LinkTime) -> CollectiveCost {
    if n <= 1 || bytes == 0 {
        return CollectiveCost::default();
    }
    let rounds = n.next_power_of_two().trailing_zeros() as f64;
    CollectiveCost {
        seconds: 2.0 * rounds * link.time(bytes),
        wire_bytes: 2 * (n - 1) * bytes,
    }
}

/// Recursive halving-doubling: mirrors the executable collective's hop
/// sequence — extras fold in/out at full payload, then `log₂ p` halving
/// steps and their reversed doubling twins.
fn rhd_cost(bytes: usize, n: usize, link: LinkTime) -> CollectiveCost {
    if n <= 1 || bytes == 0 {
        return CollectiveCost::default();
    }
    let p = if n.is_power_of_two() { n } else { n.next_power_of_two() / 2 };
    let extras = n - p;
    let mut seconds = 0.0;
    let mut wire = 0usize;
    if extras > 0 {
        seconds += 2.0 * link.time(bytes);
        wire += 2 * extras * bytes;
    }
    // Worst-rank block sizes per halving step (the upper half keeps the
    // ceil on odd splits, exactly as the executable splits blocks). The
    // executable halves at *element* granularity — `mid = l + (h−l)/2`
    // over f32 slices — so the chain must walk element counts, not bytes:
    // a byte-level ceil rounds to 2 B where the wire really carries a
    // whole 4 B element, drifting on any odd-element block. Fixed
    // buffer: this runs in the search's leaf evaluation (no allocations).
    let mut sizes = [0usize; 64];
    let steps = p.trailing_zeros() as usize;
    let mut block = bytes.div_ceil(F32);
    for s in sizes.iter_mut().take(steps) {
        let upper = block - block / 2;
        *s = upper * F32;
        block = upper;
    }
    for &s in sizes.iter().take(steps) {
        seconds += link.time(s);
    }
    for &s in sizes.iter().take(steps).rev() {
        seconds += link.time(s);
    }
    wire += 2 * (p - 1) * bytes;
    CollectiveCost { seconds, wire_bytes: wire }
}

/// All-to-all algorithm run by an expert-parallel group (the MoE token
/// dispatch/combine axis): every rank holds one equal partition per peer
/// and ends with the partitions addressed to it. Serialized nowhere —
/// resolved per collective like [`CommAlgo::Auto`]; the cost model prices
/// MoE layers with [`AllToAllAlgo::Auto`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum AllToAllAlgo {
    /// Pairwise exchange: `n−1` steps, step `s` sending rank `r`'s
    /// partition to rank `(r+s) mod n` — works for any group size, every
    /// hop pays the flat (slowest-spanned) link.
    #[default]
    Pairwise,
    /// Two-level (HetCCL-style): an intra-node all-to-all regroups
    /// partitions by destination *local index* (`k−1` steps of `m`
    /// partitions each on the fast fabric), then the `k` per-row
    /// inter-node all-to-alls run concurrently over distinct NIC flows
    /// (`m−1` steps of `k` partitions each).
    Hierarchical,
    /// Resolve per collective to the concrete variant with the lowest
    /// closed-form cost for the payload and topology at hand.
    Auto,
}

impl AllToAllAlgo {
    /// The two concrete (executable) variants, in the deterministic order
    /// [`AllToAllAlgo::resolve`] breaks cost ties by.
    pub const CONCRETE: [AllToAllAlgo; 2] = [AllToAllAlgo::Pairwise, AllToAllAlgo::Hierarchical];

    /// Human-readable variant name.
    pub fn name(self) -> &'static str {
        match self {
            AllToAllAlgo::Pairwise => "pairwise exchange",
            AllToAllAlgo::Hierarchical => "hierarchical (two-level)",
            AllToAllAlgo::Auto => "auto (topology-selected)",
        }
    }

    /// Resolve [`AllToAllAlgo::Auto`] to the concrete variant with the
    /// lowest closed-form cost for this payload and topology (ties broken
    /// in [`AllToAllAlgo::CONCRETE`] order). Concrete variants return
    /// themselves.
    pub fn resolve(self, bytes: usize, topo: &CommTopology) -> AllToAllAlgo {
        if self != AllToAllAlgo::Auto {
            return self;
        }
        let mut best = AllToAllAlgo::Pairwise;
        let mut best_seconds = f64::INFINITY;
        for algo in AllToAllAlgo::CONCRETE {
            let t = alltoall_cost(algo, bytes, topo).seconds;
            if t < best_seconds {
                best = algo;
                best_seconds = t;
            }
        }
        best
    }
}

impl fmt::Display for AllToAllAlgo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Closed-form cost of one all-to-all under `algo` on `topo`, where
/// `bytes` is ONE rank's whole send buffer (its `n` partitions together,
/// self-partition included — that one never hits the wire). The planning
/// twin of [`super::collectives::alltoall`], walking the same hop
/// sequence: seconds are bit-exact whenever the payload splits evenly
/// over the group, wire bytes are exact for every shape (parity-tested).
pub fn alltoall_cost(algo: AllToAllAlgo, bytes: usize, topo: &CommTopology) -> CollectiveCost {
    let n = topo.n_ranks;
    if n <= 1 || bytes == 0 {
        return CollectiveCost::default();
    }
    let k = topo.node_group();
    let m = n / k;
    let flat = if m > 1 { topo.inter } else { topo.intra };
    // Partition granularity is elements, like the executable: the first
    // partition always carries the ceil share, so each step's critical
    // hop moves exactly `chunk` elements.
    let elems = bytes.div_ceil(F32);
    let chunk = elems.div_ceil(n);
    match algo {
        AllToAllAlgo::Pairwise => CollectiveCost {
            seconds: (n - 1) as f64 * flat.time(chunk * F32),
            // Every rank wires out all partitions but its own.
            wire_bytes: (n - 1) * bytes,
        },
        AllToAllAlgo::Hierarchical => {
            if m == 1 || k == 1 {
                return alltoall_cost(AllToAllAlgo::Pairwise, bytes, topo);
            }
            // Phase 1 — intra-node regroup by destination local index:
            // k−1 steps, the critical message bundling m partitions.
            let intra_steps = (k - 1) as f64 * topo.intra.time(m * chunk * F32);
            // Phase 2 — per-row inter-node exchange, k rows concurrent:
            // m−1 steps, the critical message bundling k partitions.
            let inter_steps = (m - 1) as f64 * topo.inter.time(k * chunk * F32);
            CollectiveCost {
                seconds: intra_steps + inter_steps,
                // Each node's k ranks wire the payload k−1 times locally;
                // each row's m ranks wire their k-bundled payload m−1
                // times across nodes.
                wire_bytes: (k - 1) * m * bytes + (m - 1) * k * bytes,
            }
        }
        AllToAllAlgo::Auto => alltoall_cost(algo.resolve(bytes, topo), bytes, topo),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{spec, ChipKind};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn two_node_topology() -> CommTopology {
        // 2 nodes x 8 ranks, NVLink-class fabric vs a ~10 GB/s NIC flow.
        CommTopology {
            n_ranks: 16,
            ranks_per_node: 8,
            intra: LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 },
            inter: LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 },
        }
    }

    #[test]
    fn tokens_roundtrip() {
        for algo in CommAlgo::CONCRETE {
            assert_eq!(CommAlgo::parse(algo.token()), Some(algo), "{algo}");
        }
        assert_eq!(CommAlgo::parse("auto"), Some(CommAlgo::Auto));
        assert_eq!(CommAlgo::parse("HIER"), Some(CommAlgo::Hierarchical));
        assert_eq!(CommAlgo::parse("bogus"), None);
    }

    #[test]
    fn hierarchical_beats_flat_ring_on_fast_intra_fabrics() {
        // Whenever the intra-node fabric is >= 4x the NIC flow (and not
        // higher-latency), the two-level schedule must win for any
        // multi-node group and bandwidth-relevant payload.
        prop::check(200, |rng: &mut Rng| {
            let k = 1 << rng.usize(1, 5); // 2..16 ranks per node
            let m = rng.usize(2, 9); // 2..8 nodes
            let inter_bw = rng.f64() * 20e9 + 1e9;
            let ratio = 4.0 + rng.f64() * 60.0;
            let topo = CommTopology {
                n_ranks: k * m,
                ranks_per_node: k,
                intra: LinkTime { latency: 0.8e-6, bytes_per_sec: inter_bw * ratio },
                inter: LinkTime { latency: 3.0e-6, bytes_per_sec: inter_bw },
            };
            let bytes = 1 << rng.usize(20, 31); // 1 MiB .. 1 GiB
            let ring = allreduce_cost(CommAlgo::Ring, bytes, &topo).seconds;
            let hier = allreduce_cost(CommAlgo::Hierarchical, bytes, &topo).seconds;
            prop::assert_prop(
                hier < ring,
                format!("hier {hier} !< ring {ring} (k={k}, m={m}, bytes={bytes})"),
            )
        });
    }

    #[test]
    fn auto_is_the_concrete_minimum() {
        let topo = two_node_topology();
        for shift in [6, 10, 14, 18, 22, 26, 30] {
            let bytes = 1usize << shift;
            let auto = allreduce_cost(CommAlgo::Auto, bytes, &topo).seconds;
            let min = CommAlgo::CONCRETE
                .iter()
                .map(|&a| allreduce_cost(a, bytes, &topo).seconds)
                .fold(f64::INFINITY, f64::min);
            assert_eq!(auto, min, "bytes {bytes}");
        }
    }

    #[test]
    fn selector_is_message_size_aware() {
        // On a deep group (8 nodes x 16 ranks) tiny payloads are
        // latency-bound: the log-step algorithms beat both the
        // 2(n-1)-step flat ring and the hierarchical schedule's 2(k-1)
        // intra hops. Large payloads go hierarchical.
        let topo = CommTopology {
            n_ranks: 128,
            ranks_per_node: 16,
            intra: LinkTime { latency: 0.8e-6, bytes_per_sec: 200e9 },
            inter: LinkTime { latency: 3.0e-6, bytes_per_sec: 10e9 },
        };
        let small = CommAlgo::Auto.resolve(64, &topo);
        assert!(
            small == CommAlgo::RecursiveHalvingDoubling || small == CommAlgo::Tree,
            "64 B resolved to {small}"
        );
        assert_eq!(CommAlgo::Auto.resolve(64 << 20, &topo), CommAlgo::Hierarchical);
    }

    #[test]
    fn rhd_never_loses_to_tree() {
        // Same step count, halving vs full payloads.
        let topo = two_node_topology();
        for shift in [6, 12, 18, 24, 30] {
            let bytes = 1usize << shift;
            let rhd = allreduce_cost(CommAlgo::RecursiveHalvingDoubling, bytes, &topo);
            let tree = allreduce_cost(CommAlgo::Tree, bytes, &topo);
            assert!(rhd.seconds <= tree.seconds, "bytes {bytes}");
        }
    }

    #[test]
    fn single_node_groups_collapse_to_the_intra_fabric() {
        let topo = CommTopology { n_ranks: 8, ranks_per_node: 8, ..two_node_topology() };
        let ring = allreduce_cost(CommAlgo::Ring, 1 << 20, &topo);
        let hier = allreduce_cost(CommAlgo::Hierarchical, 1 << 20, &topo);
        assert_eq!(ring, hier, "m=1 hierarchical degenerates to the intra ring");
        // And the flat ring must price intra-node hops, not the NIC.
        let scattered = CommTopology { ranks_per_node: 1, ..topo };
        assert!(allreduce_cost(CommAlgo::Ring, 1 << 20, &scattered).seconds > ring.seconds);
    }

    #[test]
    fn dp_group_reflects_the_chip_topology() {
        // Chip A: 16 chips/node; a TP-4 stage co-locates 4 DP replicas.
        let a = spec(ChipKind::A);
        let t = CommTopology::dp_group(&a, 4, 4, NicAssignment::Affinity);
        assert_eq!(t.node_group(), 4);
        assert_eq!(t.nodes(), 1);
        // Chip B: 8 chips/node; TP-4 leaves room for 2 replicas per node.
        let b = spec(ChipKind::B);
        let t = CommTopology::dp_group(&b, 4, 4, NicAssignment::Affinity);
        assert_eq!(t.node_group(), 2);
        assert_eq!(t.nodes(), 2);
        // Non-affinity NIC mapping degrades only the inter link.
        let non = CommTopology::dp_group(&b, 4, 4, NicAssignment::NonAffinity);
        assert!(non.inter.bytes_per_sec < t.inter.bytes_per_sec);
        assert_eq!(non.intra, t.intra);
    }

    #[test]
    fn wire_bytes_scale_with_group_size() {
        let topo = two_node_topology();
        let bytes = 1 << 20;
        assert_eq!(allreduce_cost(CommAlgo::Ring, bytes, &topo).wire_bytes, 30 * bytes);
        assert_eq!(allreduce_cost(CommAlgo::Tree, bytes, &topo).wire_bytes, 30 * bytes);
        assert_eq!(
            allreduce_cost(CommAlgo::RecursiveHalvingDoubling, bytes, &topo).wire_bytes,
            30 * bytes
        );
        // Hierarchical: 2·m·(k−1)·B intra + 2·(m−1)·B inter.
        assert_eq!(
            allreduce_cost(CommAlgo::Hierarchical, bytes, &topo).wire_bytes,
            (2 * 2 * 7 + 2) * bytes
        );
    }

    #[test]
    fn probe_recovers_affine_links() {
        let link = LinkTime { latency: 2.5e-6, bytes_per_sec: 12.5e9 };
        let probed = LinkTime::probe(&|b| link.time(b));
        assert!((probed.latency - link.latency).abs() < 1e-12);
        assert!((probed.bytes_per_sec - link.bytes_per_sec).abs() / link.bytes_per_sec < 1e-9);
    }
}
