//! DiComm timing model (§3.2, Figure 6/7).
//!
//! Three cross-node communication strategies:
//!
//! * **TCP (CPU-mediated)** — device→host copy, kernel TCP/IP stack,
//!   host→device copy. High per-message overhead, low single-stream
//!   throughput.
//! * **CPU-mediated RDMA** — host staging copies, but RDMA verbs on the
//!   wire (the Gloo-style baseline in Fig 6 left).
//! * **Device-direct RDMA (DDR)** — NIC DMAs straight from device memory
//!   (Fig 6 right): no staging, minimal per-message latency.
//!
//! Constants are calibrated so the Fig 7 sweep reproduces the paper's
//! measurements: DDR vs TCP = 1.79× at 64 B, 16.0× at large messages,
//! 9.94× on average over the 64 B – 64 MiB sweep (see EXPERIMENTS.md).

use crate::hetero::ChipSpec;
use crate::topology::{flow_bandwidth_gbps, NicAssignment, RDMA_EFFICIENCY};

/// Cross-chip communication strategy (Fig 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CommMode {
    /// CPU-mediated TCP: staging copies + kernel network stack.
    TcpCpu,
    /// CPU-mediated RDMA: staging copies, verbs on the wire.
    RdmaCpu,
    /// Device-direct RDMA: the NIC DMAs straight from device memory.
    DeviceDirect,
}

impl CommMode {
    /// Human-readable strategy name.
    pub fn name(self) -> &'static str {
        match self {
            CommMode::TcpCpu => "CPU-mediated TCP",
            CommMode::RdmaCpu => "CPU-mediated RDMA",
            CommMode::DeviceDirect => "device-direct RDMA",
        }
    }

    /// Parse a mode token (`tcp`, `rdma-cpu`/`gloo`, `ddr`/`rdma`).
    pub fn parse(s: &str) -> Option<CommMode> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Some(CommMode::TcpCpu),
            "rdma-cpu" | "gloo" => Some(CommMode::RdmaCpu),
            "ddr" | "rdma" | "device-direct" => Some(CommMode::DeviceDirect),
            _ => None,
        }
    }

    /// Canonical short token, accepted back by [`CommMode::parse`] — the
    /// serialization currency of config and plan files.
    pub fn token(self) -> &'static str {
        match self {
            CommMode::TcpCpu => "tcp",
            CommMode::RdmaCpu => "rdma-cpu",
            CommMode::DeviceDirect => "ddr",
        }
    }
}

const GB: f64 = 1e9;

/// Per-hop base latency (s) of the intra-node fabric (kernel launch +
/// copy-engine setup) — the latency term of [`intra_node_time`] and of the
/// intra-node link in [`crate::comm::algo::CommTopology`].
pub const INTRA_NODE_LATENCY: f64 = 0.8e-6;

/// Base one-way latency (s) of each strategy: protocol + setup cost — the
/// latency term of the DiComm closed-form link model.
pub fn base_latency(mode: CommMode) -> f64 {
    match mode {
        CommMode::TcpCpu => 5.23e-6,      // kernel stack + two staging setups
        CommMode::RdmaCpu => 4.5e-6,      // verbs post + staging setups
        CommMode::DeviceDirect => 3.0e-6, // verbs post only
    }
}

/// Effective end-to-end streaming bandwidth (bytes/s) of each strategy on a
/// 200 GbE-class NIC path. TCP is single-stream (the PyTorch Gloo path the
/// paper compares against); host staging serializes with the wire for the
/// CPU-mediated modes.
fn streaming_bandwidth(mode: CommMode, wire_gbps: f64) -> f64 {
    let wire = wire_gbps * GB;
    match mode {
        // Single-stream kernel TCP manages a small fraction of the wire.
        CommMode::TcpCpu => wire / 16.0,
        // d2h copy + RDMA wire + h2d copy, non-overlapped staging.
        CommMode::RdmaCpu => 1.0 / (1.0 / 20e9 + 1.0 / wire + 1.0 / 20e9),
        CommMode::DeviceDirect => wire,
    }
}

/// One-way point-to-point latency (s) for `bytes` between two chips on
/// different nodes (the Fig 7 microbenchmark).
pub fn p2p_latency(mode: CommMode, bytes: usize) -> f64 {
    // Fig 7 was measured on the common 200 GbE path; 23 GB/s effective.
    let wire = 25.0 * 0.92;
    base_latency(mode) + bytes as f64 / streaming_bandwidth(mode, wire)
}

/// Effective cross-node streaming bandwidth (bytes/s) for one chip-to-chip
/// flow under a communication strategy and NIC-affinity configuration —
/// the bandwidth term of the DiComm closed-form link model, shared by
/// [`cross_node_time`] and [`crate::comm::algo::CommTopology`].
pub fn cross_node_bandwidth(
    mode: CommMode,
    src: &ChipSpec,
    dst: &ChipSpec,
    assign: NicAssignment,
) -> f64 {
    // Per-flow wire ceiling from the topology model (already includes RDMA
    // efficiency and NIC sharing across the server's concurrent flows).
    let flow = flow_bandwidth_gbps(src, dst, assign) * GB;
    match mode {
        CommMode::DeviceDirect => flow,
        CommMode::RdmaCpu => 1.0 / (1.0 / 20e9 + 1.0 / flow + 1.0 / 20e9),
        CommMode::TcpCpu => {
            // TCP ignores the RDMA efficiency win but still shares the NIC.
            let wire = flow / RDMA_EFFICIENCY / 16.0;
            wire.min(flow)
        }
    }
}

/// Cross-node transfer time (s) between two specific chip types, with NIC
/// affinity configuration — used by the resharding and pipeline models.
pub fn cross_node_time(
    mode: CommMode,
    bytes: usize,
    src: &ChipSpec,
    dst: &ChipSpec,
    assign: NicAssignment,
) -> f64 {
    base_latency(mode) + bytes as f64 / cross_node_bandwidth(mode, src, dst, assign)
}

/// Intra-node transfer time (s) between two chip slots of the same server.
pub fn intra_node_time(spec: &ChipSpec, slot_a: usize, slot_b: usize, bytes: usize) -> f64 {
    let bw = spec.intra_node.bandwidth_gbps(slot_a, slot_b) * GB;
    INTRA_NODE_LATENCY + bytes as f64 / bw
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_small_message_ratio() {
        // Paper's smallest sweep point: 1.79x.
        let r = p2p_latency(CommMode::TcpCpu, 256) / p2p_latency(CommMode::DeviceDirect, 256);
        assert!((r - 1.79).abs() < 0.03, "256B ratio {r}");
    }

    #[test]
    fn fig7_large_message_ratio() {
        let r = p2p_latency(CommMode::TcpCpu, 1 << 30) / p2p_latency(CommMode::DeviceDirect, 1 << 30);
        assert!((r - 16.0).abs() < 0.1, "1GiB ratio {r}");
    }

    #[test]
    fn fig7_average_ratio_near_paper() {
        // The paper's sweep: average 9.94x across message sizes.
        let sizes: Vec<usize> = (0..11).map(|i| 256usize << (2 * i)).collect(); // 256B..256MiB
        let mean: f64 = sizes.iter()
            .map(|&s| p2p_latency(CommMode::TcpCpu, s) / p2p_latency(CommMode::DeviceDirect, s))
            .sum::<f64>() / sizes.len() as f64;
        assert!((mean - 9.94).abs() < 1.0, "avg ratio {mean}");
    }

    #[test]
    fn rdma_cpu_sits_between() {
        for shift in [10, 16, 22, 26] {
            let s = 1usize << shift;
            let tcp = p2p_latency(CommMode::TcpCpu, s);
            let mid = p2p_latency(CommMode::RdmaCpu, s);
            let ddr = p2p_latency(CommMode::DeviceDirect, s);
            assert!(ddr < mid && mid < tcp, "ordering at {s}");
        }
    }

    #[test]
    fn latency_monotonic_in_size() {
        for mode in [CommMode::TcpCpu, CommMode::RdmaCpu, CommMode::DeviceDirect] {
            let mut last = 0.0;
            for shift in 6..30 {
                let t = p2p_latency(mode, 1 << shift);
                assert!(t > last);
                last = t;
            }
        }
    }

    #[test]
    fn cross_node_affinity_beats_non_affinity() {
        use crate::hetero::{spec, ChipKind};
        let a = spec(ChipKind::A);
        let b = spec(ChipKind::B);
        let bytes = 64 << 20;
        let aff = cross_node_time(CommMode::DeviceDirect, bytes, &a, &b, NicAssignment::Affinity);
        let non = cross_node_time(CommMode::DeviceDirect, bytes, &a, &b, NicAssignment::NonAffinity);
        assert!(aff < non);
    }
}
