//! # H2 — hyper-heterogeneous LLM training (paper reproduction)
//!
//! Three-layer architecture (DESIGN.md): Pallas kernels (L1) and the JAX
//! stage model (L2) are AOT-compiled to HLO text by `python/compile/`;
//! everything at runtime is this rust crate (L3). The narrative guide to
//! the module layout and data flow lives in `docs/architecture.md`; the
//! plan-file wire format is specified field by field in
//! `docs/plan-format.md`.
//!
//! ## The plan-centric workflow
//!
//! The crate's public API revolves around one serializable artifact, the
//! [`plan::ExecutionPlan`]: cluster + model shape + parallel strategy
//! (including the pipeline [`costmodel::Schedule`]) + per-stage
//! chip/TP/layer assignment + communication mode + NIC topology +
//! precision policy. The H2 loop is *search once, execute many times*:
//!
//! ```text
//!   auto::search ──► SearchResult::into_plan ──► plan.json
//!                                                  │
//!                    sim::simulate_plan ◄──────────┤  (HeteroPP simulator)
//!                    coordinator::train_plan ◄─────┤  (schedule + collectives
//!                      / train_virtual             │   executed; PJRT or
//!                                                  │   virtual compute)
//!                    costmodel::evaluate_plan ◄────┘  (§4.3.2 closed form)
//! ```
//!
//! Plans are built with the validating [`plan::PlanBuilder`] (structured
//! [`plan::PlanError`]s, all violations at once), round-trip losslessly
//! through JSON (`to_json`/`from_json` over [`util::json`]), and embed any
//! custom chips they reference, so a plan file is self-contained. The
//! [`config`] module is the JSON front-end that lowers into the builder;
//! its `chips` section feeds the data-driven chip registry
//! ([`hetero::register_custom`]) so user-defined accelerators are
//! searchable and simulatable without recompiling.
//!
//! In-process, the same flow is three calls (this is the README quickstart,
//! compiled as a doctest so it cannot rot):
//!
//! ```no_run
//! use h2::auto::{search, SearchConfig};
//! use h2::costmodel::H2_100B;
//! use h2::hetero::experiment;
//!
//! fn main() -> anyhow::Result<()> {
//!     let exp = experiment("exp-a-1")?;
//!     let cfg = SearchConfig::default();       // searches 1f1b, interleaved:2, zbv
//!     let r = search(&H2_100B, &exp.cluster, exp.gbs_tokens, &cfg)?;
//!     let plan = r.into_plan(&H2_100B, &exp.cluster, exp.gbs_tokens);
//!
//!     let eval = plan.evaluate();              // §4.3.2 closed-form cost model
//!     let sim = plan.simulate();               // HeteroPP discrete-event simulator
//!     println!("schedule {} -> TGS {:.1}", plan.schedule(),
//!              plan.tgs(sim.iteration_seconds));
//!     assert!(eval.feasible);
//!     plan.save("plan.json")?;                 // `h2 simulate --plan plan.json`
//!     Ok(())
//! }
//! ```
//!
//! One level up, the [`fleet`] layer packs a whole queue of jobs onto a
//! cluster (this is the README fleet quickstart, also compiled):
//!
//! ```no_run
//! use h2::fleet::{run, FleetOptions, JobTrace, Policy};
//! use h2::hetero::experiment;
//!
//! fn main() -> anyhow::Result<()> {
//!     let exp = experiment("exp-mega")?;           // 1,280 chips, 4 vendors
//!     let trace = JobTrace::generate(42, 12, exp.cluster.total_chips());
//!     let opts = FleetOptions { policy: Policy::PriorityBackfill, ..Default::default() };
//!     let timeline = run(&exp.cluster, &trace, &opts)?;
//!     println!(
//!         "makespan {:.0}s  p99 wait {:.0}s  utilization {:.2}",
//!         timeline.metrics.makespan_seconds,
//!         timeline.metrics.p99_wait_seconds,
//!         timeline.metrics.utilization,
//!     );
//!     timeline.save("fleet.json")?;                // bit-identical per seed+policy
//!     Ok(())
//! }
//! ```
//!
//! Pinning a schedule and re-scheduling a loaded plan are one-liners:
//!
//! ```no_run
//! use h2::costmodel::Schedule;
//! use h2::plan::ExecutionPlan;
//!
//! fn main() -> anyhow::Result<()> {
//!     let mut plan = ExecutionPlan::load("plan.json")?;
//!     plan.strategy.schedule = Schedule::ZeroBubbleV; // or Interleaved { .. }
//!     plan.validate().map_err(|e| anyhow::anyhow!(h2::plan::render_errors(&e)))?;
//!     println!("{}", plan.simulate().iteration_seconds);
//!     Ok(())
//! }
//! ```
//!
//! ## Subsystems
//!
//! * [`hetero`] — the chip catalog (Table 5) + runtime chip registry and
//!   cluster/experiment definitions (Table 7).
//! * [`comm`] — DiComm: the unified heterogeneous communication library
//!   (§3.2) with calibrated TCP / CPU-RDMA / device-direct RDMA models.
//! * [`topology`] — server/NIC topology and the affinity model (§5, Table 3).
//! * [`precision`] — DiTorch precision-alignment tooling (§3.1.2, Fig 5).
//! * [`costmodel`] — the §4.3.2 iteration-time + memory cost model, with
//!   the pipeline [`costmodel::Schedule`] as a first-class dimension.
//! * [`auto`] — HeteroAuto strategy search (§4.3.3), parallel over
//!   (data-parallel × schedule) candidates with branch-and-bound pruning,
//!   plus [`auto::replan`] for incremental re-planning after chip loss.
//! * [`elastic`] — fault injection, step-time monitoring, and hot-swap
//!   state migration: the detect → replan → migrate loop.
//! * [`fleet`] — the cluster-level scheduler: a seedable job queue
//!   packed onto one cluster with HeteroAuto as the inner solver per
//!   placement, FIFO or priority-with-backfill policies,
//!   preempt-by-resize via [`auto::replan`], and a deterministic
//!   [`fleet::FleetTimeline`] of events and fleet metrics.
//! * [`sim`] — the HeteroPP discrete-event simulator (§4.2) with a real
//!   issue order per schedule: the flat-arena [`sim::SimEngine`] hot
//!   path, machine-readable [`sim::EventTimeline`]s, and the preserved
//!   pre-arena executors in [`sim::reference`] as a differential
//!   baseline.
//! * [`coordinator`] — the training coordinator: executes a plan's
//!   schedule and DP collective over PJRT artifacts
//!   ([`coordinator::train_plan`]) or with modeled compute as the third
//!   plan evaluator ([`coordinator::train_virtual`]).
//! * [`plan`] — the serializable `ExecutionPlan` tying them together.
//! * [`config`] — JSON config front-end lowering into the plan builder.
//! * [`report`] — paper-table drivers (Table 6/9, Fig 11) over plans.

#![warn(missing_docs)]

pub mod auto;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod elastic;
pub mod fleet;
pub mod hetero;
pub mod plan;
pub mod precision;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod topology;
pub mod util;
