//! # H2 — hyper-heterogeneous LLM training (paper reproduction)
//!
//! Three-layer architecture (DESIGN.md): Pallas kernels (L1) and the JAX
//! stage model (L2) are AOT-compiled to HLO text by `python/compile/`;
//! everything at runtime is this rust crate (L3): the DiComm communication
//! library, the NIC/PCIe topology model, the DiTorch precision tooling,
//! the §4.3.2 cost model with its memory model, the HeteroAuto strategy
//! search, the HeteroPP discrete-event simulator, and the real 1F1B
//! training coordinator over the PJRT runtime.

pub mod auto;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod hetero;
pub mod precision;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod topology;
pub mod util;
