//! HeteroPP pipeline simulator: discrete-event execution at full cluster
//! scale with a real issue order per pipeline schedule (1F1B, interleaved,
//! zero-bubble — see [`crate::costmodel::Schedule`]), activation-resharding
//! strategies, and the Table 9 ablation axes.
//!
//! Layout after the flat-arena refactor: [`engine`] holds the hot
//! allocation-free event loop ([`SimEngine`]) and the machine-readable
//! [`EventTimeline`]; [`pipeline`] owns the pricing (stage timing tables,
//! reshard links) and the plan-level entry points, including the
//! deterministic parallel fault/batch drivers; [`reference`] preserves the
//! pre-refactor executors verbatim as the differential-testing baseline.

pub mod engine;
pub mod pipeline;
pub mod reference;
pub mod reshard;

pub use engine::{EventKind, EventTimeline, SimEngine, TimelineEvent};
pub use pipeline::{
    simulate_iteration, simulate_iteration_timeline, simulate_plan, simulate_plan_with_faults,
    simulate_plan_with_faults_workers, simulate_plans, FaultSimResult, SimOptions, SimResult,
    FINE_OVERLAP_HIDDEN,
};
pub use reshard::{reshard_time, ReshardStrategy};
