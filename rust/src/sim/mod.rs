//! HeteroPP pipeline simulator: discrete-event 1F1B execution at full
//! cluster scale, with activation-resharding strategies and the Table 9
//! ablation axes.

pub mod pipeline;
pub mod reshard;

pub use pipeline::{simulate_iteration, simulate_plan, SimOptions, SimResult, FINE_OVERLAP_HIDDEN};
pub use reshard::{reshard_time, ReshardStrategy};
