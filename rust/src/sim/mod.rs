//! HeteroPP pipeline simulator: discrete-event execution at full cluster
//! scale with a real issue order per pipeline schedule (1F1B, interleaved,
//! zero-bubble — see [`crate::costmodel::Schedule`]), activation-resharding
//! strategies, and the Table 9 ablation axes.

pub mod pipeline;
pub mod reshard;

pub use pipeline::{
    simulate_iteration, simulate_plan, simulate_plan_with_faults, FaultSimResult, SimOptions,
    SimResult, FINE_OVERLAP_HIDDEN,
};
pub use reshard::{reshard_time, ReshardStrategy};
