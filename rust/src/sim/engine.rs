//! Flat-arena discrete-event engine for the pipeline simulator.
//!
//! [`SimEngine`] is the allocation-free hot path behind
//! [`simulate_iteration`](super::simulate_iteration): construction does all
//! the pricing work (per-stage timing tables via
//! [`plan_stage_sims`](super::pipeline), reshard link costs via
//! [`stage_links`](super::pipeline), and the static per-stage issue orders
//! from the shared [`stage_orders`] generators), and every subsequent
//! [`SimEngine::run`] replays the iteration over pre-sized flat arenas
//! keyed by `(micro, virtual-stage)` indices — no per-op allocation, no
//! `Vec<Vec<_>>` pointer chasing, no re-derivation of the schedule.
//!
//! The engine is bit-identical to the pre-arena executors preserved in
//! [`super::reference`]: the 1F1B and interleaved schedules replay the same
//! static queues with the same readiness formulas (1F1B is the `v = 1`
//! degenerate case — `x / 1.0 == x` bitwise), and the zero-bubble schedule
//! delegates to the shared heap-based
//! [`ZbRunner`](crate::coordinator::schedule::ZbRunner), itself pinned
//! against the original scan greedy. The differential suite
//! (`tests/sim_differential.rs`) and the golden timelines
//! (`tests/golden_timeline.rs`) hold that equivalence.
//!
//! Every execution can optionally record an [`EventTimeline`] — the
//! machine-readable per-op `(stage, chunk, micro, kind, start, end)` trace
//! that is the currency of the golden-snapshot harness.

use anyhow::{bail, Result};

use crate::coordinator::schedule::{stage_orders, PipeOp, ZbRunner, ZbStage};
use crate::costmodel::{ModelShape, Schedule, Strategy};
use crate::hetero::ChipGroup;
use crate::util::json::{self, Value};

use super::pipeline::{finish, plan_stage_sims, stage_links, SimOptions, SimResult, StageSim};

/// Kind of one simulated pipeline op.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EventKind {
    /// Forward pass of one micro-batch through one (virtual) stage.
    #[default]
    Fwd,
    /// Backward pass (full, or the input-gradient phase under zero-bubble).
    Bwd,
    /// Zero-bubble weight-gradient phase (bubble filler).
    BwdWeight,
}

impl EventKind {
    /// Canonical token used in the timeline JSON.
    pub fn token(self) -> &'static str {
        match self {
            EventKind::Fwd => "fwd",
            EventKind::Bwd => "bwd",
            EventKind::BwdWeight => "bwd-w",
        }
    }

    /// Parse a canonical token back into the kind.
    pub fn parse(token: &str) -> Result<EventKind> {
        match token {
            "fwd" => Ok(EventKind::Fwd),
            "bwd" => Ok(EventKind::Bwd),
            "bwd-w" => Ok(EventKind::BwdWeight),
            other => bail!("unknown event kind `{other}`"),
        }
    }
}

/// One executed op in a simulated iteration.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TimelineEvent {
    /// Physical pipeline stage the op ran on.
    pub stage: usize,
    /// Virtual-stage chunk (0 for non-interleaved schedules).
    pub chunk: usize,
    /// Micro-batch index.
    pub micro: usize,
    /// Op kind.
    pub kind: EventKind,
    /// Start time (seconds from iteration start).
    pub start: f64,
    /// End time (seconds from iteration start).
    pub end: f64,
}

/// Machine-readable trace of one simulated iteration: every op's
/// `(stage, chunk, micro, kind, start, end)`, grouped by stage and in
/// per-stage execution order. Round-trips through JSON bit-exactly (the
/// writer prints `f64`s shortest-roundtrip), which is what lets the golden
/// snapshots under `rust/tests/golden/` pin the engine to the reference
/// executors timestamp-for-timestamp.
#[derive(Clone, Debug, PartialEq)]
pub struct EventTimeline {
    /// Canonical schedule token ([`Schedule::token`]).
    pub schedule: String,
    /// Physical stage count.
    pub stages: usize,
    /// Micro-batches per iteration.
    pub micro_batches: usize,
    /// All executed ops, sorted by stage, per-stage execution order.
    pub events: Vec<TimelineEvent>,
}

impl EventTimeline {
    /// Canonicalize a raw event list: stable-sort by stage so that
    /// executors that emit events in different global interleavings (the
    /// arena engine replays stage-by-stage, the reference executors sweep)
    /// produce comparable traces — within a stage every executor emits in
    /// execution order, so the stable sort is a total canonical order.
    pub fn from_events(
        schedule: Schedule,
        stages: usize,
        micro_batches: usize,
        mut events: Vec<TimelineEvent>,
    ) -> EventTimeline {
        events.sort_by_key(|e| e.stage);
        EventTimeline { schedule: schedule.token(), stages, micro_batches, events }
    }

    /// Serialize to the canonical JSON shape (sorted keys, shortest
    /// round-trip floats).
    pub fn to_json(&self) -> Value {
        let events = self
            .events
            .iter()
            .map(|e| {
                json::obj(vec![
                    ("stage", json::num(e.stage as f64)),
                    ("chunk", json::num(e.chunk as f64)),
                    ("micro", json::num(e.micro as f64)),
                    ("kind", json::s(e.kind.token())),
                    ("start", json::num(e.start)),
                    ("end", json::num(e.end)),
                ])
            })
            .collect();
        json::obj(vec![
            ("schedule", json::s(&self.schedule)),
            ("stages", json::num(self.stages as f64)),
            ("micro_batches", json::num(self.micro_batches as f64)),
            ("events", json::arr(events)),
        ])
    }

    /// Parse a timeline back from its canonical JSON shape.
    pub fn from_json(v: &Value) -> Result<EventTimeline> {
        let mut events = Vec::new();
        for e in v.get("events")?.arr()? {
            events.push(TimelineEvent {
                stage: e.get("stage")?.usize()?,
                chunk: e.get("chunk")?.usize()?,
                micro: e.get("micro")?.usize()?,
                kind: EventKind::parse(e.get("kind")?.str()?)?,
                start: e.get("start")?.num()?,
                end: e.get("end")?.num()?,
            });
        }
        Ok(EventTimeline {
            schedule: v.get("schedule")?.str()?.to_string(),
            stages: v.get("stages")?.usize()?,
            micro_batches: v.get("micro_batches")?.usize()?,
            events,
        })
    }

    /// First difference against another timeline, as a human-readable
    /// description — `None` when the two are identical (bit-for-bit on
    /// every timestamp).
    pub fn diff(&self, other: &EventTimeline) -> Option<String> {
        if self.schedule != other.schedule {
            return Some(format!("schedule: `{}` vs `{}`", self.schedule, other.schedule));
        }
        if self.stages != other.stages {
            return Some(format!("stage count: {} vs {}", self.stages, other.stages));
        }
        if self.micro_batches != other.micro_batches {
            return Some(format!(
                "micro-batches: {} vs {}",
                self.micro_batches, other.micro_batches
            ));
        }
        if self.events.len() != other.events.len() {
            return Some(format!(
                "event count: {} vs {}",
                self.events.len(),
                other.events.len()
            ));
        }
        for (i, (a, b)) in self.events.iter().zip(&other.events).enumerate() {
            if a != b {
                return Some(format!("event {i}: {a:?} vs {b:?}"));
            }
        }
        None
    }
}

/// Reusable per-iteration scratch state, sized once at engine build time.
/// Done-time arenas are flat `[micro * d_n + virtual_stage]` slabs; the
/// work-list (`stack`/`queued`) drives the stage replay loop.
#[derive(Clone, Debug)]
struct Scratch {
    fwd_done: Vec<f64>,
    bwd_done: Vec<f64>,
    head: Vec<usize>,
    clock: Vec<f64>,
    busy: Vec<f64>,
    exposed: Vec<f64>,
    stack: Vec<usize>,
    queued: Vec<bool>,
}

/// Flat-arena pipeline simulator, priced once and replayed many times.
///
/// Construction folds everything iteration-invariant into the engine: the
/// per-stage timing table, the exposed reshard link costs, and the static
/// per-stage issue orders from the shared
/// [`stage_orders`] generators (so the simulator executes
/// exactly the queues the training coordinator executes and the two cannot
/// drift). [`SimEngine::run`] then replays the iteration with zero
/// allocation: a work-list loop over per-stage queue heads for the static
/// schedules, the heap-based [`ZbRunner`] for zero-bubble.
///
/// [`SimEngine::run_scaled`] re-prices the same iteration under per-stage
/// `(compute, nic)` fault factors — the elastic fault path — by rescaling
/// the cached base table in place, and [`SimEngine::run_timeline`] records
/// the machine-readable [`EventTimeline`]. The engine is `Clone`, which is
/// what the deterministic parallel drivers
/// ([`simulate_plan_with_faults_workers`](super::simulate_plan_with_faults_workers),
/// [`simulate_plans`](super::simulate_plans)) hand to each worker thread.
#[derive(Clone, Debug)]
pub struct SimEngine {
    s_n: usize,
    v: usize,
    b: usize,
    schedule: Schedule,
    base_stages: Vec<StageSim>,
    base_link: Vec<f64>,
    base_wrap: f64,
    scaled_stages: Vec<StageSim>,
    scaled_link: Vec<f64>,
    /// Static issue orders, all stages concatenated (`off` delimits).
    ops: Vec<PipeOp>,
    /// `ops[off[s]..off[s + 1]]` is stage `s`'s queue.
    off: Vec<usize>,
    scratch: Scratch,
    zb: ZbRunner,
    zb_stages: Vec<ZbStage>,
}

impl SimEngine {
    /// Price a strategy into a reusable engine (the expensive part:
    /// per-stage profiles, reshard links, static issue orders).
    pub fn new(
        model: &ModelShape,
        groups: &[&ChipGroup],
        strategy: &Strategy,
        micro_tokens: usize,
        opts: &SimOptions,
    ) -> SimEngine {
        let base_stages = plan_stage_sims(model, groups, strategy, micro_tokens, opts);
        let (base_link, base_wrap) = stage_links(&base_stages, groups, model, micro_tokens, opts);
        let s_n = base_stages.len();
        let schedule = strategy.schedule;
        let v = schedule.virtual_stages();
        let b = strategy.micro_batches;
        let (ops, off, zb) = match schedule {
            Schedule::ZeroBubbleV => (Vec::new(), vec![0; s_n + 1], ZbRunner::new(s_n, b)),
            _ => {
                let queues = stage_orders(schedule, s_n, b);
                let mut ops = Vec::new();
                let mut off = Vec::with_capacity(s_n + 1);
                off.push(0);
                for q in queues {
                    ops.extend(q);
                    off.push(ops.len());
                }
                (ops, off, ZbRunner::new(0, 0))
            }
        };
        let d_n = s_n * v;
        SimEngine {
            s_n,
            v,
            b,
            schedule,
            scaled_stages: base_stages.clone(),
            scaled_link: base_link.clone(),
            base_stages,
            base_link,
            base_wrap,
            ops,
            off,
            scratch: Scratch {
                fwd_done: vec![0.0; b * d_n],
                bwd_done: vec![0.0; b * d_n],
                head: vec![0; s_n],
                clock: vec![0.0; s_n],
                busy: vec![0.0; s_n],
                exposed: vec![0.0; s_n],
                stack: Vec::with_capacity(s_n),
                queued: vec![false; s_n],
            },
            zb,
            zb_stages: Vec::with_capacity(s_n),
        }
    }

    /// Build the engine for a serialized [`crate::plan::ExecutionPlan`].
    pub fn for_plan(plan: &crate::plan::ExecutionPlan) -> SimEngine {
        let groups = plan.group_refs();
        SimEngine::new(
            &plan.model,
            &groups,
            &plan.strategy,
            plan.micro_tokens,
            &plan.sim_options(),
        )
    }

    /// Physical stage count of the priced pipeline.
    pub fn stages(&self) -> usize {
        self.s_n
    }

    /// Simulate one healthy iteration (the hot path — no allocation).
    pub fn run(&mut self) -> SimResult {
        self.execute(false, self.base_wrap, None)
    }

    /// Simulate one healthy iteration and record its [`EventTimeline`].
    pub fn run_timeline(&mut self) -> (SimResult, EventTimeline) {
        let cap = if matches!(self.schedule, Schedule::ZeroBubbleV) {
            3 * self.b * self.s_n
        } else {
            self.ops.len()
        };
        let mut events = Vec::with_capacity(cap);
        let r = self.execute(false, self.base_wrap, Some(&mut events));
        let t = EventTimeline::from_events(self.schedule, self.s_n, self.b, events);
        (r, t)
    }

    /// Simulate one iteration under per-stage `(compute, nic)` fault
    /// factors, with the exact scaling semantics of the fault loop: a
    /// compute factor multiplies the stage's compute times plus the
    /// compute share of its update, a NIC factor multiplies its outgoing
    /// activation hop and its exposed DP-sync slice.
    pub fn run_scaled(&mut self, factors: &[(f64, f64)]) -> SimResult {
        assert_eq!(factors.len(), self.s_n, "one (compute, nic) pair per stage");
        for s in 0..self.s_n {
            let (cf, nf) = factors[s];
            let st = &self.base_stages[s];
            self.scaled_stages[s] = StageSim {
                t_fwd: st.t_fwd * cf,
                t_bwd: st.t_bwd * cf,
                t_bwd_input: st.t_bwd_input * cf,
                t_bwd_weight: st.t_bwd_weight * cf,
                t_update: (st.t_update - st.t_update_comm) * cf + st.t_update_comm * nf,
                t_update_comm: st.t_update_comm * nf,
                ..st.clone()
            };
        }
        for i in 0..self.base_link.len() {
            self.scaled_link[i] = self.base_link[i] * factors[i].1;
        }
        let wrap = if self.s_n > 0 {
            self.base_wrap * factors[self.s_n - 1].1
        } else {
            self.base_wrap
        };
        self.execute(true, wrap, None)
    }

    /// Replay one iteration over the scratch arenas against either the
    /// base or the fault-scaled timing table.
    fn execute(
        &mut self,
        scaled: bool,
        wrap: f64,
        timeline: Option<&mut Vec<TimelineEvent>>,
    ) -> SimResult {
        let SimEngine {
            v,
            schedule,
            ref base_stages,
            ref base_link,
            ref scaled_stages,
            ref scaled_link,
            ref ops,
            ref off,
            ref mut scratch,
            ref mut zb,
            ref mut zb_stages,
            ..
        } = *self;
        let (stages, link): (&[StageSim], &[f64]) = if scaled {
            (scaled_stages, scaled_link)
        } else {
            (base_stages, base_link)
        };
        if matches!(schedule, Schedule::ZeroBubbleV) {
            zb_stages.clear();
            zb_stages.extend(stages.iter().map(|s| ZbStage {
                t_fwd: s.t_fwd,
                t_bwd_input: s.t_bwd_input,
                t_bwd_weight: s.t_bwd_weight,
            }));
            scratch.clock.fill(0.0);
            scratch.busy.fill(0.0);
            scratch.exposed.fill(0.0);
            let mut out = timeline;
            if let Some(o) = out.as_deref_mut() {
                o.clear();
            }
            for e in zb.run(zb_stages, link) {
                scratch.clock[e.stage] = e.end;
                scratch.busy[e.stage] += e.end - e.start;
                scratch.exposed[e.stage] += e.wait_comm;
                if let Some(o) = out.as_deref_mut() {
                    let (chunk, micro, kind) = match e.op {
                        PipeOp::Fwd { chunk, micro } => (chunk, micro, EventKind::Fwd),
                        PipeOp::Bwd { chunk, micro } => (chunk, micro, EventKind::Bwd),
                        PipeOp::BwdWeight { chunk, micro } => {
                            (chunk, micro, EventKind::BwdWeight)
                        }
                    };
                    o.push(TimelineEvent {
                        stage: e.stage,
                        chunk,
                        micro,
                        kind,
                        start: e.start,
                        end: e.end,
                    });
                }
            }
            return finish(stages, &scratch.clock, &scratch.busy, &scratch.exposed);
        }
        replay(stages, link, wrap, v, ops, off, scratch, timeline)
    }
}

/// Work-list replay of the static per-stage issue orders (1F1B and
/// interleaved; 1F1B is the `v = 1` case — same readiness formulas, and
/// `x / 1.0 == x` bitwise so chunk durations degrade exactly).
///
/// Values are traversal-order independent: each stage's queue is a fixed
/// sequence, an op's start is `clock[stage].max(ready)` where `ready`
/// depends only on already-executed ops' end times, so any order that
/// respects readiness yields the same timestamps — this loop just reaches
/// the fixed point without re-sweeping stages whose head op is still
/// blocked. A stage parks when its head op's cross-stage input is missing
/// and is re-queued by the completion that supplies it (forward at virtual
/// stage `d` wakes `d + 1`'s stage, backward wakes `d - 1`'s).
#[allow(clippy::too_many_arguments)]
fn replay(
    stages: &[StageSim],
    link: &[f64],
    wrap_link: f64,
    v: usize,
    ops: &[PipeOp],
    off: &[usize],
    sc: &mut Scratch,
    mut timeline: Option<&mut Vec<TimelineEvent>>,
) -> SimResult {
    let s_n = stages.len();
    let d_n = s_n * v;
    const UNSET: f64 = -1.0;
    sc.fwd_done.fill(UNSET);
    sc.bwd_done.fill(UNSET);
    sc.head.fill(0);
    sc.clock.fill(0.0);
    sc.busy.fill(0.0);
    sc.exposed.fill(0.0);
    sc.stack.clear();
    for s in (0..s_n).rev() {
        sc.stack.push(s);
        sc.queued[s] = true;
    }
    if let Some(out) = timeline.as_deref_mut() {
        out.clear();
        out.resize(ops.len(), TimelineEvent::default());
    }
    // Hop latency leaving virtual stage d toward d+1 (or back, for
    // gradients): adjacent physical stages, except the wrap from the last
    // physical stage back to the first between chunks.
    let hop = |d: usize| -> f64 {
        if d % s_n == s_n - 1 {
            wrap_link
        } else {
            link[d % s_n]
        }
    };
    while let Some(s) = sc.stack.pop() {
        sc.queued[s] = false;
        while off[s] + sc.head[s] < off[s + 1] {
            let slot = off[s] + sc.head[s];
            let (d, m, fwd) = match ops[slot] {
                PipeOp::Fwd { chunk, micro } => (chunk * s_n + s, micro, true),
                PipeOp::Bwd { chunk, micro } => (chunk * s_n + s, micro, false),
                PipeOp::BwdWeight { .. } => {
                    unreachable!("static replay has no weight phase")
                }
            };
            let (ready, comm) = if fwd {
                if d == 0 {
                    (Some(0.0), 0.0)
                } else if sc.fwd_done[m * d_n + d - 1] >= 0.0 {
                    (Some(sc.fwd_done[m * d_n + d - 1] + hop(d - 1)), hop(d - 1))
                } else {
                    (None, 0.0)
                }
            } else if sc.fwd_done[m * d_n + d] < 0.0 {
                (None, 0.0)
            } else if d == d_n - 1 {
                (Some(sc.fwd_done[m * d_n + d]), 0.0)
            } else if sc.bwd_done[m * d_n + d + 1] >= 0.0 {
                (Some(sc.bwd_done[m * d_n + d + 1] + hop(d)), hop(d))
            } else {
                (None, 0.0)
            };
            let Some(ready) = ready else { break };
            let dur = if fwd {
                stages[s].t_fwd / v as f64
            } else {
                stages[s].t_bwd / v as f64
            };
            let start = sc.clock[s].max(ready);
            sc.exposed[s] += (ready - sc.clock[s]).max(0.0).min(comm);
            let end = start + dur;
            sc.clock[s] = end;
            sc.busy[s] += dur;
            if fwd {
                sc.fwd_done[m * d_n + d] = end;
            } else {
                sc.bwd_done[m * d_n + d] = end;
            }
            if let Some(out) = timeline.as_deref_mut() {
                out[slot] = TimelineEvent {
                    stage: s,
                    chunk: d / s_n,
                    micro: m,
                    kind: if fwd { EventKind::Fwd } else { EventKind::Bwd },
                    start,
                    end,
                };
            }
            sc.head[s] += 1;
            // Wake the stage whose parked head op this completion feeds.
            let wake = if fwd {
                if d + 1 < d_n {
                    Some((d + 1) % s_n)
                } else {
                    None
                }
            } else if d > 0 {
                Some((d - 1) % s_n)
            } else {
                None
            };
            if let Some(t) = wake {
                if t != s && !sc.queued[t] {
                    sc.queued[t] = true;
                    sc.stack.push(t);
                }
            }
        }
    }
    assert!(
        (0..s_n).all(|s| off[s] + sc.head[s] == off[s + 1]),
        "pipeline deadlocked"
    );
    finish(stages, &sc.clock, &sc.busy, &sc.exposed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommAlgo;
    use crate::costmodel::{GroupPlan, H2_100B};
    use crate::hetero::{homogeneous_baseline, ChipKind};

    fn strategy(schedule: Schedule) -> Strategy {
        Strategy {
            s_ep: 1,
            s_dp: 4,
            micro_batches: 32,
            schedule,
            comm_algo: CommAlgo::Ring,
            plans: vec![GroupPlan { s_pp: 8, s_tp: 4, layers: 96, recompute: false }],
        }
    }

    #[test]
    fn rerun_is_bit_identical() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        for schedule in Schedule::SEARCH_SPACE {
            let mut eng = SimEngine::new(
                &H2_100B,
                &groups,
                &strategy(schedule),
                4096,
                &SimOptions::default(),
            );
            let a = eng.run();
            let b = eng.run();
            assert_eq!(a.iteration_seconds, b.iteration_seconds, "{schedule}");
            assert_eq!(a.busy, b.busy, "{schedule}");
            assert_eq!(a.exposed_comm, b.exposed_comm, "{schedule}");
        }
    }

    #[test]
    fn unit_fault_factors_match_the_healthy_run() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        for schedule in Schedule::SEARCH_SPACE {
            let mut eng = SimEngine::new(
                &H2_100B,
                &groups,
                &strategy(schedule),
                4096,
                &SimOptions::default(),
            );
            let healthy = eng.run();
            let unit = vec![(1.0, 1.0); eng.stages()];
            let scaled = eng.run_scaled(&unit);
            assert_eq!(healthy.iteration_seconds, scaled.iteration_seconds, "{schedule}");
            assert_eq!(healthy.busy, scaled.busy, "{schedule}");
        }
    }

    #[test]
    fn timeline_roundtrips_through_json_bit_exactly() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let mut eng = SimEngine::new(
            &H2_100B,
            &groups,
            &strategy(Schedule::ZeroBubbleV),
            4096,
            &SimOptions::default(),
        );
        let (_, t) = eng.run_timeline();
        assert!(!t.events.is_empty());
        let text = t.to_json().to_string_pretty();
        let back = EventTimeline::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(t, back);
        assert_eq!(t.diff(&back), None);
    }

    #[test]
    fn timeline_covers_every_op_exactly_once() {
        let exp = homogeneous_baseline(ChipKind::A);
        let groups = exp.cluster.groups_by_memory_desc();
        let strat = strategy(Schedule::Interleaved { virtual_stages: 2 });
        let mut eng = SimEngine::new(&H2_100B, &groups, &strat, 4096, &SimOptions::default());
        let (_, t) = eng.run_timeline();
        let s_n = eng.stages();
        let (v, b) = (2, strat.micro_batches);
        assert_eq!(t.events.len(), 2 * v * b * s_n);
        let mut seen = std::collections::BTreeSet::new();
        for e in &t.events {
            assert!(e.end >= e.start);
            assert!(seen.insert((e.stage, e.chunk, e.micro, e.kind.token())), "duplicate {e:?}");
        }
    }
}
