//! Pre-arena-engine pipeline executors, kept verbatim as the
//! differential-testing reference.
//!
//! These are the original `sim::pipeline` executors from before the
//! flat-arena [`SimEngine`](super::SimEngine) refactor: `Vec<Vec<_>>`
//! done-time tables, fixed-point sweeps, per-call re-derivation of every
//! issue order, and the `O(ops × stages)` rescan greedy for zero-bubble.
//! They are deliberately slow and deliberately untouched — the
//! differential proptest (`tests/sim_differential.rs`), the golden-timeline
//! suite (`tests/golden_timeline.rs`) and the `sim-reference:` benches in
//! `benches/perf_hotpath.rs` all hold the fast engine against this module
//! bit-for-bit, so any behavioral drift in the hot path shows up as a
//! timestamp mismatch rather than a silent re-baseline.
//!
//! The only addition over the historical code is optional
//! [`EventTimeline`] recording, so the reference path can emit the same
//! machine-readable trace the engine emits (the "old-path shim").

use anyhow::Result;

use crate::coordinator::schedule::{
    interleaved_orders, one_f1b_order, Op, PipeOp, ZbEvent, ZbStage,
};
use crate::costmodel::Schedule;
use crate::elastic::FaultPlan;

use super::engine::{EventKind, EventTimeline, TimelineEvent};
use super::pipeline::{
    finish, plan_stage_sims, stage_links, FaultSimResult, SimOptions, SimResult, StageSim,
};

/// Reference (pre-refactor) single-iteration simulation — the slow twin of
/// [`simulate_iteration`](super::simulate_iteration), priced from scratch
/// on every call exactly as the original did.
pub fn simulate_iteration_reference(
    model: &crate::costmodel::ModelShape,
    groups: &[&crate::hetero::ChipGroup],
    strategy: &crate::costmodel::Strategy,
    micro_tokens: usize,
    opts: &SimOptions,
) -> SimResult {
    let stages = plan_stage_sims(model, groups, strategy, micro_tokens, opts);
    let (link, wrap_link) = stage_links(&stages, groups, model, micro_tokens, opts);
    dispatch_reference(&stages, &link, wrap_link, strategy.schedule, strategy.micro_batches, None)
}

/// [`simulate_iteration_reference`] plus the recorded [`EventTimeline`] —
/// the old-path shim the golden harness diffs against the arena engine.
pub fn simulate_iteration_reference_timeline(
    model: &crate::costmodel::ModelShape,
    groups: &[&crate::hetero::ChipGroup],
    strategy: &crate::costmodel::Strategy,
    micro_tokens: usize,
    opts: &SimOptions,
) -> (SimResult, EventTimeline) {
    let stages = plan_stage_sims(model, groups, strategy, micro_tokens, opts);
    let (link, wrap_link) = stage_links(&stages, groups, model, micro_tokens, opts);
    let mut events = Vec::new();
    let r = dispatch_reference(
        &stages,
        &link,
        wrap_link,
        strategy.schedule,
        strategy.micro_batches,
        Some(&mut events),
    );
    let t = EventTimeline::from_events(
        strategy.schedule,
        stages.len(),
        strategy.micro_batches,
        events,
    );
    (r, t)
}

/// Reference fault-path simulation — the original sequential per-step loop
/// of [`simulate_plan_with_faults`](super::simulate_plan_with_faults),
/// re-pricing the scaled stage tables per faulty step.
pub fn simulate_plan_with_faults_reference(
    plan: &crate::plan::ExecutionPlan,
    faults: &FaultPlan,
    steps: usize,
) -> Result<FaultSimResult> {
    let groups = plan.group_refs();
    let opts = plan.sim_options();
    let stages = plan_stage_sims(&plan.model, &groups, &plan.strategy, plan.micro_tokens, &opts);
    let s_n = stages.len();
    faults.validate(s_n)?;
    let (link, wrap_link) = stage_links(&stages, &groups, &plan.model, plan.micro_tokens, &opts);

    let (run_steps, halted_at) = match faults.first_death() {
        Some(death) if death.step < steps => (death.step, Some(death.step)),
        _ => (steps, None),
    };

    // Healthy steps all cost the same — simulate that case once.
    let mut healthy: Option<f64> = None;
    let schedule = plan.strategy.schedule;
    let b = plan.strategy.micro_batches;
    let mut step_seconds = Vec::with_capacity(run_steps);
    for step in 0..run_steps {
        let factors: Vec<(f64, f64)> = (0..s_n).map(|s| faults.factors_at(step, s)).collect();
        if factors.iter().all(|&(cf, nf)| cf == 1.0 && nf == 1.0) {
            let t = match healthy {
                Some(t) => t,
                None => {
                    let r = dispatch_reference(&stages, &link, wrap_link, schedule, b, None);
                    healthy = Some(r.iteration_seconds);
                    r.iteration_seconds
                }
            };
            step_seconds.push(t);
            continue;
        }
        let scaled: Vec<StageSim> = stages
            .iter()
            .enumerate()
            .map(|(s, st)| {
                let (cf, nf) = factors[s];
                StageSim {
                    t_fwd: st.t_fwd * cf,
                    t_bwd: st.t_bwd * cf,
                    t_bwd_input: st.t_bwd_input * cf,
                    t_bwd_weight: st.t_bwd_weight * cf,
                    t_update: (st.t_update - st.t_update_comm) * cf + st.t_update_comm * nf,
                    t_update_comm: st.t_update_comm * nf,
                    ..st.clone()
                }
            })
            .collect();
        let scaled_link: Vec<f64> =
            link.iter().enumerate().map(|(i, &l)| l * factors[i].1).collect();
        let scaled_wrap = wrap_link * factors[s_n - 1].1;
        let r = dispatch_reference(&scaled, &scaled_link, scaled_wrap, schedule, b, None);
        step_seconds.push(r.iteration_seconds);
    }
    Ok(FaultSimResult {
        total_seconds: step_seconds.iter().sum(),
        step_seconds,
        halted_at,
    })
}

/// Route a timing table to its schedule's reference executor.
fn dispatch_reference(
    stages: &[StageSim],
    link: &[f64],
    wrap_link: f64,
    schedule: Schedule,
    micro_batches: usize,
    events: Option<&mut Vec<TimelineEvent>>,
) -> SimResult {
    let exposed = |t: f64| t;
    match schedule {
        Schedule::OneF1B => simulate_1f1b(stages, link, micro_batches, &exposed, events),
        Schedule::Interleaved { virtual_stages } => {
            let v = virtual_stages.max(1);
            simulate_interleaved(stages, link, wrap_link, micro_batches, v, events)
        }
        Schedule::ZeroBubbleV => simulate_zero_bubble(stages, link, micro_batches, events),
    }
}

/// Core 1F1B list scheduler over explicit per-stage op queues.
fn simulate_1f1b(
    stages: &[StageSim],
    link: &[f64],
    micro_batches: usize,
    exposed: &dyn Fn(f64) -> f64,
    mut events: Option<&mut Vec<TimelineEvent>>,
) -> SimResult {
    let s_n = stages.len();
    let b = micro_batches;
    const UNSET: f64 = -1.0;
    // fwd_done[m][s], bwd_done[m][s]
    let mut fwd_done = vec![vec![UNSET; s_n]; b];
    let mut bwd_done = vec![vec![UNSET; s_n]; b];

    // Static 1F1B issue order per stage — the same queue the real training
    // coordinator executes.
    let queues: Vec<Vec<Op>> = (0..s_n).map(|s| one_f1b_order(s, s_n, b)).collect();

    let mut head = vec![0usize; s_n]; // next op index per stage
    let mut clock = vec![0.0f64; s_n]; // stage-busy-until
    let mut busy = vec![0.0f64; s_n];
    let mut exposed_comm = vec![0.0f64; s_n];

    // Fixed-point scheduling: keep sweeping stages until no progress.
    let mut progressed = true;
    while progressed {
        progressed = false;
        for s in 0..s_n {
            while head[s] < queues[s].len() {
                let op = queues[s][head[s]];
                // Readiness: input availability time, or None if dep not done.
                let ready = match op {
                    Op::Fwd(m) => {
                        if s == 0 {
                            Some(0.0)
                        } else if fwd_done[m][s - 1] >= 0.0 {
                            Some(fwd_done[m][s - 1] + exposed(link[s - 1]))
                        } else {
                            None
                        }
                    }
                    Op::Bwd(m) => {
                        if fwd_done[m][s] < 0.0 {
                            None
                        } else if s == s_n - 1 {
                            Some(fwd_done[m][s])
                        } else if bwd_done[m][s + 1] >= 0.0 {
                            Some(bwd_done[m][s + 1] + exposed(link[s]))
                        } else {
                            None
                        }
                    }
                };
                let Some(ready) = ready else { break };
                let start = clock[s].max(ready);
                let (dur, m, is_f) = match op {
                    Op::Fwd(m) => (stages[s].t_fwd, m, true),
                    Op::Bwd(m) => (stages[s].t_bwd, m, false),
                };
                let wait_comm = (ready - clock[s]).max(0.0);
                exposed_comm[s] += wait_comm.min(match op {
                    Op::Fwd(_) if s > 0 => exposed(link[s - 1]),
                    Op::Bwd(_) if s < s_n - 1 => exposed(link[s]),
                    _ => 0.0,
                });
                let end = start + dur;
                clock[s] = end;
                busy[s] += dur;
                if is_f {
                    fwd_done[m][s] = end;
                } else {
                    bwd_done[m][s] = end;
                }
                if let Some(out) = events.as_deref_mut() {
                    out.push(TimelineEvent {
                        stage: s,
                        chunk: 0,
                        micro: m,
                        kind: if is_f { EventKind::Fwd } else { EventKind::Bwd },
                        start,
                        end,
                    });
                }
                head[s] += 1;
                progressed = true;
            }
        }
    }
    debug_assert!(head.iter().zip(&queues).all(|(h, q)| *h == q.len()), "pipeline deadlocked");

    finish(stages, &clock, &busy, &exposed_comm)
}

/// Interleaved 1F1B over `v` virtual chunks per physical stage (the
/// original fixed-point sweep; see the engine's `replay` for the formulas).
fn simulate_interleaved(
    stages: &[StageSim],
    link: &[f64],
    wrap_link: f64,
    micro_batches: usize,
    v: usize,
    mut events: Option<&mut Vec<TimelineEvent>>,
) -> SimResult {
    let s_n = stages.len();
    let b = micro_batches;
    if v <= 1 || s_n == 0 {
        return simulate_1f1b(stages, link, b, &|t| t, events);
    }
    let d_n = s_n * v;

    // Hop latency leaving virtual stage d toward d+1 (or back, for
    // gradients): adjacent physical stages, except the wrap from the last
    // physical stage back to the first between chunks.
    let hop = |d: usize| -> f64 {
        if d % s_n == s_n - 1 {
            wrap_link
        } else {
            link[d % s_n]
        }
    };

    let queues = interleaved_orders(s_n, v, b);

    const UNSET: f64 = -1.0;
    let mut fwd_done = vec![vec![UNSET; d_n]; b];
    let mut bwd_done = vec![vec![UNSET; d_n]; b];
    let mut head = vec![0usize; s_n];
    let mut clock = vec![0.0f64; s_n];
    let mut busy = vec![0.0f64; s_n];
    let mut exposed_comm = vec![0.0f64; s_n];

    let mut progressed = true;
    while progressed {
        progressed = false;
        for s in 0..s_n {
            while head[s] < queues[s].len() {
                let (d, m, fwd) = match queues[s][head[s]] {
                    PipeOp::Fwd { chunk, micro } => (chunk * s_n + s, micro, true),
                    PipeOp::Bwd { chunk, micro } => (chunk * s_n + s, micro, false),
                    PipeOp::BwdWeight { .. } => {
                        unreachable!("interleaved orders have no weight phase")
                    }
                };
                let (ready, comm) = if fwd {
                    if d == 0 {
                        (Some(0.0), 0.0)
                    } else if fwd_done[m][d - 1] >= 0.0 {
                        (Some(fwd_done[m][d - 1] + hop(d - 1)), hop(d - 1))
                    } else {
                        (None, 0.0)
                    }
                } else if fwd_done[m][d] < 0.0 {
                    (None, 0.0)
                } else if d == d_n - 1 {
                    (Some(fwd_done[m][d]), 0.0)
                } else if bwd_done[m][d + 1] >= 0.0 {
                    (Some(bwd_done[m][d + 1] + hop(d)), hop(d))
                } else {
                    (None, 0.0)
                };
                let Some(ready) = ready else { break };
                let dur = if fwd {
                    stages[s].t_fwd / v as f64
                } else {
                    stages[s].t_bwd / v as f64
                };
                let start = clock[s].max(ready);
                exposed_comm[s] += (ready - clock[s]).max(0.0).min(comm);
                let end = start + dur;
                clock[s] = end;
                busy[s] += dur;
                if fwd {
                    fwd_done[m][d] = end;
                } else {
                    bwd_done[m][d] = end;
                }
                if let Some(out) = events.as_deref_mut() {
                    out.push(TimelineEvent {
                        stage: s,
                        chunk: d / s_n,
                        micro: m,
                        kind: if fwd { EventKind::Fwd } else { EventKind::Bwd },
                        start,
                        end,
                    });
                }
                head[s] += 1;
                progressed = true;
            }
        }
    }
    assert!(
        head.iter().zip(&queues).all(|(h, q)| *h == q.len()),
        "interleaved pipeline deadlocked"
    );

    finish(stages, &clock, &busy, &exposed_comm)
}

/// Zero-bubble schedule: the original rescan greedy folded into the
/// per-stage clock/busy/exposed-comm view.
fn simulate_zero_bubble(
    stages: &[StageSim],
    link: &[f64],
    micro_batches: usize,
    mut events: Option<&mut Vec<TimelineEvent>>,
) -> SimResult {
    let s_n = stages.len();
    let zb: Vec<ZbStage> = stages
        .iter()
        .map(|s| ZbStage {
            t_fwd: s.t_fwd,
            t_bwd_input: s.t_bwd_input,
            t_bwd_weight: s.t_bwd_weight,
        })
        .collect();
    let mut clock = vec![0.0f64; s_n];
    let mut busy = vec![0.0f64; s_n];
    let mut exposed_comm = vec![0.0f64; s_n];
    for e in zb_events_scan(&zb, link, micro_batches) {
        clock[e.stage] = e.end;
        busy[e.stage] += e.end - e.start;
        exposed_comm[e.stage] += e.wait_comm;
        if let Some(out) = events.as_deref_mut() {
            let (chunk, micro, kind) = match e.op {
                PipeOp::Fwd { chunk, micro } => (chunk, micro, EventKind::Fwd),
                PipeOp::Bwd { chunk, micro } => (chunk, micro, EventKind::Bwd),
                PipeOp::BwdWeight { chunk, micro } => (chunk, micro, EventKind::BwdWeight),
            };
            out.push(TimelineEvent {
                stage: e.stage,
                chunk,
                micro,
                kind,
                start: e.start,
                end: e.end,
            });
        }
    }

    finish(stages, &clock, &busy, &exposed_comm)
}

/// The original `O(ops × stages)` zero-bubble greedy: every pick rescans
/// every stage's B/F/W candidates. Kept verbatim so the heap-based
/// [`ZbRunner`](crate::coordinator::schedule::ZbRunner) has a fixed point
/// of comparison (`heap_greedy_matches_the_reference_scan`).
pub(crate) fn zb_events_scan(stages: &[ZbStage], link: &[f64], b: usize) -> Vec<ZbEvent> {
    let s_n = stages.len();
    if s_n == 0 || b == 0 {
        return Vec::new();
    }
    const UNSET: f64 = -1.0;
    let mut fwd_done = vec![vec![UNSET; s_n]; b];
    let mut bwd_done = vec![vec![UNSET; s_n]; b]; // input-gradient phase end
    let mut next_f = vec![0usize; s_n];
    let mut next_b = vec![0usize; s_n];
    let mut next_w = vec![0usize; s_n];
    let cap: Vec<usize> = (0..s_n).map(|s| (s_n - s).min(b).max(1)).collect();

    let mut clock = vec![0.0f64; s_n];
    let mut events = Vec::with_capacity(3 * b * s_n);

    // Op kinds by tie-break priority: B (0) > F (1) > W (2).
    let total_ops = 3 * b * s_n;
    for _ in 0..total_ops {
        // (start, priority, stage) minimal over every stage's candidates.
        let mut best: Option<(f64, u8, usize, f64)> = None; // +ready for comm
        let mut consider = |start: f64, prio: u8, s: usize, ready: f64| {
            let better = match &best {
                None => true,
                Some((bs, bp, bi, _)) => (start, prio, s) < (*bs, *bp, *bi),
            };
            if better {
                best = Some((start, prio, s, ready));
            }
        };
        for s in 0..s_n {
            if next_b[s] < b {
                let m = next_b[s];
                if fwd_done[m][s] >= 0.0 {
                    let ready = if s == s_n - 1 {
                        Some(fwd_done[m][s])
                    } else if bwd_done[m][s + 1] >= 0.0 {
                        Some(bwd_done[m][s + 1] + link[s])
                    } else {
                        None
                    };
                    if let Some(r) = ready {
                        consider(clock[s].max(r), 0, s, r);
                    }
                }
            }
            if next_f[s] < b && next_f[s] - next_b[s] < cap[s] {
                let m = next_f[s];
                let ready = if s == 0 {
                    Some(0.0)
                } else if fwd_done[m][s - 1] >= 0.0 {
                    Some(fwd_done[m][s - 1] + link[s - 1])
                } else {
                    None
                };
                if let Some(r) = ready {
                    consider(clock[s].max(r), 1, s, r);
                }
            }
            if next_w[s] < next_b[s] {
                consider(clock[s], 2, s, clock[s]);
            }
        }
        let (start, prio, s, ready) = best.expect("zero-bubble schedule deadlocked");
        let dur = match prio {
            0 => stages[s].t_bwd_input,
            1 => stages[s].t_fwd,
            _ => stages[s].t_bwd_weight,
        };
        // Exposed comm: the wait attributable to the inbound hop.
        let wait_comm = if prio < 2 {
            let hop = match prio {
                0 if s < s_n - 1 => link[s],
                1 if s > 0 => link[s - 1],
                _ => 0.0,
            };
            (ready - clock[s]).max(0.0).min(hop)
        } else {
            0.0
        };
        let end = start + dur;
        clock[s] = end;
        let op = match prio {
            0 => {
                let m = next_b[s];
                bwd_done[m][s] = end;
                next_b[s] += 1;
                PipeOp::Bwd { chunk: 0, micro: m }
            }
            1 => {
                let m = next_f[s];
                fwd_done[m][s] = end;
                next_f[s] += 1;
                PipeOp::Fwd { chunk: 0, micro: m }
            }
            _ => {
                let m = next_w[s];
                next_w[s] += 1;
                PipeOp::BwdWeight { chunk: 0, micro: m }
            }
        };
        events.push(ZbEvent { stage: s, op, ready, start, end, wait_comm });
    }
    events
}
